//! Quickstart: submit one geo-distributed TPC-H job to HOUTU and watch
//! its lifecycle — replicated JMs, Af resource ramp, Parades locality.
//!
//! Run: `cargo run --release --example quickstart`

use houtu::config::{Config, Deployment};
use houtu::dag::{SizeClass, WorkloadKind};
use houtu::deploy::{run_single_job, SingleJobPlan};
use houtu::ids::{DcId, JobId};

fn main() {
    let cfg = Config::default();
    println!("HOUTU quickstart — TPC-H Q3 across {} regions", cfg.topology.num_dcs());
    println!("regions: {:?}", cfg.topology.regions);
    println!("containers: {} ({} per region)\n", cfg.topology.total_containers(), cfg.topology.containers_per_dc());

    let world = run_single_job(
        &cfg,
        Deployment::Houtu,
        SingleJobPlan {
            kind: WorkloadKind::TpcH,
            size: SizeClass::Medium,
            home: DcId(0),
            inject_at: None,
            kill_jm_at: None,
        },
    );

    let job = JobId(0);
    let rec = &world.metrics.jobs[&job];
    let rt = &world.jobs[&job];
    println!("job {job}: {} {} submitted to {}", rec.kind.name(), rec.size.name(), rt.spec.home_dc);
    println!("stages: {}   tasks: {}", rt.spec.stages.len(), rec.tasks_total);
    println!("work T1 = {:.1} container-seconds, critical path T∞ = {:.1}s", rt.spec.work(), rt.spec.critical_path());
    println!("\nper-region job managers:");
    for (dc, jm) in &rt.jms {
        println!(
            "  {} {:<11} node-local {:>3}  rack-local {:>3}  any {:>3}  stolen-in {:>2}  stolen-out {:>2}",
            dc,
            format!("{:?}", jm.role),
            jm.stats.assigned_node_local,
            jm.stats.assigned_rack_local,
            jm.stats.assigned_any,
            jm.stats.tasks_stolen_in,
            jm.stats.tasks_stolen_out,
        );
    }
    println!("\njob response time: {:.1}s", rec.jrt().unwrap());
    println!(
        "task input locality: {} local / {} cross-DC fetches",
        world.metrics.local_input_tasks, world.metrics.remote_input_tasks
    );
    println!(
        "cross-DC traffic: {} ({} control msgs)",
        houtu::util::fmt_bytes(world.wan.stats.cross_dc_total_bytes()),
        world.wan.stats.messages
    );
    println!("intermediate info final size: {} bytes", rt.info.encoded_size());
}
