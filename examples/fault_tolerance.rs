//! Fault tolerance walkthrough (§6.4 / Fig 11): kill the VM hosting a JM
//! at t=70 s and watch HOUTU continue while the centralized baseline
//! resubmits from scratch.
//!
//! Run: `cargo run --release --example fault_tolerance`

use houtu::config::{Config, Deployment};
use houtu::dag::{SizeClass, WorkloadKind};
use houtu::deploy::{run_single_job, SingleJobPlan};
use houtu::ids::{DcId, JobId};

fn scenario(label: &str, mode: Deployment, kill_dc: DcId) {
    let cfg = Config::default();
    let w = run_single_job(
        &cfg,
        mode,
        SingleJobPlan {
            kind: WorkloadKind::WordCount,
            size: SizeClass::Large,
            home: DcId(0),
            inject_at: None,
            kill_jm_at: Some((70.0, kill_dc)),
        },
    );
    let rec = &w.metrics.jobs[&JobId(0)];
    println!("--- {label} ---");
    println!("JRT: {:.0}s   restarts: {}   recoveries: {}", rec.jrt().unwrap(), rec.restarts, rec.recoveries);
    if let Some(iv) = w.metrics.recovery_intervals_secs.first() {
        println!("recovery interval (kill → successor operating): {iv:.1}s");
    }
    if let Some(el) = w.metrics.election_delays_secs.first() {
        println!("pJM election delay: {el:.2}s");
    }
    if mode == Deployment::Houtu {
        let rt = &w.jobs[&JobId(0)];
        println!("primary ended at {} (started at dc0)", rt.primary);
    }
    println!();
}

fn main() {
    println!("HOUTU job-level fault tolerance (JM VM killed at t=70s)\n");
    scenario("HOUTU — kill the PRIMARY JM (election + continue)", Deployment::Houtu, DcId(0));
    scenario("HOUTU — kill a SEMI-ACTIVE JM (inherit containers + continue)", Deployment::Houtu, DcId(2));
    scenario("centralized baseline — kill the only JM (full resubmission)", Deployment::CentDyna, DcId(0));
}
