//! Cost analysis (§6.3 / Fig 10): the same online workload on all four
//! deployments; decentralized ones ride Spot instances, centralized ones
//! On-demand. Prints the machine + communication cost breakdown.
//!
//! Run: `cargo run --release --example spot_cost`

use houtu::cloud::fig3_prices;
use houtu::config::{Config, Deployment};
use houtu::exp;

fn main() {
    let cfg = Config::default();
    println!("Spot vs On-demand economics (AliCloud row of Fig 3):");
    for r in fig3_prices() {
        if r.provider == "AliCloud" {
            println!(
                "  on-demand ${}/h vs spot ~${}/h  ({}x cheaper, no reliability SLA)",
                r.on_demand_hourly,
                r.spot_hourly,
                (r.on_demand_hourly / r.spot_hourly).round()
            );
        }
    }
    println!("\nrunning the {}-job online trace on all four deployments...\n", cfg.workload.num_jobs);
    let results: Vec<_> = Deployment::ALL.iter().map(|&m| exp::run_deployment(&cfg, m)).collect();
    print!("{}", exp::fig10_cost(&results));
    println!("\n(The machine-cost gap is spot pricing x the makespan gap; the");
    println!(" communication gap is HOUTU keeping tasks in their data's region");
    println!(" unless stolen after the 2τ·p patience threshold.)");
}
