//! END-TO-END VALIDATION (DESIGN.md): the full three-layer stack on a
//! real small workload.
//!
//! The HOUTU coordinator (L3, rust) schedules an online trace of
//! geo-distributed jobs across four simulated regions. Every Iterative-ML
//! gradient stage, PageRank iteration stage and WordCount reduce that the
//! coordinator completes triggers *real numerics* through the PJRT
//! runtime executing the JAX/Pallas artifacts (L2/L1, compiled once by
//! `make artifacts`):
//!
//! * Iterative-ML: per-DC logistic-regression shards; each gradient stage
//!   runs one local-SGD step per sub-job shard and averages the weights —
//!   the loss curve is printed and must decrease.
//! * PageRank: a 256-node synthetic web graph; each iteration stage runs
//!   one damped power-iteration — the L1 residual is printed and must
//!   shrink; rank mass stays 1.
//! * WordCount: the reduce stage aggregates token counts via the one-hot
//!   matmul kernel; totals are checked against a host-side count.
//!
//! Finally the scheduler-level headline (avg JRT + makespan, HOUTU vs
//! cent-stat) is reported. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_geo_analytics`

use std::collections::BTreeMap;

use houtu::config::{Config, Deployment};
use houtu::dag::WorkloadKind;
use houtu::deploy::world::ComputeHook;
use houtu::deploy::{build_sim, schedule_trace};
use houtu::ids::{DcId, JobId, StageId};
use houtu::runtime::{default_artifact_dir, Runtime, LOGREG_D, LOGREG_N, PAGERANK_N, SEG_K, SEG_N, SEG_V};
use houtu::sim::secs;
use houtu::util::Pcg;
use houtu::workloads::WorkloadGen;

struct MlJob {
    /// Per-DC shards: (x, y) with LOGREG_N rows each.
    shards: Vec<(Vec<f32>, Vec<f32>)>,
    w: Vec<f32>,
    losses: Vec<f32>,
}

struct PrJob {
    m: Vec<f32>,
    r: Vec<f32>,
    residuals: Vec<f32>,
}

struct RealCompute {
    rt: Runtime,
    rng: Pcg,
    ml: BTreeMap<JobId, MlJob>,
    pr: BTreeMap<JobId, PrJob>,
    wc_checked: u32,
    log: Vec<String>,
}

impl RealCompute {
    fn new(rt: Runtime) -> Self {
        RealCompute { rt, rng: Pcg::seeded(2024), ml: BTreeMap::new(), pr: BTreeMap::new(), wc_checked: 0, log: Vec::new() }
    }

    fn ml_job(&mut self, job: JobId, num_dcs: usize) -> &mut MlJob {
        let rng = &mut self.rng;
        self.ml.entry(job).or_insert_with(|| {
            // Separable synthetic data, one shard per region.
            let w_true: Vec<f32> = (0..LOGREG_D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let shards = (0..num_dcs)
                .map(|_| {
                    let x: Vec<f32> =
                        (0..LOGREG_N * LOGREG_D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
                    let y: Vec<f32> = (0..LOGREG_N)
                        .map(|i| {
                            let dot: f32 =
                                (0..LOGREG_D).map(|j| x[i * LOGREG_D + j] * w_true[j]).sum();
                            if dot > 0.0 {
                                1.0
                            } else {
                                0.0
                            }
                        })
                        .collect();
                    (x, y)
                })
                .collect();
            MlJob { shards, w: vec![0.0; LOGREG_D], losses: Vec::new() }
        })
    }

    fn pr_job(&mut self, job: JobId) -> &mut PrJob {
        let rng = &mut self.rng;
        self.pr.entry(job).or_insert_with(|| {
            let n = PAGERANK_N;
            let mut m = vec![0.0f32; n * n];
            for c in 0..n {
                let mut deg = 0;
                for r in 0..n {
                    if rng.chance(0.04) {
                        m[r * n + c] = 1.0;
                        deg += 1;
                    }
                }
                if deg == 0 {
                    m[c] = 1.0;
                    deg = 1;
                }
                for r in 0..n {
                    m[r * n + c] /= deg as f32;
                }
            }
            PrJob { m, r: vec![1.0 / n as f32; n], residuals: Vec::new() }
        })
    }
}

impl ComputeHook for RealCompute {
    fn on_task_finished(&mut self, _job: JobId, _kind: WorkloadKind, _stage: StageId, _i: u32, _dc: DcId) {}

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn on_stage_done(&mut self, job: JobId, kind: WorkloadKind, stage: StageId) {
        match kind {
            WorkloadKind::IterativeMl if stage.0 >= 1 => {
                self.ml_job(job, 4);
                // One local-SGD step per regional shard, then average —
                // the stage's tasks ARE the shard computations.
                let mlj = &self.ml[&job];
                let w0 = mlj.w.clone();
                let shards = mlj.shards.clone();
                let nsh = shards.len();
                let mut acc = vec![0.0f32; LOGREG_D];
                let mut loss_acc = 0.0;
                for (x, y) in &shards {
                    let (w2, loss) = self.rt.logreg_step(&w0, x, y, 0.5).expect("logreg step");
                    for (a, b) in acc.iter_mut().zip(&w2) {
                        *a += b / nsh as f32;
                    }
                    loss_acc += loss / nsh as f32;
                }
                let mlj = self.ml.get_mut(&job).unwrap();
                mlj.w = acc;
                mlj.losses.push(loss_acc);
                self.log.push(format!("  {job} ML stage {stage}: mean shard loss {loss_acc:.4}"));
            }
            WorkloadKind::PageRank if stage.0 >= 1 => {
                self.pr_job(job);
                let prj = &self.pr[&job];
                let (m, r) = (prj.m.clone(), prj.r.clone());
                let (r2, resid) = self.rt.pagerank_step(&m, &r, 0.85).expect("pagerank step");
                let prj = self.pr.get_mut(&job).unwrap();
                prj.r = r2;
                prj.residuals.push(resid);
                self.log.push(format!("  {job} PageRank stage {stage}: residual {resid:.5}"));
            }
            WorkloadKind::WordCount if stage.0 == 1 => {
                // The reduce stage: aggregate synthetic token counts.
                let mut onehot = vec![0.0f32; SEG_N * SEG_K];
                let mut expect = vec![0.0f32; SEG_K];
                for i in 0..SEG_N {
                    let k = self.rng.index(SEG_K);
                    onehot[i * SEG_K + k] = 1.0;
                    expect[k] += 1.0;
                }
                let values: Vec<f32> =
                    (0..SEG_N * SEG_V).map(|i| if i % SEG_V == 0 { 1.0 } else { 0.0 }).collect();
                let out = self.rt.wordcount_agg(&onehot, &values).expect("wordcount agg");
                for k in 0..SEG_K {
                    assert!((out[k * SEG_V] - expect[k]).abs() < 1e-3, "wordcount mismatch");
                }
                self.wc_checked += 1;
                self.log.push(format!("  {job} WordCount reduce: {SEG_N} tokens over {SEG_K} keys ok"));
            }
            _ => {}
        }
    }

    fn on_job_done(&mut self, job: JobId, kind: WorkloadKind) {
        if kind == WorkloadKind::IterativeMl {
            if let Some(m) = self.ml.get(&job) {
                self.log.push(format!(
                    "  {job} ML done: loss {:.4} -> {:.4} over {} stages",
                    m.losses.first().unwrap_or(&f32::NAN),
                    m.losses.last().unwrap_or(&f32::NAN),
                    m.losses.len()
                ));
            }
        }
    }
}

fn main() {
    let cfg = Config::default();
    println!("=== e2e: HOUTU coordinator + PJRT-executed JAX/Pallas compute ===\n");
    let rt = Runtime::load(&default_artifact_dir()).expect("run `make artifacts` first");

    let trace = {
        let mut gen = WorkloadGen::new(&cfg, Pcg::new(cfg.seed, 777));
        gen.trace(&cfg, cfg.workload.num_jobs)
    };
    let horizon = secs(14_400);
    let mut sim = build_sim(cfg.clone(), Deployment::Houtu, horizon);
    sim.state.hook = Some(Box::new(RealCompute::new(rt)));
    schedule_trace(&mut sim, &trace);
    let t0 = std::time::Instant::now();
    sim.run_until(horizon);
    let wall = t0.elapsed();

    let w = &sim.state;
    assert_eq!(w.metrics.completed_jobs(), cfg.workload.num_jobs, "all jobs must finish");

    let rc: &RealCompute = w
        .hook
        .as_ref()
        .unwrap()
        .as_any()
        .downcast_ref()
        .expect("hook is RealCompute");
    println!("real-compute log (every line = PJRT executions of the AOT artifacts):");
    for line in &rc.log {
        println!("{line}");
    }

    println!("\nvalidation:");
    let mut ml_ok = 0;
    for (job, m) in &rc.ml {
        let first = m.losses.first().copied().unwrap_or(f32::NAN);
        let last = m.losses.last().copied().unwrap_or(f32::NAN);
        assert!(last < first, "{job}: ML loss did not decrease ({first} -> {last})");
        ml_ok += 1;
    }
    let mut pr_ok = 0;
    for (job, p) in &rc.pr {
        let first = p.residuals.first().copied().unwrap_or(f32::NAN);
        let last = p.residuals.last().copied().unwrap_or(f32::NAN);
        assert!(last < first, "{job}: PageRank residual did not shrink");
        let mass: f32 = p.r.iter().sum();
        assert!((mass - 1.0).abs() < 1e-3, "{job}: rank mass {mass}");
        pr_ok += 1;
    }
    println!("  {ml_ok} ML jobs: loss strictly decreased (local-SGD over 4 regional shards)");
    println!("  {pr_ok} PageRank jobs: residual shrank, rank mass conserved");
    println!("  {} WordCount reduces verified against host-side counts", rc.wc_checked);
    println!("  {} PJRT executions total", rc.rt.executions.get());

    println!("\nscheduler headline (same trace, HOUTU vs cent-stat):");
    let base = houtu::exp::run_deployment(&cfg, Deployment::CentStat);
    println!(
        "  houtu    : avg JRT {:>5.0}s   makespan {:>5.0}s",
        w.metrics.avg_jrt(),
        w.metrics.makespan()
    );
    println!(
        "  cent-stat: avg JRT {:>5.0}s   makespan {:>5.0}s",
        base.avg_jrt, base.makespan
    );
    println!("\ne2e complete in {wall:.2?} (simulated {:.0}s of cluster time)", w.metrics.makespan());
}
