//! First-class benchmark harness: the `BENCH_*.json` perf trajectory.
//!
//! Every PR that claims a hot-path win needs a number, and one-off
//! figure scripts don't accumulate into a trajectory. This module is the
//! repeatable measurement harness behind `houtu bench [--smoke]
//! [--iters N] [--report BENCH_sim.json]`: a fixed set of
//! scenario-backed workloads, a warmup/iters timing loop, and a
//! round-trip-verified JSON report (via the in-repo [`crate::util::json`]
//! parser, same contract as the campaign/fuzz exports).
//!
//! # Workloads
//!
//! * `campaign-smoke` — every cell of [`crate::scenario::smoke_campaign`]
//!   run serially through [`run_scenario_on`]: the end-to-end DES +
//!   deployment-stack number. Run on **both** queue engines
//!   (`…-legacy` is the vendored pre-overhaul queue), so every report
//!   carries the measured old-vs-new ratio — the speedup claim is
//!   re-measured on every run, not frozen in a PR description.
//! * `fuzz-batch` — a deterministic batch of generated chaos cells
//!   (seeded [`CellGen`]), the shape `houtu fuzz` hammers.
//! * `soak-slice` — a slice of the long-horizon soak load: the online
//!   trace workload under spot revocations across several seeds.
//! * `dense-cancel-churn` — a queue microbenchmark: schedule/cancel
//!   storms plus periodic timer chains, the access pattern that made the
//!   old tombstone-set queue hurt. Also run on both engines.
//! * `bid-churn-{naive,adaptive,deadline}` — the cost-aware bidding
//!   subsystem under a revocation-heavy spot-price storm, once per
//!   [`StrategyKind`]; each row reports the run's total USD next to its
//!   wall time, so the report carries the measured cost/latency
//!   trade-off per strategy (insurance replication rides along for the
//!   non-naive strategies).
//! * `dispatch-churn-{typed,boxed}` — the event-representation
//!   microbenchmark behind the typed-payload refactor: the identical
//!   schedule executed once as a typed payload enum (zero allocations on
//!   the hot path) and once as per-event boxed closures (the
//!   pre-refactor representation). The pair is the measured
//!   typed-vs-boxed `events_per_sec` claim.
//! * `multi-dc-churn` — the parallel-engine workload: token chains
//!   hopping between four DC parts with WAN-floor delays and a heavy
//!   hash-mixing core per hop, run once on the sequential engine and
//!   once (`…-sharded`) on the conservative-parallel
//!   [`crate::sim::ShardedSim`] with one thread per shard. Both drivers
//!   share one pure hop core, so event counts and state checksums match
//!   exactly and the row pair is the measured sharded-vs-sequential
//!   speedup. `campaign-smoke` also gets a `…-sharded` row — that one
//!   exercises the exact-merge [`crate::sim::ShardedQueue`] under the
//!   full deployment stack (a determinism gate, not a parallel claim).
//! * `campaign-smoke-parts` / `campaign-smoke-threaded` — the smoke
//!   campaign on the World-as-parts model ([`crate::deploy::parts`]):
//!   the identical cell matrix executed on [`crate::sim::ShardedSim`]'s
//!   serial round twin (1 shard) and on 4 real threads. The digests are
//!   pinned thread-count-invariant by the differential wall, so the row
//!   pair is the measured threaded-vs-sequential campaign speedup
//!   ([`BenchReport::threaded_speedup`]).
//! * `planet-churn-{64,256}dc` — the two-tier fidelity claim: the same
//!   trace workload on generated planet-scale worlds
//!   (`topology.generated`, [`crate::topo`]) with `exact_dcs = 4`, so
//!   only the job-touching tier simulates exactly while 60 vs 252
//!   background DCs ride the aggregate tier. Flat `events_per_sec`
//!   across the pair is the measured background-DC independence; the
//!   render footer reports the SoA per-node memory
//!   ([`crate::cluster::soa_bytes_per_node`]) next to the rows.
//!
//! # Baseline gate
//!
//! `houtu bench --compare BENCH_baseline.json` re-checks every workload's
//! `events_per_sec` against a committed baseline report and fails (exit
//! non-zero) on a regression beyond a generous noise band derived from
//! the baseline's own wall-clock spread — see [`compare_to_baseline`].
//!
//! # History trajectory
//!
//! `houtu bench --history BENCH_history.jsonl` appends one JSON line
//! per run — UTC seconds, the repo's short git SHA, the smoke flag and
//! every workload's `events_per_sec` keyed by name — so the perf
//! trajectory accumulates across commits instead of each report
//! overwriting the last ([`append_history`]).
//!
//! # Report schema (`BENCH_sim.json`)
//!
//! ```json
//! {
//!   "bench": "sim-hot-path",
//!   "smoke": false,
//!   "workloads": [
//!     {"name": "campaign-smoke", "queue": "slab", "iters": 3,
//!      "warmup": 1, "events_total": 123456, "peak_pending": 789,
//!      "wall_ms_mean": 12.5, "wall_ms_min": 12.1, "wall_ms_max": 13.0,
//!      "events_per_sec": 9876543.2, "usd": 0.0}
//!   ]
//! }
//! ```
//!
//! `events_total` is summed over the timed iterations, `events_per_sec`
//! is `events_total / total_wall_secs`, and `peak_pending` is the
//! highest queue depth any run reached ([`crate::sim::Sim::peak_pending`]).
//! Adding a workload = adding a [`BenchWorkload`] variant and its
//! `run_once` arm; the report, CLI and round-trip check pick it up
//! automatically.

use std::fmt::Write as _;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use crate::cloud::bidding::StrategyKind;
use crate::config::{Config, Deployment};
use crate::ids::DcId;
use crate::scenario::{
    resolve_threads, run_scenario_on, smoke_campaign, CellGen, ChaosEvent, FuzzSpace,
    ScenarioSpec, ScenarioWorkload,
};
use crate::sim::{every, Dispatch, Lookahead, QueueKind, ShardCtx, ShardEvent, ShardedSim, Sim};
use crate::testkit::Gen as _;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::util::{stats, Pcg};
use crate::{anyhow, ensure};

/// Harness knobs (the CLI surface).
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Shrink workload scales and run one untimed-warmup-free iteration
    /// (the ci.sh gate).
    pub smoke: bool,
    /// Timed iterations per workload.
    pub iters: usize,
    /// Untimed warmup iterations per workload.
    pub warmup: usize,
    /// Thread/shard count for the sharded rows (0 = `HOUTU_THREADS`,
    /// else one per core — [`resolve_threads`]).
    pub threads: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { smoke: false, iters: 3, warmup: 1, threads: 0 }
    }
}

impl BenchOpts {
    /// The fast ci.sh configuration.
    pub fn smoke() -> Self {
        BenchOpts { smoke: true, iters: 1, warmup: 0, threads: 0 }
    }
}

/// What one workload iteration produced.
struct IterOut {
    events: u64,
    peak_pending: usize,
    /// Run-level cost (USD) — nonzero only for the bid-churn family.
    usd: f64,
}

/// The fixed workload set. See the module docs for what each measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchWorkload {
    CampaignSmoke,
    FuzzBatch,
    SoakSlice,
    /// The smoke load ramp (`houtu load --smoke`): open-loop arrivals,
    /// per-step folding and knee detection — the latency-under-load axis.
    LoadKnee,
    DenseCancelChurn,
    /// Spot-storm trace under the given bid strategy (cost + wall time).
    BidChurn(StrategyKind),
    /// The identical event schedule dispatched typed (payload enum) vs
    /// boxed (one heap closure per event).
    DispatchChurn { typed: bool },
    /// Token chains hopping between DC parts over WAN-floor delays —
    /// sequential on [`QueueKind::Slab`], thread-per-shard on
    /// [`QueueKind::Sharded`] (the measured parallel speedup pair).
    MultiDcChurn,
    /// The smoke campaign on the World-as-parts model, with this many
    /// ShardedSim shards (1 = the serial round twin; the matrix pairs it
    /// with 4 for the threaded-vs-sequential campaign speedup).
    CampaignSmokeParts { threads: usize },
    /// The trace workload on a generated `dcs`-DC world with a 4-DC
    /// exact tier (`topology.exact_dcs=4`) — the matrix pairs 64 with
    /// 256 so the report carries the background-DC scaling claim.
    PlanetChurn { dcs: usize },
}

impl BenchWorkload {
    pub fn name(self) -> &'static str {
        match self {
            BenchWorkload::CampaignSmoke => "campaign-smoke",
            BenchWorkload::FuzzBatch => "fuzz-batch",
            BenchWorkload::SoakSlice => "soak-slice",
            BenchWorkload::LoadKnee => "load-knee",
            BenchWorkload::DenseCancelChurn => "dense-cancel-churn",
            BenchWorkload::BidChurn(StrategyKind::Naive) => "bid-churn-naive",
            BenchWorkload::BidChurn(StrategyKind::Adaptive) => "bid-churn-adaptive",
            BenchWorkload::BidChurn(StrategyKind::Deadline) => "bid-churn-deadline",
            BenchWorkload::DispatchChurn { typed: true } => "dispatch-churn-typed",
            BenchWorkload::DispatchChurn { typed: false } => "dispatch-churn-boxed",
            BenchWorkload::MultiDcChurn => "multi-dc-churn",
            BenchWorkload::CampaignSmokeParts { threads: 1 } => "campaign-smoke-parts",
            BenchWorkload::CampaignSmokeParts { .. } => "campaign-smoke-threaded",
            BenchWorkload::PlanetChurn { dcs: 64 } => "planet-churn-64dc",
            BenchWorkload::PlanetChurn { .. } => "planet-churn-256dc",
        }
    }

    fn run_once(self, base: &Config, queue: QueueKind, smoke: bool) -> IterOut {
        match self {
            BenchWorkload::CampaignSmoke => {
                let spec = smoke_campaign();
                let mut out = IterOut { events: 0, peak_pending: 0, usd: 0.0 };
                for (sc, seed) in spec.expand() {
                    let run = run_scenario_on(base, &sc, seed, queue)
                        .expect("smoke campaign cells are always valid");
                    out.events += run.events_processed;
                    out.peak_pending = out.peak_pending.max(run.peak_pending);
                }
                out
            }
            BenchWorkload::FuzzBatch => {
                let space = FuzzSpace::default();
                let gen = CellGen::new(&space, base);
                let mut rng = Pcg::seeded(0xBE7C);
                let cells = if smoke { 3 } else { 6 };
                let mut out = IterOut { events: 0, peak_pending: 0, usd: 0.0 };
                for _ in 0..cells {
                    let cell = gen.generate(&mut rng);
                    // Chaos cells may legitimately trip simulator
                    // assertions (the fuzzer reports those as findings);
                    // the bench must time a deterministic batch either
                    // way, so panics count as a zero-event run.
                    let done = catch_unwind(AssertUnwindSafe(|| {
                        run_scenario_on(base, &cell.spec, cell.seed, queue)
                    }));
                    if let Ok(Ok(run)) = done {
                        out.events += run.events_processed;
                        out.peak_pending = out.peak_pending.max(run.peak_pending);
                    }
                }
                out
            }
            BenchWorkload::SoakSlice => {
                let num_jobs = if smoke { 2 } else { 4 };
                let seeds: &[u64] = if smoke { &[42] } else { &[42, 7, 1234] };
                let sc = ScenarioSpec {
                    name: "soak-slice".to_string(),
                    deployment: Deployment::Houtu,
                    regions: 0,
                    workload: ScenarioWorkload::Trace { num_jobs },
                    events: vec![],
                    overrides: vec![
                        "cloud.revocations=true".to_string(),
                        "cloud.spot_volatility=0.5".to_string(),
                        "cloud.market_period_secs=120.0".to_string(),
                        "cloud.bid_multiplier=1.5".to_string(),
                    ],
                };
                let mut out = IterOut { events: 0, peak_pending: 0, usd: 0.0 };
                for &seed in seeds {
                    let run = run_scenario_on(base, &sc, seed, queue)
                        .expect("soak slice spec is always valid");
                    out.events += run.events_processed;
                    out.peak_pending = out.peak_pending.max(run.peak_pending);
                }
                out
            }
            BenchWorkload::LoadKnee => {
                let spec = crate::load::smoke_spec();
                let out = crate::load::run_load_on(base, &spec, 42, queue)
                    .expect("smoke load spec is always valid");
                IterOut { events: out.events_processed, peak_pending: out.peak_pending, usd: 0.0 }
            }
            BenchWorkload::DenseCancelChurn => {
                let n = if smoke { 60_000 } else { 200_000 };
                dense_cancel_churn(queue, n)
            }
            BenchWorkload::DispatchChurn { typed } => {
                let n = if smoke { 60_000 } else { 200_000 };
                dispatch_churn(queue, n, typed)
            }
            BenchWorkload::MultiDcChurn => {
                let (chains, hops) = if smoke { (256, 150) } else { (1024, 400) };
                multi_dc_churn(queue, chains, hops).0
            }
            BenchWorkload::CampaignSmokeParts { threads } => {
                let spec = smoke_campaign();
                let report = crate::deploy::run_campaign_parts(base, &spec, threads)
                    .expect("smoke campaign cells are always valid on the parts engine");
                IterOut {
                    events: report.cells.iter().map(|c| c.events).sum(),
                    peak_pending: report.cells.iter().map(|c| c.peak).max().unwrap_or(0),
                    usd: 0.0,
                }
            }
            BenchWorkload::PlanetChurn { dcs } => {
                // The same exact-tier work at every scale: only the 60
                // vs 252 aggregate-tier background DCs differ, so the
                // row pair isolates the background-scan cost.
                let sc = ScenarioSpec {
                    name: format!("planet-churn-{dcs}dc"),
                    deployment: Deployment::Houtu,
                    regions: 0,
                    workload: ScenarioWorkload::Trace {
                        num_jobs: if smoke { 2 } else { 4 },
                    },
                    events: vec![],
                    overrides: vec![
                        format!("topology.generated=generated:{dcs},8,1"),
                        "topology.exact_dcs=4".to_string(),
                    ],
                };
                let cell = crate::deploy::run_cell_on_parts(base, &sc, 42, 1)
                    .expect("planet churn spec is always valid");
                IterOut { events: cell.events, peak_pending: cell.peak, usd: 0.0 }
            }
            BenchWorkload::BidChurn(strategy) => {
                // The bid-insurance-storm shape: a revocation-heavy price
                // storm over the online trace, priced by one strategy.
                // Insurance rides along for the non-naive strategies so
                // the row reflects the whole subsystem's overhead.
                let num_jobs = if smoke { 2 } else { 3 };
                let seeds: &[u64] = if smoke { &[42] } else { &[42, 7] };
                let mut overrides = vec![
                    "cloud.revocations=true".to_string(),
                    "cloud.bid_multiplier=1.5".to_string(),
                    "cloud.market_period_secs=120.0".to_string(),
                    format!("bidding.strategy={}", strategy.name()),
                ];
                if strategy != StrategyKind::Naive {
                    overrides.push("bidding.insurance=true".to_string());
                }
                if strategy == StrategyKind::Deadline {
                    // Without a soft deadline the policy never leaves its
                    // calm baseline; a tight one makes the row measure
                    // the aggressive-bidding path, not an inert no-op.
                    overrides.push("workload.deadline_secs=300".to_string());
                    overrides.push("workload.budget_usd=5.0".to_string());
                }
                let sc = ScenarioSpec {
                    name: format!("bid-churn-{}", strategy.name()),
                    deployment: Deployment::Houtu,
                    regions: 0,
                    workload: ScenarioWorkload::Trace { num_jobs },
                    events: vec![ChaosEvent::SpotStorm {
                        at_secs: 120.0,
                        dc: DcId(1),
                        dur_secs: 600.0,
                        sigma_factor: 3.0,
                    }],
                    overrides,
                };
                let mut out = IterOut { events: 0, peak_pending: 0, usd: 0.0 };
                for &seed in seeds {
                    let run = run_scenario_on(base, &sc, seed, queue)
                        .expect("bid churn spec is always valid");
                    out.events += run.events_processed;
                    out.peak_pending = out.peak_pending.max(run.peak_pending);
                    out.usd += run.world.cost.total_usd();
                }
                out
            }
        }
    }
}

/// Queue microbenchmark: a schedule/cancel storm (half of everything
/// scheduled gets cancelled, hitting the O(1)-cancel path hard) plus
/// self-rescheduling timer chains, then a full drain.
fn dense_cancel_churn(queue: QueueKind, n: usize) -> IterOut {
    let mut sim = Sim::with_queue(0u64, queue);
    let mut rng = Pcg::seeded(0xC0FFEE);
    let mut ids = Vec::with_capacity(n);
    for i in 0..n {
        let t = rng.below(1_000_000);
        ids.push(sim.schedule_at(t, move |s| {
            s.state = s.state.wrapping_add(i as u64);
        }));
        if rng.chance(0.5) {
            let j = rng.index(ids.len());
            sim.cancel(ids[j]);
        }
    }
    let mut ticks = 0u32;
    every(&mut sim, 500, move |_| {
        ticks += 1;
        ticks < 1_000
    });
    sim.run_to_completion();
    IterOut { events: sim.events_processed, peak_pending: sim.peak_pending(), usd: 0.0 }
}

/// The typed-vs-boxed dispatch microbenchmark: `n` one-shot adds at
/// pseudo-random times plus 64 self-rescheduling 50-step chains — the
/// recurring-timer shape — executed either as a typed payload enum or as
/// one boxed closure per event. Both paths schedule the identical
/// (time, order) stream, so `events_per_sec` differences isolate the
/// representation: enum move + match vs heap allocation + indirect call.
fn dispatch_churn(queue: QueueKind, n: usize, typed: bool) -> IterOut {
    const CHAINS: u64 = 64;
    const CHAIN_STEPS: u32 = 50;

    enum Churn {
        Add(u64),
        Chain { left: u32, step: u64 },
    }
    impl Dispatch<u64> for Churn {
        fn dispatch(self, sim: &mut Sim<u64, Churn>) {
            match self {
                Churn::Add(v) => sim.state = sim.state.wrapping_add(v),
                Churn::Chain { left, step } => {
                    sim.state = sim.state.wrapping_add(left as u64);
                    if left > 0 {
                        sim.schedule_event_in(step, Churn::Chain { left: left - 1, step });
                    }
                }
            }
        }
        fn kind(&self) -> &'static str {
            match self {
                Churn::Add(_) => "add",
                Churn::Chain { .. } => "chain",
            }
        }
    }

    fn chain_boxed(sim: &mut Sim<u64>, left: u32, step: u64) {
        sim.state = sim.state.wrapping_add(left as u64);
        if left > 0 {
            sim.schedule_in(step, move |sim| chain_boxed(sim, left - 1, step));
        }
    }

    let mut rng = Pcg::seeded(0xD15_0A7C);
    if typed {
        let mut sim: Sim<u64, Churn> = Sim::typed_with_queue(0u64, queue);
        for i in 0..n {
            sim.schedule_event_at(rng.below(1_000_000), Churn::Add(i as u64));
        }
        for c in 0..CHAINS {
            sim.schedule_event_at(c, Churn::Chain { left: CHAIN_STEPS, step: 1_000 + c });
        }
        sim.run_to_completion();
        IterOut { events: sim.events_processed, peak_pending: sim.peak_pending(), usd: 0.0 }
    } else {
        let mut sim = Sim::with_queue(0u64, queue);
        for i in 0..n {
            sim.schedule_at(rng.below(1_000_000), move |sim| {
                sim.state = sim.state.wrapping_add(i as u64);
            });
        }
        for c in 0..CHAINS {
            sim.schedule_at(c, move |sim| chain_boxed(sim, CHAIN_STEPS, 1_000 + c));
        }
        sim.run_to_completion();
        IterOut { events: sim.events_processed, peak_pending: sim.peak_pending(), usd: 0.0 }
    }
}

/// Parts (DCs), cross-part floor and per-hop mixing work of the
/// `multi-dc-churn` workload. The floor mirrors the default WAN's
/// one-way cross-DC latency (rtt 30 ms ⇒ 15 ms); the work rounds make
/// one hop expensive enough that LBTS barrier costs amortize away on a
/// multi-core runner.
const HOP_DCS: usize = 4;
const HOP_CROSS_MS: u64 = 15;
const HOP_WORK_ROUNDS: u32 = 192;

/// splitmix64 finalizer — the hop core's unit of "real work".
fn hop_mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// One hop's pure core, shared verbatim by the sequential and sharded
/// drivers: burn [`HOP_WORK_ROUNDS`] of mixing on the token, fold it
/// into the part accumulator (`wrapping_add` — tie-order independent),
/// and derive the next hop. Destination, extra delay and the next token
/// are functions of the token alone, so both engines schedule
/// bit-identical arrival times and end at the same checksum.
fn hop_core(acc: &mut u64, part: usize, token: u64) -> (usize, u64, u64) {
    let mut x = token ^ (part as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    for _ in 0..HOP_WORK_ROUNDS {
        x = hop_mix(x);
    }
    *acc = acc.wrapping_add(x);
    let to = (x % HOP_DCS as u64) as usize;
    let extra = (x >> 32) & 0x3f; // up to 63 ms of per-hop jitter
    (to, extra, x)
}

fn hop_floor(from: usize, to: usize) -> u64 {
    if from == to {
        1
    } else {
        HOP_CROSS_MS
    }
}

/// The sharded-vs-sequential workload driver. Returns the iteration
/// outcome plus the summed part-accumulator checksum — the parity tests
/// assert the checksum and event count are engine- and
/// shard-count-invariant, so the two timed rows measure the same work.
fn multi_dc_churn(queue: QueueKind, chains: usize, hops: u32) -> (IterOut, u64) {
    struct Hop {
        token: u64,
        left: u32,
    }
    impl ShardEvent<u64> for Hop {
        fn apply(self, ctx: &mut ShardCtx<'_, u64, Hop>) {
            let part = ctx.part();
            let (to, extra, x) = hop_core(ctx.state, part, self.token);
            if self.left > 0 {
                // `send` adds the lookahead floor itself: arrival is
                // now + floor(part, to) + extra, same as the twin below.
                ctx.send(to, extra, Hop { token: x, left: self.left - 1 });
            }
        }
        fn kind(&self) -> &'static str {
            "hop"
        }
    }
    struct SeqHop {
        part: usize,
        token: u64,
        left: u32,
    }
    impl Dispatch<Vec<u64>> for SeqHop {
        fn dispatch(self, sim: &mut Sim<Vec<u64>, SeqHop>) {
            let (to, extra, x) = hop_core(&mut sim.state[self.part], self.part, self.token);
            if self.left > 0 {
                let delay = hop_floor(self.part, to) + extra;
                sim.schedule_event_in(delay, SeqHop { part: to, token: x, left: self.left - 1 });
            }
        }
        fn kind(&self) -> &'static str {
            "hop"
        }
        fn affinity(&self) -> Option<usize> {
            Some(self.part)
        }
    }

    let seed_token = |i: usize| hop_mix(0x5eed ^ (i as u64).wrapping_mul(0x2545_f491_4f6c_dd1d));
    match queue {
        QueueKind::Sharded(shards) => {
            let la = Lookahead::from_fn(HOP_DCS, hop_floor);
            let mut sim = ShardedSim::new(vec![0u64; HOP_DCS], la, shards);
            for i in 0..chains {
                sim.seed(i % HOP_DCS, 1 + (i as u64 % 7), Hop { token: seed_token(i), left: hops });
            }
            sim.run();
            let checksum =
                (0..HOP_DCS).fold(0u64, |a, p| a.wrapping_add(*sim.part_state(p)));
            let out = IterOut {
                events: sim.events_processed(),
                peak_pending: sim.peak_pending(),
                usd: 0.0,
            };
            (out, checksum)
        }
        _ => {
            let mut sim: Sim<Vec<u64>, SeqHop> =
                Sim::typed_with_queue(vec![0u64; HOP_DCS], queue);
            for i in 0..chains {
                sim.schedule_event_at(
                    1 + (i as u64 % 7),
                    SeqHop { part: i % HOP_DCS, token: seed_token(i), left: hops },
                );
            }
            sim.run_to_completion();
            let checksum = sim.state.iter().fold(0u64, |a, v| a.wrapping_add(*v));
            let out = IterOut {
                events: sim.events_processed,
                peak_pending: sim.peak_pending(),
                usd: 0.0,
            };
            (out, checksum)
        }
    }
}

/// One workload's timed outcome.
#[derive(Debug, Clone)]
pub struct WorkloadResult {
    /// Workload name, `-legacy`-suffixed for the baseline engine.
    pub name: String,
    pub queue: &'static str,
    pub iters: usize,
    pub warmup: usize,
    /// Simulation events executed across the timed iterations.
    pub events_total: u64,
    /// Highest queue depth any run reached.
    pub peak_pending: usize,
    pub wall_ms_mean: f64,
    pub wall_ms_min: f64,
    pub wall_ms_max: f64,
    /// `events_total / total_wall_secs` — the headline hot-path number.
    pub events_per_sec: f64,
    /// Mean run cost per iteration (USD); 0 for cost-free workloads.
    pub usd: f64,
}

/// A whole bench run.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub smoke: bool,
    pub workloads: Vec<WorkloadResult>,
}

fn time_workload(
    base: &Config,
    w: BenchWorkload,
    queue: QueueKind,
    opts: &BenchOpts,
) -> WorkloadResult {
    for _ in 0..opts.warmup {
        let _ = w.run_once(base, queue, opts.smoke);
    }
    let mut wall_ms = Vec::with_capacity(opts.iters);
    let mut events_total = 0u64;
    let mut peak_pending = 0usize;
    let mut usd_total = 0.0f64;
    for _ in 0..opts.iters.max(1) {
        let t0 = Instant::now();
        let out = w.run_once(base, queue, opts.smoke);
        wall_ms.push(t0.elapsed().as_secs_f64() * 1000.0);
        events_total += out.events;
        peak_pending = peak_pending.max(out.peak_pending);
        usd_total += out.usd;
    }
    let total_secs: f64 = wall_ms.iter().sum::<f64>() / 1000.0;
    let events_per_sec = if total_secs > 0.0 { events_total as f64 / total_secs } else { 0.0 };
    let name = match queue {
        QueueKind::Slab => w.name().to_string(),
        QueueKind::Legacy => format!("{}-legacy", w.name()),
        QueueKind::Sharded(_) => format!("{}-sharded", w.name()),
    };
    WorkloadResult {
        name,
        queue: queue.name(),
        iters: opts.iters.max(1),
        warmup: opts.warmup,
        events_total,
        peak_pending,
        wall_ms_mean: stats::mean(&wall_ms),
        wall_ms_min: stats::min(&wall_ms),
        wall_ms_max: stats::max(&wall_ms),
        events_per_sec,
        usd: usd_total / opts.iters.max(1) as f64,
    }
}

/// Run the full workload matrix. The two hot workloads run on both queue
/// engines so the report always carries the old-vs-new comparison, and
/// the multi-DC workload runs sequential + sharded so it always carries
/// the parallel one.
pub fn run_bench(base: &Config, opts: &BenchOpts) -> BenchReport {
    let threads = resolve_threads(opts.threads);
    let matrix: &[(BenchWorkload, QueueKind)] = &[
        (BenchWorkload::CampaignSmoke, QueueKind::Slab),
        (BenchWorkload::CampaignSmoke, QueueKind::Legacy),
        (BenchWorkload::CampaignSmoke, QueueKind::Sharded(threads)),
        (BenchWorkload::FuzzBatch, QueueKind::Slab),
        (BenchWorkload::SoakSlice, QueueKind::Slab),
        (BenchWorkload::LoadKnee, QueueKind::Slab),
        (BenchWorkload::DenseCancelChurn, QueueKind::Slab),
        (BenchWorkload::DenseCancelChurn, QueueKind::Legacy),
        (BenchWorkload::BidChurn(StrategyKind::Naive), QueueKind::Slab),
        (BenchWorkload::BidChurn(StrategyKind::Adaptive), QueueKind::Slab),
        (BenchWorkload::BidChurn(StrategyKind::Deadline), QueueKind::Slab),
        (BenchWorkload::DispatchChurn { typed: true }, QueueKind::Slab),
        (BenchWorkload::DispatchChurn { typed: false }, QueueKind::Slab),
        (BenchWorkload::MultiDcChurn, QueueKind::Slab),
        (BenchWorkload::MultiDcChurn, QueueKind::Sharded(threads)),
        // The parts model runs its own ShardedSim internally, so both
        // rows sit on the Slab axis and keep their plain names.
        (BenchWorkload::CampaignSmokeParts { threads: 1 }, QueueKind::Slab),
        (BenchWorkload::CampaignSmokeParts { threads: 4 }, QueueKind::Slab),
        (BenchWorkload::PlanetChurn { dcs: 64 }, QueueKind::Slab),
        (BenchWorkload::PlanetChurn { dcs: 256 }, QueueKind::Slab),
    ];
    let workloads =
        matrix.iter().map(|&(w, q)| time_workload(base, w, q, opts)).collect();
    BenchReport { name: "sim-hot-path".to_string(), smoke: opts.smoke, workloads }
}

impl BenchReport {
    /// Speedup of a slab workload over its `-legacy` twin, if both ran.
    pub fn speedup(&self, workload: &str) -> Option<f64> {
        let slab = self.workloads.iter().find(|w| w.name == workload)?;
        let legacy =
            self.workloads.iter().find(|w| w.name == format!("{workload}-legacy"))?;
        if legacy.events_per_sec > 0.0 {
            Some(slab.events_per_sec / legacy.events_per_sec)
        } else {
            None
        }
    }

    /// Speedup of `campaign-smoke-threaded` (the parts model on 4
    /// ShardedSim shards) over `campaign-smoke-parts` (the same model on
    /// the serial round twin), if both ran — the threaded-vs-sequential
    /// campaign claim (> 1 means the threads paid for their barriers).
    pub fn threaded_speedup(&self) -> Option<f64> {
        let serial = self.workloads.iter().find(|w| w.name == "campaign-smoke-parts")?;
        let threaded =
            self.workloads.iter().find(|w| w.name == "campaign-smoke-threaded")?;
        if serial.events_per_sec > 0.0 {
            Some(threaded.events_per_sec / serial.events_per_sec)
        } else {
            None
        }
    }

    /// Speedup of a workload's `-sharded` twin over its sequential row,
    /// if both ran — the sharded-vs-sequential claim of the parallel
    /// engine (> 1 means the sharded row is faster).
    pub fn sharded_speedup(&self, workload: &str) -> Option<f64> {
        let seq = self.workloads.iter().find(|w| w.name == workload)?;
        let sharded =
            self.workloads.iter().find(|w| w.name == format!("{workload}-sharded"))?;
        if seq.events_per_sec > 0.0 {
            Some(sharded.events_per_sec / seq.events_per_sec)
        } else {
            None
        }
    }

    /// Human-readable table + the old-vs-new ratios.
    pub fn render(&self) -> String {
        let mut out = String::new();
        writeln!(
            out,
            "Bench {:?}{} — {} workloads",
            self.name,
            if self.smoke { " (smoke)" } else { "" },
            self.workloads.len()
        )
        .unwrap();
        writeln!(
            out,
            "{:>26} {:>7} {:>6} {:>12} {:>10} {:>12} {:>12} {:>9}",
            "workload", "queue", "iters", "events", "peak-q", "ms/iter", "events/s", "usd"
        )
        .unwrap();
        for w in &self.workloads {
            writeln!(
                out,
                "{:>26} {:>7} {:>6} {:>12} {:>10} {:>12.1} {:>12.0} {:>9.3}",
                w.name, w.queue, w.iters, w.events_total, w.peak_pending, w.wall_ms_mean,
                w.events_per_sec, w.usd
            )
            .unwrap();
        }
        for base in ["campaign-smoke", "dense-cancel-churn"] {
            if let Some(x) = self.speedup(base) {
                writeln!(out, "{base}: slab is {x:.2}x the legacy queue (events/s)").unwrap();
            }
        }
        for base in ["multi-dc-churn", "campaign-smoke"] {
            if let Some(x) = self.sharded_speedup(base) {
                writeln!(out, "{base}: sharded is {x:.2}x the sequential engine (events/s)")
                    .unwrap();
            }
        }
        if let Some(x) = self.threaded_speedup() {
            writeln!(
                out,
                "campaign-smoke-threaded: parts on 4 threads is {x:.2}x the serial \
                 parts engine (events/s)"
            )
            .unwrap();
        }
        if self.workloads.iter().any(|w| w.name.starts_with("planet-churn")) {
            writeln!(
                out,
                "planet-churn: SoA node state is {} bytes/node",
                crate::cluster::soa_bytes_per_node()
            )
            .unwrap();
            let small = self.workloads.iter().find(|w| w.name == "planet-churn-64dc");
            let big = self.workloads.iter().find(|w| w.name == "planet-churn-256dc");
            if let (Some(s), Some(b)) = (small, big) {
                if s.events_per_sec > 0.0 {
                    writeln!(
                        out,
                        "planet-churn: 256dc runs at {:.2}x the 64dc rate (flat ⇒ \
                         background DCs are free)",
                        b.events_per_sec / s.events_per_sec
                    )
                    .unwrap();
                }
            }
        }
        out
    }

    /// The report as a JSON document (schema in the module docs).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": {},\n", json::escape(&self.name)));
        out.push_str(&format!("  \"smoke\": {},\n", self.smoke));
        out.push_str("  \"workloads\": [\n");
        for (i, w) in self.workloads.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"name\": {}, ", json::escape(&w.name)));
            out.push_str(&format!("\"queue\": {}, ", json::escape(w.queue)));
            out.push_str(&format!("\"iters\": {}, ", w.iters));
            out.push_str(&format!("\"warmup\": {}, ", w.warmup));
            out.push_str(&format!("\"events_total\": {}, ", w.events_total));
            out.push_str(&format!("\"peak_pending\": {}, ", w.peak_pending));
            out.push_str(&format!("\"wall_ms_mean\": {}, ", json::num(w.wall_ms_mean)));
            out.push_str(&format!("\"wall_ms_min\": {}, ", json::num(w.wall_ms_min)));
            out.push_str(&format!("\"wall_ms_max\": {}, ", json::num(w.wall_ms_max)));
            out.push_str(&format!("\"events_per_sec\": {}, ", json::num(w.events_per_sec)));
            out.push_str(&format!("\"usd\": {}", json::num(w.usd)));
            out.push_str(if i + 1 == self.workloads.len() { "}\n" } else { "},\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Verify a serialized report parses back with every workload's identity
/// fields intact (integers exactly, floats bit-for-bit — Rust's shortest
/// `{}` float repr round-trips).
pub fn verify_report_json(report: &BenchReport, text: &str) -> Result<()> {
    let doc = json::parse(text).map_err(|e| anyhow!("bench report json: {e}"))?;
    ensure!(
        doc.get("bench").and_then(Json::as_str) == Some(report.name.as_str()),
        "bench name did not round-trip"
    );
    ensure!(
        doc.get("smoke").and_then(Json::as_bool) == Some(report.smoke),
        "smoke flag did not round-trip"
    );
    let runs = doc
        .get("workloads")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("missing workloads array"))?;
    ensure!(
        runs.len() == report.workloads.len(),
        "workload count drifted: {} vs {}",
        runs.len(),
        report.workloads.len()
    );
    for (j, w) in runs.iter().zip(&report.workloads) {
        ensure!(
            j.get("name").and_then(Json::as_str) == Some(w.name.as_str()),
            "workload name did not round-trip"
        );
        ensure!(
            j.get("queue").and_then(Json::as_str) == Some(w.queue),
            "{}: queue did not round-trip",
            w.name
        );
        ensure!(
            j.get("events_total").and_then(Json::as_u64) == Some(w.events_total),
            "{}: events_total did not round-trip",
            w.name
        );
        ensure!(
            j.get("peak_pending").and_then(Json::as_u64) == Some(w.peak_pending as u64),
            "{}: peak_pending did not round-trip",
            w.name
        );
        let eps = j
            .get("events_per_sec")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("{}: events_per_sec missing", w.name))?;
        ensure!(
            eps.to_bits() == w.events_per_sec.to_bits(),
            "{}: events_per_sec did not round-trip",
            w.name
        );
        ensure!(eps >= 0.0, "{}: negative events_per_sec", w.name);
        let usd = j
            .get("usd")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("{}: usd missing", w.name))?;
        ensure!(usd.to_bits() == w.usd.to_bits(), "{}: usd did not round-trip", w.name);
        ensure!(usd >= 0.0, "{}: negative usd", w.name);
    }
    Ok(())
}

/// Compare a fresh report against a committed baseline `BENCH_*.json`,
/// returning one line per regressed workload (empty ⇒ the gate passes).
///
/// Per workload present in **both** reports, the current `events_per_sec`
/// must stay above `baseline * band`. The band is derived from the
/// baseline's own wall-clock spread (`wall_ms_min / wall_ms_mean`, 1.0
/// when iters == 1) scaled by 0.5 and floored at 0.3: smoke runs time a
/// single iteration on shared hardware, so only a gross (≳2–3×)
/// slowdown should gate, never scheduler jitter. Baseline rows with
/// zero/absent throughput are skipped — that's the committed *bootstrap*
/// baseline, which ci.sh replaces with measured numbers on first run.
pub fn compare_to_baseline(current: &BenchReport, baseline_text: &str) -> Result<Vec<String>> {
    let doc = json::parse(baseline_text).map_err(|e| anyhow!("baseline json: {e}"))?;
    let rows = doc
        .get("workloads")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("baseline has no workloads array"))?;
    let mut regressions = Vec::new();
    for row in rows {
        let Some(name) = row.get("name").and_then(Json::as_str) else { continue };
        let base_eps = row.get("events_per_sec").and_then(Json::as_f64).unwrap_or(0.0);
        if !(base_eps > 0.0) {
            continue; // bootstrap row (or null/NaN) — nothing to compare against
        }
        let Some(cur) = current.workloads.iter().find(|w| w.name == name) else { continue };
        let mean = row.get("wall_ms_mean").and_then(Json::as_f64).unwrap_or(0.0);
        let min = row.get("wall_ms_min").and_then(Json::as_f64).unwrap_or(0.0);
        let spread = if mean > 0.0 { (min / mean).clamp(0.0, 1.0) } else { 1.0 };
        let band = (0.5 * spread).max(0.3);
        let floor = base_eps * band;
        if cur.events_per_sec < floor {
            regressions.push(format!(
                "{name}: {:.0} events/s vs baseline {:.0} (floor {:.0}, band {:.2})",
                cur.events_per_sec, base_eps, floor, band
            ));
        }
    }
    Ok(regressions)
}

/// Write the report as JSON, read the file back and verify the
/// round-trip (same contract as the campaign/fuzz exports, so a future
/// schema change that breaks parsing fails loudly in ci).
pub fn write_report(report: &BenchReport, path: &str) -> Result<()> {
    ensure!(path.ends_with(".json"), "bench report path {path:?} must end in .json");
    let text = report.to_json();
    std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
    let back = std::fs::read_to_string(path).with_context(|| format!("re-reading {path}"))?;
    verify_report_json(report, &back)
}

/// The repo's short git SHA, or `"unknown"` outside a work tree (the
/// history file must still append — a missing `git` never fails a bench
/// run).
fn git_short_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Render one history row (JSONL) for this run: timestamp, git SHA,
/// smoke flag and every workload's `events_per_sec` keyed by name.
fn history_row(report: &BenchReport, ts: u64, sha: &str) -> String {
    let mut row = String::new();
    row.push_str(&format!(
        "{{\"ts\": {ts}, \"sha\": {}, \"smoke\": {}, \"workloads\": {{",
        json::escape(sha),
        report.smoke
    ));
    for (i, w) in report.workloads.iter().enumerate() {
        if i > 0 {
            row.push_str(", ");
        }
        row.push_str(&format!("{}: {}", json::escape(&w.name), json::num(w.events_per_sec)));
    }
    row.push_str("}}\n");
    row
}

/// Append this run's row to a JSONL history file (`houtu bench
/// --history BENCH_history.jsonl`), creating it on first use. Each line
/// is independently parseable, so the trajectory survives partial
/// writes and ad-hoc tooling can `grep`/`jq` it per commit.
///
/// Torn-write hardening: the whole row (parse-checked first) lands in
/// one flushed `write_all`, and if a previous run crashed mid-append —
/// leaving a final line with no trailing newline — this append starts
/// with a `\n` so the torn fragment stays isolated on its own line
/// instead of corrupting the new row too. [`read_history`] then skips
/// such fragments with a warning rather than failing every later
/// parse-check.
pub fn append_history(report: &BenchReport, path: &str) -> Result<()> {
    use std::io::Write as _;
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let row = history_row(report, ts, &git_short_sha());
    json::parse(row.trim()).map_err(|e| anyhow!("history row does not parse: {e}"))?;
    let torn_tail = match std::fs::read(path) {
        Ok(bytes) => !bytes.is_empty() && bytes.last() != Some(&b'\n'),
        Err(_) => false, // absent file: OpenOptions creates it below
    };
    let mut buf = String::with_capacity(row.len() + 1);
    if torn_tail {
        eprintln!("warning: {path} ends in a torn row (crash mid-append?); starting a fresh line");
        buf.push('\n');
    }
    buf.push_str(&row);
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening {path}"))?;
    f.write_all(buf.as_bytes()).with_context(|| format!("appending {path}"))?;
    f.flush().with_context(|| format!("flushing {path}"))?;
    Ok(())
}

/// Parse a JSONL history file, skipping (with a stderr warning) any line
/// that does not parse — the residue of a torn append — instead of
/// failing the run. Returns the parsed rows and the skipped-line count.
/// I/O errors still fail: an unreadable trajectory is a real problem, a
/// single torn line is not.
pub fn read_history(path: &str) -> Result<(Vec<Json>, usize)> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let mut rows = Vec::new();
    let mut skipped = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        match json::parse(line) {
            Ok(doc) => rows.push(doc),
            Err(e) => {
                skipped += 1;
                eprintln!("warning: {path}:{}: skipping torn history row ({e})", i + 1);
            }
        }
    }
    Ok((rows, skipped))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_report() -> BenchReport {
        BenchReport {
            name: "sim-hot-path".to_string(),
            smoke: true,
            workloads: vec![
                WorkloadResult {
                    name: "campaign-smoke".to_string(),
                    queue: "slab",
                    iters: 1,
                    warmup: 0,
                    events_total: 123_456,
                    peak_pending: 789,
                    wall_ms_mean: 12.5,
                    wall_ms_min: 12.5,
                    wall_ms_max: 12.5,
                    events_per_sec: 9_876_543.21,
                    usd: 0.0,
                },
                WorkloadResult {
                    name: "campaign-smoke-legacy".to_string(),
                    queue: "legacy",
                    iters: 1,
                    warmup: 0,
                    events_total: 123_456,
                    peak_pending: 789,
                    wall_ms_mean: 25.0,
                    wall_ms_min: 25.0,
                    wall_ms_max: 25.0,
                    events_per_sec: 4_938_271.5,
                    usd: 1.234,
                },
            ],
        }
    }

    #[test]
    fn report_round_trips_through_json() {
        let r = tiny_report();
        verify_report_json(&r, &r.to_json()).expect("round trip");
    }

    #[test]
    fn verification_catches_drift() {
        let r = tiny_report();
        let mut text = r.to_json();
        text = text.replace("123456", "123457");
        assert!(verify_report_json(&r, &text).is_err(), "event drift must fail");
        assert!(verify_report_json(&r, "{}").is_err(), "empty doc must fail");
        assert!(verify_report_json(&r, "not json").is_err());
    }

    #[test]
    fn speedup_reads_the_legacy_twin() {
        let r = tiny_report();
        let x = r.speedup("campaign-smoke").expect("both rows present");
        assert!((x - 2.0).abs() < 1e-9, "speedup {x}");
        assert!(r.speedup("fuzz-batch").is_none());
    }

    #[test]
    fn dense_cancel_churn_is_deterministic_and_queue_agnostic() {
        let a = dense_cancel_churn(QueueKind::Slab, 5_000);
        let b = dense_cancel_churn(QueueKind::Slab, 5_000);
        assert_eq!(a.events, b.events, "same seed ⇒ same event count");
        assert_eq!(a.peak_pending, b.peak_pending);
        let c = dense_cancel_churn(QueueKind::Legacy, 5_000);
        assert_eq!(a.events, c.events, "engines must execute the same schedule");
        assert_eq!(a.peak_pending, c.peak_pending);
        assert!(a.events > 5_000 / 2, "survivors + 1000 timer ticks executed");
    }

    #[test]
    fn dispatch_churn_paths_execute_identical_schedules() {
        // Typed and boxed must run the same (time, order) event stream —
        // otherwise the events/s comparison measures different work.
        let typed = dispatch_churn(QueueKind::Slab, 5_000, true);
        let boxed = dispatch_churn(QueueKind::Slab, 5_000, false);
        assert_eq!(typed.events, boxed.events, "schedules diverged");
        assert_eq!(typed.peak_pending, boxed.peak_pending);
        assert!(typed.events > 5_000, "adds + 64 chains of 50 steps");
        // And identically across queue engines.
        let legacy = dispatch_churn(QueueKind::Legacy, 5_000, true);
        assert_eq!(typed.events, legacy.events);
    }

    #[test]
    fn multi_dc_churn_parity_across_engines_and_shard_counts() {
        // The speedup pair must measure the same work: the sequential
        // twin and the sharded engine execute the same hop count and
        // reach the same part-accumulator checksum at every shard count
        // (1 = the serial-round twin path, >1 = real threads).
        let (seq, sum_seq) = multi_dc_churn(QueueKind::Slab, 48, 30);
        assert_eq!(seq.events, 48 * 31, "each chain is left+1 hops");
        for shards in [1usize, 2, 4] {
            let (sh, sum_sh) = multi_dc_churn(QueueKind::Sharded(shards), 48, 30);
            assert_eq!(seq.events, sh.events, "event count diverged at {shards} shards");
            assert_eq!(sum_seq, sum_sh, "checksum diverged at {shards} shards");
        }
    }

    #[test]
    fn history_rows_append_and_parse() {
        let r = tiny_report();
        let path = std::env::temp_dir()
            .join(format!("houtu-bench-history-{}.jsonl", std::process::id()));
        let path = path.to_str().expect("utf8 temp path").to_string();
        let _ = std::fs::remove_file(&path);
        append_history(&r, &path).expect("first append");
        append_history(&r, &path).expect("second append");
        let text = std::fs::read_to_string(&path).expect("history readable");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one row per run");
        for line in lines {
            let doc = json::parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert!(doc.get("sha").and_then(Json::as_str).is_some(), "{line}");
            assert!(doc.get("ts").and_then(Json::as_u64).is_some(), "{line}");
            let eps = doc
                .get("workloads")
                .and_then(|w| w.get("campaign-smoke"))
                .and_then(Json::as_f64);
            assert_eq!(eps, Some(9_876_543.21), "{line}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_history_tail_is_repaired_and_skipped() {
        use std::io::Write as _;
        let r = tiny_report();
        let path = std::env::temp_dir()
            .join(format!("houtu-bench-history-torn-{}.jsonl", std::process::id()));
        let path = path.to_str().expect("utf8 temp path").to_string();
        let _ = std::fs::remove_file(&path);
        append_history(&r, &path).expect("first append");
        // Simulate a crash mid-append: half a JSON row, no newline.
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"ts\": 12, \"sha").unwrap();
        }
        // The next append must not fail, and must isolate the fragment
        // on its own line so the new row parses.
        append_history(&r, &path).expect("append over a torn tail");
        let text = std::fs::read_to_string(&path).expect("history readable");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "good, torn, good: {lines:?}");
        assert!(json::parse(lines[0]).is_ok());
        assert!(json::parse(lines[1]).is_err(), "the torn fragment stays visible");
        assert!(json::parse(lines[2]).is_ok(), "the fresh row must parse");
        // The parse-check skips the torn line with a warning instead of
        // failing the run.
        let (rows, skipped) = read_history(&path).expect("read_history");
        assert_eq!(rows.len(), 2);
        assert_eq!(skipped, 1);
        assert!(rows
            .iter()
            .all(|d| d.get("sha").and_then(Json::as_str).is_some()));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sharded_speedup_reads_the_sharded_twin() {
        let mut r = tiny_report();
        let mut sharded = r.workloads[0].clone();
        sharded.name = "campaign-smoke-sharded".to_string();
        sharded.queue = "sharded";
        sharded.events_per_sec = r.workloads[0].events_per_sec * 3.0;
        r.workloads.push(sharded);
        let x = r.sharded_speedup("campaign-smoke").expect("both rows present");
        assert!((x - 3.0).abs() < 1e-9, "speedup {x}");
        assert!(r.sharded_speedup("multi-dc-churn").is_none());
    }

    #[test]
    fn baseline_compare_flags_gross_regressions_only() {
        let r = tiny_report();
        // Baseline twice as fast as the current report: current sits at
        // 0.5x, inside the generous 0.3 floor band — no regression.
        let mut fast = tiny_report();
        for w in &mut fast.workloads {
            w.events_per_sec *= 2.0;
        }
        let ok = compare_to_baseline(&r, &fast.to_json()).unwrap();
        assert!(ok.is_empty(), "2x baseline must not gate: {ok:?}");
        // Baseline ten times as fast: current sits at 0.1x — regression.
        let mut much_faster = tiny_report();
        for w in &mut much_faster.workloads {
            w.events_per_sec *= 10.0;
        }
        let bad = compare_to_baseline(&r, &much_faster.to_json()).unwrap();
        assert_eq!(bad.len(), 2, "both rows regressed: {bad:?}");
        // Bootstrap baseline (zero throughput) gates nothing.
        let mut bootstrap = tiny_report();
        for w in &mut bootstrap.workloads {
            w.events_per_sec = 0.0;
        }
        assert!(compare_to_baseline(&r, &bootstrap.to_json()).unwrap().is_empty());
        // Garbage baseline is an error, not a silent pass.
        assert!(compare_to_baseline(&r, "not json").is_err());
    }

    #[test]
    fn threaded_speedup_reads_the_parts_row_pair() {
        let mut r = tiny_report();
        assert!(r.threaded_speedup().is_none(), "no parts rows yet");
        let mut serial = r.workloads[0].clone();
        serial.name = "campaign-smoke-parts".to_string();
        let mut threaded = r.workloads[0].clone();
        threaded.name = "campaign-smoke-threaded".to_string();
        threaded.events_per_sec = serial.events_per_sec * 2.5;
        r.workloads.push(serial);
        r.workloads.push(threaded);
        let x = r.threaded_speedup().expect("both parts rows present");
        assert!((x - 2.5).abs() < 1e-9, "speedup {x}");
    }

    #[test]
    fn parts_workload_rows_measure_identical_work() {
        // The speedup pair must time the same schedule: event totals and
        // digest-bearing cells are thread-count invariant by the wall,
        // so the serial and 4-thread rows only differ in wall time.
        let base = Config::default();
        let serial = BenchWorkload::CampaignSmokeParts { threads: 1 }
            .run_once(&base, QueueKind::Slab, true);
        let threaded = BenchWorkload::CampaignSmokeParts { threads: 4 }
            .run_once(&base, QueueKind::Slab, true);
        assert!(serial.events > 0, "parts cells must execute events");
        assert_eq!(serial.events, threaded.events, "row pair diverged");
        assert_eq!(
            BenchWorkload::CampaignSmokeParts { threads: 1 }.name(),
            "campaign-smoke-parts"
        );
        assert_eq!(
            BenchWorkload::CampaignSmokeParts { threads: 4 }.name(),
            "campaign-smoke-threaded"
        );
    }

    #[test]
    fn planet_churn_rows_run_identical_exact_tier_work() {
        // 64 vs 256 DCs differ only in the aggregate-tier background:
        // generated topologies are prefix-stable, so the 4-DC exact tier
        // is bit-identical and the event totals must match exactly —
        // that is what makes the events/s pair a background-cost probe.
        let base = Config::default();
        let small =
            BenchWorkload::PlanetChurn { dcs: 64 }.run_once(&base, QueueKind::Slab, true);
        let big =
            BenchWorkload::PlanetChurn { dcs: 256 }.run_once(&base, QueueKind::Slab, true);
        assert!(small.events > 0, "planet churn must execute events");
        assert_eq!(small.events, big.events, "background DCs leaked into the exact tier");
        assert_eq!(BenchWorkload::PlanetChurn { dcs: 64 }.name(), "planet-churn-64dc");
        assert_eq!(BenchWorkload::PlanetChurn { dcs: 256 }.name(), "planet-churn-256dc");
    }

    #[test]
    fn campaign_smoke_workload_agrees_across_engines() {
        // One real (tiny) timed pass per engine: identical schedules must
        // execute identical event counts and reach identical peak depth;
        // the full 6-workload matrix runs in release through the ci.sh
        // `bench --smoke` gate.
        let base = Config::default();
        let opts = BenchOpts::smoke();
        let slab = time_workload(&base, BenchWorkload::CampaignSmoke, QueueKind::Slab, &opts);
        let legacy =
            time_workload(&base, BenchWorkload::CampaignSmoke, QueueKind::Legacy, &opts);
        assert!(slab.events_total > 0, "no events executed");
        assert_eq!(
            slab.events_total, legacy.events_total,
            "both engines must run the identical smoke campaign"
        );
        assert_eq!(slab.peak_pending, legacy.peak_pending);
        assert_eq!(legacy.name, "campaign-smoke-legacy");
        assert_eq!((slab.queue, legacy.queue), ("slab", "legacy"));
        let r = BenchReport {
            name: "sim-hot-path".to_string(),
            smoke: true,
            workloads: vec![slab, legacy],
        };
        assert!(r.speedup("campaign-smoke").is_some());
        verify_report_json(&r, &r.to_json()).expect("live report round-trips");
    }
}
