//! Cluster substrate: data centers, worker nodes, containers and the
//! per-container utilization monitor (§5 "Monitor mechanism").
//!
//! A *container* is the unified resource unit of the paper: a fixed
//! `<cores, memory>` slot, normalized to capacity 1.0. Both tasks and job
//! managers run in containers, which is why both failure classes occur
//! with the same probability on spot instances (§2.3). Parades may pack
//! multiple tasks into one container as long as Σ r ≤ 1.
//!
//! Utilization is tracked as a time-weighted step function of the used
//! fraction, mirroring the 1 Hz OS-counter monitor the paper adds to
//! YARN's NodeManager; [`Cluster::take_period_utilization`] returns the
//! average over the closing scheduling period — exactly the `u(q−1)` that
//! Af consumes.

use crate::cloud::InstanceClass;
use crate::ids::{ContainerId, DcId, JmId, NodeId, TaskId};
use crate::sim::{to_secs, SimTime};
use crate::util::stats::TimeWeighted;

/// A running task's footprint inside a container.
#[derive(Debug, Clone, Copy)]
pub struct RunningTask {
    pub task: TaskId,
    pub r: f64,
}

/// A container (executor slot), capacity normalized to 1.0.
#[derive(Debug)]
pub struct Container {
    pub id: ContainerId,
    pub node: NodeId,
    pub rack: usize,
    /// Free resource in [0, 1].
    pub free: f64,
    pub running: Vec<RunningTask>,
    /// Sub-job currently granted this container (None = in the DC free pool).
    pub owner: Option<JmId>,
    /// Utilization monitor (used fraction over time).
    util: TimeWeighted,
    pub alive: bool,
}

impl Container {
    pub fn used(&self) -> f64 {
        1.0 - self.free
    }
}

/// A worker machine hosting several containers.
#[derive(Debug)]
pub struct Node {
    pub id: NodeId,
    pub rack: usize,
    pub class: InstanceClass,
    pub containers: Vec<ContainerId>,
    pub alive: bool,
    pub started_at: SimTime,
}

/// One region's machines.
#[derive(Debug)]
pub struct DataCenter {
    pub id: DcId,
    pub region: String,
    pub nodes: Vec<Node>,
}

/// Dense container table: ids are allocated monotonically and entries are
/// never removed (death just flips `alive`), so a Vec indexed by id
/// replaces a HashMap — this store sits on the hottest path (every
/// heartbeat / allocation / steal check) and hashing it cost ~38 % of
/// end-to-end runtime before the swap (EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct ContainerStore(Vec<Container>);

impl ContainerStore {
    #[inline]
    pub fn get(&self, id: &ContainerId) -> Option<&Container> {
        self.0.get(id.0 as usize)
    }
    #[inline]
    pub fn get_mut(&mut self, id: &ContainerId) -> Option<&mut Container> {
        self.0.get_mut(id.0 as usize)
    }
    pub fn push(&mut self, c: Container) {
        debug_assert_eq!(c.id.0 as usize, self.0.len(), "ids must stay dense");
        self.0.push(c);
    }
    pub fn len(&self) -> usize {
        self.0.len()
    }
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
    pub fn iter(&self) -> impl Iterator<Item = &Container> {
        self.0.iter()
    }
}

impl std::ops::Index<&ContainerId> for ContainerStore {
    type Output = Container;
    #[inline]
    fn index(&self, id: &ContainerId) -> &Container {
        &self.0[id.0 as usize]
    }
}

/// All machines in all regions, plus the global container table.
#[derive(Debug, Default)]
pub struct Cluster {
    pub dcs: Vec<DataCenter>,
    pub containers: ContainerStore,
    next_container: u64,
}

impl Cluster {
    /// Build the testbed: `workers` nodes per region, `slots` containers
    /// per node, spread round-robin over `racks` racks. Spot bids are drawn
    /// by the caller (cloud layer) and passed in via `classes`.
    pub fn build(
        regions: &[String],
        workers: usize,
        slots: usize,
        racks: usize,
        mut class_of: impl FnMut(DcId, usize) -> InstanceClass,
    ) -> Cluster {
        let mut cluster = Cluster::default();
        for (d, region) in regions.iter().enumerate() {
            let dc = DcId(d);
            let mut nodes = Vec::new();
            for n in 0..workers {
                let id = NodeId { dc, idx: n };
                let rack = n % racks.max(1);
                let mut node = Node {
                    id,
                    rack,
                    class: class_of(dc, n),
                    containers: Vec::new(),
                    alive: true,
                    started_at: 0,
                };
                for _ in 0..slots {
                    let cid = ContainerId(cluster.next_container);
                    cluster.next_container += 1;
                    cluster.containers.push(Container {
                        id: cid,
                        node: id,
                        rack,
                        free: 1.0,
                        running: Vec::new(),
                        owner: None,
                        util: TimeWeighted::new(0.0, 0.0),
                        alive: true,
                    });
                    node.containers.push(cid);
                }
                nodes.push(node);
            }
            cluster.dcs.push(DataCenter { id: dc, region: region.clone(), nodes });
        }
        cluster
    }

    pub fn container(&self, id: ContainerId) -> &Container {
        &self.containers[&id]
    }

    pub fn container_mut(&mut self, id: ContainerId) -> &mut Container {
        self.containers.get_mut(&id).expect("unknown container")
    }

    /// All live containers in a DC.
    pub fn dc_containers(&self, dc: DcId) -> Vec<ContainerId> {
        self.dcs[dc.0]
            .nodes
            .iter()
            .filter(|n| n.alive)
            .flat_map(|n| n.containers.iter().copied())
            .filter(|c| self.containers[c].alive)
            .collect()
    }

    /// Live containers in a DC not granted to any sub-job.
    /// Single pass, no intermediate allocation — hot in every allocation
    /// round and steal check.
    pub fn free_pool(&self, dc: DcId) -> Vec<ContainerId> {
        let mut out = Vec::new();
        for n in &self.dcs[dc.0].nodes {
            if !n.alive {
                continue;
            }
            for &cid in &n.containers {
                let c = &self.containers[&cid];
                if c.alive && c.owner.is_none() {
                    out.push(cid);
                }
            }
        }
        out
    }

    /// Total live container capacity per DC (|P_j| in the analysis).
    /// Allocation-free count.
    pub fn dc_capacity(&self, dc: DcId) -> usize {
        self.dcs[dc.0]
            .nodes
            .iter()
            .filter(|n| n.alive)
            .map(|n| n.containers.iter().filter(|c| self.containers[c].alive).count())
            .sum()
    }

    /// Grant a free container to a sub-job. Panics if already owned.
    pub fn grant(&mut self, cid: ContainerId, owner: JmId) {
        let c = self.container_mut(cid);
        assert!(c.alive, "granting dead container {cid}");
        assert!(c.owner.is_none(), "container {cid} already owned by {:?}", c.owner);
        c.owner = Some(owner);
    }

    /// Transfer ownership (token re-grant after JM recovery, §5).
    pub fn regrant(&mut self, cid: ContainerId, new_owner: JmId) {
        let c = self.container_mut(cid);
        assert!(c.alive);
        c.owner = Some(new_owner);
    }

    /// Return a container to the free pool. Running tasks must have been
    /// handled by the caller; we assert the container is idle.
    pub fn release(&mut self, cid: ContainerId, t: SimTime) {
        let c = self.container_mut(cid);
        debug_assert!(c.running.is_empty(), "releasing busy container {cid}");
        c.owner = None;
        c.free = 1.0;
        c.util.set(to_secs(t), 0.0);
    }

    /// Start a task of footprint `r` on a container. Panics on over-commit
    /// — Parades must check `free` first (the no-over-commit invariant is
    /// property-tested in `jm`).
    pub fn start_task(&mut self, cid: ContainerId, task: TaskId, r: f64, t: SimTime) {
        let c = self.container_mut(cid);
        assert!(c.alive, "starting task on dead container");
        assert!(
            c.free + 1e-9 >= r,
            "over-commit on {cid}: free={} r={r}",
            c.free
        );
        c.free = (c.free - r).max(0.0);
        c.running.push(RunningTask { task, r });
        let used = c.used();
        c.util.set(to_secs(t), used);
    }

    /// Finish (or abort) a task on a container, freeing its resources.
    pub fn finish_task(&mut self, cid: ContainerId, task: TaskId, t: SimTime) -> bool {
        let c = self.container_mut(cid);
        if let Some(pos) = c.running.iter().position(|rt| rt.task == task) {
            let rt = c.running.swap_remove(pos);
            c.free = (c.free + rt.r).min(1.0);
            let used = c.used();
            c.util.set(to_secs(t), used);
            true
        } else {
            false
        }
    }

    /// Period-average utilization of a set of containers (Af's `u(q−1)`),
    /// resetting each monitor window. Containers average equally, matching
    /// the paper's per-second sampling then per-period averaging.
    pub fn take_period_utilization(&mut self, cids: &[ContainerId], t: SimTime) -> f64 {
        if cids.is_empty() {
            return 0.0;
        }
        let ts = to_secs(t);
        let mut sum = 0.0;
        for cid in cids {
            if let Some(c) = self.containers.get_mut(cid) {
                sum += c.util.take_average(ts);
            }
        }
        sum / cids.len() as f64
    }

    /// Kill a node (spot revocation / manual VM termination). Returns the
    /// containers that died and the tasks that were running on them.
    pub fn kill_node(&mut self, node: NodeId, t: SimTime) -> (Vec<ContainerId>, Vec<TaskId>) {
        let mut dead_containers = Vec::new();
        let mut dead_tasks = Vec::new();
        let n = &mut self.dcs[node.dc.0].nodes[node.idx];
        if !n.alive {
            return (dead_containers, dead_tasks);
        }
        n.alive = false;
        let cids = n.containers.clone();
        for cid in cids {
            let c = self.container_mut(cid);
            if !c.alive {
                continue;
            }
            c.alive = false;
            c.util.set(to_secs(t), 0.0);
            for rt in c.running.drain(..) {
                dead_tasks.push(rt.task);
            }
            c.free = 0.0;
            dead_containers.push(cid);
        }
        (dead_containers, dead_tasks)
    }

    /// Restart a dead node with fresh containers (new instance acquired
    /// from the market). Returns the new container ids.
    pub fn restart_node(&mut self, node: NodeId, slots: usize, t: SimTime) -> Vec<ContainerId> {
        let rack = self.dcs[node.dc.0].nodes[node.idx].rack;
        let mut fresh = Vec::new();
        for _ in 0..slots {
            let cid = ContainerId(self.next_container);
            self.next_container += 1;
            self.containers.push(Container {
                id: cid,
                node,
                rack,
                free: 1.0,
                running: Vec::new(),
                owner: None,
                util: TimeWeighted::new(to_secs(t), 0.0),
                alive: true,
            });
            fresh.push(cid);
        }
        let n = &mut self.dcs[node.dc.0].nodes[node.idx];
        n.alive = true;
        n.started_at = t;
        n.containers = fresh.clone();
        fresh
    }

    /// The instance class a node is currently paid under.
    pub fn node_class(&self, node: NodeId) -> InstanceClass {
        self.dcs[node.dc.0].nodes[node.idx].class
    }

    /// Re-class a node (market re-acquisition may come back with a fresh
    /// bid or as an on-demand instance — the bid strategy's decision).
    pub fn set_node_class(&mut self, node: NodeId, class: InstanceClass) {
        self.dcs[node.dc.0].nodes[node.idx].class = class;
    }

    /// Sum of used resource over live containers of a DC (for injection
    /// experiments and reporting).
    pub fn dc_load(&self, dc: DcId) -> f64 {
        self.dc_containers(dc).iter().map(|c| self.containers[c].used()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::{JobId, StageId};
    use crate::sim::secs;

    fn task(i: u32) -> TaskId {
        TaskId { job: JobId(1), stage: StageId(0), index: i }
    }

    fn jm() -> JmId {
        JmId { job: JobId(1), dc: DcId(0) }
    }

    fn small_cluster() -> Cluster {
        Cluster::build(
            &["A".into(), "B".into()],
            2,
            2,
            2,
            |_, _| InstanceClass::OnDemand,
        )
    }

    #[test]
    fn build_shapes() {
        let c = small_cluster();
        assert_eq!(c.dcs.len(), 2);
        assert_eq!(c.dc_containers(DcId(0)).len(), 4);
        assert_eq!(c.dc_capacity(DcId(1)), 4);
        assert_eq!(c.free_pool(DcId(0)).len(), 4);
        // Rack spread: nodes 0,1 on racks 0,1.
        assert_eq!(c.dcs[0].nodes[0].rack, 0);
        assert_eq!(c.dcs[0].nodes[1].rack, 1);
    }

    #[test]
    fn grant_and_release_cycle() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        assert_eq!(c.free_pool(DcId(0)).len(), 3);
        assert_eq!(c.container(cid).owner, Some(jm()));
        c.release(cid, secs(10));
        assert_eq!(c.free_pool(DcId(0)).len(), 4);
    }

    #[test]
    #[should_panic(expected = "already owned")]
    fn double_grant_panics() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        c.grant(cid, jm());
    }

    #[test]
    fn task_packing_respects_capacity() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        c.start_task(cid, task(0), 0.6, secs(1));
        assert!((c.container(cid).free - 0.4).abs() < 1e-9);
        c.start_task(cid, task(1), 0.4, secs(2));
        assert!(c.container(cid).free < 1e-9);
        assert!(c.finish_task(cid, task(0), secs(5)));
        assert!((c.container(cid).free - 0.6).abs() < 1e-9);
        assert!(!c.finish_task(cid, task(0), secs(6)), "double finish is a no-op");
    }

    #[test]
    #[should_panic(expected = "over-commit")]
    fn overcommit_panics() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        c.start_task(cid, task(0), 0.8, secs(1));
        c.start_task(cid, task(1), 0.3, secs(1));
    }

    #[test]
    fn period_utilization_is_time_weighted() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        // busy 0.5 for the first half of a 10 s period, idle after.
        c.start_task(cid, task(0), 0.5, secs(0));
        c.finish_task(cid, task(0), secs(5));
        let u = c.take_period_utilization(&[cid], secs(10));
        assert!((u - 0.25).abs() < 1e-9, "u={u}");
        // Next period: fully idle.
        let u2 = c.take_period_utilization(&[cid], secs(20));
        assert!(u2.abs() < 1e-9);
    }

    #[test]
    fn kill_node_reports_casualties_and_restart_revives() {
        let mut c = small_cluster();
        let node = NodeId { dc: DcId(0), idx: 0 };
        let cids = c.dcs[0].nodes[0].containers.clone();
        c.grant(cids[0], jm());
        c.start_task(cids[0], task(3), 0.5, secs(1));
        let (dead_c, dead_t) = c.kill_node(node, secs(2));
        assert_eq!(dead_c.len(), 2);
        assert_eq!(dead_t, vec![task(3)]);
        assert_eq!(c.dc_capacity(DcId(0)), 2);
        // Idempotent.
        let (dc2, dt2) = c.kill_node(node, secs(3));
        assert!(dc2.is_empty() && dt2.is_empty());
        let fresh = c.restart_node(node, 2, secs(10));
        assert_eq!(fresh.len(), 2);
        assert_eq!(c.dc_capacity(DcId(0)), 4);
        // New ids, never reused.
        assert!(fresh.iter().all(|f| !cids.contains(f)));
    }

    #[test]
    fn dc_load_sums_usage() {
        let mut c = small_cluster();
        let pool = c.free_pool(DcId(0));
        c.grant(pool[0], jm());
        c.grant(pool[1], jm());
        c.start_task(pool[0], task(0), 0.5, secs(1));
        c.start_task(pool[1], task(1), 0.25, secs(1));
        assert!((c.dc_load(DcId(0)) - 0.75).abs() < 1e-9);
        assert_eq!(c.dc_load(DcId(1)), 0.0);
    }
}
