//! Cluster substrate: data centers, worker nodes, containers and the
//! per-container utilization monitor (§5 "Monitor mechanism").
//!
//! A *container* is the unified resource unit of the paper: a fixed
//! `<cores, memory>` slot, normalized to capacity 1.0. Both tasks and job
//! managers run in containers, which is why both failure classes occur
//! with the same probability on spot instances (§2.3). Parades may pack
//! multiple tasks into one container as long as Σ r ≤ 1.
//!
//! Utilization is tracked as a time-weighted step function of the used
//! fraction, mirroring the 1 Hz OS-counter monitor the paper adds to
//! YARN's NodeManager; [`Cluster::take_period_utilization`] returns the
//! average over the closing scheduling period — exactly the `u(q−1)` that
//! Af consumes.
//!
//! Node state is stored struct-of-arrays ([`NodeTable`]): parallel `Vec`s
//! for class/alive/started-at plus a `(base, count)` range into the dense
//! container table, indexed by `dc * workers_per_dc + idx`. A node costs
//! [`soa_bytes_per_node`] bytes, and the sweeps that touch every node of
//! a DC (`market_tick` revocation scans, `kill_dc`) walk contiguous
//! memory — the layout planet-scale generated topologies (`crate::topo`)
//! need. The representation is private; callers go through the same
//! accessor surface as before ([`Cluster::node_class`],
//! [`Cluster::node_alive`], [`Cluster::node_ids`], ...), and
//! [`set_shadow_check`] can arm a legacy per-node-struct mirror that
//! cross-checks every mutation — `rust/tests/golden_digests.rs` runs the
//! whole standard campaign under it to prove the swap is a pure
//! representation change.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::cloud::InstanceClass;
use crate::ids::{ContainerId, DcId, JmId, NodeId, TaskId};
use crate::sim::{to_secs, SimTime};
use crate::util::stats::TimeWeighted;

/// A running task's footprint inside a container.
#[derive(Debug, Clone, Copy)]
pub struct RunningTask {
    pub task: TaskId,
    pub r: f64,
}

/// A container (executor slot), capacity normalized to 1.0.
#[derive(Debug)]
pub struct Container {
    pub id: ContainerId,
    pub node: NodeId,
    pub rack: usize,
    /// Free resource in [0, 1].
    pub free: f64,
    pub running: Vec<RunningTask>,
    /// Sub-job currently granted this container (None = in the DC free pool).
    pub owner: Option<JmId>,
    /// Utilization monitor (used fraction over time).
    util: TimeWeighted,
    pub alive: bool,
}

impl Container {
    pub fn used(&self) -> f64 {
        1.0 - self.free
    }
}

/// One region (node state lives in the [`NodeTable`], not here).
#[derive(Debug)]
pub struct DataCenter {
    pub id: DcId,
    pub region: String,
}

/// Struct-of-arrays node store. Row `dc * workers_per_dc + idx` holds one
/// node; a node's containers are the consecutive id range
/// `cbase..cbase + ccount` (both [`Cluster::build`] and
/// [`Cluster::restart_node`] allocate container ids consecutively, so a
/// range replaces the per-node `Vec<ContainerId>`). Racks are a pure
/// function of the in-DC index (`idx % racks_per_dc`) and are not stored.
#[derive(Debug, Default)]
struct NodeTable {
    workers_per_dc: usize,
    racks_per_dc: usize,
    class: Vec<InstanceClass>,
    alive: Vec<bool>,
    started_at: Vec<SimTime>,
    cbase: Vec<u64>,
    ccount: Vec<u32>,
}

impl NodeTable {
    #[inline]
    fn row(&self, node: NodeId) -> usize {
        debug_assert!(node.idx < self.workers_per_dc, "node idx out of range");
        node.dc.0 * self.workers_per_dc + node.idx
    }

    #[inline]
    fn rack_of(&self, idx: usize) -> usize {
        idx % self.racks_per_dc.max(1)
    }

    #[inline]
    fn containers_of(&self, row: usize) -> impl Iterator<Item = ContainerId> {
        let base = self.cbase[row];
        (base..base + self.ccount[row] as u64).map(ContainerId)
    }
}

/// Memory cost of one node row in the struct-of-arrays store (the figure
/// the `planet-churn-*` bench rows report).
pub fn soa_bytes_per_node() -> usize {
    std::mem::size_of::<InstanceClass>()
        + std::mem::size_of::<bool>()
        + std::mem::size_of::<SimTime>()
        + std::mem::size_of::<u64>()
        + std::mem::size_of::<u32>()
}

/// When armed (differential tests only), every [`Cluster::build`] also
/// populates a legacy per-node-struct mirror and every node mutation
/// cross-checks the two representations. Read once at build time.
static SHADOW_CHECK: AtomicBool = AtomicBool::new(false);

/// Arm/disarm the legacy shadow mirror for clusters built from now on.
pub fn set_shadow_check(on: bool) {
    SHADOW_CHECK.store(on, Ordering::SeqCst);
}

/// The pre-SoA per-node struct, kept verbatim as the shadow-check mirror.
#[derive(Debug, Clone, PartialEq)]
struct LegacyNode {
    id: NodeId,
    rack: usize,
    class: InstanceClass,
    containers: Vec<ContainerId>,
    alive: bool,
    started_at: SimTime,
}

/// Dense container table: ids are allocated monotonically and entries are
/// never removed (death just flips `alive`), so a Vec indexed by id
/// replaces a HashMap — this store sits on the hottest path (every
/// heartbeat / allocation / steal check) and hashing it cost ~38 % of
/// end-to-end runtime before the swap (EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct ContainerStore(Vec<Container>);

impl ContainerStore {
    #[inline]
    pub fn get(&self, id: &ContainerId) -> Option<&Container> {
        self.0.get(id.0 as usize)
    }
    #[inline]
    pub fn get_mut(&mut self, id: &ContainerId) -> Option<&mut Container> {
        self.0.get_mut(id.0 as usize)
    }
    pub fn push(&mut self, c: Container) {
        debug_assert_eq!(c.id.0 as usize, self.0.len(), "ids must stay dense");
        self.0.push(c);
    }
    pub fn len(&self) -> usize {
        self.0.len()
    }
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
    pub fn iter(&self) -> impl Iterator<Item = &Container> {
        self.0.iter()
    }
}

impl std::ops::Index<&ContainerId> for ContainerStore {
    type Output = Container;
    #[inline]
    fn index(&self, id: &ContainerId) -> &Container {
        &self.0[id.0 as usize]
    }
}

/// All machines in all regions, plus the global container table.
#[derive(Debug, Default)]
pub struct Cluster {
    pub dcs: Vec<DataCenter>,
    pub containers: ContainerStore,
    nodes: NodeTable,
    shadow: Option<Vec<LegacyNode>>,
    next_container: u64,
}

impl Cluster {
    /// Build the testbed: `workers` nodes per region, `slots` containers
    /// per node, spread round-robin over `racks` racks. Spot bids are drawn
    /// by the caller (cloud layer) and passed in via `classes`.
    pub fn build(
        regions: &[String],
        workers: usize,
        slots: usize,
        racks: usize,
        mut class_of: impl FnMut(DcId, usize) -> InstanceClass,
    ) -> Cluster {
        let mut cluster = Cluster::default();
        cluster.nodes.workers_per_dc = workers;
        cluster.nodes.racks_per_dc = racks;
        let mut shadow = SHADOW_CHECK.load(Ordering::SeqCst).then(Vec::new);
        for (d, region) in regions.iter().enumerate() {
            let dc = DcId(d);
            for n in 0..workers {
                let id = NodeId { dc, idx: n };
                let rack = n % racks.max(1);
                let class = class_of(dc, n);
                let cbase = cluster.next_container;
                for _ in 0..slots {
                    let cid = ContainerId(cluster.next_container);
                    cluster.next_container += 1;
                    cluster.containers.push(Container {
                        id: cid,
                        node: id,
                        rack,
                        free: 1.0,
                        running: Vec::new(),
                        owner: None,
                        util: TimeWeighted::new(0.0, 0.0),
                        alive: true,
                    });
                }
                cluster.nodes.class.push(class);
                cluster.nodes.alive.push(true);
                cluster.nodes.started_at.push(0);
                cluster.nodes.cbase.push(cbase);
                cluster.nodes.ccount.push(slots as u32);
                if let Some(s) = shadow.as_mut() {
                    s.push(LegacyNode {
                        id,
                        rack,
                        class,
                        containers: (cbase..cbase + slots as u64).map(ContainerId).collect(),
                        alive: true,
                        started_at: 0,
                    });
                }
            }
            cluster.dcs.push(DataCenter { id: dc, region: region.clone() });
        }
        cluster.shadow = shadow;
        if cluster.shadow.is_some() {
            for row in 0..cluster.nodes.alive.len() {
                cluster.shadow_verify(row);
            }
        }
        cluster
    }

    /// Cross-check one node row against the legacy mirror (no-op unless
    /// the cluster was built with [`set_shadow_check`] armed).
    fn shadow_verify(&self, row: usize) {
        let Some(s) = self.shadow.as_ref() else { return };
        let (n, t) = (&s[row], &self.nodes);
        assert_eq!(n.class, t.class[row], "shadow class diverged at node row {row}");
        assert_eq!(n.alive, t.alive[row], "shadow alive diverged at node row {row}");
        assert_eq!(
            n.started_at, t.started_at[row],
            "shadow started_at diverged at node row {row}"
        );
        assert_eq!(n.rack, t.rack_of(n.id.idx), "shadow rack diverged at node row {row}");
        let soa: Vec<ContainerId> = t.containers_of(row).collect();
        assert_eq!(n.containers, soa, "shadow containers diverged at node row {row}");
    }

    pub fn container(&self, id: ContainerId) -> &Container {
        &self.containers[&id]
    }

    pub fn container_mut(&mut self, id: ContainerId) -> &mut Container {
        self.containers.get_mut(&id).expect("unknown container")
    }

    /// The node ids of one DC, in index order (owned, so callers can keep
    /// mutating the cluster while walking them).
    pub fn node_ids(&self, dc: DcId) -> Vec<NodeId> {
        (0..self.nodes.workers_per_dc).map(|idx| NodeId { dc, idx }).collect()
    }

    /// Whether a node is currently up.
    pub fn node_alive(&self, node: NodeId) -> bool {
        self.nodes.alive[self.nodes.row(node)]
    }

    /// The container ids a node currently hosts (its latest incarnation).
    pub fn node_containers(&self, node: NodeId) -> Vec<ContainerId> {
        self.nodes.containers_of(self.nodes.row(node)).collect()
    }

    /// All live containers in a DC.
    pub fn dc_containers(&self, dc: DcId) -> Vec<ContainerId> {
        let mut out = Vec::new();
        for idx in 0..self.nodes.workers_per_dc {
            let row = dc.0 * self.nodes.workers_per_dc + idx;
            if !self.nodes.alive[row] {
                continue;
            }
            out.extend(self.nodes.containers_of(row).filter(|c| self.containers[c].alive));
        }
        out
    }

    /// Live containers in a DC not granted to any sub-job.
    /// Single pass, no intermediate allocation — hot in every allocation
    /// round and steal check.
    pub fn free_pool(&self, dc: DcId) -> Vec<ContainerId> {
        let mut out = Vec::new();
        for idx in 0..self.nodes.workers_per_dc {
            let row = dc.0 * self.nodes.workers_per_dc + idx;
            if !self.nodes.alive[row] {
                continue;
            }
            for cid in self.nodes.containers_of(row) {
                let c = &self.containers[&cid];
                if c.alive && c.owner.is_none() {
                    out.push(cid);
                }
            }
        }
        out
    }

    /// Total live container capacity per DC (|P_j| in the analysis).
    /// Allocation-free count.
    pub fn dc_capacity(&self, dc: DcId) -> usize {
        let mut sum = 0;
        for idx in 0..self.nodes.workers_per_dc {
            let row = dc.0 * self.nodes.workers_per_dc + idx;
            if !self.nodes.alive[row] {
                continue;
            }
            sum += self.nodes.containers_of(row).filter(|c| self.containers[c].alive).count();
        }
        sum
    }

    /// Grant a free container to a sub-job. Panics if already owned.
    pub fn grant(&mut self, cid: ContainerId, owner: JmId) {
        let c = self.container_mut(cid);
        assert!(c.alive, "granting dead container {cid}");
        assert!(c.owner.is_none(), "container {cid} already owned by {:?}", c.owner);
        c.owner = Some(owner);
    }

    /// Transfer ownership (token re-grant after JM recovery, §5).
    pub fn regrant(&mut self, cid: ContainerId, new_owner: JmId) {
        let c = self.container_mut(cid);
        assert!(c.alive);
        c.owner = Some(new_owner);
    }

    /// Return a container to the free pool. Running tasks must have been
    /// handled by the caller; we assert the container is idle.
    pub fn release(&mut self, cid: ContainerId, t: SimTime) {
        let c = self.container_mut(cid);
        debug_assert!(c.running.is_empty(), "releasing busy container {cid}");
        c.owner = None;
        c.free = 1.0;
        c.util.set(to_secs(t), 0.0);
    }

    /// Start a task of footprint `r` on a container. Panics on over-commit
    /// — Parades must check `free` first (the no-over-commit invariant is
    /// property-tested in `jm`).
    pub fn start_task(&mut self, cid: ContainerId, task: TaskId, r: f64, t: SimTime) {
        let c = self.container_mut(cid);
        assert!(c.alive, "starting task on dead container");
        assert!(
            c.free + 1e-9 >= r,
            "over-commit on {cid}: free={} r={r}",
            c.free
        );
        c.free = (c.free - r).max(0.0);
        c.running.push(RunningTask { task, r });
        let used = c.used();
        c.util.set(to_secs(t), used);
    }

    /// Finish (or abort) a task on a container, freeing its resources.
    pub fn finish_task(&mut self, cid: ContainerId, task: TaskId, t: SimTime) -> bool {
        let c = self.container_mut(cid);
        if let Some(pos) = c.running.iter().position(|rt| rt.task == task) {
            let rt = c.running.swap_remove(pos);
            c.free = (c.free + rt.r).min(1.0);
            let used = c.used();
            c.util.set(to_secs(t), used);
            true
        } else {
            false
        }
    }

    /// Period-average utilization of a set of containers (Af's `u(q−1)`),
    /// resetting each monitor window. Containers average equally, matching
    /// the paper's per-second sampling then per-period averaging.
    pub fn take_period_utilization(&mut self, cids: &[ContainerId], t: SimTime) -> f64 {
        if cids.is_empty() {
            return 0.0;
        }
        let ts = to_secs(t);
        let mut sum = 0.0;
        for cid in cids {
            if let Some(c) = self.containers.get_mut(cid) {
                sum += c.util.take_average(ts);
            }
        }
        sum / cids.len() as f64
    }

    /// Kill a node (spot revocation / manual VM termination). Returns the
    /// containers that died and the tasks that were running on them.
    pub fn kill_node(&mut self, node: NodeId, t: SimTime) -> (Vec<ContainerId>, Vec<TaskId>) {
        let mut dead_containers = Vec::new();
        let mut dead_tasks = Vec::new();
        let row = self.nodes.row(node);
        if !self.nodes.alive[row] {
            return (dead_containers, dead_tasks);
        }
        self.nodes.alive[row] = false;
        let cids: Vec<ContainerId> = self.nodes.containers_of(row).collect();
        for cid in cids {
            let c = self.container_mut(cid);
            if !c.alive {
                continue;
            }
            c.alive = false;
            c.util.set(to_secs(t), 0.0);
            for rt in c.running.drain(..) {
                dead_tasks.push(rt.task);
            }
            c.free = 0.0;
            dead_containers.push(cid);
        }
        if let Some(s) = self.shadow.as_mut() {
            s[row].alive = false;
        }
        self.shadow_verify(row);
        (dead_containers, dead_tasks)
    }

    /// Restart a dead node with fresh containers (new instance acquired
    /// from the market). Returns the new container ids.
    pub fn restart_node(&mut self, node: NodeId, slots: usize, t: SimTime) -> Vec<ContainerId> {
        let row = self.nodes.row(node);
        let rack = self.nodes.rack_of(node.idx);
        let cbase = self.next_container;
        let mut fresh = Vec::new();
        for _ in 0..slots {
            let cid = ContainerId(self.next_container);
            self.next_container += 1;
            self.containers.push(Container {
                id: cid,
                node,
                rack,
                free: 1.0,
                running: Vec::new(),
                owner: None,
                util: TimeWeighted::new(to_secs(t), 0.0),
                alive: true,
            });
            fresh.push(cid);
        }
        self.nodes.alive[row] = true;
        self.nodes.started_at[row] = t;
        self.nodes.cbase[row] = cbase;
        self.nodes.ccount[row] = slots as u32;
        if let Some(s) = self.shadow.as_mut() {
            let n = &mut s[row];
            n.alive = true;
            n.started_at = t;
            n.containers = fresh.clone();
        }
        self.shadow_verify(row);
        fresh
    }

    /// The instance class a node is currently paid under.
    pub fn node_class(&self, node: NodeId) -> InstanceClass {
        self.nodes.class[self.nodes.row(node)]
    }

    /// Re-class a node (market re-acquisition may come back with a fresh
    /// bid or as an on-demand instance — the bid strategy's decision).
    pub fn set_node_class(&mut self, node: NodeId, class: InstanceClass) {
        let row = self.nodes.row(node);
        self.nodes.class[row] = class;
        if let Some(s) = self.shadow.as_mut() {
            s[row].class = class;
        }
        self.shadow_verify(row);
    }

    /// Sum of used resource over live containers of a DC (for injection
    /// experiments and reporting).
    pub fn dc_load(&self, dc: DcId) -> f64 {
        self.dc_containers(dc).iter().map(|c| self.containers[c].used()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::{JobId, StageId};
    use crate::sim::secs;

    fn task(i: u32) -> TaskId {
        TaskId { job: JobId(1), stage: StageId(0), index: i }
    }

    fn jm() -> JmId {
        JmId { job: JobId(1), dc: DcId(0) }
    }

    fn small_cluster() -> Cluster {
        Cluster::build(
            &["A".into(), "B".into()],
            2,
            2,
            2,
            |_, _| InstanceClass::OnDemand,
        )
    }

    #[test]
    fn build_shapes() {
        let c = small_cluster();
        assert_eq!(c.dcs.len(), 2);
        assert_eq!(c.dc_containers(DcId(0)).len(), 4);
        assert_eq!(c.dc_capacity(DcId(1)), 4);
        assert_eq!(c.free_pool(DcId(0)).len(), 4);
        // Rack spread: nodes 0,1 on racks 0,1.
        let n0 = c.node_containers(NodeId { dc: DcId(0), idx: 0 });
        let n1 = c.node_containers(NodeId { dc: DcId(0), idx: 1 });
        assert_eq!(c.container(n0[0]).rack, 0);
        assert_eq!(c.container(n1[0]).rack, 1);
    }

    #[test]
    fn node_accessors_expose_the_soa_store() {
        let mut c = small_cluster();
        assert_eq!(
            c.node_ids(DcId(1)),
            vec![NodeId { dc: DcId(1), idx: 0 }, NodeId { dc: DcId(1), idx: 1 }]
        );
        let node = NodeId { dc: DcId(1), idx: 1 };
        assert!(c.node_alive(node));
        let before = c.node_containers(node);
        assert_eq!(before.len(), 2);
        c.kill_node(node, secs(1));
        assert!(!c.node_alive(node));
        let fresh = c.restart_node(node, 2, secs(5));
        assert!(c.node_alive(node));
        assert_eq!(c.node_containers(node), fresh);
        assert_ne!(c.node_containers(node), before, "restart re-homes containers");
        // The whole store costs a few tens of bytes per node.
        assert!(soa_bytes_per_node() <= 48, "{}", soa_bytes_per_node());
    }

    #[test]
    fn shadow_mirror_cross_checks_every_mutation() {
        set_shadow_check(true);
        let mut c = small_cluster();
        set_shadow_check(false);
        assert!(c.shadow.is_some(), "shadow must arm at build");
        let node = NodeId { dc: DcId(0), idx: 1 };
        c.kill_node(node, secs(2));
        c.restart_node(node, 2, secs(9));
        c.set_node_class(node, InstanceClass::Spot { bid: 0.05 });
        assert_eq!(c.node_class(node), InstanceClass::Spot { bid: 0.05 });
        // An unarmed build carries no mirror.
        assert!(small_cluster().shadow.is_none());
    }

    #[test]
    fn grant_and_release_cycle() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        assert_eq!(c.free_pool(DcId(0)).len(), 3);
        assert_eq!(c.container(cid).owner, Some(jm()));
        c.release(cid, secs(10));
        assert_eq!(c.free_pool(DcId(0)).len(), 4);
    }

    #[test]
    #[should_panic(expected = "already owned")]
    fn double_grant_panics() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        c.grant(cid, jm());
    }

    #[test]
    fn task_packing_respects_capacity() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        c.start_task(cid, task(0), 0.6, secs(1));
        assert!((c.container(cid).free - 0.4).abs() < 1e-9);
        c.start_task(cid, task(1), 0.4, secs(2));
        assert!(c.container(cid).free < 1e-9);
        assert!(c.finish_task(cid, task(0), secs(5)));
        assert!((c.container(cid).free - 0.6).abs() < 1e-9);
        assert!(!c.finish_task(cid, task(0), secs(6)), "double finish is a no-op");
    }

    #[test]
    #[should_panic(expected = "over-commit")]
    fn overcommit_panics() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        c.start_task(cid, task(0), 0.8, secs(1));
        c.start_task(cid, task(1), 0.3, secs(1));
    }

    #[test]
    fn period_utilization_is_time_weighted() {
        let mut c = small_cluster();
        let cid = c.free_pool(DcId(0))[0];
        c.grant(cid, jm());
        // busy 0.5 for the first half of a 10 s period, idle after.
        c.start_task(cid, task(0), 0.5, secs(0));
        c.finish_task(cid, task(0), secs(5));
        let u = c.take_period_utilization(&[cid], secs(10));
        assert!((u - 0.25).abs() < 1e-9, "u={u}");
        // Next period: fully idle.
        let u2 = c.take_period_utilization(&[cid], secs(20));
        assert!(u2.abs() < 1e-9);
    }

    #[test]
    fn kill_node_reports_casualties_and_restart_revives() {
        let mut c = small_cluster();
        let node = NodeId { dc: DcId(0), idx: 0 };
        let cids = c.node_containers(node);
        c.grant(cids[0], jm());
        c.start_task(cids[0], task(3), 0.5, secs(1));
        let (dead_c, dead_t) = c.kill_node(node, secs(2));
        assert_eq!(dead_c.len(), 2);
        assert_eq!(dead_t, vec![task(3)]);
        assert_eq!(c.dc_capacity(DcId(0)), 2);
        // Idempotent.
        let (dc2, dt2) = c.kill_node(node, secs(3));
        assert!(dc2.is_empty() && dt2.is_empty());
        let fresh = c.restart_node(node, 2, secs(10));
        assert_eq!(fresh.len(), 2);
        assert_eq!(c.dc_capacity(DcId(0)), 4);
        // New ids, never reused.
        assert!(fresh.iter().all(|f| !cids.contains(f)));
    }

    #[test]
    fn dc_load_sums_usage() {
        let mut c = small_cluster();
        let pool = c.free_pool(DcId(0));
        c.grant(pool[0], jm());
        c.grant(pool[1], jm());
        c.start_task(pool[0], task(0), 0.5, secs(1));
        c.start_task(pool[1], task(1), 0.25, secs(1));
        assert!((c.dc_load(DcId(0)) - 0.75).abs() < 1e-9);
        assert_eq!(c.dc_load(DcId(1)), 0.0);
    }
}
