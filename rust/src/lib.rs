//! HOUTU — a reproduction of "Towards Reliable (and Efficient) Job
//! Executions in a Practical Geo-distributed Data Analytics System"
//! (Zhang et al., 2018) as a Rust coordinator over JAX/Pallas-compiled
//! compute artifacts executed through PJRT.
//!
//! See DESIGN.md for the system inventory and the per-figure experiment
//! index, and EXPERIMENTS.md for measured results.

pub mod bench;
pub mod cli;
pub mod cloud;
pub mod dag;
pub mod deploy;
pub mod exp;
pub mod cluster;
pub mod config;
pub mod consensus;
pub mod ids;
pub mod jm;
pub mod load;
pub mod master;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod storage;
pub mod testkit;
pub mod topo;
pub mod trace;
pub mod util;
pub mod workloads;
