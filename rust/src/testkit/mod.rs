//! Minimal in-repo property-testing kit.
//!
//! The offline image does not ship `proptest`, so this module provides the
//! subset we need: seeded random generators, a `forall` runner that reports
//! the failing seed + case, and greedy shrinking. All property tests in
//! this repo (scheduler invariants, consensus log consistency, DAG
//! topology, Af bounds) run through this kit, so a failure is always
//! reproducible by re-running with the printed seed.
//!
//! The [`Gen`] shrink contract is not limited to scalars and vectors: the
//! chaos fuzzer ([`crate::scenario::fuzz`]) implements `Gen` over whole
//! `ScenarioSpec` cells, so the same greedy [`shrink_failure`] loop that
//! minimizes a failing integer also minimizes a failing chaos schedule
//! (drop events, halve times/factors/counts, shrink seeds).

use crate::util::Pcg;

/// Number of cases per property (kept modest; every case is deterministic).
pub const DEFAULT_CASES: usize = 256;

/// A generator of random values of type `T`.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg) -> T;
    /// Candidate smaller values to try when shrinking a failing case.
    /// Candidates must be *strictly simpler* by some finite measure so the
    /// greedy loop terminates; returning the input itself would loop until
    /// the iteration budget.
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Functions are generators.
impl<T, F: Fn(&mut Pcg) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Pcg) -> T {
        self(rng)
    }
}

/// Generator of usize in [lo, hi] with halving shrink.
pub struct UsizeIn(pub usize, pub usize);

impl Gen<usize> for UsizeIn {
    fn generate(&self, rng: &mut Pcg) -> usize {
        self.0 + rng.index(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator of f64 in [lo, hi) with halving shrink toward `lo`.
pub struct F64In(pub f64, pub f64);

impl Gen<f64> for F64In {
    fn generate(&self, rng: &mut Pcg) -> f64 {
        rng.uniform(self.0, self.1)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        // Any value strictly above `lo` shrinks; the old epsilon guard
        // (`|v - lo| > 1e-9`) dropped the boundary candidate for values
        // within epsilon of `lo`, so shrinking stalled at `lo + tiny`
        // instead of converging to the exact bound.
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            let mid = self.0 + (*v - self.0) / 2.0;
            if mid > self.0 && mid < *v {
                out.push(mid);
            }
        }
        out
    }
}

/// Generator of vectors with length in [min_len, max_len], shrinking by
/// halving the vector and element-wise shrinking the first failing slot.
pub struct VecOf<G> {
    pub elem: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<T: Clone, G: Gen<T>> Gen<Vec<T>> for VecOf<G> {
    fn generate(&self, rng: &mut Pcg) -> Vec<T> {
        let len = self.min_len + rng.index(self.max_len - self.min_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, v: &Vec<T>) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // Drop the back half, drop one element.
            let keep = (v.len() / 2).max(self.min_len);
            out.push(v[..keep].to_vec());
            out.push(v[1..].to_vec());
            let mut minus_last = v.clone();
            minus_last.pop();
            out.push(minus_last);
        }
        // Shrink each position once.
        for (i, x) in v.iter().enumerate().take(8) {
            for sx in self.elem.shrink(x) {
                let mut v2 = v.clone();
                v2[i] = sx;
                out.push(v2);
            }
        }
        out
    }
}

/// Outcome carried by a failed property for reporting.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub case: String,
    pub message: String,
    pub shrunk_iterations: usize,
}

/// Greedily minimize a failing case: repeatedly replace it with the first
/// shrink candidate that still fails, until no candidate fails or the
/// `max_iters` probe budget runs out. Deterministic: candidate order comes
/// from [`Gen::shrink`] alone, so the same failing input always shrinks to
/// the same minimum. Returns the minimal failing case, its failure
/// message, and the number of candidate probes spent.
pub fn shrink_failure<T, G>(
    gen: &G,
    input: T,
    message: String,
    max_iters: usize,
    prop: impl Fn(&T) -> Result<(), String>,
) -> (T, String, usize)
where
    T: Clone,
    G: Gen<T>,
{
    let mut best = input;
    let mut best_msg = message;
    let mut iters = 0;
    'outer: loop {
        for cand in gen.shrink(&best) {
            iters += 1;
            if iters > max_iters {
                break 'outer;
            }
            if let Err(m2) = prop(&cand) {
                best = cand;
                best_msg = m2;
                continue 'outer;
            }
        }
        break;
    }
    (best, best_msg, iters)
}

/// Run `prop` on `cases` generated inputs. On failure, greedily shrink and
/// panic with the smallest failing case and the seed to reproduce.
pub fn forall_cases<T, G>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&T) -> Result<(), String>)
where
    T: Clone + std::fmt::Debug,
    G: Gen<T>,
{
    let mut rng = Pcg::seeded(seed);
    for case_idx in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let (best, best_msg, iters) = shrink_failure(gen, input, msg, 2000, &prop);
            panic!(
                "property failed (seed={seed}, case #{case_idx}, {iters} shrink steps)\n\
                 input: {best:?}\nerror: {best_msg}"
            );
        }
    }
}

/// `forall` with the default case count.
pub fn forall<T, G>(seed: u64, gen: &G, prop: impl Fn(&T) -> Result<(), String>)
where
    T: Clone + std::fmt::Debug,
    G: Gen<T>,
{
    forall_cases(seed, DEFAULT_CASES, gen, prop)
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, &UsizeIn(0, 100), |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(2, &UsizeIn(0, 1000), |&x| {
            if x < 500 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        });
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Capture the panic message and check the shrunk case is minimal-ish.
        let result = std::panic::catch_unwind(|| {
            forall(3, &UsizeIn(0, 10_000), |&x| {
                if x < 123 {
                    Ok(())
                } else {
                    Err("boom".into())
                }
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("property should have failed"),
        };
        // Greedy halving shrink should land well below the original range.
        let input_line = msg.lines().find(|l| l.starts_with("input:")).unwrap();
        let value: usize = input_line.trim_start_matches("input: ").parse().unwrap();
        assert!((123..=1000).contains(&value), "shrunk to {value}");
    }

    #[test]
    fn f64_shrink_converges_to_the_exact_lower_bound() {
        // Property over the generator itself: from any start in [lo, hi),
        // greedily shrinking an always-failing property must terminate at
        // *exactly* `lo` — including starts within the old 1e-9 epsilon
        // of the bound, which previously stalled one ulp short.
        let lo = 0.25;
        let gen_range = F64In(lo, 10.0);
        forall_cases(21, 64, &F64In(lo, 10.0), |&start: &f64| {
            let (best, _, _) =
                shrink_failure(&gen_range, start, "always fails".into(), 200, |_| {
                    Err("still failing".into())
                });
            prop_assert!(best == lo, "stalled at {best} (start {start})");
            Ok(())
        });
        // The regression case the epsilon comparison used to lose: a value
        // epsilon-close to (but not at) the bound still offers `lo`.
        let near = lo + 1e-12;
        let cands = gen_range.shrink(&near);
        assert!(cands.contains(&lo), "boundary candidate missing: {cands:?}");
        // The bound itself is a fixed point.
        assert!(gen_range.shrink(&lo).is_empty());
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let gen = VecOf { elem: UsizeIn(1, 5), min_len: 2, max_len: 9 };
        forall(4, &gen, |v: &Vec<usize>| {
            prop_assert!((2..=9).contains(&v.len()), "len {}", v.len());
            prop_assert!(v.iter().all(|&x| (1..=5).contains(&x)), "elem out of range");
            Ok(())
        });
    }
}
