//! Seeded planet-scale topology generator (`topology = "generated:..."`).
//!
//! Every built-in scenario mirrors the paper's 4-region deployment; the
//! ROADMAP's north star needs dozens-to-hundreds of DCs with realistic
//! WAN structure. This module turns a three-token spec string,
//! `generated:<dcs>,<nodes_per_dc>,<seed>`, into a deterministic world
//! layout: per-DC positions on a unit square, spot-price correlation
//! groups (DCs in one group share a regional market), and a symmetric
//! `(mean, std)` bandwidth matrix whose cross-DC capacity decays with
//! distance while the diagonal keeps the measured LAN figure.
//!
//! Two properties are load-bearing and pinned by `rust/tests/planet.rs`:
//!
//! * **Purity.** The layout is a pure function of `(dcs, nodes_per_dc,
//!   seed)` — regenerating a spec is bit-identical, so a topology token
//!   in a campaign/load/fuzz spec is a complete description of the
//!   world and repro TOMLs stay one-line.
//! * **Prefix stability.** DC `i` draws from its own seeded substream
//!   and every matrix entry is a function of the two endpoint positions
//!   only, so the leading `k×k` block of a `generated:n,...` world is
//!   identical to the whole `generated:k,...` world (same seed, same
//!   nodes). The two-tier fidelity model leans on this: growing the
//!   *background* DC count cannot perturb the exact tier's WAN inputs,
//!   which is what makes the 0-vs-200-background digest invariance in
//!   `rust/tests/part_world.rs` provable rather than lucky.
//!
//! The config layer (`topology.generated`) expands a parsed spec into
//! concrete region names / worker counts / bandwidth via [`generate`];
//! see `docs/SCALE.md` for the schema and the promotion rule the
//! two-tier engine applies on top.

use crate::util::error::Result;
use crate::util::Pcg;
use crate::{anyhow, ensure};

/// Per-DC substream base: DC `i` draws from `Pcg::new(seed, DC_STREAM + i)`.
const DC_STREAM: u64 = 0x7070;
/// Per-group substream base: group centers are functions of the group
/// index alone, never of the DC count.
const GROUP_STREAM: u64 = 0x9090;

/// Number of spot-price correlation groups ("continental" markets). A
/// fixed constant — not a function of the DC count — so group draws stay
/// prefix-stable as worlds grow.
pub const CORRELATION_GROUPS: usize = 16;

/// Intra-DC (diagonal) bandwidth `(mean, std)` in MB/s — the measured
/// LAN figure the paper-shaped 4-region matrix also uses.
pub const LAN_BW: (f64, f64) = (827.0, 104.0);

/// Cross-DC bandwidth floor (MB/s): the capacity two antipodal DCs keep.
const CROSS_BW_FLOOR: f64 = 25.0;
/// Cross-DC bandwidth scale: capacity added as distance shrinks to 0.
/// Floor + scale = 525 MB/s < LAN, so intra-DC always beats cross-DC.
const CROSS_BW_SCALE: f64 = 500.0;
/// Distance-decay rate for cross-DC capacity.
const CROSS_BW_DECAY: f64 = 3.0;

/// Hard caps on a parsed spec, so a typo'd token fails fast instead of
/// allocating a gigabyte of bandwidth matrix.
pub const MAX_DCS: usize = 1024;
pub const MAX_NODES_PER_DC: usize = 4096;
const MAX_TOTAL_NODES: usize = 1 << 20;

/// Parsed `generated:<dcs>,<nodes_per_dc>,<seed>` topology token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopoSpec {
    pub dcs: usize,
    pub nodes_per_dc: usize,
    pub seed: u64,
}

/// Parse a `generated:<dcs>,<nodes_per_dc>,<seed>` token with bounds
/// checks. Every failure names the token and the expected shape, so a
/// bad `--topology` / `topology =` value is a clear error, not a panic.
pub fn parse_spec(s: &str) -> Result<TopoSpec> {
    let rest = s.strip_prefix("generated:").ok_or_else(|| {
        anyhow!(
            "topology spec {s:?} must have the form \
             \"generated:<dcs>,<nodes_per_dc>,<seed>\""
        )
    })?;
    let parts: Vec<&str> = rest.split(',').collect();
    ensure!(
        parts.len() == 3,
        "topology spec {s:?} needs exactly three comma-separated fields \
         (<dcs>,<nodes_per_dc>,<seed>)"
    );
    let field = |idx: usize, name: &str| -> Result<u64> {
        parts[idx]
            .trim()
            .parse::<u64>()
            .map_err(|_| anyhow!("topology spec {s:?}: {name} {:?} is not a number", parts[idx]))
    };
    let dcs = field(0, "dc count")? as usize;
    let nodes_per_dc = field(1, "nodes_per_dc")? as usize;
    let seed = field(2, "seed")?;
    ensure!(
        (1..=MAX_DCS).contains(&dcs),
        "topology spec {s:?}: dc count {dcs} out of range 1..={MAX_DCS}"
    );
    ensure!(
        (1..=MAX_NODES_PER_DC).contains(&nodes_per_dc),
        "topology spec {s:?}: nodes_per_dc {nodes_per_dc} out of range 1..={MAX_NODES_PER_DC}"
    );
    ensure!(
        dcs * nodes_per_dc <= MAX_TOTAL_NODES,
        "topology spec {s:?}: {dcs}x{nodes_per_dc} nodes exceeds the \
         {MAX_TOTAL_NODES}-node cap"
    );
    Ok(TopoSpec { dcs, nodes_per_dc, seed })
}

/// A fully generated world layout (see the module docs for the model).
#[derive(Debug, Clone, PartialEq)]
pub struct GeneratedTopology {
    pub spec: TopoSpec,
    /// Region names, `"G<group>-DC<i>"` — the `G` prefix is the DC's
    /// spot-price correlation group.
    pub regions: Vec<String>,
    /// Correlation group per DC (`0..CORRELATION_GROUPS`).
    pub groups: Vec<usize>,
    /// DC positions on the unit square (group center + local jitter).
    pub positions: Vec<(f64, f64)>,
    /// Symmetric `dcs × dcs` `(mean, std)` bandwidth matrix in MB/s;
    /// the diagonal is [`LAN_BW`].
    pub bandwidth: Vec<Vec<(f64, f64)>>,
}

/// Position + group of one DC, drawn from its own substream. Public to
/// the crate only through [`generate`]; factored out so the prefix
/// stability argument is visible: nothing here reads the DC count.
fn place_dc(seed: u64, i: usize) -> (usize, (f64, f64)) {
    let mut rng = Pcg::new(seed, DC_STREAM + i as u64);
    let g = rng.index(CORRELATION_GROUPS);
    let mut grng = Pcg::new(seed, GROUP_STREAM + g as u64);
    let (cx, cy) = (grng.f64(), grng.f64());
    let x = (cx + rng.uniform(-0.06, 0.06)).clamp(0.0, 1.0);
    let y = (cy + rng.uniform(-0.06, 0.06)).clamp(0.0, 1.0);
    (g, (x, y))
}

/// Deterministically expand a spec into a world layout. Pure function of
/// the spec; see the module docs for the purity/prefix-stability pins.
pub fn generate(spec: TopoSpec) -> GeneratedTopology {
    let n = spec.dcs;
    let mut groups = Vec::with_capacity(n);
    let mut positions = Vec::with_capacity(n);
    let mut regions = Vec::with_capacity(n);
    for i in 0..n {
        let (g, pos) = place_dc(spec.seed, i);
        groups.push(g);
        positions.push(pos);
        regions.push(format!("G{g}-DC{i}"));
    }
    let mut bandwidth = vec![vec![LAN_BW; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let (xi, yi) = positions[i];
            let (xj, yj) = positions[j];
            let d = ((xi - xj).powi(2) + (yi - yj).powi(2)).sqrt();
            let mean = CROSS_BW_FLOOR + CROSS_BW_SCALE * (-CROSS_BW_DECAY * d).exp();
            let cell = (mean, mean / 4.0);
            bandwidth[i][j] = cell;
            bandwidth[j][i] = cell;
        }
    }
    GeneratedTopology { spec, regions, groups, positions, bandwidth }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_tokens_parse_and_bad_ones_fail_with_clear_errors() {
        let ts = parse_spec("generated:64,8,7").expect("valid token");
        assert_eq!(ts, TopoSpec { dcs: 64, nodes_per_dc: 8, seed: 7 });
        let ts = parse_spec("generated: 16 , 2 , 42 ").expect("whitespace tolerated");
        assert_eq!(ts, TopoSpec { dcs: 16, nodes_per_dc: 2, seed: 42 });
        for bad in [
            "64,8,7",
            "generated:64,8",
            "generated:64,8,7,9",
            "generated:zero,8,7",
            "generated:0,8,7",
            "generated:64,0,7",
            "generated:9999,8,7",
            "generated:1024,4096,7",
        ] {
            let err = parse_spec(bad).expect_err(bad).to_string();
            assert!(err.contains("topology spec"), "{bad}: unhelpful error {err:?}");
        }
    }

    #[test]
    fn generation_is_a_pure_function_of_the_spec() {
        let spec = TopoSpec { dcs: 48, nodes_per_dc: 4, seed: 11 };
        let a = generate(spec);
        let b = generate(spec);
        assert_eq!(a, b, "same spec must regenerate bit-identically");
        let c = generate(TopoSpec { seed: 12, ..spec });
        assert_ne!(a.bandwidth, c.bandwidth, "the seed must move the matrix");
    }

    #[test]
    fn matrices_are_symmetric_finite_positive_and_lan_dominates() {
        let g = generate(TopoSpec { dcs: 32, nodes_per_dc: 2, seed: 3 });
        for i in 0..32 {
            assert_eq!(g.bandwidth[i][i], LAN_BW);
            for j in 0..32 {
                let (m, s) = g.bandwidth[i][j];
                assert!(m.is_finite() && m > 0.0, "[{i}][{j}] mean {m}");
                assert!(s.is_finite() && s > 0.0, "[{i}][{j}] std {s}");
                assert_eq!(g.bandwidth[i][j], g.bandwidth[j][i], "asymmetry at [{i}][{j}]");
                if i != j {
                    assert!(m < LAN_BW.0, "cross-DC [{i}][{j}] {m} beats the LAN");
                }
            }
        }
    }

    #[test]
    fn leading_block_is_prefix_stable_as_the_world_grows() {
        let small = generate(TopoSpec { dcs: 16, nodes_per_dc: 2, seed: 7 });
        let big = generate(TopoSpec { dcs: 64, nodes_per_dc: 2, seed: 7 });
        assert_eq!(&big.regions[..16], &small.regions[..]);
        assert_eq!(&big.groups[..16], &small.groups[..]);
        for i in 0..16 {
            assert_eq!(
                &big.bandwidth[i][..16],
                &small.bandwidth[i][..],
                "row {i} of the leading block drifted with the DC count"
            );
        }
    }

    #[test]
    fn correlation_groups_cluster_capacity() {
        // Same-group DCs sit around one center, so their mean pairwise
        // bandwidth must beat the cross-group mean (deterministic for a
        // fixed seed; a large world keeps the averages stable).
        let g = generate(TopoSpec { dcs: 128, nodes_per_dc: 1, seed: 5 });
        let (mut same, mut cross) = ((0.0, 0usize), (0.0, 0usize));
        for i in 0..128 {
            for j in (i + 1)..128 {
                let m = g.bandwidth[i][j].0;
                if g.groups[i] == g.groups[j] {
                    same = (same.0 + m, same.1 + 1);
                } else {
                    cross = (cross.0 + m, cross.1 + 1);
                }
            }
        }
        assert!(same.1 > 0 && cross.1 > 0, "both pair kinds must occur");
        assert!(
            same.0 / same.1 as f64 > cross.0 / cross.1 as f64,
            "same-group capacity must beat cross-group on average"
        );
    }
}
