//! Load report export: the human table, plus JSON/CSV serialization with
//! the same write-then-read-back round-trip verification the campaign
//! report uses (the crate stays dependency-free, so both writers are
//! hand-rolled and the verifier re-parses with [`crate::util::json`]).
//!
//! The CSV is tidy-shaped: one row per ramp step, with the run-level
//! columns (name, seed, digest, knee) repeated on every row — digests
//! are 16-hex strings because JSON numbers (and spreadsheet importers)
//! cannot carry a u64 losslessly.

use std::fmt::Write as _;

use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::{anyhow, ensure};

use super::run::LoadOutcome;

fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Quote a CSV cell when it needs it (commas, quotes, newlines).
fn csv_cell(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

const STEP_COLUMNS: [&str; 12] = [
    "step",
    "offered_rps",
    "from_secs",
    "until_secs",
    "submitted",
    "completed",
    "p50_secs",
    "p99_secs",
    "p999_secs",
    "goodput_rps",
    "goodput_frac",
    "slo_ok",
];

impl LoadOutcome {
    /// The one-line knee verdict (also printed by the CLI, greppably).
    pub fn knee_line(&self) -> String {
        match &self.knee {
            Some(k) => {
                let sustained = match k.sustained_rps {
                    Some(r) => format!("{r:.3} rps sustained"),
                    None => "nothing sustained".to_string(),
                };
                format!(
                    "knee: broke at step {} ({:.3} rps): {}; {sustained}",
                    k.broke_step, k.broke_rps, k.reason
                )
            }
            None => {
                let top = self.steps.last().map_or(0.0, |s| s.offered_rps);
                format!("knee: none up to {top:.3} rps (SLO held at every step)")
            }
        }
    }

    /// Human-readable ramp table + verdict.
    pub fn render(&self) -> String {
        let mut out = String::new();
        writeln!(
            out,
            "Load {:?} on {}, seed {} — digest {:016x}",
            self.name, self.deployment, self.seed, self.digest
        )
        .unwrap();
        writeln!(
            out,
            "  {} arrivals, {} completed; SLO: p99 <= {:.1}s, goodput >= {:.0}%",
            self.arrivals,
            self.completed,
            self.slo_p99_secs,
            self.slo_goodput_frac * 100.0
        )
        .unwrap();
        writeln!(
            out,
            "{:>5} {:>9} {:>10} {:>10} {:>8} {:>8} {:>8} {:>9} {:>8} {:>5}",
            "step", "rps", "submitted", "completed", "p50(s)", "p99(s)", "p999(s)",
            "goodput", "frac", "slo"
        )
        .unwrap();
        for s in &self.steps {
            writeln!(
                out,
                "{:>5} {:>9.3} {:>10} {:>10} {:>8.1} {:>8.1} {:>8.1} {:>9.3} {:>7.0}% {:>5}",
                s.step,
                s.offered_rps,
                s.submitted,
                s.completed,
                s.p50_secs,
                s.p99_secs,
                s.p999_secs,
                s.goodput_rps,
                s.goodput_frac * 100.0,
                if s.slo_ok { "ok" } else { "BRK" }
            )
            .unwrap();
        }
        writeln!(out, "{}", self.knee_line()).unwrap();
        for v in &self.violations {
            writeln!(out, "violation: {v}").unwrap();
        }
        out
    }

    /// The outcome as a JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"load\": {},\n", json::escape(&self.name)));
        out.push_str(&format!("  \"deployment\": {},\n", json::escape(self.deployment)));
        out.push_str(&format!("  \"seed\": {},\n", self.seed));
        out.push_str(&format!("  \"digest\": \"{:016x}\",\n", self.digest));
        out.push_str(&format!("  \"events_processed\": {},\n", self.events_processed));
        out.push_str(&format!("  \"peak_pending\": {},\n", self.peak_pending));
        out.push_str(&format!("  \"arrivals\": {},\n", self.arrivals));
        out.push_str(&format!("  \"completed\": {},\n", self.completed));
        out.push_str(&format!(
            "  \"slo\": {{\"p99_secs\": {}, \"goodput_frac\": {}}},\n",
            json_f64(self.slo_p99_secs),
            json_f64(self.slo_goodput_frac)
        ));
        match &self.knee {
            Some(k) => {
                let sustained = match k.sustained_rps {
                    Some(r) => json_f64(r),
                    None => "null".to_string(),
                };
                out.push_str(&format!(
                    "  \"knee\": {{\"broke_step\": {}, \"broke_rps\": {}, \
                     \"sustained_rps\": {sustained}, \"reason\": {}}},\n",
                    k.broke_step,
                    json_f64(k.broke_rps),
                    json::escape(&k.reason)
                ));
            }
            None => out.push_str("  \"knee\": null,\n"),
        }
        out.push_str("  \"steps\": [\n");
        for (i, s) in self.steps.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"step\": {}, ", s.step));
            out.push_str(&format!("\"offered_rps\": {}, ", json_f64(s.offered_rps)));
            out.push_str(&format!("\"from_secs\": {}, ", json_f64(s.from_secs)));
            out.push_str(&format!("\"until_secs\": {}, ", json_f64(s.until_secs)));
            out.push_str(&format!("\"submitted\": {}, ", s.submitted));
            out.push_str(&format!("\"completed\": {}, ", s.completed));
            out.push_str(&format!("\"p50_secs\": {}, ", json_f64(s.p50_secs)));
            out.push_str(&format!("\"p99_secs\": {}, ", json_f64(s.p99_secs)));
            out.push_str(&format!("\"p999_secs\": {}, ", json_f64(s.p999_secs)));
            out.push_str(&format!("\"goodput_rps\": {}, ", json_f64(s.goodput_rps)));
            out.push_str(&format!("\"goodput_frac\": {}, ", json_f64(s.goodput_frac)));
            out.push_str(&format!("\"slo_ok\": {}", s.slo_ok));
            out.push_str(if i + 1 == self.steps.len() { "}\n" } else { "},\n" });
        }
        out.push_str("  ],\n");
        let viol: Vec<String> = self.violations.iter().map(|v| json::escape(v)).collect();
        out.push_str(&format!("  \"violations\": [{}]\n", viol.join(", ")));
        out.push_str("}\n");
        out
    }

    /// The outcome as tidy CSV: one row per step, run-level columns
    /// repeated.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str("load,seed,deployment,digest,knee_step,knee_rps,sustained_rps,knee_reason,");
        out.push_str(&STEP_COLUMNS.join(","));
        out.push('\n');
        let (knee_step, knee_rps, sustained, reason) = match &self.knee {
            Some(k) => (
                k.broke_step.to_string(),
                format!("{}", k.broke_rps),
                k.sustained_rps.map(|r| format!("{r}")).unwrap_or_default(),
                k.reason.clone(),
            ),
            None => (String::new(), String::new(), String::new(), String::new()),
        };
        for s in &self.steps {
            out.push_str(&format!(
                "{},{},{},{:016x},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                csv_cell(&self.name),
                self.seed,
                csv_cell(self.deployment),
                self.digest,
                knee_step,
                knee_rps,
                sustained,
                csv_cell(&reason),
                s.step,
                s.offered_rps,
                s.from_secs,
                s.until_secs,
                s.submitted,
                s.completed,
                s.p50_secs,
                s.p99_secs,
                s.p999_secs,
                s.goodput_rps,
                s.goodput_frac,
                s.slo_ok
            ));
        }
        out
    }
}

/// Which format a path's extension selects.
fn format_of(path: &str) -> Result<&'static str> {
    if path.ends_with(".json") {
        Ok("json")
    } else if path.ends_with(".csv") {
        Ok("csv")
    } else {
        Err(anyhow!("report path {path:?} must end in .json or .csv"))
    }
}

/// Write the outcome to `path` (format by extension), read the file back
/// and verify the round trip: byte-identical text, and (for JSON) a
/// successful re-parse whose digest, knee and step count match.
pub fn write_and_verify(out: &LoadOutcome, path: &str) -> Result<&'static str> {
    let format = format_of(path)?;
    let text = match format {
        "json" => out.to_json(),
        _ => out.to_csv(),
    };
    std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
    let back = std::fs::read_to_string(path).with_context(|| format!("re-reading {path}"))?;
    ensure!(back == text, "load report {path}: read-back text differs from what was written");
    match format {
        "json" => verify_json(out, &back)?,
        _ => verify_csv(out, &back)?,
    }
    Ok(format)
}

fn verify_json(out: &LoadOutcome, text: &str) -> Result<()> {
    let doc = json::parse(text).map_err(|e| anyhow!("load report is not valid JSON: {e}"))?;
    ensure!(
        doc.get("load").and_then(Json::as_str) == Some(out.name.as_str()),
        "load name did not round-trip"
    );
    let digest = doc.get("digest").and_then(Json::as_str).context("digest missing")?;
    ensure!(
        u64::from_str_radix(digest, 16).ok() == Some(out.digest),
        "digest did not round-trip"
    );
    let steps = doc.get("steps").and_then(Json::as_array).context("steps missing")?;
    ensure!(
        steps.len() == out.steps.len(),
        "step count did not round-trip: {} vs {}",
        steps.len(),
        out.steps.len()
    );
    for (got, want) in steps.iter().zip(&out.steps) {
        let p99 = got.get("p99_secs").and_then(Json::as_f64).context("p99_secs missing")?;
        ensure!(
            p99.to_bits() == want.p99_secs.to_bits(),
            "step {} p99 did not round-trip: {} vs {}",
            want.step,
            p99,
            want.p99_secs
        );
        let ok = got.get("slo_ok").and_then(Json::as_bool).context("slo_ok missing")?;
        ensure!(ok == want.slo_ok, "step {} slo_ok did not round-trip", want.step);
    }
    let knee = doc.get("knee").context("knee missing")?;
    match &out.knee {
        Some(k) => {
            let step = knee
                .get("broke_step")
                .and_then(Json::as_u64)
                .context("knee.broke_step missing")?;
            ensure!(step as usize == k.broke_step, "knee step did not round-trip");
        }
        None => ensure!(*knee == Json::Null, "absent knee must serialize as null"),
    }
    Ok(())
}

fn verify_csv(out: &LoadOutcome, text: &str) -> Result<()> {
    let mut lines = text.lines();
    let header = lines.next().context("CSV is empty")?;
    let want_cols = 8 + STEP_COLUMNS.len();
    ensure!(
        header.split(',').count() == want_cols,
        "CSV header has {} columns, expected {want_cols}",
        header.split(',').count()
    );
    let rows: Vec<&str> = lines.filter(|l| !l.is_empty()).collect();
    ensure!(
        rows.len() == out.steps.len(),
        "CSV row count did not round-trip: {} vs {}",
        rows.len(),
        out.steps.len()
    );
    for row in &rows {
        ensure!(
            row.contains(&format!("{:016x}", out.digest)),
            "CSV row is missing the run digest"
        );
    }
    Ok(())
}
