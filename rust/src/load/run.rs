//! The ramp runner: schedule the open-loop arrival stream on one
//! continuous simulation, fold the trace into per-step windows, evaluate
//! the SLO per step and locate the saturation knee.
//!
//! One sim per ramp (not one per step): queue buildup is the *point* of
//! an open-loop knee hunt, so backlog must carry from step to step the
//! way it would on a real cluster. Latency is credited to the step that
//! *submitted* the job (completions may land later, inside a following
//! step or the drain window), which is the standard open-loop accounting
//! — a saturated step owns the queueing delay it caused.

use crate::config::Config;
use crate::deploy::{build_sim_with, SimEvent};
use crate::scenario::runner::{install_probe, schedule_events};
use crate::scenario::{check_world, StreamChecker};
use crate::sim::{secs_f, QueueKind};
use crate::util::error::Result;
use crate::util::stats;

use super::gen::{arrivals, Arrival};
use super::spec::LoadSpec;

/// One ramp step's folded outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct StepStats {
    pub step: usize,
    /// Offered (not achieved) arrival rate — the open-loop setpoint.
    pub offered_rps: f64,
    pub from_secs: f64,
    pub until_secs: f64,
    /// Jobs submitted inside the window.
    pub submitted: usize,
    /// Of those, jobs that completed by the run horizon.
    pub completed: usize,
    pub p50_secs: f64,
    pub p99_secs: f64,
    pub p999_secs: f64,
    /// `completed / window length` — achieved throughput.
    pub goodput_rps: f64,
    /// `completed / submitted`; 1.0 for a window with no submissions.
    pub goodput_frac: f64,
    /// SLO verdict; vacuously true for a window with no submissions.
    pub slo_ok: bool,
}

/// Where (and why) the ramp broke the SLO.
#[derive(Debug, Clone, PartialEq)]
pub struct Knee {
    /// First step that broke the SLO.
    pub broke_step: usize,
    /// That step's offered rate.
    pub broke_rps: f64,
    /// Highest offered rate of an earlier step that *held* the SLO
    /// (with at least one submission); `None` if nothing held.
    pub sustained_rps: Option<f64>,
    pub reason: String,
}

/// A finished load run: the digest-pinned outcome plus the ramp report.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadOutcome {
    pub name: String,
    pub deployment: &'static str,
    pub seed: u64,
    /// Order-sensitive fold of the run's trace stream — same spec + seed
    /// ⇒ same digest, on every queue engine.
    pub digest: u64,
    pub events_processed: u64,
    pub peak_pending: usize,
    /// Total jobs scheduled by the generator.
    pub arrivals: usize,
    /// Total jobs completed by the horizon.
    pub completed: usize,
    pub slo_p99_secs: f64,
    pub slo_goodput_frac: f64,
    pub steps: Vec<StepStats>,
    pub knee: Option<Knee>,
    /// Invariant findings, minus `job-terminates` (an overloaded
    /// open-loop run legitimately leaves jobs in flight at the horizon;
    /// that is the knee, not a bug). Informational — the load verdict is
    /// the knee, not a pass/fail gate.
    pub violations: Vec<String>,
}

/// [`run_load_on`] on the default queue engine.
pub fn run_load(base: &Config, spec: &LoadSpec, seed: u64) -> Result<LoadOutcome> {
    run_load_on(base, spec, seed, QueueKind::Slab)
}

/// Execute one load cell: build the config through the scenario stack
/// (overrides + chaos validation), schedule the precomputed arrival
/// stream and the chaos events, run to the horizon and fold the report.
pub fn run_load_on(
    base: &Config,
    spec: &LoadSpec,
    seed: u64,
    queue: QueueKind,
) -> Result<LoadOutcome> {
    let cfg = spec.build_config(base, seed)?;
    let num_dcs = cfg.topology.num_dcs();
    let schedule: Vec<Arrival> = arrivals(spec, seed, num_dcs);
    let rates = spec.step_rates();
    let step_secs = spec.ramp.step_secs;
    let horizon = secs_f(spec.horizon_secs());
    let mode = cfg.deployment;
    let deployment = mode.name();
    let mut sim = build_sim_with(cfg, mode, horizon, queue);
    install_probe(&mut sim, horizon);
    let stream = StreamChecker::install(&sim.state);
    for a in &schedule {
        // `max(1)`: t=0 submissions move to tick 1, after the timer
        // install, same as the single-job scenario path.
        sim.schedule_event_at(
            secs_f(a.at_secs).max(1),
            SimEvent::SubmitJob { kind: a.kind, size: a.size, home: a.home },
        );
    }
    schedule_events(&mut sim, &spec.events);
    sim.run_until(horizon);
    let makespan = sim.state.metrics.makespan();
    sim.state.bill_machines(makespan);
    for v in stream.borrow().violations() {
        if sim.state.probe_violations.len() < 64 {
            sim.state.probe_violations.push(v.clone());
        }
    }
    let events_processed = sim.events_processed;
    let peak_pending = sim.peak_pending();
    let world = sim.state;

    // Fold the per-job records into per-step windows, keyed by
    // submission time. `min(last)` absorbs float edge rounding on the
    // final boundary.
    let nsteps = rates.len();
    let mut submitted = vec![0usize; nsteps];
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); nsteps];
    let mut completed_total = 0usize;
    for rec in world.metrics.jobs.values() {
        let k = ((rec.submitted_secs / step_secs).floor().max(0.0) as usize).min(nsteps - 1);
        submitted[k] += 1;
        if let Some(jrt) = rec.jrt() {
            latencies[k].push(jrt);
            completed_total += 1;
        }
    }
    let mut steps = Vec::with_capacity(nsteps);
    for (k, &offered_rps) in rates.iter().enumerate() {
        let mut lat = std::mem::take(&mut latencies[k]);
        lat.sort_by(f64::total_cmp);
        let sub = submitted[k];
        let done = lat.len();
        let goodput_frac = if sub == 0 { 1.0 } else { done as f64 / sub as f64 };
        let p99 = stats::percentile_sorted(&lat, 99.0);
        let slo_ok =
            sub == 0 || (p99 <= spec.slo.p99_secs && goodput_frac >= spec.slo.goodput_frac);
        steps.push(StepStats {
            step: k,
            offered_rps,
            from_secs: k as f64 * step_secs,
            until_secs: (k + 1) as f64 * step_secs,
            submitted: sub,
            completed: done,
            p50_secs: stats::percentile_sorted(&lat, 50.0),
            p99_secs: p99,
            p999_secs: stats::percentile_sorted(&lat, 99.9),
            goodput_rps: done as f64 / step_secs,
            goodput_frac,
            slo_ok,
        });
    }

    let mut knee = None;
    let mut sustained_rps = None;
    for s in &steps {
        if s.submitted == 0 {
            continue;
        }
        if s.slo_ok {
            sustained_rps = Some(s.offered_rps);
            continue;
        }
        let mut why = Vec::new();
        if s.p99_secs > spec.slo.p99_secs {
            why.push(format!("p99 {:.1}s > {:.1}s", s.p99_secs, spec.slo.p99_secs));
        }
        if s.goodput_frac < spec.slo.goodput_frac {
            why.push(format!(
                "goodput {:.0}% < {:.0}%",
                s.goodput_frac * 100.0,
                spec.slo.goodput_frac * 100.0
            ));
        }
        knee = Some(Knee {
            broke_step: s.step,
            broke_rps: s.offered_rps,
            sustained_rps,
            reason: why.join(", "),
        });
        break;
    }

    let violations: Vec<String> = check_world(&world)
        .iter()
        .filter(|v| v.check != "job-terminates")
        .map(|v| v.to_string())
        .collect();

    Ok(LoadOutcome {
        name: spec.name.clone(),
        deployment,
        seed,
        digest: world.trace_digest(),
        events_processed,
        peak_pending,
        arrivals: schedule.len(),
        completed: completed_total,
        slo_p99_secs: spec.slo.p99_secs,
        slo_goodput_frac: spec.slo.goodput_frac,
        steps,
        knee,
        violations,
    })
}
