//! Open-loop load engine: ramp a mixed arrival stream to the saturation
//! knee (`houtu load`).
//!
//! Every campaign cell runs a fixed handful of jobs; this subsystem
//! measures what the replicated-JM architecture does under a
//! *continuous* arrival stream — the regime a practical geo-distributed
//! service actually lives in. Three pieces:
//!
//! * **[`spec`]** — a TOML-described workload: job classes
//!   (kind × size × weight × home) crossed with arrival processes
//!   (Poisson, bursty MMPP-2, diurnal), plus the ramp controller
//!   (`initial_rps` / `increment_rps` / `step_secs` / `max_rps`) and the
//!   SLO (`slo_p99_secs`, `slo_goodput_frac`). Chaos events and config
//!   overrides reuse the campaign DSL unchanged, so a load cell composes
//!   with `kill_dc@` / `spot_storm@` like any scenario.
//! * **[`gen`]** — the *open-loop* generator: the whole arrival schedule
//!   is a pure function of `(spec, seed, topology)`, materialized up
//!   front and scheduled as typed [`crate::deploy::SimEvent`]s.
//!   Submission never waits for completion, so queueing delay shows up
//!   in the JRT instead of being hidden closed-loop style.
//! * **[`run`]** + **[`report`]** — one continuous simulation per ramp;
//!   per-step windows (keyed by submission time) fold p50/p99/p999 JRT
//!   and goodput from the metrics layer; the first step that breaks the
//!   SLO is the **knee**. Reports render as a table and export as
//!   JSON/CSV with round-trip verification; every run carries the same
//!   order-sensitive trace digest as campaign cells, so `same spec +
//!   seed ⇒ same digest` on every queue engine.
//!
//! CLI: `houtu load [--spec FILE | --smoke] [--seed S]
//! [--report out.json|out.csv] [--shards N]`. `ci.sh` pins the smoke
//! ramp's digest across engines; `houtu bench` times the same cell as
//! the `load-knee` workload. See `docs/LOAD.md` for the schema and the
//! knee definition.

pub mod gen;
pub mod report;
pub mod run;
pub mod spec;

pub use gen::{arrivals, Arrival};
pub use report::write_and_verify;
pub use run::{run_load, run_load_on, Knee, LoadOutcome, StepStats};
pub use spec::{ArrivalProcess, ClassSpec, LoadSpec, RampSpec, SloSpec};

use crate::config::Deployment;
use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::DcId;

/// The built-in smoke ramp (`houtu load --smoke`, the `load-knee` bench
/// workload, and the ci.sh determinism gate): a three-class mix — steady
/// Poisson wordcount, bursty ML, diurnal PageRank — ramped 0.03 → 0.09
/// jobs/s in 120 s steps over the default 4-DC topology. Small enough
/// to finish in seconds, busy enough (~20 arrivals) to exercise every
/// arrival process and the per-step folding.
pub fn smoke_spec() -> LoadSpec {
    LoadSpec {
        name: "smoke-ramp".to_string(),
        deployment: Deployment::Houtu,
        classes: vec![
            ClassSpec {
                name: "ml-burst".to_string(),
                kind: WorkloadKind::IterativeMl,
                size: SizeClass::Small,
                weight: 1.0,
                home: Some(DcId(1)),
                arrival: ArrivalProcess::Bursty {
                    factor: 4.0,
                    burst_secs: 30.0,
                    calm_secs: 120.0,
                },
            },
            ClassSpec {
                name: "pr-diurnal".to_string(),
                kind: WorkloadKind::PageRank,
                size: SizeClass::Small,
                weight: 1.0,
                home: None,
                arrival: ArrivalProcess::Diurnal { period_secs: 240.0, amplitude: 0.8 },
            },
            ClassSpec {
                name: "wc-steady".to_string(),
                kind: WorkloadKind::WordCount,
                size: SizeClass::Small,
                weight: 3.0,
                home: None,
                arrival: ArrivalProcess::Poisson,
            },
        ],
        ramp: RampSpec {
            initial_rps: 0.03,
            increment_rps: 0.03,
            step_secs: 120.0,
            max_rps: 0.09,
            drain_secs: 480.0,
        },
        // Generous on purpose: the smoke gate pins determinism (digest +
        // knee), not a tuned saturation point — 64 containers at
        // ≤ 0.09 jobs/s of smalls is far from the knee.
        slo: SloSpec { p99_secs: 900.0, goodput_frac: 0.5 },
        events: vec![],
        overrides: vec![],
    }
}
