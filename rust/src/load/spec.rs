//! Declarative load specs: job-class mix × arrival processes × ramp ×
//! SLO, parsed from the same TOML subset as campaign files.
//!
//! A load file has one `[load]` section (ramp, SLO, chaos, overrides)
//! and any number of `[class.<name>]` sections (one per job class). The
//! TOML subset has no nested tables, so chaos events reuse the campaign
//! `kind@time:args` string DSL ([`ChaosEvent::parse`]) — a load cell
//! composes with `kill_dc@` / `spot_storm@` exactly like a scenario.
//!
//! ```toml
//! [load]
//! name = "knee-hunt"
//! deployment = "houtu"         # houtu|cent-dyna|cent-stat|decent-stat
//! initial_rps = 0.05           # ramp start (jobs per second, open loop)
//! increment_rps = 0.05         # added per step
//! step_secs = 180              # dwell per step
//! max_rps = 0.30               # ramp ceiling (inclusive)
//! drain_secs = 300             # post-ramp window for in-flight jobs
//! slo_p99_secs = 600           # p99 JRT ceiling per step
//! slo_goodput_frac = 0.9       # completed/submitted floor per step
//! events = ["spot_storm@0:dc1,600,4"]
//! overrides = ["cloud.revocations=true"]
//!
//! [class.wc-small]
//! kind = "wordcount"           # wordcount|tpch|ml|pagerank
//! size = "small"               # small|medium|large
//! weight = 3.0                 # share of the offered rate
//! home = "spread"              # submitting DC: index, or "spread"
//! arrival = "poisson"          # poisson|bursty|diurnal
//!
//! [class.ml-burst]
//! kind = "ml"
//! size = "small"
//! weight = 1.0
//! home = 1
//! arrival = "bursty"           # MMPP-2: calm/burst phase switching
//! burst_factor = 4.0           # burst rate = factor × calm rate
//! burst_secs = 30              # mean burst dwell
//! calm_secs = 120              # mean calm dwell
//! ```
//!
//! Classes are keyed by section name; the subset parser sorts sections
//! alphabetically, so the class *index* order (which the arrival
//! generator's RNG streams key on) is the sorted-name order — renaming a
//! class legitimately changes the stream, adding an unrelated key does
//! not.

use crate::config::toml::{self, Value};
use crate::config::{Config, Deployment};
use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::DcId;
use crate::scenario::{ChaosEvent, ScenarioSpec, ScenarioWorkload};
use crate::util::error::{Context, Result};
use crate::{bail, ensure};

/// Hard cap on ramp steps (guards runaway `increment_rps` → `max_rps`
/// combinations; a real knee hunt is tens of steps).
pub const MAX_STEPS: usize = 10_000;

/// Hard cap on the *expected* total arrival count across the whole ramp
/// — an open-loop spec that asks for more than this is a config error,
/// not a workload (the DES event budget would absorb it, slowly).
pub const MAX_EXPECTED_ARRIVALS: f64 = 1_000_000.0;

/// How a class's arrivals are spaced within each ramp step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at the class's rate share.
    Poisson,
    /// MMPP-2: exponentially-dwelling calm/burst phases; the burst phase
    /// runs at `factor ×` the calm rate, and the calm rate is scaled so
    /// the long-run average still matches the class's rate share.
    Bursty { factor: f64, burst_secs: f64, calm_secs: f64 },
    /// Sinusoidally-modulated Poisson (thinned NHPP):
    /// `rate(t) = r·(1 + amplitude·sin(2πt/period))` over absolute sim
    /// time, so the cycle phase is continuous across ramp steps.
    Diurnal { period_secs: f64, amplitude: f64 },
}

/// One job class of the mixed workload.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassSpec {
    pub name: String,
    pub kind: WorkloadKind,
    pub size: SizeClass,
    /// Share of the step's offered rate (normalized over all classes).
    pub weight: f64,
    /// Submitting DC; `None` = spread uniformly per arrival.
    pub home: Option<DcId>,
    pub arrival: ArrivalProcess,
}

/// The open-loop ramp controller's knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RampSpec {
    pub initial_rps: f64,
    pub increment_rps: f64,
    pub step_secs: f64,
    /// Inclusive ceiling: the ramp holds a step at every rate
    /// `initial + k·increment ≤ max (+ε)`.
    pub max_rps: f64,
    /// Extra horizon after the last step so in-flight work can land.
    pub drain_secs: f64,
}

/// What "saturated" means: a step whose p99 JRT exceeds `p99_secs` *or*
/// whose completed/submitted fraction falls below `goodput_frac` breaks
/// the SLO; the first broken step is the knee.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    pub p99_secs: f64,
    pub goodput_frac: f64,
}

/// A fully-described load cell.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadSpec {
    pub name: String,
    pub deployment: Deployment,
    pub classes: Vec<ClassSpec>,
    pub ramp: RampSpec,
    pub slo: SloSpec,
    /// Chaos schedule, same DSL and semantics as scenario cells.
    pub events: Vec<ChaosEvent>,
    /// `section.key=value` strings, same surface as the CLI `--set`.
    pub overrides: Vec<String>,
}

impl LoadSpec {
    /// The offered rate of every ramp step, in step order.
    pub fn step_rates(&self) -> Vec<f64> {
        let mut rates = Vec::new();
        let mut r = self.ramp.initial_rps;
        // The ε absorbs float accumulation so `0.05 + 5×0.05` still
        // counts as ≤ 0.30.
        while r <= self.ramp.max_rps + 1e-9 && rates.len() < MAX_STEPS {
            rates.push(r);
            r += self.ramp.increment_rps;
        }
        if rates.is_empty() {
            rates.push(self.ramp.initial_rps);
        }
        rates
    }

    /// Ramp end (seconds): when the last step's window closes.
    pub fn ramp_end_secs(&self) -> f64 {
        self.step_rates().len() as f64 * self.ramp.step_secs
    }

    /// Full run horizon (seconds): ramp plus the drain window.
    pub fn horizon_secs(&self) -> f64 {
        self.ramp_end_secs() + self.ramp.drain_secs
    }

    /// The synthetic scenario this load cell rides on: its chaos events
    /// and overrides under a placeholder workload (arrivals are scheduled
    /// by the load runner, not by the scenario workload), so
    /// [`ScenarioSpec::build_config`] supplies override application,
    /// chaos-vs-topology fit checks and storm/WAN overlap validation
    /// unchanged.
    pub fn scenario(&self) -> ScenarioSpec {
        ScenarioSpec {
            name: format!("load:{}", self.name),
            deployment: self.deployment,
            regions: 0,
            workload: ScenarioWorkload::SingleJob {
                kind: WorkloadKind::WordCount,
                size: SizeClass::Small,
                home: DcId(0),
            },
            events: self.events.clone(),
            overrides: self.overrides.clone(),
        }
    }

    /// Materialize the run config (base ⊕ seed ⊕ deployment ⊕ overrides,
    /// then the scenario-level validation stack).
    pub fn build_config(&self, base: &Config, seed: u64) -> Result<Config> {
        self.validate()?;
        let cfg = self.scenario().build_config(base, seed)?;
        for cl in &self.classes {
            if let Some(home) = cl.home {
                ensure!(
                    home.0 < cfg.topology.num_dcs(),
                    "load {:?}: class {:?} home dc{} outside the {}-region topology",
                    self.name,
                    cl.name,
                    home.0,
                    cfg.topology.num_dcs()
                );
            }
        }
        Ok(cfg)
    }

    /// Spec-level sanity: every knob finite and in range, and the ramp
    /// bounded in both step count and expected arrival volume.
    pub fn validate(&self) -> Result<()> {
        let n = &self.name;
        ensure!(!self.classes.is_empty(), "load {n:?}: needs at least one [class.*]");
        let r = &self.ramp;
        for (label, v) in [
            ("initial_rps", r.initial_rps),
            ("increment_rps", r.increment_rps),
            ("step_secs", r.step_secs),
            ("max_rps", r.max_rps),
        ] {
            ensure!(v.is_finite() && v > 0.0, "load {n:?}: {label} must be finite and > 0");
        }
        ensure!(
            r.drain_secs.is_finite() && r.drain_secs >= 0.0,
            "load {n:?}: drain_secs must be finite and >= 0"
        );
        ensure!(r.max_rps >= r.initial_rps, "load {n:?}: max_rps must be >= initial_rps");
        let steps = ((r.max_rps - r.initial_rps) / r.increment_rps) as usize + 1;
        ensure!(
            steps <= MAX_STEPS,
            "load {n:?}: ramp would take {steps} steps (cap {MAX_STEPS})"
        );
        let expected: f64 = self.step_rates().iter().map(|rate| rate * r.step_secs).sum();
        ensure!(
            expected <= MAX_EXPECTED_ARRIVALS,
            "load {n:?}: ramp expects ~{expected:.0} arrivals (cap {MAX_EXPECTED_ARRIVALS:.0})"
        );
        ensure!(
            self.slo.p99_secs.is_finite() && self.slo.p99_secs > 0.0,
            "load {n:?}: slo_p99_secs must be finite and > 0"
        );
        ensure!(
            self.slo.goodput_frac.is_finite()
                && self.slo.goodput_frac > 0.0
                && self.slo.goodput_frac <= 1.0,
            "load {n:?}: slo_goodput_frac must be in (0, 1]"
        );
        for cl in &self.classes {
            let c = &cl.name;
            ensure!(
                cl.weight.is_finite() && cl.weight > 0.0,
                "load {n:?}: class {c:?} weight must be finite and > 0"
            );
            match cl.arrival {
                ArrivalProcess::Poisson => {}
                ArrivalProcess::Bursty { factor, burst_secs, calm_secs } => {
                    ensure!(
                        factor.is_finite() && factor > 1.0,
                        "load {n:?}: class {c:?} burst_factor must be > 1"
                    );
                    for (label, v) in [("burst_secs", burst_secs), ("calm_secs", calm_secs)] {
                        ensure!(
                            v.is_finite() && v > 0.0,
                            "load {n:?}: class {c:?} {label} must be finite and > 0"
                        );
                    }
                }
                ArrivalProcess::Diurnal { period_secs, amplitude } => {
                    ensure!(
                        period_secs.is_finite() && period_secs > 0.0,
                        "load {n:?}: class {c:?} period_secs must be finite and > 0"
                    );
                    ensure!(
                        amplitude.is_finite() && (0.0..=1.0).contains(&amplitude),
                        "load {n:?}: class {c:?} amplitude must be in [0, 1]"
                    );
                }
            }
        }
        Ok(())
    }

    /// Parse from TOML text (see the module docs for the schema).
    pub fn parse(text: &str) -> Result<LoadSpec> {
        let doc = toml::parse(text).map_err(|e| crate::anyhow!("load spec: {e}"))?;
        let load = doc
            .sections
            .get("load")
            .context("load spec: missing [load] section")?;
        for section in doc.sections.keys() {
            ensure!(
                section == "load" || section.starts_with("class."),
                "load spec: unknown section [{section}] (expected [load] or [class.<name>])"
            );
        }
        const KNOWN: [&str; 12] = [
            "name",
            "deployment",
            "initial_rps",
            "increment_rps",
            "step_secs",
            "max_rps",
            "drain_secs",
            "slo_p99_secs",
            "slo_goodput_frac",
            "events",
            "overrides",
            "topology",
        ];
        for k in load.keys() {
            ensure!(
                KNOWN.contains(&k.as_str()),
                "load spec: unknown [load] key {k:?} (known: {})",
                KNOWN.join(", ")
            );
        }
        let name = load
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("load")
            .to_string();
        let deployment = match load.get("deployment").and_then(Value::as_str) {
            Some(s) => Deployment::parse(s)?,
            None => Deployment::Houtu,
        };
        let f64_or = |k: &str, d: f64| -> f64 {
            load.get(k).and_then(Value::as_f64).unwrap_or(d)
        };
        let ramp = RampSpec {
            initial_rps: f64_or("initial_rps", 0.05),
            increment_rps: f64_or("increment_rps", 0.05),
            step_secs: f64_or("step_secs", 180.0),
            max_rps: f64_or("max_rps", 0.3),
            drain_secs: f64_or("drain_secs", 300.0),
        };
        let slo = SloSpec {
            p99_secs: f64_or("slo_p99_secs", 600.0),
            goodput_frac: f64_or("slo_goodput_frac", 0.9),
        };
        let str_array = |k: &str| -> Result<Vec<String>> {
            match load.get(k) {
                None => Ok(Vec::new()),
                Some(v) => v
                    .as_array()
                    .with_context(|| format!("load {name:?}: {k} must be an array"))?
                    .iter()
                    .map(|x| {
                        x.as_str().map(str::to_string).with_context(|| {
                            format!("load {name:?}: {k} entries must be strings")
                        })
                    })
                    .collect(),
            }
        };
        let events = str_array("events")?
            .iter()
            .map(|s| ChaosEvent::parse(s))
            .collect::<Result<Vec<_>>>()?;
        let mut overrides = str_array("overrides")?;
        // `topology = "generated:<dcs>,<nodes>,<seed>"` — same surface as
        // scenario specs: parse-checked here, then desugared into a
        // `topology.generated` override so the config layer expands it.
        if let Some(v) = load.get("topology") {
            let s = v
                .as_str()
                .with_context(|| format!("load {name:?}: topology must be a string"))?;
            crate::topo::parse_spec(s)
                .with_context(|| format!("load {name:?}: bad topology"))?;
            overrides.push(format!("topology.generated={s}"));
        }

        let mut classes = Vec::new();
        // BTreeMap order = alphabetical class names = stable class
        // indices for the generator's RNG streams.
        for (section, keys) in &doc.sections {
            let Some(cname) = section.strip_prefix("class.") else { continue };
            ensure!(!cname.is_empty(), "load {name:?}: empty class name in [{section}]");
            const CKNOWN: [&str; 10] = [
                "kind",
                "size",
                "weight",
                "home",
                "arrival",
                "burst_factor",
                "burst_secs",
                "calm_secs",
                "period_secs",
                "amplitude",
            ];
            for k in keys.keys() {
                ensure!(
                    CKNOWN.contains(&k.as_str()),
                    "load {name:?}: unknown [class.{cname}] key {k:?} (known: {})",
                    CKNOWN.join(", ")
                );
            }
            let get_str = |k: &str| keys.get(k).and_then(Value::as_str);
            let get_f64 = |k: &str, d: f64| keys.get(k).and_then(Value::as_f64).unwrap_or(d);
            let kind = match get_str("kind").unwrap_or("wordcount") {
                "wordcount" => WorkloadKind::WordCount,
                "tpch" => WorkloadKind::TpcH,
                "ml" => WorkloadKind::IterativeMl,
                "pagerank" => WorkloadKind::PageRank,
                other => bail!(
                    "load {name:?}: class {cname:?} unknown kind {other:?} \
                     (wordcount|tpch|ml|pagerank)"
                ),
            };
            let size = match get_str("size").unwrap_or("small") {
                "small" => SizeClass::Small,
                "medium" => SizeClass::Medium,
                "large" => SizeClass::Large,
                other => bail!("load {name:?}: class {cname:?} unknown size {other:?}"),
            };
            let home = match keys.get("home") {
                None => None,
                Some(v) => {
                    if v.as_str() == Some("spread") {
                        None
                    } else if let Some(i) = v.as_i64() {
                        ensure!(
                            i >= 0,
                            "load {name:?}: class {cname:?} home must be >= 0 or \"spread\""
                        );
                        Some(DcId(i as usize))
                    } else {
                        bail!(
                            "load {name:?}: class {cname:?} home must be a DC index or \"spread\""
                        );
                    }
                }
            };
            let arrival = match get_str("arrival").unwrap_or("poisson") {
                "poisson" => ArrivalProcess::Poisson,
                "bursty" => ArrivalProcess::Bursty {
                    factor: get_f64("burst_factor", 4.0),
                    burst_secs: get_f64("burst_secs", 60.0),
                    calm_secs: get_f64("calm_secs", 240.0),
                },
                "diurnal" => ArrivalProcess::Diurnal {
                    period_secs: get_f64("period_secs", 3600.0),
                    amplitude: get_f64("amplitude", 0.5),
                },
                other => bail!(
                    "load {name:?}: class {cname:?} unknown arrival {other:?} \
                     (poisson|bursty|diurnal)"
                ),
            };
            classes.push(ClassSpec {
                name: cname.to_string(),
                kind,
                size,
                weight: get_f64("weight", 1.0),
                home,
                arrival,
            });
        }

        let spec =
            LoadSpec { name, deployment, classes, ramp, slo, events, overrides };
        spec.validate()?;
        Ok(spec)
    }

    /// [`LoadSpec::parse`] from a file path.
    pub fn from_file(path: &str) -> Result<LoadSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading load spec {path}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
[load]
name = "knee-hunt"
deployment = "houtu"
initial_rps = 0.05
increment_rps = 0.05
step_secs = 180
max_rps = 0.3
drain_secs = 300
slo_p99_secs = 600
slo_goodput_frac = 0.9
events = ["spot_storm@0:dc1,600,4"]
overrides = ["cloud.revocations=true"]

[class.ml-burst]
kind = "ml"
size = "small"
weight = 1.0
home = 1
arrival = "bursty"
burst_factor = 4.0
burst_secs = 30
calm_secs = 120

[class.wc-small]
kind = "wordcount"
size = "small"
weight = 3.0
home = "spread"
arrival = "poisson"
"#;

    #[test]
    fn full_spec_parses_and_validates() {
        let spec = LoadSpec::parse(FULL).expect("full spec parses");
        assert_eq!(spec.name, "knee-hunt");
        assert_eq!(spec.classes.len(), 2);
        // BTreeMap section order: class indices follow sorted names.
        assert_eq!(spec.classes[0].name, "ml-burst");
        assert_eq!(spec.classes[1].name, "wc-small");
        assert_eq!(spec.classes[1].home, None);
        assert_eq!(spec.classes[0].home, Some(DcId(1)));
        assert_eq!(spec.events.len(), 1);
        assert_eq!(spec.step_rates().len(), 6); // 0.05 .. 0.30
        assert!((spec.horizon_secs() - (6.0 * 180.0 + 300.0)).abs() < 1e-9);
    }

    #[test]
    fn unknown_keys_and_sections_are_rejected() {
        let bad_key = FULL.replace("drain_secs = 300", "dran_secs = 300");
        assert!(LoadSpec::parse(&bad_key).is_err(), "typoed [load] key must be rejected");
        let bad_class_key = FULL.replace("burst_factor = 4.0", "burst_facter = 4.0");
        assert!(LoadSpec::parse(&bad_class_key).is_err(), "typoed class key must be rejected");
        let bad_section = format!("{FULL}\n[classs.typo]\nweight = 1.0\n");
        assert!(LoadSpec::parse(&bad_section).is_err(), "typoed section must be rejected");
    }

    #[test]
    fn invalid_ramps_are_rejected() {
        for (from, to) in [
            ("initial_rps = 0.05", "initial_rps = 0.0"),
            ("max_rps = 0.3", "max_rps = 0.01"),
            ("step_secs = 180", "step_secs = -5"),
            ("slo_goodput_frac = 0.9", "slo_goodput_frac = 1.5"),
            ("burst_factor = 4.0", "burst_factor = 0.5"),
        ] {
            let text = FULL.replace(from, to);
            assert!(LoadSpec::parse(&text).is_err(), "{to:?} must be rejected");
        }
    }

    #[test]
    fn topology_key_desugars_and_class_homes_validate_against_it() {
        let text = FULL.replace(
            "overrides = [\"cloud.revocations=true\"]",
            "overrides = [\"cloud.revocations=true\"]\ntopology = \"generated:16,2,7\"",
        );
        let spec = LoadSpec::parse(&text).expect("topology key parses");
        assert!(
            spec.overrides.iter().any(|o| o == "topology.generated=generated:16,2,7"),
            "{:?}",
            spec.overrides
        );
        let cfg = spec.build_config(&Config::default(), 42).expect("generated world builds");
        assert_eq!(cfg.topology.num_dcs(), 16);
        assert_eq!(cfg.topology.workers_per_dc, 2);
        // A bad token is a clear parse error naming the load spec.
        let bad = text.replace("generated:16,2,7", "generated:16,2");
        let e = LoadSpec::parse(&bad).expect_err("short token").to_string();
        assert!(e.contains("bad topology"), "{e}");
        // Class homes validate against the *generated* DC count.
        let far = text.replace("home = 1", "home = 20");
        let spec = LoadSpec::parse(&far).expect("parses; fit is checked at build");
        let e = spec.build_config(&Config::default(), 42).expect_err("dc20 of 16").to_string();
        assert!(e.contains("outside the 16-region topology"), "{e}");
    }

    #[test]
    fn build_config_applies_overrides_and_checks_chaos_fit() {
        let spec = LoadSpec::parse(FULL).unwrap();
        let cfg = spec.build_config(&Config::default(), 42).expect("config builds");
        assert_eq!(cfg.seed, 42);
        assert!(cfg.cloud.revocations, "override must land");
        let bad = LoadSpec {
            events: vec![ChaosEvent::KillDc { at_secs: 10.0, dc: DcId(99) }],
            ..spec
        };
        assert!(
            bad.build_config(&Config::default(), 42).is_err(),
            "chaos outside the topology must be rejected"
        );
    }
}
