//! The open-loop arrival generator: a pure function from
//! `(spec, seed, topology)` to a canonical arrival schedule.
//!
//! Determinism contract: the whole schedule is materialized up front by
//! a single sequential pass — nothing about workers, shards or the queue
//! engine is visible here — so the same `(spec, seed, num_dcs)` always
//! yields the bit-identical `Vec<Arrival>` (a regression test pins
//! this). Each `(class, step)` window draws from its own PCG stream
//! ([`stream_for`]), so adding a ramp step or a class never perturbs the
//! arrivals of the others, and none of the streams collide with the
//! world RNG or the trace generator's stream 777.
//!
//! Boundary simplification (documented on purpose): windows are
//! generated independently, so an inter-arrival gap does not carry
//! across a step boundary — the first arrival of step `k` is drawn
//! fresh from the step's own stream. For knee hunting this is the shape
//! we want: every step is the same process at a higher rate, not a
//! continuation biased by where the previous step's last gap fell.

use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::DcId;
use crate::util::Pcg;

use super::spec::{ArrivalProcess, LoadSpec};

/// PCG stream namespace for the load generator: `0x10AD` ("load") in the
/// top bits keeps every `(class, step)` stream disjoint from the world's
/// per-subsystem streams and the trace generator's stream 777.
const STREAM_BASE: u64 = 0x10AD << 40;

/// The RNG stream of one `(class index, step index)` window.
fn stream_for(class: usize, step: usize) -> u64 {
    // `validate` caps steps at 10_000 (< 2^20), so the shifted class
    // index can never collide with another window's step index.
    STREAM_BASE + ((class as u64) << 20) + step as u64
}

/// One scheduled job submission.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    /// Absolute submission time (seconds since sim start).
    pub at_secs: f64,
    /// Index into `spec.classes` (sorted-name order, see the spec docs).
    pub class: usize,
    /// Per-class sequence number in generation order — the deterministic
    /// tie-breaker when two classes draw the same timestamp.
    pub seq: u64,
    pub kind: WorkloadKind,
    pub size: SizeClass,
    pub home: DcId,
}

/// Materialize the full open-loop schedule for a spec at a seed.
///
/// Arrivals are sorted by `(time, class, seq)` — a total order, so the
/// schedule (and therefore the DES event stream it feeds) is canonical.
pub fn arrivals(spec: &LoadSpec, seed: u64, num_dcs: usize) -> Vec<Arrival> {
    let rates = spec.step_rates();
    let weight_sum: f64 = spec.classes.iter().map(|c| c.weight).sum();
    let step_secs = spec.ramp.step_secs;
    let mut out = Vec::new();
    for (ci, cl) in spec.classes.iter().enumerate() {
        let mut seq = 0u64;
        for (k, &step_rate) in rates.iter().enumerate() {
            let rate = step_rate * cl.weight / weight_sum;
            if rate <= 0.0 {
                continue;
            }
            let lo = k as f64 * step_secs;
            let hi = lo + step_secs;
            let mut rng = Pcg::new(seed, stream_for(ci, k));
            let mut push = |t: f64, rng: &mut Pcg, seq: &mut u64| {
                let home = match cl.home {
                    Some(dc) => dc,
                    None => DcId(rng.index(num_dcs)),
                };
                out.push(Arrival {
                    at_secs: t,
                    class: ci,
                    seq: *seq,
                    kind: cl.kind,
                    size: cl.size,
                    home,
                });
                *seq += 1;
            };
            match cl.arrival {
                ArrivalProcess::Poisson => {
                    let mut t = lo;
                    loop {
                        t += rng.exp(1.0 / rate);
                        if t >= hi {
                            break;
                        }
                        push(t, &mut rng, &mut seq);
                    }
                }
                ArrivalProcess::Bursty { factor, burst_secs, calm_secs } => {
                    // MMPP-2 by thinning: draw the calm/burst phase
                    // schedule first, then generate candidates at the
                    // burst rate and keep calm-phase candidates with
                    // probability 1/factor. The calm rate is scaled so
                    // the long-run average matches the class share:
                    // r = (1-pb)·calm + pb·calm·factor.
                    let pb = burst_secs / (burst_secs + calm_secs);
                    let calm_rate = rate / ((1.0 - pb) + pb * factor);
                    let burst_rate = calm_rate * factor;
                    // Phase segments as (end-time, was-burst) in order,
                    // starting calm at the window open.
                    let mut segs: Vec<(f64, bool)> = Vec::new();
                    let mut edge = lo;
                    let mut in_burst = false;
                    while edge < hi {
                        let mean = if in_burst { burst_secs } else { calm_secs };
                        edge += rng.exp(mean).max(1e-9);
                        segs.push((edge, in_burst));
                        in_burst = !in_burst;
                    }
                    let mut cursor = 0usize;
                    let mut t = lo;
                    loop {
                        t += rng.exp(1.0 / burst_rate);
                        if t >= hi {
                            break;
                        }
                        while cursor < segs.len() && segs[cursor].0 <= t {
                            cursor += 1;
                        }
                        let bursting = segs.get(cursor).map_or(false, |s| s.1);
                        if bursting || rng.chance(1.0 / factor) {
                            push(t, &mut rng, &mut seq);
                        }
                    }
                }
                ArrivalProcess::Diurnal { period_secs, amplitude } => {
                    // Thinned NHPP against the cycle's peak rate. The
                    // sine runs over absolute time, so the cycle phase
                    // is continuous across ramp steps.
                    let peak = rate * (1.0 + amplitude);
                    let mut t = lo;
                    loop {
                        t += rng.exp(1.0 / peak);
                        if t >= hi {
                            break;
                        }
                        let now = rate
                            * (1.0
                                + amplitude
                                    * (2.0 * std::f64::consts::PI * t / period_secs).sin());
                        if rng.chance((now / peak).clamp(0.0, 1.0)) {
                            push(t, &mut rng, &mut seq);
                        }
                    }
                }
            }
        }
    }
    out.sort_by(|a, b| {
        a.at_secs
            .total_cmp(&b.at_secs)
            .then(a.class.cmp(&b.class))
            .then(a.seq.cmp(&b.seq))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;
    use crate::load::spec::{ClassSpec, RampSpec, SloSpec};

    fn flat_poisson(rate: f64, step_secs: f64) -> LoadSpec {
        LoadSpec {
            name: "gen-test".to_string(),
            deployment: Deployment::Houtu,
            classes: vec![ClassSpec {
                name: "wc".to_string(),
                kind: WorkloadKind::WordCount,
                size: SizeClass::Small,
                weight: 1.0,
                home: None,
                arrival: ArrivalProcess::Poisson,
            }],
            ramp: RampSpec {
                initial_rps: rate,
                increment_rps: rate,
                step_secs,
                max_rps: rate,
                drain_secs: 0.0,
            },
            slo: SloSpec { p99_secs: 600.0, goodput_frac: 0.9 },
            events: vec![],
            overrides: vec![],
        }
    }

    #[test]
    fn schedule_is_sorted_in_window_and_seed_sensitive() {
        let spec = flat_poisson(2.0, 300.0);
        let a = arrivals(&spec, 7, 4);
        assert!(!a.is_empty(), "λT = 600 must yield arrivals");
        for w in a.windows(2) {
            assert!(w[0].at_secs <= w[1].at_secs, "schedule must be time-sorted");
        }
        for x in &a {
            assert!(x.at_secs >= 0.0 && x.at_secs < 300.0, "arrival outside the window");
            assert!(x.home.0 < 4, "spread home outside the topology");
        }
        let b = arrivals(&spec, 8, 4);
        assert_ne!(a, b, "different seeds must yield different schedules");
    }

    #[test]
    fn class_streams_are_independent() {
        // Adding a second class must not perturb the first class's
        // schedule: per-(class, step) streams, not one shared stream.
        let solo = flat_poisson(1.0, 200.0);
        let mut duo = solo.clone();
        duo.classes.push(ClassSpec {
            name: "ml".to_string(),
            kind: WorkloadKind::IterativeMl,
            size: SizeClass::Small,
            weight: 1.0,
            home: Some(DcId(2)),
            arrival: ArrivalProcess::Poisson,
        });
        // Same per-class share: double the duo's offered rate so class 0
        // keeps rate 1.0 after the weight split.
        duo.ramp.initial_rps = 2.0;
        duo.ramp.increment_rps = 2.0;
        duo.ramp.max_rps = 2.0;
        let a: Vec<Arrival> =
            arrivals(&solo, 42, 4).into_iter().filter(|x| x.class == 0).collect();
        let b: Vec<Arrival> =
            arrivals(&duo, 42, 4).into_iter().filter(|x| x.class == 0).collect();
        assert_eq!(a, b, "class 0 schedule must not depend on class 1's presence");
    }

    #[test]
    fn bursty_and_diurnal_stay_in_window_and_average_out() {
        let mut spec = flat_poisson(1.0, 600.0);
        spec.classes[0].arrival =
            ArrivalProcess::Bursty { factor: 5.0, burst_secs: 20.0, calm_secs: 80.0 };
        let b = arrivals(&spec, 3, 4);
        for x in &b {
            assert!(x.at_secs >= 0.0 && x.at_secs < 600.0);
        }
        // λT = 600 on average, but the MMPP's realized burst fraction is
        // noisy over ~6 dwell cycles — only pin the structural envelope
        // (all-calm ≈ 333 … all-burst ≈ 1667); the tight mean property
        // lives in the Poisson `forall_cases` test.
        assert!(
            (150..=1800).contains(&b.len()),
            "bursty arrival count {} outside the MMPP envelope",
            b.len()
        );
        spec.classes[0].arrival =
            ArrivalProcess::Diurnal { period_secs: 300.0, amplitude: 0.8 };
        let d = arrivals(&spec, 3, 4);
        for x in &d {
            assert!(x.at_secs >= 0.0 && x.at_secs < 600.0);
        }
        assert!(
            (d.len() as f64 - 600.0).abs() < 200.0,
            "diurnal arrival count {} too far from λT = 600",
            d.len()
        );
    }
}
