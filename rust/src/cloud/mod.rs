//! Cloud market substrate: the Fig-3 price table, a per-region spot
//! market with bid-based revocation, cost metering (machine-hours plus
//! the $0.13/GB cross-DC transfer tariff of §6.3), and the pluggable
//! [`bidding`] strategies that decide *what* to bid.
//!
//! The spot price follows a mean-reverting log-AR(1) process recalculated
//! every `market_period_secs`; each spot instance carries its own bid
//! (chosen by the configured [`bidding::BidStrategy`]; the baseline
//! jitters around `bid_multiplier × mean spot price`), and a price
//! excursion above a bid revokes exactly the instances it out-prices —
//! matching the paper's "terminate those instances whose maximum bid is
//! below the new market price". [`CostMeter`] accumulates the Fig-10
//! cost components, both per run (`World::bill_machines`) and per job
//! (folded into `CostCharged` trace events and the campaign/fuzz/bench
//! cost columns).

pub mod bidding;

use crate::config::CloudConfig;
use crate::util::Pcg;

/// One row of the paper's Fig 3 (USD; <4 vCPU, 16 GB> class).
#[derive(Debug, Clone, Copy)]
pub struct PriceRow {
    pub provider: &'static str,
    pub reserved_yearly: f64,
    pub on_demand_hourly: f64,
    pub spot_hourly: f64,
}

/// The paper's Fig 3 table, verbatim.
pub fn fig3_prices() -> Vec<PriceRow> {
    vec![
        PriceRow { provider: "GCP", reserved_yearly: 1164.0, on_demand_hourly: 0.19, spot_hourly: 0.04 },
        PriceRow { provider: "EC2", reserved_yearly: 1013.0, on_demand_hourly: 0.2, spot_hourly: 0.035 },
        PriceRow { provider: "AliCloud", reserved_yearly: 866.0, on_demand_hourly: 0.312, spot_hourly: 0.036 },
        PriceRow { provider: "Azure", reserved_yearly: 1312.0, on_demand_hourly: 0.26, spot_hourly: 0.06 },
    ]
}

/// How an instance is paid for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InstanceClass {
    OnDemand,
    /// Spot instance with our standing bid ($/hour).
    Spot { bid: f64 },
}

impl InstanceClass {
    pub fn is_spot(&self) -> bool {
        matches!(self, InstanceClass::Spot { .. })
    }
}

/// Per-region spot market.
#[derive(Debug, Clone)]
pub struct SpotMarket {
    mean: f64,
    phi: f64,
    sigma: f64,
    /// Scheduled volatility multiplier (the `spot_storm@` chaos family):
    /// 1 = calm; a storm window scales the log-price innovation stddev.
    storm: f64,
    price: f64,
    rng: Pcg,
}

impl SpotMarket {
    pub fn new(cfg: &CloudConfig, rng: Pcg) -> Self {
        SpotMarket {
            mean: cfg.spot_hourly_mean,
            phi: 0.9,
            sigma: cfg.spot_volatility,
            storm: 1.0,
            price: cfg.spot_hourly_mean,
            rng,
        }
    }

    /// Current market price ($/hour).
    pub fn price(&self) -> f64 {
        self.price
    }

    /// Enter (factor > 1) or leave (factor = 1) a volatility storm: the
    /// next [`SpotMarket::step`] draws its innovation with
    /// `sigma × factor`. Rolling spot-price storms (PingAn's adversarial
    /// price dynamics) are scheduled as a (set, restore-to-1) pair.
    pub fn set_storm(&mut self, factor: f64) {
        self.storm = factor;
    }

    /// The current volatility multiplier (1 = calm).
    pub fn storm(&self) -> f64 {
        self.storm
    }

    /// Recalculate the market price (one market period). Returns the new
    /// price. Log-AR(1) around log(mean) keeps the price positive and
    /// produces occasional multi-× spikes — the revocation driver.
    pub fn step(&mut self) -> f64 {
        let lmean = self.mean.ln();
        let lx = self.price.ln();
        let innov = (1.0 - self.phi * self.phi).sqrt();
        let eps = self.rng.std_normal();
        self.price = (lmean + self.phi * (lx - lmean) + innov * self.sigma * self.storm * eps).exp();
        self.price
    }

    /// Draw a per-instance bid: `bid_multiplier × mean`, jittered ±10 % so
    /// a spike revokes a subset rather than the whole fleet. This is the
    /// [`bidding::Naive`] baseline.
    pub fn draw_bid(&mut self, cfg: &CloudConfig) -> f64 {
        self.draw_bid_with(cfg.bid_multiplier, cfg)
    }

    /// [`SpotMarket::draw_bid`] at an explicit multiplier — the adaptive
    /// and deadline strategies pick `mult` dynamically but keep the same
    /// ±10 % jitter (and the same RNG stream shape) as the baseline.
    pub fn draw_bid_with(&mut self, mult: f64, _cfg: &CloudConfig) -> f64 {
        mult * self.mean * self.rng.uniform(0.9, 1.1)
    }

    /// Would an instance with `bid` be revoked at the current price?
    pub fn revokes(&self, bid: f64) -> bool {
        self.price > bid
    }
}

/// Accumulates the Fig-10 cost components for one deployment run.
#[derive(Debug, Default, Clone)]
pub struct CostMeter {
    pub machine_usd: f64,
    pub transfer_usd: f64,
    /// Machine-hours billed per class, for reporting.
    pub on_demand_hours: f64,
    pub spot_hours: f64,
}

impl CostMeter {
    /// Bill `hours` of an instance at the given class. Spot usage is billed
    /// at the current market price (as AliCloud does), not at the bid.
    pub fn charge_machine(&mut self, class: InstanceClass, hours: f64, market_price: f64) {
        match class {
            InstanceClass::OnDemand => {
                self.on_demand_hours += hours;
                self.machine_usd += hours * market_price;
            }
            InstanceClass::Spot { .. } => {
                self.spot_hours += hours;
                self.machine_usd += hours * market_price;
            }
        }
    }

    /// Bill cross-DC transfer bytes at `per_gb` $/GB.
    pub fn charge_transfer(&mut self, bytes: u64, per_gb: f64) {
        self.transfer_usd += bytes as f64 / (1024.0 * 1024.0 * 1024.0) * per_gb;
    }

    pub fn total_usd(&self) -> f64 {
        self.machine_usd + self.transfer_usd
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cloud_cfg() -> CloudConfig {
        Config::default().cloud
    }

    #[test]
    fn fig3_table_matches_paper() {
        let rows = fig3_prices();
        assert_eq!(rows.len(), 4);
        let ali = rows.iter().find(|r| r.provider == "AliCloud").unwrap();
        assert_eq!(ali.reserved_yearly, 866.0);
        assert_eq!(ali.on_demand_hourly, 0.312);
        assert_eq!(ali.spot_hourly, 0.036);
        // §2.3: spot up to ~10x below on-demand.
        for r in &rows {
            assert!(r.on_demand_hourly / r.spot_hourly >= 4.0, "{}", r.provider);
        }
    }

    #[test]
    fn spot_price_stays_positive_and_near_mean() {
        let cfg = cloud_cfg();
        let mut m = SpotMarket::new(&cfg, Pcg::seeded(5));
        let mut prices = Vec::new();
        for _ in 0..20_000 {
            prices.push(m.step());
        }
        assert!(prices.iter().all(|&p| p > 0.0));
        let mean = crate::util::stats::mean(&prices);
        assert!((mean - cfg.spot_hourly_mean).abs() < cfg.spot_hourly_mean * 0.5, "mean {mean}");
    }

    #[test]
    fn spikes_above_bid_occur_but_are_rare() {
        let cfg = cloud_cfg();
        let mut m = SpotMarket::new(&cfg, Pcg::seeded(6));
        let bid = cfg.bid_multiplier * cfg.spot_hourly_mean;
        let n = 50_000;
        let spikes = (0..n).filter(|_| m.step() > bid).count();
        let frac = spikes as f64 / n as f64;
        assert!(frac > 0.0005, "no revocation events at all ({frac})");
        assert!(frac < 0.15, "revocations too frequent ({frac})");
    }

    #[test]
    fn storm_raises_revocation_pressure_and_restores() {
        let cfg = cloud_cfg();
        let bid = cfg.bid_multiplier * cfg.spot_hourly_mean;
        let spikes = |storm: f64| {
            let mut m = SpotMarket::new(&cfg, Pcg::seeded(8));
            m.set_storm(storm);
            let n = 20_000;
            (0..n).filter(|_| m.step() > bid).count()
        };
        let calm = spikes(1.0);
        let stormy = spikes(4.0);
        assert!(stormy > calm * 3, "storm x4: {stormy} spikes vs calm {calm}");
        // Restoring the storm factor restores the calm trajectory: the
        // factor multiplies the innovation, it does not mutate sigma.
        let mut m = SpotMarket::new(&cfg, Pcg::seeded(8));
        m.set_storm(6.0);
        m.step();
        m.set_storm(1.0);
        assert_eq!(m.storm(), 1.0);
        let mut prices = Vec::new();
        for _ in 0..20_000 {
            prices.push(m.step());
        }
        let mean = crate::util::stats::mean(&prices);
        assert!((mean - cfg.spot_hourly_mean).abs() < cfg.spot_hourly_mean * 0.5, "mean {mean}");
    }

    #[test]
    fn bids_are_jittered() {
        let cfg = cloud_cfg();
        let mut m = SpotMarket::new(&cfg, Pcg::seeded(7));
        let bids: Vec<f64> = (0..100).map(|_| m.draw_bid(&cfg)).collect();
        let base = cfg.bid_multiplier * cfg.spot_hourly_mean;
        assert!(bids.iter().all(|&b| b >= base * 0.9 - 1e-12 && b <= base * 1.1 + 1e-12));
        assert!(crate::util::stats::std_dev(&bids) > 0.0);
    }

    #[test]
    fn cost_meter_accumulates() {
        let mut c = CostMeter::default();
        c.charge_machine(InstanceClass::OnDemand, 2.0, 0.312);
        c.charge_machine(InstanceClass::Spot { bid: 0.06 }, 10.0, 0.036);
        c.charge_transfer(10 * 1024 * 1024 * 1024, 0.13);
        assert!((c.machine_usd - (2.0 * 0.312 + 10.0 * 0.036)).abs() < 1e-9);
        assert!((c.transfer_usd - 1.3).abs() < 1e-9);
        assert_eq!(c.on_demand_hours, 2.0);
        assert_eq!(c.spot_hours, 10.0);
        assert!((c.total_usd() - (c.machine_usd + c.transfer_usd)).abs() < 1e-12);
    }

    #[test]
    fn cost_meter_zero_hour_charges_are_exact_noops() {
        // Billing zero hours (a job that finished within one stamp, or a
        // class that was never used) must not perturb any component.
        let mut c = CostMeter::default();
        c.charge_machine(InstanceClass::OnDemand, 0.0, 0.312);
        c.charge_machine(InstanceClass::Spot { bid: 0.05 }, 0.0, 0.036);
        c.charge_transfer(0, 0.13);
        assert_eq!(c.machine_usd, 0.0);
        assert_eq!(c.transfer_usd, 0.0);
        assert_eq!(c.on_demand_hours, 0.0);
        assert_eq!(c.spot_hours, 0.0);
        assert_eq!(c.total_usd(), 0.0);
        // And zero-hour charges interleaved with real ones change nothing.
        c.charge_machine(InstanceClass::OnDemand, 1.0, 0.312);
        let snapshot = c.total_usd();
        c.charge_machine(InstanceClass::OnDemand, 0.0, 0.312);
        assert_eq!(c.total_usd(), snapshot);
    }

    #[test]
    fn storm_window_prices_stay_positive_and_finite() {
        // Even an absurd storm factor cannot push the log-AR(1) price to
        // zero, negative or non-finite values — the storm scales the
        // innovation, it never escapes the exp() clamp.
        let cfg = cloud_cfg();
        let mut m = SpotMarket::new(&cfg, Pcg::seeded(11));
        m.set_storm(50.0);
        for _ in 0..5_000 {
            let p = m.step();
            assert!(p.is_finite() && p > 0.0, "storm price escaped the clamp: {p}");
        }
        // Restoring calm also restores the configured storm factor.
        m.set_storm(1.0);
        assert_eq!(m.storm(), 1.0);
    }

    #[test]
    fn revokes_is_deterministic_under_fixed_seeds() {
        // Same seed ⇒ the same price trajectory ⇒ the same revocation
        // verdict at every step, for any bid. Different seeds diverge.
        let cfg = cloud_cfg();
        let bid = cfg.bid_multiplier * cfg.spot_hourly_mean;
        let verdicts = |seed: u64| -> Vec<bool> {
            let mut m = SpotMarket::new(&cfg, Pcg::seeded(seed));
            (0..2_000)
                .map(|_| {
                    m.step();
                    m.revokes(bid)
                })
                .collect()
        };
        assert_eq!(verdicts(21), verdicts(21), "fixed seed must replay bit-identically");
        assert_ne!(verdicts(21), verdicts(22), "different seeds must diverge");
        // revokes() itself is a pure threshold: boundary cases are exact.
        let m = SpotMarket::new(&cfg, Pcg::seeded(21));
        assert!(!m.revokes(m.price()), "price == bid must not revoke");
        assert!(m.revokes(m.price() - 1e-12));
        assert!(!m.revokes(f64::INFINITY));
    }

    #[test]
    fn draw_bid_with_matches_draw_bid_at_the_config_multiplier() {
        let cfg = cloud_cfg();
        let mut a = SpotMarket::new(&cfg, Pcg::seeded(13));
        let mut b = SpotMarket::new(&cfg, Pcg::seeded(13));
        for _ in 0..20 {
            assert_eq!(a.draw_bid(&cfg), b.draw_bid_with(cfg.bid_multiplier, &cfg));
        }
    }

    #[test]
    fn spot_is_much_cheaper_for_same_hours() {
        // The Fig-10 effect in miniature: 16 workers for 1 h.
        let cfg = cloud_cfg();
        let mut spot = CostMeter::default();
        let mut ondemand = CostMeter::default();
        for _ in 0..16 {
            spot.charge_machine(InstanceClass::Spot { bid: 0.06 }, 1.0, cfg.spot_hourly_mean);
            ondemand.charge_machine(InstanceClass::OnDemand, 1.0, cfg.on_demand_hourly);
        }
        assert!(spot.machine_usd < ondemand.machine_usd * 0.15);
    }
}
