//! Bid strategies: how a deployment acquires revocable capacity.
//!
//! HOUTU's efficiency half (§2.3/§6.3) rents cheap spot instances whose
//! continued existence depends on the standing bid beating the market
//! price. The seed reproduction drew one blind random bid per VM
//! ([`SpotMarket::draw_bid`]); this module turns that into a pluggable
//! [`BidStrategy`] decided per *acquisition* (initial fleet build and
//! every post-revocation re-acquisition) and per *container request*
//! (the class preference a JM attaches when it asks its master for
//! capacity):
//!
//! * [`Naive`] — the seed behaviour, kept as the bit-identical baseline:
//!   `bid_multiplier × mean`, jittered ±10 %. Default; a run under
//!   `bidding.strategy = "naive"` consumes the same RNG stream and
//!   publishes the same trace events as the pre-subsystem code.
//! * [`AdaptivePredictor`] — an EWMA price forecast per DC plus an EWMA
//!   absolute-deviation volatility proxy, both fed by every market
//!   recalculation ([`BidStrategy::observe_price`]). Bids track the
//!   forecast with a volatility-scaled safety margin, so `spot_storm@`
//!   windows raise the bid *before* the next spike out-prices the fleet;
//!   if the forecast itself crosses the on-demand rate the strategy backs
//!   off spot entirely and buys on-demand — the "picks on-demand vs spot"
//!   decision of the wide-area-analytics cost/latency trade-off.
//! * [`DeadlineAware`] — per-job budget + soft deadline (the
//!   `workload.budget_usd` / `workload.deadline_secs` config keys). It
//!   bids at the calm baseline while jobs track their critical-path
//!   estimate ([`crate::deploy::JobRt::remaining_critical_path`]) and
//!   scales toward `bidding.aggressive_multiplier` only when a job is
//!   projected to overshoot its deadline — and never while over budget.
//!
//! The insurance half of the subsystem (PingAn, arXiv:1804.02817) lives
//! in `deploy::lifecycle`: tasks launched on high-revocation-risk spot
//! containers get a duplicate copy, first commit wins. Strategies here
//! only decide *prices*; the risk gate is `bidding.risk_margin`.

use crate::config::{BiddingConfig, CloudConfig};
use crate::ids::DcId;
use crate::util::error::Result;
use crate::bail;

use super::{InstanceClass, SpotMarket};

/// Which [`BidStrategy`] a run uses (`bidding.strategy` in the config,
/// `strategy = "..."` in campaign scenarios).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// Blind random bid (the seed behaviour; bit-identical baseline).
    Naive,
    /// EWMA price forecast + volatility back-off per DC.
    Adaptive,
    /// Budget/deadline-driven aggression.
    Deadline,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 3] =
        [StrategyKind::Naive, StrategyKind::Adaptive, StrategyKind::Deadline];

    pub fn parse(s: &str) -> Result<StrategyKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "naive" => StrategyKind::Naive,
            "adaptive" => StrategyKind::Adaptive,
            "deadline" => StrategyKind::Deadline,
            other => bail!("unknown bid strategy {other:?} (naive|adaptive|deadline)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Naive => "naive",
            StrategyKind::Adaptive => "adaptive",
            StrategyKind::Deadline => "deadline",
        }
    }
}

/// Context for one acquisition decision.
#[derive(Debug, Clone, Copy)]
pub struct BidRequest {
    pub dc: DcId,
    /// How far behind schedule the worst active job is: 0 = on track,
    /// 1 = projected to overshoot its soft deadline by ≥ 100 %.
    pub urgency: f64,
    /// Some active job has exhausted its `workload.budget_usd`.
    pub over_budget: bool,
}

impl BidRequest {
    /// A calm request (fleet build time: no jobs yet, nothing urgent).
    pub fn calm(dc: DcId) -> BidRequest {
        BidRequest { dc, urgency: 0.0, over_budget: false }
    }
}

/// Instance-class preference a JM attaches to its container requests
/// (carried to the master and honoured by its allocation pass).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClassPref {
    /// Any free container (the default; allocation order unchanged).
    Any,
    /// Prefer containers hosted on on-demand (revocation-proof) VMs.
    Reliable,
}

/// A pluggable bidding policy. One instance lives on the [`World`] and
/// sees every market recalculation; [`BidStrategy::quote`] is consulted
/// at every worker-VM acquisition and [`BidStrategy::container_pref`] at
/// every scheduling period for every live JM.
///
/// [`World`]: crate::deploy::World
pub trait BidStrategy {
    fn kind(&self) -> StrategyKind;

    /// A market recalculated its price (every `market_period_secs`).
    /// State-only: must not consume RNG.
    fn observe_price(&mut self, _dc: DcId, _price: f64) {}

    /// Decide the instance class (+ standing bid) for a fresh worker VM.
    fn quote(
        &mut self,
        req: &BidRequest,
        market: &mut SpotMarket,
        cfg: &CloudConfig,
    ) -> InstanceClass;

    /// The class preference a JM in `dc` attaches to its container
    /// requests this period.
    fn container_pref(&self, _dc: DcId, _urgency: f64) -> ClassPref {
        ClassPref::Any
    }
}

/// The seed baseline: blind `bid_multiplier × mean`, jittered ±10 %.
/// Byte-identical to the pre-subsystem [`SpotMarket::draw_bid`] path.
pub struct Naive;

impl BidStrategy for Naive {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Naive
    }

    fn quote(
        &mut self,
        _req: &BidRequest,
        market: &mut SpotMarket,
        cfg: &CloudConfig,
    ) -> InstanceClass {
        InstanceClass::Spot { bid: market.draw_bid(cfg) }
    }
}

/// EWMA price forecast per DC. `forecast` tracks the level, `dev` the
/// mean absolute deviation (a robust volatility proxy); both start at
/// the configured mean / calm deviation so the strategy is sane before
/// the first observation.
pub struct AdaptivePredictor {
    alpha: f64,
    forecast: Vec<f64>,
    dev: Vec<f64>,
}

/// Volatility ratio above which the predictor treats a region as inside
/// a price storm and backs off: bids carry the full safety margin and
/// container requests prefer reliable hosts.
const STORM_VOL_RATIO: f64 = 0.25;

impl AdaptivePredictor {
    pub fn new(num_dcs: usize, cloud: &CloudConfig, bidding: &BiddingConfig) -> AdaptivePredictor {
        AdaptivePredictor {
            alpha: bidding.ewma_alpha,
            forecast: vec![cloud.spot_hourly_mean; num_dcs],
            // Calm log-AR(1) deviation is roughly sigma × mean.
            dev: vec![cloud.spot_volatility * cloud.spot_hourly_mean; num_dcs],
        }
    }

    /// Deviation-to-level ratio: the storm detector.
    pub fn vol_ratio(&self, dc: DcId) -> f64 {
        let f = self.forecast[dc.0].max(1e-9);
        self.dev[dc.0] / f
    }

    /// The bid the predictor wants for `dc` (before jitter): forecast
    /// plus a volatility-scaled safety margin, floored at the naive
    /// baseline so calm markets never bid below the seed behaviour.
    pub fn target_bid(&self, dc: DcId, cfg: &CloudConfig) -> f64 {
        let f = self.forecast[dc.0];
        let safety = 1.0 + 4.0 * self.vol_ratio(dc);
        (f * safety).max(cfg.bid_multiplier * cfg.spot_hourly_mean)
    }
}

impl BidStrategy for AdaptivePredictor {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Adaptive
    }

    fn observe_price(&mut self, dc: DcId, price: f64) {
        let a = self.alpha;
        let err = (price - self.forecast[dc.0]).abs();
        self.dev[dc.0] = a * err + (1.0 - a) * self.dev[dc.0];
        self.forecast[dc.0] = a * price + (1.0 - a) * self.forecast[dc.0];
    }

    fn quote(
        &mut self,
        req: &BidRequest,
        market: &mut SpotMarket,
        cfg: &CloudConfig,
    ) -> InstanceClass {
        if self.forecast[req.dc.0] >= cfg.on_demand_hourly {
            // The forecast *level* out-prices on-demand: spot has stopped
            // being the cheap option — back off to the reliable class.
            // (Gated on the level, not level × safety margin: a high bid
            // costs nothing unless revoked, but an on-demand VM bills at
            // the premium rate for as long as it is held.)
            return InstanceClass::OnDemand;
        }
        let target = self.target_bid(req.dc, cfg);
        // Same ±10 % jitter as the naive path, so one spike still revokes
        // a subset of the fleet rather than all of it at once.
        InstanceClass::Spot { bid: market.draw_bid_with(target / cfg.spot_hourly_mean, cfg) }
    }

    fn container_pref(&self, dc: DcId, _urgency: f64) -> ClassPref {
        if self.vol_ratio(dc) > STORM_VOL_RATIO {
            ClassPref::Reliable
        } else {
            ClassPref::Any
        }
    }
}

/// Budget/deadline-driven: calm-baseline bids while on schedule, scaled
/// toward `aggressive_multiplier` as jobs fall behind their critical-path
/// estimate — and never aggressive while a job is over budget.
pub struct DeadlineAware {
    base: f64,
    aggressive: f64,
}

impl DeadlineAware {
    pub fn new(cloud: &CloudConfig, bidding: &BiddingConfig) -> DeadlineAware {
        DeadlineAware {
            base: cloud.bid_multiplier,
            aggressive: bidding.aggressive_multiplier.max(cloud.bid_multiplier),
        }
    }

    /// The bid multiplier for a given urgency/budget state.
    pub fn multiplier(&self, urgency: f64, over_budget: bool) -> f64 {
        if over_budget {
            self.base
        } else {
            self.base + (self.aggressive - self.base) * urgency.clamp(0.0, 1.0)
        }
    }
}

impl BidStrategy for DeadlineAware {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Deadline
    }

    fn quote(
        &mut self,
        req: &BidRequest,
        market: &mut SpotMarket,
        cfg: &CloudConfig,
    ) -> InstanceClass {
        let mult = self.multiplier(req.urgency, req.over_budget);
        InstanceClass::Spot { bid: market.draw_bid_with(mult, cfg) }
    }

    fn container_pref(&self, _dc: DcId, urgency: f64) -> ClassPref {
        if urgency > 0.5 {
            ClassPref::Reliable
        } else {
            ClassPref::Any
        }
    }
}

/// Build the configured strategy for a topology of `num_dcs` regions.
pub fn build_strategy(
    num_dcs: usize,
    cloud: &CloudConfig,
    bidding: &BiddingConfig,
) -> Box<dyn BidStrategy> {
    match bidding.strategy {
        StrategyKind::Naive => Box::new(Naive),
        StrategyKind::Adaptive => Box::new(AdaptivePredictor::new(num_dcs, cloud, bidding)),
        StrategyKind::Deadline => Box::new(DeadlineAware::new(cloud, bidding)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::util::Pcg;

    fn cfgs() -> (CloudConfig, BiddingConfig) {
        let c = Config::default();
        (c.cloud, c.bidding)
    }

    #[test]
    fn strategy_kind_parse_roundtrip() {
        for k in StrategyKind::ALL {
            assert_eq!(StrategyKind::parse(k.name()).unwrap(), k);
        }
        assert!(StrategyKind::parse("greedy").is_err());
    }

    #[test]
    fn naive_matches_the_seed_draw_bid_stream() {
        let (cloud, _) = cfgs();
        let mut legacy = SpotMarket::new(&cloud, Pcg::seeded(3));
        let mut ours = SpotMarket::new(&cloud, Pcg::seeded(3));
        let mut naive = Naive;
        for _ in 0..50 {
            let want = legacy.draw_bid(&cloud);
            let got = naive.quote(&BidRequest::calm(DcId(0)), &mut ours, &cloud);
            assert_eq!(got, InstanceClass::Spot { bid: want }, "naive must stay bit-identical");
        }
    }

    #[test]
    fn adaptive_raises_bids_when_observed_prices_turn_volatile() {
        let (cloud, bidding) = cfgs();
        let mut a = AdaptivePredictor::new(1, &cloud, &bidding);
        let calm = a.target_bid(DcId(0), &cloud);
        // A storm: prices swinging multi-x around the mean.
        for (i, p) in [0.03, 0.11, 0.04, 0.15, 0.05, 0.18, 0.04, 0.2].iter().enumerate() {
            a.observe_price(DcId(0), *p);
            let _ = i;
        }
        let stormy = a.target_bid(DcId(0), &cloud);
        assert!(
            stormy > calm * 1.3,
            "volatile series must raise the bid: calm {calm:.4} stormy {stormy:.4}"
        );
        assert!(a.vol_ratio(DcId(0)) > STORM_VOL_RATIO);
        assert_eq!(a.container_pref(DcId(0), 0.0), ClassPref::Reliable, "storm backs off spot");
    }

    #[test]
    fn adaptive_converges_back_after_calm_returns() {
        let (cloud, bidding) = cfgs();
        let mut a = AdaptivePredictor::new(1, &cloud, &bidding);
        for p in [0.3, 0.02, 0.25, 0.03] {
            a.observe_price(DcId(0), p);
        }
        assert_eq!(a.container_pref(DcId(0), 0.0), ClassPref::Reliable);
        for _ in 0..60 {
            a.observe_price(DcId(0), cloud.spot_hourly_mean);
        }
        assert_eq!(a.container_pref(DcId(0), 0.0), ClassPref::Any, "calm restores Any");
        let target = a.target_bid(DcId(0), &cloud);
        assert!(
            (target - cloud.bid_multiplier * cloud.spot_hourly_mean).abs() < 0.01,
            "target {target} should settle near the naive floor"
        );
    }

    #[test]
    fn adaptive_backs_off_to_on_demand_when_spot_out_prices_it() {
        let (cloud, bidding) = cfgs();
        let mut a = AdaptivePredictor::new(1, &cloud, &bidding);
        // Sustained prices above the on-demand rate.
        for _ in 0..30 {
            a.observe_price(DcId(0), cloud.on_demand_hourly * 2.0);
        }
        let mut market = SpotMarket::new(&cloud, Pcg::seeded(9));
        let got = a.quote(&BidRequest::calm(DcId(0)), &mut market, &cloud);
        assert_eq!(got, InstanceClass::OnDemand, "forecast above on-demand must back off spot");
    }

    #[test]
    fn deadline_bids_aggressively_only_when_behind_and_within_budget() {
        let (cloud, bidding) = cfgs();
        let d = DeadlineAware::new(&cloud, &bidding);
        let calm = d.multiplier(0.0, false);
        assert_eq!(calm, cloud.bid_multiplier, "on-track jobs bid the calm baseline");
        let behind = d.multiplier(1.0, false);
        assert!(
            (behind - bidding.aggressive_multiplier).abs() < 1e-12,
            "fully behind ⇒ full aggression (got {behind})"
        );
        assert!(d.multiplier(0.5, false) > calm);
        assert!(d.multiplier(0.5, false) < behind);
        assert_eq!(d.multiplier(1.0, true), calm, "over budget caps the aggression");
        assert_eq!(d.container_pref(DcId(0), 0.9), ClassPref::Reliable);
        assert_eq!(d.container_pref(DcId(0), 0.1), ClassPref::Any);
    }

    #[test]
    fn build_strategy_honours_the_config() {
        let (cloud, mut bidding) = cfgs();
        for k in StrategyKind::ALL {
            bidding.strategy = k;
            assert_eq!(build_strategy(4, &cloud, &bidding).kind(), k);
        }
    }
}
