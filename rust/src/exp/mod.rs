//! Experiment harness: one function per paper table/figure, each returning
//! a formatted report (and structured numbers where benches need them).
//! The `benches/` binaries and the CLI both call through here, so
//! `cargo bench` regenerates every row the paper reports.

use std::fmt::Write as _;

use crate::cloud::fig3_prices;
use crate::config::{Config, Deployment};
use crate::dag::{SizeClass, WorkloadKind};
use crate::deploy::{run_single_job, run_trace_experiment, SingleJobPlan, World};
use crate::ids::{DcId, JobId};
use crate::net::Wan;
use crate::util::stats::Summary;
use crate::util::Pcg;
use crate::workloads::input_bytes;

/// Fig 2: measured WAN bandwidth between region pairs, (mean, std) Mbps.
pub fn fig2_wan(cfg: &Config) -> String {
    let mut wan = Wan::new(cfg.wan.clone(), Pcg::seeded(cfg.seed));
    let names = &cfg.topology.regions;
    let n = names.len();
    let mut out = String::new();
    writeln!(out, "Fig 2 — WAN bandwidth between regions, (mean, std) Mbps").unwrap();
    write!(out, "{:>8}", "").unwrap();
    for name in names {
        write!(out, "{name:>14}").unwrap();
    }
    writeln!(out).unwrap();
    for i in 0..n {
        write!(out, "{:>8}", names[i]).unwrap();
        for j in 0..n {
            if j < i {
                write!(out, "{:>14}", "").unwrap();
            } else {
                // 3 rounds x 5 minutes at 1 sample/s, as in §2.2.
                let (m, s) = wan.measure_pair(DcId(i), DcId(j), 3, 300);
                write!(out, "{:>14}", format!("({m:.0},{s:.0})")).unwrap();
            }
        }
        writeln!(out).unwrap();
    }
    out
}

/// Fig 3: the pricing table.
pub fn fig3_table() -> String {
    let mut out = String::new();
    writeln!(out, "Fig 3 — price of a <4 vCPU, 16 GB> instance (USD)").unwrap();
    writeln!(out, "{:>10} {:>14} {:>14} {:>10}", "provider", "Reserved/yr", "OnDemand/hr", "Spot/hr").unwrap();
    for r in fig3_prices() {
        writeln!(
            out,
            "{:>10} {:>14.0} {:>14.3} {:>10.3}",
            r.provider, r.reserved_yearly, r.on_demand_hourly, r.spot_hourly
        )
        .unwrap();
    }
    out
}

/// Fig 7: workload input sizes.
pub fn fig7_table() -> String {
    let mut out = String::new();
    writeln!(out, "Fig 7 — input sizes per workload").unwrap();
    writeln!(out, "{:>12} {:>10} {:>10} {:>10}", "workload", "small", "medium", "large").unwrap();
    for kind in WorkloadKind::ALL {
        let cell = |s: SizeClass| crate::util::fmt_bytes(input_bytes(kind, s));
        writeln!(
            out,
            "{:>12} {:>10} {:>10} {:>10}",
            kind.name(),
            if kind == WorkloadKind::TpcH { "-".into() } else { cell(SizeClass::Small) },
            cell(SizeClass::Medium),
            cell(SizeClass::Large)
        )
        .unwrap();
    }
    out
}

/// One deployment's Fig-8/Fig-10 numbers.
pub struct DeploymentResult {
    pub mode: Deployment,
    pub avg_jrt: f64,
    pub makespan: f64,
    pub jrt_cdf: Vec<(f64, f64)>,
    pub machine_usd: f64,
    pub transfer_usd: f64,
    pub cross_dc_gb: f64,
    pub world: World,
}

/// Run the Fig-8 online trace on one deployment.
pub fn run_deployment(cfg: &Config, mode: Deployment) -> DeploymentResult {
    let world = run_trace_experiment(cfg, mode);
    DeploymentResult {
        mode,
        avg_jrt: world.metrics.avg_jrt(),
        makespan: world.metrics.makespan(),
        jrt_cdf: world.metrics.jrt_cdf(&[0.1, 0.25, 0.5, 0.75, 0.9, 1.0]),
        machine_usd: world.cost.machine_usd,
        transfer_usd: world.cost.transfer_usd,
        cross_dc_gb: world.wan.stats.cross_dc_total_bytes() as f64 / (1 << 30) as f64,
        world,
    }
}

/// Fig 8: job performance across the four deployments.
pub fn fig8_performance(cfg: &Config) -> (String, Vec<DeploymentResult>) {
    let results: Vec<DeploymentResult> =
        Deployment::ALL.iter().map(|&m| run_deployment(cfg, m)).collect();
    let mut out = String::new();
    writeln!(out, "Fig 8(b) — avg job response time and makespan ({} jobs)", cfg.workload.num_jobs)
        .unwrap();
    writeln!(out, "{:>12} {:>14} {:>12}", "deployment", "avg JRT (s)", "makespan (s)").unwrap();
    for r in &results {
        writeln!(out, "{:>12} {:>14.0} {:>12.0}", r.mode.name(), r.avg_jrt, r.makespan).unwrap();
    }
    writeln!(out, "\nFig 8(a) — JRT CDF (seconds at fraction)").unwrap();
    write!(out, "{:>12}", "fraction").unwrap();
    for f in [0.1, 0.25, 0.5, 0.75, 0.9, 1.0] {
        write!(out, "{f:>10.2}").unwrap();
    }
    writeln!(out).unwrap();
    for r in &results {
        write!(out, "{:>12}", r.mode.name()).unwrap();
        for (v, _) in &r.jrt_cdf {
            write!(out, "{v:>10.0}").unwrap();
        }
        writeln!(out).unwrap();
    }
    (out, results)
}

/// Fig 10: normalized machine + communication cost vs cent-stat.
pub fn fig10_cost(results: &[DeploymentResult]) -> String {
    let baseline = results
        .iter()
        .find(|r| r.mode == Deployment::CentStat)
        .expect("cent-stat baseline required");
    let mut out = String::new();
    writeln!(out, "Fig 10 — cost normalized to cent-stat").unwrap();
    writeln!(
        out,
        "{:>12} {:>14} {:>18} {:>12} {:>14}",
        "deployment", "machine cost", "communication cost", "machine $", "cross-DC GB"
    )
    .unwrap();
    let order = [Deployment::Houtu, Deployment::CentDyna, Deployment::DecentStat, Deployment::CentStat];
    for mode in order {
        let r = results.iter().find(|r| r.mode == mode).unwrap();
        writeln!(
            out,
            "{:>12} {:>14.2} {:>18.2} {:>12.2} {:>14.2}",
            r.mode.name(),
            r.machine_usd / baseline.machine_usd,
            r.transfer_usd / baseline.transfer_usd,
            r.machine_usd,
            r.cross_dc_gb
        )
        .unwrap();
    }
    out
}

/// Fig 9: cumulative running tasks of one job under (a) normal operation,
/// (b) injected load with stealing, (c) injected load without stealing.
/// The three situations are scenario-engine presets, so the figure and
/// `houtu campaign` exercise the same machinery (parity is pinned by the
/// `scenarios` integration tests).
pub fn fig9_stealing(cfg: &Config) -> (String, [Vec<(f64, f64)>; 3]) {
    use crate::scenario::{presets, run_scenario};
    let run = |spec: &crate::scenario::ScenarioSpec| {
        run_scenario(cfg, spec, cfg.seed).expect("fig9 scenario").world
    };
    let normal = run(&presets::fig9_normal());
    let steal = run(&presets::fig9_inject_steal());
    let nosteal = run(&presets::fig9_inject_nosteal());

    let tl = |w: &World| w.metrics.task_launches.get(&JobId(0)).cloned().unwrap_or_default();
    let jrt = |w: &World| w.metrics.jobs[&JobId(0)].jrt().unwrap_or(f64::NAN);
    let mut out = String::new();
    writeln!(out, "Fig 9 — cumulative running tasks of one job (PageRank large)").unwrap();
    writeln!(
        out,
        "(a) normal: JRT {:.0}s   (b) inject@100s + stealing: JRT {:.0}s   (c) inject@100s, no stealing: JRT {:.0}s",
        jrt(&normal),
        jrt(&steal),
        jrt(&nosteal)
    )
    .unwrap();
    let stolen: u64 =
        steal.jobs[&JobId(0)].jms.values().map(|j| j.stats.tasks_stolen_in).sum();
    writeln!(out, "tasks stolen cross-DC in (b): {stolen}").unwrap();
    writeln!(out, "\n{:>8} {:>10} {:>12} {:>14}", "t (s)", "normal", "steal", "no-steal").unwrap();
    let series = [tl(&normal), tl(&steal), tl(&nosteal)];
    let max_t = series
        .iter()
        .filter_map(|s| s.last().map(|&(t, _)| t))
        .fold(0.0, f64::max);
    let sample = |s: &[(f64, f64)], t: f64| {
        s.iter().take_while(|&&(ts, _)| ts <= t).last().map(|&(_, c)| c).unwrap_or(0.0)
    };
    let steps = 12usize;
    for k in 0..=steps {
        let t = max_t * k as f64 / steps as f64;
        writeln!(
            out,
            "{:>8.0} {:>10.0} {:>12.0} {:>14.0}",
            t,
            sample(&series[0], t),
            sample(&series[1], t),
            sample(&series[2], t)
        )
        .unwrap();
    }
    (out, series)
}

/// Fig 11: job recovery from JM failures — containers over time and JRTs
/// for pJM kill, sJM kill (HOUTU) and JM kill (centralized restart).
/// All three kills run through the scenario engine's `fig11_kill` preset.
pub fn fig11_recovery(cfg: &Config) -> String {
    use crate::scenario::{presets, run_scenario};
    let run = |dc, mode| {
        run_scenario(cfg, &presets::fig11_kill(dc, mode), cfg.seed)
            .expect("fig11 scenario")
            .world
    };
    let pjm = run(DcId(0), Deployment::Houtu);
    let sjm = run(DcId(2), Deployment::Houtu);
    let cent = run(DcId(0), Deployment::CentDyna);

    let jrt = |w: &World| w.metrics.jobs[&JobId(0)].jrt().unwrap_or(f64::NAN);
    let mut out = String::new();
    writeln!(out, "Fig 11 — JM failure at t=70 s (WordCount large)").unwrap();
    writeln!(out, "(a) HOUTU, kill pJM : JRT {:.0}s, recoveries: {}", jrt(&pjm),
        pjm.metrics.recovery_intervals_secs.len()).unwrap();
    writeln!(out, "(b) HOUTU, kill sJM : JRT {:.0}s, recoveries: {}", jrt(&sjm),
        sjm.metrics.recovery_intervals_secs.len()).unwrap();
    writeln!(out, "(c) centralized, kill JM → resubmission: JRT {:.0}s, restarts: {}",
        jrt(&cent), cent.metrics.jobs[&JobId(0)].restarts).unwrap();
    for (label, w) in [("pJM-kill", &pjm), ("sJM-kill", &sjm)] {
        let ivs = &w.metrics.recovery_intervals_secs;
        if !ivs.is_empty() {
            writeln!(out, "{label}: recovery interval {:.1}s (paper: < 20 s)", ivs[0]).unwrap();
        }
        if !w.metrics.election_delays_secs.is_empty() {
            writeln!(out, "{label}: election delay {:.2}s", w.metrics.election_delays_secs[0])
                .unwrap();
        }
    }
    writeln!(out, "\ncontainers belonging to the job over time:").unwrap();
    writeln!(out, "{:>8} {:>10} {:>10} {:>12}", "t (s)", "pJM-kill", "sJM-kill", "centralized").unwrap();
    let tls = [
        pjm.metrics.containers.get(&JobId(0)).cloned().unwrap_or_default(),
        sjm.metrics.containers.get(&JobId(0)).cloned().unwrap_or_default(),
        cent.metrics.containers.get(&JobId(0)).cloned().unwrap_or_default(),
    ];
    let max_t = tls.iter().filter_map(|s| s.last().map(|&(t, _)| t)).fold(0.0, f64::max);
    let sample = |s: &[(f64, f64)], t: f64| {
        s.iter().take_while(|&&(ts, _)| ts <= t).last().map(|&(_, c)| c).unwrap_or(0.0)
    };
    for k in 0..=14 {
        let t = max_t * k as f64 / 14.0;
        writeln!(
            out,
            "{:>8.0} {:>10.0} {:>10.0} {:>12.0}",
            t,
            sample(&tls[0], t),
            sample(&tls[1], t),
            sample(&tls[2], t)
        )
        .unwrap();
    }
    out
}

/// Fig 12: overheads — (a) intermediate-info sizes on large inputs,
/// (b) time costs of HOUTU's mechanisms.
pub fn fig12_overhead(cfg: &Config) -> String {
    // (a) run each workload on its large input and sample info sizes.
    let mut out = String::new();
    writeln!(out, "Fig 12(a) — intermediate info size per workload (large inputs)").unwrap();
    writeln!(out, "{:>12} {:>10} {:>10} {:>10} {:>10}", "workload", "p25 KB", "median KB", "p75 KB", "mean KB").unwrap();
    let mut steal_delays = Vec::new();
    for kind in WorkloadKind::ALL {
        let w = run_single_job(
            cfg,
            Deployment::Houtu,
            SingleJobPlan {
                kind,
                size: SizeClass::Large,
                home: DcId(0),
                inject_at: None,
                kill_jm_at: None,
            },
        );
        let sizes = w.metrics.info_sizes.get(&kind).cloned().unwrap_or_default();
        let s = Summary::of(&sizes);
        writeln!(
            out,
            "{:>12} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
            kind.name(),
            s.p25 / 1024.0,
            s.p50 / 1024.0,
            s.p75 / 1024.0,
            s.mean / 1024.0
        )
        .unwrap();
        steal_delays.extend(w.metrics.steal_delays_ms.iter().copied());
    }
    // (b) mechanism time costs: steal delay under load + recovery numbers.
    let mut loaded = cfg.clone();
    loaded.workload.num_jobs = cfg.workload.num_jobs.max(8);
    let w = run_trace_experiment(&loaded, Deployment::Houtu);
    steal_delays.extend(w.metrics.steal_delays_ms.iter().copied());
    let kill = crate::scenario::run_scenario(
        cfg,
        &crate::scenario::presets::fig11_kill(DcId(2), Deployment::Houtu),
        cfg.seed,
    )
    .expect("fig12 kill scenario")
    .world;
    writeln!(out, "\nFig 12(b) — time cost of mechanisms").unwrap();
    let sd = Summary::of(&steal_delays);
    writeln!(out, "steal message delay      : mean {:.2} ms (n={}, paper: 63.53 ms)", sd.mean, sd.n)
        .unwrap();
    let rec = Summary::of(&kill.metrics.recovery_intervals_secs);
    writeln!(out, "sJM recovery interval    : mean {:.1} s (paper: < 20 s)", rec.mean).unwrap();
    let zk_writes = w.zk.stats.writes;
    writeln!(out, "zk writes on the trace   : {zk_writes} (Af bookkeeping itself is negligible)")
        .unwrap();
    out
}

/// Theorem 1 check: makespan vs the T1/|P| lower bound over the trace —
/// the competitive ratio should be a small constant.
pub fn theorem1_bound(cfg: &Config) -> (String, f64) {
    let w = run_trace_experiment(cfg, Deployment::Houtu);
    let total_work: f64 = w.jobs.values().map(|rt| rt.spec.work()).sum();
    let p: usize = (0..w.cfg.topology.num_dcs())
        .map(|d| w.cluster.dc_capacity(DcId(d)))
        .sum();
    // Lower bounds on the optimal makespan: work bound and span bound.
    let arrival_span = w
        .jobs
        .values()
        .map(|rt| rt.submitted_secs)
        .fold(0.0_f64, f64::max);
    let critical: f64 = w.jobs.values().map(|rt| rt.spec.critical_path()).fold(0.0, f64::max);
    let lb = (total_work / p as f64).max(critical).max(1.0) + 0.0;
    let makespan = w.metrics.makespan();
    let ratio = makespan / (lb + arrival_span * 0.0).max(1.0);
    let mut out = String::new();
    writeln!(out, "Theorem 1 — competitive makespan check").unwrap();
    writeln!(out, "T1(J)/|P| = {:.1}s, max T∞ = {critical:.1}s, lower bound = {lb:.1}s", total_work / p as f64).unwrap();
    writeln!(out, "achieved makespan = {makespan:.1}s  →  ratio = {ratio:.2}x (O(1) expected)").unwrap();
    (out, ratio)
}

/// Export the plot data behind every figure as CSV files under `dir`
/// (for regenerating the paper's plots outside the terminal).
pub fn export_csv(cfg: &Config, dir: &std::path::Path) -> std::io::Result<Vec<String>> {
    use std::io::Write;
    std::fs::create_dir_all(dir)?;
    let mut written = Vec::new();
    let mut save = |name: &str, header: &str, rows: &[String]| -> std::io::Result<()> {
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{header}")?;
        for r in rows {
            writeln!(f, "{r}")?;
        }
        written.push(name.to_string());
        Ok(())
    };

    // Fig 8: full per-job JRTs per deployment (the CDF's raw data).
    let (_, results) = fig8_performance(cfg);
    let mut rows = Vec::new();
    for r in &results {
        for (job, rec) in &r.world.metrics.jobs {
            if let Some(jrt) = rec.jrt() {
                rows.push(format!("{},{},{},{:.2}", r.mode.name(), job.0, rec.kind.name(), jrt));
            }
        }
    }
    save("fig8_jrt.csv", "deployment,job,workload,jrt_secs", &rows)?;
    let rows: Vec<String> = results
        .iter()
        .map(|r| {
            format!(
                "{},{:.2},{:.2},{:.4},{:.4},{:.3}",
                r.mode.name(), r.avg_jrt, r.makespan, r.machine_usd, r.transfer_usd, r.cross_dc_gb
            )
        })
        .collect();
    save(
        "fig8_fig10_summary.csv",
        "deployment,avg_jrt_secs,makespan_secs,machine_usd,transfer_usd,cross_dc_gb",
        &rows,
    )?;

    // Fig 9: cumulative launched tasks timelines.
    let (_, series) = fig9_stealing(cfg);
    let mut rows = Vec::new();
    for (label, s) in ["normal", "steal", "no_steal"].iter().zip(&series) {
        for (t, c) in s {
            rows.push(format!("{label},{t:.2},{c}"));
        }
    }
    save("fig9_tasks.csv", "scenario,t_secs,cumulative_tasks", &rows)?;

    // Fig 11: container timelines per kill scenario (engine presets).
    let mk = |dc, mode| {
        crate::scenario::run_scenario(cfg, &crate::scenario::presets::fig11_kill(dc, mode), cfg.seed)
            .expect("fig11 scenario")
            .world
    };
    let worlds = [
        ("pjm_kill", mk(DcId(0), Deployment::Houtu)),
        ("sjm_kill", mk(DcId(2), Deployment::Houtu)),
        ("centralized", mk(DcId(0), Deployment::CentDyna)),
    ];
    let mut rows = Vec::new();
    for (label, w) in &worlds {
        if let Some(tl) = w.metrics.containers.get(&JobId(0)) {
            for (t, c) in tl {
                rows.push(format!("{label},{t:.2},{c}"));
            }
        }
    }
    save("fig11_containers.csv", "scenario,t_secs,containers", &rows)?;
    Ok(written)
}
