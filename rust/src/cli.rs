//! Command-line launcher (hand-rolled — no clap in the offline image).
//!
//! `--set` reaches every config knob, so subsystem axes ride the same
//! surface — e.g. `--set bidding.strategy=adaptive --set
//! bidding.insurance=true` turns on cost-aware bidding + insurance
//! replication for any command below (see `docs/CAMPAIGN.md` for the
//! campaign-file form of the same axes).
//!
//! ```text
//! houtu <command> [--config FILE] [--set section.key=value]...
//!
//! commands:
//!   fig2|fig3|fig7|fig8|fig9|fig10|fig11|fig12   regenerate a paper figure
//!   theorem1                                     check the makespan bound
//!   run --deployment D --workload W --size S     run one job
//!   trace --deployment D                         run the online trace
//!   load [--spec FILE | --smoke]                 open-loop ramp to the saturation knee
//!        [--seed S] [--report out.json|out.csv]  ... fixed seed / export the ramp report
//!        [--shards N]                            ... on the sharded queue engine
//!                                                (digest must not change)
//!   campaign [--spec FILE | --smoke]             run a scenario-matrix campaign
//!            [--report out.json|out.csv]         ... and export the report
//!            [--record out.log]                  ... and persist the event streams
//!            [--shards N]                        ... on the sharded queue engine
//!                                                (0 = auto; digests must not change)
//!            [--threads N]                       ... on N worker threads (N >= 1)
//!            [--topology generated:D,N,S]        ... every scenario on a generated
//!                                                world with D DCs, N nodes per DC,
//!                                                seed S (see docs/SCALE.md; pair
//!                                                with --set topology.exact_dcs=K
//!                                                for the two-tier engine)
//!            [--engine slab|sharded-sim]         ... slab (default): the sequential
//!                                                World; sharded-sim: the World-as-parts
//!                                                model on the threaded ShardedSim
//!                                                (--threads picks the shard count;
//!                                                digests are thread-count invariant)
//!   replay LOG                                   re-execute a recorded event log and
//!                                                assert streams + digests match
//!   fuzz [--cases N] [--seed S]                  chaos-fuzz random scenarios
//!        [--soak MINUTES] [--repro out.toml]     ... soak / write minimal repro
//!        [--report out.json]                     ... and export the fuzz report
//!   bench [--smoke] [--iters N]                  time the sim hot-path workloads
//!         [--threads N]                          ... sharded rows on N threads
//!         [--report BENCH_sim.json]              ... and export the perf report
//!         [--compare BENCH_baseline.json]        ... and gate events/s vs a baseline
//!         [--history BENCH_history.jsonl]        ... and append one trajectory row
//!   all                                          every figure in sequence
//! ```
//!
//! `--threads 0` (the default) resolves through the `HOUTU_THREADS`
//! environment variable, then one worker per core — the same rule every
//! thread pool in the crate uses.

use crate::config::{Config, Deployment};
use crate::dag::{SizeClass, WorkloadKind};
use crate::deploy::{run_single_job, SingleJobPlan};
use crate::exp;
use crate::ids::DcId;

fn usage() -> ! {
    eprintln!(
        "usage: houtu <fig2|fig3|fig7|fig8|fig9|fig10|fig11|fig12|theorem1|run|trace|load|campaign|replay|fuzz|bench|export|all> \
         [--config FILE] [--set section.key=value]... [--deployment D] [--workload W] [--size S] \
         [--spec FILE] [--smoke] [--report out.json|out.csv] [--record out.log] \
         [--shards N] [--threads N] [--engine slab|sharded-sim] [--topology generated:D,N,S] \
         [--cases N] [--seed S] [--soak MINUTES] [--repro out.toml] [--iters N] \
         [--compare BENCH_baseline.json] [--history BENCH_history.jsonl]\n\
         replay takes the log path as its positional argument: houtu replay out.log"
    );
    std::process::exit(2);
}

/// Parsed command line.
pub struct Cli {
    pub command: String,
    pub cfg: Config,
    pub deployment: Deployment,
    pub workload: WorkloadKind,
    pub size: SizeClass,
    /// Campaign spec file (`campaign --spec FILE`).
    pub spec: Option<String>,
    /// Built-in smoke campaign (`campaign --smoke`).
    pub smoke: bool,
    /// Campaign report export path (`campaign --report out.json|out.csv`).
    pub report: Option<String>,
    /// Fuzz cases per batch (`fuzz --cases N`).
    pub cases: usize,
    /// Fuzz seed (`fuzz --seed S`); independent of the config seed, which
    /// the sampled cells override per run.
    pub fuzz_seed: u64,
    /// Soak budget in minutes (`fuzz --soak MINUTES`).
    pub soak_minutes: Option<f64>,
    /// Where to write the first failure's minimal repro TOML
    /// (`fuzz --repro out.toml`).
    pub repro: Option<String>,
    /// Timed iterations per bench workload (`bench --iters N`).
    pub iters: Option<usize>,
    /// Event-log path to record a campaign into (`campaign --record out.log`).
    pub record: Option<String>,
    /// Baseline bench report to gate against (`bench --compare FILE`).
    pub compare: Option<String>,
    /// JSONL perf-history file to append to (`bench --history FILE`).
    pub history: Option<String>,
    /// Worker-thread knob for campaign/bench pools and the sharded
    /// engine (0 = `HOUTU_THREADS`, else one per core).
    pub threads: usize,
    /// Run the campaign on the sharded queue engine with this shard
    /// count (`campaign --shards N`; 0 = auto). `None` = sequential.
    pub shards: Option<usize>,
    /// Campaign execution engine (`campaign --engine slab|sharded-sim`).
    /// `None`/`slab` runs the sequential World; `sharded-sim` runs the
    /// World-as-parts model on the threaded ShardedSim.
    pub engine: Option<String>,
    /// Generated-world token for `campaign --topology generated:D,N,S`:
    /// every scenario in the campaign runs on that topology (scenarios
    /// that already pin a `topology.generated=` override keep theirs).
    pub topology: Option<String>,
    /// Positional event-log path (`replay LOG`).
    pub log_path: Option<String>,
}

pub fn parse(args: &[String]) -> Cli {
    if args.is_empty() {
        usage();
    }
    let command = args[0].clone();
    let mut cfg = Config::default();
    let mut deployment = Deployment::Houtu;
    let mut workload = WorkloadKind::WordCount;
    let mut size = SizeClass::Medium;
    let mut spec = None;
    let mut smoke = false;
    let mut report = None;
    let mut cases = 32usize;
    let mut fuzz_seed = 1u64;
    let mut soak_minutes = None;
    let mut repro = None;
    let mut iters = None;
    let mut record = None;
    let mut compare = None;
    let mut history = None;
    let mut threads = 0usize;
    let mut shards = None;
    let mut engine = None;
    let mut topology = None;
    let mut log_path = None;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                let path = args.get(i).unwrap_or_else(|| usage());
                cfg = Config::from_file(path).unwrap_or_else(|e| {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                });
            }
            "--set" => {
                i += 1;
                let kv = args.get(i).unwrap_or_else(|| usage());
                if let Err(e) = cfg.apply_override(kv) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "--deployment" => {
                i += 1;
                deployment = Deployment::parse(args.get(i).unwrap_or_else(|| usage()))
                    .unwrap_or_else(|e| {
                        eprintln!("error: {e:#}");
                        std::process::exit(1);
                    });
            }
            "--workload" => {
                i += 1;
                workload = match args.get(i).map(String::as_str) {
                    Some("wordcount") => WorkloadKind::WordCount,
                    Some("tpch") => WorkloadKind::TpcH,
                    Some("ml") => WorkloadKind::IterativeMl,
                    Some("pagerank") => WorkloadKind::PageRank,
                    _ => usage(),
                };
            }
            "--size" => {
                i += 1;
                size = match args.get(i).map(String::as_str) {
                    Some("small") => SizeClass::Small,
                    Some("medium") => SizeClass::Medium,
                    Some("large") => SizeClass::Large,
                    _ => usage(),
                };
            }
            "--spec" => {
                i += 1;
                spec = Some(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--smoke" => {
                smoke = true;
            }
            "--report" => {
                i += 1;
                report = Some(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--cases" => {
                i += 1;
                cases = args
                    .get(i)
                    .and_then(|s| s.parse::<usize>().ok())
                    .filter(|&n| n > 0)
                    .unwrap_or_else(|| usage());
            }
            "--seed" => {
                i += 1;
                fuzz_seed =
                    args.get(i).and_then(|s| s.parse::<u64>().ok()).unwrap_or_else(|| usage());
            }
            "--soak" => {
                i += 1;
                soak_minutes = Some(
                    args.get(i)
                        .and_then(|s| s.parse::<f64>().ok())
                        .filter(|m| m.is_finite() && *m > 0.0)
                        .unwrap_or_else(|| usage()),
                );
            }
            "--repro" => {
                i += 1;
                repro = Some(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--iters" => {
                i += 1;
                iters = Some(
                    args.get(i)
                        .and_then(|s| s.parse::<usize>().ok())
                        .filter(|&n| n > 0)
                        .unwrap_or_else(|| usage()),
                );
            }
            "--record" => {
                i += 1;
                record = Some(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--compare" => {
                i += 1;
                compare = Some(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--history" => {
                i += 1;
                history = Some(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--threads" => {
                i += 1;
                threads =
                    args.get(i).and_then(|s| s.parse::<usize>().ok()).unwrap_or_else(|| usage());
                // Reject the explicit zero instead of silently falling
                // back to auto-sizing (omit the flag for that).
                if threads == 0 {
                    eprintln!(
                        "error: --threads must be >= 1 (omit the flag or unset \
                         HOUTU_THREADS for auto-sizing)"
                    );
                    std::process::exit(2);
                }
            }
            "--engine" => {
                i += 1;
                let e = args.get(i).unwrap_or_else(|| usage()).clone();
                if e != "slab" && e != "sharded-sim" {
                    eprintln!("error: unknown engine {e:?} (known: slab, sharded-sim)");
                    std::process::exit(2);
                }
                engine = Some(e);
            }
            "--shards" => {
                i += 1;
                shards = Some(
                    args.get(i).and_then(|s| s.parse::<usize>().ok()).unwrap_or_else(|| usage()),
                );
            }
            "--topology" => {
                i += 1;
                let t = args.get(i).unwrap_or_else(|| usage()).clone();
                if let Err(e) = crate::topo::parse_spec(&t) {
                    eprintln!("error: {e:#}");
                    std::process::exit(2);
                }
                topology = Some(t);
            }
            other => {
                // `replay` takes its log path as the one positional arg.
                if command == "replay" && !other.starts_with('-') && log_path.is_none() {
                    log_path = Some(other.to_string());
                } else {
                    eprintln!("unknown flag {other:?}");
                    usage();
                }
            }
        }
        i += 1;
    }
    Cli {
        command,
        cfg,
        deployment,
        workload,
        size,
        spec,
        smoke,
        report,
        cases,
        fuzz_seed,
        soak_minutes,
        repro,
        iters,
        record,
        compare,
        history,
        threads,
        shards,
        engine,
        topology,
        log_path,
    }
}

/// Entry point used by `main.rs`.
pub fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = parse(&args);
    run(&cli);
}

pub fn run(cli: &Cli) {
    let cfg = &cli.cfg;
    match cli.command.as_str() {
        "fig2" => print!("{}", exp::fig2_wan(cfg)),
        "fig3" => print!("{}", exp::fig3_table()),
        "fig7" => print!("{}", exp::fig7_table()),
        "fig8" => {
            let (report, _) = exp::fig8_performance(cfg);
            print!("{report}");
        }
        "fig9" => {
            let (report, _) = exp::fig9_stealing(cfg);
            print!("{report}");
        }
        "fig10" => {
            let (_, results) = exp::fig8_performance(cfg);
            print!("{}", exp::fig10_cost(&results));
        }
        "fig11" => print!("{}", exp::fig11_recovery(cfg)),
        "fig12" => print!("{}", exp::fig12_overhead(cfg)),
        "theorem1" => {
            let (report, _) = exp::theorem1_bound(cfg);
            print!("{report}");
        }
        "run" => {
            let w = run_single_job(
                cfg,
                cli.deployment,
                SingleJobPlan {
                    kind: cli.workload,
                    size: cli.size,
                    home: DcId(0),
                    inject_at: None,
                    kill_jm_at: None,
                },
            );
            let rec = &w.metrics.jobs[&crate::ids::JobId(0)];
            println!(
                "{} {} on {}: JRT {:.1}s ({} tasks, {} cross-DC inputs)",
                rec.kind.name(),
                rec.size.name(),
                cli.deployment.name(),
                rec.jrt().unwrap_or(f64::NAN),
                rec.tasks_total,
                w.metrics.remote_input_tasks,
            );
        }
        "export" => {
            let dir = std::path::Path::new("results");
            match exp::export_csv(cfg, dir) {
                Ok(files) => {
                    for f in files {
                        println!("wrote results/{f}");
                    }
                }
                Err(e) => {
                    eprintln!("export failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        "load" => {
            use crate::load::{self, LoadSpec};
            let spec = if cli.smoke {
                load::smoke_spec()
            } else if let Some(path) = &cli.spec {
                LoadSpec::from_file(path).unwrap_or_else(|e| {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                })
            } else {
                eprintln!("load needs --spec FILE or --smoke");
                usage();
            };
            let queue = match cli.shards {
                Some(n) => {
                    crate::sim::QueueKind::Sharded(crate::scenario::resolve_threads(n))
                }
                None => crate::sim::QueueKind::Slab,
            };
            // `--seed` (shared with fuzz) picks the arrival-stream and
            // world seed; default 1, ci.sh pins 42.
            let out = crate::load::run_load_on(cfg, &spec, cli.fuzz_seed, queue)
                .unwrap_or_else(|e| {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                });
            print!("{}", out.render());
            if let Some(path) = &cli.report {
                match load::write_and_verify(&out, path) {
                    Ok(format) => println!(
                        "wrote {path} ({format}, {} steps, round-trip OK)",
                        out.steps.len()
                    ),
                    Err(e) => {
                        eprintln!("load report export failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
        }
        "campaign" => {
            use crate::scenario::{self, CampaignSpec};
            let load = |path: &str| -> CampaignSpec {
                CampaignSpec::from_file(path).unwrap_or_else(|e| {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                })
            };
            // The recorded source tag lets `houtu replay` rebuild the
            // same cell matrix without embedding scenario definitions.
            let (mut spec, source) = if cli.smoke {
                (scenario::smoke_campaign(), "smoke".to_string())
            } else if let Some(path) = &cli.spec {
                (load(path), format!("spec:{path}"))
            } else if std::path::Path::new("configs/campaign.toml").exists() {
                (load("configs/campaign.toml"), "spec:configs/campaign.toml".to_string())
            } else {
                (scenario::standard_campaign(), "standard".to_string())
            };
            if cli.threads > 0 {
                spec.parallelism = cli.threads;
            }
            if let Some(t) = &cli.topology {
                // Rebase every scenario onto the generated world; a
                // scenario that already pins its own topology keeps it.
                for sc in &mut spec.scenarios {
                    if !sc.overrides.iter().any(|o| o.starts_with("topology.generated=")) {
                        sc.regions = 0;
                        sc.overrides.push(format!("topology.generated={t}"));
                    }
                }
            }
            if cli.engine.as_deref() == Some("sharded-sim") {
                // The World-as-parts model on ShardedSim: `--threads`
                // picks the shard count (digests are invariant to it).
                if cli.record.is_some() {
                    eprintln!("--record is not supported on --engine sharded-sim");
                    std::process::exit(2);
                }
                let threads = scenario::resolve_threads(cli.threads);
                let report = crate::deploy::run_campaign_parts(cfg, &spec, threads)
                    .unwrap_or_else(|e| {
                        eprintln!("error: {e:#}");
                        std::process::exit(1);
                    });
                print!("{}", report.render());
                if let Some(path) = &cli.report {
                    match std::fs::write(path, report.to_json()) {
                        Ok(()) => println!(
                            "wrote {path} (json, {} cells, engine sharded-sim)",
                            report.cells.len()
                        ),
                        Err(e) => {
                            eprintln!("report export failed: {e}");
                            std::process::exit(1);
                        }
                    }
                }
                return;
            }
            let queue = match cli.shards {
                Some(n) => crate::sim::QueueKind::Sharded(scenario::resolve_threads(n)),
                None => crate::sim::QueueKind::Slab,
            };
            let report = scenario::run_campaign_on(cfg, &spec, queue);
            print!("{}", report.render());
            // Export before the pass/fail gate so failing campaigns
            // still leave their report (violations included) behind.
            if let Some(path) = &cli.report {
                match scenario::write_and_verify(&report, path) {
                    Ok(format) => {
                        println!(
                            "wrote {path} ({format}, {} runs, round-trip OK)",
                            report.runs.len()
                        );
                    }
                    Err(e) => {
                        eprintln!("report export failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
            if let Some(path) = &cli.record {
                let recorded = scenario::record_campaign(cfg, &spec, &source)
                    .and_then(|log| scenario::write_log(&log, path).map(|()| log));
                match recorded {
                    Ok(log) => println!(
                        "recorded {path} ({} cells, {} events, round-trip OK)",
                        log.cells.len(),
                        log.cells.iter().map(|c| c.events).sum::<u64>()
                    ),
                    Err(e) => {
                        eprintln!("event-log record failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
            if !report.all_pass() {
                eprintln!("campaign FAILED: {} violations", report.total_violations());
                std::process::exit(1);
            }
        }
        "replay" => {
            let path = cli.log_path.as_deref().unwrap_or_else(|| usage());
            match crate::scenario::replay_file(cfg, path) {
                Ok(s) => println!(
                    "replay OK: {} cells, {} events re-executed, streams and digests match",
                    s.cells, s.events
                ),
                Err(e) => {
                    eprintln!("replay FAILED: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        "fuzz" => {
            use crate::scenario::{fuzz, FuzzOpts, FuzzSpace};
            let space = FuzzSpace::default();
            let opts = FuzzOpts { cases: cli.cases, seed: cli.fuzz_seed, ..FuzzOpts::default() };
            let report = match cli.soak_minutes {
                Some(minutes) => fuzz::run_soak(cfg, &space, &opts, minutes),
                None => fuzz::run_fuzz(cfg, &space, &opts),
            };
            print!("{}", report.render());
            // Export before the pass/fail gate so failing fuzz runs
            // still leave their report behind (mirrors `campaign`).
            if let Some(path) = &cli.report {
                match fuzz::write_report(&report, path) {
                    Ok(()) => {
                        println!("wrote {path} (json, {} cases, round-trip OK)", report.cases);
                    }
                    Err(e) => {
                        eprintln!("fuzz report export failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
            if let Some(first) = report.failures.first() {
                if let Some(path) = &cli.repro {
                    match fuzz::write_repro(&first.shrunk, path) {
                        Ok(()) => println!(
                            "wrote {path} ({} chaos event(s), seed {}, round-trip OK)",
                            first.shrunk.spec.events.len(),
                            first.shrunk.seed
                        ),
                        Err(e) => {
                            eprintln!("repro export failed: {e:#}");
                            std::process::exit(1);
                        }
                    }
                }
                eprintln!(
                    "fuzz FAILED: {} of {} cases violated invariants",
                    report.failures.len(),
                    report.cases
                );
                std::process::exit(1);
            }
        }
        "bench" => {
            use crate::bench::{self, BenchOpts};
            let mut opts = if cli.smoke { BenchOpts::smoke() } else { BenchOpts::default() };
            if let Some(n) = cli.iters {
                opts.iters = n;
            }
            opts.threads = cli.threads;
            let report = bench::run_bench(cfg, &opts);
            print!("{}", report.render());
            if let Some(path) = &cli.report {
                match bench::write_report(&report, path) {
                    Ok(()) => println!(
                        "wrote {path} (json, {} workloads, round-trip OK)",
                        report.workloads.len()
                    ),
                    Err(e) => {
                        eprintln!("bench report export failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
            // History appends before the baseline gate, so a regressed
            // run still lands in the trajectory.
            if let Some(path) = &cli.history {
                match bench::append_history(&report, path) {
                    Ok(()) => println!("appended history row to {path}"),
                    Err(e) => {
                        eprintln!("bench history append failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
            if let Some(path) = &cli.compare {
                let baseline = std::fs::read_to_string(path).unwrap_or_else(|e| {
                    eprintln!("reading baseline {path}: {e}");
                    std::process::exit(1);
                });
                match bench::compare_to_baseline(&report, &baseline) {
                    Ok(regressions) if regressions.is_empty() => {
                        println!("baseline check OK vs {path}");
                    }
                    Ok(regressions) => {
                        for r in &regressions {
                            eprintln!("bench REGRESSION: {r}");
                        }
                        std::process::exit(1);
                    }
                    Err(e) => {
                        eprintln!("baseline compare failed: {e:#}");
                        std::process::exit(1);
                    }
                }
            }
        }
        "trace" => {
            let r = exp::run_deployment(cfg, cli.deployment);
            println!(
                "{}: {} jobs, avg JRT {:.0}s, makespan {:.0}s, machine ${:.2}, transfer ${:.2}",
                r.mode.name(),
                cfg.workload.num_jobs,
                r.avg_jrt,
                r.makespan,
                r.machine_usd,
                r.transfer_usd
            );
        }
        "all" => {
            print!("{}", exp::fig2_wan(cfg));
            print!("{}", exp::fig3_table());
            print!("{}", exp::fig7_table());
            let (report, results) = exp::fig8_performance(cfg);
            print!("{report}");
            print!("{}", exp::fig10_cost(&results));
            let (r9, _) = exp::fig9_stealing(cfg);
            print!("{r9}");
            print!("{}", exp::fig11_recovery(cfg));
            print!("{}", exp::fig12_overhead(cfg));
            let (t1, _) = exp::theorem1_bound(cfg);
            print!("{t1}");
        }
        _ => usage(),
    }
}
