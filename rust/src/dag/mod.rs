//! DAG job model (§4.1 / Appendix A).
//!
//! A job is a DAG of *stages*; each stage is a set of tasks performing the
//! same computation over different partitions, so tasks within a stage
//! share characteristics. Each task `t_ij` carries a peak requirement
//! `r ∈ (θ, 1]` (normalized to container capacity), a processing time `p`,
//! its input size and a locality preference (the node/DC holding its
//! input). Only *available* stages' task information is known to the
//! schedulers — the semi-clairvoyant model — which [`JobProgress`]
//! enforces: a stage's tasks are released exactly when all parent stages
//! complete.

use std::collections::HashMap;

use crate::ids::{DcId, JobId, NodeId, StageId, TaskId};

/// Workload family (§6.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkloadKind {
    WordCount,
    TpcH,
    IterativeMl,
    PageRank,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 4] =
        [WorkloadKind::WordCount, WorkloadKind::TpcH, WorkloadKind::IterativeMl, WorkloadKind::PageRank];

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::WordCount => "WordCount",
            WorkloadKind::TpcH => "TPC-H",
            WorkloadKind::IterativeMl => "IterativeML",
            WorkloadKind::PageRank => "PageRank",
        }
    }
}

/// Input size class (Fig 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SizeClass {
    Small,
    Medium,
    Large,
}

impl SizeClass {
    pub fn name(&self) -> &'static str {
        match self {
            SizeClass::Small => "small",
            SizeClass::Medium => "medium",
            SizeClass::Large => "large",
        }
    }
}

/// Static description of one task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub id: TaskId,
    /// Peak resource requirement, normalized to container capacity.
    pub r: f64,
    /// Processing time in seconds (on its preferred placement).
    pub p: f64,
    /// Bytes this task reads.
    pub input_bytes: u64,
    /// Bytes this task writes (consumed by child stages).
    pub output_bytes: u64,
    /// Node whose local storage holds the input (None for shuffle reads —
    /// resolved from the partitionList when the stage is released).
    pub pref_node: Option<NodeId>,
    /// DC where the input (or most of it) lives.
    pub pref_dc: DcId,
}

/// Static description of one stage.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub id: StageId,
    pub parents: Vec<StageId>,
    pub tasks: Vec<TaskSpec>,
}

/// Static description of a job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    pub kind: WorkloadKind,
    pub size: SizeClass,
    /// DC the user submits to (the pJM's home).
    pub home_dc: DcId,
    pub stages: Vec<StageSpec>,
}

impl JobSpec {
    /// Total work T₁(J) = Σ r·p over all tasks (Appendix A).
    pub fn work(&self) -> f64 {
        self.stages
            .iter()
            .flat_map(|s| &s.tasks)
            .map(|t| t.r * t.p)
            .sum()
    }

    /// Critical-path length T∞: the longest chain of per-stage maximum
    /// processing times (a lower bound on completion with infinite
    /// containers).
    pub fn critical_path(&self) -> f64 {
        let mut memo: HashMap<StageId, f64> = HashMap::new();
        fn depth(s: StageId, spec: &JobSpec, memo: &mut HashMap<StageId, f64>) -> f64 {
            if let Some(&d) = memo.get(&s) {
                return d;
            }
            let stage = spec.stage(s);
            let own = stage.tasks.iter().map(|t| t.p).fold(0.0, f64::max);
            let parent = stage
                .parents
                .iter()
                .map(|&p| depth(p, spec, memo))
                .fold(0.0, f64::max);
            let d = own + parent;
            memo.insert(s, d);
            d
        }
        self.stages
            .iter()
            .map(|s| depth(s.id, self, &mut memo))
            .fold(0.0, f64::max)
    }

    pub fn num_tasks(&self) -> usize {
        self.stages.iter().map(|s| s.tasks.len()).sum()
    }

    pub fn stage(&self, id: StageId) -> &StageSpec {
        &self.stages[id.0 as usize]
    }

    /// Structural validation: ids dense, DAG acyclic (parents must have
    /// smaller ids — generators emit topo order), tasks well-formed.
    pub fn validate(&self, theta: f64) -> Result<(), String> {
        for (i, s) in self.stages.iter().enumerate() {
            if s.id.0 as usize != i {
                return Err(format!("stage id {} at index {i}", s.id));
            }
            for p in &s.parents {
                if p.0 >= s.id.0 {
                    return Err(format!("stage {} has non-topological parent {}", s.id, p));
                }
            }
            if s.tasks.is_empty() {
                return Err(format!("stage {} has no tasks", s.id));
            }
            for t in &s.tasks {
                if t.id.job != self.id || t.id.stage != s.id {
                    return Err(format!("task {} mislabeled", t.id));
                }
                if !(t.r > 0.0 && t.r <= 1.0) {
                    return Err(format!("task {} r={} out of (0,1]", t.id, t.r));
                }
                if t.r < theta {
                    return Err(format!("task {} r={} below theta={theta}", t.id, t.r));
                }
                if t.p <= 0.0 {
                    return Err(format!("task {} has p={}", t.id, t.p));
                }
            }
        }
        Ok(())
    }
}

/// Runtime status of a task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskStatus {
    /// Parent stages incomplete — not yet visible to schedulers.
    Unreleased,
    /// Released, waiting for assignment.
    Waiting,
    Running,
    Done,
}

/// Runtime progress of one job: which stages are released/complete and the
/// status of every task. This is the semi-clairvoyance gate: schedulers may
/// only query *released* tasks.
#[derive(Debug)]
pub struct JobProgress {
    pub job: JobId,
    status: Vec<Vec<TaskStatus>>,
    remaining: Vec<usize>,
    released: Vec<bool>,
    pub done_tasks: usize,
    pub total_tasks: usize,
}

impl JobProgress {
    pub fn new(spec: &JobSpec) -> JobProgress {
        let status: Vec<Vec<TaskStatus>> = spec
            .stages
            .iter()
            .map(|s| vec![TaskStatus::Unreleased; s.tasks.len()])
            .collect();
        let remaining = spec.stages.iter().map(|s| s.tasks.len()).collect();
        JobProgress {
            job: spec.id,
            status,
            remaining,
            released: vec![false; spec.stages.len()],
            done_tasks: 0,
            total_tasks: spec.num_tasks(),
        }
    }

    pub fn task_status(&self, t: TaskId) -> TaskStatus {
        self.status[t.stage.0 as usize][t.index as usize]
    }

    pub fn stage_released(&self, s: StageId) -> bool {
        self.released[s.0 as usize]
    }

    pub fn stage_done(&self, s: StageId) -> bool {
        self.remaining[s.0 as usize] == 0
    }

    pub fn job_done(&self) -> bool {
        self.done_tasks == self.total_tasks
    }

    /// Release every stage whose parents are all complete (and that isn't
    /// already released). Returns the newly released stage ids, in order.
    pub fn release_ready_stages(&mut self, spec: &JobSpec) -> Vec<StageId> {
        let mut fresh = Vec::new();
        for s in &spec.stages {
            if self.released[s.id.0 as usize] {
                continue;
            }
            if s.parents.iter().all(|&p| self.stage_done(p)) {
                self.released[s.id.0 as usize] = true;
                for st in &mut self.status[s.id.0 as usize] {
                    *st = TaskStatus::Waiting;
                }
                fresh.push(s.id);
            }
        }
        fresh
    }

    pub fn mark_running(&mut self, t: TaskId) {
        let st = &mut self.status[t.stage.0 as usize][t.index as usize];
        assert_eq!(*st, TaskStatus::Waiting, "task {t} not waiting");
        *st = TaskStatus::Running;
    }

    /// Task failed (container death) — goes back to waiting.
    pub fn mark_waiting(&mut self, t: TaskId) {
        let st = &mut self.status[t.stage.0 as usize][t.index as usize];
        assert_eq!(*st, TaskStatus::Running, "task {t} not running");
        *st = TaskStatus::Waiting;
    }

    /// Task completed. Returns true if this completed its stage.
    pub fn mark_done(&mut self, t: TaskId) -> bool {
        let st = &mut self.status[t.stage.0 as usize][t.index as usize];
        assert_eq!(*st, TaskStatus::Running, "task {t} not running");
        *st = TaskStatus::Done;
        self.done_tasks += 1;
        let rem = &mut self.remaining[t.stage.0 as usize];
        *rem -= 1;
        *rem == 0
    }

    /// Count of tasks in a given status (for reporting).
    pub fn count(&self, wanted: TaskStatus) -> usize {
        self.status
            .iter()
            .flat_map(|v| v.iter())
            .filter(|&&s| s == wanted)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, UsizeIn, VecOf};

    /// A diamond DAG: s0 -> {s1, s2} -> s3, two tasks per stage.
    fn diamond() -> JobSpec {
        let job = JobId(1);
        let mk_stage = |sid: u32, parents: Vec<u32>| StageSpec {
            id: StageId(sid),
            parents: parents.into_iter().map(StageId).collect(),
            tasks: (0..2)
                .map(|i| TaskSpec {
                    id: TaskId { job, stage: StageId(sid), index: i },
                    r: 0.5,
                    p: 10.0,
                    input_bytes: 1 << 20,
                    output_bytes: 1 << 18,
                    pref_node: Some(NodeId { dc: DcId(0), idx: 0 }),
                    pref_dc: DcId(0),
                })
                .collect(),
        };
        JobSpec {
            id: job,
            kind: WorkloadKind::WordCount,
            size: SizeClass::Small,
            home_dc: DcId(0),
            stages: vec![mk_stage(0, vec![]), mk_stage(1, vec![0]), mk_stage(2, vec![0]), mk_stage(3, vec![1, 2])],
        }
    }

    #[test]
    fn work_and_critical_path() {
        let j = diamond();
        assert!((j.work() - 8.0 * 0.5 * 10.0).abs() < 1e-9);
        // 3 stages deep, 10 s each.
        assert!((j.critical_path() - 30.0).abs() < 1e-9);
        assert_eq!(j.num_tasks(), 8);
        j.validate(0.05).unwrap();
    }

    #[test]
    fn validate_catches_bad_specs() {
        let mut j = diamond();
        j.stages[1].parents = vec![StageId(3)];
        assert!(j.validate(0.05).is_err(), "non-topological parent");

        let mut j = diamond();
        j.stages[0].tasks[0].r = 0.0;
        assert!(j.validate(0.05).is_err(), "zero r");

        let mut j = diamond();
        j.stages[0].tasks[0].r = 0.01;
        assert!(j.validate(0.05).is_err(), "below theta");

        let mut j = diamond();
        j.stages[0].tasks[0].p = -1.0;
        assert!(j.validate(0.05).is_err(), "negative p");
    }

    #[test]
    fn stages_release_in_dependency_order() {
        let j = diamond();
        let mut prog = JobProgress::new(&j);
        assert_eq!(prog.release_ready_stages(&j), vec![StageId(0)]);
        assert!(prog.release_ready_stages(&j).is_empty(), "no double release");
        assert_eq!(prog.task_status(j.stages[1].tasks[0].id), TaskStatus::Unreleased);

        // Finish stage 0 -> releases 1 and 2, not 3.
        for t in &j.stages[0].tasks {
            prog.mark_running(t.id);
            prog.mark_done(t.id);
        }
        assert_eq!(prog.release_ready_stages(&j), vec![StageId(1), StageId(2)]);

        for t in j.stages[1].tasks.iter().chain(&j.stages[2].tasks) {
            prog.mark_running(t.id);
            prog.mark_done(t.id);
        }
        assert_eq!(prog.release_ready_stages(&j), vec![StageId(3)]);
        for t in &j.stages[3].tasks {
            prog.mark_running(t.id);
            assert!(!prog.job_done());
            prog.mark_done(t.id);
        }
        assert!(prog.job_done());
        assert_eq!(prog.done_tasks, 8);
    }

    #[test]
    fn failed_task_returns_to_waiting() {
        let j = diamond();
        let mut prog = JobProgress::new(&j);
        prog.release_ready_stages(&j);
        let t = j.stages[0].tasks[0].id;
        prog.mark_running(t);
        prog.mark_waiting(t); // container died
        assert_eq!(prog.task_status(t), TaskStatus::Waiting);
        prog.mark_running(t);
        prog.mark_done(t);
        assert_eq!(prog.task_status(t), TaskStatus::Done);
    }

    #[test]
    #[should_panic(expected = "not waiting")]
    fn cannot_run_unreleased_task() {
        let j = diamond();
        let mut prog = JobProgress::new(&j);
        prog.mark_running(j.stages[3].tasks[0].id);
    }

    /// Property: for random chain DAGs, counts are conserved and release
    /// order respects dependencies whatever the completion order.
    #[test]
    fn prop_task_conservation_over_random_chains() {
        // Generate a random chain of stage widths, drive to completion in a
        // seeded-random order, check invariants throughout.
        let gen = VecOf { elem: UsizeIn(1, 6), min_len: 1, max_len: 8 };
        forall(0xDA6, &gen, |widths: &Vec<usize>| {
            let job = JobId(9);
            let stages: Vec<StageSpec> = widths
                .iter()
                .enumerate()
                .map(|(i, &w)| StageSpec {
                    id: StageId(i as u32),
                    parents: if i == 0 { vec![] } else { vec![StageId(i as u32 - 1)] },
                    tasks: (0..w as u32)
                        .map(|k| TaskSpec {
                            id: TaskId { job, stage: StageId(i as u32), index: k },
                            r: 0.5,
                            p: 1.0,
                            input_bytes: 1,
                            output_bytes: 1,
                            pref_node: None,
                            pref_dc: DcId(0),
                        })
                        .collect(),
                })
                .collect();
            let spec = JobSpec {
                id: job,
                kind: WorkloadKind::PageRank,
                size: SizeClass::Small,
                home_dc: DcId(0),
                stages,
            };
            spec.validate(0.05).map_err(|e| e)?;
            let mut prog = JobProgress::new(&spec);
            let mut released_total = 0;
            loop {
                let fresh = prog.release_ready_stages(&spec);
                released_total += fresh.len();
                let waiting: Vec<TaskId> = spec
                    .stages
                    .iter()
                    .flat_map(|s| &s.tasks)
                    .filter(|t| prog.task_status(t.id) == TaskStatus::Waiting)
                    .map(|t| t.id)
                    .collect();
                if waiting.is_empty() {
                    break;
                }
                for t in waiting {
                    prog.mark_running(t);
                    prog.mark_done(t);
                }
                crate::prop_assert!(
                    prog.count(TaskStatus::Done) == prog.done_tasks,
                    "done count mismatch"
                );
            }
            crate::prop_assert!(prog.job_done(), "job should complete");
            crate::prop_assert!(released_total == widths.len(), "all stages released once");
            Ok(())
        });
    }
}
