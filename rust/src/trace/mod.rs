//! Typed trace bus: a flight recorder for everything the testbed does.
//!
//! HOUTU's evaluation and its reliability claims all reduce to *what
//! happened when* — task launches, steals, elections, recoveries, WAN
//! transfers. Instead of scattering per-figure bookkeeping pushes across
//! the deployment layer, every emission site publishes one typed
//! [`TraceEvent`] through the [`Tracer`] handle stored on the world;
//! downstream consumers (the figure [`crate::metrics::Metrics`], the
//! replay digest, streaming invariant checkers, ring-buffer forensics)
//! are all [`TraceSink`]s folding the same stream.
//!
//! # Event taxonomy
//!
//! * **Job/task lifecycle** — `JobSubmitted`, `StageReleased`,
//!   `TaskLaunched`, `TaskFinished`, `TaskRequeued`,
//!   `SpeculativeRelaunch`, `JobCompleted`, `JobRestarted`.
//! * **Containers & masters** — `ContainerCount` (the Fig-11 quantity),
//!   `ContainersGranted` (period-boundary water-filling),
//!   `ContainersReturned` (Af surplus release).
//! * **JM replicas** — `JmSpawned`, `JmFailed`, `JmRecovered`,
//!   `ElectionWon` (§3.2.2 failure handling).
//! * **Work stealing** — `StealRequested` (thief turns), `StealGranted`
//!   (victim leaks tasks), `StealCompleted` (round trip done; Fig 12b).
//! * **Replication & WAN** — `InfoReplicated` (Fig 12a sizes),
//!   `WanMessage` / `WanTransfer` (control vs bulk traffic).
//! * **Cloud & chaos** — `SpotRevoked`, `NodeKilled`, `NodeRestarted`,
//!   `RunBilled`, `ChaosInjected` (scenario-engine injections).
//! * **Cost-aware bidding** — `BidPlaced` (a strategy's class + bid
//!   decision at VM acquisition), `InsuranceLaunched` (PingAn-style
//!   duplicate on a risky spot container), `CostCharged` (a job's
//!   accumulated [`crate::cloud::CostMeter`] total at completion).
//!   Published only when the bidding subsystem is active
//!   (`BiddingConfig::active`), so the naive baseline's event stream —
//!   and therefore every pre-subsystem replay digest — stays
//!   bit-identical.
//!
//! # Ordering guarantees
//!
//! Every published event carries a `(SimTime, seq)` stamp. `seq` is a
//! per-run monotone counter, so stamps are strictly increasing in
//! publication order; `time` is the virtual time of the simulation event
//! being executed (the sim's step hook advances the tracer clock *before*
//! each event closure runs) and is therefore non-decreasing. Same
//! (config, seed) ⇒ byte-identical stream, which is what makes the
//! trace-folded digest a replay check that sees *order*, not just end
//! state.
//!
//! # Sink contract
//!
//! A [`TraceSink`] observes each stamped event exactly once, in
//! publication order, synchronously with the emission. Sinks must be
//! cheap (they run on the hot path of every emission), must not publish
//! events themselves (the bus is borrowed during dispatch; re-entrant
//! publication panics), and must not assume they see the whole run —
//! they may be attached mid-flight. The built-in digest fold and step
//! counter live on the bus itself and cannot be detached.

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;

use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::{DcId, JmId, JobId, NodeId, StageId, TaskId};
use crate::sim::{SimTime, StepClock};

/// One thing that happened in the simulated testbed.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A job entered the system (release time, §4.1).
    JobSubmitted { job: JobId, kind: WorkloadKind, size: SizeClass, tasks: usize },
    /// All stages complete; JMs release their resources (§3.2.1).
    JobCompleted { job: JobId },
    /// Centralized baseline resubmission — all progress lost (§6.4).
    JobRestarted { job: JobId },
    /// The pJM released a stage whose parents completed.
    StageReleased { job: JobId, stage: StageId, tasks: usize },
    /// A task attempt started on a container in `dc`. `locality` is the
    /// Parades placement decision (`node-local`/`rack-local`/`any`, or
    /// `stolen` for cross-DC work stealing).
    TaskLaunched { job: JobId, task: TaskId, dc: DcId, locality: &'static str, remote_input: bool },
    /// A task attempt completed (post attempt/generation validation).
    TaskFinished { job: JobId, task: TaskId, dc: DcId },
    /// A running task lost its container and went back to Waiting.
    TaskRequeued { job: JobId, task: TaskId, dc: DcId },
    /// Straggler mitigation aborted and re-queued a running task (§7).
    SpeculativeRelaunch { job: JobId, task: TaskId, dc: DcId },
    /// Containers belonging to a job changed (the Fig-11 timeline).
    ContainerCount { job: JobId, count: usize },
    /// Period-boundary grants from a master to a sub-job.
    ContainersGranted { jm: JmId, count: usize },
    /// Af surplus: a sub-job proactively returned idle containers.
    ContainersReturned { jm: JmId, count: usize },
    /// A JM replica came up (step 2/2b).
    JmSpawned { job: JobId, dc: DcId, primary: bool },
    /// A JM replica's container died (detection happens later).
    JmFailed { job: JobId, dc: DcId },
    /// A replacement JM is operational; `interval_secs` is the Fig-11
    /// failure interval (VM kill → successor operating).
    JmRecovered { job: JobId, dc: DcId, interval_secs: f64 },
    /// A new primary won the Zookeeper election (§3.2.2).
    ElectionWon { job: JobId, new_primary: DcId, delay_secs: f64 },
    /// An idle JM turned thief and offered a container (Algorithm 2).
    StealRequested { job: JobId, thief: DcId, victim: DcId },
    /// The victim leaked long-waiting tasks to the thief.
    StealGranted { job: JobId, victim: DcId, thief: DcId, tasks: usize },
    /// The steal round trip finished at the thief (Fig 12b delay).
    StealCompleted { job: JobId, thief: DcId, victim: DcId, tasks: usize, delay_ms: f64 },
    /// Intermediate info re-encoded and pushed through zk (Fig 12a).
    InfoReplicated { job: JobId, kind: WorkloadKind, bytes: usize },
    /// A small control message crossed the fabric.
    WanMessage { from: DcId, to: DcId, bytes: u64 },
    /// A bulk data transfer began on a (src, dst) pair.
    WanTransfer { from: DcId, to: DcId, bytes: u64 },
    /// The market out-priced an instance's bid (§2.3 revocation).
    SpotRevoked { node: NodeId, price: f64, bid: f64 },
    /// A worker VM died (revocation or injected termination).
    NodeKilled { node: NodeId, containers: usize, tasks: usize },
    /// A replacement instance came back with fresh containers.
    NodeRestarted { node: NodeId },
    /// End-of-run billing (§6.3 model).
    RunBilled { machine_usd: f64, transfer_usd: f64 },
    /// The scenario engine injected a chaos event (its DSL rendering).
    ChaosInjected { label: String },
    /// A bid strategy decided the class (+ standing bid) of a worker VM
    /// at (re-)acquisition. `bid` is 0 for on-demand decisions.
    BidPlaced { node: NodeId, on_demand: bool, bid: f64 },
    /// A duplicate insurance copy launched for a task running on a
    /// high-revocation-risk spot container (first commit wins).
    InsuranceLaunched { job: JobId, task: TaskId, dc: DcId },
    /// A job completed with this accumulated per-job cost (machine
    /// occupancy + cross-DC transfer attribution).
    CostCharged { job: JobId, usd: f64 },
}

impl TraceEvent {
    /// Compact kebab-case tag, for counting sinks and reports.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::JobSubmitted { .. } => "job-submitted",
            TraceEvent::JobCompleted { .. } => "job-completed",
            TraceEvent::JobRestarted { .. } => "job-restarted",
            TraceEvent::StageReleased { .. } => "stage-released",
            TraceEvent::TaskLaunched { .. } => "task-launched",
            TraceEvent::TaskFinished { .. } => "task-finished",
            TraceEvent::TaskRequeued { .. } => "task-requeued",
            TraceEvent::SpeculativeRelaunch { .. } => "speculative-relaunch",
            TraceEvent::ContainerCount { .. } => "container-count",
            TraceEvent::ContainersGranted { .. } => "containers-granted",
            TraceEvent::ContainersReturned { .. } => "containers-returned",
            TraceEvent::JmSpawned { .. } => "jm-spawned",
            TraceEvent::JmFailed { .. } => "jm-failed",
            TraceEvent::JmRecovered { .. } => "jm-recovered",
            TraceEvent::ElectionWon { .. } => "election-won",
            TraceEvent::StealRequested { .. } => "steal-requested",
            TraceEvent::StealGranted { .. } => "steal-granted",
            TraceEvent::StealCompleted { .. } => "steal-completed",
            TraceEvent::InfoReplicated { .. } => "info-replicated",
            TraceEvent::WanMessage { .. } => "wan-message",
            TraceEvent::WanTransfer { .. } => "wan-transfer",
            TraceEvent::SpotRevoked { .. } => "spot-revoked",
            TraceEvent::NodeKilled { .. } => "node-killed",
            TraceEvent::NodeRestarted { .. } => "node-restarted",
            TraceEvent::RunBilled { .. } => "run-billed",
            TraceEvent::ChaosInjected { .. } => "chaos-injected",
            TraceEvent::BidPlaced { .. } => "bid-placed",
            TraceEvent::InsuranceLaunched { .. } => "insurance-launched",
            TraceEvent::CostCharged { .. } => "cost-charged",
        }
    }

    /// Fold the full payload into an FNV accumulator (order-sensitive
    /// replay digests are built from this).
    pub fn fold(&self, h: &mut Fnv64) {
        h.bytes(self.kind().as_bytes());
        match self {
            TraceEvent::JobSubmitted { job, kind, size, tasks } => {
                h.u64(job.0);
                h.bytes(kind.name().as_bytes());
                h.bytes(size.name().as_bytes());
                h.u64(*tasks as u64);
            }
            TraceEvent::JobCompleted { job }
            | TraceEvent::JobRestarted { job } => h.u64(job.0),
            TraceEvent::StageReleased { job, stage, tasks } => {
                h.u64(job.0);
                h.u64(stage.0 as u64);
                h.u64(*tasks as u64);
            }
            TraceEvent::TaskLaunched { job, task, dc, locality, remote_input } => {
                h.u64(job.0);
                fold_task(h, task);
                h.u64(dc.0 as u64);
                h.bytes(locality.as_bytes());
                h.u64(*remote_input as u64);
            }
            TraceEvent::TaskFinished { job, task, dc }
            | TraceEvent::TaskRequeued { job, task, dc }
            | TraceEvent::SpeculativeRelaunch { job, task, dc } => {
                h.u64(job.0);
                fold_task(h, task);
                h.u64(dc.0 as u64);
            }
            TraceEvent::ContainerCount { job, count } => {
                h.u64(job.0);
                h.u64(*count as u64);
            }
            TraceEvent::ContainersGranted { jm, count }
            | TraceEvent::ContainersReturned { jm, count } => {
                h.u64(jm.job.0);
                h.u64(jm.dc.0 as u64);
                h.u64(*count as u64);
            }
            TraceEvent::JmSpawned { job, dc, primary } => {
                h.u64(job.0);
                h.u64(dc.0 as u64);
                h.u64(*primary as u64);
            }
            TraceEvent::JmFailed { job, dc } => {
                h.u64(job.0);
                h.u64(dc.0 as u64);
            }
            TraceEvent::JmRecovered { job, dc, interval_secs } => {
                h.u64(job.0);
                h.u64(dc.0 as u64);
                h.u64(interval_secs.to_bits());
            }
            TraceEvent::ElectionWon { job, new_primary, delay_secs } => {
                h.u64(job.0);
                h.u64(new_primary.0 as u64);
                h.u64(delay_secs.to_bits());
            }
            TraceEvent::StealRequested { job, thief, victim } => {
                h.u64(job.0);
                h.u64(thief.0 as u64);
                h.u64(victim.0 as u64);
            }
            TraceEvent::StealGranted { job, victim, thief, tasks } => {
                h.u64(job.0);
                h.u64(victim.0 as u64);
                h.u64(thief.0 as u64);
                h.u64(*tasks as u64);
            }
            TraceEvent::StealCompleted { job, thief, victim, tasks, delay_ms } => {
                h.u64(job.0);
                h.u64(thief.0 as u64);
                h.u64(victim.0 as u64);
                h.u64(*tasks as u64);
                h.u64(delay_ms.to_bits());
            }
            TraceEvent::InfoReplicated { job, kind, bytes } => {
                h.u64(job.0);
                h.bytes(kind.name().as_bytes());
                h.u64(*bytes as u64);
            }
            TraceEvent::WanMessage { from, to, bytes }
            | TraceEvent::WanTransfer { from, to, bytes } => {
                h.u64(from.0 as u64);
                h.u64(to.0 as u64);
                h.u64(*bytes);
            }
            TraceEvent::SpotRevoked { node, price, bid } => {
                fold_node(h, node);
                h.u64(price.to_bits());
                h.u64(bid.to_bits());
            }
            TraceEvent::NodeKilled { node, containers, tasks } => {
                fold_node(h, node);
                h.u64(*containers as u64);
                h.u64(*tasks as u64);
            }
            TraceEvent::NodeRestarted { node } => fold_node(h, node),
            TraceEvent::RunBilled { machine_usd, transfer_usd } => {
                h.u64(machine_usd.to_bits());
                h.u64(transfer_usd.to_bits());
            }
            TraceEvent::ChaosInjected { label } => h.bytes(label.as_bytes()),
            TraceEvent::BidPlaced { node, on_demand, bid } => {
                fold_node(h, node);
                h.u64(*on_demand as u64);
                h.u64(bid.to_bits());
            }
            TraceEvent::InsuranceLaunched { job, task, dc } => {
                h.u64(job.0);
                fold_task(h, task);
                h.u64(dc.0 as u64);
            }
            TraceEvent::CostCharged { job, usd } => {
                h.u64(job.0);
                h.u64(usd.to_bits());
            }
        }
    }
}

fn fold_task(h: &mut Fnv64, t: &TaskId) {
    h.u64(t.job.0);
    h.u64(t.stage.0 as u64);
    h.u64(t.index as u64);
}

fn fold_node(h: &mut Fnv64, n: &NodeId) {
    h.u64(n.dc.0 as u64);
    h.u64(n.idx as u64);
}

/// A published event with its `(SimTime, seq)` stamp.
#[derive(Debug, Clone, PartialEq)]
pub struct Stamped {
    /// Virtual time (ms) of the simulation event that emitted this.
    pub time: SimTime,
    /// Per-run monotone publication counter.
    pub seq: u64,
    pub event: TraceEvent,
}

impl Stamped {
    /// Stamp + payload fold (what the bus digest accumulates per event).
    pub fn fold(&self, h: &mut Fnv64) {
        h.u64(self.time);
        h.u64(self.seq);
        self.event.fold(h);
    }
}

/// A consumer of the stream. See the module docs for the contract.
pub trait TraceSink {
    fn on_event(&mut self, ev: &Stamped);
}

/// FNV-1a accumulator shared by the trace digest and campaign digests.
#[derive(Debug, Clone, Copy)]
pub struct Fnv64(pub u64);

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

impl Fnv64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }
}

/// Combine per-part `(tracer steps, part digest)` pairs, in part order,
/// into one order-sensitive digest — the parts-engine analogue of how
/// [`Tracer::digest`] mixes its step clock into the stream fold. Used by
/// [`crate::deploy::parts`] to pin cells across thread counts.
pub fn fold_part_digests<I: IntoIterator<Item = (u64, u64)>>(parts: I) -> u64 {
    let mut h = Fnv64::new();
    for (steps, digest) in parts {
        h.u64(steps);
        h.u64(digest);
    }
    h.0
}

/// Bounded history of the most recent events (flight-recorder memory).
#[derive(Debug)]
pub struct RingBuffer {
    cap: usize,
    buf: VecDeque<Stamped>,
    /// Total events ever pushed (≥ `len()` once the ring wraps).
    pub pushed: u64,
}

impl RingBuffer {
    pub fn new(cap: usize) -> RingBuffer {
        RingBuffer { cap: cap.max(1), buf: VecDeque::new(), pushed: 0 }
    }

    /// A shareable ring: attach `RingSink(handle.clone())` to a tracer and
    /// read the captured events from `handle` after the run.
    pub fn shared(cap: usize) -> Rc<RefCell<RingBuffer>> {
        Rc::new(RefCell::new(RingBuffer::new(cap)))
    }

    pub fn push(&mut self, ev: Stamped) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(ev);
        self.pushed += 1;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Oldest-to-newest iteration over the retained window.
    pub fn iter(&self) -> impl Iterator<Item = &Stamped> {
        self.buf.iter()
    }
}

/// [`TraceSink`] adapter writing into a shared [`RingBuffer`].
pub struct RingSink(pub Rc<RefCell<RingBuffer>>);

impl TraceSink for RingSink {
    fn on_event(&mut self, ev: &Stamped) {
        self.0.borrow_mut().push(ev.clone());
    }
}

/// [`TraceSink`] counting events per kind (cheap campaign telemetry).
#[derive(Default)]
pub struct CountingSink(pub Rc<RefCell<BTreeMap<&'static str, u64>>>);

impl CountingSink {
    pub fn shared() -> (CountingSink, Rc<RefCell<BTreeMap<&'static str, u64>>>) {
        let counts: Rc<RefCell<BTreeMap<&'static str, u64>>> = Rc::default();
        (CountingSink(counts.clone()), counts)
    }
}

impl TraceSink for CountingSink {
    fn on_event(&mut self, ev: &Stamped) {
        *self.0.borrow_mut().entry(ev.event.kind()).or_insert(0) += 1;
    }
}

struct Core {
    next_seq: u64,
    digest: Fnv64,
    sinks: Vec<Box<dyn TraceSink>>,
}

/// The bus handle. Cheap to clone; every clone publishes into the same
/// per-run stream (the world holds one, the WAN fabric holds another).
///
/// The stamp clock lives in a shared [`StepClock`] (plain `Cell`s) that
/// the sim advances *inline* on every step — see
/// [`crate::sim::Sim::attach_clock`]. The tracer reads it lazily when an
/// event is actually published, so a sim step that publishes nothing
/// costs the bus no dynamic dispatch and no `RefCell` borrow (it used to
/// pay a boxed step-hook call per event just to move this clock).
#[derive(Clone)]
pub struct Tracer {
    core: Rc<RefCell<Core>>,
    clock: Rc<StepClock>,
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer {
            core: Rc::new(RefCell::new(Core {
                next_seq: 0,
                digest: Fnv64::new(),
                sinks: Vec::new(),
            })),
            clock: Rc::new(StepClock::default()),
        }
    }

    /// The shared step clock — hand it to [`crate::sim::Sim::attach_clock`]
    /// so the sim advances it inline instead of through a boxed hook.
    pub fn clock(&self) -> Rc<StepClock> {
        self.clock.clone()
    }

    /// Advance the stamp clock to an executing event's time and count the
    /// step. The sim normally does this inline through the attached
    /// [`StepClock`]; this method remains for unit tests and hand-driven
    /// streams.
    pub fn on_step(&self, now: SimTime) {
        self.clock.advance(now);
    }

    /// Current stamp clock (virtual ms).
    pub fn now(&self) -> SimTime {
        self.clock.now()
    }

    /// Publish one event: stamp it, fold it into the run digest, hand it
    /// to every attached sink, and return the stamped copy so the caller
    /// can feed owned consumers (the world feeds [`crate::metrics::Metrics`]).
    pub fn publish(&self, event: TraceEvent) -> Stamped {
        let mut c = self.core.borrow_mut();
        let stamped = Stamped { time: self.clock.now(), seq: c.next_seq, event };
        c.next_seq += 1;
        stamped.fold(&mut c.digest);
        for sink in c.sinks.iter_mut() {
            sink.on_event(&stamped);
        }
        stamped
    }

    /// Attach a sink; it observes every event published from now on.
    pub fn attach(&self, sink: Box<dyn TraceSink>) {
        self.core.borrow_mut().sinks.push(sink);
    }

    /// Order-sensitive digest of everything published so far, with the
    /// event and step counts mixed in — same (config, seed) ⇒ same value.
    pub fn digest(&self) -> u64 {
        let c = self.core.borrow();
        let mut h = c.digest;
        h.u64(c.next_seq);
        h.u64(self.clock.steps());
        h.0
    }

    /// Events published so far.
    pub fn events_published(&self) -> u64 {
        self.core.borrow().next_seq
    }

    /// Sim events executed so far (fed by the inline step clock).
    pub fn steps(&self) -> u64 {
        self.clock.steps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(job: u64) -> TraceEvent {
        TraceEvent::JobCompleted { job: JobId(job) }
    }

    #[test]
    fn stamps_are_strictly_increasing() {
        let t = Tracer::new();
        t.on_step(5);
        let a = t.publish(ev(1));
        let b = t.publish(ev(2));
        t.on_step(9);
        let c = t.publish(ev(3));
        assert_eq!((a.time, a.seq), (5, 0));
        assert_eq!((b.time, b.seq), (5, 1));
        assert_eq!((c.time, c.seq), (9, 2));
        assert_eq!(t.events_published(), 3);
        assert_eq!(t.steps(), 2);
    }

    #[test]
    fn digest_is_order_sensitive() {
        let mk = |first: u64, second: u64| {
            let t = Tracer::new();
            t.on_step(1);
            t.publish(ev(first));
            t.publish(ev(second));
            t.digest()
        };
        assert_eq!(mk(1, 2), mk(1, 2), "same stream replays identically");
        assert_ne!(mk(1, 2), mk(2, 1), "order must change the digest");
    }

    #[test]
    fn digest_covers_time_and_payload() {
        let base = {
            let t = Tracer::new();
            t.on_step(10);
            t.publish(ev(1));
            t.digest()
        };
        let late = {
            let t = Tracer::new();
            t.on_step(11);
            t.publish(ev(1));
            t.digest()
        };
        let other = {
            let t = Tracer::new();
            t.on_step(10);
            t.publish(TraceEvent::JobRestarted { job: JobId(1) });
            t.digest()
        };
        assert_ne!(base, late, "stamp time folds in");
        assert_ne!(base, other, "variant tag folds in");
    }

    #[test]
    fn ring_buffer_keeps_the_tail() {
        let ring = RingBuffer::shared(3);
        let t = Tracer::new();
        t.attach(Box::new(RingSink(ring.clone())));
        t.on_step(1);
        for j in 0..5 {
            t.publish(ev(j));
        }
        let r = ring.borrow();
        assert_eq!(r.len(), 3);
        assert_eq!(r.pushed, 5);
        let jobs: Vec<u64> = r
            .iter()
            .map(|s| match s.event {
                TraceEvent::JobCompleted { job } => job.0,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(jobs, vec![2, 3, 4], "oldest evicted first");
    }

    #[test]
    fn counting_sink_tallies_kinds() {
        let (sink, counts) = CountingSink::shared();
        let t = Tracer::new();
        t.attach(Box::new(sink));
        t.publish(ev(0));
        t.publish(ev(1));
        t.publish(TraceEvent::JobRestarted { job: JobId(0) });
        let c = counts.borrow();
        assert_eq!(c.get("job-completed"), Some(&2));
        assert_eq!(c.get("job-restarted"), Some(&1));
    }

    #[test]
    fn clones_share_one_stream() {
        let t = Tracer::new();
        let t2 = t.clone();
        t.on_step(3);
        let a = t.publish(ev(1));
        let b = t2.publish(ev(2));
        assert_eq!(a.seq, 0);
        assert_eq!(b.seq, 1);
        assert_eq!(t.digest(), t2.digest());
    }

    #[test]
    fn sim_attached_clock_stamps_publishes() {
        // The end-to-end fast path: a Sim advancing the tracer's shared
        // StepClock inline must stamp publishes exactly like the old
        // boxed step hook did.
        let t = Tracer::new();
        let mut sim = crate::sim::Sim::new(Tracer::clone(&t));
        sim.attach_clock(t.clock());
        sim.schedule_at(5, |s| {
            s.state.publish(TraceEvent::JobCompleted { job: JobId(1) });
        });
        sim.schedule_at(9, |s| {
            s.state.publish(TraceEvent::JobCompleted { job: JobId(2) });
        });
        sim.run_to_completion();
        assert_eq!(t.steps(), 2);
        assert_eq!(t.now(), 9);
        assert_eq!(t.events_published(), 2);
    }

    /// Seqs of every event ever pushed through a ring, captured by an
    /// unbounded side sink for comparison.
    struct VecSink(Rc<RefCell<Vec<Stamped>>>);
    impl TraceSink for VecSink {
        fn on_event(&mut self, ev: &Stamped) {
            self.0.borrow_mut().push(ev.clone());
        }
    }

    #[test]
    fn ring_at_exact_capacity_keeps_everything() {
        // Boundary: pushing exactly `cap` events must not evict — the
        // wrap happens on push `cap + 1`, not `cap`.
        let ring = RingBuffer::shared(4);
        let t = Tracer::new();
        t.attach(Box::new(RingSink(ring.clone())));
        for j in 0..4 {
            t.publish(ev(j));
        }
        {
            let r = ring.borrow();
            assert_eq!(r.len(), 4);
            assert_eq!(r.pushed, 4);
            let seqs: Vec<u64> = r.iter().map(|s| s.seq).collect();
            assert_eq!(seqs, vec![0, 1, 2, 3], "no eviction at exact capacity");
        }
        t.publish(ev(4));
        let r = ring.borrow();
        assert_eq!(r.len(), 4, "one past capacity evicts exactly one");
        assert_eq!(r.pushed, 5);
        assert_eq!(r.iter().next().map(|s| s.seq), Some(1), "oldest went first");
    }

    #[test]
    fn ring_seq_continuity_across_many_overwrites() {
        // After wrapping several times the retained window must be a
        // contiguous seq range ending at the last published event — no
        // gaps, no reordering across the wrap point.
        let ring = RingBuffer::shared(3);
        let t = Tracer::new();
        t.attach(Box::new(RingSink(ring.clone())));
        for j in 0..11 {
            t.publish(ev(j));
        }
        let r = ring.borrow();
        let seqs: Vec<u64> = r.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![8, 9, 10], "window = the last cap seqs, in order");
        for w in seqs.windows(2) {
            assert_eq!(w[1], w[0] + 1, "seq continuity across overwrite");
        }
        assert_eq!(r.pushed, 11);
        assert_eq!(r.pushed - r.len() as u64, 8, "exactly the overwritten prefix");
    }

    #[test]
    fn counting_sink_totals_match_an_unbounded_sink() {
        // CountingSink's per-kind tallies must agree with a full
        // unbounded capture of the same stream, even while a small ring
        // on the same bus wraps many times.
        let (csink, counts) = CountingSink::shared();
        let full: Rc<RefCell<Vec<Stamped>>> = Rc::default();
        let ring = RingBuffer::shared(2);
        let t = Tracer::new();
        t.attach(Box::new(csink));
        t.attach(Box::new(VecSink(full.clone())));
        t.attach(Box::new(RingSink(ring.clone())));
        t.on_step(1);
        for j in 0..9 {
            t.publish(ev(j));
            if j % 3 == 0 {
                t.publish(TraceEvent::JobRestarted { job: JobId(j) });
            }
        }
        let full = full.borrow();
        let mut expect: BTreeMap<&'static str, u64> = BTreeMap::new();
        for s in full.iter() {
            *expect.entry(s.event.kind()).or_insert(0) += 1;
        }
        assert_eq!(*counts.borrow(), expect, "tallies must match the full stream");
        let total: u64 = counts.borrow().values().sum();
        assert_eq!(total, full.len() as u64);
        assert_eq!(ring.borrow().pushed, full.len() as u64, "ring saw every event");
        assert_eq!(ring.borrow().len(), 2, "but only retains its window");
    }
}
