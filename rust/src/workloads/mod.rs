//! Workload generators (§6.1): WordCount, TPC-H (Q3-shaped), Iterative ML
//! and PageRank, with the Fig-7 input sizes and the 46/40/14
//! small/medium/large job mix, arriving online with exponential
//! inter-arrival times.
//!
//! DAG shapes:
//! * WordCount — map-per-block → reduce (shuffle).
//! * TPC-H Q3 — scan(customer) ∥ scan(orders) ∥ scan(lineitem) →
//!   join(C⋈O) → join(⋈L) → group-by/agg. Tables are pinned to specific
//!   regions ("two tables per data center").
//! * IterativeML — load → K gradient stages over cached partitions (the
//!   L2 `logreg_grad` artifact computes these numerics in the e2e run) →
//!   model collect.
//! * PageRank — load graph → K damped power-iteration stages (L2
//!   `pagerank_step`) → rank collect.
//!
//! Map tasks prefer the node holding their block; shuffle tasks resolve
//! their preference at release time from the partitionList (handled by the
//! job managers).

use crate::config::{Config, TopologyConfig};
use crate::dag::{JobSpec, SizeClass, StageSpec, TaskSpec, WorkloadKind};
use crate::ids::{DcId, JobId, StageId, TaskId};
use crate::storage::{Dfs, BLOCK_BYTES};
use crate::util::Pcg;

const MB: u64 = 1024 * 1024;
const GB: u64 = 1024 * MB;

/// Fig 7: input bytes per (workload, size class). TPC-H has no "small"
/// class in the paper; callers should upgrade small→medium for TPC-H
/// (`WorkloadGen::sample_class` does).
pub fn input_bytes(kind: WorkloadKind, size: SizeClass) -> u64 {
    use SizeClass::*;
    use WorkloadKind::*;
    match (kind, size) {
        (WordCount, Small) => 200 * MB,
        (WordCount, Medium) => GB,
        (WordCount, Large) => 5 * GB,
        (TpcH, Small) | (TpcH, Medium) => GB,
        (TpcH, Large) => 10 * GB,
        (IterativeMl, Small) => 170 * MB,
        (IterativeMl, Medium) => GB,
        (IterativeMl, Large) => 3 * GB,
        (PageRank, Small) => 150 * MB,
        (PageRank, Medium) => GB,
        (PageRank, Large) => 6 * GB,
    }
}

/// Per-task scan/processing rate (MB/s) by workload — calibrated so job
/// response times land in the paper's tens-to-hundreds-of-seconds range on
/// a 64-container testbed.
fn scan_rate(kind: WorkloadKind) -> f64 {
    match kind {
        WorkloadKind::WordCount => 3.0,
        WorkloadKind::TpcH => 3.6,
        WorkloadKind::IterativeMl => 2.2,
        WorkloadKind::PageRank => 2.6,
    }
}

/// Map-output selectivity (output bytes / input bytes).
fn selectivity(kind: WorkloadKind) -> f64 {
    match kind {
        WorkloadKind::WordCount => 0.30,
        WorkloadKind::TpcH => 0.45,
        WorkloadKind::IterativeMl => 0.03,
        WorkloadKind::PageRank => 0.12,
    }
}

/// Iteration counts for the iterative workloads.
pub const ML_ITERATIONS: usize = 4;
pub const PAGERANK_ITERATIONS: usize = 5;

/// One entry of an online submission trace.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    pub arrival_secs: f64,
    pub kind: WorkloadKind,
    pub size: SizeClass,
    pub home_dc: DcId,
}

/// Generator state (deterministic given its RNG stream).
pub struct WorkloadGen {
    rng: Pcg,
    topo: TopologyConfig,
}

impl WorkloadGen {
    pub fn new(cfg: &Config, rng: Pcg) -> Self {
        WorkloadGen { rng, topo: cfg.topology.clone() }
    }

    /// Dataset name shared by all jobs of a (kind, size).
    pub fn dataset_name(kind: WorkloadKind, size: SizeClass) -> String {
        format!("{}-{}", kind.name(), size.name())
    }

    /// Per-DC placement weights for a workload's input (§6.1: TPC-H pins
    /// two tables per DC; the rest partition evenly).
    fn placement(&self, kind: WorkloadKind) -> Vec<f64> {
        let n = self.topo.num_dcs();
        match kind {
            // Q3's three tables live in specific regions (see tpch_job);
            // the combined dataset weight reflects |lineitem| ≈ 2(|C|+|O|).
            WorkloadKind::TpcH => {
                let mut w = vec![0.0; n];
                w[0] = 1.0; // customer
                w[1 % n] = 1.0; // orders
                w[2 % n] = 2.0; // lineitem (larger)
                w
            }
            _ => vec![1.0; n],
        }
    }

    /// Ensure the shared input dataset exists in the DFS.
    pub fn ensure_dataset(&mut self, dfs: &mut Dfs, kind: WorkloadKind, size: SizeClass) {
        let name = Self::dataset_name(kind, size);
        if dfs.get(&name).is_none() {
            let weights = self.placement(kind);
            dfs.ingest(&name, input_bytes(kind, size), &weights, self.topo.workers_per_dc, &mut self.rng);
        }
    }

    /// Draw a size class from the paper's 46/40/14 mix (TPC-H upgrades
    /// small → medium since Fig 7 defines no small TPC-H input).
    pub fn sample_class(&mut self, mix: &[f64; 3], kind: WorkloadKind) -> SizeClass {
        let c = match self.rng.weighted(&mix[..]) {
            0 => SizeClass::Small,
            1 => SizeClass::Medium,
            _ => SizeClass::Large,
        };
        if kind == WorkloadKind::TpcH && c == SizeClass::Small {
            SizeClass::Medium
        } else {
            c
        }
    }

    /// Build the online submission trace (Fig 8 methodology): `n` jobs,
    /// kinds round-robin over the four workloads, sizes from the mix,
    /// exponential inter-arrivals, homes round-robin over regions.
    pub fn trace(&mut self, cfg: &Config, n: usize) -> Vec<TraceEntry> {
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let kind = WorkloadKind::ALL[i % 4];
            let size = self.sample_class(&cfg.workload.mix, kind);
            out.push(TraceEntry {
                arrival_secs: t,
                kind,
                size,
                home_dc: DcId(i % self.topo.num_dcs()),
            });
            t += self.rng.exp(cfg.workload.mean_interarrival_secs);
        }
        out
    }

    /// Instantiate the DAG for one job. The dataset must already be in the
    /// DFS (call [`WorkloadGen::ensure_dataset`] first).
    pub fn make_job(
        &mut self,
        id: JobId,
        kind: WorkloadKind,
        size: SizeClass,
        home_dc: DcId,
        dfs: &Dfs,
    ) -> JobSpec {
        let name = Self::dataset_name(kind, size);
        let ds = dfs.get(&name).unwrap_or_else(|| panic!("dataset {name} not ingested"));
        match kind {
            WorkloadKind::WordCount => self.two_stage_job(id, kind, size, home_dc, ds),
            // Rotate the TPC-H query shape by job id: Q1 (single-table
            // aggregate), Q3 (3-way join, the paper's Fig 5 example),
            // Q12 (2-way join) — same regional table pinning.
            WorkloadKind::TpcH => match id.0 % 3 {
                0 => self.tpch_q3(id, size, home_dc, ds),
                1 => self.tpch_q1(id, size, home_dc, ds),
                _ => self.tpch_q12(id, size, home_dc, ds),
            },
            WorkloadKind::IterativeMl => {
                self.iterative_job(id, kind, size, home_dc, ds, ML_ITERATIONS)
            }
            WorkloadKind::PageRank => {
                self.iterative_job(id, kind, size, home_dc, ds, PAGERANK_ITERATIONS)
            }
        }
    }

    /// Stage-level r: tasks in a stage share characteristics (§4.1).
    fn stage_r(&mut self) -> f64 {
        self.rng.uniform(0.3, 0.7)
    }

    /// ±10 % per-task jitter on processing time.
    fn jitter(&mut self) -> f64 {
        self.rng.uniform(0.9, 1.1)
    }

    /// A map stage with one task per dataset block, node-local preference.
    fn map_stage(
        &mut self,
        job: JobId,
        sid: u32,
        parents: Vec<StageId>,
        ds: &crate::storage::Dataset,
        rate: f64,
        sel: f64,
    ) -> StageSpec {
        let r = self.stage_r();
        let tasks = ds
            .partitions
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let p_secs = (p.bytes as f64 / MB as f64) / rate * self.jitter();
                TaskSpec {
                    id: TaskId { job, stage: StageId(sid), index: i as u32 },
                    r,
                    p: p_secs.max(0.5),
                    input_bytes: p.bytes,
                    output_bytes: (p.bytes as f64 * sel) as u64,
                    pref_node: Some(p.node),
                    pref_dc: p.dc,
                }
            })
            .collect();
        StageSpec { id: StageId(sid), parents, tasks }
    }

    /// A shuffle stage: width derived from total parent output, preference
    /// unresolved (None) until the partitionList is known.
    #[allow(clippy::too_many_arguments)]
    fn shuffle_stage(
        &mut self,
        job: JobId,
        sid: u32,
        parents: Vec<StageId>,
        parent_out_bytes: u64,
        width: usize,
        rate: f64,
        sel: f64,
    ) -> StageSpec {
        let r = self.stage_r();
        let per_task = parent_out_bytes / width.max(1) as u64;
        let tasks = (0..width.max(1))
            .map(|i| {
                let p_secs = (per_task as f64 / MB as f64) / rate * self.jitter();
                TaskSpec {
                    id: TaskId { job, stage: StageId(sid), index: i as u32 },
                    r,
                    p: p_secs.max(0.5),
                    input_bytes: per_task,
                    output_bytes: (per_task as f64 * sel) as u64,
                    pref_node: None,
                    pref_dc: DcId(0), // resolved at release
                }
            })
            .collect();
        StageSpec { id: StageId(sid), parents, tasks }
    }

    /// WordCount: map → reduce.
    fn two_stage_job(
        &mut self,
        id: JobId,
        kind: WorkloadKind,
        size: SizeClass,
        home_dc: DcId,
        ds: &crate::storage::Dataset,
    ) -> JobSpec {
        let rate = scan_rate(kind);
        let sel = selectivity(kind);
        let s0 = self.map_stage(id, 0, vec![], ds, rate, sel);
        let map_out: u64 = s0.tasks.iter().map(|t| t.output_bytes).sum();
        let width = (s0.tasks.len() / 2).clamp(1, 8);
        let s1 = self.shuffle_stage(id, 1, vec![StageId(0)], map_out, width, rate * 2.0, 0.1);
        JobSpec { id, kind, size, home_dc, stages: vec![s0, s1] }
    }

    /// Regional scan stage over the partitions pinned to `dc`.
    fn tpch_scan_stage(
        &mut self,
        id: JobId,
        sid: u32,
        dc: DcId,
        ds: &crate::storage::Dataset,
        rate: f64,
        sel: f64,
    ) -> StageSpec {
        let r = self.stage_r();
        let tasks: Vec<TaskSpec> = ds
            .partitions
            .iter()
            .filter(|p| p.dc == dc)
            .enumerate()
            .map(|(i, p)| {
                let p_secs = (p.bytes as f64 / MB as f64) / rate * self.jitter();
                TaskSpec {
                    id: TaskId { job: id, stage: StageId(sid), index: i as u32 },
                    r,
                    p: p_secs.max(0.5),
                    input_bytes: p.bytes,
                    output_bytes: (p.bytes as f64 * sel) as u64,
                    pref_node: Some(p.node),
                    pref_dc: p.dc,
                }
            })
            .collect();
        let tasks = if tasks.is_empty() {
            vec![TaskSpec {
                id: TaskId { job: id, stage: StageId(sid), index: 0 },
                r,
                p: 1.0,
                input_bytes: MB,
                output_bytes: MB / 5,
                pref_node: None,
                pref_dc: dc,
            }]
        } else {
            tasks
        };
        StageSpec { id: StageId(sid), parents: vec![], tasks }
    }

    /// TPC-H Q1: scan lineitem (the big table in DC2) -> group-by agg.
    fn tpch_q1(
        &mut self,
        id: JobId,
        size: SizeClass,
        home_dc: DcId,
        ds: &crate::storage::Dataset,
    ) -> JobSpec {
        let kind = WorkloadKind::TpcH;
        let rate = scan_rate(kind);
        let n = self.topo.num_dcs();
        let s0 = self.tpch_scan_stage(id, 0, DcId(2 % n), ds, rate, 0.25);
        let o0: u64 = s0.tasks.iter().map(|t| t.output_bytes).sum();
        let w = (s0.tasks.len() / 2).clamp(1, 8);
        let s1 = self.shuffle_stage(id, 1, vec![StageId(0)], o0, w, rate * 2.0, 0.05);
        JobSpec { id, kind, size, home_dc, stages: vec![s0, s1] }
    }

    /// TPC-H Q12: orders (DC1) join lineitem (DC2) -> agg.
    fn tpch_q12(
        &mut self,
        id: JobId,
        size: SizeClass,
        home_dc: DcId,
        ds: &crate::storage::Dataset,
    ) -> JobSpec {
        let kind = WorkloadKind::TpcH;
        let rate = scan_rate(kind);
        let n = self.topo.num_dcs();
        let s0 = self.tpch_scan_stage(id, 0, DcId(1 % n), ds, rate, 0.4);
        let s1 = self.tpch_scan_stage(id, 1, DcId(2 % n), ds, rate, 0.4);
        let mut s1 = s1;
        s1.id = StageId(1);
        for (i, t) in s1.tasks.iter_mut().enumerate() {
            t.id = TaskId { job: id, stage: StageId(1), index: i as u32 };
        }
        let out = |s: &StageSpec| s.tasks.iter().map(|t| t.output_bytes).sum::<u64>();
        let (o0, o1) = (out(&s0), out(&s1));
        let jw = ((s0.tasks.len() + s1.tasks.len()) / 2).clamp(2, 12);
        let s2 = self.shuffle_stage(id, 2, vec![StageId(0), StageId(1)], o0 + o1, jw, rate, 0.3);
        let o2 = out(&s2);
        let s3 = self.shuffle_stage(id, 3, vec![StageId(2)], o2, (jw / 2).max(1), rate * 2.0, 0.05);
        JobSpec { id, kind, size, home_dc, stages: vec![s0, s1, s2, s3] }
    }

    /// TPC-H Q3: three regional scans, two joins, one aggregation.
    fn tpch_q3(
        &mut self,
        id: JobId,
        size: SizeClass,
        home_dc: DcId,
        ds: &crate::storage::Dataset,
    ) -> JobSpec {
        let kind = WorkloadKind::TpcH;
        let rate = scan_rate(kind);
        let sel = selectivity(kind);
        let n = self.topo.num_dcs();
        // Slice the shared dataset's partitions by table region: customer
        // in DC0, orders in DC1, lineitem in DC2 (mod #regions).
        let table_dc = [DcId(0), DcId(1 % n), DcId(2 % n)];
        let mut stages = Vec::new();
        for (tbl, &dc) in table_dc.iter().enumerate() {
            let r = self.stage_r();
            let tasks: Vec<TaskSpec> = ds
                .partitions
                .iter()
                .filter(|p| p.dc == dc)
                .enumerate()
                .map(|(i, p)| {
                    let p_secs = (p.bytes as f64 / MB as f64) / rate * self.jitter();
                    TaskSpec {
                        id: TaskId { job: id, stage: StageId(tbl as u32), index: i as u32 },
                        r,
                        p: p_secs.max(0.5),
                        input_bytes: p.bytes,
                        output_bytes: (p.bytes as f64 * sel) as u64,
                        pref_node: Some(p.node),
                        pref_dc: p.dc,
                    }
                })
                .collect();
            // A region may hold no partitions for tiny inputs; synthesize a
            // single small scan task so the DAG shape is stable.
            let tasks = if tasks.is_empty() {
                vec![TaskSpec {
                    id: TaskId { job: id, stage: StageId(tbl as u32), index: 0 },
                    r,
                    p: 1.0,
                    input_bytes: MB,
                    output_bytes: MB / 5,
                    pref_node: None,
                    pref_dc: dc,
                }]
            } else {
                tasks
            };
            stages.push(StageSpec { id: StageId(tbl as u32), parents: vec![], tasks });
        }
        let out = |s: &StageSpec| s.tasks.iter().map(|t| t.output_bytes).sum::<u64>();
        let (o0, o1, o2) = (out(&stages[0]), out(&stages[1]), out(&stages[2]));
        // join1 = C ⋈ O, join2 = join1 ⋈ L, then aggregate.
        let j1w = ((stages[0].tasks.len() + stages[1].tasks.len()) / 2).clamp(2, 12);
        let s3 = self.shuffle_stage(id, 3, vec![StageId(0), StageId(1)], o0 + o1, j1w, rate, 0.5);
        let o3 = out(&s3);
        let j2w = ((s3.tasks.len() + stages[2].tasks.len()) / 2).clamp(2, 12);
        let s4 = self.shuffle_stage(id, 4, vec![StageId(3), StageId(2)], o3 + o2, j2w, rate, 0.3);
        let o4 = out(&s4);
        let s5 = self.shuffle_stage(id, 5, vec![StageId(4)], o4, (j2w / 2).max(1), rate * 2.0, 0.05);
        stages.extend([s3, s4, s5]);
        JobSpec { id, kind, size, home_dc, stages }
    }

    /// Iterative ML / PageRank: load → K iteration stages over the cached
    /// partitions → collect.
    #[allow(clippy::too_many_arguments)]
    fn iterative_job(
        &mut self,
        id: JobId,
        kind: WorkloadKind,
        size: SizeClass,
        home_dc: DcId,
        ds: &crate::storage::Dataset,
        iters: usize,
    ) -> JobSpec {
        let rate = scan_rate(kind);
        let sel = selectivity(kind);
        let mut stages = vec![self.map_stage(id, 0, vec![], ds, rate * 1.5, sel)];
        // Per-iteration exchanged state (model weights / rank vector).
        let state_bytes = ((ds.total_bytes() as f64 * sel) as u64).clamp(MB, 160 * MB);
        for k in 0..iters {
            let sid = (k + 1) as u32;
            let r = self.stage_r();
            let tasks: Vec<TaskSpec> = ds
                .partitions
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let p_secs = (p.bytes as f64 / MB as f64) / rate * self.jitter();
                    TaskSpec {
                        id: TaskId { job: id, stage: StageId(sid), index: i as u32 },
                        r,
                        // Iterations run over cached data: cheaper than load.
                        p: (p_secs * 0.6).max(0.5),
                        input_bytes: state_bytes / ds.partitions.len().max(1) as u64,
                        output_bytes: state_bytes / ds.partitions.len().max(1) as u64,
                        pref_node: Some(p.node),
                        pref_dc: p.dc,
                    }
                })
                .collect();
            stages.push(StageSpec { id: StageId(sid), parents: vec![StageId(sid - 1)], tasks });
        }
        // Collect stage: single small task gathering the final state.
        let last = StageId(iters as u32);
        let r = self.stage_r();
        stages.push(StageSpec {
            id: StageId((iters + 1) as u32),
            parents: vec![last],
            tasks: vec![TaskSpec {
                id: TaskId { job: id, stage: StageId((iters + 1) as u32), index: 0 },
                r,
                p: 2.0,
                input_bytes: state_bytes,
                output_bytes: MB,
                pref_node: None,
                pref_dc: home_dc,
            }],
        });
        JobSpec { id, kind, size, home_dc, stages }
    }
}

/// Expected block count for an input size (for tests / sanity).
pub fn expected_blocks(total_bytes: u64, num_dcs: usize) -> usize {
    let per_dc = total_bytes / num_dcs as u64;
    (per_dc.div_ceil(BLOCK_BYTES).max(1) as usize) * num_dcs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Config, Dfs, WorkloadGen) {
        let cfg = Config::default();
        let dfs = Dfs::default();
        let gen = WorkloadGen::new(&cfg, Pcg::seeded(7));
        (cfg, dfs, gen)
    }

    #[test]
    fn fig7_sizes_match_paper() {
        use SizeClass::*;
        use WorkloadKind::*;
        assert_eq!(input_bytes(WordCount, Small), 200 * MB);
        assert_eq!(input_bytes(WordCount, Large), 5 * GB);
        assert_eq!(input_bytes(TpcH, Large), 10 * GB);
        assert_eq!(input_bytes(IterativeMl, Small), 170 * MB);
        assert_eq!(input_bytes(PageRank, Large), 6 * GB);
    }

    #[test]
    fn wordcount_is_map_reduce() {
        let (cfg, mut dfs, mut gen) = setup();
        gen.ensure_dataset(&mut dfs, WorkloadKind::WordCount, SizeClass::Medium);
        let j = gen.make_job(JobId(1), WorkloadKind::WordCount, SizeClass::Medium, DcId(0), &dfs);
        j.validate(cfg.scheduler.theta).unwrap();
        assert_eq!(j.stages.len(), 2);
        // 1 GB over 4 DCs = 2 blocks per DC = 8 map tasks.
        assert_eq!(j.stages[0].tasks.len(), 8);
        assert!(j.stages[0].tasks.iter().all(|t| t.pref_node.is_some()));
        assert!(j.stages[1].tasks.iter().all(|t| t.pref_node.is_none()));
        assert!(j.work() > 0.0);
    }

    #[test]
    fn tpch_dag_has_join_structure() {
        let (cfg, mut dfs, mut gen) = setup();
        gen.ensure_dataset(&mut dfs, WorkloadKind::TpcH, SizeClass::Large);
        // JobId % 3 == 0 selects the Q3 shape.
        let j = gen.make_job(JobId(3), WorkloadKind::TpcH, SizeClass::Large, DcId(1), &dfs);
        j.validate(cfg.scheduler.theta).unwrap();
        assert_eq!(j.stages.len(), 6);
        assert_eq!(j.stages[3].parents, vec![StageId(0), StageId(1)]);
        assert_eq!(j.stages[4].parents, vec![StageId(3), StageId(2)]);
        assert_eq!(j.stages[5].parents, vec![StageId(4)]);
        // Scans are regional: every customer-scan task prefers DC0.
        assert!(j.stages[0].tasks.iter().all(|t| t.pref_dc == DcId(0)));
        assert!(j.stages[1].tasks.iter().all(|t| t.pref_dc == DcId(1)));
        assert!(j.stages[2].tasks.iter().all(|t| t.pref_dc == DcId(2)));
    }

    #[test]
    fn tpch_q1_is_scan_agg() {
        let (cfg, mut dfs, mut gen) = setup();
        gen.ensure_dataset(&mut dfs, WorkloadKind::TpcH, SizeClass::Medium);
        let j = gen.make_job(JobId(1), WorkloadKind::TpcH, SizeClass::Medium, DcId(0), &dfs);
        j.validate(cfg.scheduler.theta).unwrap();
        assert_eq!(j.stages.len(), 2, "Q1 = scan + aggregate");
        assert!(j.stages[0].tasks.iter().all(|t| t.pref_dc == DcId(2)), "lineitem is in EC-1");
    }

    #[test]
    fn tpch_q12_is_two_way_join() {
        let (cfg, mut dfs, mut gen) = setup();
        gen.ensure_dataset(&mut dfs, WorkloadKind::TpcH, SizeClass::Medium);
        let j = gen.make_job(JobId(2), WorkloadKind::TpcH, SizeClass::Medium, DcId(0), &dfs);
        j.validate(cfg.scheduler.theta).unwrap();
        assert_eq!(j.stages.len(), 4, "Q12 = 2 scans + join + agg");
        assert_eq!(j.stages[2].parents, vec![StageId(0), StageId(1)]);
        assert!(j.stages[0].tasks.iter().all(|t| t.pref_dc == DcId(1)), "orders in NC-5");
        assert!(j.stages[1].tasks.iter().all(|t| t.pref_dc == DcId(2)), "lineitem in EC-1");
    }

    #[test]
    fn iterative_jobs_chain_stages() {
        let (cfg, mut dfs, mut gen) = setup();
        gen.ensure_dataset(&mut dfs, WorkloadKind::IterativeMl, SizeClass::Small);
        let j = gen.make_job(JobId(3), WorkloadKind::IterativeMl, SizeClass::Small, DcId(2), &dfs);
        j.validate(cfg.scheduler.theta).unwrap();
        assert_eq!(j.stages.len(), ML_ITERATIONS + 2);
        for k in 1..=ML_ITERATIONS {
            assert_eq!(j.stages[k].parents, vec![StageId(k as u32 - 1)]);
            // Iterations keep data locality of the cached partitions.
            assert!(j.stages[k].tasks.iter().all(|t| t.pref_node.is_some()));
        }
        // Critical path grows with iterations.
        let cp = j.critical_path();
        assert!(cp > ML_ITERATIONS as f64 * 0.5, "cp {cp}");
    }

    #[test]
    fn pagerank_has_five_iterations() {
        let (_, mut dfs, mut gen) = setup();
        gen.ensure_dataset(&mut dfs, WorkloadKind::PageRank, SizeClass::Medium);
        let j = gen.make_job(JobId(4), WorkloadKind::PageRank, SizeClass::Medium, DcId(0), &dfs);
        assert_eq!(j.stages.len(), PAGERANK_ITERATIONS + 2);
    }

    #[test]
    fn trace_follows_mix_and_arrivals() {
        let (cfg, _, mut gen) = setup();
        let trace = gen.trace(&cfg, 400);
        assert_eq!(trace.len(), 400);
        // Arrivals increase; mean gap ≈ 60 s.
        let mut gaps = Vec::new();
        for w in trace.windows(2) {
            assert!(w[1].arrival_secs >= w[0].arrival_secs);
            gaps.push(w[1].arrival_secs - w[0].arrival_secs);
        }
        // Default calibrated inter-arrival is 30 s (see config defaults).
        let mean_gap = crate::util::stats::mean(&gaps);
        assert!((mean_gap - 30.0).abs() < 5.0, "mean gap {mean_gap}");
        // Size mix roughly 46/40/14 (TPC-H upgrades small→medium).
        let small = trace.iter().filter(|e| e.size == SizeClass::Small).count() as f64 / 400.0;
        let large = trace.iter().filter(|e| e.size == SizeClass::Large).count() as f64 / 400.0;
        assert!((small - 0.46 * 0.75).abs() < 0.12, "small {small}");
        assert!((large - 0.14).abs() < 0.07, "large {large}");
        // All four kinds cycle.
        assert_eq!(trace[0].kind, WorkloadKind::WordCount);
        assert_eq!(trace[1].kind, WorkloadKind::TpcH);
    }

    #[test]
    fn tpch_small_upgrades_to_medium() {
        let (cfg, _, mut gen) = setup();
        for _ in 0..200 {
            let c = gen.sample_class(&cfg.workload.mix, WorkloadKind::TpcH);
            assert_ne!(c, SizeClass::Small);
        }
    }

    #[test]
    fn jobs_are_deterministic_given_seed() {
        let build = || {
            let cfg = Config::default();
            let mut dfs = Dfs::default();
            let mut gen = WorkloadGen::new(&cfg, Pcg::seeded(99));
            gen.ensure_dataset(&mut dfs, WorkloadKind::TpcH, SizeClass::Medium);
            let j = gen.make_job(JobId(5), WorkloadKind::TpcH, SizeClass::Medium, DcId(0), &dfs);
            (j.work(), j.num_tasks())
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn all_workloads_validate_at_all_sizes() {
        let (cfg, mut dfs, mut gen) = setup();
        let mut id = 0;
        for kind in WorkloadKind::ALL {
            for size in [SizeClass::Small, SizeClass::Medium, SizeClass::Large] {
                gen.ensure_dataset(&mut dfs, kind, size);
                let j = gen.make_job(JobId(id), kind, size, DcId(0), &dfs);
                j.validate(cfg.scheduler.theta)
                    .unwrap_or_else(|e| panic!("{} {}: {e}", kind.name(), size.name()));
                id += 1;
            }
        }
    }
}
