//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! the rust request path. Python runs only at build time (`make
//! artifacts`); this module is the only consumer of its output.
//!
//! Artifacts are HLO *text* (see `python/compile/aot.py` for why), parsed
//! by `HloModuleProto::from_text_file`, compiled once per process on the
//! PJRT CPU client, then executed with concrete buffers. Shapes are fixed
//! at export: the constants below must stay in sync with `aot.py`.
//!
//! The real runtime depends on the `xla` bindings, which the offline image
//! does not vendor — it is gated behind the `pjrt` cargo feature. The
//! default build ships an API-identical stub whose [`Runtime::load`]
//! fails fast with an actionable message, so the coordinator, examples
//! and benches all compile (and the simulator runs) without PJRT.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::Result;
#[cfg(feature = "pjrt")]
use crate::anyhow;
#[cfg(feature = "pjrt")]
use crate::ensure;
#[cfg(feature = "pjrt")]
use crate::util::error::Context;

/// Export shapes — keep in sync with python/compile/aot.py.
pub const LOGREG_N: usize = 1024;
pub const LOGREG_D: usize = 64;
pub const PAGERANK_N: usize = 256;
pub const SEG_N: usize = 1024;
pub const SEG_K: usize = 64;
pub const SEG_V: usize = 4;

/// Locate `artifacts/` relative to the current dir or the repo root.
pub fn default_artifact_dir() -> PathBuf {
    for cand in ["artifacts", "../artifacts", "/root/repo/artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("logreg_step.hlo.txt").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// A loaded, compiled artifact.
#[cfg(feature = "pjrt")]
struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: &'static str,
}

#[cfg(feature = "pjrt")]
impl Executable {
    fn load(client: &xla::PjRtClient, dir: &Path, name: &'static str) -> Result<Executable> {
        let path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Executable { exe, name })
    }

    fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} result: {e:?}", self.name))?;
        // aot.py lowers with return_tuple=True.
        let tuple = result
            .decompose_tuple()
            .map_err(|e| anyhow!("untupling {}: {e:?}", self.name))?;
        Ok(tuple)
    }
}

/// The compute engine backing real-numerics tasks in the coordinator.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    logreg: Executable,
    pagerank: Executable,
    wordcount: Executable,
    /// Executions served (perf accounting).
    pub executions: std::cell::Cell<u64>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load and compile every artifact. Fails fast with a pointer to
    /// `make artifacts` when they are missing.
    pub fn load(dir: &Path) -> Result<Runtime> {
        if !dir.join("logreg_step.hlo.txt").exists() {
            bail!(
                "artifacts not found in {dir:?} — run `make artifacts` first \
                 (python lowers the L2 graphs to HLO text exactly once)"
            );
        }
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            logreg: Executable::load(&client, dir, "logreg_step")?,
            pagerank: Executable::load(&client, dir, "pagerank_step")?,
            wordcount: Executable::load(&client, dir, "wordcount_agg")?,
            client,
            executions: std::cell::Cell::new(0),
        })
    }

    /// One SGD step of logistic regression over a (LOGREG_N, LOGREG_D)
    /// shard. Returns (new weights, loss).
    pub fn logreg_step(&self, w: &[f32], x: &[f32], y: &[f32], lr: f32) -> Result<(Vec<f32>, f32)> {
        ensure!(w.len() == LOGREG_D, "w must be {LOGREG_D}, got {}", w.len());
        ensure!(x.len() == LOGREG_N * LOGREG_D, "x shard shape mismatch");
        ensure!(y.len() == LOGREG_N, "y shard shape mismatch");
        let w_l = xla::Literal::vec1(w);
        let x_l = xla::Literal::vec1(x)
            .reshape(&[LOGREG_N as i64, LOGREG_D as i64])
            .map_err(|e| anyhow!("reshape x: {e:?}"))?;
        let y_l = xla::Literal::vec1(y);
        let lr_l = xla::Literal::from(lr);
        let out = self.logreg.run(&[w_l, x_l, y_l, lr_l])?;
        self.executions.set(self.executions.get() + 1);
        let new_w = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let loss = out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok((new_w, loss))
    }

    /// One damped PageRank iteration over a PAGERANK_N-node graph.
    /// Returns (new ranks, L1 residual).
    pub fn pagerank_step(&self, m: &[f32], r: &[f32], damping: f32) -> Result<(Vec<f32>, f32)> {
        ensure!(m.len() == PAGERANK_N * PAGERANK_N, "matrix shape mismatch");
        ensure!(r.len() == PAGERANK_N, "rank shape mismatch");
        let m_l = xla::Literal::vec1(m)
            .reshape(&[PAGERANK_N as i64, PAGERANK_N as i64])
            .map_err(|e| anyhow!("reshape m: {e:?}"))?;
        let r_l = xla::Literal::vec1(r);
        let d_l = xla::Literal::from(damping);
        let out = self.pagerank.run(&[m_l, r_l, d_l])?;
        self.executions.set(self.executions.get() + 1);
        let ranks = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let resid = out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok((ranks, resid))
    }

    /// Segment-sum aggregation over a (SEG_N, SEG_K) one-hot and
    /// (SEG_N, SEG_V) values. Returns flattened (SEG_K, SEG_V) totals.
    pub fn wordcount_agg(&self, onehot: &[f32], values: &[f32]) -> Result<Vec<f32>> {
        ensure!(onehot.len() == SEG_N * SEG_K, "onehot shape mismatch");
        ensure!(values.len() == SEG_N * SEG_V, "values shape mismatch");
        let h_l = xla::Literal::vec1(onehot)
            .reshape(&[SEG_N as i64, SEG_K as i64])
            .map_err(|e| anyhow!("reshape onehot: {e:?}"))?;
        let v_l = xla::Literal::vec1(values)
            .reshape(&[SEG_N as i64, SEG_V as i64])
            .map_err(|e| anyhow!("reshape values: {e:?}"))?;
        let out = self.wordcount.run(&[h_l, v_l])?;
        self.executions.set(self.executions.get() + 1);
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }
}

/// API-identical stub for builds without the `xla` crate: every
/// constructor fails fast, so nothing downstream needs cfg churn.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    /// Executions served (perf accounting).
    pub executions: std::cell::Cell<u64>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always fails: points at `make artifacts` when the HLO inputs are
    /// missing, and at the `pjrt` feature otherwise.
    pub fn load(dir: &Path) -> Result<Runtime> {
        if !dir.join("logreg_step.hlo.txt").exists() {
            bail!(
                "artifacts not found in {dir:?} — run `make artifacts` first \
                 (python lowers the L2 graphs to HLO text exactly once)"
            );
        }
        bail!(
            "the PJRT runtime is gated behind the `pjrt` cargo feature (the \
             offline build carries no xla crate) — rebuild with --features pjrt"
        );
    }

    /// See the `pjrt` build; unreachable here because `load` always fails.
    pub fn logreg_step(&self, w: &[f32], x: &[f32], y: &[f32], lr: f32) -> Result<(Vec<f32>, f32)> {
        let _ = (w, x, y, lr);
        bail!("PJRT runtime disabled (build with --features pjrt)");
    }

    /// See the `pjrt` build; unreachable here because `load` always fails.
    pub fn pagerank_step(&self, m: &[f32], r: &[f32], damping: f32) -> Result<(Vec<f32>, f32)> {
        let _ = (m, r, damping);
        bail!("PJRT runtime disabled (build with --features pjrt)");
    }

    /// See the `pjrt` build; unreachable here because `load` always fails.
    pub fn wordcount_agg(&self, onehot: &[f32], values: &[f32]) -> Result<Vec<f32>> {
        let _ = (onehot, values);
        bail!("PJRT runtime disabled (build with --features pjrt)");
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_load_gives_actionable_errors() {
        let err = Runtime::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
