//! Typed identifiers shared across the stack. Everything is `Copy` and
//! displays compactly for traces (`dc2`, `j3.s1.t07`, `jm[j3@dc1]`, ...).

use std::fmt;

/// Data center (region) index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DcId(pub usize);

impl fmt::Display for DcId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "dc{}", self.0)
    }
}

/// A worker machine within a data center.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId {
    pub dc: DcId,
    pub idx: usize,
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.n{}", self.dc, self.idx)
    }
}

/// A container (executor slot). Globally unique across the run — container
/// ids are never reused even after spot revocations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContainerId(pub u64);

impl fmt::Display for ContainerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "c{}", self.0)
    }
}

/// A submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "j{}", self.0)
    }
}

/// A stage within a job's DAG.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StageId(pub u32);

impl fmt::Display for StageId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// A task = (job, stage, index-within-stage).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId {
    pub job: JobId,
    pub stage: StageId,
    pub index: u32,
}

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}.t{:02}", self.job, self.stage, self.index)
    }
}

/// A job manager replica: one per (job, dc).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JmId {
    pub job: JobId,
    pub dc: DcId,
}

impl fmt::Display for JmId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "jm[{}@{}]", self.job, self.dc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let t = TaskId { job: JobId(3), stage: StageId(1), index: 7 };
        assert_eq!(t.to_string(), "j3.s1.t07");
        assert_eq!(DcId(2).to_string(), "dc2");
        assert_eq!(NodeId { dc: DcId(0), idx: 4 }.to_string(), "dc0.n4");
        assert_eq!(JmId { job: JobId(3), dc: DcId(1) }.to_string(), "jm[j3@dc1]");
    }

    #[test]
    fn ids_are_ordered_and_hashable() {
        use std::collections::HashSet;
        let a = TaskId { job: JobId(1), stage: StageId(0), index: 0 };
        let b = TaskId { job: JobId(1), stage: StageId(0), index: 1 };
        assert!(a < b);
        let set: HashSet<TaskId> = [a, b, a].into_iter().collect();
        assert_eq!(set.len(), 2);
    }
}
