//! Per-data-center master: the YARN-style resource manager each autonomous
//! system runs (§3.1 steps 3–4).
//!
//! Sub-jobs (via their JM) register a *desire* — the container count Af
//! computed for the next period — and at each period boundary the master
//! runs the **fair scheduler** (§4.4): repeatedly hand one free container
//! to the registered sub-job that currently occupies the smallest share,
//! unless its desire is met. Allocation never exceeds desire (`a ≤ d`,
//! Appendix A) and does not change within a period; between boundaries the
//! master only *reclaims* containers the JM proactively returns.
//!
//! The master also spawns JM containers (step 2/2b) and re-grants a failed
//! JM's containers to its replacement via jobId-keyed tokens (§5).
//!
//! Container requests may carry an instance-class preference
//! ([`ClassPref`], pushed by the JM's bid strategy alongside its desire):
//! a sub-job preferring [`ClassPref::Reliable`] is handed free containers
//! hosted on on-demand (revocation-proof) VMs first. With no preference
//! registered the allocation order is byte-identical to the plain fair
//! scheduler, so the naive bidding baseline leaves replay digests
//! untouched.

use std::collections::BTreeMap;

use crate::cloud::bidding::ClassPref;
use crate::cloud::InstanceClass;
use crate::cluster::Cluster;

/// How free containers are handed to unsatisfied sub-jobs.
///
/// * `FairShare` — max-min water-filling (the fair scheduler the Af
///   analysis assumes, §4.4).
/// * `Fifo` — oldest job first (stock YARN's default queue, used by the
///   static baselines; this is what serializes cent-stat's makespan in
///   Fig 8/10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    FairShare,
    Fifo,
}
use crate::ids::{ContainerId, DcId, JmId, JobId};
use crate::sim::SimTime;

/// A token authorizing a (replacement) JM to access a job's containers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ContainerToken {
    pub job: JobId,
    pub containers: Vec<ContainerId>,
}

#[derive(Debug)]
pub struct Master {
    /// DCs whose container pools this master controls. A per-DC master
    /// (decentralized) holds one; the centralized baselines' monolithic
    /// master holds all of them.
    pub dcs: Vec<DcId>,
    /// Home DC: where this master itself runs (JM spawn preference).
    pub home: DcId,
    /// Registered sub-jobs and their desires for the coming period.
    desires: BTreeMap<JmId, usize>,
    /// Containers currently granted per sub-job (excluding the JM's own).
    granted: BTreeMap<JmId, Vec<ContainerId>>,
    /// Instance-class preferences attached to container requests (only
    /// non-default preferences are stored; see [`Master::set_class_pref`]).
    prefs: BTreeMap<JmId, ClassPref>,
    pub policy: AllocPolicy,
}

impl Master {
    /// A per-DC (autonomous) master.
    pub fn new(dc: DcId) -> Self {
        Master {
            dcs: vec![dc],
            home: dc,
            desires: BTreeMap::new(),
            granted: BTreeMap::new(),
            prefs: BTreeMap::new(),
            policy: AllocPolicy::FairShare,
        }
    }

    /// The centralized baselines' monolithic master over all regions.
    pub fn centralized(dcs: Vec<DcId>) -> Self {
        let home = dcs[0];
        Master {
            dcs,
            home,
            desires: BTreeMap::new(),
            granted: BTreeMap::new(),
            prefs: BTreeMap::new(),
            policy: AllocPolicy::FairShare,
        }
    }

    /// Union free pool over every DC this master controls, interleaved
    /// round-robin across DCs so a centralized master's grants spread over
    /// all regions (it "controls the worker machines from all data
    /// centers", Fig 1a) instead of draining one region first.
    fn pool(&self, cluster: &Cluster) -> Vec<ContainerId> {
        let mut per_dc: Vec<Vec<ContainerId>> = self
            .dcs
            .iter()
            .map(|&d| {
                let mut p = cluster.free_pool(d);
                p.sort_unstable();
                p
            })
            .collect();
        let mut pool = Vec::with_capacity(per_dc.iter().map(Vec::len).sum());
        let ndc = per_dc.len();
        let mut i = 0;
        while per_dc.iter().any(|p| !p.is_empty()) {
            if let Some(c) = per_dc[i % ndc].pop() {
                pool.push(c);
            }
            i += 1;
        }
        pool.reverse(); // allocate() pops from the back
        pool
    }

    /// Register a sub-job (JM generated). Initial desire is set by the
    /// first `set_desire` call (Af starts at 1).
    pub fn register(&mut self, jm: JmId) {
        self.desires.entry(jm).or_insert(0);
        self.granted.entry(jm).or_default();
    }

    pub fn is_registered(&self, jm: JmId) -> bool {
        self.desires.contains_key(&jm)
    }

    /// Update a sub-job's desire (the JM pushes d(q) at period end).
    pub fn set_desire(&mut self, jm: JmId, d: usize) {
        if let Some(v) = self.desires.get_mut(&jm) {
            *v = d;
        }
    }

    pub fn desire(&self, jm: JmId) -> usize {
        self.desires.get(&jm).copied().unwrap_or(0)
    }

    pub fn granted(&self, jm: JmId) -> &[ContainerId] {
        self.granted.get(&jm).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn allocation(&self, jm: JmId) -> usize {
        self.granted(jm).len()
    }

    /// Deregister a finished sub-job; caller releases the returned
    /// containers back to the cluster pool.
    pub fn unregister(&mut self, jm: JmId) -> Vec<ContainerId> {
        self.desires.remove(&jm);
        self.prefs.remove(&jm);
        self.granted.remove(&jm).unwrap_or_default()
    }

    /// Attach the instance-class preference a sub-job's container
    /// requests carry this period (the JM's bid strategy pushes it next
    /// to the desire). [`ClassPref::Any`] clears the entry, restoring the
    /// byte-identical default allocation order.
    pub fn set_class_pref(&mut self, jm: JmId, pref: ClassPref) {
        match pref {
            ClassPref::Any => {
                self.prefs.remove(&jm);
            }
            ClassPref::Reliable => {
                self.prefs.insert(jm, pref);
            }
        }
    }

    pub fn class_pref(&self, jm: JmId) -> ClassPref {
        self.prefs.get(&jm).copied().unwrap_or(ClassPref::Any)
    }

    /// A JM proactively returns a container (Af decrease path).
    pub fn return_container(&mut self, jm: JmId, cid: ContainerId, cluster: &mut Cluster, t: SimTime) {
        if let Some(v) = self.granted.get_mut(&jm) {
            v.retain(|&c| c != cid);
        }
        cluster.release(cid, t);
    }

    /// A granted container died (spot revocation): forget it.
    pub fn forget_container(&mut self, cid: ContainerId) {
        for v in self.granted.values_mut() {
            v.retain(|&c| c != cid);
        }
    }

    /// Spawn a JM container from the free pool of `prefer` (falling back
    /// to any controlled DC). Returns None when out of capacity.
    pub fn spawn_jm_container_at(
        &mut self,
        jm: JmId,
        cluster: &mut Cluster,
        prefer: DcId,
    ) -> Option<ContainerId> {
        let cid = cluster
            .free_pool(prefer)
            .first()
            .copied()
            .or_else(|| self.pool(cluster).first().copied())?;
        cluster.grant(cid, jm);
        Some(cid)
    }

    /// Spawn a JM container in the master's home DC.
    pub fn spawn_jm_container(&mut self, jm: JmId, cluster: &mut Cluster) -> Option<ContainerId> {
        self.spawn_jm_container_at(jm, cluster, self.home)
    }

    /// Period-boundary allocation: max-min (water-filling) over desires
    /// with one-container granularity. Returns the fresh grants per
    /// sub-job. Deterministic: ties break by JmId order.
    pub fn allocate(&mut self, cluster: &mut Cluster) -> Vec<(JmId, Vec<ContainerId>)> {
        let mut pool = self.pool(cluster); // sorted => deterministic grants
        let mut fresh: BTreeMap<JmId, Vec<ContainerId>> = BTreeMap::new();
        while !pool.is_empty() {
            // FairShare: unsatisfied sub-job with the fewest grants.
            // Fifo: oldest unsatisfied job (stock YARN default queue).
            let next = match self.policy {
                AllocPolicy::FairShare => self
                    .desires
                    .iter()
                    .filter(|(jm, &d)| self.granted[jm].len() < d)
                    .min_by_key(|(jm, _)| (self.granted[jm].len(), **jm)),
                AllocPolicy::Fifo => self
                    .desires
                    .iter()
                    .filter(|(jm, &d)| self.granted[jm].len() < d)
                    .min_by_key(|(jm, _)| **jm),
            };
            let Some((&jm, _)) = next else { break };
            // The chosen sub-job's class preference picks *which* free
            // container it gets: Reliable takes the nearest-to-pop-order
            // container hosted on an on-demand VM, falling back to plain
            // pop order when none remains. With no preference this is
            // exactly `pool.pop()` — the pre-subsystem order.
            let at = match self.prefs.get(&jm) {
                Some(ClassPref::Reliable) => pool
                    .iter()
                    .rposition(|cid| {
                        matches!(
                            cluster.node_class(cluster.containers[cid].node),
                            InstanceClass::OnDemand
                        )
                    })
                    .unwrap_or(pool.len() - 1),
                _ => pool.len() - 1,
            };
            let cid = pool.remove(at);
            cluster.grant(cid, jm);
            self.granted.get_mut(&jm).unwrap().push(cid);
            fresh.entry(jm).or_default().push(cid);
        }
        fresh.into_iter().collect()
    }

    /// Token re-grant after JM failure (§5): transfer every container of
    /// `job` in this DC to the replacement JM identity.
    pub fn reissue_tokens(&mut self, job: JobId, new_jm: JmId, cluster: &mut Cluster) -> ContainerToken {
        // Collect containers held by any JM identity of this job in this DC
        // (the replacement usually reuses the same (job, dc) identity).
        let old_keys: Vec<JmId> = self
            .granted
            .keys()
            .filter(|k| k.job == job)
            .copied()
            .collect();
        let mut containers = Vec::new();
        for k in old_keys {
            let mut v = self.granted.remove(&k).unwrap_or_default();
            self.desires.remove(&k);
            containers.append(&mut v);
        }
        containers.retain(|c| cluster.containers[c].alive);
        for &c in &containers {
            cluster.regrant(c, new_jm);
        }
        self.register(new_jm);
        self.granted.get_mut(&new_jm).unwrap().extend(containers.iter().copied());
        ContainerToken { job, containers }
    }

    /// All registered sub-jobs (deterministic order).
    pub fn sub_jobs(&self) -> Vec<JmId> {
        self.desires.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::InstanceClass;
    use crate::ids::StageId;
    use crate::sim::secs;

    fn cluster_with(n_containers: usize) -> Cluster {
        // One DC, n nodes of 1 container each.
        Cluster::build(&["A".into()], n_containers, 1, 2, |_, _| InstanceClass::OnDemand)
    }

    fn jm(j: u64) -> JmId {
        JmId { job: JobId(j), dc: DcId(0) }
    }

    #[test]
    fn water_filling_splits_evenly() {
        let mut cluster = cluster_with(10);
        let mut m = Master::new(DcId(0));
        for j in 0..2 {
            m.register(jm(j));
            m.set_desire(jm(j), 10);
        }
        let fresh = m.allocate(&mut cluster);
        assert_eq!(fresh.len(), 2);
        assert_eq!(m.allocation(jm(0)), 5);
        assert_eq!(m.allocation(jm(1)), 5);
        assert!(cluster.free_pool(DcId(0)).is_empty());
    }

    #[test]
    fn allocation_never_exceeds_desire() {
        let mut cluster = cluster_with(10);
        let mut m = Master::new(DcId(0));
        m.register(jm(0));
        m.set_desire(jm(0), 3);
        m.register(jm(1));
        m.set_desire(jm(1), 100);
        m.allocate(&mut cluster);
        assert_eq!(m.allocation(jm(0)), 3, "a <= d");
        assert_eq!(m.allocation(jm(1)), 7, "rest goes to the hungry job");
    }

    #[test]
    fn incremental_allocation_tops_up() {
        let mut cluster = cluster_with(8);
        let mut m = Master::new(DcId(0));
        m.register(jm(0));
        m.set_desire(jm(0), 2);
        m.allocate(&mut cluster);
        assert_eq!(m.allocation(jm(0)), 2);
        // Next period: desire rises (Af increase), master tops up.
        m.set_desire(jm(0), 5);
        let fresh = m.allocate(&mut cluster);
        assert_eq!(fresh[0].1.len(), 3);
        assert_eq!(m.allocation(jm(0)), 5);
    }

    #[test]
    fn return_container_frees_pool() {
        let mut cluster = cluster_with(4);
        let mut m = Master::new(DcId(0));
        m.register(jm(0));
        m.set_desire(jm(0), 4);
        m.allocate(&mut cluster);
        let cid = m.granted(jm(0))[0];
        m.return_container(jm(0), cid, &mut cluster, secs(1));
        assert_eq!(m.allocation(jm(0)), 3);
        assert_eq!(cluster.free_pool(DcId(0)).len(), 1);
    }

    #[test]
    fn unregister_returns_everything() {
        let mut cluster = cluster_with(4);
        let mut m = Master::new(DcId(0));
        m.register(jm(0));
        m.set_desire(jm(0), 4);
        m.allocate(&mut cluster);
        let held = m.unregister(jm(0));
        assert_eq!(held.len(), 4);
        assert!(!m.is_registered(jm(0)));
    }

    #[test]
    fn spawn_jm_container_takes_from_pool() {
        let mut cluster = cluster_with(2);
        let mut m = Master::new(DcId(0));
        let c = m.spawn_jm_container(jm(0), &mut cluster).unwrap();
        assert_eq!(cluster.container(c).owner, Some(jm(0)));
        assert_eq!(cluster.free_pool(DcId(0)).len(), 1);
        m.spawn_jm_container(jm(1), &mut cluster).unwrap();
        assert!(m.spawn_jm_container(jm(2), &mut cluster).is_none(), "pool exhausted");
    }

    #[test]
    fn reissue_tokens_transfers_live_containers() {
        let mut cluster = cluster_with(6);
        let mut m = Master::new(DcId(0));
        let old = jm(7);
        m.register(old);
        m.set_desire(old, 3);
        m.allocate(&mut cluster);
        let held = m.granted(old).to_vec();
        assert_eq!(held.len(), 3);
        // Replacement identity is the same (job, dc) in practice; simulate a
        // re-keyed JM by first renaming: use a different dc id to force a
        // distinct key.
        let newer = JmId { job: JobId(7), dc: DcId(0) };
        // Kill one container's node so only live ones transfer.
        let node = cluster.container(held[0]).node;
        cluster.kill_node(node, secs(5));
        let tok = m.reissue_tokens(JobId(7), newer, &mut cluster);
        assert_eq!(tok.job, JobId(7));
        assert_eq!(tok.containers.len(), 2, "dead container filtered");
        for c in &tok.containers {
            assert_eq!(cluster.container(*c).owner, Some(newer));
        }
        let _ = StageId(0);
    }

    #[test]
    fn reliable_class_pref_steers_grants_onto_on_demand_nodes() {
        // 4 nodes of 1 container: nodes 0 and 2 on-demand, 1 and 3 spot.
        let mut cluster = Cluster::build(&["A".into()], 4, 1, 2, |_, idx| {
            if idx % 2 == 0 {
                InstanceClass::OnDemand
            } else {
                InstanceClass::Spot { bid: 0.05 }
            }
        });
        let mut m = Master::new(DcId(0));
        m.register(jm(0));
        m.set_desire(jm(0), 2);
        m.set_class_pref(jm(0), ClassPref::Reliable);
        m.allocate(&mut cluster);
        for &cid in m.granted(jm(0)) {
            let node = cluster.container(cid).node;
            assert_eq!(
                cluster.node_class(node),
                InstanceClass::OnDemand,
                "reliable pref must pick on-demand hosts while any remain"
            );
        }
        // A third grant must still succeed when only spot hosts remain.
        m.set_desire(jm(0), 3);
        m.allocate(&mut cluster);
        assert_eq!(m.allocation(jm(0)), 3, "pref falls back to spot when exhausted");
        // Clearing the pref removes the stored entry.
        m.set_class_pref(jm(0), ClassPref::Any);
        assert_eq!(m.class_pref(jm(0)), ClassPref::Any);
    }

    #[test]
    fn no_class_pref_keeps_the_legacy_allocation_order() {
        // Identical desires, identical pool: a master with no preference
        // entries must produce exactly the pre-subsystem grants.
        let build = || {
            Cluster::build(&["A".into()], 6, 1, 2, |_, idx| {
                if idx < 3 {
                    InstanceClass::Spot { bid: 0.05 }
                } else {
                    InstanceClass::OnDemand
                }
            })
        };
        let run = |set_noop_pref: bool| {
            let mut cluster = build();
            let mut m = Master::new(DcId(0));
            for j in 0..2 {
                m.register(jm(j));
                m.set_desire(jm(j), 3);
            }
            if set_noop_pref {
                m.set_class_pref(jm(0), ClassPref::Any);
            }
            m.allocate(&mut cluster);
            (m.granted(jm(0)).to_vec(), m.granted(jm(1)).to_vec())
        };
        assert_eq!(run(false), run(true), "Any pref must not perturb grant order");
    }

    /// Property: max-min fairness — after allocation, (1) a ≤ d for all,
    /// (2) pool exhausted or all satisfied, (3) any two *unsatisfied*
    /// sub-jobs' allocations differ by at most 1, and (4) no satisfied
    /// sub-job holds more than any unsatisfied one + 1.
    #[test]
    fn prop_max_min_invariants() {
        use crate::testkit::{forall, UsizeIn, VecOf};
        let gen = VecOf { elem: UsizeIn(0, 12), min_len: 1, max_len: 8 };
        forall(0xFA1, &gen, |desires: &Vec<usize>| {
            let mut cluster = cluster_with(10);
            let mut m = Master::new(DcId(0));
            for (j, &d) in desires.iter().enumerate() {
                m.register(jm(j as u64));
                m.set_desire(jm(j as u64), d);
            }
            m.allocate(&mut cluster);
            let total: usize = (0..desires.len()).map(|j| m.allocation(jm(j as u64))).sum();
            let pool_left = cluster.free_pool(DcId(0)).len();
            for (j, &d) in desires.iter().enumerate() {
                let a = m.allocation(jm(j as u64));
                crate::prop_assert!(a <= d, "job {j}: a={a} > d={d}");
            }
            let unsatisfied: Vec<usize> = (0..desires.len())
                .filter(|&j| m.allocation(jm(j as u64)) < desires[j])
                .collect();
            if !unsatisfied.is_empty() {
                crate::prop_assert!(pool_left == 0, "unsatisfied jobs but {pool_left} free");
                let allocs: Vec<usize> =
                    unsatisfied.iter().map(|&j| m.allocation(jm(j as u64))).collect();
                let lo = *allocs.iter().min().unwrap();
                let hi = *allocs.iter().max().unwrap();
                crate::prop_assert!(hi - lo <= 1, "unsatisfied spread {lo}..{hi}");
                // No one (satisfied or not) may exceed an unsatisfied job's
                // share by 2+ — that's what max-min means here.
                for j in 0..desires.len() {
                    let a = m.allocation(jm(j as u64));
                    crate::prop_assert!(
                        a <= lo + 1 || a <= desires[j],
                        "job {j} a={a} vs min unsatisfied {lo}"
                    );
                }
            }
            crate::prop_assert!(total + pool_left == 10, "container conservation");
            Ok(())
        });
    }
}
