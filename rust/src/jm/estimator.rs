//! Task-characteristic estimation (§5 "Parameterized delay scheduling").
//!
//! The paper does not assume oracle task knowledge: "we estimate the
//! requirements using the measured statistics from the first few
//! executions of tasks in a stage. We continue to refine these
//! estimations as more tasks have been measured. We estimate task
//! processing time as the average processing time of all finished tasks
//! in the same stage."
//!
//! [`StageEstimator`] implements exactly that contract per (job, stage):
//! until `warmup` samples exist, it returns a prior (scaled from the
//! task's input size); afterwards the running mean of measured values.
//! Parades consumes the *estimated* `p` for its τ·p thresholds, so the
//! scheduler stays semi-clairvoyant even about processing times.

use std::collections::HashMap;

use crate::ids::StageId;

/// Running mean of (p, r) per stage.
#[derive(Debug, Clone, Default)]
struct StageStats {
    n: u64,
    p_sum: f64,
    r_sum: f64,
}

/// Per-job estimator over its stages.
#[derive(Debug, Default)]
pub struct StageEstimator {
    stages: HashMap<StageId, StageStats>,
    /// Samples needed before trusting the measurement over the prior.
    warmup: u64,
    /// Prior processing rate (seconds per MB of input) used pre-warmup.
    prior_secs_per_mb: f64,
    /// Prior resource requirement.
    prior_r: f64,
}

impl StageEstimator {
    pub fn new(warmup: u64, prior_secs_per_mb: f64, prior_r: f64) -> Self {
        StageEstimator {
            stages: HashMap::new(),
            warmup: warmup.max(1),
            prior_secs_per_mb,
            prior_r,
        }
    }

    /// Defaults matching the calibrated workload rates.
    pub fn standard() -> Self {
        Self::new(2, 0.3, 0.5)
    }

    /// Record a finished task's measured processing time and footprint.
    pub fn record(&mut self, stage: StageId, measured_p: f64, measured_r: f64) {
        let s = self.stages.entry(stage).or_default();
        s.n += 1;
        s.p_sum += measured_p;
        s.r_sum += measured_r;
    }

    /// Estimated processing time for a task of `input_bytes` in `stage`.
    /// Pre-warmup: size-scaled prior. Post-warmup: stage mean (§5 — tasks
    /// in a stage share characteristics).
    pub fn estimate_p(&self, stage: StageId, input_bytes: u64) -> f64 {
        match self.stages.get(&stage) {
            Some(s) if s.n >= self.warmup => s.p_sum / s.n as f64,
            _ => (input_bytes as f64 / (1024.0 * 1024.0) * self.prior_secs_per_mb).max(0.5),
        }
    }

    /// Estimated resource requirement for `stage`.
    pub fn estimate_r(&self, stage: StageId) -> f64 {
        match self.stages.get(&stage) {
            Some(s) if s.n >= self.warmup => (s.r_sum / s.n as f64).clamp(0.01, 1.0),
            _ => self.prior_r,
        }
    }

    /// Number of measurements for a stage (diagnostics).
    pub fn samples(&self, stage: StageId) -> u64 {
        self.stages.get(&stage).map(|s| s.n).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_scales_with_input_size_before_warmup() {
        let e = StageEstimator::new(2, 0.5, 0.4);
        let small = e.estimate_p(StageId(0), 10 * 1024 * 1024);
        let large = e.estimate_p(StageId(0), 100 * 1024 * 1024);
        assert!((small - 5.0).abs() < 1e-9);
        assert!((large - 50.0).abs() < 1e-9);
        assert_eq!(e.estimate_r(StageId(0)), 0.4);
    }

    #[test]
    fn measurements_take_over_after_warmup() {
        let mut e = StageEstimator::new(2, 0.5, 0.4);
        e.record(StageId(1), 20.0, 0.6);
        // One sample < warmup: still the prior.
        assert!((e.estimate_p(StageId(1), 1024) - 0.5f64.max(0.5)).abs() < 1e-9);
        e.record(StageId(1), 30.0, 0.8);
        assert!((e.estimate_p(StageId(1), 1024) - 25.0).abs() < 1e-9);
        assert!((e.estimate_r(StageId(1)) - 0.7).abs() < 1e-9);
        assert_eq!(e.samples(StageId(1)), 2);
    }

    #[test]
    fn estimates_refine_with_more_samples() {
        let mut e = StageEstimator::new(1, 0.5, 0.4);
        for i in 1..=10 {
            e.record(StageId(2), i as f64, 0.5);
        }
        assert!((e.estimate_p(StageId(2), 0) - 5.5).abs() < 1e-9, "mean of 1..=10");
    }

    #[test]
    fn stages_are_independent() {
        let mut e = StageEstimator::new(1, 0.5, 0.4);
        e.record(StageId(0), 100.0, 0.9);
        assert_eq!(e.samples(StageId(1)), 0);
        assert_eq!(e.estimate_r(StageId(1)), 0.4, "other stage keeps prior");
    }

    #[test]
    fn r_estimate_is_clamped() {
        let mut e = StageEstimator::new(1, 0.5, 0.4);
        e.record(StageId(0), 1.0, 7.5); // bogus measurement
        assert_eq!(e.estimate_r(StageId(0)), 1.0);
    }

    #[test]
    fn tiny_inputs_floor_at_half_second() {
        let e = StageEstimator::standard();
        assert_eq!(e.estimate_p(StageId(0), 1), 0.5);
    }
}
