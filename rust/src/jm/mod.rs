//! The replicated job manager (§3.2): one JM per (job, data center).
//!
//! The *primary* JM (pJM) decides the initial cross-DC task assignment
//! (proportional to input data per DC) and coordinates stage releases;
//! every JM — primary or semi-active — *individually* manages its own
//! sub-job: it requests containers from its local master via [`af`],
//! assigns tasks via [`parades`], and participates in cross-DC work
//! stealing. The replicated [`info::IntermediateInfo`] lets any replica
//! take over and *continue* the job after a failure.
//!
//! This module is deliberately simulator-agnostic: the deployment layer
//! (`deploy/`) owns the event loop and calls into these methods, which
//! makes every scheduling decision unit- and property-testable.
//!
//! Paper-to-code map for this module (see `docs/ARCHITECTURE.md` for the
//! whole system): [`af`] is §4.2/Appendix A's adaptive-feedback resource
//! requester, [`parades`] is §4.3/Algorithm 2's delay-scheduling +
//! work-stealing assigner, [`estimator`] is the §5 monitor's per-stage
//! (p, r) estimator, and [`info::IntermediateInfo`] is the replicated
//! state (§5 "intermediate information") that lets a replacement replica
//! *continue* a job instead of restarting it. Container requests pushed
//! by a JM may additionally carry an instance-class preference from the
//! cost-aware bidding subsystem ([`crate::cloud::bidding`]).

pub mod af;
pub mod estimator;
pub mod info;
pub mod parades;

use std::collections::HashMap;

use crate::ids::{ContainerId, DcId, JmId, TaskId};

pub use af::{AfDecision, AfState, PeriodFeedback};
pub use estimator::StageEstimator;
pub use info::{ExecutorEntry, IntermediateInfo, PartitionEntry, Role};
pub use parades::{age_queue, on_update, Assignment, ContainerView, Locality, ParadesParams, WaitingTask};

/// Per-JM counters (Fig 9 / Fig 12b reporting).
#[derive(Debug, Default, Clone)]
pub struct JmStats {
    pub assigned_node_local: u64,
    pub assigned_rack_local: u64,
    pub assigned_any: u64,
    pub tasks_stolen_in: u64,
    pub tasks_stolen_out: u64,
    pub steal_requests_sent: u64,
}

/// One job manager replica.
#[derive(Debug)]
pub struct JobManager {
    pub id: JmId,
    pub role: Role,
    /// Container hosting this JM process itself.
    pub container: ContainerId,
    /// Containers granted by the local master for task execution.
    pub executors: Vec<ContainerId>,
    /// Released tasks waiting for assignment in this DC.
    pub queue: Vec<WaitingTask>,
    /// Running tasks -> container.
    pub running: HashMap<TaskId, ContainerId>,
    pub af: AfState,
    /// Time (secs) of the last UPDATE event — Algorithm 2's aging clock.
    last_update_secs: f64,
    /// Whether any task waited at some point during the current period
    /// (Af's "no waiting tasks" input).
    had_waiting_this_period: bool,
    pub stats: JmStats,
    pub alive: bool,
}

impl JobManager {
    pub fn new(id: JmId, role: Role, container: ContainerId, now_secs: f64) -> Self {
        JobManager {
            id,
            role,
            container,
            executors: Vec::new(),
            queue: Vec::new(),
            running: HashMap::new(),
            af: AfState::default(),
            last_update_secs: now_secs,
            had_waiting_this_period: false,
            stats: JmStats::default(),
            alive: true,
        }
    }

    pub fn dc(&self) -> DcId {
        self.id.dc
    }

    /// Add released tasks to the waiting queue (initial assignment or
    /// re-queue after failure). Waits start at zero.
    pub fn enqueue(&mut self, tasks: impl IntoIterator<Item = WaitingTask>) {
        self.queue.extend(tasks);
        if !self.queue.is_empty() {
            self.had_waiting_this_period = true;
        }
    }

    pub fn has_waiting(&self) -> bool {
        !self.queue.is_empty()
    }

    /// The UPDATE event (Algorithm 2): a container reported free capacity.
    /// Ages the queue by the time since the last event, then matches.
    /// Returns assignments the caller must commit (start tasks, move
    /// queue entries to running).
    pub fn handle_update(
        &mut self,
        n: ContainerView,
        now_secs: f64,
        params: ParadesParams,
    ) -> Vec<Assignment> {
        let elapsed = (now_secs - self.last_update_secs).max(0.0);
        age_queue(&mut self.queue, elapsed);
        self.last_update_secs = now_secs;
        if !self.queue.is_empty() {
            self.had_waiting_this_period = true;
        }
        let picks = on_update(&mut self.queue, n, params, false);
        for a in &picks {
            match a.locality {
                Locality::NodeLocal => self.stats.assigned_node_local += 1,
                Locality::RackLocal => self.stats.assigned_rack_local += 1,
                Locality::Any => self.stats.assigned_any += 1,
                Locality::Stolen => unreachable!("local update can't steal"),
            }
            self.running.insert(a.task.id, a.container);
        }
        picks
    }

    /// ONRECEIVESTEAL (Algorithm 2 line 15): a thief JM of the same job
    /// offers a remote container. Only long-waiting tasks leak out; the
    /// caller transfers returned tasks to the thief.
    pub fn handle_steal_request(
        &mut self,
        thief_container: ContainerView,
        now_secs: f64,
        params: ParadesParams,
    ) -> Vec<Assignment> {
        let elapsed = (now_secs - self.last_update_secs).max(0.0);
        age_queue(&mut self.queue, elapsed);
        self.last_update_secs = now_secs;
        let picks = on_update(&mut self.queue, thief_container, params, true);
        self.stats.tasks_stolen_out += picks.len() as u64;
        picks
    }

    /// The thief side: record tasks stolen from a victim as running here.
    pub fn accept_stolen(&mut self, assignments: &[Assignment]) {
        for a in assignments {
            self.running.insert(a.task.id, a.container);
        }
        self.stats.tasks_stolen_in += assignments.len() as u64;
    }

    /// Task finished on a container.
    pub fn task_done(&mut self, t: TaskId) -> Option<ContainerId> {
        self.running.remove(&t)
    }

    /// A container died: forget it and return the tasks to re-queue
    /// (caller re-enqueues with fresh waits, possibly on another JM).
    pub fn container_lost(&mut self, cid: ContainerId) -> Vec<TaskId> {
        self.executors.retain(|&c| c != cid);
        let mut lost: Vec<TaskId> =
            self.running.iter().filter(|(_, &c)| c == cid).map(|(&t, _)| t).collect();
        lost.sort_unstable(); // HashMap order must not leak into event order
        for t in &lost {
            self.running.remove(t);
        }
        lost
    }

    /// Period boundary: compute Af feedback, advance desire, and return
    /// the new request to push to the master. `utilization` is the
    /// cluster-measured average over this JM's executors.
    pub fn period_tick(
        &mut self,
        utilization: f64,
        allocation: usize,
        delta: f64,
        rho: f64,
        capacity: usize,
    ) -> (usize, AfDecision) {
        let fb = PeriodFeedback {
            utilization,
            allocation,
            had_waiting_tasks: self.had_waiting_this_period || !self.queue.is_empty(),
        };
        let decision = self.af.step(fb, delta, rho, capacity);
        self.had_waiting_this_period = !self.queue.is_empty();
        (self.af.request(), decision)
    }

    /// Containers this JM would give back when its desire dropped below
    /// its allocation: the idle ones first (§5 "aggressively kill the
    /// several containers which firstly become free").
    pub fn surplus_idle_containers(
        &self,
        target: usize,
        container_free: impl Fn(ContainerId) -> f64,
    ) -> Vec<ContainerId> {
        if self.executors.len() <= target {
            return Vec::new();
        }
        let mut idle: Vec<ContainerId> = self
            .executors
            .iter()
            .copied()
            .filter(|&c| container_free(c) >= 1.0 - 1e-9)
            .collect();
        idle.sort_unstable();
        idle.truncate(self.executors.len() - target);
        idle
    }

    /// Snapshot this JM's contribution to the executorList.
    pub fn executor_entries(&self) -> Vec<ExecutorEntry> {
        let mut out = vec![ExecutorEntry {
            container: self.container,
            dc: self.dc(),
            jm_role: Some(self.role),
        }];
        out.extend(self.executors.iter().map(|&c| ExecutorEntry {
            container: c,
            dc: self.dc(),
            jm_role: None,
        }));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::{JobId, NodeId, StageId};

    const PARAMS: ParadesParams = ParadesParams { delta: 0.7, tau: 0.5 };

    fn jm_at(dc: usize) -> JobManager {
        JobManager::new(
            JmId { job: JobId(1), dc: DcId(dc) },
            if dc == 0 { Role::Primary } else { Role::SemiActive },
            ContainerId(100 + dc as u64),
            0.0,
        )
    }

    fn wt(i: u32, pref: Option<NodeId>) -> WaitingTask {
        WaitingTask {
            id: TaskId { job: JobId(1), stage: StageId(0), index: i },
            r: 0.5,
            p: 4.0,
            input_bytes: 1,
            pref_node: pref,
            pref_rack: pref.map(|n| (n.dc, n.idx % 2)),
            wait: 0.0,
        }
    }

    fn view(dc: usize, idx: usize, free: f64) -> ContainerView {
        ContainerView {
            id: ContainerId(7),
            node: NodeId { dc: DcId(dc), idx },
            rack: idx % 2,
            free,
        }
    }

    #[test]
    fn update_ages_then_assigns_and_tracks_running() {
        let mut jm = jm_at(0);
        jm.enqueue([wt(0, Some(NodeId { dc: DcId(0), idx: 1 }))]);
        // First update at t=3 on the wrong node: task ages to 3 s but
        // 3 < tau*p=2? no: tau*p = 2 -> rack threshold passed; wrong rack
        // though (node 0 rack 0 vs pref rack 1). Any needs 4 s.
        let picks = jm.handle_update(view(0, 0, 1.0), 3.0, PARAMS);
        assert!(picks.is_empty());
        // t=5: wait=5 ≥ 2*tau*p=4 -> any placement.
        let picks = jm.handle_update(view(0, 0, 1.0), 5.0, PARAMS);
        assert_eq!(picks.len(), 1);
        assert_eq!(jm.running.len(), 1);
        assert!(!jm.has_waiting());
        assert_eq!(jm.stats.assigned_any, 1);
        // Completion clears it.
        let c = jm.task_done(picks[0].task.id).unwrap();
        assert_eq!(c, ContainerId(7));
        assert!(jm.running.is_empty());
    }

    #[test]
    fn steal_roundtrip_between_jms() {
        let mut victim = jm_at(1);
        let mut thief = jm_at(2);
        let pref = NodeId { dc: DcId(1), idx: 0 };
        victim.enqueue([wt(0, Some(pref)), wt(1, Some(pref))]);
        // Long wait so the steal gate (2*tau*p = 4 s) passes.
        let picks = victim.handle_steal_request(view(2, 0, 1.0), 10.0, PARAMS);
        assert_eq!(picks.len(), 2);
        assert_eq!(victim.stats.tasks_stolen_out, 2);
        assert_eq!(victim.queue.len(), 0);
        thief.accept_stolen(&picks);
        assert_eq!(thief.stats.tasks_stolen_in, 2);
        assert_eq!(thief.running.len(), 2);
    }

    #[test]
    fn container_lost_requeues_tasks() {
        let mut jm = jm_at(0);
        jm.executors = vec![ContainerId(7), ContainerId(8)];
        jm.enqueue([wt(0, None)]);
        let picks = jm.handle_update(view(0, 0, 1.0), 100.0, PARAMS);
        assert_eq!(picks.len(), 1);
        let lost = jm.container_lost(ContainerId(7));
        assert_eq!(lost, vec![picks[0].task.id]);
        assert_eq!(jm.executors, vec![ContainerId(8)]);
        assert!(jm.running.is_empty());
    }

    #[test]
    fn period_tick_tracks_waiting_flag() {
        let mut jm = jm_at(0);
        // Bootstrap.
        let (req, dec) = jm.period_tick(0.0, 0, 0.7, 1.5, 16);
        assert_eq!((req, dec), (1, AfDecision::Bootstrap));
        // Tasks queued during the period -> not inefficient even if idle.
        jm.enqueue([wt(0, None)]);
        let picks = jm.handle_update(view(0, 0, 1.0), 100.0, PARAMS);
        assert_eq!(picks.len(), 1);
        let (_, dec) = jm.period_tick(0.1, 1, 0.7, 1.5, 16);
        assert_ne!(dec, AfDecision::Inefficient, "waiting happened this period");
        // Next period: nothing waited, idle -> inefficient.
        let (_, dec) = jm.period_tick(0.1, 1, 0.7, 1.5, 16);
        assert_eq!(dec, AfDecision::Inefficient);
    }

    #[test]
    fn surplus_returns_only_idle_containers() {
        let mut jm = jm_at(0);
        jm.executors = vec![ContainerId(1), ContainerId(2), ContainerId(3), ContainerId(4)];
        // Containers 1 and 3 are idle, 2 and 4 busy.
        let free = |c: ContainerId| if c.0 % 2 == 1 { 1.0 } else { 0.4 };
        let surplus = jm.surplus_idle_containers(1, free);
        assert_eq!(surplus, vec![ContainerId(1), ContainerId(3)]);
        // Target met already -> nothing.
        assert!(jm.surplus_idle_containers(4, free).is_empty());
        // Can't return busy ones even if target is 0.
        assert_eq!(jm.surplus_idle_containers(0, free).len(), 2);
    }

    #[test]
    fn executor_entries_include_self_with_role() {
        let mut jm = jm_at(3);
        jm.executors = vec![ContainerId(50)];
        let entries = jm.executor_entries();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].jm_role, Some(Role::SemiActive));
        assert_eq!(entries[1].jm_role, None);
        assert!(entries.iter().all(|e| e.dc == DcId(3)));
    }
}
