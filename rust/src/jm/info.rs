//! The job's intermediate information (Fig 4(b), §3.2.1) and its wire
//! encoding.
//!
//! HOUTU replicates, per job, exactly the state needed to *continue* (not
//! restart) after a JM failure: jobId, the released stages, the
//! executorList (available executors from all DCs plus JM roles), the
//! taskMap (which JM owns which task) and the partitionList (output
//! partition locations reported by finished tasks). The paper measures
//! these at 30–45 KB for large jobs (Fig 12a) — small enough for
//! Zookeeper. We serialize with a fixed little-endian layout (no serde in
//! the image) and measure real encoded sizes for the Fig 12a
//! reproduction.

use crate::ids::{ContainerId, DcId, JobId, NodeId, StageId, TaskId};

/// Role of a JM replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Primary,
    SemiActive,
}

impl Role {
    fn to_byte(self) -> u8 {
        match self {
            Role::Primary => 0,
            Role::SemiActive => 1,
        }
    }
    fn from_byte(b: u8) -> Result<Role, String> {
        match b {
            0 => Ok(Role::Primary),
            1 => Ok(Role::SemiActive),
            _ => Err(format!("bad role byte {b}")),
        }
    }
}

/// One executorList entry: a container granted somewhere, plus whether a
/// JM (and which role) runs in it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecutorEntry {
    pub container: ContainerId,
    pub dc: DcId,
    pub jm_role: Option<Role>,
}

/// One partitionList entry: a finished task's output location and size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionEntry {
    pub task: TaskId,
    pub node: NodeId,
    pub bytes: u64,
}

/// The replicated intermediate information of one job.
#[derive(Debug, Clone, PartialEq)]
pub struct IntermediateInfo {
    pub job: JobId,
    /// Highest released stage per the pJM (stageId in Fig 4b).
    pub released_stages: Vec<StageId>,
    pub executor_list: Vec<ExecutorEntry>,
    /// task -> owning JM's DC.
    pub task_map: Vec<(TaskId, DcId)>,
    pub partition_list: Vec<PartitionEntry>,
}

impl Default for IntermediateInfo {
    fn default() -> Self {
        IntermediateInfo {
            job: JobId(0),
            released_stages: Vec::new(),
            executor_list: Vec::new(),
            task_map: Vec::new(),
            partition_list: Vec::new(),
        }
    }
}

struct Writer(Vec<u8>);

impl Writer {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn task(&mut self, t: TaskId) {
        self.u64(t.job.0);
        self.u32(t.stage.0);
        self.u32(t.index);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!("truncated at {}+{n}/{}", self.pos, self.buf.len()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn task(&mut self) -> Result<TaskId, String> {
        Ok(TaskId { job: JobId(self.u64()?), stage: StageId(self.u32()?), index: self.u32()? })
    }
}

const MAGIC: u32 = 0x484F5554; // "HOUT"
const VERSION: u8 = 1;

impl IntermediateInfo {
    /// Serialize to the replicated wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer(Vec::with_capacity(
            64 + 20 * self.task_map.len() + 36 * self.partition_list.len(),
        ));
        w.u32(MAGIC);
        w.u8(VERSION);
        w.u64(self.job.0);
        w.u32(self.released_stages.len() as u32);
        for s in &self.released_stages {
            w.u32(s.0);
        }
        w.u32(self.executor_list.len() as u32);
        for e in &self.executor_list {
            w.u64(e.container.0);
            w.u32(e.dc.0 as u32);
            match e.jm_role {
                None => w.u8(0xFF),
                Some(r) => w.u8(r.to_byte()),
            }
        }
        w.u32(self.task_map.len() as u32);
        for (t, dc) in &self.task_map {
            w.task(*t);
            w.u32(dc.0 as u32);
        }
        w.u32(self.partition_list.len() as u32);
        for p in &self.partition_list {
            w.task(p.task);
            w.u32(p.node.dc.0 as u32);
            w.u32(p.node.idx as u32);
            w.u64(p.bytes);
        }
        w.0
    }

    /// Deserialize; strict — any trailing/truncated bytes are an error.
    pub fn decode(buf: &[u8]) -> Result<IntermediateInfo, String> {
        let mut r = Reader { buf, pos: 0 };
        if r.u32()? != MAGIC {
            return Err("bad magic".into());
        }
        let v = r.u8()?;
        if v != VERSION {
            return Err(format!("unsupported version {v}"));
        }
        let job = JobId(r.u64()?);
        let ns = r.u32()? as usize;
        let mut released_stages = Vec::with_capacity(ns);
        for _ in 0..ns {
            released_stages.push(StageId(r.u32()?));
        }
        let ne = r.u32()? as usize;
        let mut executor_list = Vec::with_capacity(ne);
        for _ in 0..ne {
            let container = ContainerId(r.u64()?);
            let dc = DcId(r.u32()? as usize);
            let role = match r.u8()? {
                0xFF => None,
                b => Some(Role::from_byte(b)?),
            };
            executor_list.push(ExecutorEntry { container, dc, jm_role: role });
        }
        let nt = r.u32()? as usize;
        let mut task_map = Vec::with_capacity(nt);
        for _ in 0..nt {
            let t = r.task()?;
            task_map.push((t, DcId(r.u32()? as usize)));
        }
        let np = r.u32()? as usize;
        let mut partition_list = Vec::with_capacity(np);
        for _ in 0..np {
            let task = r.task()?;
            let dc = DcId(r.u32()? as usize);
            let idx = r.u32()? as usize;
            let bytes = r.u64()?;
            partition_list.push(PartitionEntry { task, node: NodeId { dc, idx }, bytes });
        }
        if r.pos != buf.len() {
            return Err(format!("{} trailing bytes", buf.len() - r.pos));
        }
        Ok(IntermediateInfo { job, released_stages, executor_list, task_map, partition_list })
    }

    /// Encoded size in bytes (what Fig 12a plots).
    pub fn encoded_size(&self) -> usize {
        13 + 4
            + 4 * self.released_stages.len()
            + 4
            + 13 * self.executor_list.len()
            + 4
            + 20 * self.task_map.len()
            + 4
            + 32 * self.partition_list.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> IntermediateInfo {
        let job = JobId(3);
        IntermediateInfo {
            job,
            released_stages: vec![StageId(0), StageId(1)],
            executor_list: vec![
                ExecutorEntry { container: ContainerId(5), dc: DcId(0), jm_role: Some(Role::Primary) },
                ExecutorEntry { container: ContainerId(9), dc: DcId(2), jm_role: Some(Role::SemiActive) },
                ExecutorEntry { container: ContainerId(11), dc: DcId(1), jm_role: None },
            ],
            task_map: vec![
                (TaskId { job, stage: StageId(0), index: 0 }, DcId(0)),
                (TaskId { job, stage: StageId(0), index: 1 }, DcId(3)),
            ],
            partition_list: vec![PartitionEntry {
                task: TaskId { job, stage: StageId(0), index: 0 },
                node: NodeId { dc: DcId(0), idx: 2 },
                bytes: 123456,
            }],
        }
    }

    #[test]
    fn roundtrip() {
        let info = sample();
        let bytes = info.encode();
        let back = IntermediateInfo::decode(&bytes).unwrap();
        assert_eq!(info, back);
    }

    #[test]
    fn encoded_size_matches_actual() {
        let info = sample();
        assert_eq!(info.encode().len(), info.encoded_size());
    }

    #[test]
    fn rejects_corruption() {
        let info = sample();
        let mut bytes = info.encode();
        assert!(IntermediateInfo::decode(&bytes[..bytes.len() - 1]).is_err(), "truncated");
        bytes.push(0);
        assert!(IntermediateInfo::decode(&bytes).is_err(), "trailing");
        let mut bad = info.encode();
        bad[0] ^= 0xFF;
        assert!(IntermediateInfo::decode(&bad).is_err(), "bad magic");
        let mut badv = info.encode();
        badv[4] = 99;
        assert!(IntermediateInfo::decode(&badv).is_err(), "bad version");
    }

    #[test]
    fn empty_info_roundtrips() {
        let info = IntermediateInfo { job: JobId(0), ..Default::default() };
        assert_eq!(IntermediateInfo::decode(&info.encode()).unwrap(), info);
    }

    /// Property: arbitrary intermediate info round-trips exactly.
    #[test]
    fn prop_roundtrip_random() {
        use crate::testkit::{forall, Gen};
        use crate::util::Pcg;
        struct InfoGen;
        impl Gen<IntermediateInfo> for InfoGen {
            fn generate(&self, rng: &mut Pcg) -> IntermediateInfo {
                let job = JobId(rng.below(1000));
                let tid = |rng: &mut Pcg| TaskId {
                    job,
                    stage: StageId(rng.below(8) as u32),
                    index: rng.below(200) as u32,
                };
                IntermediateInfo {
                    job,
                    released_stages: (0..rng.index(6)).map(|i| StageId(i as u32)).collect(),
                    executor_list: (0..rng.index(70))
                        .map(|_| ExecutorEntry {
                            container: ContainerId(rng.below(1 << 40)),
                            dc: DcId(rng.index(4)),
                            jm_role: match rng.index(3) {
                                0 => None,
                                1 => Some(Role::Primary),
                                _ => Some(Role::SemiActive),
                            },
                        })
                        .collect(),
                    task_map: (0..rng.index(150)).map(|_| { let t = tid(rng); (t, DcId(rng.index(4))) }).collect(),
                    partition_list: (0..rng.index(150))
                        .map(|_| PartitionEntry {
                            task: tid(rng),
                            node: NodeId { dc: DcId(rng.index(4)), idx: rng.index(5) },
                            bytes: rng.next_u64() >> 20,
                        })
                        .collect(),
                }
            }
        }
        forall(0x1F0, &InfoGen, |info: &IntermediateInfo| {
            let bytes = info.encode();
            crate::prop_assert!(bytes.len() == info.encoded_size(), "size prediction");
            let back = IntermediateInfo::decode(&bytes).map_err(|e| e)?;
            crate::prop_assert!(&back == info, "roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn large_job_info_is_tens_of_kb() {
        // Shape check against Fig 12a: a large job (hundreds of tasks, 64
        // executors) encodes to the tens-of-KB range, small enough for zk.
        let job = JobId(1);
        let tid = |s: u32, i: u32| TaskId { job, stage: StageId(s), index: i };
        let info = IntermediateInfo {
            job,
            released_stages: (0..7).map(StageId).collect(),
            executor_list: (0..64)
                .map(|i| ExecutorEntry {
                    container: ContainerId(i),
                    dc: DcId((i % 4) as usize),
                    jm_role: if i < 4 { Some(Role::SemiActive) } else { None },
                })
                .collect(),
            task_map: (0..7).flat_map(|s| (0..80).map(move |i| (tid(s, i), DcId(0)))).collect(),
            partition_list: (0..7)
                .flat_map(|s| {
                    (0..80).map(move |i| PartitionEntry {
                        task: tid(s, i),
                        node: NodeId { dc: DcId(0), idx: 0 },
                        bytes: 1,
                    })
                })
                .collect(),
        };
        let kb = info.encode().len() as f64 / 1024.0;
        assert!((10.0..100.0).contains(&kb), "{kb} KB");
    }
}
