//! Af — the Adaptive feedback algorithm (Algorithm 1, §4.2).
//!
//! Each job manager runs Af independently at every period boundary to set
//! its *desire* `d(q)` — how many containers to request from its local
//! master — from pure feedback: last period's desire, allocation and
//! measured utilization, plus whether tasks are waiting. No future job
//! characteristics are used (semi-clairvoyance).
//!
//! Period classification (after [12] / COBRA [53]):
//! * **inefficient**  — `u(q−1) < δ` and no waiting tasks → shrink by ρ;
//! * **efficient & deprived** — allocation fell short of desire
//!   (`a < d`): the sub-job used what it got, keep the desire;
//! * **efficient & satisfied** — got all it asked and used it → grow by ρ.

/// Af state carried by a job manager for one sub-job.
#[derive(Debug, Clone)]
pub struct AfState {
    /// Continuous desire; the request pushed to the master is
    /// `ceil(desire)` clamped to [1, capacity].
    pub desire: f64,
    /// Period counter `q` (1-based; q=1 bootstraps d=1).
    pub period: u64,
}

impl Default for AfState {
    fn default() -> Self {
        AfState { desire: 1.0, period: 0 }
    }
}

/// Inputs measured over the closing period `q−1`.
#[derive(Debug, Clone, Copy)]
pub struct PeriodFeedback {
    /// Average utilization of the sub-job's containers, in [0, 1].
    pub utilization: f64,
    /// Containers actually allocated by the master for the period.
    pub allocation: usize,
    /// Whether any task of the sub-job waited during the period.
    pub had_waiting_tasks: bool,
}

/// Why Af chose what it chose (for traces / tests / Fig 9 narration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AfDecision {
    Bootstrap,
    Inefficient,
    EfficientDeprived,
    EfficientSatisfied,
}

impl AfState {
    /// Advance one period (Algorithm 1). Returns the decision taken.
    /// `delta` = utilization threshold δ, `rho` = adjustment factor ρ > 1,
    /// `capacity` = |P_j|, the ceiling on any desire.
    pub fn step(
        &mut self,
        fb: PeriodFeedback,
        delta: f64,
        rho: f64,
        capacity: usize,
    ) -> AfDecision {
        self.period += 1;
        let decision = if self.period == 1 {
            self.desire = 1.0;
            AfDecision::Bootstrap
        } else if fb.utilization < delta && !fb.had_waiting_tasks {
            self.desire /= rho;
            AfDecision::Inefficient
        } else if self.request() > fb.allocation {
            AfDecision::EfficientDeprived
        } else {
            self.desire *= rho;
            AfDecision::EfficientSatisfied
        };
        self.desire = self.desire.clamp(1.0, capacity.max(1) as f64);
        decision
    }

    /// The integral container request pushed to the master.
    pub fn request(&self) -> usize {
        self.desire.ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DELTA: f64 = 0.7;
    const RHO: f64 = 1.5;
    const CAP: usize = 16;

    fn fb(u: f64, a: usize, waiting: bool) -> PeriodFeedback {
        PeriodFeedback { utilization: u, allocation: a, had_waiting_tasks: waiting }
    }

    #[test]
    fn first_period_bootstraps_to_one() {
        let mut af = AfState::default();
        let d = af.step(fb(0.0, 0, false), DELTA, RHO, CAP);
        assert_eq!(d, AfDecision::Bootstrap);
        assert_eq!(af.request(), 1);
    }

    #[test]
    fn efficient_satisfied_grows_geometrically() {
        let mut af = AfState::default();
        af.step(fb(0.0, 0, false), DELTA, RHO, CAP); // q=1
        // Fully used, fully granted -> multiply by rho each period.
        let mut seen = vec![af.request()];
        for _ in 0..6 {
            let a = af.request();
            let d = af.step(fb(0.9, a, true), DELTA, RHO, CAP);
            assert_eq!(d, AfDecision::EfficientSatisfied);
            seen.push(af.request());
        }
        assert!(seen.windows(2).all(|w| w[1] >= w[0]), "monotone growth {seen:?}");
        assert_eq!(*seen.last().unwrap(), CAP.min(seen.last().copied().unwrap()));
        // 1 * 1.5^6 ≈ 11.4 -> request 12.
        assert_eq!(af.request(), 12);
    }

    #[test]
    fn deprived_holds_desire() {
        let mut af = AfState::default();
        af.step(fb(0.0, 0, false), DELTA, RHO, CAP);
        af.step(fb(0.9, 1, true), DELTA, RHO, CAP); // grow to 1.5 -> req 2
        let before = af.desire;
        // Master gave less than requested, sub-job stayed busy.
        let d = af.step(fb(0.95, af.request() - 1, true), DELTA, RHO, CAP);
        assert_eq!(d, AfDecision::EfficientDeprived);
        assert_eq!(af.desire, before, "desire held");
    }

    #[test]
    fn inefficient_shrinks() {
        let mut af = AfState::default();
        af.step(fb(0.0, 0, false), DELTA, RHO, CAP);
        for _ in 0..4 {
            let a = af.request();
            af.step(fb(1.0, a, true), DELTA, RHO, CAP);
        }
        let grown = af.desire;
        assert!(grown > 3.0);
        let d = af.step(fb(0.1, af.request(), false), DELTA, RHO, CAP);
        assert_eq!(d, AfDecision::Inefficient);
        assert!((af.desire - grown / RHO).abs() < 1e-9);
    }

    #[test]
    fn low_utilization_with_waiting_tasks_is_not_inefficient() {
        // Waiting tasks mean the sub-job *wants* resources even if current
        // containers idle (e.g. locality delays) — Af must not shrink.
        let mut af = AfState::default();
        af.step(fb(0.0, 0, false), DELTA, RHO, CAP);
        af.step(fb(0.9, 1, true), DELTA, RHO, CAP);
        let before = af.desire;
        let d = af.step(fb(0.2, af.request(), true), DELTA, RHO, CAP);
        assert_ne!(d, AfDecision::Inefficient);
        assert!(af.desire >= before);
    }

    #[test]
    fn desire_bounded_by_capacity_and_floor() {
        let mut af = AfState::default();
        af.step(fb(0.0, 0, false), DELTA, RHO, CAP);
        for _ in 0..50 {
            let a = af.request();
            af.step(fb(1.0, a, true), DELTA, RHO, CAP);
        }
        assert_eq!(af.request(), CAP, "capped at capacity");
        for _ in 0..50 {
            af.step(fb(0.0, af.request(), false), DELTA, RHO, CAP);
        }
        assert_eq!(af.request(), 1, "never below one");
    }

    /// Property: desire stays in [1, cap] and reacts in the right
    /// direction for random feedback sequences.
    #[test]
    fn prop_af_bounds_and_monotonicity() {
        use crate::testkit::{forall, F64In, Gen, VecOf};
        use crate::util::Pcg;
        struct FbGen;
        impl Gen<(f64, usize, bool)> for FbGen {
            fn generate(&self, rng: &mut Pcg) -> (f64, usize, bool) {
                (rng.f64(), rng.index(17), rng.chance(0.5))
            }
        }
        let gen = VecOf { elem: FbGen, min_len: 1, max_len: 40 };
        let _ = F64In(0.0, 1.0); // (kept for symmetry with other props)
        forall(0xAF, &gen, |seq: &Vec<(f64, usize, bool)>| {
            let mut af = AfState::default();
            for &(u, a, w) in seq {
                let before = af.desire;
                let dec = af.step(fb(u, a, w), DELTA, RHO, CAP);
                crate::prop_assert!(
                    (1.0..=CAP as f64 + 1e-9).contains(&af.desire),
                    "desire {} out of bounds",
                    af.desire
                );
                match dec {
                    AfDecision::Inefficient => crate::prop_assert!(
                        af.desire <= before + 1e-12,
                        "inefficient must not grow"
                    ),
                    AfDecision::EfficientSatisfied => crate::prop_assert!(
                        af.desire + 1e-12 >= before,
                        "satisfied must not shrink"
                    ),
                    AfDecision::EfficientDeprived => crate::prop_assert!(
                        (af.desire - before.clamp(1.0, CAP as f64)).abs() < 1e-9,
                        "deprived must hold"
                    ),
                    AfDecision::Bootstrap => {}
                }
            }
            Ok(())
        });
    }
}
