//! Parades — Parameterized delay scheduling with work stealing
//! (Algorithm 2, §4.3).
//!
//! Applied by every job manager on each container-update event. Extends
//! classic delay scheduling [50] in two ways:
//!
//! 1. **Parameterized thresholds**: a task may relax from node-local to
//!    rack-local after waiting `τ·p` (its own processing time scales the
//!    patience — long tasks can afford to wait for locality), and to
//!    *any* placement after `2τ·p` provided the container is nearly empty
//!    (`free ≥ 1 − δ`, which with the assumption `r + δ ≤ 1` guarantees
//!    fit).
//! 2. **Work stealing**: a JM whose queue is empty turns thief and offers
//!    its free container to the other JMs of the same job; each victim
//!    treats the offer as an UPDATE event on a remote container — only
//!    tasks that already waited past `2τ·p` leak across DCs, so steals
//!    happen only after the thief exhausted its own work (§6.3).
//!
//! This module is pure scheduling logic over a waiting queue and a
//! container view — no simulator types — so the invariants (no
//! over-commit, threshold gating, conservation) are directly
//! property-testable.

use crate::ids::{ContainerId, DcId, NodeId, TaskId};

/// A released-but-unassigned task as the JM sees it.
#[derive(Debug, Clone)]
pub struct WaitingTask {
    pub id: TaskId,
    /// Peak resource requirement (normalized).
    pub r: f64,
    /// Known processing time (tasks in a stage share characteristics; the
    /// implementation estimates from finished siblings, §5).
    pub p: f64,
    pub input_bytes: u64,
    /// Preferred node (input block location); None = no locality
    /// preference (shuffle task whose inputs are spread out).
    pub pref_node: Option<NodeId>,
    /// Preferred rack within the preferred node's DC.
    pub pref_rack: Option<(DcId, usize)>,
    /// Accumulated waiting time (seconds since release / last failure).
    pub wait: f64,
}

/// The free container as seen at an UPDATE event (Algorithm 2's `n`).
#[derive(Debug, Clone, Copy)]
pub struct ContainerView {
    pub id: ContainerId,
    pub node: NodeId,
    pub rack: usize,
    pub free: f64,
}

/// How a task matched its container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Locality {
    NodeLocal,
    RackLocal,
    Any,
    /// Assigned to a *remote* JM's container through a steal.
    Stolen,
}

impl Locality {
    /// Compact tag for trace events and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Locality::NodeLocal => "node-local",
            Locality::RackLocal => "rack-local",
            Locality::Any => "any",
            Locality::Stolen => "stolen",
        }
    }
}

/// One assignment decided by Parades.
#[derive(Debug, Clone)]
pub struct Assignment {
    pub task: WaitingTask,
    pub container: ContainerId,
    pub locality: Locality,
}

/// Tunables lifted from the config.
#[derive(Debug, Clone, Copy)]
pub struct ParadesParams {
    pub delta: f64,
    pub tau: f64,
}

/// Add `elapsed` seconds of waiting to every queued task (Algorithm 2
/// line 2: "increase t_ij.wait by the time since last event UPDATE").
pub fn age_queue(queue: &mut [WaitingTask], elapsed: f64) {
    debug_assert!(elapsed >= 0.0);
    for t in queue {
        t.wait += elapsed;
    }
}

fn fits(free: f64, r: f64) -> bool {
    free + 1e-9 >= r
}

/// The task-assignment procedure of ONUPDATE (lines 5–14): repeatedly
/// match the free container against the queue until nothing fits.
/// Matched tasks are removed from `queue` and returned with their
/// locality level; `steal` marks assignments made on behalf of a remote
/// thief (ONRECEIVESTEAL), which go through the *any* clause only.
pub fn on_update(
    queue: &mut Vec<WaitingTask>,
    n: ContainerView,
    params: ParadesParams,
    steal: bool,
) -> Vec<Assignment> {
    let mut free = n.free;
    let mut out = Vec::new();
    loop {
        let pick = pick_one(queue, n, free, params, steal);
        let Some((idx, locality)) = pick else { break };
        let task = queue.swap_remove(idx);
        free -= task.r;
        out.push(Assignment { task, container: n.id, locality });
        if free <= 1e-9 {
            break;
        }
    }
    out
}

/// One round of the matching cascade. Returns (queue index, locality).
/// Ties break toward the longest-waiting task, then smallest id, for
/// determinism and FIFO fairness.
fn pick_one(
    queue: &[WaitingTask],
    n: ContainerView,
    free: f64,
    params: ParadesParams,
    steal: bool,
) -> Option<(usize, Locality)> {
    let better = |a: (f64, TaskId), b: (f64, TaskId)| -> bool {
        // Longer wait wins; tie -> smaller TaskId.
        a.0 > b.0 + 1e-12 || ((a.0 - b.0).abs() <= 1e-12 && a.1 < b.1)
    };
    if !steal {
        // 1. Node-local.
        let mut best: Option<(usize, f64, TaskId)> = None;
        for (i, t) in queue.iter().enumerate() {
            if t.pref_node == Some(n.node) && fits(free, t.r) {
                let key = (t.wait, t.id);
                if best.is_none() || better(key, (best.unwrap().1, best.unwrap().2)) {
                    best = Some((i, t.wait, t.id));
                }
            }
        }
        if let Some((i, _, _)) = best {
            return Some((i, Locality::NodeLocal));
        }
        // 2. Rack-local, gated by wait >= tau * p.
        let mut best: Option<(usize, f64, TaskId)> = None;
        for (i, t) in queue.iter().enumerate() {
            let rack_match = t.pref_rack == Some((n.node.dc, n.rack));
            if rack_match && fits(free, t.r) && t.wait + 1e-12 >= params.tau * t.p {
                let key = (t.wait, t.id);
                if best.is_none() || better(key, (best.unwrap().1, best.unwrap().2)) {
                    best = Some((i, t.wait, t.id));
                }
            }
        }
        if let Some((i, _, _)) = best {
            return Some((i, Locality::RackLocal));
        }
    }
    // 3. Any placement: wait >= 2 tau p AND container nearly free
    //    (free >= 1 - delta). For steals this is the only clause.
    if free + 1e-9 >= 1.0 - params.delta {
        let mut best: Option<(usize, f64, TaskId)> = None;
        for (i, t) in queue.iter().enumerate() {
            if fits(free, t.r) && t.wait + 1e-12 >= 2.0 * params.tau * t.p {
                let key = (t.wait, t.id);
                if best.is_none() || better(key, (best.unwrap().1, best.unwrap().2)) {
                    best = Some((i, t.wait, t.id));
                }
            }
        }
        if let Some((i, _, _)) = best {
            return Some((i, if steal { Locality::Stolen } else { Locality::Any }));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::{JobId, StageId};

    const PARAMS: ParadesParams = ParadesParams { delta: 0.7, tau: 0.5 };

    fn tid(i: u32) -> TaskId {
        TaskId { job: JobId(1), stage: StageId(0), index: i }
    }

    fn node(dc: usize, idx: usize) -> NodeId {
        NodeId { dc: DcId(dc), idx }
    }

    fn task(i: u32, r: f64, p: f64, pref: Option<NodeId>, wait: f64) -> WaitingTask {
        WaitingTask {
            id: tid(i),
            r,
            p,
            input_bytes: 1 << 20,
            pref_node: pref,
            pref_rack: pref.map(|nd| (nd.dc, nd.idx % 2)),
            wait,
        }
    }

    fn container(dc: usize, idx: usize, free: f64) -> ContainerView {
        ContainerView { id: ContainerId(9), node: node(dc, idx), rack: idx % 2, free }
    }

    #[test]
    fn node_local_assigned_immediately() {
        let mut q = vec![task(0, 0.5, 10.0, Some(node(0, 0)), 0.0)];
        let picks = on_update(&mut q, container(0, 0, 1.0), PARAMS, false);
        assert_eq!(picks.len(), 1);
        assert_eq!(picks[0].locality, Locality::NodeLocal);
        assert!(q.is_empty());
    }

    #[test]
    fn rack_local_waits_for_tau_p() {
        // Task prefers node 0; container is node 2, same rack (both even).
        let mk = |wait| vec![task(0, 0.5, 10.0, Some(node(0, 0)), wait)];
        // tau*p = 5 s: below -> refuse rack-local.
        let mut q = mk(4.9);
        assert!(on_update(&mut q, container(0, 2, 1.0), PARAMS, false).is_empty());
        // Past the threshold -> rack-local.
        let mut q = mk(5.1);
        let picks = on_update(&mut q, container(0, 2, 1.0), PARAMS, false);
        assert_eq!(picks[0].locality, Locality::RackLocal);
    }

    #[test]
    fn any_placement_needs_double_threshold_and_empty_container() {
        // Container in a different DC & rack entirely.
        let mk = |wait| vec![task(0, 0.2, 10.0, Some(node(0, 0)), wait)];
        let mut q = mk(9.0); // < 2*tau*p = 10
        assert!(on_update(&mut q, container(1, 1, 1.0), PARAMS, false).is_empty());
        let mut q = mk(10.5);
        let picks = on_update(&mut q, container(1, 1, 1.0), PARAMS, false);
        assert_eq!(picks[0].locality, Locality::Any);
        // Same wait but container too full: free < 1 - delta = 0.3.
        let mut q = mk(10.5);
        assert!(on_update(&mut q, container(1, 1, 0.25), PARAMS, false).is_empty());
    }

    #[test]
    fn no_preference_tasks_use_any_clause() {
        // Shuffle task (no pref): scheduled via the any clause once waited.
        let mut q = vec![WaitingTask {
            id: tid(0),
            r: 0.3,
            p: 2.0,
            input_bytes: 0,
            pref_node: None,
            pref_rack: None,
            wait: 2.1, // 2*tau*p = 2.0
        }];
        let picks = on_update(&mut q, container(3, 1, 1.0), PARAMS, false);
        assert_eq!(picks.len(), 1);
        assert_eq!(picks[0].locality, Locality::Any);
    }

    #[test]
    fn packs_multiple_tasks_until_full() {
        let mut q = vec![
            task(0, 0.4, 10.0, Some(node(0, 0)), 0.0),
            task(1, 0.4, 10.0, Some(node(0, 0)), 0.0),
            task(2, 0.4, 10.0, Some(node(0, 0)), 0.0),
        ];
        let picks = on_update(&mut q, container(0, 0, 1.0), PARAMS, false);
        assert_eq!(picks.len(), 2, "only 2×0.4 fit");
        assert_eq!(q.len(), 1);
        let total: f64 = picks.iter().map(|a| a.task.r).sum();
        assert!(total <= 1.0 + 1e-9);
    }

    #[test]
    fn longest_waiting_wins_ties_deterministic() {
        let mut q = vec![
            task(5, 0.5, 10.0, Some(node(0, 0)), 3.0),
            task(2, 0.5, 10.0, Some(node(0, 0)), 8.0),
            task(9, 0.5, 10.0, Some(node(0, 0)), 8.0),
        ];
        let picks = on_update(&mut q, container(0, 0, 1.0), PARAMS, false);
        assert_eq!(picks[0].task.id, tid(2), "longest wait, smallest id first");
        assert_eq!(picks[1].task.id, tid(9));
    }

    #[test]
    fn steal_only_takes_long_waiting_tasks() {
        // Victim queue: one fresh node-local task, one long-waiting task.
        let mut q = vec![
            task(0, 0.3, 10.0, Some(node(0, 0)), 0.5),
            task(1, 0.3, 10.0, Some(node(0, 1)), 11.0), // > 2*tau*p
        ];
        // Thief's container is in DC 2 — steal path.
        let picks = on_update(&mut q, container(2, 0, 1.0), PARAMS, true);
        assert_eq!(picks.len(), 1);
        assert_eq!(picks[0].task.id, tid(1));
        assert_eq!(picks[0].locality, Locality::Stolen);
        assert_eq!(q.len(), 1, "fresh task stays home");
    }

    #[test]
    fn steal_ignores_node_and_rack_clauses() {
        // Even a would-be node-local match must pass the 2τp gate when the
        // update is a steal (the thief's container can never be local —
        // guard against id collisions across DCs).
        let mut q = vec![task(0, 0.3, 10.0, Some(node(2, 0)), 0.0)];
        let picks = on_update(&mut q, container(2, 0, 1.0), PARAMS, true);
        assert!(picks.is_empty());
    }

    #[test]
    fn aging_accumulates() {
        let mut q = vec![task(0, 0.5, 10.0, None, 0.0), task(1, 0.5, 10.0, None, 2.0)];
        age_queue(&mut q, 3.0);
        assert_eq!(q[0].wait, 3.0);
        assert_eq!(q[1].wait, 5.0);
    }

    /// Property: over random queues/containers, Parades (1) never
    /// over-commits the container, (2) conserves tasks, (3) every
    /// non-node-local assignment satisfies its waiting-time gate, and
    /// (4) the any-clause only fires on nearly-free containers.
    #[test]
    fn prop_parades_invariants() {
        use crate::testkit::{forall_cases, Gen};
        use crate::util::Pcg;

        #[derive(Clone, Debug)]
        struct Case {
            tasks: Vec<WaitingTask>,
            n: ContainerView,
            steal: bool,
        }
        struct CaseGen;
        impl Gen<Case> for CaseGen {
            fn generate(&self, rng: &mut Pcg) -> Case {
                let ntasks = rng.index(12);
                let tasks = (0..ntasks)
                    .map(|i| {
                        let pref = if rng.chance(0.7) {
                            Some(NodeId { dc: DcId(rng.index(3)), idx: rng.index(4) })
                        } else {
                            None
                        };
                        WaitingTask {
                            id: TaskId { job: JobId(1), stage: StageId(0), index: i as u32 },
                            r: {
                                let hi = 0.3 + rng.f64() * 0.65;
                                rng.uniform(0.05, hi)
                            },
                            p: rng.uniform(0.5, 30.0),
                            input_bytes: 1,
                            pref_node: pref,
                            pref_rack: pref.map(|nd| (nd.dc, nd.idx % 2)),
                            wait: rng.uniform(0.0, 40.0),
                        }
                    })
                    .collect();
                let nd = NodeId { dc: DcId(rng.index(3)), idx: rng.index(4) };
                Case {
                    tasks,
                    n: ContainerView {
                        id: ContainerId(1),
                        node: nd,
                        rack: nd.idx % 2,
                        free: rng.uniform(0.0, 1.0),
                    },
                    steal: rng.chance(0.3),
                }
            }
        }
        forall_cases(0x9A2A, 512, &CaseGen, |case: &Case| {
            let mut q = case.tasks.clone();
            let before = q.len();
            let picks = on_update(&mut q, case.n, PARAMS, case.steal);
            let committed: f64 = picks.iter().map(|a| a.task.r).sum();
            crate::prop_assert!(
                committed <= case.n.free + 1e-6,
                "over-commit: {committed} > free {}",
                case.n.free
            );
            crate::prop_assert!(q.len() + picks.len() == before, "task conservation");
            for a in &picks {
                match a.locality {
                    Locality::NodeLocal => {
                        crate::prop_assert!(
                            a.task.pref_node == Some(case.n.node),
                            "node-local mismatch"
                        );
                        crate::prop_assert!(!case.steal, "steal can't be node-local");
                    }
                    Locality::RackLocal => crate::prop_assert!(
                        a.task.wait >= PARAMS.tau * a.task.p - 1e-9,
                        "rack gate violated"
                    ),
                    Locality::Any | Locality::Stolen => {
                        crate::prop_assert!(
                            a.task.wait >= 2.0 * PARAMS.tau * a.task.p - 1e-9,
                            "any gate violated"
                        );
                    }
                }
                crate::prop_assert!(
                    (a.locality == Locality::Stolen) == case.steal,
                    "steal labeling"
                );
            }
            // The any clause requires a nearly-free container at pick time;
            // the *first* such pick must satisfy it w.r.t. the original free.
            if let Some(first_any) = picks
                .iter()
                .position(|a| matches!(a.locality, Locality::Any | Locality::Stolen))
            {
                let free_before: f64 =
                    case.n.free - picks[..first_any].iter().map(|a| a.task.r).sum::<f64>();
                crate::prop_assert!(
                    free_before + 1e-6 >= 1.0 - PARAMS.delta,
                    "any clause on busy container"
                );
            }
            Ok(())
        });
    }
}
