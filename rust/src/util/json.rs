//! Minimal JSON support (the offline image ships no external crates):
//! a recursive-descent parser into a [`Json`] value tree plus string
//! escaping for writers. Covers the full JSON grammar the in-repo
//! writers emit (objects, arrays, strings with escapes, finite numbers,
//! booleans, null); used by the campaign report export's round-trip
//! check and its tests.

use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in document order (duplicates preserved).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer view, `Some` only when the conversion is lossless: JSON
    /// numbers are f64s, so only integers up to 2^53 survive exactly.
    pub fn as_u64(&self) -> Option<u64> {
        const EXACT_MAX: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= EXACT_MAX => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON document (quotes included).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render an `f64` for embedding in a JSON document. JSON has no NaN or
/// ±∞ literal, and [`parse`] (rightly) rejects them — writers that rendered
/// non-finite values with `{}` produced documents the round-trip check
/// could never read back. Non-finite values become `null`.
pub fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Parse a JSON document. Errors carry the byte offset of the problem.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, String> {
        Err(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", b as char))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected {s}"))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // BMP only (surrogate pairs land as U+FFFD);
                            // the in-repo writers never emit surrogates.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (we validated the input is
                    // &str, so byte boundaries are safe to re-decode).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .ok()
            .filter(|x| x.is_finite())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number {s:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -12.5e1 ").unwrap(), Json::Num(-125.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        let v = parse(r#"{"xs": [1, 2, {"ok": false}], "s": "hi"}"#).unwrap();
        assert_eq!(v.get("s").and_then(Json::as_str), Some("hi"));
        let xs = v.get("xs").and_then(Json::as_array).unwrap();
        assert_eq!(xs[0].as_u64(), Some(1));
        assert_eq!(xs[2].get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn escape_round_trips_through_parse() {
        for s in ["plain", "with \"quotes\"", "tab\there", "nl\nand\\slash", "unicode: héllo", "ctrl:\u{1}"] {
            let doc = format!("{{{}: {}}}", escape("k"), escape(s));
            let v = parse(&doc).unwrap();
            assert_eq!(v.get("k").and_then(Json::as_str), Some(s), "{doc}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\" 1}", "nul", "1.2.3", "\"open", "{} extra", "NaN"] {
            assert!(parse(s).is_err(), "{s:?} should not parse");
        }
    }

    #[test]
    fn num_maps_non_finite_to_null() {
        assert_eq!(num(1.5), "1.5");
        assert_eq!(num(-0.0), "-0");
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
        assert_eq!(num(f64::NEG_INFINITY), "null");
        // Whatever `num` emits must parse back.
        for x in [0.25, f64::NAN, f64::INFINITY] {
            assert!(parse(&num(x)).is_ok());
        }
    }

    #[test]
    fn u64_guard_rejects_fractions_and_negatives() {
        assert_eq!(parse("3").unwrap().as_u64(), Some(3));
        assert_eq!(parse("3.5").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_u64(), None);
    }
}
