//! Small statistics helpers used by the metrics layer and the experiment
//! harness: summary stats, percentiles, CDFs, time-weighted averages and a
//! fixed-width histogram. All pure functions over `f64` slices.

/// Mean of a slice; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for slices shorter than 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via the documented rule of [`percentile_sorted`]
/// (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice — the nearest-rank rule with
/// linear interpolation (Hyndman–Fan type 7, the numpy/Excel default),
/// with the edges handled exactly:
///
/// * `q = clamp(p / 100, 0, 1)`; a NaN `p` counts as 0 (the minimum)
///   instead of poisoning the index arithmetic.
/// * `n == 0` ⇒ 0.0 (finite and JSON-encodable, like [`min`]/[`max`]);
///   `n == 1` ⇒ the sample, for every `q`.
/// * `q == 0` ⇒ `sorted[0]` exactly and `q == 1` ⇒ `sorted[n-1]`
///   exactly — no floating-point rank can index past either end.
/// * otherwise `h = q·(n−1)`, `lo = ⌊h⌋` capped at `n−2` (so `lo+1` is
///   always in range even if `h` rounds up to `n−1`), and the result is
///   `sorted[lo] + (h − lo)·(sorted[lo+1] − sorted[lo])`.
///
/// Consequence worth knowing for tail quantiles on small samples: the
/// estimate interpolates between the top *two* order statistics rather
/// than silently returning the maximum — p999 of n=100 samples reads
/// 99.9 % of the way from the 99th to the 100th order statistic.
/// Callers that want "the largest observed" should ask for p100 (or
/// [`max`]), which is exact.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return 0.0;
    }
    if n == 1 {
        return sorted[0];
    }
    let q = (p / 100.0).clamp(0.0, 1.0);
    let q = if q.is_nan() { 0.0 } else { q };
    if q <= 0.0 {
        return sorted[0];
    }
    if q >= 1.0 {
        return sorted[n - 1];
    }
    let h = q * (n - 1) as f64;
    let lo = (h.floor() as usize).min(n - 2);
    let frac = h - lo as f64;
    sorted[lo] + frac * (sorted[lo + 1] - sorted[lo])
}

/// Smallest sample; 0.0 for an empty slice. (An ∞ sentinel would leak
/// into reports — and `util::json` rejects non-finite numbers outright.)
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Largest sample; 0.0 for an empty slice (see [`min`]).
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Empirical CDF: returns (value, fraction ≤ value) pairs, one per sample.
pub fn cdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len() as f64;
    v.iter()
        .enumerate()
        .map(|(i, &x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Sample the empirical CDF at fixed fractions (for compact table output).
pub fn cdf_at(xs: &[f64], fractions: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    fractions
        .iter()
        .map(|&f| (percentile_sorted(&v, f * 100.0), f))
        .collect()
}

/// Five-number-ish summary used by the overhead boxplots (Fig 12a).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let mut v = xs.to_vec();
        v.sort_by(f64::total_cmp);
        Summary {
            n: v.len(),
            mean: mean(&v),
            std: std_dev(&v),
            min: if v.is_empty() { 0.0 } else { v[0] },
            p25: percentile_sorted(&v, 25.0),
            p50: percentile_sorted(&v, 50.0),
            p75: percentile_sorted(&v, 75.0),
            max: if v.is_empty() { 0.0 } else { v[v.len() - 1] },
        }
    }
}

/// Accumulates a time-weighted average of a step function — e.g. container
/// utilization over a scheduling period, where the value changes whenever a
/// task starts or finishes.
#[derive(Debug, Clone)]
pub struct TimeWeighted {
    last_t: f64,
    value: f64,
    area: f64,
    start_t: f64,
}

impl TimeWeighted {
    pub fn new(t0: f64, v0: f64) -> Self {
        TimeWeighted { last_t: t0, value: v0, area: 0.0, start_t: t0 }
    }

    /// The step function changed to `v` at time `t`.
    pub fn set(&mut self, t: f64, v: f64) {
        debug_assert!(t >= self.last_t, "time must be monotonic");
        self.area += self.value * (t - self.last_t);
        self.last_t = t;
        self.value = v;
    }

    /// Current value of the step function.
    pub fn current(&self) -> f64 {
        self.value
    }

    /// Average over [start, t], then reset the window to begin at `t`.
    pub fn take_average(&mut self, t: f64) -> f64 {
        self.set(t, self.value);
        let span = t - self.start_t;
        let avg = if span > 0.0 { self.area / span } else { self.value };
        self.area = 0.0;
        self.start_t = t;
        avg
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets (under/overflow
/// clamp to the edge buckets). NaN samples are tallied in [`Histogram::nan`]
/// rather than silently landing in bucket 0 — `(NaN).clamp(0.0, hi)` is NaN,
/// and `NaN as usize` is 0, so the old code quietly inflated the first bucket.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    /// Number of NaN samples fed to [`Histogram::add`].
    pub nan: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], nan: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x.is_nan() {
            self.nan += 1;
            return;
        }
        let bins = self.counts.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64)
            .floor()
            .clamp(0.0, (bins - 1) as f64) as usize;
        self.counts[idx] += 1;
    }

    /// Total finite (bucketed) samples; excludes the NaN tally.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert!(cdf(&[]).is_empty());
        // min/max of nothing must be a finite, JSON-encodable number — the
        // ±∞ fold seeds used to leak straight into reports.
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic_the_folds() {
        // `partial_cmp(..).unwrap()` used to panic on the first NaN; the
        // `total_cmp` sorts order NaN after every finite value instead.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let p = percentile(&xs, 0.0);
        assert_eq!(p, 1.0);
        let c = cdf(&xs);
        assert_eq!(c.len(), 4);
        assert_eq!(c[0].0, 1.0);
        let s = Summary::of(&xs);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN sorts last under total_cmp");
        let at = cdf_at(&xs, &[0.0, 0.5]);
        assert_eq!(at[0].0, 1.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_boundaries_n1_n2() {
        // n = 1: every quantile is the sample.
        for p in [0.0, 50.0, 99.9, 100.0] {
            assert_eq!(percentile(&[7.0], p), 7.0, "p{p}");
        }
        // n = 2: exact edges, interpolated interior.
        let xs = [10.0, 20.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert!((percentile(&xs, 50.0) - 15.0).abs() < 1e-12);
        assert!((percentile(&xs, 99.9) - 19.99).abs() < 1e-12, "p999 interpolates, not max");
        assert_eq!(percentile(&xs, 100.0), 20.0);
    }

    #[test]
    fn extreme_quantiles_do_not_collapse_to_the_edges() {
        // p999 of n = 100 must land strictly between the top two order
        // statistics (the old floor/ceil rank collapsed it onto max for
        // some n, hiding tail latency in the load reports).
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p999 = percentile(&xs, 99.9);
        assert!(p999 > 99.0 && p999 < 100.0, "p999 = {p999}");
        assert!((p999 - (99.0 + 0.901)).abs() < 1e-9, "h = 0.999·99 = 98.901");
        // ... while p100 stays exact.
        assert_eq!(percentile(&xs, 100.0), 100.0);
        // Out-of-range and NaN p clamp to the edges instead of indexing
        // out of bounds (or poisoning the rank arithmetic).
        assert_eq!(percentile(&xs, -5.0), 1.0);
        assert_eq!(percentile(&xs, 250.0), 100.0);
        assert_eq!(percentile(&xs, f64::NAN), 1.0);
    }

    #[test]
    fn cdf_is_monotone_and_ends_at_one() {
        let xs = [5.0, 1.0, 3.0, 3.0, 2.0];
        let c = cdf(&xs);
        assert_eq!(c.len(), 5);
        for w in c.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert!((c.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_quartiles() {
        let xs: Vec<f64> = (1..=101).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.p50, 51.0);
        assert_eq!(s.p25, 26.0);
        assert_eq!(s.p75, 76.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 101.0);
    }

    #[test]
    fn time_weighted_average() {
        // value 1.0 on [0,10), 3.0 on [10,20) -> avg 2.0
        let mut tw = TimeWeighted::new(0.0, 1.0);
        tw.set(10.0, 3.0);
        let avg = tw.take_average(20.0);
        assert!((avg - 2.0).abs() < 1e-12);
        // window resets: 3.0 on [20,30) -> avg 3.0
        let avg2 = tw.take_average(30.0);
        assert!((avg2 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(-5.0);
        h.add(0.5);
        h.add(9.9);
        h.add(100.0);
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn histogram_counts_nan_separately() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(f64::NAN);
        h.add(0.5);
        h.add(f64::NAN);
        assert_eq!(h.nan, 2, "NaN must not be bucketed");
        assert_eq!(h.counts[0], 1, "bucket 0 holds only the finite sample");
        assert_eq!(h.total(), 1);
    }
}
