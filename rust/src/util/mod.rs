//! Shared utilities: deterministic RNG, statistics, typed ids and
//! byte/time formatting helpers.

pub mod error;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Pcg;

/// Format a byte count as a human-readable string (for logs / reports).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format simulated seconds as "Xs" / "Xm Ys".
pub fn fmt_secs(s: f64) -> String {
    if s < 60.0 {
        format!("{s:.1}s")
    } else {
        let m = (s / 60.0).floor();
        format!("{m:.0}m {:.0}s", s - m * 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.0 GB");
    }

    #[test]
    fn secs_format() {
        assert_eq!(fmt_secs(12.34), "12.3s");
        assert_eq!(fmt_secs(125.0), "2m 5s");
    }
}
