//! Minimal `anyhow`-style error plumbing.
//!
//! The offline image ships no external crates, so this module provides
//! the small subset of `anyhow` the codebase uses: a string-backed
//! [`Error`], a [`Result`] alias defaulting the error type, a [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros (exported at the crate root, as `#[macro_export]`
//! requires). Like `anyhow::Error`, [`Error`] deliberately does *not*
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// A human-readable error message with its context chain pre-rendered.
pub struct Error(String);

impl Error {
    /// Build an error from a message (the `anyhow!` macro calls this).
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human context to failures, `anyhow::Context`-style.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke with code {}", 7);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke with code 7");
        assert_eq!(format!("{e:#}"), "broke with code 7");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "{x} too big");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(30).unwrap_err().to_string(), "30 too big");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<u32, std::num::ParseIntError> = "x".parse();
        let e = r.context("parsing x").unwrap_err();
        assert!(e.to_string().starts_with("parsing x: "), "{e}");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let e = fails().with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1: broke with code 7");
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn go() -> Result<u32> {
            let n: u32 = "12".parse()?;
            let _bad: std::result::Result<u32, _> = "nope".parse::<u32>();
            Ok(n)
        }
        assert_eq!(go().unwrap(), 12);
        fn go_bad() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(go_bad().is_err());
    }
}
