//! Deterministic, seedable PRNG + the distributions the simulator needs.
//!
//! The offline image has no `rand` crate, so we carry a small PCG-XSH-RR
//! 64/32 implementation (O'Neill 2014). Determinism matters more than
//! statistical perfection here: every experiment in EXPERIMENTS.md is
//! reproducible from a single `u64` seed.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Split off an independent child stream (for per-component RNGs).
    pub fn split(&mut self, stream: u64) -> Pcg {
        let seed = self.next_u64();
        Pcg::new(seed, stream)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (inverse-CDF method).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // in (0, 1], avoids ln(0)
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn std_normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.std_normal()
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Pareto (heavy-tailed) with scale `xm` and shape `alpha`.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Sample an index from (unnormalized, non-negative) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed rank in [1, n] with exponent `s` (rejection-free
    /// inverse CDF over precomputed harmonic weights is overkill; we use
    /// the simple cumulative scan since n is small in our workloads).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        let weights: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
        self.weighted(&weights) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg::seeded(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Pcg::seeded(9);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exp(60.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 60.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Pcg::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05, "mean {mean}");
        assert!((var.sqrt() - 2.0).abs() < 0.05, "std {}", var.sqrt());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Pcg::seeded(13);
        let mut counts = [0usize; 3];
        for _ in 0..90_000 {
            counts[r.weighted(&[1.0, 2.0, 6.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        assert!((counts[2] as f64 / 90_000.0 - 6.0 / 9.0).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seeded(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg::seeded(5);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }
}
