//! Typed configuration for the whole system, with paper-faithful defaults.
//!
//! Every experiment is driven by a [`Config`]; the CLI and the bench
//! harness construct one from defaults and optionally overlay a TOML file
//! (parsed by [`toml`], the in-repo TOML-subset parser) and `--set
//! section.key=value` overrides. Defaults encode the paper's testbed:
//! four AliCloud regions (Fig 2 bandwidth matrix), 5 machines per region
//! (1 on-demand master + 4 spot workers), the Fig 3 price table, the Fig 7
//! workload sizes and the 46/40/14 job-size mix.

pub mod toml;

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use self::toml::Doc;

/// Which system assembly to run (§6.1 "Baselines").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Deployment {
    /// Decentralized, Af + Parades (the paper's system).
    Houtu,
    /// Centralized, Af + parameterized delay scheduling (COBRA [53]).
    CentDyna,
    /// Centralized, static resource scheduling (stock Spark-on-YARN).
    CentStat,
    /// Decentralized architecture, static resource scheduling, no stealing.
    DecentStat,
}

impl Deployment {
    pub const ALL: [Deployment; 4] =
        [Deployment::Houtu, Deployment::CentDyna, Deployment::CentStat, Deployment::DecentStat];

    pub fn parse(s: &str) -> Result<Deployment> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "houtu" => Deployment::Houtu,
            "cent-dyna" | "centdyna" | "cobra" => Deployment::CentDyna,
            "cent-stat" | "centstat" => Deployment::CentStat,
            "decent-stat" | "decentstat" => Deployment::DecentStat,
            other => bail!("unknown deployment {other:?} (houtu|cent-dyna|cent-stat|decent-stat)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Deployment::Houtu => "houtu",
            Deployment::CentDyna => "cent-dyna",
            Deployment::CentStat => "cent-stat",
            Deployment::DecentStat => "decent-stat",
        }
    }

    /// Centralized = one global master controls containers in all DCs.
    pub fn centralized(&self) -> bool {
        matches!(self, Deployment::CentDyna | Deployment::CentStat)
    }

    /// Adaptive = job managers run Af; static = fixed executor count.
    pub fn adaptive(&self) -> bool {
        matches!(self, Deployment::Houtu | Deployment::CentDyna)
    }

    /// Cross-DC work stealing is a HOUTU-only mechanism.
    pub fn stealing(&self) -> bool {
        matches!(self, Deployment::Houtu)
    }
}

/// Per-pair WAN bandwidth (mean, std) in Mbps — Fig 2 of the paper.
/// Index order matches [`TopologyConfig::regions`].
pub type BandwidthMatrix = Vec<Vec<(f64, f64)>>;

#[derive(Debug, Clone)]
pub struct TopologyConfig {
    /// Region names; one autonomous system per region.
    pub regions: Vec<String>,
    /// Worker machines per region (paper: 4 Spot workers + 1 master).
    pub workers_per_dc: usize,
    /// Containers hosted per worker machine (fixed <1 core, 2 GB> slots on
    /// the paper's <4 vCPU, 8 GB> instances).
    pub containers_per_worker: usize,
    /// Racks per DC (locality tier between node-local and any).
    pub racks_per_dc: usize,
    /// Generated-topology token (`generated:<dcs>,<nodes_per_dc>,<seed>`
    /// — see [`crate::topo`]). Empty = the explicit `regions` list above.
    /// Setting `topology.generated` expands the token: it installs the
    /// generated region names, `workers_per_dc` and the full bandwidth
    /// matrix, winning over explicit values in the same document.
    pub generated: String,
    /// Two-tier fidelity boundary for the parts engine: DCs
    /// `0..exact_dcs` simulate exactly, the rest run as aggregate
    /// background until promoted (see `docs/SCALE.md`). 0 = all exact.
    /// The sequential slab engine ignores this knob.
    pub exact_dcs: usize,
}

impl TopologyConfig {
    pub fn num_dcs(&self) -> usize {
        self.regions.len()
    }
    pub fn containers_per_dc(&self) -> usize {
        self.workers_per_dc * self.containers_per_worker
    }
    pub fn total_containers(&self) -> usize {
        self.num_dcs() * self.containers_per_dc()
    }
}

#[derive(Debug, Clone)]
pub struct WanConfig {
    /// (mean, std) Mbps per region pair; diagonal = LAN within the DC.
    pub bandwidth: BandwidthMatrix,
    /// One-way propagation delay between different regions (ms).
    pub rtt_ms: f64,
    /// AR(1) persistence of the bandwidth fluctuation process.
    pub ar1_phi: f64,
    /// Seconds between bandwidth re-samples.
    pub resample_secs: f64,
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Scheduling period length L (seconds).
    pub period_l_secs: f64,
    /// Af utilization threshold δ.
    pub delta: f64,
    /// Af resource adjustment factor ρ (> 1).
    pub rho: f64,
    /// Parades waiting-time multiplier τ (threshold = τ·p, rack; 2τ·p any).
    pub tau: f64,
    /// Executors per sub-job under *static* scheduling.
    pub static_executors: usize,
    /// Minimum task resource requirement θ (normalized, > 0).
    pub theta: f64,
    /// Heartbeat / container-update interval (seconds).
    pub heartbeat_secs: f64,
    /// Master switch for cross-DC work stealing (Fig 9c disables it).
    pub work_stealing: bool,
    /// Static baselines allocate FIFO (stock YARN default queue) instead
    /// of fair-share. Ablatable: set false to give the static baselines
    /// the fair scheduler too.
    pub static_fifo: bool,
}

#[derive(Debug, Clone)]
pub struct CloudConfig {
    /// On-demand $/hour (AliCloud row of Fig 3).
    pub on_demand_hourly: f64,
    /// Mean spot $/hour.
    pub spot_hourly_mean: f64,
    /// Spot price volatility (stddev of the log-price innovation).
    pub spot_volatility: f64,
    /// Our standing bid as a multiple of the mean spot price.
    pub bid_multiplier: f64,
    /// Cross-DC transfer price $/GB (free within a DC).
    pub transfer_per_gb: f64,
    /// Seconds between spot market price recalculations.
    pub market_period_secs: f64,
    /// Whether spot revocations actually kill instances.
    pub revocations: bool,
    /// §2.3 extension (the paper's "of particular interest" future work):
    /// keep the first worker per region On-demand and steer JM containers
    /// onto it, buying deterministic JM reliability in a mixed fleet for
    /// a small premium. Ablated in benches/ablations.rs.
    pub reliable_jm_hosts: bool,
}

#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// P(small), P(medium), P(large) — paper: 46/40/14.
    pub mix: [f64; 3],
    /// Mean inter-arrival of jobs (seconds, exponential).
    pub mean_interarrival_secs: f64,
    /// Number of jobs in the online trace.
    pub num_jobs: usize,
    /// Probability that a task straggles (runs `straggler_factor` slow) —
    /// models the §2.2 changeable environment at task granularity.
    pub straggler_prob: f64,
    pub straggler_factor: f64,
    /// Per-job USD budget consumed by the [`BiddingConfig::strategy`]
    /// `deadline` policy (0 = unlimited): a job over budget stops the
    /// strategy from bidding aggressively on its behalf.
    pub budget_usd: f64,
    /// Per-job soft deadline in seconds (0 = none): a job projected —
    /// elapsed time plus its remaining critical-path estimate — to
    /// overshoot it counts as *behind*, which is when the `deadline`
    /// strategy turns aggressive.
    pub deadline_secs: f64,
}

/// The cost-aware bidding subsystem (`[bidding]` section): which
/// [`crate::cloud::bidding::BidStrategy`] prices worker-VM acquisitions,
/// and whether PingAn-style insurance replication hedges risky spot
/// containers. The `naive` default keeps the seed behaviour bit-identical
/// (same RNG stream, same trace events).
#[derive(Debug, Clone)]
pub struct BiddingConfig {
    /// Which strategy prices acquisitions (naive|adaptive|deadline).
    pub strategy: crate::cloud::bidding::StrategyKind,
    /// Duplicate tasks launched on high-revocation-risk spot containers
    /// (first commit wins; exactly-once is enforced duplicate-safely).
    pub insurance: bool,
    /// The `deadline` strategy's bid multiplier when fully behind
    /// schedule (its calm baseline is `cloud.bid_multiplier`).
    pub aggressive_multiplier: f64,
    /// EWMA smoothing factor for the `adaptive` price forecast, in (0,1].
    pub ewma_alpha: f64,
    /// Insurance risk gate: a spot container is *risky* when
    /// `market price × risk_margin ≥ its bid` (or a storm is active).
    pub risk_margin: f64,
}

impl BiddingConfig {
    /// Whether the subsystem publishes its trace events (`BidPlaced`,
    /// `InsuranceLaunched`, `CostCharged`). False under the naive
    /// default, which keeps pre-subsystem replay digests bit-identical.
    pub fn active(&self) -> bool {
        self.strategy != crate::cloud::bidding::StrategyKind::Naive || self.insurance
    }
}

#[derive(Debug, Clone)]
pub struct FailureConfig {
    /// Enable heartbeat-based JM failure detection + recovery.
    pub recovery_enabled: bool,
    /// Task-level straggler mitigation (§7: "reschedules a copy task when
    /// the execution time exceeds a threshold"): abort and relaunch tasks
    /// running past `speculation_factor` × their estimated p.
    pub speculation: bool,
    pub speculation_factor: f64,
    /// JM heartbeat timeout before declaring failure (seconds).
    pub detect_timeout_secs: f64,
    /// Time for a master to spawn a replacement JM container (seconds).
    pub respawn_secs: f64,
}

#[derive(Debug, Clone)]
pub struct Config {
    pub seed: u64,
    pub deployment: Deployment,
    pub topology: TopologyConfig,
    pub wan: WanConfig,
    pub scheduler: SchedulerConfig,
    pub cloud: CloudConfig,
    pub workload: WorkloadConfig,
    pub failures: FailureConfig,
    pub bidding: BiddingConfig,
}

/// Fig 2 of the paper, (mean, std) Mbps. Order: NC-3, NC-5, EC-1, SC-1.
pub fn fig2_bandwidth() -> BandwidthMatrix {
    let m = |a: f64, b: f64| (a, b);
    vec![
        vec![m(821.0, 95.0), m(79.0, 22.0), m(78.0, 24.0), m(79.0, 24.0)],
        vec![m(79.0, 22.0), m(820.0, 115.0), m(103.0, 28.0), m(71.0, 28.0)],
        vec![m(78.0, 24.0), m(103.0, 28.0), m(848.0, 99.0), m(103.0, 30.0)],
        vec![m(79.0, 24.0), m(71.0, 28.0), m(103.0, 30.0), m(821.0, 107.0)],
    ]
}

impl Default for Config {
    fn default() -> Self {
        Config {
            seed: 42,
            deployment: Deployment::Houtu,
            topology: TopologyConfig {
                regions: vec!["NC-3".into(), "NC-5".into(), "EC-1".into(), "SC-1".into()],
                workers_per_dc: 4,
                containers_per_worker: 4,
                racks_per_dc: 2,
                generated: String::new(),
                exact_dcs: 0,
            },
            wan: WanConfig {
                bandwidth: fig2_bandwidth(),
                rtt_ms: 30.0,
                ar1_phi: 0.8,
                resample_secs: 5.0,
            },
            scheduler: SchedulerConfig {
                period_l_secs: 5.0,
                delta: 0.7,
                rho: 1.5,
                tau: 0.5,
                static_executors: 8,
                theta: 0.05,
                heartbeat_secs: 1.0,
                work_stealing: true,
                static_fifo: true,
            },
            cloud: CloudConfig {
                on_demand_hourly: 0.312,
                spot_hourly_mean: 0.036,
                spot_volatility: 0.25,
                bid_multiplier: 1.8,
                transfer_per_gb: 0.13,
                market_period_secs: 300.0,
                revocations: false,
                reliable_jm_hosts: false,
            },
            workload: WorkloadConfig {
                mix: [0.46, 0.40, 0.14],
                // The paper submits with exp(60 s); our calibrated tasks run
                // ~2x faster than the paper's Spark tasks, so exp(30 s)
                // holds the same ~5-jobs-in-flight contention regime
                // (EXPERIMENTS.md 'Calibration').
                mean_interarrival_secs: 30.0,
                num_jobs: 12,
                straggler_prob: 0.0,
                straggler_factor: 4.0,
                budget_usd: 0.0,
                deadline_secs: 0.0,
            },
            failures: FailureConfig {
                recovery_enabled: true,
                speculation: true,
                speculation_factor: 2.0,
                detect_timeout_secs: 5.0,
                respawn_secs: 4.0,
            },
            bidding: BiddingConfig {
                strategy: crate::cloud::bidding::StrategyKind::Naive,
                insurance: false,
                aggressive_multiplier: 3.0,
                ewma_alpha: 0.3,
                risk_margin: 1.25,
            },
        }
    }
}

impl Config {
    /// Overlay values from a parsed TOML document onto `self`.
    pub fn apply_doc(&mut self, doc: &Doc) -> Result<()> {
        self.seed = doc.i64_or("experiment", "seed", self.seed as i64) as u64;
        if let Some(v) = doc.get("experiment", "deployment") {
            let s = v.as_str().context("experiment.deployment must be a string")?;
            self.deployment = Deployment::parse(s)?;
        }
        if let Some(v) = doc.get("topology", "regions") {
            let arr = v.as_array().context("topology.regions must be an array")?;
            self.topology.regions = arr
                .iter()
                .map(|x| x.as_str().map(str::to_string).context("region must be a string"))
                .collect::<Result<_>>()?;
        }
        let t = &mut self.topology;
        t.workers_per_dc = doc.i64_or("topology", "workers_per_dc", t.workers_per_dc as i64) as usize;
        t.containers_per_worker =
            doc.i64_or("topology", "containers_per_worker", t.containers_per_worker as i64) as usize;
        t.racks_per_dc = doc.i64_or("topology", "racks_per_dc", t.racks_per_dc as i64) as usize;
        t.exact_dcs = doc.i64_or("topology", "exact_dcs", t.exact_dcs as i64) as usize;
        // Handled after the scalar keys so a generated token wins over
        // explicit regions/workers values in the same document.
        if let Some(v) = doc.get("topology", "generated") {
            let s = v.as_str().context("topology.generated must be a string")?;
            self.expand_generated(s)?;
        }

        let w = &mut self.wan;
        w.rtt_ms = doc.f64_or("wan", "rtt_ms", w.rtt_ms);
        w.ar1_phi = doc.f64_or("wan", "ar1_phi", w.ar1_phi);
        w.resample_secs = doc.f64_or("wan", "resample_secs", w.resample_secs);

        let s = &mut self.scheduler;
        s.period_l_secs = doc.f64_or("scheduler", "period_l_secs", s.period_l_secs);
        s.delta = doc.f64_or("scheduler", "delta", s.delta);
        s.rho = doc.f64_or("scheduler", "rho", s.rho);
        s.tau = doc.f64_or("scheduler", "tau", s.tau);
        s.static_executors =
            doc.i64_or("scheduler", "static_executors", s.static_executors as i64) as usize;
        s.theta = doc.f64_or("scheduler", "theta", s.theta);
        s.heartbeat_secs = doc.f64_or("scheduler", "heartbeat_secs", s.heartbeat_secs);
        s.work_stealing = doc.bool_or("scheduler", "work_stealing", s.work_stealing);
        s.static_fifo = doc.bool_or("scheduler", "static_fifo", s.static_fifo);

        let c = &mut self.cloud;
        c.on_demand_hourly = doc.f64_or("cloud", "on_demand_hourly", c.on_demand_hourly);
        c.spot_hourly_mean = doc.f64_or("cloud", "spot_hourly_mean", c.spot_hourly_mean);
        c.spot_volatility = doc.f64_or("cloud", "spot_volatility", c.spot_volatility);
        c.bid_multiplier = doc.f64_or("cloud", "bid_multiplier", c.bid_multiplier);
        c.transfer_per_gb = doc.f64_or("cloud", "transfer_per_gb", c.transfer_per_gb);
        c.market_period_secs = doc.f64_or("cloud", "market_period_secs", c.market_period_secs);
        c.revocations = doc.bool_or("cloud", "revocations", c.revocations);
        c.reliable_jm_hosts = doc.bool_or("cloud", "reliable_jm_hosts", c.reliable_jm_hosts);

        let wl = &mut self.workload;
        wl.mean_interarrival_secs =
            doc.f64_or("workload", "mean_interarrival_secs", wl.mean_interarrival_secs);
        wl.num_jobs = doc.i64_or("workload", "num_jobs", wl.num_jobs as i64) as usize;
        wl.straggler_prob = doc.f64_or("workload", "straggler_prob", wl.straggler_prob);
        wl.straggler_factor = doc.f64_or("workload", "straggler_factor", wl.straggler_factor);
        wl.budget_usd = doc.f64_or("workload", "budget_usd", wl.budget_usd);
        wl.deadline_secs = doc.f64_or("workload", "deadline_secs", wl.deadline_secs);
        if let Some(v) = doc.get("workload", "mix") {
            let arr = v.as_array().context("workload.mix must be an array")?;
            if arr.len() != 3 {
                bail!("workload.mix must have 3 entries");
            }
            for (i, x) in arr.iter().enumerate() {
                wl.mix[i] = x.as_f64().context("mix entries must be numeric")?;
            }
        }

        let f = &mut self.failures;
        f.recovery_enabled = doc.bool_or("failures", "recovery_enabled", f.recovery_enabled);
        f.speculation = doc.bool_or("failures", "speculation", f.speculation);
        f.speculation_factor = doc.f64_or("failures", "speculation_factor", f.speculation_factor);
        f.detect_timeout_secs = doc.f64_or("failures", "detect_timeout_secs", f.detect_timeout_secs);
        f.respawn_secs = doc.f64_or("failures", "respawn_secs", f.respawn_secs);

        let b = &mut self.bidding;
        if let Some(v) = doc.get("bidding", "strategy") {
            let s = v.as_str().context("bidding.strategy must be a string")?;
            b.strategy = crate::cloud::bidding::StrategyKind::parse(s)?;
        }
        b.insurance = doc.bool_or("bidding", "insurance", b.insurance);
        b.aggressive_multiplier =
            doc.f64_or("bidding", "aggressive_multiplier", b.aggressive_multiplier);
        b.ewma_alpha = doc.f64_or("bidding", "ewma_alpha", b.ewma_alpha);
        b.risk_margin = doc.f64_or("bidding", "risk_margin", b.risk_margin);

        self.validate()
    }

    /// Load from a TOML file path, overlaying onto the defaults.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let doc = toml::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let mut cfg = Config::default();
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }

    /// Apply one `section.key=value` override string.
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (path, value) = kv
            .split_once('=')
            .with_context(|| format!("override {kv:?} must be section.key=value"))?;
        let (section, key) = path
            .split_once('.')
            .with_context(|| format!("override path {path:?} must be section.key"))?;
        let text = format!("[{section}]\n{key} = {value}\n");
        // Try raw first (numbers/bools/arrays), then as a quoted string.
        let doc = match toml::parse(&text) {
            Ok(d) => d,
            Err(_) => toml::parse(&format!("[{section}]\n{key} = \"{value}\"\n"))
                .map_err(|e| anyhow!("bad override {kv:?}: {e}"))?,
        };
        self.apply_doc(&doc)
    }

    /// Expand a `generated:<dcs>,<nodes_per_dc>,<seed>` token (see
    /// [`crate::topo`]) into concrete region names, worker count and the
    /// full `(mean, std)` bandwidth matrix. The installed matrix is
    /// exactly `dcs × dcs`, so a later [`Config::resize_bandwidth`] is a
    /// no-op that preserves it.
    pub fn expand_generated(&mut self, token: &str) -> Result<()> {
        let spec = crate::topo::parse_spec(token)?;
        let g = crate::topo::generate(spec);
        self.topology.generated = token.to_string();
        self.topology.regions = g.regions;
        self.topology.workers_per_dc = spec.nodes_per_dc;
        self.wan.bandwidth = g.bandwidth;
        Ok(())
    }

    /// Sanity checks on parameter ranges.
    pub fn validate(&self) -> Result<()> {
        let n = self.topology.num_dcs();
        if n == 0 {
            bail!("need at least one region");
        }
        if !self.topology.generated.is_empty() {
            crate::topo::parse_spec(&self.topology.generated)?;
        }
        if self.topology.exact_dcs > n {
            bail!(
                "topology.exact_dcs {} exceeds the topology's {} DCs",
                self.topology.exact_dcs,
                n
            );
        }
        if self.wan.bandwidth.len() != n {
            // The Fig-2 matrix is 4x4; synthesize a uniform matrix for other
            // region counts so tests can use small topologies.
            // (Handled by Config::resize_bandwidth, called here.)
        }
        let s = &self.scheduler;
        if !(0.0 < s.delta && s.delta < 1.0) {
            bail!("scheduler.delta must be in (0,1), got {}", s.delta);
        }
        if s.rho <= 1.0 {
            bail!("scheduler.rho must exceed 1, got {}", s.rho);
        }
        if s.tau < 0.0 {
            bail!("scheduler.tau must be >= 0");
        }
        if !(0.0 < s.theta && s.theta <= 1.0) {
            bail!("scheduler.theta must be in (0,1]");
        }
        if s.period_l_secs <= 0.0 {
            bail!("scheduler.period_l_secs must be positive");
        }
        let mix_sum: f64 = self.workload.mix.iter().sum();
        if (mix_sum - 1.0).abs() > 1e-6 {
            bail!("workload.mix must sum to 1, got {mix_sum}");
        }
        if self.workload.budget_usd < 0.0 {
            bail!("workload.budget_usd must be >= 0 (0 = unlimited)");
        }
        if self.workload.deadline_secs < 0.0 {
            bail!("workload.deadline_secs must be >= 0 (0 = none)");
        }
        let b = &self.bidding;
        if !(0.0 < b.ewma_alpha && b.ewma_alpha <= 1.0) {
            bail!("bidding.ewma_alpha must be in (0,1], got {}", b.ewma_alpha);
        }
        if b.aggressive_multiplier < 1.0 {
            bail!("bidding.aggressive_multiplier must be >= 1");
        }
        if b.risk_margin < 1.0 {
            bail!("bidding.risk_margin must be >= 1");
        }
        Ok(())
    }

    /// Ensure the bandwidth matrix matches the region count (tests may use
    /// 2- or 8-region topologies): keep Fig-2 values where defined, fill
    /// the rest with the Fig-2 averages (WAN ≈ 85 ± 26, LAN ≈ 827 ± 104).
    pub fn resize_bandwidth(&mut self) {
        let n = self.topology.num_dcs();
        let old = self.wan.bandwidth.clone();
        let mut m = vec![vec![(85.0, 26.0); n]; n];
        for (i, row) in m.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                if i == j {
                    *cell = (827.0, 104.0);
                }
                if i < old.len() && j < old.len() {
                    *cell = old[i][j];
                }
            }
        }
        self.wan.bandwidth = m;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid_and_paper_shaped() {
        let cfg = Config::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.topology.num_dcs(), 4);
        assert_eq!(cfg.topology.total_containers(), 64);
        assert_eq!(cfg.wan.bandwidth[0][1].0, 79.0);
        assert_eq!(cfg.wan.bandwidth[2][2].0, 848.0);
        assert!((cfg.workload.mix.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deployment_parse_roundtrip() {
        for d in Deployment::ALL {
            assert_eq!(Deployment::parse(d.name()).unwrap(), d);
        }
        assert!(Deployment::parse("nope").is_err());
        assert!(Deployment::parse("cobra").unwrap() == Deployment::CentDyna);
    }

    #[test]
    fn deployment_capability_matrix() {
        use Deployment::*;
        assert!(Houtu.stealing() && Houtu.adaptive() && !Houtu.centralized());
        assert!(!CentDyna.stealing() && CentDyna.adaptive() && CentDyna.centralized());
        assert!(!CentStat.adaptive() && CentStat.centralized());
        assert!(!DecentStat.adaptive() && !DecentStat.centralized() && !DecentStat.stealing());
    }

    #[test]
    fn overlay_from_toml() {
        let mut cfg = Config::default();
        let doc = toml::parse(
            r#"
            [experiment]
            seed = 7
            deployment = "cent-stat"
            [scheduler]
            rho = 2.0
            [workload]
            num_jobs = 5
            mix = [0.5, 0.3, 0.2]
            "#,
        )
        .unwrap();
        cfg.apply_doc(&doc).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.deployment, Deployment::CentStat);
        assert_eq!(cfg.scheduler.rho, 2.0);
        assert_eq!(cfg.workload.num_jobs, 5);
        assert_eq!(cfg.workload.mix, [0.5, 0.3, 0.2]);
    }

    #[test]
    fn overrides_parse_values_and_strings() {
        let mut cfg = Config::default();
        cfg.apply_override("scheduler.delta=0.5").unwrap();
        assert_eq!(cfg.scheduler.delta, 0.5);
        cfg.apply_override("experiment.deployment=cent-dyna").unwrap();
        assert_eq!(cfg.deployment, Deployment::CentDyna);
        assert!(cfg.apply_override("noequals").is_err());
        assert!(cfg.apply_override("nodot=1").is_err());
    }

    #[test]
    fn validation_rejects_bad_params() {
        let mut cfg = Config::default();
        cfg.scheduler.delta = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = Config::default();
        cfg.scheduler.rho = 0.9;
        assert!(cfg.validate().is_err());
        let mut cfg = Config::default();
        cfg.workload.mix = [0.5, 0.5, 0.5];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn bidding_section_overlays_and_validates() {
        use crate::cloud::bidding::StrategyKind;
        let mut cfg = Config::default();
        assert_eq!(cfg.bidding.strategy, StrategyKind::Naive);
        assert!(!cfg.bidding.insurance);
        assert!(!cfg.bidding.active(), "naive + no insurance is the silent baseline");
        cfg.apply_override("bidding.strategy=adaptive").unwrap();
        assert_eq!(cfg.bidding.strategy, StrategyKind::Adaptive);
        assert!(cfg.bidding.active());
        cfg.apply_override("bidding.insurance=true").unwrap();
        cfg.apply_override("workload.budget_usd=2.5").unwrap();
        cfg.apply_override("workload.deadline_secs=600").unwrap();
        assert!(cfg.bidding.insurance);
        assert_eq!(cfg.workload.budget_usd, 2.5);
        assert_eq!(cfg.workload.deadline_secs, 600.0);
        assert!(cfg.apply_override("bidding.strategy=greedy").is_err());
        assert!(cfg.apply_override("bidding.ewma_alpha=0").is_err());
        assert!(cfg.apply_override("bidding.risk_margin=0.5").is_err());
        assert!(cfg.apply_override("workload.budget_usd=-1").is_err());
        // Insurance alone (without a non-naive strategy) also activates
        // the subsystem's trace events.
        let mut cfg = Config::default();
        cfg.apply_override("bidding.insurance=true").unwrap();
        assert!(cfg.bidding.active());
    }

    #[test]
    fn generated_topology_expands_and_validates() {
        let mut cfg = Config::default();
        cfg.apply_override("topology.generated=generated:16,2,7").unwrap();
        assert_eq!(cfg.topology.num_dcs(), 16);
        assert_eq!(cfg.topology.workers_per_dc, 2);
        assert!(cfg.topology.regions[0].starts_with('G'), "{:?}", cfg.topology.regions[0]);
        assert_eq!(cfg.wan.bandwidth.len(), 16);
        assert_eq!(cfg.wan.bandwidth[3][3], (827.0, 104.0));
        assert!(cfg.wan.bandwidth[0][1].0 < 827.0, "cross-DC must trail the LAN");
        assert_eq!(cfg.wan.bandwidth[0][1], cfg.wan.bandwidth[1][0], "symmetric");
        // The installed matrix is exactly n×n, so resize preserves it.
        let before = cfg.wan.bandwidth.clone();
        cfg.resize_bandwidth();
        assert_eq!(cfg.wan.bandwidth, before);
        // A bad token is a clear error, not a panic.
        let e = cfg
            .apply_override("topology.generated=generated:64")
            .expect_err("missing fields must fail")
            .to_string();
        assert!(e.contains("topology spec"), "{e}");
        // The two-tier boundary knob validates against the DC count.
        cfg.apply_override("topology.exact_dcs=4").unwrap();
        assert_eq!(cfg.topology.exact_dcs, 4);
        assert!(cfg.apply_override("topology.exact_dcs=99").is_err());
    }

    #[test]
    fn resize_bandwidth_fills_new_regions() {
        let mut cfg = Config::default();
        cfg.topology.regions.push("US-1".into());
        cfg.resize_bandwidth();
        assert_eq!(cfg.wan.bandwidth.len(), 5);
        assert_eq!(cfg.wan.bandwidth[0][1], (79.0, 22.0)); // preserved
        assert_eq!(cfg.wan.bandwidth[4][4], (827.0, 104.0)); // LAN fill
        assert_eq!(cfg.wan.bandwidth[0][4], (85.0, 26.0)); // WAN fill
    }
}
