//! Tiny TOML-subset parser (no serde in the offline image).
//!
//! Supported: `[section]` headers, `key = value` with values being
//! integers, floats, booleans, quoted strings, and flat arrays of those.
//! Comments start with `#`. This covers every config file this repo ships;
//! anything fancier is a parse error, not silent misbehaviour.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// A parsed document: section name → key → value. Top-level keys live in
/// the "" section.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_i64).unwrap_or(default)
    }
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}
impl std::error::Error for ParseError {}

fn err(line: usize, message: impl Into<String>) -> ParseError {
    ParseError { line, message: message.into() }
}

/// Strip a trailing comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_scalar(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            return Err(err(line, format!("unterminated string: {s}")));
        }
        let body = &s[1..s.len() - 1];
        // Minimal escapes.
        let unescaped = body.replace("\\\"", "\"").replace("\\\\", "\\");
        return Ok(Value::Str(unescaped));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line, format!("cannot parse value: {s:?}")))
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?;
        let mut items = Vec::new();
        // Split on commas outside of strings.
        let mut depth_str = false;
        let mut cur = String::new();
        for c in body.chars() {
            match c {
                '"' => {
                    depth_str = !depth_str;
                    cur.push(c);
                }
                ',' if !depth_str => {
                    if !cur.trim().is_empty() {
                        items.push(parse_scalar(&cur, line)?);
                    }
                    cur.clear();
                }
                _ => cur.push(c),
            }
        }
        if !cur.trim().is_empty() {
            items.push(parse_scalar(&cur, line)?);
        }
        return Ok(Value::Array(items));
    }
    parse_scalar(s, line)
}

/// Parse a document from text.
pub fn parse(text: &str) -> Result<Doc, ParseError> {
    let mut doc = Doc::default();
    let mut section = String::new();
    doc.sections.entry(section.clone()).or_default();

    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?;
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, format!("expected key = value, got {line:?}")))?;
        let key = line[..eq].trim().to_string();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(&line[eq + 1..], lineno)?;
        doc.sections.get_mut(&section).unwrap().insert(key, value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
            top = 1
            [experiment]
            seed = 42          # a comment
            duration = 3600.5
            name = "fig8 run"
            enabled = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.i64_or("", "top", 0), 1);
        assert_eq!(doc.i64_or("experiment", "seed", 0), 42);
        assert!((doc.f64_or("experiment", "duration", 0.0) - 3600.5).abs() < 1e-9);
        assert_eq!(doc.str_or("experiment", "name", ""), "fig8 run");
        assert!(doc.bool_or("experiment", "enabled", false));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse(r#"regions = ["NC-3", "NC-5", "EC-1", "SC-1"]"#).unwrap();
        let arr = doc.get("", "regions").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[0].as_str(), Some("NC-3"));
    }

    #[test]
    fn parses_numeric_arrays_and_underscores() {
        let doc = parse("sizes = [200, 1_000, 5000]\nbig = 1_000_000").unwrap();
        let arr = doc.get("", "sizes").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_i64(), Some(1000));
        assert_eq!(doc.i64_or("", "big", 0), 1_000_000);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse(r##"s = "a # b""##).unwrap();
        assert_eq!(doc.str_or("", "s", ""), "a # b");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("not a kv line").is_err());
        assert!(parse("[unterminated").is_err());
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("x = \"open").is_err());
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = parse("[a]\nx = 1").unwrap();
        assert_eq!(doc.i64_or("a", "missing", 7), 7);
        assert_eq!(doc.i64_or("nosection", "x", 9), 9);
    }
}
