//! WAN fabric between data centers.
//!
//! Reproduces the paper's §2.2 observations: inter-DC bandwidth is ~10×
//! below LAN and *fluctuates* — the measured std is up to 30 % of the mean
//! (Fig 2). We model each (region, region) pair as an AR(1) mean-reverting
//! process around the Fig-2 mean with the Fig-2 stationary std:
//!
//! `x_t = mean + φ (x_{t-1} − mean) + sqrt(1 − φ²) · std · ε_t`
//!
//! resampled every `resample_secs` of virtual time. Concurrent transfers on
//! a pair fair-share the instantaneous bandwidth (sampled at transfer
//! start). Control messages pay one-way propagation (rtt/2) plus
//! serialization, which is what puts the paper's ~63 ms steal-message
//! delay (Fig 12b) in range.

use crate::config::WanConfig;
use crate::ids::DcId;
use crate::sim::{secs_f, SimTime};
use crate::trace::{TraceEvent, Tracer};
use crate::util::Pcg;

/// Traffic classes, tracked separately for the Fig-10 cost breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Traffic {
    /// Task input / shuffle data.
    Data,
    /// JM coordination: steal messages, intermediate-info replication.
    Control,
}

/// Cumulative WAN accounting.
#[derive(Debug, Default, Clone)]
pub struct WanStats {
    pub cross_dc_data_bytes: u64,
    pub cross_dc_control_bytes: u64,
    pub transfers: u64,
    pub messages: u64,
}

impl WanStats {
    pub fn cross_dc_total_bytes(&self) -> u64 {
        self.cross_dc_data_bytes + self.cross_dc_control_bytes
    }
}

pub struct Wan {
    cfg: WanConfig,
    /// Instantaneous bandwidth per pair (Mbps), AR(1) state.
    current: Vec<Vec<f64>>,
    /// Active bulk transfers per pair (for fair sharing).
    active: Vec<Vec<u32>>,
    /// Scenario-injected cross-DC degradation (brownout) multiplier:
    /// 1.0 = nominal; applied on top of the AR(1) process to *inter*-DC
    /// links only. The chaos engine toggles this for WAN-window events.
    degrade: f64,
    /// Per-pair degradation multipliers (asymmetric partitions): applied
    /// on top of both the AR(1) process and the global `degrade` factor,
    /// to the one inter-DC pair the chaos engine targeted.
    pair_degrade: Vec<Vec<f64>>,
    /// Trace bus handle; when attached, every control message and bulk
    /// transfer is published as a typed event.
    tracer: Option<Tracer>,
    rng: Pcg,
    pub stats: WanStats,
}

impl Wan {
    pub fn new(cfg: WanConfig, rng: Pcg) -> Self {
        let n = cfg.bandwidth.len();
        let current = cfg
            .bandwidth
            .iter()
            .map(|row| row.iter().map(|&(m, _)| m).collect())
            .collect();
        Wan {
            cfg,
            current,
            active: vec![vec![0; n]; n],
            degrade: 1.0,
            pair_degrade: vec![vec![1.0; n]; n],
            tracer: None,
            rng,
            stats: WanStats::default(),
        }
    }

    /// Publish WAN traffic onto the trace bus (the world attaches its
    /// tracer at construction; standalone Wans — Fig 2 — stay silent).
    pub fn attach_tracer(&mut self, tracer: Tracer) {
        self.tracer = Some(tracer);
    }

    fn emit(&self, event: TraceEvent) {
        if let Some(t) = &self.tracer {
            t.publish(event);
        }
    }

    /// Set the cross-DC degradation multiplier (clamped away from zero so
    /// transfers always terminate). 1.0 restores nominal behaviour.
    pub fn set_degrade(&mut self, factor: f64) {
        self.degrade = factor.max(0.01);
    }

    /// Current cross-DC degradation multiplier.
    pub fn degrade_factor(&self) -> f64 {
        self.degrade
    }

    /// Degrade (or restore, with 1.0) a single unordered region pair —
    /// the asymmetric-partition chaos axis. Clamped away from zero;
    /// intra-DC "pairs" are never degraded.
    pub fn set_pair_degrade(&mut self, a: DcId, b: DcId, factor: f64) {
        if a == b {
            return;
        }
        let f = factor.max(0.01);
        self.pair_degrade[a.0][b.0] = f;
        self.pair_degrade[b.0][a.0] = f;
    }

    /// Current per-pair degradation multiplier (1.0 = nominal).
    pub fn pair_degrade_factor(&self, a: DcId, b: DcId) -> f64 {
        self.pair_degrade[a.0][b.0]
    }

    pub fn num_dcs(&self) -> usize {
        self.current.len()
    }

    /// Seconds between AR(1) re-samples (driven by the world's timer).
    pub fn resample_period(&self) -> SimTime {
        secs_f(self.cfg.resample_secs)
    }

    /// Advance the AR(1) bandwidth process one step for every pair.
    pub fn resample(&mut self) {
        let phi = self.cfg.ar1_phi;
        let innov = (1.0 - phi * phi).sqrt();
        let n = self.num_dcs();
        for i in 0..n {
            for j in i..n {
                let (mean, std) = self.cfg.bandwidth[i][j];
                let x = self.current[i][j];
                let eps = self.rng.std_normal();
                let next = (mean + phi * (x - mean) + innov * std * eps).max(mean * 0.05);
                self.current[i][j] = next;
                self.current[j][i] = next; // symmetric links
            }
        }
    }

    /// Instantaneous bandwidth between two DCs (Mbps). Cross-DC links are
    /// additionally scaled by the scenario degradation multiplier.
    pub fn bandwidth_mbps(&self, a: DcId, b: DcId) -> f64 {
        if a == b {
            self.current[a.0][b.0]
        } else {
            self.current[a.0][b.0] * self.degrade * self.pair_degrade[a.0][b.0]
        }
    }

    /// One-way latency between two DCs (ms of virtual time).
    pub fn latency_ms(&self, a: DcId, b: DcId) -> f64 {
        if a == b {
            0.5
        } else {
            self.cfg.rtt_ms / 2.0
        }
    }

    /// Delay for a small control message of `bytes` from `a` to `b`.
    /// Control messages don't contend with bulk transfers (they are tiny),
    /// but they do ride the fluctuating bandwidth.
    pub fn message_delay(&mut self, a: DcId, b: DcId, bytes: u64) -> SimTime {
        self.stats.messages += 1;
        if a != b {
            self.stats.cross_dc_control_bytes += bytes;
        }
        self.emit(TraceEvent::WanMessage { from: a, to: b, bytes });
        let bw = self.bandwidth_mbps(a, b); // Mbps
        let ser_ms = (bytes as f64 * 8.0) / (bw * 1000.0); // ms
        secs_f((self.latency_ms(a, b) + ser_ms) / 1000.0).max(1)
    }

    /// Begin a bulk data transfer; returns its duration. Caller must call
    /// [`Wan::end_transfer`] when the scheduled completion event fires.
    /// Effective bandwidth = instantaneous pair bandwidth fair-shared
    /// across transfers active at start (including this one).
    pub fn begin_transfer(&mut self, a: DcId, b: DcId, bytes: u64) -> SimTime {
        self.stats.transfers += 1;
        if a != b {
            self.stats.cross_dc_data_bytes += bytes;
        }
        self.emit(TraceEvent::WanTransfer { from: a, to: b, bytes });
        self.active[a.0][b.0] += 1;
        if a != b {
            self.active[b.0][a.0] += 1;
        }
        let share = self.active[a.0][b.0].max(1) as f64;
        let bw = self.bandwidth_mbps(a, b) / share; // Mbps
        let xfer_ms = (bytes as f64 * 8.0) / (bw * 1000.0);
        secs_f((self.latency_ms(a, b) + xfer_ms) / 1000.0).max(1)
    }

    /// Release the slot taken by [`Wan::begin_transfer`].
    pub fn end_transfer(&mut self, a: DcId, b: DcId) {
        let x = &mut self.active[a.0][b.0];
        *x = x.saturating_sub(1);
        if a != b {
            let y = &mut self.active[b.0][a.0];
            *y = y.saturating_sub(1);
        }
    }

    /// iperf-style measurement of a pair: sample the AR(1) process
    /// `rounds × samples_per_round` times (advancing it), return
    /// (mean, std) Mbps — regenerates Fig 2.
    pub fn measure_pair(&mut self, a: DcId, b: DcId, rounds: usize, samples: usize) -> (f64, f64) {
        let mut xs = Vec::with_capacity(rounds * samples);
        for _ in 0..rounds {
            for _ in 0..samples {
                self.resample();
                xs.push(self.bandwidth_mbps(a, b));
            }
        }
        (crate::util::stats::mean(&xs), crate::util::stats::std_dev(&xs))
    }
}

/// Conservative-lookahead table for the sharded DES engine
/// ([`crate::sim::shard`]): the per-pair WAN latency *floor*, derived
/// from the same constants as [`Wan::latency_ms`] — 0.5 ms intra-DC,
/// one-way `rtt/2` cross-DC — rounded down and clamped `≥ 1` ms (the
/// engine's progress requirement). Every actual delay the fabric
/// computes adds serialization on top and is itself floored at 1 ms
/// ([`Wan::message_delay`], [`Wan::begin_transfer`]), so no event can
/// undercut these floors: they are safe lookahead.
pub fn wan_lookahead(cfg: &WanConfig, parts: usize) -> crate::sim::shard::Lookahead {
    let cross = (cfg.rtt_ms / 2.0).floor().max(1.0) as u64;
    crate::sim::shard::Lookahead::from_fn(parts, |a, b| if a == b { 1 } else { cross })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn wan() -> Wan {
        let cfg = Config::default();
        Wan::new(cfg.wan, Pcg::seeded(1))
    }

    /// The lookahead table must be a true lower bound on every delay the
    /// fabric can produce — otherwise conservative parallel execution
    /// would be unsound.
    #[test]
    fn lookahead_floors_never_exceed_actual_delays() {
        let mut w = wan();
        let cfg = Config::default();
        let la = wan_lookahead(&cfg.wan, w.num_dcs());
        for a in 0..w.num_dcs() {
            for b in 0..w.num_dcs() {
                assert!(la.floor(a, b) >= 1, "progress requires floors >= 1");
                let msg = w.message_delay(DcId(a), DcId(b), 64);
                assert!(
                    la.floor(a, b) <= msg,
                    "floor({a},{b})={} exceeds message delay {msg}",
                    la.floor(a, b)
                );
                let xfer = w.begin_transfer(DcId(a), DcId(b), 1024);
                w.end_transfer(DcId(a), DcId(b));
                assert!(la.floor(a, b) <= xfer, "floor exceeds transfer time");
            }
        }
    }

    #[test]
    fn lan_is_much_faster_than_wan() {
        let w = wan();
        assert!(w.bandwidth_mbps(DcId(0), DcId(0)) > 8.0 * w.bandwidth_mbps(DcId(0), DcId(1)));
    }

    #[test]
    fn ar1_stays_near_mean_with_right_spread() {
        let mut w = wan();
        let (mean, std) = w.measure_pair(DcId(0), DcId(1), 3, 1000);
        // Fig 2: NC-3 <-> NC-5 is (79, 22) Mbps.
        assert!((mean - 79.0).abs() < 5.0, "mean {mean}");
        assert!((std - 22.0).abs() < 5.0, "std {std}");
    }

    #[test]
    fn bandwidth_never_collapses_to_zero() {
        let mut w = wan();
        for _ in 0..10_000 {
            w.resample();
            assert!(w.bandwidth_mbps(DcId(1), DcId(3)) > 0.0);
        }
    }

    #[test]
    fn symmetric_links() {
        let mut w = wan();
        for _ in 0..100 {
            w.resample();
            assert_eq!(w.bandwidth_mbps(DcId(0), DcId(2)), w.bandwidth_mbps(DcId(2), DcId(0)));
        }
    }

    #[test]
    fn message_delay_scales_with_distance() {
        let mut w = wan();
        let local = w.message_delay(DcId(0), DcId(0), 1024);
        let remote = w.message_delay(DcId(0), DcId(1), 1024);
        assert!(remote > local, "remote {remote} <= local {local}");
        // rtt/2 = 15 ms dominates small messages.
        assert!((14..=40).contains(&remote), "remote {remote} ms");
    }

    #[test]
    fn transfers_fair_share_bandwidth() {
        let mut w = wan();
        let bytes = 100 * 1024 * 1024; // 100 MB
        let solo = w.begin_transfer(DcId(0), DcId(1), bytes);
        // A second concurrent transfer sees half the bandwidth.
        let shared = w.begin_transfer(DcId(0), DcId(1), bytes);
        assert!(shared > solo + solo / 2, "shared {shared} vs solo {solo}");
        w.end_transfer(DcId(0), DcId(1));
        w.end_transfer(DcId(0), DcId(1));
        let again = w.begin_transfer(DcId(0), DcId(1), bytes);
        assert_eq!(again, solo);
    }

    #[test]
    fn stats_track_cross_dc_traffic_only() {
        let mut w = wan();
        w.begin_transfer(DcId(0), DcId(0), 500);
        assert_eq!(w.stats.cross_dc_data_bytes, 0);
        w.begin_transfer(DcId(0), DcId(2), 500);
        assert_eq!(w.stats.cross_dc_data_bytes, 500);
        w.message_delay(DcId(1), DcId(2), 100);
        assert_eq!(w.stats.cross_dc_control_bytes, 100);
        assert_eq!(w.stats.transfers, 2);
        assert_eq!(w.stats.messages, 1);
    }

    #[test]
    fn degrade_scales_wan_but_not_lan() {
        let mut w = wan();
        let lan = w.bandwidth_mbps(DcId(0), DcId(0));
        let wan_bw = w.bandwidth_mbps(DcId(0), DcId(1));
        w.set_degrade(0.25);
        assert_eq!(w.bandwidth_mbps(DcId(0), DcId(0)), lan, "LAN untouched");
        assert!((w.bandwidth_mbps(DcId(0), DcId(1)) - wan_bw * 0.25).abs() < 1e-9);
        let slow = w.begin_transfer(DcId(0), DcId(1), 10 * 1024 * 1024);
        w.end_transfer(DcId(0), DcId(1));
        w.set_degrade(1.0);
        assert_eq!(w.bandwidth_mbps(DcId(0), DcId(1)), wan_bw, "restored exactly");
        let fast = w.begin_transfer(DcId(0), DcId(1), 10 * 1024 * 1024);
        assert!(slow > 3 * fast, "degraded transfer {slow}ms vs nominal {fast}ms");
    }

    #[test]
    fn pair_degrade_hits_only_the_targeted_pair() {
        let mut w = wan();
        let lan = w.bandwidth_mbps(DcId(0), DcId(0));
        let targeted = w.bandwidth_mbps(DcId(0), DcId(2));
        let other = w.bandwidth_mbps(DcId(0), DcId(1));
        w.set_pair_degrade(DcId(0), DcId(2), 0.1);
        assert_eq!(w.bandwidth_mbps(DcId(0), DcId(0)), lan, "LAN untouched");
        assert_eq!(w.bandwidth_mbps(DcId(0), DcId(1)), other, "other pairs untouched");
        assert!((w.bandwidth_mbps(DcId(0), DcId(2)) - targeted * 0.1).abs() < 1e-9);
        assert!((w.bandwidth_mbps(DcId(2), DcId(0)) - targeted * 0.1).abs() < 1e-9, "symmetric");
        // Composes with the global brownout factor.
        w.set_degrade(0.5);
        assert!((w.bandwidth_mbps(DcId(0), DcId(2)) - targeted * 0.05).abs() < 1e-9);
        w.set_degrade(1.0);
        w.set_pair_degrade(DcId(0), DcId(2), 1.0);
        assert_eq!(w.bandwidth_mbps(DcId(0), DcId(2)), targeted, "restored exactly");
        // Intra-DC pairs cannot be degraded.
        w.set_pair_degrade(DcId(1), DcId(1), 0.01);
        assert_eq!(w.pair_degrade_factor(DcId(1), DcId(1)), 1.0);
    }

    #[test]
    fn attached_tracer_sees_wan_traffic() {
        use crate::trace::{RingBuffer, RingSink, Tracer};
        let mut w = wan();
        let tracer = Tracer::new();
        let ring = RingBuffer::shared(16);
        tracer.attach(Box::new(RingSink(ring.clone())));
        w.attach_tracer(tracer);
        w.message_delay(DcId(0), DcId(1), 256);
        w.begin_transfer(DcId(1), DcId(2), 1024);
        let r = ring.borrow();
        let kinds: Vec<&str> = r.iter().map(|s| s.event.kind()).collect();
        assert_eq!(kinds, vec!["wan-message", "wan-transfer"]);
    }

    #[test]
    fn hundred_mb_transfer_is_seconds_over_wan() {
        let mut w = wan();
        let d = w.begin_transfer(DcId(0), DcId(1), 100 * 1024 * 1024);
        let secs = d as f64 / 1000.0;
        // 100 MB at ~79 Mbps ≈ 10.6 s.
        assert!((8.0..16.0).contains(&secs), "{secs}s");
    }
}
