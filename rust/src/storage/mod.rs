//! DFS substrate: HDFS-like datasets partitioned across data centers.
//!
//! The paper's jobs read tables "as if" centralized but with per-DC
//! masters (`hdfs://master1:9000/tpch/lineitem.tbl`, Fig 5); raw data may
//! not cross borders, so inputs stay put and tasks prefer the nodes that
//! host their partition. Each partition records its (dc, node) placement —
//! the locality preference Parades schedules against — plus its size,
//! which drives both transfer times and the initial task assignment
//! (proportional to per-DC data, §4.3).

use std::collections::HashMap;

use crate::ids::{DcId, NodeId};
use crate::util::Pcg;

/// One block/partition of a dataset.
#[derive(Debug, Clone)]
pub struct Partition {
    pub dataset: String,
    pub index: usize,
    pub bytes: u64,
    pub dc: DcId,
    pub node: NodeId,
}

/// A named dataset (input table / file).
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub name: String,
    pub partitions: Vec<Partition>,
}

impl Dataset {
    pub fn total_bytes(&self) -> u64 {
        self.partitions.iter().map(|p| p.bytes).sum()
    }

    /// Bytes per DC (the initial-assignment weights).
    pub fn bytes_per_dc(&self, num_dcs: usize) -> Vec<u64> {
        let mut out = vec![0u64; num_dcs];
        for p in &self.partitions {
            out[p.dc.0] += p.bytes;
        }
        out
    }
}

/// The geo-distributed file system: one logical namespace, physical blocks
/// pinned to regions.
#[derive(Debug, Default)]
pub struct Dfs {
    pub datasets: HashMap<String, Dataset>,
}

/// Standard HDFS block size (128 MB) — partition granularity.
pub const BLOCK_BYTES: u64 = 128 * 1024 * 1024;

impl Dfs {
    /// Ingest a dataset of `total_bytes`, split into ≥1 blocks of at most
    /// [`BLOCK_BYTES`], distributed over DCs proportionally to `weights`
    /// (e.g. `[1,1,1,1]` = even split; `[1,1,0,0]` = two regions only).
    /// Blocks land on nodes round-robin with a random rotation so
    /// placements differ across datasets.
    pub fn ingest(
        &mut self,
        name: &str,
        total_bytes: u64,
        weights: &[f64],
        nodes_per_dc: usize,
        rng: &mut Pcg,
    ) -> &Dataset {
        let wsum: f64 = weights.iter().sum();
        assert!(wsum > 0.0, "dataset {name} has zero placement weight");
        let mut ds = Dataset { name: name.to_string(), partitions: Vec::new() };
        let mut index = 0;
        for (d, &w) in weights.iter().enumerate() {
            if w <= 0.0 {
                continue;
            }
            let dc_bytes = (total_bytes as f64 * w / wsum).round() as u64;
            if dc_bytes == 0 {
                continue;
            }
            let nblocks = dc_bytes.div_ceil(BLOCK_BYTES).max(1);
            let rot = rng.index(nodes_per_dc.max(1));
            let mut remaining = dc_bytes;
            for b in 0..nblocks {
                let bytes = remaining.min(BLOCK_BYTES);
                remaining -= bytes;
                let node_idx = (rot + b as usize) % nodes_per_dc.max(1);
                ds.partitions.push(Partition {
                    dataset: name.to_string(),
                    index,
                    bytes,
                    dc: DcId(d),
                    node: NodeId { dc: DcId(d), idx: node_idx },
                });
                index += 1;
            }
        }
        self.datasets.insert(name.to_string(), ds);
        &self.datasets[name]
    }

    pub fn get(&self, name: &str) -> Option<&Dataset> {
        self.datasets.get(name)
    }

    /// Drop a dataset (intermediate cleanup).
    pub fn remove(&mut self, name: &str) -> Option<Dataset> {
        self.datasets.remove(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingest_splits_into_blocks() {
        let mut dfs = Dfs::default();
        let mut rng = Pcg::seeded(1);
        let gb = 1024 * 1024 * 1024;
        let ds = dfs.ingest("wordcount", 5 * gb, &[1.0; 4], 4, &mut rng);
        // 5 GB over 4 DCs = 1.25 GB/DC = 10 blocks of 128 MB each.
        assert_eq!(ds.partitions.len(), 40);
        let total = ds.total_bytes();
        assert!((total as i64 - (5 * gb) as i64).unsigned_abs() < 8, "total {total}");
    }

    #[test]
    fn weights_control_placement() {
        let mut dfs = Dfs::default();
        let mut rng = Pcg::seeded(2);
        let ds = dfs.ingest("orders", 512 * 1024 * 1024, &[1.0, 0.0, 1.0, 0.0], 4, &mut rng);
        let per_dc = ds.bytes_per_dc(4);
        assert_eq!(per_dc[1], 0);
        assert_eq!(per_dc[3], 0);
        assert!(per_dc[0] > 0 && per_dc[2] > 0);
        assert!((per_dc[0] as f64 / per_dc[2] as f64 - 1.0).abs() < 0.01);
    }

    #[test]
    fn small_dataset_is_one_block() {
        let mut dfs = Dfs::default();
        let mut rng = Pcg::seeded(3);
        let ds = dfs.ingest("tiny", 1000, &[1.0, 0.0], 4, &mut rng);
        assert_eq!(ds.partitions.len(), 1);
        assert_eq!(ds.partitions[0].bytes, 1000);
        assert_eq!(ds.partitions[0].dc, DcId(0));
    }

    #[test]
    fn partitions_carry_node_locality() {
        let mut dfs = Dfs::default();
        let mut rng = Pcg::seeded(4);
        let gb = 1024 * 1024 * 1024u64;
        let ds = dfs.ingest("pr", 2 * gb, &[1.0; 4], 4, &mut rng);
        for p in &ds.partitions {
            assert_eq!(p.node.dc, p.dc, "node must live in the partition's DC");
            assert!(p.node.idx < 4);
        }
        // Blocks within a DC spread across nodes.
        let dc0_nodes: std::collections::HashSet<usize> = ds
            .partitions
            .iter()
            .filter(|p| p.dc == DcId(0))
            .map(|p| p.node.idx)
            .collect();
        assert!(dc0_nodes.len() > 1);
    }

    #[test]
    fn remove_deletes() {
        let mut dfs = Dfs::default();
        let mut rng = Pcg::seeded(5);
        dfs.ingest("x", 1, &[1.0], 1, &mut rng);
        assert!(dfs.get("x").is_some());
        assert!(dfs.remove("x").is_some());
        assert!(dfs.get("x").is_none());
    }
}
