//! HOUTU launcher — see `houtu help`.
fn main() {
    houtu::cli::main();
}
