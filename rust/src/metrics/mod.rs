//! Run metrics: everything the paper's evaluation section plots.
//!
//! * Per-job records → JRT CDF, average JRT and makespan (Fig 8).
//! * Per-job cumulative task-launch timelines (Fig 9).
//! * Per-job container-count timelines (Fig 11).
//! * Steal-message delays, recovery intervals, election delays (Fig 12b).
//! * Intermediate-information sizes per workload (Fig 12a).
//! * Cost components come from [`crate::cloud::CostMeter`] + WAN stats.
//!
//! Since the trace-bus refactor, `Metrics` is a pure *fold* over the
//! typed event stream: it implements [`TraceSink`] and is populated
//! exclusively through [`Metrics::on_event`] — emission sites publish
//! [`TraceEvent`]s and never push figure bookkeeping directly. That makes
//! the figure outputs reproducible from any captured event stream (the
//! parity tests fold a ring-buffer capture into a fresh `Metrics` and
//! assert equality with the live one).

use std::collections::BTreeMap;

use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::JobId;
use crate::sim::to_secs;
use crate::trace::{Stamped, TraceEvent, TraceSink};
use crate::util::stats;

/// Outcome of one job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub id: JobId,
    pub kind: WorkloadKind,
    pub size: SizeClass,
    pub submitted_secs: f64,
    pub completed_secs: Option<f64>,
    /// Times the job was restarted from scratch (centralized JM failure).
    pub restarts: u32,
    /// JM recoveries survived (HOUTU job-level fault tolerance).
    pub recoveries: u32,
    pub tasks_total: usize,
}

impl JobRecord {
    /// Job response time (§4.1 footnote: release → completion).
    pub fn jrt(&self) -> Option<f64> {
        self.completed_secs.map(|c| c - self.submitted_secs)
    }
}

/// A (time, value) step timeline.
pub type Timeline = Vec<(f64, f64)>;

#[derive(Debug, Default, PartialEq)]
pub struct Metrics {
    pub jobs: BTreeMap<JobId, JobRecord>,
    /// Cumulative launched tasks per job (Fig 9).
    pub task_launches: BTreeMap<JobId, Timeline>,
    /// Containers held per job over time (Fig 11).
    pub containers: BTreeMap<JobId, Timeline>,
    /// One entry per steal round-trip, in milliseconds (Fig 12b).
    pub steal_delays_ms: Vec<f64>,
    /// JM failure → successor operating, in seconds (Fig 11 / 12b).
    pub recovery_intervals_secs: Vec<f64>,
    /// pJM election delays, seconds.
    pub election_delays_secs: Vec<f64>,
    /// Sampled intermediate-info sizes (bytes) per workload (Fig 12a).
    pub info_sizes: BTreeMap<WorkloadKind, Vec<f64>>,
    /// Tasks whose input crossed DCs (communication accounting aid).
    pub remote_input_tasks: u64,
    pub local_input_tasks: u64,
}

impl Metrics {
    fn submit(&mut self, id: JobId, kind: WorkloadKind, size: SizeClass, t: f64, tasks: usize) {
        self.jobs.insert(
            id,
            JobRecord {
                id,
                kind,
                size,
                submitted_secs: t,
                completed_secs: None,
                restarts: 0,
                recoveries: 0,
                tasks_total: tasks,
            },
        );
    }

    fn complete(&mut self, id: JobId, t: f64) {
        if let Some(r) = self.jobs.get_mut(&id) {
            r.completed_secs = Some(t);
        }
    }

    fn record_launch(&mut self, id: JobId, t: f64) {
        let tl = self.task_launches.entry(id).or_default();
        let next = tl.last().map(|&(_, c)| c + 1.0).unwrap_or(1.0);
        tl.push((t, next));
    }

    fn record_containers(&mut self, id: JobId, t: f64, count: usize) {
        self.containers.entry(id).or_default().push((t, count as f64));
    }

    fn record_info_size(&mut self, kind: WorkloadKind, bytes: usize) {
        self.info_sizes.entry(kind).or_default().push(bytes as f64);
    }

    /// Completed-job response times (seconds).
    pub fn jrts(&self) -> Vec<f64> {
        self.jobs.values().filter_map(JobRecord::jrt).collect()
    }

    pub fn avg_jrt(&self) -> f64 {
        stats::mean(&self.jrts())
    }

    /// Makespan: first submission → last completion (Definition 1).
    pub fn makespan(&self) -> f64 {
        let start = self
            .jobs
            .values()
            .map(|j| j.submitted_secs)
            .fold(f64::INFINITY, f64::min);
        let end = self
            .jobs
            .values()
            .filter_map(|j| j.completed_secs)
            .fold(f64::NEG_INFINITY, f64::max);
        if end > start {
            end - start
        } else {
            0.0
        }
    }

    pub fn completed_jobs(&self) -> usize {
        self.jobs.values().filter(|j| j.completed_secs.is_some()).count()
    }

    /// JRT CDF sampled at the given fractions (compact Fig-8a output).
    pub fn jrt_cdf(&self, fractions: &[f64]) -> Vec<(f64, f64)> {
        stats::cdf_at(&self.jrts(), fractions)
    }
}

impl TraceSink for Metrics {
    /// Fold one stamped event into the figure structures. The stamp's
    /// virtual time is the figure timestamp, so the fold reproduces the
    /// pre-trace-bus direct pushes bit for bit.
    fn on_event(&mut self, ev: &Stamped) {
        let t = to_secs(ev.time);
        match &ev.event {
            TraceEvent::JobSubmitted { job, kind, size, tasks } => {
                self.submit(*job, *kind, *size, t, *tasks);
            }
            TraceEvent::JobCompleted { job } => self.complete(*job, t),
            TraceEvent::JobRestarted { job } => {
                if let Some(r) = self.jobs.get_mut(job) {
                    r.restarts += 1;
                }
            }
            TraceEvent::TaskLaunched { job, remote_input, .. } => {
                self.record_launch(*job, t);
                if *remote_input {
                    self.remote_input_tasks += 1;
                } else {
                    self.local_input_tasks += 1;
                }
            }
            TraceEvent::ContainerCount { job, count } => {
                self.record_containers(*job, t, *count);
            }
            TraceEvent::InfoReplicated { kind, bytes, .. } => {
                self.record_info_size(*kind, *bytes);
            }
            TraceEvent::StealCompleted { delay_ms, .. } => {
                self.steal_delays_ms.push(*delay_ms);
            }
            TraceEvent::JmRecovered { job, interval_secs, .. } => {
                self.recovery_intervals_secs.push(*interval_secs);
                if let Some(r) = self.jobs.get_mut(job) {
                    r.recoveries += 1;
                }
            }
            TraceEvent::ElectionWon { delay_secs, .. } => {
                self.election_delays_secs.push(*delay_secs);
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::secs_f;

    fn m() -> Metrics {
        let mut m = Metrics::default();
        m.submit(JobId(1), WorkloadKind::WordCount, SizeClass::Small, 0.0, 4);
        m.submit(JobId(2), WorkloadKind::TpcH, SizeClass::Large, 60.0, 10);
        m.complete(JobId(1), 100.0);
        m.complete(JobId(2), 360.0);
        m
    }

    #[test]
    fn jrt_and_makespan() {
        let m = m();
        let mut jrts = m.jrts();
        jrts.sort_by(f64::total_cmp);
        assert_eq!(jrts, vec![100.0, 300.0]);
        assert_eq!(m.avg_jrt(), 200.0);
        assert_eq!(m.makespan(), 360.0);
        assert_eq!(m.completed_jobs(), 2);
    }

    #[test]
    fn incomplete_jobs_excluded_from_jrt() {
        let mut m = m();
        m.submit(JobId(3), WorkloadKind::PageRank, SizeClass::Medium, 120.0, 5);
        assert_eq!(m.jrts().len(), 2);
        assert_eq!(m.completed_jobs(), 2);
    }

    #[test]
    fn launch_timeline_is_cumulative() {
        let mut m = Metrics::default();
        for t in [1.0, 2.0, 5.0] {
            m.record_launch(JobId(1), t);
        }
        let tl = &m.task_launches[&JobId(1)];
        assert_eq!(tl.as_slice(), &[(1.0, 1.0), (2.0, 2.0), (5.0, 3.0)]);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::default();
        assert_eq!(m.avg_jrt(), 0.0);
        assert_eq!(m.makespan(), 0.0);
        assert!(m.jrt_cdf(&[0.5]).iter().all(|&(v, _)| v == 0.0));
    }

    /// Folding events through the sink must equal the direct mutators —
    /// the contract the emission-site refactor relies on.
    #[test]
    fn event_fold_matches_direct_mutators() {
        let job = JobId(7);
        let kind = WorkloadKind::PageRank;
        let task = crate::ids::TaskId { job, stage: crate::ids::StageId(0), index: 0 };
        let dc = crate::ids::DcId(1);
        let stamp = |t_secs: f64, seq, event| Stamped { time: secs_f(t_secs), seq, event };

        let mut folded = Metrics::default();
        folded.on_event(&stamp(
            1.0,
            0,
            TraceEvent::JobSubmitted { job, kind, size: SizeClass::Small, tasks: 3 },
        ));
        folded.on_event(&stamp(
            2.0,
            1,
            TraceEvent::TaskLaunched { job, task, dc, locality: "node-local", remote_input: true },
        ));
        folded.on_event(&stamp(3.0, 2, TraceEvent::ContainerCount { job, count: 4 }));
        folded.on_event(&stamp(4.0, 3, TraceEvent::InfoReplicated { job, kind, bytes: 2048 }));
        folded.on_event(&stamp(
            5.0,
            4,
            TraceEvent::StealCompleted { job, thief: dc, victim: crate::ids::DcId(2), tasks: 2, delay_ms: 63.5 },
        ));
        folded.on_event(&stamp(6.0, 5, TraceEvent::JmRecovered { job, dc, interval_secs: 12.5 }));
        folded.on_event(&stamp(
            7.0,
            6,
            TraceEvent::ElectionWon { job, new_primary: dc, delay_secs: 0.8 },
        ));
        folded.on_event(&stamp(8.0, 7, TraceEvent::JobRestarted { job }));
        folded.on_event(&stamp(9.0, 8, TraceEvent::JobCompleted { job }));

        let mut direct = Metrics::default();
        direct.submit(job, kind, SizeClass::Small, 1.0, 3);
        direct.record_launch(job, 2.0);
        direct.remote_input_tasks += 1;
        direct.record_containers(job, 3.0, 4);
        direct.record_info_size(kind, 2048);
        direct.steal_delays_ms.push(63.5);
        direct.recovery_intervals_secs.push(12.5);
        direct.jobs.get_mut(&job).unwrap().recoveries += 1;
        direct.election_delays_secs.push(0.8);
        direct.jobs.get_mut(&job).unwrap().restarts += 1;
        direct.complete(job, 9.0);

        assert_eq!(folded, direct);
    }
}
