//! Declarative scenario and campaign specs, with a TOML surface parsed by
//! the in-repo [`crate::config::toml`] subset parser.
//!
//! A *scenario* is (deployment, topology, workload, chaos events, config
//! overrides); a *campaign* is a set of scenarios crossed with a set of
//! seeds. Chaos events use a compact `kind@time:args` DSL (see
//! [`ChaosEvent::parse`]) because the TOML subset has no nested tables —
//! each event is one string in a flat array, which also keeps specs
//! greppable and diffable.

use std::collections::BTreeMap;

use crate::config::toml::{self, Doc, Value};
use crate::config::{Config, Deployment};
use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::{DcId, NodeId};
use crate::util::error::{Context, Result};
use crate::{anyhow, bail, ensure};

/// One chaos injection, placed on the simulation timeline by the runner.
#[derive(Debug, Clone, PartialEq)]
pub enum ChaosEvent {
    /// `hogs@T:0,2,3` — occupy (almost) all spare containers of the DCs
    /// from `T` seconds on (the Fig-9 resource-tense injection).
    InjectHogs { at_secs: f64, dcs: Vec<DcId> },
    /// `kill_jm@T:dc2` — kill the VM hosting job 0's JM replica in a DC
    /// (the Fig-11 pJM/sJM termination).
    KillJm { at_secs: f64, dc: DcId },
    /// `kill_jm_cascade@T:dc0,3,45` — cascading JM kills: kill job 0's
    /// JM in the given DC at `T`, then every `gap` seconds kill whichever
    /// DC hosts the *current* primary (the freshly-elected pJM), `count`
    /// kills in total. Generalizes the hand-coded
    /// `kill_pjm_then_new_pjm_too` path.
    KillJmCascade { at_secs: f64, dc: DcId, count: u32, gap_secs: f64 },
    /// `kill_node@T:dc1.n2` — spot-style termination of one worker VM.
    KillNode { at_secs: f64, node: NodeId },
    /// `kill_dc@T:dc2` — correlated whole-DC outage: every live worker VM
    /// of the region terminates at once (the ROADMAP's multi-region
    /// outage family). Nodes re-acquire after the usual delay.
    KillDc { at_secs: f64, dc: DcId },
    /// `spot_storm@T:dc1,300,4` — rolling spot-price storm: from `T` for
    /// `dur` seconds the region's market draws its log-price innovation
    /// with `sigma × factor` (PingAn-style adversarial price dynamics);
    /// the runner restores calm at `T+dur`. Only bites with
    /// `cloud.revocations=true`.
    SpotStorm { at_secs: f64, dc: DcId, dur_secs: f64, sigma_factor: f64 },
    /// `wan@T1-T2:0.25` — degrade all cross-DC bandwidth to the given
    /// fraction during the window (§2.2 changeable environment).
    WanDegrade { from_secs: f64, until_secs: f64, factor: f64 },
    /// `wan_pair@T:dc0,dc2,0.05` — asymmetric partition: from `T` on,
    /// scale only the (dcA, dcB) link by `factor`. A second event with
    /// factor 1 restores the pair.
    WanPairDegrade { at_secs: f64, a: DcId, b: DcId, factor: f64 },
}

fn parse_f64(s: &str, whole: &str) -> Result<f64> {
    s.trim()
        .parse::<f64>()
        .ok()
        .filter(|x| x.is_finite())
        .with_context(|| format!("event {whole:?}: bad number {s:?}"))
}

/// A point on the simulation timeline: finite and non-negative, so a
/// typo'd time can't silently clamp to t=0 and fire at submission.
fn parse_time(s: &str, whole: &str) -> Result<f64> {
    let t = parse_f64(s, whole)?;
    ensure!(t >= 0.0, "event {whole:?}: time {t} must be non-negative");
    Ok(t)
}

fn parse_usize(s: &str, whole: &str) -> Result<usize> {
    s.trim()
        .parse::<usize>()
        .map_err(|_| anyhow!("event {whole:?}: bad index {s:?}"))
}

fn parse_dc(s: &str, whole: &str) -> Result<DcId> {
    let body = s.trim().strip_prefix("dc").unwrap_or(s.trim());
    Ok(DcId(parse_usize(body, whole)?))
}

impl ChaosEvent {
    /// Parse the `kind@time:args` DSL (see the variant docs for shapes).
    pub fn parse(s: &str) -> Result<ChaosEvent> {
        let s = s.trim();
        let (head, rest) = s
            .split_once('@')
            .with_context(|| format!("event {s:?}: expected kind@time:args"))?;
        let (when, arg) = rest
            .split_once(':')
            .with_context(|| format!("event {s:?}: expected kind@time:args"))?;
        match head {
            "hogs" => {
                let at_secs = parse_time(when, s)?;
                let dcs = arg
                    .split(',')
                    .map(|d| parse_dc(d, s))
                    .collect::<Result<Vec<_>>>()?;
                ensure!(!dcs.is_empty(), "event {s:?}: need at least one dc");
                Ok(ChaosEvent::InjectHogs { at_secs, dcs })
            }
            "kill_jm" => Ok(ChaosEvent::KillJm {
                at_secs: parse_time(when, s)?,
                dc: parse_dc(arg, s)?,
            }),
            "kill_jm_cascade" => {
                let parts: Vec<&str> = arg.split(',').collect();
                ensure!(parts.len() == 3, "event {s:?}: args must be dc,count,gap");
                let count = parse_usize(parts[1], s)?;
                ensure!(count >= 1, "event {s:?}: need at least one kill");
                let gap_secs = parse_f64(parts[2], s)?;
                ensure!(gap_secs > 0.0, "event {s:?}: gap must be positive");
                Ok(ChaosEvent::KillJmCascade {
                    at_secs: parse_time(when, s)?,
                    dc: parse_dc(parts[0], s)?,
                    count: count as u32,
                    gap_secs,
                })
            }
            "kill_node" => {
                let (dc, idx) = arg
                    .split_once('.')
                    .with_context(|| format!("event {s:?}: node must be dcD.nI"))?;
                let idx = idx.trim().strip_prefix('n').unwrap_or(idx.trim());
                Ok(ChaosEvent::KillNode {
                    at_secs: parse_time(when, s)?,
                    node: NodeId { dc: parse_dc(dc, s)?, idx: parse_usize(idx, s)? },
                })
            }
            "kill_dc" => Ok(ChaosEvent::KillDc {
                at_secs: parse_time(when, s)?,
                dc: parse_dc(arg, s)?,
            }),
            "spot_storm" => {
                let parts: Vec<&str> = arg.split(',').collect();
                ensure!(parts.len() == 3, "event {s:?}: args must be dc,dur,sigma_factor");
                let dur_secs = parse_f64(parts[1], s)?;
                ensure!(dur_secs > 0.0, "event {s:?}: duration must be positive");
                let sigma_factor = parse_f64(parts[2], s)?;
                ensure!(sigma_factor > 0.0, "event {s:?}: sigma factor must be positive");
                Ok(ChaosEvent::SpotStorm {
                    at_secs: parse_time(when, s)?,
                    dc: parse_dc(parts[0], s)?,
                    dur_secs,
                    sigma_factor,
                })
            }
            "wan" => {
                let (from, until) = when
                    .split_once('-')
                    .with_context(|| format!("event {s:?}: window must be T1-T2"))?;
                let from_secs = parse_time(from, s)?;
                let until_secs = parse_time(until, s)?;
                let factor = parse_f64(arg, s)?;
                ensure!(until_secs > from_secs, "event {s:?}: empty window");
                ensure!(factor > 0.0, "event {s:?}: factor must be positive");
                Ok(ChaosEvent::WanDegrade { from_secs, until_secs, factor })
            }
            "wan_pair" => {
                let parts: Vec<&str> = arg.split(',').collect();
                ensure!(parts.len() == 3, "event {s:?}: args must be dcA,dcB,factor");
                let a = parse_dc(parts[0], s)?;
                let b = parse_dc(parts[1], s)?;
                ensure!(a != b, "event {s:?}: pair must span two distinct DCs");
                let factor = parse_f64(parts[2], s)?;
                ensure!(factor > 0.0, "event {s:?}: factor must be positive");
                Ok(ChaosEvent::WanPairDegrade { at_secs: parse_time(when, s)?, a, b, factor })
            }
            other => bail!(
                "unknown event kind {other:?} \
                 (hogs|kill_jm|kill_jm_cascade|kill_node|kill_dc|wan|wan_pair|spot_storm)"
            ),
        }
    }
}

impl std::fmt::Display for ChaosEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChaosEvent::InjectHogs { at_secs, dcs } => {
                let list: Vec<String> = dcs.iter().map(|d| d.0.to_string()).collect();
                write!(f, "hogs@{at_secs}:{}", list.join(","))
            }
            ChaosEvent::KillJm { at_secs, dc } => write!(f, "kill_jm@{at_secs}:dc{}", dc.0),
            ChaosEvent::KillJmCascade { at_secs, dc, count, gap_secs } => {
                write!(f, "kill_jm_cascade@{at_secs}:dc{},{count},{gap_secs}", dc.0)
            }
            ChaosEvent::KillNode { at_secs, node } => {
                write!(f, "kill_node@{at_secs}:dc{}.n{}", node.dc.0, node.idx)
            }
            ChaosEvent::KillDc { at_secs, dc } => write!(f, "kill_dc@{at_secs}:dc{}", dc.0),
            ChaosEvent::SpotStorm { at_secs, dc, dur_secs, sigma_factor } => {
                write!(f, "spot_storm@{at_secs}:dc{},{dur_secs},{sigma_factor}", dc.0)
            }
            ChaosEvent::WanDegrade { from_secs, until_secs, factor } => {
                write!(f, "wan@{from_secs}-{until_secs}:{factor}")
            }
            ChaosEvent::WanPairDegrade { at_secs, a, b, factor } => {
                write!(f, "wan_pair@{at_secs}:dc{},dc{},{factor}", a.0, b.0)
            }
        }
    }
}

/// What the scenario submits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioWorkload {
    /// One job, submitted at t≈0 (the Fig-9/Fig-11 shape).
    SingleJob { kind: WorkloadKind, size: SizeClass, home: DcId },
    /// An online trace of `num_jobs` arrivals (the Fig-8 shape).
    Trace { num_jobs: usize },
}

/// One fully-described situation to put the system in.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub deployment: Deployment,
    /// Region count; 0 keeps the base config's topology (the paper's 4).
    pub regions: usize,
    pub workload: ScenarioWorkload,
    pub events: Vec<ChaosEvent>,
    /// `section.key=value` strings applied through
    /// [`Config::apply_override`] — the same surface as the CLI `--set`.
    pub overrides: Vec<String>,
}

impl ScenarioSpec {
    /// Materialize the run config: base ⊕ seed ⊕ deployment ⊕ overrides ⊕
    /// topology ⊕ workload sizing, then validate spec-vs-topology fit.
    pub fn build_config(&self, base: &Config, seed: u64) -> Result<Config> {
        let mut cfg = base.clone();
        cfg.seed = seed;
        cfg.deployment = self.deployment;
        for ov in &self.overrides {
            cfg.apply_override(ov)
                .with_context(|| format!("scenario {:?} override {ov:?}", self.name))?;
        }
        if self.regions > 0 && !cfg.topology.generated.is_empty() {
            bail!(
                "scenario {:?}: regions = {} conflicts with generated topology {:?} \
                 (the token fixes the DC count)",
                self.name,
                self.regions,
                cfg.topology.generated
            );
        }
        if self.regions > 0 && self.regions != cfg.topology.num_dcs() {
            cfg.topology.regions = (0..self.regions).map(|i| format!("R{i}")).collect();
        }
        if let ScenarioWorkload::Trace { num_jobs } = self.workload {
            ensure!(num_jobs > 0, "scenario {:?}: trace needs at least one job", self.name);
            cfg.workload.num_jobs = num_jobs;
        }
        cfg.resize_bandwidth();
        cfg.validate()?;
        let n = cfg.topology.num_dcs();
        if let ScenarioWorkload::SingleJob { home, .. } = self.workload {
            ensure!(home.0 < n, "scenario {:?}: home dc{} out of range (n={n})", self.name, home.0);
        }
        for ev in &self.events {
            let ok = match ev {
                ChaosEvent::InjectHogs { dcs, .. } => dcs.iter().all(|d| d.0 < n),
                ChaosEvent::KillJm { dc, .. } => dc.0 < n,
                ChaosEvent::KillJmCascade { dc, .. } => dc.0 < n,
                ChaosEvent::KillNode { node, .. } => {
                    node.dc.0 < n && node.idx < cfg.topology.workers_per_dc
                }
                ChaosEvent::KillDc { dc, .. } => dc.0 < n,
                ChaosEvent::SpotStorm { dc, .. } => dc.0 < n,
                ChaosEvent::WanDegrade { .. } => true,
                ChaosEvent::WanPairDegrade { a, b, .. } => a.0 < n && b.0 < n,
            };
            ensure!(ok, "scenario {:?}: event {ev} outside the {n}-region topology", self.name);
        }
        // WAN windows restore the factor to nominal at their end, so
        // overlapping windows would silently cancel each other — reject.
        let mut windows: Vec<(f64, f64)> = self
            .events
            .iter()
            .filter_map(|e| match e {
                ChaosEvent::WanDegrade { from_secs, until_secs, .. } => {
                    Some((*from_secs, *until_secs))
                }
                _ => None,
            })
            .collect();
        windows.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        for pair in windows.windows(2) {
            ensure!(
                pair[0].1 <= pair[1].0,
                "scenario {:?}: overlapping wan windows {}-{} and {}-{}",
                self.name,
                pair[0].0,
                pair[0].1,
                pair[1].0,
                pair[1].1
            );
        }
        // Spot storms restore calm (factor 1) at their end, so overlapping
        // windows on the same region would cancel each other — reject.
        let mut storms: Vec<(usize, f64, f64)> = self
            .events
            .iter()
            .filter_map(|e| match e {
                ChaosEvent::SpotStorm { at_secs, dc, dur_secs, .. } => {
                    Some((dc.0, *at_secs, *at_secs + *dur_secs))
                }
                _ => None,
            })
            .collect();
        storms.sort_by(|a, b| {
            a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)).then(a.2.total_cmp(&b.2))
        });
        for pair in storms.windows(2) {
            ensure!(
                pair[0].0 != pair[1].0 || pair[0].2 <= pair[1].1,
                "scenario {:?}: overlapping spot storms on dc{}",
                self.name,
                pair[0].0
            );
        }
        Ok(cfg)
    }

    fn from_keys(name: &str, keys: &BTreeMap<String, Value>) -> Result<ScenarioSpec> {
        // A typo'd key (e.g. `event` for `events`) must not silently yield
        // a chaos-free scenario that then passes every invariant.
        const KNOWN: [&str; 10] = [
            "deployment",
            "workload",
            "size",
            "home",
            "num_jobs",
            "regions",
            "events",
            "overrides",
            "strategy",
            "topology",
        ];
        for k in keys.keys() {
            ensure!(
                KNOWN.contains(&k.as_str()),
                "scenario {name:?}: unknown key {k:?} (known: {})",
                KNOWN.join(", ")
            );
        }
        let get_str = |k: &str| keys.get(k).and_then(Value::as_str);
        let get_i64 = |k: &str, d: i64| keys.get(k).and_then(Value::as_i64).unwrap_or(d);
        let deployment = match get_str("deployment") {
            Some(s) => Deployment::parse(s)?,
            None => Deployment::Houtu,
        };
        let workload = match get_str("workload").unwrap_or("wordcount") {
            "trace" => ScenarioWorkload::Trace { num_jobs: get_i64("num_jobs", 4).max(1) as usize },
            w => {
                let kind = match w {
                    "wordcount" => WorkloadKind::WordCount,
                    "tpch" => WorkloadKind::TpcH,
                    "ml" => WorkloadKind::IterativeMl,
                    "pagerank" => WorkloadKind::PageRank,
                    other => bail!(
                        "scenario {name:?}: unknown workload {other:?} \
                         (wordcount|tpch|ml|pagerank|trace)"
                    ),
                };
                let size = match get_str("size").unwrap_or("medium") {
                    "small" => SizeClass::Small,
                    "medium" => SizeClass::Medium,
                    "large" => SizeClass::Large,
                    other => bail!("scenario {name:?}: unknown size {other:?}"),
                };
                let home = DcId(get_i64("home", 0).max(0) as usize);
                ScenarioWorkload::SingleJob { kind, size, home }
            }
        };
        let str_array = |k: &str| -> Result<Vec<String>> {
            match keys.get(k) {
                None => Ok(Vec::new()),
                Some(v) => v
                    .as_array()
                    .with_context(|| format!("scenario {name:?}: {k} must be an array"))?
                    .iter()
                    .map(|x| {
                        x.as_str()
                            .map(str::to_string)
                            .with_context(|| format!("scenario {name:?}: {k} entries must be strings"))
                    })
                    .collect(),
            }
        };
        let events = str_array("events")?
            .iter()
            .map(|s| ChaosEvent::parse(s))
            .collect::<Result<Vec<_>>>()?;
        let mut overrides = str_array("overrides")?;
        // `strategy = "adaptive"` is sugar for the bidding override: it
        // validates the token at parse time and lands in `overrides`, so
        // spec equality, repro TOMLs and the fuzzer all see one surface.
        if let Some(s) = get_str("strategy") {
            crate::cloud::bidding::StrategyKind::parse(s)
                .with_context(|| format!("scenario {name:?}: bad strategy"))?;
            overrides.push(format!("bidding.strategy={s}"));
        }
        // `topology = "generated:..."` is sugar for the topology override,
        // validated at parse time like `strategy` above.
        if let Some(s) = get_str("topology") {
            crate::topo::parse_spec(s)
                .with_context(|| format!("scenario {name:?}: bad topology"))?;
            overrides.push(format!("topology.generated={s}"));
        }
        Ok(ScenarioSpec {
            name: name.to_string(),
            deployment,
            regions: get_i64("regions", 0).max(0) as usize,
            workload,
            events,
            overrides,
        })
    }
}

/// A scenario × seed matrix.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    pub name: String,
    pub seeds: Vec<u64>,
    pub scenarios: Vec<ScenarioSpec>,
    /// Worker threads; 0 = one per available core.
    pub parallelism: usize,
}

impl CampaignSpec {
    /// The full run matrix, scenario-major then seed (stable order — run
    /// indices, reports and campaign digests all follow it).
    pub fn expand(&self) -> Vec<(ScenarioSpec, u64)> {
        let mut out = Vec::with_capacity(self.scenarios.len() * self.seeds.len());
        for sc in &self.scenarios {
            for &seed in &self.seeds {
                out.push((sc.clone(), seed));
            }
        }
        out
    }

    /// Parse from TOML text: a `[campaign]` section (`name`, `seeds`,
    /// optional `parallelism`) plus one `[scenario.<name>]` section per
    /// scenario.
    pub fn from_doc(doc: &Doc) -> Result<CampaignSpec> {
        let name = doc.str_or("campaign", "name", "campaign");
        let seeds: Vec<u64> = match doc.get("campaign", "seeds") {
            None => vec![42],
            Some(v) => v
                .as_array()
                .context("campaign.seeds must be an array")?
                .iter()
                .map(|x| {
                    x.as_i64()
                        .filter(|&i| i >= 0)
                        .map(|i| i as u64)
                        .context("campaign.seeds entries must be non-negative integers")
                })
                .collect::<Result<_>>()?,
        };
        ensure!(!seeds.is_empty(), "campaign.seeds must not be empty");
        let mut scenarios = Vec::new();
        for (section, keys) in &doc.sections {
            if section.is_empty() {
                ensure!(
                    keys.is_empty(),
                    "top-level keys {:?} are not allowed (use [campaign] or [scenario.<name>])",
                    keys.keys().collect::<Vec<_>>()
                );
                continue;
            }
            if section == "campaign" {
                for k in keys.keys() {
                    ensure!(
                        matches!(k.as_str(), "name" | "seeds" | "parallelism"),
                        "unknown campaign key {k:?} (known: name, seeds, parallelism)"
                    );
                }
                continue;
            }
            let Some(sc_name) = section.strip_prefix("scenario.") else {
                bail!("unknown section [{section}] (expected [campaign] or [scenario.<name>])");
            };
            scenarios.push(ScenarioSpec::from_keys(sc_name, keys)?);
        }
        ensure!(!scenarios.is_empty(), "campaign has no [scenario.<name>] sections");
        Ok(CampaignSpec {
            name,
            seeds,
            scenarios,
            parallelism: doc.i64_or("campaign", "parallelism", 0).max(0) as usize,
        })
    }

    /// Parse a campaign TOML file.
    pub fn from_file(path: &str) -> Result<CampaignSpec> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let doc = toml::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        Self::from_doc(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_dsl_parses_every_kind() {
        assert_eq!(
            ChaosEvent::parse("hogs@100:0,2,3").unwrap(),
            ChaosEvent::InjectHogs { at_secs: 100.0, dcs: vec![DcId(0), DcId(2), DcId(3)] }
        );
        assert_eq!(
            ChaosEvent::parse("kill_jm@70:dc2").unwrap(),
            ChaosEvent::KillJm { at_secs: 70.0, dc: DcId(2) }
        );
        assert_eq!(
            ChaosEvent::parse("kill_node@50:dc1.n2").unwrap(),
            ChaosEvent::KillNode { at_secs: 50.0, node: NodeId { dc: DcId(1), idx: 2 } }
        );
        assert_eq!(
            ChaosEvent::parse("wan@120-300:0.25").unwrap(),
            ChaosEvent::WanDegrade { from_secs: 120.0, until_secs: 300.0, factor: 0.25 }
        );
        assert_eq!(
            ChaosEvent::parse("wan_pair@30:dc0,dc2,0.05").unwrap(),
            ChaosEvent::WanPairDegrade { at_secs: 30.0, a: DcId(0), b: DcId(2), factor: 0.05 }
        );
        assert_eq!(
            ChaosEvent::parse("kill_jm_cascade@70:dc0,3,45").unwrap(),
            ChaosEvent::KillJmCascade { at_secs: 70.0, dc: DcId(0), count: 3, gap_secs: 45.0 }
        );
        assert_eq!(
            ChaosEvent::parse("kill_dc@60:dc2").unwrap(),
            ChaosEvent::KillDc { at_secs: 60.0, dc: DcId(2) }
        );
        assert_eq!(
            ChaosEvent::parse("spot_storm@120:dc1,300,4").unwrap(),
            ChaosEvent::SpotStorm {
                at_secs: 120.0,
                dc: DcId(1),
                dur_secs: 300.0,
                sigma_factor: 4.0
            }
        );
    }

    #[test]
    fn nan_event_times_fail_validation_without_panicking() {
        // The window/storm overlap checks sort by f64 keys; the old
        // `partial_cmp(..).unwrap()` panicked on the first NaN instead of
        // rejecting the spec. NaN never satisfies `a <= b`, so the overlap
        // ensure now reports these as invalid.
        let spec = ScenarioSpec {
            name: "nan-windows".into(),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::Trace { num_jobs: 1 },
            events: vec![
                ChaosEvent::WanDegrade { from_secs: 10.0, until_secs: 20.0, factor: 0.5 },
                ChaosEvent::WanDegrade { from_secs: f64::NAN, until_secs: f64::NAN, factor: 0.5 },
            ],
            overrides: vec![],
        };
        assert!(spec.build_config(&Config::default(), 1).is_err());

        let spec = ScenarioSpec {
            name: "nan-storms".into(),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::Trace { num_jobs: 1 },
            events: vec![
                ChaosEvent::SpotStorm { at_secs: 5.0, dc: DcId(0), dur_secs: 10.0, sigma_factor: 2.0 },
                ChaosEvent::SpotStorm {
                    at_secs: f64::NAN,
                    dc: DcId(0),
                    dur_secs: 10.0,
                    sigma_factor: 2.0,
                },
            ],
            overrides: vec![],
        };
        assert!(spec.build_config(&Config::default(), 1).is_err());
    }

    #[test]
    fn event_dsl_display_roundtrips() {
        for s in [
            "hogs@100:0,2,3",
            "kill_jm@70:dc2",
            "kill_jm_cascade@70:dc0,3,45",
            "kill_node@50:dc1.n2",
            "kill_dc@60:dc2",
            "spot_storm@120:dc1,300,4",
            "spot_storm@12.5:dc0,60.25,2.5",
            "wan@120-300:0.25",
            "wan_pair@30:dc0,dc2,0.05",
        ] {
            let ev = ChaosEvent::parse(s).unwrap();
            assert_eq!(ChaosEvent::parse(&ev.to_string()).unwrap(), ev, "{s}");
        }
    }

    #[test]
    fn event_dsl_rejects_garbage() {
        for s in [
            "hogs100:0",
            "hogs@x:0",
            "hogs@10:",
            "kill_jm@70",
            "kill_jm@-70:dc0",
            "kill_jm@NaN:dc0",
            "kill_jm@inf:dc0",
            "kill_jm_cascade@70:dc0",
            "kill_jm_cascade@70:dc0,0,45",
            "kill_jm_cascade@70:dc0,3,0",
            "kill_jm_cascade@70:dc0,3,45,9",
            "kill_node@50:dc1",
            "kill_dc@60",
            "kill_dc@-5:dc1",
            "spot_storm@120:dc1",
            "spot_storm@120:dc1,0,4",
            "spot_storm@120:dc1,300,0",
            "spot_storm@120:dc1,300,4,9",
            "wan@300-120:0.25",
            "wan@1-2:0",
            "wan@1-2:NaN",
            "wan_pair@30:dc0,dc0,0.5",
            "wan_pair@30:dc0,dc1,0",
            "wan_pair@30:dc0,dc1",
            "meteor@9:dc0",
        ] {
            assert!(ChaosEvent::parse(s).is_err(), "{s:?} should not parse");
        }
    }

    #[test]
    fn campaign_doc_parses_matrix() {
        let doc = toml::parse(
            r#"
            [campaign]
            name = "demo"
            seeds = [1, 2, 3]

            [scenario.a]
            workload = "pagerank"
            size = "large"
            home = 1
            events = ["hogs@100:0,2,3"]

            [scenario.b]
            workload = "trace"
            num_jobs = 5
            deployment = "cent-dyna"
            overrides = ["cloud.revocations=true"]
            "#,
        )
        .unwrap();
        let c = CampaignSpec::from_doc(&doc).unwrap();
        assert_eq!(c.name, "demo");
        assert_eq!(c.expand().len(), 6);
        let a = &c.scenarios[0];
        assert_eq!(a.name, "a");
        assert_eq!(
            a.workload,
            ScenarioWorkload::SingleJob {
                kind: WorkloadKind::PageRank,
                size: SizeClass::Large,
                home: DcId(1)
            }
        );
        assert_eq!(a.events.len(), 1);
        let b = &c.scenarios[1];
        assert_eq!(b.deployment, Deployment::CentDyna);
        assert_eq!(b.workload, ScenarioWorkload::Trace { num_jobs: 5 });
        assert_eq!(b.overrides, vec!["cloud.revocations=true".to_string()]);
    }

    #[test]
    fn strategy_key_desugars_to_a_bidding_override() {
        let doc = toml::parse(
            r#"
            [campaign]
            seeds = [1]
            [scenario.bid]
            workload = "trace"
            num_jobs = 2
            strategy = "adaptive"
            overrides = ["cloud.revocations=true"]
            "#,
        )
        .unwrap();
        let c = CampaignSpec::from_doc(&doc).unwrap();
        assert_eq!(
            c.scenarios[0].overrides,
            vec!["cloud.revocations=true".to_string(), "bidding.strategy=adaptive".to_string()]
        );
        // The materialized config actually carries the strategy.
        let cfg = c.scenarios[0].build_config(&Config::default(), 1).unwrap();
        assert_eq!(cfg.bidding.strategy, crate::cloud::bidding::StrategyKind::Adaptive);
        // A bad token fails at parse time, not at run time.
        let doc = toml::parse(
            "[campaign]\nseeds = [1]\n[scenario.x]\nworkload = \"trace\"\nstrategy = \"greedy\"\n",
        )
        .unwrap();
        let err = CampaignSpec::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("bad strategy"), "{err}");
    }

    #[test]
    fn topology_key_desugars_to_a_generated_override() {
        let doc = toml::parse(
            r#"
            [campaign]
            seeds = [1]
            [scenario.planet]
            workload = "trace"
            num_jobs = 2
            topology = "generated:16,2,7"
            "#,
        )
        .unwrap();
        let c = CampaignSpec::from_doc(&doc).unwrap();
        assert_eq!(
            c.scenarios[0].overrides,
            vec!["topology.generated=generated:16,2,7".to_string()]
        );
        let cfg = c.scenarios[0].build_config(&Config::default(), 1).unwrap();
        assert_eq!(cfg.topology.num_dcs(), 16);
        assert_eq!(cfg.topology.workers_per_dc, 2);
        assert_eq!(cfg.wan.bandwidth.len(), 16);
        // A bad token fails at parse time, not at run time.
        let doc = toml::parse(
            "[campaign]\nseeds = [1]\n[scenario.x]\nworkload = \"trace\"\ntopology = \"generated:16\"\n",
        )
        .unwrap();
        let err = CampaignSpec::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("bad topology"), "{err}");
        // `regions` and a generated topology fight over the DC count.
        let clash = ScenarioSpec {
            name: "clash".into(),
            deployment: Deployment::Houtu,
            regions: 8,
            workload: ScenarioWorkload::Trace { num_jobs: 1 },
            events: vec![],
            overrides: vec!["topology.generated=generated:16,2,7".into()],
        };
        let err = clash.build_config(&Config::default(), 1).unwrap_err().to_string();
        assert!(err.contains("conflicts with generated topology"), "{err}");
        // Chaos targets validate against the generated DC/node counts.
        let out_of_range = ScenarioSpec {
            name: "oob".into(),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::Trace { num_jobs: 1 },
            events: vec![ChaosEvent::KillDc { at_secs: 10.0, dc: DcId(70) }],
            overrides: vec!["topology.generated=generated:64,4,7".into()],
        };
        let err = out_of_range.build_config(&Config::default(), 1).unwrap_err().to_string();
        assert!(err.contains("outside the 64-region topology"), "{err}");
    }

    #[test]
    fn campaign_doc_requires_scenarios() {
        let doc = toml::parse("[campaign]\nseeds = [1]\n").unwrap();
        assert!(CampaignSpec::from_doc(&doc).is_err());
    }

    #[test]
    fn campaign_doc_rejects_typo_keys_and_sections() {
        // `event` (singular) must not silently produce a chaos-free run.
        let doc = toml::parse(
            "[campaign]\nseeds = [1]\n[scenario.x]\nevent = [\"kill_jm@70:dc0\"]\n",
        )
        .unwrap();
        let err = CampaignSpec::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown key"), "{err}");
        // Typo'd section name.
        let doc = toml::parse("[campaign]\nseeds = [1]\n[scenarios.x]\nworkload = \"trace\"\n")
            .unwrap();
        assert!(CampaignSpec::from_doc(&doc).is_err());
        // Typo'd campaign key.
        let doc = toml::parse("[campaign]\nseed = [1]\n[scenario.x]\nworkload = \"trace\"\n")
            .unwrap();
        let err = CampaignSpec::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown campaign key"), "{err}");
        // Stray top-level key.
        let doc = toml::parse("seeds = [1]\n[scenario.x]\nworkload = \"trace\"\n").unwrap();
        assert!(CampaignSpec::from_doc(&doc).is_err());
    }

    #[test]
    fn overlapping_wan_windows_are_rejected() {
        let mk = |events| ScenarioSpec {
            name: "wan".into(),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::Trace { num_jobs: 1 },
            events,
            overrides: vec![],
        };
        let sequential = mk(vec![
            ChaosEvent::WanDegrade { from_secs: 0.0, until_secs: 100.0, factor: 0.5 },
            ChaosEvent::WanDegrade { from_secs: 100.0, until_secs: 200.0, factor: 0.2 },
        ]);
        assert!(sequential.build_config(&Config::default(), 1).is_ok());
        let overlapping = mk(vec![
            ChaosEvent::WanDegrade { from_secs: 0.0, until_secs: 500.0, factor: 0.5 },
            ChaosEvent::WanDegrade { from_secs: 100.0, until_secs: 200.0, factor: 0.1 },
        ]);
        let err = overlapping.build_config(&Config::default(), 1).unwrap_err();
        assert!(err.to_string().contains("overlapping wan windows"), "{err}");
    }

    #[test]
    fn overlapping_spot_storms_same_dc_are_rejected() {
        let mk = |events| ScenarioSpec {
            name: "storm".into(),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::Trace { num_jobs: 1 },
            events,
            overrides: vec![],
        };
        let storm = |dc, at, dur| ChaosEvent::SpotStorm {
            at_secs: at,
            dc: DcId(dc),
            dur_secs: dur,
            sigma_factor: 3.0,
        };
        // Sequential on one DC and concurrent on two DCs are both fine.
        assert!(mk(vec![storm(0, 10.0, 50.0), storm(0, 60.0, 50.0)])
            .build_config(&Config::default(), 1)
            .is_ok());
        assert!(mk(vec![storm(0, 10.0, 500.0), storm(1, 100.0, 50.0)])
            .build_config(&Config::default(), 1)
            .is_ok());
        // Overlap on the same DC would let the first restore cancel the
        // second storm mid-window.
        let err = mk(vec![storm(2, 10.0, 500.0), storm(2, 100.0, 50.0)])
            .build_config(&Config::default(), 1)
            .unwrap_err();
        assert!(err.to_string().contains("overlapping spot storms"), "{err}");
    }

    #[test]
    fn build_config_applies_axes_and_checks_fit() {
        let base = Config::default();
        let spec = ScenarioSpec {
            name: "t".into(),
            deployment: Deployment::DecentStat,
            regions: 8,
            workload: ScenarioWorkload::Trace { num_jobs: 3 },
            events: vec![ChaosEvent::KillJm { at_secs: 10.0, dc: DcId(7) }],
            overrides: vec!["scheduler.tau=0.25".into()],
        };
        let cfg = spec.build_config(&base, 9).unwrap();
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.deployment, Deployment::DecentStat);
        assert_eq!(cfg.topology.num_dcs(), 8);
        assert_eq!(cfg.wan.bandwidth.len(), 8);
        assert_eq!(cfg.workload.num_jobs, 3);
        assert_eq!(cfg.scheduler.tau, 0.25);
        // Same spec on the 4-region default topology: the dc7 kill no
        // longer fits.
        let narrow = ScenarioSpec { regions: 0, ..spec };
        assert!(narrow.build_config(&base, 9).is_err());
    }

    #[test]
    fn bad_override_is_reported_with_scenario_name() {
        let spec = ScenarioSpec {
            name: "oops".into(),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::Trace { num_jobs: 1 },
            events: vec![],
            overrides: vec!["scheduler.rho=0.5".into()],
        };
        let err = spec.build_config(&Config::default(), 1).unwrap_err();
        assert!(err.to_string().contains("oops"), "{err}");
    }
}
