//! Scenario-matrix chaos engine: declarative reliability campaigns.
//!
//! HOUTU's claim is *reliable* job execution under spot revocations, JM
//! failures and WAN variability. Hand-coding each situation (as `exp/`
//! historically did) caps the explored space at however many functions we
//! write; this subsystem makes scenario count a **config knob**: a TOML
//! file describes a matrix of (scenario × seed) runs, a parallel runner
//! executes them on the deterministic DES, and an invariant layer turns
//! every run into a test.
//!
//! # Spec schema
//!
//! A campaign file has one `[campaign]` section and any number of
//! `[scenario.<name>]` sections (the TOML subset parser has no nested
//! tables, so chaos events use the `kind@time:args` string DSL of
//! [`ChaosEvent::parse`]):
//!
//! ```toml
//! [campaign]
//! name = "reliability-matrix"
//! seeds = [42, 7, 1234]        # every scenario runs at every seed
//! # parallelism = 8            # worker threads; default = cores
//!
//! [scenario.steal-under-pressure]
//! deployment = "houtu"         # houtu|cent-dyna|cent-stat|decent-stat
//! workload = "pagerank"        # wordcount|tpch|ml|pagerank|trace
//! size = "large"               # single-job only: small|medium|large
//! home = 1                     # single-job only: submitting DC
//! events = ["hogs@100:0,2,3"]  # chaos DSL, see below
//!
//! [scenario.spot-chaos]
//! workload = "trace"           # the online Fig-8 shape
//! num_jobs = 4
//! regions = 8                  # topology axis (0/omitted = paper's 4)
//! overrides = ["cloud.revocations=true", "cloud.spot_volatility=0.5"]
//! ```
//!
//! Event DSL: `hogs@T:0,2,3` (resource hogs into DCs at `T` seconds),
//! `kill_jm@T:dc2` (kill job 0's JM replica host),
//! `kill_jm_cascade@T:dc0,2,45` (kill, then re-kill each freshly-elected
//! primary every 45 s, 2 kills total), `kill_node@T:dc1.n2` (spot-style
//! VM termination), `kill_dc@T:dc2` (correlated whole-DC outage: every
//! live worker VM of the region terminates at once), `wan@T1-T2:0.25`
//! (degrade all cross-DC bandwidth to 25 % during the window),
//! `wan_pair@T:dc0,dc2,0.05` (asymmetric partition of a single region
//! pair; factor 1 restores), `spot_storm@T:dc1,300,4` (rolling
//! spot-price storm: the region's market draws its log-price innovation
//! with `sigma × 4` for 300 s, then calm is restored; pair it with
//! `cloud.revocations=true` to let the spikes kill instances).
//! `overrides` strings reuse the CLI's `--set section.key=value`
//! surface, so every config knob — including the straggler sweep axes
//! `workload.straggler_prob` / `workload.straggler_factor` and the
//! cost-aware bidding axes `bidding.strategy` / `bidding.insurance` —
//! is a scenario axis for free. `strategy = "naive|adaptive|deadline"`
//! is first-class sugar for the `bidding.strategy` override (validated
//! at parse time). The full schema, every chaos kind and every axis are
//! documented in `docs/CAMPAIGN.md`.
//!
//! Run a campaign with `houtu campaign [--spec FILE | --smoke]
//! [--report out.json|out.csv] [--record out.log]`; `--record` persists
//! every cell's executed `(time, seq, event)` stream as a [`replay`]
//! event log, and `houtu replay out.log` re-executes the cells in
//! lockstep and asserts the streams and digests match bit-for-bit (the
//! determinism regression gate). Every run must pass the [`invariants`]
//! checkers — the streaming [`invariants::StreamChecker`] riding the
//! [`crate::trace`] bus (exactly-once at the offending event's
//! timestamp, steal conservation, stamp monotonicity), the periodic
//! fair-share probe, and the post-run [`check_world`] — and gets a
//! deterministic trace-folded digest: same (spec, seed) ⇒ identical
//! event stream ⇒ identical digest, which the replay regression tests
//! pin down. `--report` serializes the [`CampaignReport`] (per-run
//! metrics + digests + violations) as JSON or CSV.
//!
//! Beyond hand-written campaigns, `houtu fuzz [--cases N] [--seed S]
//! [--soak MINUTES] [--repro out.toml] [--report out.json]` *generates*
//! scenarios: the
//! [`fuzz`] module samples random cells from a declarative
//! [`fuzz::FuzzSpace`] over the whole DSL plus the topology, workload,
//! straggler and override axes, runs them through the same invariant
//! stack, and greedily shrinks any violation to a minimal chaos schedule
//! emitted as a `campaign --spec`-loadable repro TOML.

pub mod fuzz;
pub mod invariants;
pub mod replay;
pub mod report;
pub mod runner;
pub mod spec;

pub use fuzz::{
    repro_toml, run_fuzz, run_fuzz_with, run_soak, sim_oracle, write_report, write_repro, CellGen,
    CellOutcome, FuzzCell, FuzzFailure, FuzzOpts, FuzzReport, FuzzSpace,
};
pub use invariants::{check_world, probe_world, StreamChecker, Violation};
pub use replay::{
    record_campaign, record_cells, replay_file, replay_log, write_log, CellRecord, EventLog,
    ReplaySummary,
};
pub use report::write_and_verify;
pub use runner::{
    resolve_threads, run_campaign, run_campaign_on, run_digest, run_one, run_one_on, run_scenario,
    run_scenario_hooked, run_scenario_on, try_resolve_threads, CampaignReport, FinishedRun,
    RunReport,
};
pub use spec::{CampaignSpec, ChaosEvent, ScenarioSpec, ScenarioWorkload};

use crate::config::Deployment;
use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::DcId;

/// Canned scenarios for the paper figures and the §6.4 chaos experiment —
/// `exp/` drives its fault-injection figures through these, so the
/// hand-coded experiments and campaign runs share one engine.
pub mod presets {
    use super::*;

    fn single(
        name: &str,
        deployment: Deployment,
        kind: WorkloadKind,
        size: SizeClass,
        home: DcId,
        events: Vec<ChaosEvent>,
        overrides: Vec<String>,
    ) -> ScenarioSpec {
        ScenarioSpec {
            name: name.to_string(),
            deployment,
            regions: 0,
            workload: ScenarioWorkload::SingleJob { kind, size, home },
            events,
            overrides,
        }
    }

    /// Fig 9(a): PageRank-large from dc1, no interference.
    pub fn fig9_normal() -> ScenarioSpec {
        single(
            "fig9-normal",
            Deployment::Houtu,
            WorkloadKind::PageRank,
            SizeClass::Large,
            DcId(1),
            vec![],
            vec![],
        )
    }

    /// Fig 9(b): resource hogs occupy the other three DCs at t=100 s;
    /// work stealing pulls the starved tasks to dc1.
    pub fn fig9_inject_steal() -> ScenarioSpec {
        single(
            "fig9-inject-steal",
            Deployment::Houtu,
            WorkloadKind::PageRank,
            SizeClass::Large,
            DcId(1),
            vec![ChaosEvent::InjectHogs { at_secs: 100.0, dcs: vec![DcId(0), DcId(2), DcId(3)] }],
            vec![],
        )
    }

    /// Fig 9(c): same injection with stealing disabled.
    pub fn fig9_inject_nosteal() -> ScenarioSpec {
        single(
            "fig9-inject-nosteal",
            Deployment::Houtu,
            WorkloadKind::PageRank,
            SizeClass::Large,
            DcId(1),
            vec![ChaosEvent::InjectHogs { at_secs: 100.0, dcs: vec![DcId(0), DcId(2), DcId(3)] }],
            vec!["scheduler.work_stealing=false".to_string()],
        )
    }

    /// Fig 11 / Fig 12(b): WordCount-large from dc0, kill the JM replica
    /// in `dc` at t=70 s (dc0 = pJM election path, other DCs = sJM
    /// respawn path, centralized deployments = full restart path).
    pub fn fig11_kill(dc: DcId, deployment: Deployment) -> ScenarioSpec {
        single(
            &format!("fig11-kill-dc{}-{}", dc.0, deployment.name()),
            deployment,
            WorkloadKind::WordCount,
            SizeClass::Large,
            DcId(0),
            vec![ChaosEvent::KillJm { at_secs: 70.0, dc }],
            vec![],
        )
    }

    /// §6.4 chaos: spiky spot market with revocations enabled over the
    /// online trace (the `survives_spot_revocation_chaos` shape).
    pub fn revocation_chaos(num_jobs: usize) -> ScenarioSpec {
        ScenarioSpec {
            name: format!("revocation-chaos-{num_jobs}"),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::Trace { num_jobs },
            events: vec![],
            overrides: vec![
                "cloud.revocations=true".to_string(),
                "cloud.spot_volatility=0.6".to_string(),
                "cloud.market_period_secs=60.0".to_string(),
                "cloud.bid_multiplier=1.3".to_string(),
            ],
        }
    }
}

/// The built-in smoke campaign behind `houtu campaign --smoke`: small,
/// fast (seconds), still chaotic enough to exercise the hog injection and
/// every invariant checker.
pub fn smoke_campaign() -> CampaignSpec {
    CampaignSpec {
        name: "smoke".to_string(),
        seeds: vec![42, 99],
        parallelism: 0,
        scenarios: vec![
            ScenarioSpec {
                name: "baseline-wordcount".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::WordCount,
                    size: SizeClass::Small,
                    home: DcId(0),
                },
                events: vec![],
                overrides: vec![],
            },
            ScenarioSpec {
                name: "hogs-pagerank".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::PageRank,
                    size: SizeClass::Small,
                    home: DcId(1),
                },
                events: vec![ChaosEvent::InjectHogs {
                    at_secs: 10.0,
                    dcs: vec![DcId(0), DcId(2), DcId(3)],
                }],
                overrides: vec![],
            },
        ],
    }
}

/// The built-in standard campaign: the same matrix `configs/campaign.toml`
/// ships (kept in sync by a regression test), used when the CLI finds no
/// spec file. 10 scenarios × 3 seeds = 30 runs. Scenario order matches the
/// TOML parse order (sections sort alphabetically in the subset parser).
pub fn standard_campaign() -> CampaignSpec {
    CampaignSpec {
        name: "reliability-matrix".to_string(),
        seeds: vec![42, 7, 1234],
        parallelism: 0,
        scenarios: vec![
            ScenarioSpec {
                name: "asym-wan-partition".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::TpcH,
                    size: SizeClass::Medium,
                    home: DcId(0),
                },
                events: vec![
                    ChaosEvent::WanPairDegrade {
                        at_secs: 30.0,
                        a: DcId(0),
                        b: DcId(2),
                        factor: 0.05,
                    },
                    ChaosEvent::WanPairDegrade {
                        at_secs: 500.0,
                        a: DcId(0),
                        b: DcId(2),
                        factor: 1.0,
                    },
                ],
                overrides: vec![],
            },
            ScenarioSpec {
                name: "baseline-wordcount".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::WordCount,
                    size: SizeClass::Medium,
                    home: DcId(0),
                },
                events: vec![],
                overrides: vec![],
            },
            ScenarioSpec {
                name: "bid-insurance-storm".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::Trace { num_jobs: 3 },
                events: vec![ChaosEvent::SpotStorm {
                    at_secs: 120.0,
                    dc: DcId(1),
                    dur_secs: 600.0,
                    sigma_factor: 3.0,
                }],
                overrides: vec![
                    "cloud.revocations=true".to_string(),
                    "cloud.bid_multiplier=1.5".to_string(),
                    "cloud.market_period_secs=120.0".to_string(),
                    "bidding.strategy=adaptive".to_string(),
                    "bidding.insurance=true".to_string(),
                ],
            },
            ScenarioSpec {
                name: "dc-outage".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::WordCount,
                    size: SizeClass::Large,
                    home: DcId(0),
                },
                events: vec![ChaosEvent::KillDc { at_secs: 70.0, dc: DcId(2) }],
                overrides: vec![],
            },
            ScenarioSpec {
                name: "jm-kill-cascade".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::WordCount,
                    size: SizeClass::Large,
                    home: DcId(0),
                },
                events: vec![ChaosEvent::KillJmCascade {
                    at_secs: 70.0,
                    dc: DcId(0),
                    count: 2,
                    gap_secs: 45.0,
                }],
                overrides: vec![],
            },
            ScenarioSpec {
                name: "pjm-kill".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::WordCount,
                    size: SizeClass::Large,
                    home: DcId(0),
                },
                events: vec![ChaosEvent::KillJm { at_secs: 70.0, dc: DcId(0) }],
                overrides: vec![],
            },
            ScenarioSpec {
                name: "spot-chaos".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::Trace { num_jobs: 4 },
                events: vec![],
                overrides: vec![
                    "cloud.revocations=true".to_string(),
                    "cloud.spot_volatility=0.5".to_string(),
                    "cloud.market_period_secs=120.0".to_string(),
                    "cloud.bid_multiplier=1.5".to_string(),
                ],
            },
            ScenarioSpec {
                name: "spot-storm".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::Trace { num_jobs: 3 },
                events: vec![ChaosEvent::SpotStorm {
                    at_secs: 120.0,
                    dc: DcId(1),
                    dur_secs: 600.0,
                    sigma_factor: 3.0,
                }],
                overrides: vec![
                    "cloud.revocations=true".to_string(),
                    "cloud.bid_multiplier=1.5".to_string(),
                    "cloud.market_period_secs=120.0".to_string(),
                ],
            },
            ScenarioSpec {
                name: "steal-under-pressure".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::PageRank,
                    size: SizeClass::Large,
                    home: DcId(1),
                },
                events: vec![ChaosEvent::InjectHogs {
                    at_secs: 100.0,
                    dcs: vec![DcId(0), DcId(2), DcId(3)],
                }],
                overrides: vec![],
            },
            ScenarioSpec {
                name: "straggler-storm".to_string(),
                deployment: Deployment::Houtu,
                regions: 0,
                workload: ScenarioWorkload::SingleJob {
                    kind: WorkloadKind::PageRank,
                    size: SizeClass::Medium,
                    home: DcId(1),
                },
                events: vec![],
                overrides: vec![
                    "workload.straggler_prob=0.2".to_string(),
                    "workload.straggler_factor=4.0".to_string(),
                ],
            },
        ],
    }
}
