//! Campaign runner: expand a [`CampaignSpec`] into its scenario × seed
//! matrix and execute the runs in parallel on `std::thread` (the crate is
//! dependency-free), each run flowing through the invariant checkers and
//! a deterministic digest. A panic inside a run (a tripped simulator
//! assertion is itself an invariant failure) is caught and reported as a
//! violation instead of killing the campaign.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::Config;
use crate::deploy::{build_sim_with, schedule_trace, SimEvent, World, WorldSim};
use crate::ids::{JmId, JobId};
use crate::sim::{secs, secs_f, QueueKind, SimTime};
use crate::trace::Fnv64;
use crate::util::error::Result;

use super::invariants::{check_world, probe_world, StreamChecker};
use super::spec::{CampaignSpec, ChaosEvent, ScenarioSpec, ScenarioWorkload};

/// A finished simulation plus what only the engine knows about it.
pub struct FinishedRun {
    pub world: World,
    pub events_processed: u64,
    /// High-water mark of the event queue over the run (the bench
    /// harness reports it as peak queue depth).
    pub peak_pending: usize,
}

/// Execute one scenario at one seed and return the finished world.
/// This is the same machinery `deploy::run_single_job` /
/// `run_trace_experiment` wire by hand — the experiment harness calls
/// through here so figure scenarios and campaign scenarios stay one code
/// path. The runtime probe is always armed (it is read-only and cheap:
/// one grant-table scan per scheduling period); its findings land in
/// `World::probe_violations`, which [`check_world`] folds into the
/// campaign verdict and the preset regression tests assert empty.
pub fn run_scenario(base: &Config, spec: &ScenarioSpec, seed: u64) -> Result<FinishedRun> {
    run_scenario_on(base, spec, seed, QueueKind::Slab)
}

/// [`run_scenario`] on an explicit queue engine. The golden-digest suite
/// replays every standard-campaign cell on [`QueueKind::Legacy`] and
/// asserts the digests match the slab queue bit-for-bit; `houtu bench`
/// times the same pair.
pub fn run_scenario_on(
    base: &Config,
    spec: &ScenarioSpec,
    seed: u64,
    queue: QueueKind,
) -> Result<FinishedRun> {
    run_scenario_hooked(base, spec, seed, queue, |_| {})
}

/// [`run_scenario_on`] with a hook called on the fully-built simulation —
/// workload, probe and chaos events already scheduled — just before it
/// runs. The record/replay layer uses the hook to install the engine's
/// event recorder ([`crate::sim::Sim::set_event_recorder`]); everything
/// else goes through the no-op wrappers above. The hook must not execute
/// events itself, or the digest no longer matches the unhooked run.
pub fn run_scenario_hooked(
    base: &Config,
    spec: &ScenarioSpec,
    seed: u64,
    queue: QueueKind,
    before: impl FnOnce(&mut WorldSim),
) -> Result<FinishedRun> {
    let cfg = spec.build_config(base, seed)?;
    let mode = cfg.deployment;
    let (mut sim, horizon) = match spec.workload {
        ScenarioWorkload::SingleJob { kind, size, home } => {
            let horizon = secs(14_400);
            let mut sim = build_sim_with(cfg, mode, horizon, queue);
            sim.schedule_event_at(1, SimEvent::SubmitJob { kind, size, home });
            (sim, horizon)
        }
        ScenarioWorkload::Trace { .. } => {
            let (trace, horizon) = crate::deploy::online_trace(&cfg);
            let mut sim = build_sim_with(cfg, mode, horizon, queue);
            schedule_trace(&mut sim, &trace);
            (sim, horizon)
        }
    };
    install_probe(&mut sim, horizon);
    // Streaming invariants ride the trace bus for the whole run; their
    // findings join the probe's in `World::probe_violations`, which
    // `check_world` folds into the campaign verdict.
    let stream = StreamChecker::install(&sim.state);
    schedule_events(&mut sim, &spec.events);
    before(&mut sim);
    sim.run_until(horizon);
    let makespan = sim.state.metrics.makespan();
    sim.state.bill_machines(makespan);
    for v in stream.borrow().violations() {
        if sim.state.probe_violations.len() < 64 {
            sim.state.probe_violations.push(v.clone());
        }
    }
    Ok(FinishedRun {
        events_processed: sim.events_processed,
        peak_pending: sim.peak_pending(),
        world: sim.state,
    })
}

/// Place the spec's chaos events on the simulation timeline.
///
/// WAN windows and spot storms are scheduled as (set factor, restore 1.0)
/// pairs in chronological order with restores sorted *before* starts at
/// equal timestamps — same-time DES events run in scheduling order, so a
/// window beginning exactly where another ends always wins the boundary,
/// regardless of the order events appear in the spec.
pub(crate) fn schedule_events(sim: &mut WorldSim, events: &[ChaosEvent]) {
    let mut wan_actions: Vec<(f64, bool, f64)> = Vec::new(); // (t, is_start, factor)
    let mut storm_actions: Vec<(f64, bool, usize, f64)> = Vec::new(); // (t, is_start, dc, factor)
    for ev in events.iter().cloned() {
        let label = ev.to_string();
        match ev {
            ChaosEvent::InjectHogs { at_secs, dcs } => {
                sim.schedule_event_at(secs_f(at_secs), SimEvent::ChaosInjectHogs { label, dcs });
            }
            ChaosEvent::KillJm { at_secs, dc } => {
                sim.schedule_event_at(
                    secs_f(at_secs),
                    SimEvent::ChaosKillJm { label, job: JobId(0), dc },
                );
            }
            ChaosEvent::KillJmCascade { at_secs, dc, count, gap_secs } => {
                sim.schedule_event_at(
                    secs_f(at_secs),
                    SimEvent::ChaosCascade { label, job: JobId(0), dc, count, gap: secs_f(gap_secs) },
                );
            }
            ChaosEvent::KillNode { at_secs, node } => {
                sim.schedule_event_at(secs_f(at_secs), SimEvent::ChaosKillNode { label, node });
            }
            ChaosEvent::KillDc { at_secs, dc } => {
                sim.schedule_event_at(secs_f(at_secs), SimEvent::ChaosKillDc { label, dc });
            }
            ChaosEvent::SpotStorm { at_secs, dc, dur_secs, sigma_factor } => {
                storm_actions.push((at_secs, true, dc.0, sigma_factor));
                storm_actions.push((at_secs + dur_secs, false, dc.0, 1.0));
            }
            ChaosEvent::WanDegrade { from_secs, until_secs, factor } => {
                wan_actions.push((from_secs, true, factor));
                wan_actions.push((until_secs, false, 1.0));
            }
            ChaosEvent::WanPairDegrade { at_secs, a, b, factor } => {
                sim.schedule_event_at(
                    secs_f(at_secs),
                    SimEvent::ChaosWanPairDegrade { label, a, b, factor },
                );
            }
        }
    }
    // NaN-proof two-key sorts: `total_cmp` on the time key cannot panic
    // the campaign on a malformed sample (spec validation rejects NaN
    // times, but a sort must never be the thing that takes the run down).
    wan_actions.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    for (t, _, factor) in wan_actions {
        sim.schedule_event_at(secs_f(t), SimEvent::ChaosWanDegrade { factor });
    }
    storm_actions.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    for (t, _, dc, factor) in storm_actions {
        sim.schedule_event_at(secs_f(t), SimEvent::ChaosSpotStorm { dc, factor });
    }
}

/// Arm the runtime invariant probe: fires every scheduling period, right
/// after the period tick (installed later, so its events sort after the
/// tick's at equal timestamps).
pub(crate) fn install_probe(sim: &mut WorldSim, horizon: SimTime) {
    let period = secs_f(sim.state.cfg.scheduler.period_l_secs);
    arm_probe(sim, period, horizon, HashMap::new());
}

fn arm_probe(sim: &mut WorldSim, period: SimTime, horizon: SimTime, prev: HashMap<JmId, usize>) {
    if sim.now() + period > horizon {
        return;
    }
    sim.schedule_in(period, move |sim| {
        let mut prev = prev;
        probe_world(&mut sim.state, &mut prev);
        arm_probe(sim, period, horizon, prev);
    });
}

/// Deterministic digest of a finished run: same (spec, seed) ⇒ same
/// digest, byte for byte. Since the trace-bus refactor this is a fold of
/// the run's *entire event stream* — every `(time, seq)` stamp and typed
/// payload, plus the event and step counts — so it is strictly stronger
/// than the old end-state scan: two runs that reach the same final world
/// through different event orders digest differently.
pub fn run_digest(run: &FinishedRun) -> u64 {
    run.world.trace_digest()
}

/// Everything a campaign records about one (scenario, seed) run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub scenario: String,
    pub seed: u64,
    pub deployment: &'static str,
    pub completed_jobs: usize,
    pub total_jobs: usize,
    pub avg_jrt_secs: f64,
    pub makespan_secs: f64,
    pub events_processed: u64,
    pub tasks_stolen: u64,
    pub recoveries: usize,
    pub elections: usize,
    pub restarts: u32,
    pub cross_dc_bytes: u64,
    pub machine_usd: f64,
    /// Run-level machine + transfer total (the §6.3 billing model).
    pub total_usd: f64,
    /// Sum of the per-job CostMeter attributions (the `CostCharged` payloads).
    pub job_usd: f64,
    pub digest: u64,
    pub violations: Vec<String>,
    pub wall_ms: u64,
}

impl RunReport {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    fn broken(spec: &ScenarioSpec, seed: u64, detail: String) -> RunReport {
        RunReport {
            scenario: spec.name.clone(),
            seed,
            deployment: spec.deployment.name(),
            completed_jobs: 0,
            total_jobs: 0,
            avg_jrt_secs: 0.0,
            makespan_secs: 0.0,
            events_processed: 0,
            tasks_stolen: 0,
            recoveries: 0,
            elections: 0,
            restarts: 0,
            cross_dc_bytes: 0,
            machine_usd: 0.0,
            total_usd: 0.0,
            job_usd: 0.0,
            digest: 0,
            violations: vec![detail],
            wall_ms: 0,
        }
    }
}

/// Run one (scenario, seed) cell: execute, check invariants, digest.
/// Never panics — simulator panics become violations.
pub fn run_one(base: &Config, spec: &ScenarioSpec, seed: u64) -> RunReport {
    run_one_on(base, spec, seed, QueueKind::Slab)
}

/// [`run_one`] on an explicit queue engine — the sharded CI leg runs the
/// whole smoke campaign on [`QueueKind::Sharded`] through this and diffs
/// the report digests against the sequential leg.
pub fn run_one_on(base: &Config, spec: &ScenarioSpec, seed: u64, queue: QueueKind) -> RunReport {
    let t0 = std::time::Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| run_scenario_on(base, spec, seed, queue)));
    let run = match outcome {
        Ok(Ok(run)) => run,
        Ok(Err(e)) => return RunReport::broken(spec, seed, format!("spec: {e}")),
        Err(p) => {
            let msg = p
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "non-string panic payload".into());
            return RunReport::broken(spec, seed, format!("panic: {msg}"));
        }
    };
    let w = &run.world;
    let violations: Vec<String> = check_world(w).iter().map(|v| v.to_string()).collect();
    let tasks_stolen: u64 = w
        .jobs
        .values()
        .flat_map(|rt| rt.jms.values())
        .map(|jm| jm.stats.tasks_stolen_in)
        .sum();
    RunReport {
        scenario: spec.name.clone(),
        seed,
        deployment: spec.deployment.name(),
        completed_jobs: w.metrics.completed_jobs(),
        total_jobs: w.metrics.jobs.len(),
        avg_jrt_secs: w.metrics.avg_jrt(),
        makespan_secs: w.metrics.makespan(),
        events_processed: run.events_processed,
        tasks_stolen,
        recoveries: w.metrics.recovery_intervals_secs.len(),
        elections: w.metrics.election_delays_secs.len(),
        restarts: w.metrics.jobs.values().map(|j| j.restarts).sum(),
        cross_dc_bytes: w.wan.stats.cross_dc_total_bytes(),
        machine_usd: w.cost.machine_usd,
        total_usd: w.cost.total_usd(),
        job_usd: w.jobs.values().map(|rt| rt.cost.total_usd()).sum(),
        digest: run_digest(&run),
        violations,
        wall_ms: t0.elapsed().as_millis() as u64,
    }
}

/// A whole campaign's outcome.
pub struct CampaignReport {
    pub name: String,
    pub workers: usize,
    pub runs: Vec<RunReport>,
    pub campaign_digest: u64,
}

impl CampaignReport {
    pub fn all_pass(&self) -> bool {
        self.runs.iter().all(RunReport::passed)
    }

    pub fn total_violations(&self) -> usize {
        self.runs.iter().map(|r| r.violations.len()).sum()
    }

    /// Human-readable campaign table + violation details.
    pub fn render(&self) -> String {
        let mut out = String::new();
        writeln!(
            out,
            "Campaign {:?} — {} runs on {} workers",
            self.name,
            self.runs.len(),
            self.workers
        )
        .unwrap();
        writeln!(
            out,
            "{:>24} {:>6} {:>12} {:>6} {:>10} {:>10} {:>7} {:>6} {:>9} {:>5}  {:>16}",
            "scenario", "seed", "deployment", "jobs", "avgJRT(s)", "mkspan(s)", "steals", "recov", "usd", "viol", "digest"
        )
        .unwrap();
        for r in &self.runs {
            writeln!(
                out,
                "{:>24} {:>6} {:>12} {:>6} {:>10.1} {:>10.1} {:>7} {:>6} {:>9.3} {:>5}  {:016x}",
                r.scenario,
                r.seed,
                r.deployment,
                format!("{}/{}", r.completed_jobs, r.total_jobs),
                r.avg_jrt_secs,
                r.makespan_secs,
                r.tasks_stolen,
                r.recoveries + r.elections,
                r.total_usd,
                r.violations.len(),
                r.digest
            )
            .unwrap();
        }
        for r in &self.runs {
            for v in &r.violations {
                writeln!(out, "  ! {}/seed{}: {v}", r.scenario, r.seed).unwrap();
            }
        }
        let clean = self.runs.iter().filter(|r| r.passed()).count();
        writeln!(
            out,
            "{clean}/{} runs clean, {} violations, campaign digest {:016x}",
            self.runs.len(),
            self.total_violations(),
            self.campaign_digest
        )
        .unwrap();
        out
    }
}

/// The thread-count precedence rule, with the environment read lifted
/// out for error reporting and testing: an explicit `explicit > 0` wins,
/// then `HOUTU_THREADS` (which must parse to a positive integer — `0` or
/// garbage is an error, not a silent clamp), then one worker per
/// available core. `env` is the raw `HOUTU_THREADS` value, `None` when
/// unset; an empty / whitespace-only value counts as unset.
pub fn try_resolve_threads(
    explicit: usize,
    env: Option<&str>,
) -> std::result::Result<usize, String> {
    if explicit > 0 {
        return Ok(explicit);
    }
    if let Some(v) = env {
        let v = v.trim();
        if !v.is_empty() {
            return match v.parse::<usize>() {
                Ok(0) => Err("HOUTU_THREADS must be >= 1 (got 0); unset it for auto-sizing"
                    .to_string()),
                Ok(k) => Ok(k),
                Err(_) => Err(format!(
                    "HOUTU_THREADS must be a positive integer, got {v:?}; unset it for \
                     auto-sizing"
                )),
            };
        }
    }
    Ok(std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4))
}

/// Resolve a thread-count knob: an explicit `n > 0` wins, then a
/// positive `HOUTU_THREADS` environment variable, then one worker per
/// available core. This is the single sizing rule for every pool in the
/// crate — the campaign runner, the fuzzer, the bench harness and the
/// sharded engines' shard count all route through it, so `--threads N`
/// and `HOUTU_THREADS=N` mean the same thing everywhere. A
/// `HOUTU_THREADS` of `0` (or one that does not parse) is rejected with
/// a clear diagnostic and exit code 2 instead of being silently clamped
/// — see [`try_resolve_threads`] for the testable core.
pub fn resolve_threads(n: usize) -> usize {
    let env = std::env::var("HOUTU_THREADS").ok();
    match try_resolve_threads(n, env.as_deref()) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

/// Resolve a parallelism knob (0 = `HOUTU_THREADS`, else one worker per
/// core) against a job count.
pub(crate) fn resolve_workers(parallelism: usize, jobs: usize) -> usize {
    resolve_threads(parallelism).min(jobs.max(1))
}

/// Extract a human-readable message from a caught panic payload.
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<String>()
        .cloned()
        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "non-string panic payload".into())
}

/// Run `n` indexed jobs on a pool of `workers` `std::thread`s and collect
/// the results in index order, independent of worker interleaving. Shared
/// by the campaign runner, the chaos fuzzer and (hence `pub`, but hidden
/// — not a stable API) the golden-digest differential suite.
///
/// A panicking job is an error for the caller to absorb, never a pool
/// failure: each `f(i)` runs under `catch_unwind`, so one job's panic
/// can neither poison the result mutex nor unwind through
/// `thread::scope` (which would re-raise on join and abort every
/// sibling mid-flight). The old pool did exactly that — one panicking
/// cell took the whole campaign down via the poisoned `slots` lock and
/// the `expect("parallel worker lost a job")` collection.
#[doc(hidden)]
pub fn par_try_map<T: Send>(
    workers: usize,
    n: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<std::result::Result<T, String>> {
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<std::result::Result<T, String>>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i))).map_err(panic_message);
                // catch_unwind means no worker can die holding the lock,
                // but recover from poison anyway: a slot write is
                // all-or-nothing, so the data stays sound either way.
                let mut guard = slots.lock().unwrap_or_else(|p| p.into_inner());
                guard[i] = Some(out);
            });
        }
    });
    slots
        .into_inner()
        .unwrap_or_else(|p| p.into_inner())
        .into_iter()
        .enumerate()
        .map(|(i, o)| o.unwrap_or_else(|| Err(format!("job {i} lost by the worker pool"))))
        .collect()
}

/// [`par_try_map`] for infallible jobs: a panic in `f` still lets every
/// sibling job finish, then resurfaces (with its payload) from the
/// calling thread.
#[doc(hidden)]
pub fn par_map<T: Send>(workers: usize, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    par_try_map(workers, n, f)
        .into_iter()
        .map(|r| r.unwrap_or_else(|msg| panic!("parallel worker panicked: {msg}")))
        .collect()
}

/// Execute the campaign's scenario × seed matrix in parallel and collect
/// the per-run reports (in stable matrix order, independent of worker
/// interleaving).
pub fn run_campaign(base: &Config, spec: &CampaignSpec) -> CampaignReport {
    run_campaign_on(base, spec, QueueKind::Slab)
}

/// [`run_campaign`] on an explicit queue engine (`houtu campaign
/// --shards N` routes here with [`QueueKind::Sharded`]). Digests are
/// engine-invariant, so the two reports must agree bit-for-bit — `ci.sh`
/// diffs them on every run.
pub fn run_campaign_on(base: &Config, spec: &CampaignSpec, queue: QueueKind) -> CampaignReport {
    let plans = spec.expand();
    let workers = resolve_workers(spec.parallelism, plans.len());
    // `run_one_on` already converts simulator panics into violations;
    // `par_try_map` catches anything that escapes it (a panicking probe
    // fold, an invariant checker bug), so one broken cell reports as a
    // violation while the rest of the matrix still finishes.
    let runs: Vec<RunReport> = par_try_map(workers, plans.len(), |i| {
        let (sc, seed) = &plans[i];
        run_one_on(base, sc, *seed, queue)
    })
    .into_iter()
    .enumerate()
    .map(|(i, r)| {
        let (sc, seed) = &plans[i];
        r.unwrap_or_else(|msg| RunReport::broken(sc, *seed, format!("panic: {msg}")))
    })
    .collect();
    let mut h = Fnv64::new();
    for r in &runs {
        h.bytes(r.scenario.as_bytes());
        h.u64(r.seed);
        h.u64(r.digest);
    }
    CampaignReport { name: spec.name.clone(), workers, runs, campaign_digest: h.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;
    use crate::ids::DcId;

    /// The WAN-window / spot-storm two-key sorts must never panic on a
    /// NaN time — `partial_cmp(..).unwrap()` did exactly that before the
    /// `total_cmp` sweep. (Spec validation rejects NaN-timed events, but
    /// the fuzzer and future callers reach `schedule_events` directly.)
    #[test]
    fn nan_chaos_times_do_not_panic_the_schedulers() {
        let cfg = Config::default();
        let mut sim = build_sim_with(cfg, Deployment::Houtu, secs(100), QueueKind::Slab);
        let events = vec![
            ChaosEvent::WanDegrade { from_secs: f64::NAN, until_secs: f64::NAN, factor: 0.5 },
            ChaosEvent::WanDegrade { from_secs: 10.0, until_secs: 20.0, factor: 0.5 },
            ChaosEvent::SpotStorm {
                at_secs: f64::NAN,
                dc: DcId(0),
                dur_secs: 5.0,
                sigma_factor: 2.0,
            },
            ChaosEvent::SpotStorm {
                at_secs: 1.0,
                dc: DcId(1),
                dur_secs: 5.0,
                sigma_factor: 2.0,
            },
        ];
        schedule_events(&mut sim, &events);
        assert!(sim.pending() > 0, "events were scheduled, not dropped");
    }

    /// A deliberately-panicking probe in one cell must yield an `Err` for
    /// that cell only — every sibling still completes, and nothing
    /// unwinds into the caller. (Regression: the old pool let the panic
    /// poison the slots mutex and re-raise from `thread::scope`, so one
    /// bad cell aborted the whole campaign.)
    #[test]
    fn a_panicking_job_is_isolated_from_its_siblings() {
        let out = par_try_map(2, 5, |i| {
            if i == 2 {
                panic!("probe tripped on cell {i}");
            }
            i * 10
        });
        assert_eq!(out.len(), 5);
        for (i, r) in out.iter().enumerate() {
            if i == 2 {
                let msg = r.as_ref().unwrap_err();
                assert!(msg.contains("probe tripped on cell 2"), "{msg}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 10, "sibling {i} must finish");
            }
        }
        // The infallible wrapper resurfaces the panic from the calling
        // thread — after the siblings have finished — not from the pool.
        let caught = catch_unwind(AssertUnwindSafe(|| {
            par_map(2, 3, |i| {
                if i == 1 {
                    panic!("boom");
                }
                i
            })
        }));
        let msg = panic_message(caught.unwrap_err());
        assert!(msg.contains("boom"), "{msg}");
    }

    /// End-to-end: a campaign whose cell panics beyond `run_one_on`'s own
    /// catch reports the panic as that cell's violation while the other
    /// cells run clean.
    #[test]
    fn campaign_reports_a_panicking_cell_as_a_violation() {
        use crate::config::Deployment;
        use crate::dag::{SizeClass, WorkloadKind};
        let panicking = ScenarioSpec {
            name: "nan-windows-panic".into(),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::SingleJob {
                kind: WorkloadKind::WordCount,
                size: SizeClass::Small,
                home: DcId(0),
            },
            // regions beyond the topology: build_config errors (not a
            // panic), exercising the broken-report path cleanly...
            events: vec![ChaosEvent::KillDc { at_secs: 10.0, dc: DcId(9) }],
            overrides: vec![],
        };
        let clean = ScenarioSpec {
            name: "clean".into(),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::SingleJob {
                kind: WorkloadKind::WordCount,
                size: SizeClass::Small,
                home: DcId(0),
            },
            events: vec![],
            overrides: vec![],
        };
        let spec = CampaignSpec {
            name: "mixed".into(),
            seeds: vec![42],
            scenarios: vec![panicking, clean],
            parallelism: 2,
        };
        let report = run_campaign_on(&Config::default(), &spec, QueueKind::Slab);
        assert_eq!(report.runs.len(), 2);
        assert!(!report.runs[0].passed(), "broken cell must carry a violation");
        assert!(report.runs[1].passed(), "sibling cell must run clean");
        assert!(report.runs[1].completed_jobs > 0);
    }

    /// The thread-sizing precedence order, on the pure core so no test
    /// has to mutate process-global environment state: explicit flag >
    /// `HOUTU_THREADS` > auto, and a zero / unparsable `HOUTU_THREADS`
    /// is a hard error rather than a silent clamp.
    #[test]
    fn thread_resolution_precedence_and_zero_rejection() {
        // An explicit --threads N shadows whatever the environment says.
        assert_eq!(try_resolve_threads(3, Some("7")), Ok(3));
        assert_eq!(try_resolve_threads(3, Some("0")), Ok(3));
        assert_eq!(try_resolve_threads(1, None), Ok(1));
        // No explicit flag: HOUTU_THREADS decides (whitespace tolerated).
        assert_eq!(try_resolve_threads(0, Some("7")), Ok(7));
        assert_eq!(try_resolve_threads(0, Some(" 2 ")), Ok(2));
        // Unset or blank env falls through to core-count auto-sizing.
        assert!(try_resolve_threads(0, None).unwrap() >= 1);
        assert!(try_resolve_threads(0, Some("")).unwrap() >= 1);
        assert!(try_resolve_threads(0, Some("   ")).unwrap() >= 1);
        // HOUTU_THREADS=0 and garbage are rejected with a clear message.
        let e = try_resolve_threads(0, Some("0")).unwrap_err();
        assert!(e.contains(">= 1"), "{e}");
        let e = try_resolve_threads(0, Some(" 0 ")).unwrap_err();
        assert!(e.contains(">= 1"), "{e}");
        let e = try_resolve_threads(0, Some("lots")).unwrap_err();
        assert!(e.contains("positive integer"), "{e}");
        let e = try_resolve_threads(0, Some("-2")).unwrap_err();
        assert!(e.contains("positive integer"), "{e}");
    }
}
