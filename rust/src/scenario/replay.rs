//! Persistent event-log record/replay: `houtu campaign --record out.log`
//! and `houtu replay out.log`.
//!
//! Recording re-runs every (scenario, seed) cell of a campaign with the
//! engine's event recorder installed
//! ([`crate::sim::Sim::set_event_recorder`]) and persists the executed
//! `(time, seq, event)` stream. Replaying rebuilds the campaign from the
//! log's `campaign` source tag, re-executes each recorded cell in
//! lockstep — every generated log line is string-compared against the
//! recorded prefix while a rolling FNV folds the *whole* stream — and
//! asserts the event count, stream hash and final run digest all match.
//! A replay mismatch is a determinism regression: the binary no longer
//! executes the schedule it executed when the log was written.
//!
//! # Log schema (version 1)
//!
//! One JSON document (parsed by the in-repo [`crate::util::json`]):
//!
//! ```json
//! {
//!   "houtu_event_log": 1,
//!   "campaign": "standard",
//!   "cells": [
//!     {"scenario": "pjm-kill", "seed": 42, "queue": "slab",
//!      "events": 187234, "log_fnv": "9ab3…16 hex…", "digest": "04f2…",
//!      "log": ["{\"t\":1,\"seq\":0,\"ev\":\"submit_job\",…}", "…"]}
//!   ]
//! }
//! ```
//!
//! * `campaign` names the cell source: `"smoke"`, `"standard"`, or
//!   `"spec:<path>"` for a `campaign --spec` file. Replay rebuilds the
//!   same matrix from it, so the log never embeds scenario definitions.
//! * `log` keeps at most [`RECORD_LINE_CAP`] lines per cell (standard
//!   campaign cells run hundreds of thousands of events — persisting all
//!   of them would dwarf the repo), while `events` and `log_fnv` cover
//!   the entire stream, so truncation costs diff granularity but never
//!   verification strength.
//! * `log_fnv`/`digest` are 16-digit hex strings: JSON numbers are f64s
//!   and cannot carry a u64 exactly.
//! * Custom (closure) events have no typed payload to render; they log
//!   as `{"t":T,"seq":S,"ev":"custom"}` markers — position, time and seq
//!   still verify, only the payload is opaque.

use std::cell::RefCell;
use std::rc::Rc;

use crate::config::Config;
use crate::deploy::SimEvent;
use crate::sim::{QueueKind, SimTime};
use crate::trace::Fnv64;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::{anyhow, bail, ensure};

use super::runner::{run_digest, run_scenario_hooked};
use super::spec::{CampaignSpec, ScenarioSpec};
use super::{smoke_campaign, standard_campaign};

/// Per-cell cap on persisted log lines; the count and stream FNV always
/// cover the full run regardless.
pub const RECORD_LINE_CAP: usize = 100_000;

/// One recorded (scenario, seed) cell.
#[derive(Debug, Clone, PartialEq)]
pub struct CellRecord {
    pub scenario: String,
    pub seed: u64,
    /// Queue engine the cell ran on (`"slab"` / `"legacy"`).
    pub queue: String,
    /// Events executed over the whole run.
    pub events: u64,
    /// FNV-1a fold over every log line of the run (beyond the cap too).
    pub log_fnv: u64,
    /// The run's final trace digest ([`run_digest`]).
    pub digest: u64,
    /// First [`RECORD_LINE_CAP`] log lines.
    pub log: Vec<String>,
}

/// A persisted campaign event log.
#[derive(Debug, Clone, PartialEq)]
pub struct EventLog {
    /// Cell source: `"smoke"`, `"standard"`, or `"spec:<path>"`.
    pub campaign: String,
    pub cells: Vec<CellRecord>,
}

/// What a successful replay verified.
#[derive(Debug, Clone, Copy)]
pub struct ReplaySummary {
    pub cells: usize,
    pub events: u64,
}

/// Render one executed step as a log line.
fn line_for(t: SimTime, seq: u64, ev: Option<&SimEvent>) -> String {
    match ev {
        Some(e) => e.log_line(t, seq),
        None => format!("{{\"t\":{t},\"seq\":{seq},\"ev\":\"custom\"}}"),
    }
}

struct Capture {
    kept: Vec<String>,
    total: u64,
    fnv: Fnv64,
}

/// Record the given cells (on the slab queue) into an [`EventLog`] with
/// the given `source` tag. Cells run serially — recording is a
/// diagnostic pass, and the recorder closure is not `Sync`.
pub fn record_cells(
    base: &Config,
    plans: &[(ScenarioSpec, u64)],
    source: &str,
) -> Result<EventLog> {
    let mut cells = Vec::with_capacity(plans.len());
    for (sc, seed) in plans {
        let cap = Rc::new(RefCell::new(Capture {
            kept: Vec::new(),
            total: 0,
            fnv: Fnv64::new(),
        }));
        let sink = Rc::clone(&cap);
        let run = run_scenario_hooked(base, sc, *seed, QueueKind::Slab, move |sim| {
            sim.set_event_recorder(move |t, seq, ev| {
                let line = line_for(t, seq, ev);
                let mut c = sink.borrow_mut();
                c.fnv.bytes(line.as_bytes());
                c.total += 1;
                if c.kept.len() < RECORD_LINE_CAP {
                    c.kept.push(line);
                }
            });
        })
        .with_context(|| format!("recording {}/seed{}", sc.name, seed))?;
        let digest = run_digest(&run);
        let mut c = cap.borrow_mut();
        ensure!(
            c.total == run.events_processed,
            "{}/seed{}: recorder saw {} events, engine executed {}",
            sc.name,
            seed,
            c.total,
            run.events_processed
        );
        cells.push(CellRecord {
            scenario: sc.name.clone(),
            seed: *seed,
            queue: QueueKind::Slab.name().to_string(),
            events: c.total,
            log_fnv: c.fnv.0,
            digest,
            log: std::mem::take(&mut c.kept),
        });
    }
    Ok(EventLog { campaign: source.to_string(), cells })
}

/// [`record_cells`] over a whole campaign's scenario × seed matrix.
pub fn record_campaign(base: &Config, spec: &CampaignSpec, source: &str) -> Result<EventLog> {
    record_cells(base, &spec.expand(), source)
}

/// Serialize a log to its JSON document (schema in the module docs).
pub fn render_log(log: &EventLog) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"houtu_event_log\": 1,\n");
    out.push_str(&format!("  \"campaign\": {},\n", json::escape(&log.campaign)));
    out.push_str("  \"cells\": [\n");
    for (i, c) in log.cells.iter().enumerate() {
        out.push_str("    {");
        out.push_str(&format!("\"scenario\": {}, ", json::escape(&c.scenario)));
        out.push_str(&format!("\"seed\": {}, ", c.seed));
        out.push_str(&format!("\"queue\": {}, ", json::escape(&c.queue)));
        out.push_str(&format!("\"events\": {}, ", c.events));
        out.push_str(&format!("\"log_fnv\": \"{:016x}\", ", c.log_fnv));
        out.push_str(&format!("\"digest\": \"{:016x}\", ", c.digest));
        out.push_str("\"log\": [");
        for (j, line) in c.log.iter().enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            out.push_str(&json::escape(line));
        }
        out.push_str("]}");
        out.push_str(if i + 1 == log.cells.len() { "\n" } else { ",\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

fn hex_field(cell: &Json, key: &str) -> Result<u64> {
    let s = cell
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("cell missing hex field {key:?}"))?;
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad {key} {s:?}: {e}"))
}

/// Parse a log document back into an [`EventLog`].
pub fn read_log(text: &str) -> Result<EventLog> {
    let doc = json::parse(text).map_err(|e| anyhow!("event log: {e}"))?;
    ensure!(
        doc.get("houtu_event_log").and_then(Json::as_u64) == Some(1),
        "not a houtu event log (or an unknown version)"
    );
    let campaign = doc
        .get("campaign")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("log missing campaign source"))?
        .to_string();
    let rows = doc
        .get("cells")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("log missing cells array"))?;
    let mut cells = Vec::with_capacity(rows.len());
    for row in rows {
        let scenario = row
            .get("scenario")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("cell missing scenario"))?
            .to_string();
        let seed = row
            .get("seed")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("{scenario}: cell missing seed"))?;
        let queue = row
            .get("queue")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{scenario}: cell missing queue"))?
            .to_string();
        let events = row
            .get("events")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("{scenario}: cell missing events"))?;
        let log_fnv = hex_field(row, "log_fnv")?;
        let digest = hex_field(row, "digest")?;
        let log = row
            .get("log")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow!("{scenario}: cell missing log"))?
            .iter()
            .map(|l| {
                l.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("{scenario}: non-string log line"))
            })
            .collect::<Result<Vec<_>>>()?;
        cells.push(CellRecord { scenario, seed, queue, events, log_fnv, digest, log });
    }
    Ok(EventLog { campaign, cells })
}

/// Rebuild the campaign a log was recorded from.
fn campaign_for_source(source: &str) -> Result<CampaignSpec> {
    if source == "smoke" {
        Ok(smoke_campaign())
    } else if source == "standard" {
        Ok(standard_campaign())
    } else if let Some(path) = source.strip_prefix("spec:") {
        CampaignSpec::from_file(path)
    } else {
        bail!("unknown campaign source {source:?} in event log")
    }
}

fn queue_for_name(name: &str) -> Result<QueueKind> {
    match name {
        "slab" => Ok(QueueKind::Slab),
        "legacy" => Ok(QueueKind::Legacy),
        // Recorded sharded runs don't persist the shard count — the
        // merge is exact, so any count replays to the same stream.
        "sharded" => Ok(QueueKind::Sharded(4)),
        other => bail!("unknown queue engine {other:?} in event log"),
    }
}

struct VerifyState {
    expected: Vec<String>,
    total: u64,
    fnv: Fnv64,
    /// First divergence from the recorded prefix, if any.
    mismatch: Option<String>,
}

/// Re-execute every recorded cell and assert it reproduces the log:
/// same per-line prefix, same full-stream FNV, same event count, same
/// final digest. Errors identify the first diverging cell (and line).
pub fn replay_log(base: &Config, log: &EventLog) -> Result<ReplaySummary> {
    let campaign = campaign_for_source(&log.campaign)?;
    let plans = campaign.expand();
    let mut events_total = 0u64;
    for cell in &log.cells {
        let (sc, seed) = plans
            .iter()
            .find(|(sc, seed)| sc.name == cell.scenario && *seed == cell.seed)
            .ok_or_else(|| {
                anyhow!(
                    "log cell {}/seed{} is not in campaign {:?}",
                    cell.scenario,
                    cell.seed,
                    log.campaign
                )
            })?;
        let queue = queue_for_name(&cell.queue)?;
        let st = Rc::new(RefCell::new(VerifyState {
            expected: cell.log.clone(),
            total: 0,
            fnv: Fnv64::new(),
            mismatch: None,
        }));
        let sink = Rc::clone(&st);
        let run = run_scenario_hooked(base, sc, *seed, queue, move |sim| {
            sim.set_event_recorder(move |t, seq, ev| {
                let line = line_for(t, seq, ev);
                let mut v = sink.borrow_mut();
                v.fnv.bytes(line.as_bytes());
                let i = v.total as usize;
                v.total += 1;
                if v.mismatch.is_none() && i < v.expected.len() && v.expected[i] != line {
                    v.mismatch = Some(format!(
                        "line {i}: recorded {:?}, replay produced {line:?}",
                        v.expected[i]
                    ));
                }
            });
        })
        .with_context(|| format!("replaying {}/seed{}", cell.scenario, cell.seed))?;
        let v = st.borrow();
        let who = format!("{}/seed{}", cell.scenario, cell.seed);
        if let Some(m) = &v.mismatch {
            bail!("{who}: replay diverged at {m}");
        }
        ensure!(
            v.total == cell.events,
            "{who}: replay executed {} events, log recorded {}",
            v.total,
            cell.events
        );
        ensure!(
            v.fnv.0 == cell.log_fnv,
            "{who}: replay stream fnv {:016x} != recorded {:016x}",
            v.fnv.0,
            cell.log_fnv
        );
        let digest = run_digest(&run);
        ensure!(
            digest == cell.digest,
            "{who}: replay digest {digest:016x} != recorded {:016x}",
            cell.digest
        );
        events_total += v.total;
    }
    Ok(ReplaySummary { cells: log.cells.len(), events: events_total })
}

/// Write a log to `path` and verify the file parses back identical.
pub fn write_log(log: &EventLog, path: &str) -> Result<()> {
    let text = render_log(log);
    std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
    let back =
        read_log(&std::fs::read_to_string(path).with_context(|| format!("re-reading {path}"))?)?;
    ensure!(back == *log, "event log {path:?} did not round-trip");
    Ok(())
}

/// The `houtu replay PATH` entry point: read, parse, re-execute, verify.
pub fn replay_file(base: &Config, path: &str) -> Result<ReplaySummary> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let log = read_log(&text)?;
    replay_log(base, &log)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_log() -> EventLog {
        EventLog {
            campaign: "smoke".to_string(),
            cells: vec![CellRecord {
                scenario: "baseline-wordcount".to_string(),
                seed: 42,
                queue: "slab".to_string(),
                events: 3,
                log_fnv: 0xDEAD_BEEF_0123_4567,
                digest: 0x0123_4567_89AB_CDEF,
                log: vec![
                    "{\"t\":1,\"seq\":0,\"ev\":\"submit_job\",\"kind\":\"wordcount\"}".to_string(),
                    "{\"t\":2,\"seq\":1,\"ev\":\"custom\"}".to_string(),
                ],
            }],
        }
    }

    #[test]
    fn log_serialization_round_trips() {
        let log = tiny_log();
        let text = render_log(&log);
        let back = read_log(&text).expect("render_log output must parse");
        assert_eq!(back, log);
    }

    #[test]
    fn read_log_rejects_malformed_documents() {
        assert!(read_log("not json").is_err());
        assert!(read_log("{}").is_err(), "missing version marker");
        assert!(
            read_log("{\"houtu_event_log\": 2, \"campaign\": \"smoke\", \"cells\": []}").is_err(),
            "future versions must not parse as v1"
        );
        // Digest must be a hex string, not a (lossy) JSON number.
        let bad = render_log(&tiny_log()).replace("\"digest\": \"0123456789abcdef\"", "\"digest\": 3");
        assert!(read_log(&bad).is_err());
    }

    #[test]
    fn unknown_campaign_source_is_an_error() {
        let mut log = tiny_log();
        log.campaign = "galaxy-brain".to_string();
        let base = Config::default();
        assert!(replay_log(&base, &log).is_err());
    }
}
