//! Invariant checkers: what must hold of *every* finished scenario run,
//! no matter which failures were injected. Each campaign run passes
//! through three layers that together turn every scenario execution into
//! a test:
//!
//! * [`StreamChecker`] — a [`TraceSink`] folding the typed event stream
//!   *as it happens*: exactly-once completion, steal conservation and
//!   stamp monotonicity are caught at the offending event's timestamp,
//!   not post-mortem;
//! * the periodic [`probe_world`] (installed by the runner at every
//!   scheduling period) — fair-share and grant-bookkeeping checks;
//! * [`check_world`] — post-run checks over the final [`World`].

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::dag::TaskStatus;
use crate::deploy::World;
use crate::ids::{ContainerId, DcId, JmId, JobId, TaskId};
use crate::sim::{to_secs, SimTime};
use crate::trace::{Stamped, TraceEvent, TraceSink};

/// One invariant breach, with enough detail to debug the run.
#[derive(Debug, Clone)]
pub struct Violation {
    pub check: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.check, self.detail)
    }
}

fn push(v: &mut Vec<Violation>, check: &'static str, detail: String) {
    v.push(Violation { check, detail });
}

/// Post-run checks over the final world state.
///
/// * **job-terminates** — every submitted job completed within the
///   horizon (liveness under failures, §6.4).
/// * **exactly-once** — per completed job, each task is Done exactly
///   once: no lost task, no double completion (outputs, the replicated
///   partitionList and the DAG progress all agree on the task count).
/// * **quiescence** — no task left Waiting/Running after completion.
/// * **pool-restored** — all containers returned to the free pools
///   (skipped when hog pseudo-jobs hold containers by design).
/// * **master-leak** — no sub-job stays registered after its job ended.
/// * **steal-conservation** — tasks stolen in never exceed tasks stolen
///   out; with no JM disruption the two are equal.
/// * **insurance-leak / cost-sanity** — no insurance duplicate outlives
///   its job, and per-job cost attribution stays finite and non-negative.
/// * **runtime-probe** — anything [`probe_world`] recorded during the run.
pub fn check_world(w: &World) -> Vec<Violation> {
    let mut v = Vec::new();
    let total = w.metrics.jobs.len();
    let done = w.metrics.completed_jobs();
    if done != total {
        push(&mut v, "job-terminates", format!("{done}/{total} jobs completed within horizon"));
    }

    for (&id, rt) in &w.jobs {
        if !rt.done {
            continue;
        }
        let n = rt.spec.num_tasks();
        let d = rt.progress.count(TaskStatus::Done);
        if d != n {
            push(&mut v, "exactly-once", format!("{id}: {d}/{n} tasks Done"));
        }
        if rt.outputs.len() != n {
            push(&mut v, "exactly-once", format!("{id}: {} outputs for {n} tasks", rt.outputs.len()));
        }
        let distinct: HashSet<TaskId> =
            rt.info.partition_list.iter().map(|p| p.task).collect();
        if rt.info.partition_list.len() != n || distinct.len() != n {
            push(
                &mut v,
                "exactly-once",
                format!(
                    "{id}: partitionList has {} entries / {} distinct for {n} tasks",
                    rt.info.partition_list.len(),
                    distinct.len()
                ),
            );
        }
        let (waiting, running) =
            (rt.progress.count(TaskStatus::Waiting), rt.progress.count(TaskStatus::Running));
        if waiting != 0 || running != 0 {
            push(&mut v, "quiescence", format!("{id}: {waiting} waiting, {running} running after done"));
        }
        if let Some(rec) = w.metrics.jobs.get(&id) {
            if let Some(jrt) = rec.jrt() {
                if !(jrt > 0.0) {
                    push(&mut v, "jrt-sanity", format!("{id}: non-positive JRT {jrt}"));
                }
            }
        }
        if !rt.insurance.is_empty() {
            push(
                &mut v,
                "insurance-leak",
                format!("{id}: {} insurance copies outlived the job", rt.insurance.len()),
            );
        }
        let usd = rt.cost.total_usd();
        if !usd.is_finite() || usd < 0.0 {
            push(&mut v, "cost-sanity", format!("{id}: bad per-job cost {usd}"));
        }
    }

    if w.hogs_empty() && done == total {
        for dcid in 0..w.cfg.topology.num_dcs() {
            let dc = DcId(dcid);
            let free = w.cluster.free_pool(dc).len();
            let cap = w.cluster.dc_capacity(dc);
            if free != cap {
                push(&mut v, "pool-restored", format!("{dc}: {free} free of {cap} capacity"));
            }
        }
        for (i, m) in w.masters().enumerate() {
            let leftover = m.sub_jobs();
            if !leftover.is_empty() {
                push(&mut v, "master-leak", format!("master {i} still tracks {leftover:?}"));
            }
        }
    }

    let stolen_in: u64 = w
        .jobs
        .values()
        .flat_map(|rt| rt.jms.values())
        .map(|jm| jm.stats.tasks_stolen_in)
        .sum();
    let stolen_out: u64 = w
        .jobs
        .values()
        .flat_map(|rt| rt.jms.values())
        .map(|jm| jm.stats.tasks_stolen_out)
        .sum();
    if stolen_in > stolen_out {
        push(
            &mut v,
            "steal-conservation",
            format!("{stolen_in} stolen in > {stolen_out} stolen out"),
        );
    }
    let restarts: u32 = w.metrics.jobs.values().map(|j| j.restarts).sum();
    let disrupted = restarts > 0
        || !w.metrics.recovery_intervals_secs.is_empty()
        || !w.metrics.election_delays_secs.is_empty();
    if !disrupted && stolen_in != stolen_out {
        // A deficit is legal only when a thief died mid-steal, which
        // always leaves a recovery/election/restart trace.
        push(
            &mut v,
            "steal-conservation",
            format!("undisrupted run lost steals: in {stolen_in} != out {stolen_out}"),
        );
    }

    for p in &w.probe_violations {
        push(&mut v, "runtime-probe", p.clone());
    }
    v
}

/// Streaming invariant checker over the trace bus: violations are
/// detected (and stamped) at the moment the offending event is
/// published, which pinpoints *when* a run went wrong — the post-run
/// [`check_world`] can only say that it did.
///
/// Checks:
/// * **stamp-monotone** — `(time, seq)` stamps never go backwards (the
///   bus ordering contract);
/// * **exactly-once, duplicate-safe** — no task finishes twice and no
///   finished task is relaunched (a full job restart legally resets the
///   job's slate). Insurance replication is the sanctioned exception to
///   "one copy at a time": a duplicate must be *announced* on the bus as
///   `InsuranceLaunched` (never a second `TaskLaunched`), at most one
///   copy per task may be live, and however many copies run, exactly one
///   `TaskFinished` may be published — first commit wins;
/// * **completion** — a job completes at most once, and no task activity
///   follows its job's completion;
/// * **steal-conservation** — cumulative tasks stolen in never exceed
///   tasks granted out by victims.
#[derive(Default)]
pub struct StreamChecker {
    last: Option<(SimTime, u64)>,
    done: HashSet<TaskId>,
    completed: HashSet<JobId>,
    /// Tasks with a live announced insurance duplicate.
    insured: HashSet<TaskId>,
    stolen_out: u64,
    stolen_in: u64,
    violations: Vec<String>,
}

impl StreamChecker {
    pub fn new() -> StreamChecker {
        StreamChecker::default()
    }

    /// Attach a fresh checker to the world's trace bus; read the returned
    /// handle after the run (the runner folds it into the campaign
    /// verdict via `World::probe_violations`).
    pub fn install(world: &World) -> Rc<RefCell<StreamChecker>> {
        let checker = Rc::new(RefCell::new(StreamChecker::new()));
        world.tracer.attach(Box::new(StreamSink(checker.clone())));
        checker
    }

    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    fn violate(&mut self, msg: String) {
        if self.violations.len() < 64 {
            self.violations.push(msg);
        }
    }
}

impl TraceSink for StreamChecker {
    fn on_event(&mut self, ev: &Stamped) {
        if let Some((t, s)) = self.last {
            if ev.time < t || ev.seq <= s {
                self.violate(format!(
                    "stream-order: stamp ({}, {}) after ({t}, {s})",
                    ev.time, ev.seq
                ));
            }
        }
        self.last = Some((ev.time, ev.seq));
        let at = to_secs(ev.time);
        match &ev.event {
            TraceEvent::TaskFinished { job, task, .. } => {
                if !self.done.insert(*task) {
                    self.violate(format!(
                        "stream-exactly-once: {task} completed twice (second at t={at:.1}s)"
                    ));
                }
                if self.completed.contains(job) {
                    self.violate(format!(
                        "stream-completion: {task} finished after {job} completed (t={at:.1}s)"
                    ));
                }
                // Whichever copy won, the single finish retires the
                // task's insurance duplicate.
                self.insured.remove(task);
            }
            TraceEvent::TaskLaunched { job, task, .. } => {
                if self.done.contains(task) {
                    self.violate(format!(
                        "stream-exactly-once: {task} relaunched after completion (t={at:.1}s)"
                    ));
                }
                if self.completed.contains(job) {
                    self.violate(format!(
                        "stream-completion: {task} launched after {job} completed (t={at:.1}s)"
                    ));
                }
            }
            TraceEvent::JobCompleted { job } => {
                if !self.completed.insert(*job) {
                    self.violate(format!(
                        "stream-completion: {job} completed twice (second at t={at:.1}s)"
                    ));
                }
            }
            TraceEvent::TaskRequeued { task, .. }
            | TraceEvent::SpeculativeRelaunch { task, .. } => {
                // A re-queue or speculative abort kills every live copy,
                // insurance included — the relaunch may legally re-insure.
                self.insured.remove(task);
            }
            TraceEvent::InsuranceLaunched { job, task, .. } => {
                if self.done.contains(task) {
                    self.violate(format!(
                        "stream-insurance: {task} insured after completion (t={at:.1}s)"
                    ));
                }
                if self.completed.contains(job) {
                    self.violate(format!(
                        "stream-insurance: {task} insured after {job} completed (t={at:.1}s)"
                    ));
                }
                if !self.insured.insert(*task) {
                    self.violate(format!(
                        "stream-insurance: {task} insured twice without completing (t={at:.1}s)"
                    ));
                }
            }
            TraceEvent::JobRestarted { job } => {
                // A full restart (centralized baseline) legally reruns
                // every task of the job from scratch.
                let job = *job;
                self.done.retain(|t| t.job != job);
                self.insured.retain(|t| t.job != job);
                self.completed.remove(&job);
            }
            TraceEvent::StealGranted { tasks, .. } => {
                self.stolen_out += *tasks as u64;
            }
            TraceEvent::StealCompleted { tasks, .. } => {
                self.stolen_in += *tasks as u64;
                if self.stolen_in > self.stolen_out {
                    self.violate(format!(
                        "stream-steal-conservation: {} in > {} out (t={at:.1}s)",
                        self.stolen_in, self.stolen_out
                    ));
                }
            }
            _ => {}
        }
    }
}

/// [`TraceSink`] adapter sharing one [`StreamChecker`] with the runner.
pub struct StreamSink(pub Rc<RefCell<StreamChecker>>);

impl TraceSink for StreamSink {
    fn on_event(&mut self, ev: &Stamped) {
        self.0.borrow_mut().on_event(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::StageId;
    use crate::sim::secs;

    fn st(t: u64, seq: u64, event: TraceEvent) -> Stamped {
        Stamped { time: secs(t), seq, event }
    }

    fn task(i: u32) -> TaskId {
        TaskId { job: JobId(0), stage: StageId(0), index: i }
    }

    fn finished(i: u32) -> TraceEvent {
        TraceEvent::TaskFinished { job: JobId(0), task: task(i), dc: DcId(0) }
    }

    #[test]
    fn flags_double_completion_at_the_offending_event() {
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, finished(0)));
        c.on_event(&st(11, 1, finished(1)));
        assert!(c.violations().is_empty());
        c.on_event(&st(12, 2, finished(0)));
        assert_eq!(c.violations().len(), 1);
        let v = &c.violations()[0];
        assert!(v.contains("completed twice"), "{v}");
        assert!(v.contains("t=12.0s"), "timestamped at the event: {v}");
    }

    #[test]
    fn restart_legally_reruns_the_job() {
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, finished(0)));
        c.on_event(&st(20, 1, TraceEvent::JobRestarted { job: JobId(0) }));
        c.on_event(&st(30, 2, finished(0)));
        c.on_event(&st(40, 3, TraceEvent::JobCompleted { job: JobId(0) }));
        assert!(c.violations().is_empty(), "{:?}", c.violations());
    }

    #[test]
    fn flags_activity_after_job_completion() {
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, TraceEvent::JobCompleted { job: JobId(0) }));
        c.on_event(&st(
            11,
            1,
            TraceEvent::TaskLaunched {
                job: JobId(0),
                task: task(0),
                dc: DcId(0),
                locality: "any",
                remote_input: false,
            },
        ));
        assert_eq!(c.violations().len(), 1);
        assert!(c.violations()[0].contains("launched after"), "{:?}", c.violations());
    }

    #[test]
    fn flags_steal_deficit_as_it_happens() {
        let mut c = StreamChecker::new();
        let grant = TraceEvent::StealGranted { job: JobId(0), victim: DcId(1), thief: DcId(0), tasks: 2 };
        let complete = |n| TraceEvent::StealCompleted {
            job: JobId(0),
            thief: DcId(0),
            victim: DcId(1),
            tasks: n,
            delay_ms: 60.0,
        };
        c.on_event(&st(10, 0, grant));
        c.on_event(&st(11, 1, complete(2)));
        assert!(c.violations().is_empty());
        c.on_event(&st(12, 2, complete(1)));
        assert_eq!(c.violations().len(), 1);
        assert!(c.violations()[0].contains("steal-conservation"), "{:?}", c.violations());
    }

    #[test]
    fn insurance_duplicates_are_exactly_once_safe() {
        let insure = |i| TraceEvent::InsuranceLaunched { job: JobId(0), task: task(i), dc: DcId(1) };
        // The legal shape: insure while running, single finish wins.
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, insure(0)));
        c.on_event(&st(12, 1, finished(0)));
        assert!(c.violations().is_empty(), "{:?}", c.violations());
        // Re-insuring after a re-queue (both copies died) is also legal.
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, insure(1)));
        c.on_event(&st(
            11,
            1,
            TraceEvent::TaskRequeued { job: JobId(0), task: task(1), dc: DcId(1) },
        ));
        c.on_event(&st(20, 2, insure(1)));
        c.on_event(&st(25, 3, finished(1)));
        assert!(c.violations().is_empty(), "{:?}", c.violations());
        // A speculative abort also kills the copy: re-insuring the
        // relaunched attempt is legal, not a double-insure.
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, insure(2)));
        c.on_event(&st(
            15,
            1,
            TraceEvent::SpeculativeRelaunch { job: JobId(0), task: task(2), dc: DcId(1) },
        ));
        c.on_event(&st(20, 2, insure(2)));
        c.on_event(&st(25, 3, finished(2)));
        assert!(c.violations().is_empty(), "{:?}", c.violations());
    }

    #[test]
    fn flags_double_insurance_and_insurance_after_completion() {
        let insure = |i| TraceEvent::InsuranceLaunched { job: JobId(0), task: task(i), dc: DcId(1) };
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, insure(0)));
        c.on_event(&st(11, 1, insure(0)));
        assert_eq!(c.violations().len(), 1);
        assert!(c.violations()[0].contains("insured twice"), "{:?}", c.violations());
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, finished(0)));
        c.on_event(&st(11, 1, insure(0)));
        assert_eq!(c.violations().len(), 1);
        assert!(c.violations()[0].contains("after completion"), "{:?}", c.violations());
        // Two finishes of an insured task stay a violation: first commit
        // wins is the contract, the duplicate must never also finish.
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 0, insure(2)));
        c.on_event(&st(12, 1, finished(2)));
        c.on_event(&st(13, 2, finished(2)));
        assert_eq!(c.violations().len(), 1);
        assert!(c.violations()[0].contains("completed twice"), "{:?}", c.violations());
    }

    #[test]
    fn flags_stamp_regression() {
        let mut c = StreamChecker::new();
        c.on_event(&st(10, 5, finished(0)));
        c.on_event(&st(9, 6, finished(1)));
        c.on_event(&st(10, 6, finished(2)));
        assert_eq!(c.violations().len(), 2, "{:?}", c.violations());
        assert!(c.violations().iter().all(|v| v.contains("stream-order")));
    }
}

/// Periodic runtime probe, called by the campaign runner right after each
/// scheduling-period tick. Checks the fair-share/Af contract and grant
/// bookkeeping *while the system runs*:
///
/// * a sub-job's allocation may exceed its desire only by keeping busy
///   containers it already held (the §5 "return the idle ones" rule) —
///   fresh grants must never push `a` past `d`;
/// * every granted container is alive and owned by the sub-job it is
///   booked to, and no container is booked to two sub-jobs.
///
/// `prev` carries last period's allocations (the probe owns it).
pub fn probe_world(w: &mut World, prev: &mut HashMap<JmId, usize>) {
    let mut seen: HashSet<ContainerId> = HashSet::new();
    let mut found: Vec<String> = Vec::new();
    for m in w.masters() {
        for jm in m.sub_jobs() {
            let a = m.allocation(jm);
            let d = m.desire(jm);
            let prev_a = prev.get(&jm).copied().unwrap_or(0);
            if a > d && a > prev_a {
                found.push(format!(
                    "fair-share: {jm} allocation {a} > desire {d} grew from {prev_a}"
                ));
            }
            for &cid in m.granted(jm) {
                match w.cluster.containers.get(&cid) {
                    Some(c) if c.alive && c.owner == Some(jm) => {}
                    Some(c) => found.push(format!(
                        "grant-consistency: {cid} booked to {jm} but alive={} owner={:?}",
                        c.alive, c.owner
                    )),
                    None => found.push(format!("grant-consistency: {cid} unknown to the cluster")),
                }
                if !seen.insert(cid) {
                    found.push(format!("double-grant: {cid} booked twice"));
                }
            }
            prev.insert(jm, a);
        }
    }
    prev.retain(|jm, _| w.masters().any(|m| m.is_registered(*jm)));
    for f in found {
        if w.probe_violations.len() < 64 {
            w.probe_violations.push(f);
        }
    }
}
