//! Invariant checkers: what must hold of *every* finished scenario run,
//! no matter which failures were injected. Each campaign run passes
//! through [`check_world`] (post-run, on the final [`World`]) and the
//! periodic [`probe_world`] (installed by the runner at every scheduling
//! period), which together turn every scenario execution into a test.

use std::collections::{HashMap, HashSet};

use crate::dag::TaskStatus;
use crate::deploy::World;
use crate::ids::{ContainerId, DcId, JmId, TaskId};

/// One invariant breach, with enough detail to debug the run.
#[derive(Debug, Clone)]
pub struct Violation {
    pub check: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.check, self.detail)
    }
}

fn push(v: &mut Vec<Violation>, check: &'static str, detail: String) {
    v.push(Violation { check, detail });
}

/// Post-run checks over the final world state.
///
/// * **job-terminates** — every submitted job completed within the
///   horizon (liveness under failures, §6.4).
/// * **exactly-once** — per completed job, each task is Done exactly
///   once: no lost task, no double completion (outputs, the replicated
///   partitionList and the DAG progress all agree on the task count).
/// * **quiescence** — no task left Waiting/Running after completion.
/// * **pool-restored** — all containers returned to the free pools
///   (skipped when hog pseudo-jobs hold containers by design).
/// * **master-leak** — no sub-job stays registered after its job ended.
/// * **steal-conservation** — tasks stolen in never exceed tasks stolen
///   out; with no JM disruption the two are equal.
/// * **runtime-probe** — anything [`probe_world`] recorded during the run.
pub fn check_world(w: &World) -> Vec<Violation> {
    let mut v = Vec::new();
    let total = w.metrics.jobs.len();
    let done = w.metrics.completed_jobs();
    if done != total {
        push(&mut v, "job-terminates", format!("{done}/{total} jobs completed within horizon"));
    }

    for (&id, rt) in &w.jobs {
        if !rt.done {
            continue;
        }
        let n = rt.spec.num_tasks();
        let d = rt.progress.count(TaskStatus::Done);
        if d != n {
            push(&mut v, "exactly-once", format!("{id}: {d}/{n} tasks Done"));
        }
        if rt.outputs.len() != n {
            push(&mut v, "exactly-once", format!("{id}: {} outputs for {n} tasks", rt.outputs.len()));
        }
        let distinct: HashSet<TaskId> =
            rt.info.partition_list.iter().map(|p| p.task).collect();
        if rt.info.partition_list.len() != n || distinct.len() != n {
            push(
                &mut v,
                "exactly-once",
                format!(
                    "{id}: partitionList has {} entries / {} distinct for {n} tasks",
                    rt.info.partition_list.len(),
                    distinct.len()
                ),
            );
        }
        let (waiting, running) =
            (rt.progress.count(TaskStatus::Waiting), rt.progress.count(TaskStatus::Running));
        if waiting != 0 || running != 0 {
            push(&mut v, "quiescence", format!("{id}: {waiting} waiting, {running} running after done"));
        }
        if let Some(rec) = w.metrics.jobs.get(&id) {
            if let Some(jrt) = rec.jrt() {
                if !(jrt > 0.0) {
                    push(&mut v, "jrt-sanity", format!("{id}: non-positive JRT {jrt}"));
                }
            }
        }
    }

    if w.hogs.is_empty() && done == total {
        for dcid in 0..w.cfg.topology.num_dcs() {
            let dc = DcId(dcid);
            let free = w.cluster.free_pool(dc).len();
            let cap = w.cluster.dc_capacity(dc);
            if free != cap {
                push(&mut v, "pool-restored", format!("{dc}: {free} free of {cap} capacity"));
            }
        }
        for (i, m) in w.masters.iter().enumerate() {
            let leftover = m.sub_jobs();
            if !leftover.is_empty() {
                push(&mut v, "master-leak", format!("master {i} still tracks {leftover:?}"));
            }
        }
    }

    let stolen_in: u64 = w
        .jobs
        .values()
        .flat_map(|rt| rt.jms.values())
        .map(|jm| jm.stats.tasks_stolen_in)
        .sum();
    let stolen_out: u64 = w
        .jobs
        .values()
        .flat_map(|rt| rt.jms.values())
        .map(|jm| jm.stats.tasks_stolen_out)
        .sum();
    if stolen_in > stolen_out {
        push(
            &mut v,
            "steal-conservation",
            format!("{stolen_in} stolen in > {stolen_out} stolen out"),
        );
    }
    let restarts: u32 = w.metrics.jobs.values().map(|j| j.restarts).sum();
    let disrupted = restarts > 0
        || !w.metrics.recovery_intervals_secs.is_empty()
        || !w.metrics.election_delays_secs.is_empty();
    if !disrupted && stolen_in != stolen_out {
        // A deficit is legal only when a thief died mid-steal, which
        // always leaves a recovery/election/restart trace.
        push(
            &mut v,
            "steal-conservation",
            format!("undisrupted run lost steals: in {stolen_in} != out {stolen_out}"),
        );
    }

    for p in &w.probe_violations {
        push(&mut v, "runtime-probe", p.clone());
    }
    v
}

/// Periodic runtime probe, called by the campaign runner right after each
/// scheduling-period tick. Checks the fair-share/Af contract and grant
/// bookkeeping *while the system runs*:
///
/// * a sub-job's allocation may exceed its desire only by keeping busy
///   containers it already held (the §5 "return the idle ones" rule) —
///   fresh grants must never push `a` past `d`;
/// * every granted container is alive and owned by the sub-job it is
///   booked to, and no container is booked to two sub-jobs.
///
/// `prev` carries last period's allocations (the probe owns it).
pub fn probe_world(w: &mut World, prev: &mut HashMap<JmId, usize>) {
    let mut seen: HashSet<ContainerId> = HashSet::new();
    let mut found: Vec<String> = Vec::new();
    for m in &w.masters {
        for jm in m.sub_jobs() {
            let a = m.allocation(jm);
            let d = m.desire(jm);
            let prev_a = prev.get(&jm).copied().unwrap_or(0);
            if a > d && a > prev_a {
                found.push(format!(
                    "fair-share: {jm} allocation {a} > desire {d} grew from {prev_a}"
                ));
            }
            for &cid in m.granted(jm) {
                match w.cluster.containers.get(&cid) {
                    Some(c) if c.alive && c.owner == Some(jm) => {}
                    Some(c) => found.push(format!(
                        "grant-consistency: {cid} booked to {jm} but alive={} owner={:?}",
                        c.alive, c.owner
                    )),
                    None => found.push(format!("grant-consistency: {cid} unknown to the cluster")),
                }
                if !seen.insert(cid) {
                    found.push(format!("double-grant: {cid} booked twice"));
                }
            }
            prev.insert(jm, a);
        }
    }
    prev.retain(|jm, _| w.masters.iter().any(|m| m.is_registered(*jm)));
    for f in found {
        if w.probe_violations.len() < 64 {
            w.probe_violations.push(f);
        }
    }
}
