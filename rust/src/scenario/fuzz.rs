//! Seeded chaos fuzzer with minimal-counterexample shrinking.
//!
//! The campaign engine runs the scenarios someone wrote down; this module
//! *generates* them. A [`FuzzSpace`] declares the adversary space — chaos
//! schedules over the full `kind@time:args` DSL (including the correlated
//! `kill_dc@` outages and `spot_storm@` price storms), topology and
//! workload axes, straggler sweeps and config overrides — and [`run_fuzz`]
//! samples random campaign cells from it, executes each through the full
//! invariant stack ([`super::runner::run_one`]: streaming checkers,
//! runtime probe, post-run world checks, replay digest) on the same
//! `std::thread` worker pool the campaign runner uses.
//!
//! When a cell violates an invariant, the fuzzer does not just report the
//! (often large) random schedule: it **shrinks** it. [`CellGen`] extends
//! the [`crate::testkit::Gen`] shrink contract from scalar values to whole
//! [`ScenarioSpec`]s — drop chaos events, halve times/durations/counts,
//! pull factors back toward benign, drop overrides, simplify the workload,
//! shrink the seed — and the same greedy [`crate::testkit::shrink_failure`]
//! loop that minimizes a failing integer minimizes the failing chaos
//! schedule. The result is emitted as a repro TOML ([`repro_toml`]) that
//! `houtu campaign --spec repro.toml` loads directly, so a fuzz finding is
//! one command away from a deterministic regression test.
//!
//! Determinism: cells are generated up front from the fuzz seed, executed
//! in a fixed order, and shrinking probes candidates in the deterministic
//! order [`Gen::shrink`] returns — so reports (digests, failures, shrunk
//! cells) are identical regardless of worker count.
//!
//! `houtu fuzz [--cases N] [--seed S] [--soak MINUTES] [--repro out.toml]
//! [--report out.json]` drives this; `--soak` keeps sampling fresh batches
//! until the wall-clock budget expires (the ROADMAP's long-horizon soak
//! campaigns) and `--report` exports the [`FuzzReport`] as verified JSON.

use std::time::{Duration, Instant};

use crate::config::{Config, Deployment};
use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::{DcId, NodeId};
use crate::testkit::{shrink_failure, Gen};
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::util::Pcg;
use crate::{anyhow, ensure};

use super::runner::run_one;
use super::spec::{CampaignSpec, ChaosEvent, ScenarioSpec, ScenarioWorkload};

/// The declarative adversary space [`run_fuzz`] samples from. Bounds are
/// chosen so that every generated cell is *survivable by design* on a
/// correct tree (e.g. at most one whole-DC outage per cell, hogs always
/// spare the submitting DC): the fuzzer hunts invariant bugs, not
/// impossible physics.
#[derive(Debug, Clone)]
pub struct FuzzSpace {
    /// Hard cap on chaos events per cell.
    pub max_events: usize,
    /// Deployments drawn when a cell leaves the (weighted) houtu default.
    pub deployments: Vec<Deployment>,
    /// Region-count axis; 0 keeps the base topology.
    pub regions: Vec<usize>,
    /// Trace workloads submit 1..=this many jobs.
    pub trace_jobs_max: usize,
    /// Straggler sweep axes (first-class fuzz dimensions — every cell may
    /// overlay `workload.straggler_prob`/`straggler_factor` overrides).
    pub straggler_prob_max: f64,
    pub straggler_factor_max: f64,
    /// Allow spot-market cells (revocations on, optional `spot_storm@`).
    pub allow_revocations: bool,
    /// Bid-strategy axis: non-naive strategies a cell may overlay via
    /// `bidding.strategy=...` (possibly with `bidding.insurance=true`).
    /// Empty disables the axis; naive stays the implicit default.
    pub strategies: Vec<crate::cloud::bidding::StrategyKind>,
    /// Topology-scale axis: `(dcs, nodes_per_dc)` draws for generated
    /// worlds (`topology.generated=generated:<dcs>,<nodes>,<seed>`). A
    /// cell that takes this axis forces `regions = 0` (the two topology
    /// sources are mutually exclusive) and draws chaos targets from the
    /// generated world's dimensions. Failing cells shrink down the
    /// `(dcs, nodes)` lattice toward a minimal failing scale. Empty
    /// disables the axis.
    pub topo_scales: Vec<(usize, usize)>,
}

impl Default for FuzzSpace {
    fn default() -> Self {
        FuzzSpace {
            max_events: 3,
            deployments: Deployment::ALL.to_vec(),
            // Never below the paper's 4 regions: with ≤3 chaos events and
            // ≥4 JM replicas, some replica always survives a simultaneous
            // combination, keeping cells survivable by construction.
            regions: vec![0, 0, 0, 6, 8],
            trace_jobs_max: 3,
            straggler_prob_max: 0.25,
            straggler_factor_max: 5.0,
            allow_revocations: true,
            strategies: vec![
                crate::cloud::bidding::StrategyKind::Adaptive,
                crate::cloud::bidding::StrategyKind::Deadline,
            ],
            // Small generated worlds: large enough to leave the paper's
            // 4-DC shape, small enough that every cell stays fast under
            // the full invariant oracle.
            topo_scales: vec![(8, 2), (16, 2)],
        }
    }
}

/// One sampled campaign cell: a scenario plus the seed it runs at.
#[derive(Debug, Clone, PartialEq)]
pub struct FuzzCell {
    pub spec: ScenarioSpec,
    pub seed: u64,
}

fn round1(x: f64) -> f64 {
    (x * 10.0).round() / 10.0
}

fn round2(x: f64) -> f64 {
    (x * 100.0).round() / 100.0
}

/// Generator of [`FuzzCell`]s over a [`FuzzSpace`] — the [`Gen`] shrink
/// contract extended from values to whole scenario specs.
pub struct CellGen<'a> {
    pub space: &'a FuzzSpace,
    pub base: &'a Config,
}

impl<'a> CellGen<'a> {
    pub fn new(space: &'a FuzzSpace, base: &'a Config) -> CellGen<'a> {
        CellGen { space, base }
    }

    /// Region count a cell with this `regions` axis actually runs on.
    fn dcs(&self, regions: usize) -> usize {
        if regions == 0 {
            self.base.topology.num_dcs()
        } else {
            regions
        }
    }
}

impl Gen<FuzzCell> for CellGen<'_> {
    fn generate(&self, rng: &mut Pcg) -> FuzzCell {
        let space = self.space;
        // Topology-scale axis first: a generated world replaces the
        // regions axis (mutually exclusive at the config layer), and
        // every later DC/node draw must use *its* dimensions.
        let topo = if !space.topo_scales.is_empty() && rng.chance(0.2) {
            let (dcs, nodes) = space.topo_scales[rng.index(space.topo_scales.len())];
            Some((dcs, nodes, 1 + rng.below(9)))
        } else {
            None
        };
        let regions = if topo.is_some() {
            0
        } else {
            space.regions[rng.index(space.regions.len())]
        };
        let n = match topo {
            Some((dcs, _, _)) => dcs,
            None => self.dcs(regions),
        };
        let nodes_per_dc = match topo {
            Some((_, nodes, _)) => nodes,
            None => self.base.topology.workers_per_dc,
        };
        let deployment = if rng.chance(0.7) || space.deployments.is_empty() {
            Deployment::Houtu
        } else {
            space.deployments[rng.index(space.deployments.len())]
        };
        let workload = if rng.chance(0.25) {
            ScenarioWorkload::Trace { num_jobs: 1 + rng.index(space.trace_jobs_max.max(1)) }
        } else {
            let kinds = [
                WorkloadKind::WordCount,
                WorkloadKind::TpcH,
                WorkloadKind::IterativeMl,
                WorkloadKind::PageRank,
            ];
            ScenarioWorkload::SingleJob {
                kind: kinds[rng.index(kinds.len())],
                size: if rng.chance(0.3) { SizeClass::Medium } else { SizeClass::Small },
                home: DcId(rng.index(n)),
            }
        };
        let home = match workload {
            ScenarioWorkload::SingleJob { home, .. } => home,
            ScenarioWorkload::Trace { .. } => DcId(0),
        };
        let mut events: Vec<ChaosEvent> = Vec::new();
        let mut overrides: Vec<String> = Vec::new();
        // One chaos theme per cell keeps combinations survivable while
        // still crossing every family with every workload/topology axis.
        match rng.index(6) {
            // Calm cell: pins the no-chaos invariants at random axes.
            0 => {}
            // Resource pressure: hogs into a proper subset sparing the
            // submitting DC (single-job only — trace jobs homed in a
            // hogged DC could never spawn their JM, which is starvation
            // by construction, not a bug; and only when a non-home DC
            // exists to hog).
            1 => {
                if n >= 2 && matches!(workload, ScenarioWorkload::SingleJob { .. }) {
                    let mut dcs: Vec<DcId> =
                        (0..n).map(DcId).filter(|d| *d != home).collect();
                    rng.shuffle(&mut dcs);
                    let k = 1 + rng.index(dcs.len().min(3));
                    dcs.truncate(k);
                    dcs.sort_by_key(|d| d.0);
                    events.push(ChaosEvent::InjectHogs {
                        at_secs: round1(rng.uniform(30.0, 300.0)),
                        dcs,
                    });
                }
            }
            // JM chaos: a kill or a bounded cascade, plus maybe one
            // spot-style node termination.
            2 => {
                if rng.chance(0.5) {
                    events.push(ChaosEvent::KillJm {
                        at_secs: round1(rng.uniform(20.0, 200.0)),
                        dc: DcId(rng.index(n)),
                    });
                } else {
                    events.push(ChaosEvent::KillJmCascade {
                        at_secs: round1(rng.uniform(20.0, 120.0)),
                        dc: DcId(rng.index(n)),
                        count: 1 + rng.index(2) as u32,
                        gap_secs: round1(rng.uniform(20.0, 60.0)),
                    });
                }
                if rng.chance(0.4) {
                    events.push(ChaosEvent::KillNode {
                        at_secs: round1(rng.uniform(10.0, 300.0)),
                        node: NodeId {
                            dc: DcId(rng.index(n)),
                            idx: rng.index(nodes_per_dc),
                        },
                    });
                }
            }
            // Correlated whole-DC outage (at most one per cell), plus
            // maybe a stray node kill elsewhere.
            3 => {
                let dead = DcId(rng.index(n));
                events.push(ChaosEvent::KillDc {
                    at_secs: round1(rng.uniform(30.0, 240.0)),
                    dc: dead,
                });
                if n >= 2 && rng.chance(0.3) {
                    events.push(ChaosEvent::KillNode {
                        at_secs: round1(rng.uniform(10.0, 300.0)),
                        node: NodeId {
                            dc: DcId((dead.0 + 1 + rng.index(n - 1)) % n),
                            idx: rng.index(nodes_per_dc),
                        },
                    });
                }
            }
            // WAN weather: one brown-out window, or (given two regions to
            // pair) an asymmetric pair degrade with an optional restore.
            4 => {
                if n < 2 || rng.chance(0.5) {
                    let from = round1(rng.uniform(10.0, 200.0));
                    let dur = round1(rng.uniform(30.0, 300.0));
                    events.push(ChaosEvent::WanDegrade {
                        from_secs: from,
                        until_secs: from + dur,
                        factor: round2(rng.uniform(0.05, 0.6)),
                    });
                } else {
                    let a = DcId(rng.index(n));
                    let b = DcId((a.0 + 1 + rng.index(n - 1)) % n);
                    let at = round1(rng.uniform(10.0, 200.0));
                    events.push(ChaosEvent::WanPairDegrade {
                        at_secs: at,
                        a,
                        b,
                        factor: round2(rng.uniform(0.05, 0.6)),
                    });
                    if rng.chance(0.5) {
                        events.push(ChaosEvent::WanPairDegrade {
                            at_secs: at + round1(rng.uniform(60.0, 400.0)),
                            a,
                            b,
                            factor: 1.0,
                        });
                    }
                }
            }
            // Spot-market adversary: revocations on, optionally with a
            // scheduled volatility storm on one region.
            _ => {
                if space.allow_revocations {
                    overrides.push("cloud.revocations=true".to_string());
                    overrides.push("cloud.bid_multiplier=1.5".to_string());
                    let period = [60.0, 120.0][rng.index(2)];
                    overrides.push(format!("cloud.market_period_secs={period}"));
                    if rng.chance(0.6) {
                        events.push(ChaosEvent::SpotStorm {
                            at_secs: round1(rng.uniform(60.0, 300.0)),
                            dc: DcId(rng.index(n)),
                            dur_secs: round1(rng.uniform(120.0, 600.0)),
                            sigma_factor: round1(rng.uniform(2.0, 4.0)),
                        });
                    }
                }
            }
        }
        // Cross-cutting straggler sweep: the §2.2 changeable environment
        // at task granularity, riding on top of any theme.
        if rng.chance(0.35) {
            let p = round2(rng.uniform(0.05, space.straggler_prob_max.max(0.05)));
            let f = round2(rng.uniform(1.5, space.straggler_factor_max.max(1.5)));
            overrides.push(format!("workload.straggler_prob={p}"));
            overrides.push(format!("workload.straggler_factor={f}"));
        }
        // Cost-aware bidding axis: overlay a non-naive strategy (and
        // sometimes insurance replication) on any theme, so the bidding
        // subsystem is fuzzed against every chaos family.
        if !space.strategies.is_empty() && rng.chance(0.3) {
            let strat = space.strategies[rng.index(space.strategies.len())];
            overrides.push(format!("bidding.strategy={}", strat.name()));
            if strat == crate::cloud::bidding::StrategyKind::Deadline {
                // A deadline policy with no deadline is inert — give it
                // one tight enough that jobs actually fall behind.
                let deadline = [120.0, 300.0, 900.0][rng.index(3)];
                overrides.push(format!("workload.deadline_secs={deadline}"));
            }
            if rng.chance(0.5) {
                overrides.push("bidding.insurance=true".to_string());
            }
        }
        // Occasional benign scheduler axis, to cross chaos with tuning.
        if rng.chance(0.2) {
            overrides.push(format!("scheduler.tau={}", [0.25, 0.5, 1.0][rng.index(3)]));
        }
        if let Some((dcs, nodes, tseed)) = topo {
            overrides.push(format!("topology.generated=generated:{dcs},{nodes},{tseed}"));
        }
        events.truncate(space.max_events);
        let spec = ScenarioSpec {
            name: format!("fuzz-{:08x}", rng.next_u32()),
            deployment,
            regions,
            workload,
            events,
            overrides,
        };
        FuzzCell { spec, seed: 1 + rng.below(1_000_000) }
    }

    /// Shrink a failing cell toward a minimal chaos schedule. Candidates
    /// are ordered most-aggressive-first (drop everything, then halves,
    /// then single drops, then per-field simplifications) so the greedy
    /// loop converges in few probes; every candidate is strictly simpler,
    /// and candidates that no longer fit the topology are filtered by the
    /// caller's validity check.
    fn shrink(&self, cell: &FuzzCell) -> Vec<FuzzCell> {
        let mut out: Vec<FuzzCell> = Vec::new();
        let with_spec = |spec: ScenarioSpec, seed: u64| FuzzCell { spec, seed };
        let s = &cell.spec;

        // 1. Schedule-level drops: all events, the back half, each one.
        if !s.events.is_empty() {
            out.push(with_spec(ScenarioSpec { events: Vec::new(), ..s.clone() }, cell.seed));
        }
        if s.events.len() > 1 {
            let half = s.events[..s.events.len() / 2].to_vec();
            out.push(with_spec(ScenarioSpec { events: half, ..s.clone() }, cell.seed));
        }
        for i in 0..s.events.len() {
            let mut ev = s.events.clone();
            ev.remove(i);
            if !ev.is_empty() {
                out.push(with_spec(ScenarioSpec { events: ev, ..s.clone() }, cell.seed));
            }
        }

        // 2. Per-event simplifications: halve times/durations/counts,
        // pull factors back toward benign, drop hog DCs. The submitting
        // DC is threaded through so hog shrinks can never target it —
        // hogging home starves the job by construction, which would let
        // a genuine invariant failure shrink into a trivial-starvation
        // repro and hide the actual bug.
        let home = match s.workload {
            ScenarioWorkload::SingleJob { home, .. } => home,
            ScenarioWorkload::Trace { .. } => DcId(0),
        };
        for (i, ev) in s.events.iter().enumerate() {
            for simpler in shrink_event(ev, home) {
                let mut evs = s.events.clone();
                evs[i] = simpler;
                out.push(with_spec(ScenarioSpec { events: evs, ..s.clone() }, cell.seed));
            }
        }

        // 3. Drop overrides one at a time. (Dropping a
        // `topology.generated=` override reverts to the base topology;
        // events that no longer fit are filtered by the caller's
        // validity check, like every other candidate.)
        for i in 0..s.overrides.len() {
            let mut ov = s.overrides.clone();
            ov.remove(i);
            out.push(with_spec(ScenarioSpec { overrides: ov, ..s.clone() }, cell.seed));
        }

        // 3b. Walk a generated topology down the (dcs, nodes_per_dc)
        // lattice: halve each coordinate (floored at 2 DCs / 1 node) so
        // a failing planet-scale cell minimizes to the smallest world
        // that still fails, not a 256-DC monster.
        for i in 0..s.overrides.len() {
            let rest = match s.overrides[i].strip_prefix("topology.generated=") {
                Some(r) => r,
                None => continue,
            };
            let ts = match crate::topo::parse_spec(rest) {
                Ok(t) => t,
                Err(_) => continue,
            };
            if ts.dcs > 2 {
                let mut ov = s.overrides.clone();
                ov[i] = format!(
                    "topology.generated=generated:{},{},{}",
                    (ts.dcs / 2).max(2),
                    ts.nodes_per_dc,
                    ts.seed
                );
                out.push(with_spec(ScenarioSpec { overrides: ov, ..s.clone() }, cell.seed));
            }
            if ts.nodes_per_dc > 1 {
                let mut ov = s.overrides.clone();
                ov[i] = format!(
                    "topology.generated=generated:{},{},{}",
                    ts.dcs,
                    ts.nodes_per_dc / 2,
                    ts.seed
                );
                out.push(with_spec(ScenarioSpec { overrides: ov, ..s.clone() }, cell.seed));
            }
        }

        // 4. Simplify the workload / topology / deployment axes.
        match s.workload {
            ScenarioWorkload::Trace { num_jobs } if num_jobs > 1 => {
                out.push(with_spec(
                    ScenarioSpec {
                        workload: ScenarioWorkload::Trace { num_jobs: num_jobs / 2 },
                        ..s.clone()
                    },
                    cell.seed,
                ));
            }
            ScenarioWorkload::SingleJob { kind, size, home } => {
                if let Some(smaller) = match size {
                    SizeClass::Large => Some(SizeClass::Medium),
                    SizeClass::Medium => Some(SizeClass::Small),
                    SizeClass::Small => None,
                } {
                    out.push(with_spec(
                        ScenarioSpec {
                            workload: ScenarioWorkload::SingleJob { kind, size: smaller, home },
                            ..s.clone()
                        },
                        cell.seed,
                    ));
                }
                // Moving home onto a hogged DC would starve the job by
                // construction — skip the candidate in that case.
                let dc0_hogged = s.events.iter().any(|e| {
                    matches!(e, ChaosEvent::InjectHogs { dcs, .. } if dcs.contains(&DcId(0)))
                });
                if home != DcId(0) && !dc0_hogged {
                    out.push(with_spec(
                        ScenarioSpec {
                            workload: ScenarioWorkload::SingleJob { kind, size, home: DcId(0) },
                            ..s.clone()
                        },
                        cell.seed,
                    ));
                }
            }
            _ => {}
        }
        if s.regions > 0 {
            out.push(with_spec(ScenarioSpec { regions: 0, ..s.clone() }, cell.seed));
        }
        if s.deployment != Deployment::Houtu {
            out.push(with_spec(
                ScenarioSpec { deployment: Deployment::Houtu, ..s.clone() },
                cell.seed,
            ));
        }

        // 5. Shrink the seed last: 1, then halves.
        if cell.seed > 1 {
            out.push(with_spec(s.clone(), 1));
            if cell.seed > 3 {
                out.push(with_spec(s.clone(), cell.seed / 2));
            }
        }
        out
    }
}

/// Push time-shrink candidates: jump straight to t=0, then halve (on the
/// 0.1 s grid). Guards keep every candidate *strictly* earlier, so the
/// greedy loop cannot stall on a candidate equal to its input.
fn push_time_shrinks(out: &mut Vec<ChaosEvent>, at: f64, rebuild: &dyn Fn(f64) -> ChaosEvent) {
    if at > 0.0 {
        out.push(rebuild(0.0));
        let half = round1(at / 2.0);
        if half > 0.0 && half < at {
            out.push(rebuild(half));
        }
    }
}

/// Simpler variants of one chaos event (empty when already minimal).
/// Besides times/durations/counts/factors, DC indices shrink toward dc0:
/// without that move, a failing cell generated on a widened topology
/// (`regions = 6/8`) whose events reference dc4+ could never take the
/// `regions -> 0` candidate (it would no longer fit the base topology).
/// `home` is the submitting DC; hog shrinks never remap onto it.
fn shrink_event(ev: &ChaosEvent, home: DcId) -> Vec<ChaosEvent> {
    let mut out = Vec::new();
    match ev.clone() {
        ChaosEvent::InjectHogs { at_secs, dcs } => {
            push_time_shrinks(&mut out, at_secs, &|t| ChaosEvent::InjectHogs {
                at_secs: t,
                dcs: dcs.clone(),
            });
            if dcs.len() > 1 {
                let mut fewer = dcs.clone();
                fewer.pop();
                out.push(ChaosEvent::InjectHogs { at_secs, dcs: fewer });
            }
            // Remap the (sorted, distinct) set onto the lowest indices
            // that spare the submitting DC.
            let minimal: Vec<DcId> =
                (0..).map(DcId).filter(|d| *d != home).take(dcs.len()).collect();
            if dcs != minimal {
                out.push(ChaosEvent::InjectHogs { at_secs, dcs: minimal });
            }
        }
        ChaosEvent::KillJm { at_secs, dc } => {
            push_time_shrinks(&mut out, at_secs, &|t| ChaosEvent::KillJm { at_secs: t, dc });
            if dc.0 > 0 {
                out.push(ChaosEvent::KillJm { at_secs, dc: DcId(0) });
            }
        }
        ChaosEvent::KillJmCascade { at_secs, dc, count, gap_secs } => {
            // A single kill_jm is strictly milder than any cascade.
            out.push(ChaosEvent::KillJm { at_secs, dc });
            if count > 1 {
                out.push(ChaosEvent::KillJmCascade { at_secs, dc, count: count / 2, gap_secs });
            }
            push_time_shrinks(&mut out, at_secs, &|t| ChaosEvent::KillJmCascade {
                at_secs: t,
                dc,
                count,
                gap_secs,
            });
            let half_gap = round1(gap_secs / 2.0);
            if half_gap > 0.0 && half_gap < gap_secs {
                out.push(ChaosEvent::KillJmCascade { at_secs, dc, count, gap_secs: half_gap });
            }
            if dc.0 > 0 {
                out.push(ChaosEvent::KillJmCascade { at_secs, dc: DcId(0), count, gap_secs });
            }
        }
        ChaosEvent::KillNode { at_secs, node } => {
            push_time_shrinks(&mut out, at_secs, &|t| ChaosEvent::KillNode { at_secs: t, node });
            if node.idx > 0 {
                out.push(ChaosEvent::KillNode {
                    at_secs,
                    node: NodeId { dc: node.dc, idx: 0 },
                });
            }
            if node.dc.0 > 0 {
                out.push(ChaosEvent::KillNode {
                    at_secs,
                    node: NodeId { dc: DcId(0), idx: node.idx },
                });
            }
        }
        ChaosEvent::KillDc { at_secs, dc } => {
            // A single node kill is strictly milder than a DC outage.
            out.push(ChaosEvent::KillNode { at_secs, node: NodeId { dc, idx: 0 } });
            push_time_shrinks(&mut out, at_secs, &|t| ChaosEvent::KillDc { at_secs: t, dc });
            if dc.0 > 0 {
                out.push(ChaosEvent::KillDc { at_secs, dc: DcId(0) });
            }
        }
        ChaosEvent::WanDegrade { from_secs, until_secs, factor } => {
            push_time_shrinks(&mut out, from_secs, &|t| ChaosEvent::WanDegrade {
                from_secs: t,
                until_secs: t + (until_secs - from_secs),
                factor,
            });
            let dur = until_secs - from_secs;
            let half_dur = round1(dur / 2.0);
            if half_dur > 0.0 && half_dur < dur {
                out.push(ChaosEvent::WanDegrade {
                    from_secs,
                    until_secs: from_secs + half_dur,
                    factor,
                });
            }
            let milder = round2(factor + (1.0 - factor) / 2.0);
            if factor < 0.95 && milder > factor {
                out.push(ChaosEvent::WanDegrade { from_secs, until_secs, factor: milder });
            }
        }
        ChaosEvent::WanPairDegrade { at_secs, a, b, factor } => {
            push_time_shrinks(&mut out, at_secs, &|t| ChaosEvent::WanPairDegrade {
                at_secs: t,
                a,
                b,
                factor,
            });
            let milder = round2(factor + (1.0 - factor) / 2.0);
            if factor < 0.95 && milder > factor {
                out.push(ChaosEvent::WanPairDegrade { at_secs, a, b, factor: milder });
            }
            if a.0 + b.0 > 1 {
                out.push(ChaosEvent::WanPairDegrade {
                    at_secs,
                    a: DcId(0),
                    b: DcId(1),
                    factor,
                });
            }
        }
        ChaosEvent::SpotStorm { at_secs, dc, dur_secs, sigma_factor } => {
            push_time_shrinks(&mut out, at_secs, &|t| ChaosEvent::SpotStorm {
                at_secs: t,
                dc,
                dur_secs,
                sigma_factor,
            });
            let half_dur = round1(dur_secs / 2.0);
            if half_dur > 0.0 && half_dur < dur_secs {
                out.push(ChaosEvent::SpotStorm { at_secs, dc, dur_secs: half_dur, sigma_factor });
            }
            let milder = round1(1.0 + (sigma_factor - 1.0) / 2.0);
            if sigma_factor > 1.1 && milder < sigma_factor {
                out.push(ChaosEvent::SpotStorm { at_secs, dc, dur_secs, sigma_factor: milder });
            }
            if dc.0 > 0 {
                out.push(ChaosEvent::SpotStorm { at_secs, dc: DcId(0), dur_secs, sigma_factor });
            }
        }
    }
    out
}

/// What one cell execution produced, as far as the fuzzer cares.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    pub violations: Vec<String>,
    pub digest: u64,
    /// Run-level cost (machine + transfer): the fuzz report's cost column.
    pub usd: f64,
}

/// Cell-execution oracle. The default ([`sim_oracle`]) runs the real
/// simulator through the full invariant stack; tests substitute synthetic
/// oracles to pin shrink behaviour without paying for simulations.
pub type Oracle<'a> = &'a (dyn Fn(&Config, &ScenarioSpec, u64) -> CellOutcome + Sync);

/// The production oracle: run the cell through [`run_one`] (streaming
/// checkers + runtime probe + post-run world checks + digest; panics are
/// caught and reported as violations).
pub fn sim_oracle(base: &Config, spec: &ScenarioSpec, seed: u64) -> CellOutcome {
    let rep = run_one(base, spec, seed);
    CellOutcome { violations: rep.violations, digest: rep.digest, usd: rep.total_usd }
}

/// Fuzzer knobs (the CLI surface).
#[derive(Debug, Clone)]
pub struct FuzzOpts {
    pub cases: usize,
    pub seed: u64,
    /// Worker threads; 0 = one per available core.
    pub parallelism: usize,
    /// Probe budget for shrinking each failure.
    pub max_shrink_iters: usize,
}

impl Default for FuzzOpts {
    fn default() -> Self {
        FuzzOpts { cases: 32, seed: 1, parallelism: 0, max_shrink_iters: 240 }
    }
}

/// One invariant violation found by the fuzzer, minimized.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    pub case_index: usize,
    pub original: FuzzCell,
    pub shrunk: FuzzCell,
    /// Violations of the *shrunk* cell (what the repro reproduces).
    pub violations: Vec<String>,
    pub shrink_steps: usize,
}

/// A fuzz run's outcome: per-case digests (for replay/worker-invariance
/// pins) and the minimized failures.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    pub seed: u64,
    pub cases: usize,
    pub workers: usize,
    pub case_digests: Vec<u64>,
    /// Per-case run cost (USD, machine + transfer) in case order.
    pub case_usd: Vec<f64>,
    pub failures: Vec<FuzzFailure>,
    pub wall_ms: u64,
}

impl FuzzReport {
    pub fn all_pass(&self) -> bool {
        self.failures.is_empty()
    }

    /// Human-readable summary; failing cells include their repro TOML so
    /// the finding is actionable straight from the terminal.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        writeln!(
            out,
            "Fuzz seed {} — {} cases on {} workers: {} failing ({} ms)",
            self.seed,
            self.cases,
            self.workers,
            self.failures.len(),
            self.wall_ms
        )
        .unwrap();
        for f in &self.failures {
            writeln!(
                out,
                "! case #{}: {} event(s) shrunk to {} in {} probes (scenario {:?}, seed {})",
                f.case_index,
                f.original.spec.events.len(),
                f.shrunk.spec.events.len(),
                f.shrink_steps,
                f.shrunk.spec.name,
                f.shrunk.seed
            )
            .unwrap();
            for v in &f.violations {
                writeln!(out, "    {v}").unwrap();
            }
            writeln!(out, "  repro (campaign --spec):").unwrap();
            for line in repro_toml(&f.shrunk).lines() {
                writeln!(out, "    {line}").unwrap();
            }
        }
        out
    }

    /// JSON export (in-repo writer; see [`verify_report_json`]). The
    /// `repro_toml` field embeds full TOML documents — quotes, newlines
    /// and all — so the round-trip exercises the JSON escaping paths.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"tool\": \"houtu-fuzz\",\n");
        out.push_str(&format!("  \"seed\": {},\n", self.seed));
        out.push_str(&format!("  \"cases\": {},\n", self.cases));
        out.push_str(&format!("  \"workers\": {},\n", self.workers));
        out.push_str(&format!("  \"wall_ms\": {},\n", self.wall_ms));
        let digests: Vec<String> =
            self.case_digests.iter().map(|d| format!("\"{d:016x}\"")).collect();
        out.push_str(&format!("  \"case_digests\": [{}],\n", digests.join(", ")));
        let usds: Vec<String> = self
            .case_usd
            .iter()
            .map(|u| if u.is_finite() { format!("{u}") } else { "null".to_string() })
            .collect();
        out.push_str(&format!("  \"case_usd\": [{}],\n", usds.join(", ")));
        out.push_str("  \"failures\": [\n");
        for (i, f) in self.failures.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"case\": {}, ", f.case_index));
            out.push_str(&format!("\"seed\": {}, ", f.shrunk.seed));
            out.push_str(&format!("\"shrink_steps\": {}, ", f.shrink_steps));
            let evs: Vec<String> =
                f.shrunk.spec.events.iter().map(|e| json::escape(&e.to_string())).collect();
            out.push_str(&format!("\"shrunk_events\": [{}], ", evs.join(", ")));
            let viol: Vec<String> = f.violations.iter().map(|v| json::escape(v)).collect();
            out.push_str(&format!("\"violations\": [{}], ", viol.join(", ")));
            out.push_str(&format!("\"repro_toml\": {}", json::escape(&repro_toml(&f.shrunk))));
            out.push_str(if i + 1 == self.failures.len() { "}\n" } else { "},\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Write the fuzz report as JSON (`houtu fuzz --report out.json`) and
/// assert the file parses back to the same content — the same
/// write-then-verify contract as the campaign report export.
pub fn write_report(report: &FuzzReport, path: &str) -> Result<()> {
    ensure!(path.ends_with(".json"), "fuzz report path {path:?} must end in .json");
    let text = report.to_json();
    std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
    let back = std::fs::read_to_string(path).with_context(|| format!("re-reading {path}"))?;
    verify_report_json(report, &back)
}

/// Verify a serialized fuzz report parses back to the same content —
/// seed, digests, and each failure's violations and byte-exact repro
/// TOML. Exercises the `util::json` escape/parse paths on real payloads.
pub fn verify_report_json(report: &FuzzReport, text: &str) -> Result<()> {
    let doc = json::parse(text).map_err(|e| anyhow!("fuzz report is not valid JSON: {e}"))?;
    ensure!(
        doc.get("seed").and_then(Json::as_u64) == Some(report.seed),
        "seed did not round-trip"
    );
    ensure!(
        doc.get("cases").and_then(Json::as_u64) == Some(report.cases as u64),
        "case count did not round-trip"
    );
    let digests = doc.get("case_digests").and_then(Json::as_array).context("digests missing")?;
    ensure!(digests.len() == report.case_digests.len(), "digest count did not round-trip");
    for (got, want) in digests.iter().zip(&report.case_digests) {
        let s = got.as_str().context("digest must be a string")?;
        ensure!(
            u64::from_str_radix(s, 16).ok() == Some(*want),
            "digest {s} did not round-trip"
        );
    }
    let usds = doc.get("case_usd").and_then(Json::as_array).context("case_usd missing")?;
    ensure!(usds.len() == report.case_usd.len(), "cost column did not round-trip");
    for (got, want) in usds.iter().zip(&report.case_usd) {
        let x = got.as_f64().context("case_usd entries must be numeric")?;
        ensure!(x.to_bits() == want.to_bits(), "case_usd {x} did not round-trip");
    }
    let failures = doc.get("failures").and_then(Json::as_array).context("failures missing")?;
    ensure!(failures.len() == report.failures.len(), "failure count did not round-trip");
    for (got, want) in failures.iter().zip(&report.failures) {
        ensure!(
            got.get("case").and_then(Json::as_u64) == Some(want.case_index as u64),
            "failure case index did not round-trip"
        );
        let viol = got.get("violations").and_then(Json::as_array).context("violations missing")?;
        ensure!(viol.len() == want.violations.len(), "violation count did not round-trip");
        for (gv, wv) in viol.iter().zip(&want.violations) {
            ensure!(gv.as_str() == Some(wv.as_str()), "violation text did not round-trip");
        }
        let toml_text =
            got.get("repro_toml").and_then(Json::as_str).context("repro_toml missing")?;
        ensure!(
            toml_text == repro_toml(&want.shrunk),
            "repro TOML did not round-trip byte-exactly"
        );
    }
    Ok(())
}

/// Spec-parser tokens for workload kinds ([`WorkloadKind::name`] returns
/// display names like "TPC-H", which `from_keys` does not accept).
fn kind_token(k: WorkloadKind) -> &'static str {
    match k {
        WorkloadKind::WordCount => "wordcount",
        WorkloadKind::TpcH => "tpch",
        WorkloadKind::IterativeMl => "ml",
        WorkloadKind::PageRank => "pagerank",
    }
}

/// Render a cell as a campaign TOML that `houtu campaign --spec` loads:
/// the repro artifact. [`write_repro`] asserts the round-trip.
pub fn repro_toml(cell: &FuzzCell) -> String {
    use std::fmt::Write as _;
    let s = &cell.spec;
    let mut out = String::new();
    writeln!(out, "# houtu fuzz repro — run with: houtu campaign --spec <this file>").unwrap();
    writeln!(out, "[campaign]").unwrap();
    writeln!(out, "name = \"fuzz-repro\"").unwrap();
    writeln!(out, "seeds = [{}]", cell.seed).unwrap();
    writeln!(out).unwrap();
    writeln!(out, "[scenario.{}]", s.name).unwrap();
    writeln!(out, "deployment = \"{}\"", s.deployment.name()).unwrap();
    match s.workload {
        ScenarioWorkload::SingleJob { kind, size, home } => {
            writeln!(out, "workload = \"{}\"", kind_token(kind)).unwrap();
            writeln!(out, "size = \"{}\"", size.name()).unwrap();
            writeln!(out, "home = {}", home.0).unwrap();
        }
        ScenarioWorkload::Trace { num_jobs } => {
            writeln!(out, "workload = \"trace\"").unwrap();
            writeln!(out, "num_jobs = {num_jobs}").unwrap();
        }
    }
    if s.regions > 0 {
        writeln!(out, "regions = {}", s.regions).unwrap();
    }
    if !s.events.is_empty() {
        let evs: Vec<String> = s.events.iter().map(|e| format!("\"{e}\"")).collect();
        writeln!(out, "events = [{}]", evs.join(", ")).unwrap();
    }
    if !s.overrides.is_empty() {
        let ovs: Vec<String> = s.overrides.iter().map(|o| format!("\"{o}\"")).collect();
        writeln!(out, "overrides = [{}]", ovs.join(", ")).unwrap();
    }
    out
}

/// Write a repro TOML and assert it round-trips: parsing the file back
/// through [`CampaignSpec`] must reproduce the cell bit-exactly (same
/// scenario, same seed), so the artifact is guaranteed loadable.
pub fn write_repro(cell: &FuzzCell, path: &str) -> Result<()> {
    let text = repro_toml(cell);
    std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
    let back = CampaignSpec::from_file(path)?;
    ensure!(back.seeds == vec![cell.seed], "repro seed did not round-trip");
    ensure!(
        back.scenarios.len() == 1 && back.scenarios[0] == cell.spec,
        "repro TOML did not round-trip the scenario spec"
    );
    Ok(())
}

/// Run the fuzzer with a custom oracle (tests); see [`run_fuzz`].
pub fn run_fuzz_with(
    base: &Config,
    space: &FuzzSpace,
    opts: &FuzzOpts,
    oracle: Oracle,
) -> FuzzReport {
    let t0 = Instant::now();
    let gen = CellGen::new(space, base);
    // Cells come from the fuzz seed alone, before any execution, so the
    // sampled adversaries are identical for any worker count.
    let mut rng = Pcg::new(opts.seed, 0xf0_22);
    let cells: Vec<FuzzCell> = (0..opts.cases).map(|_| gen.generate(&mut rng)).collect();
    let n = cells.len();
    let workers = super::runner::resolve_workers(opts.parallelism, n);
    let outcomes: Vec<CellOutcome> = super::runner::par_map(workers, n, |i| {
        let cell = &cells[i];
        oracle(base, &cell.spec, cell.seed)
    });

    // Shrink failures sequentially in case order: deterministic, and the
    // probes reuse the same oracle. Invalid shrink candidates (events that
    // no longer fit a shrunk topology) count as passing, so they are never
    // kept.
    let prop = |cell: &FuzzCell| -> std::result::Result<(), String> {
        if cell.spec.build_config(base, cell.seed).is_err() {
            return Ok(());
        }
        let out = oracle(base, &cell.spec, cell.seed);
        if out.violations.is_empty() {
            Ok(())
        } else {
            Err(out.violations.join("; "))
        }
    };
    let mut failures = Vec::new();
    for (i, (cell, outcome)) in cells.iter().zip(&outcomes).enumerate() {
        if outcome.violations.is_empty() {
            continue;
        }
        let (shrunk, _msg, steps) = shrink_failure(
            &gen,
            cell.clone(),
            outcome.violations.join("; "),
            opts.max_shrink_iters,
            &prop,
        );
        // Re-query the oracle for the shrunk cell's violation *list*:
        // recovering it from the joined shrink message would corrupt any
        // violation whose text itself contains the separator (panic
        // payloads routinely do). The oracle is deterministic, so this
        // reproduces exactly what the repro will show.
        let violations = oracle(base, &shrunk.spec, shrunk.seed).violations;
        failures.push(FuzzFailure {
            case_index: i,
            original: cell.clone(),
            shrunk,
            violations,
            shrink_steps: steps,
        });
    }
    FuzzReport {
        seed: opts.seed,
        cases: n,
        workers,
        case_digests: outcomes.iter().map(|o| o.digest).collect(),
        case_usd: outcomes.iter().map(|o| o.usd).collect(),
        failures,
        wall_ms: t0.elapsed().as_millis() as u64,
    }
}

/// Sample `opts.cases` cells from the space, run each through the full
/// invariant stack in parallel, and shrink every violation to a minimal
/// repro. Deterministic for a given (space, opts, tree).
pub fn run_fuzz(base: &Config, space: &FuzzSpace, opts: &FuzzOpts) -> FuzzReport {
    run_fuzz_with(base, space, opts, &sim_oracle)
}

/// Soak mode: keep running fresh `opts.cases`-sized batches (each with a
/// distinct derived seed) until `minutes` of wall clock elapse or a
/// failure is found. At least one batch always runs; the returned report
/// accumulates every batch's digests and failures, with `seed` left at
/// the base seed.
pub fn run_soak(base: &Config, space: &FuzzSpace, opts: &FuzzOpts, minutes: f64) -> FuzzReport {
    let t0 = Instant::now();
    // Clamp to a year so an absurd --soak value saturates instead of
    // overflowing Duration::from_secs_f64 (which panics).
    let budget_secs = (minutes.max(0.0) * 60.0).min(365.0 * 86_400.0);
    let deadline = t0 + Duration::from_secs_f64(budget_secs);
    let mut total: Option<FuzzReport> = None;
    let mut batch: u64 = 0;
    loop {
        let batch_opts = FuzzOpts {
            seed: opts.seed.wrapping_add(batch.wrapping_mul(0x9e37_79b9_7f4a_7c15)),
            ..opts.clone()
        };
        let rep = run_fuzz(base, space, &batch_opts);
        total = Some(match total.take() {
            None => rep,
            Some(mut acc) => {
                acc.cases += rep.cases;
                acc.case_digests.extend(rep.case_digests);
                acc.case_usd.extend(rep.case_usd);
                let offset = acc.cases - rep.cases;
                acc.failures.extend(rep.failures.into_iter().map(|mut f| {
                    f.case_index += offset;
                    f
                }));
                acc
            }
        });
        batch += 1;
        let acc = total.as_ref().unwrap();
        if !acc.failures.is_empty() || Instant::now() >= deadline {
            break;
        }
    }
    let mut rep = total.expect("soak ran at least one batch");
    rep.seed = opts.seed;
    rep.wall_ms = t0.elapsed().as_millis() as u64;
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> FuzzSpace {
        FuzzSpace::default()
    }

    #[test]
    fn generation_is_deterministic_and_valid() {
        let base = Config::default();
        let sp = space();
        let gen = CellGen::new(&sp, &base);
        let cells = |seed: u64| -> Vec<FuzzCell> {
            let mut rng = Pcg::new(seed, 0xf0_22);
            (0..40).map(|_| gen.generate(&mut rng)).collect()
        };
        let a = cells(7);
        let b = cells(7);
        assert_eq!(a, b, "same fuzz seed must sample the same cells");
        let c = cells(8);
        assert_ne!(a, c, "different fuzz seeds must sample different cells");
        for cell in &a {
            cell.spec
                .build_config(&base, cell.seed)
                .unwrap_or_else(|e| panic!("generated invalid cell {:?}: {e}", cell.spec));
            assert!(cell.spec.events.len() <= space().max_events);
        }
        // The space actually covers the three new families somewhere in a
        // modest sample.
        let all: Vec<&ChaosEvent> = a.iter().flat_map(|c| c.spec.events.iter()).collect();
        assert!(
            all.iter().any(|e| matches!(e, ChaosEvent::KillDc { .. }))
                || all.iter().any(|e| matches!(e, ChaosEvent::SpotStorm { .. }))
                || a.iter().any(|c| {
                    c.spec.overrides.iter().any(|o| o.starts_with("workload.straggler_prob"))
                }),
            "sample never drew a new chaos family"
        );
    }

    #[test]
    fn shrink_candidates_are_strictly_simpler() {
        let base = Config::default();
        let sp = space();
        let gen = CellGen::new(&sp, &base);
        let mut rng = Pcg::new(3, 0xf0_22);
        let measure = |c: &FuzzCell| -> f64 {
            let ev_cost: f64 = c
                .spec
                .events
                .iter()
                .map(|e| match e {
                    ChaosEvent::KillDc { at_secs, dc } => 20.0 + at_secs + 0.1 * dc.0 as f64,
                    ChaosEvent::KillJmCascade { at_secs, dc, count, gap_secs } => {
                        10.0 + *count as f64 * 4.0 + at_secs + gap_secs + 0.1 * dc.0 as f64
                    }
                    ChaosEvent::InjectHogs { at_secs, dcs } => {
                        let dc_sum: usize = dcs.iter().map(|d| d.0).sum();
                        10.0 + dcs.len() as f64 + at_secs + 0.1 * dc_sum as f64
                    }
                    ChaosEvent::KillJm { at_secs, dc } => 8.0 + at_secs + 0.1 * dc.0 as f64,
                    ChaosEvent::KillNode { at_secs, node } => {
                        6.0 + node.idx as f64 + at_secs + 0.1 * node.dc.0 as f64
                    }
                    ChaosEvent::WanDegrade { from_secs, until_secs, factor } => {
                        6.0 + from_secs + (until_secs - from_secs) + (1.0 - factor) * 10.0
                    }
                    ChaosEvent::WanPairDegrade { at_secs, a, b, factor } => {
                        6.0 + at_secs + (1.0 - factor) * 10.0 + 0.1 * (a.0 + b.0) as f64
                    }
                    ChaosEvent::SpotStorm { at_secs, dc, dur_secs, sigma_factor } => {
                        6.0 + at_secs + dur_secs + sigma_factor * 2.0 + 0.1 * dc.0 as f64
                    }
                })
                .sum();
            let wl_cost = match c.spec.workload {
                ScenarioWorkload::Trace { num_jobs } => 10.0 + num_jobs as f64,
                ScenarioWorkload::SingleJob { size, home, .. } => {
                    home.0 as f64
                        + match size {
                            SizeClass::Small => 0.0,
                            SizeClass::Medium => 2.0,
                            SizeClass::Large => 4.0,
                        }
                }
            };
            let topo_cost: f64 = c
                .spec
                .overrides
                .iter()
                .filter_map(|o| {
                    o.strip_prefix("topology.generated=")
                        .and_then(|r| crate::topo::parse_spec(r).ok())
                        .map(|ts| (ts.dcs * 10 + ts.nodes_per_dc) as f64)
                })
                .sum();
            ev_cost * 1000.0
                + topo_cost * 50.0
                + c.spec.overrides.len() as f64 * 100.0
                + wl_cost
                + c.spec.regions as f64
                + (c.spec.deployment != Deployment::Houtu) as u8 as f64
                + (c.seed as f64) / 1e9
        };
        for _ in 0..60 {
            let cell = gen.generate(&mut rng);
            let m = measure(&cell);
            for cand in gen.shrink(&cell) {
                assert!(
                    measure(&cand) < m,
                    "candidate not simpler:\n  from {:?}\n  to   {:?}",
                    cell,
                    cand
                );
            }
        }
    }

    #[test]
    fn repro_toml_round_trips_generated_cells() {
        let base = Config::default();
        let sp = space();
        let gen = CellGen::new(&sp, &base);
        let mut rng = Pcg::new(11, 0xf0_22);
        for _ in 0..60 {
            let cell = gen.generate(&mut rng);
            let text = repro_toml(&cell);
            let doc = crate::config::toml::parse(&text)
                .unwrap_or_else(|e| panic!("repro not parseable: {e}\n{text}"));
            let spec = CampaignSpec::from_doc(&doc).unwrap();
            assert_eq!(spec.seeds, vec![cell.seed], "{text}");
            assert_eq!(spec.scenarios.len(), 1, "{text}");
            assert_eq!(spec.scenarios[0], cell.spec, "{text}");
        }
    }

    #[test]
    fn synthetic_failures_shrink_to_a_single_event() {
        let base = Config::default();
        // Synthetic oracle: every cell with at least one event fails —
        // so the minimal counterexample is exactly one event.
        let oracle = |_b: &Config, s: &ScenarioSpec, _seed: u64| CellOutcome {
            violations: if s.events.is_empty() {
                vec![]
            } else {
                vec!["synthetic: chaos observed".to_string()]
            },
            digest: s.events.len() as u64,
            usd: 0.0,
        };
        let opts = FuzzOpts { cases: 24, seed: 5, parallelism: 2, max_shrink_iters: 200 };
        let rep = run_fuzz_with(&base, &space(), &opts, &oracle);
        assert_eq!(rep.cases, 24);
        assert_eq!(rep.case_digests.len(), 24);
        assert!(!rep.failures.is_empty(), "the sample should contain chaotic cells");
        for f in &rep.failures {
            assert_eq!(
                f.shrunk.spec.events.len(),
                1,
                "not minimal: {:?} (from {:?})",
                f.shrunk.spec.events,
                f.original.spec.events
            );
            assert!(f.shrunk.seed == 1, "seed not shrunk: {}", f.shrunk.seed);
        }
    }
}
