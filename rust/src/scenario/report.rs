//! Campaign report export: serialize a [`CampaignReport`] — per-run
//! metrics, trace-folded digests and violations — as JSON or CSV via the
//! in-repo writers (the crate stays dependency-free), plus the
//! round-trip validation `houtu campaign --report` and `ci.sh` rely on.
//!
//! The JSON shape is the trace-derived summary: one object per
//! (scenario, seed) run with its figure-level metrics and its 16-hex
//! `digest` string (digests are u64s, which JSON numbers cannot carry
//! losslessly). CSV has one row per run with the same columns;
//! violations are `;`-joined inside one quoted cell.

use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::{anyhow, ensure};

use super::runner::{CampaignReport, RunReport};

/// Columns shared by the CSV header and the JSON run objects.
const COLUMNS: [&str; 18] = [
    "scenario",
    "seed",
    "deployment",
    "completed_jobs",
    "total_jobs",
    "avg_jrt_secs",
    "makespan_secs",
    "events_processed",
    "tasks_stolen",
    "recoveries",
    "elections",
    "restarts",
    "cross_dc_bytes",
    "machine_usd",
    "total_usd",
    "job_usd",
    "digest",
    "violations",
];

fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

impl CampaignReport {
    /// The report as a JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"campaign\": {},\n", json::escape(&self.name)));
        out.push_str(&format!("  \"workers\": {},\n", self.workers));
        out.push_str(&format!("  \"campaign_digest\": \"{:016x}\",\n", self.campaign_digest));
        out.push_str(&format!("  \"total_violations\": {},\n", self.total_violations()));
        out.push_str("  \"runs\": [\n");
        for (i, r) in self.runs.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"scenario\": {}, ", json::escape(&r.scenario)));
            out.push_str(&format!("\"seed\": {}, ", r.seed));
            out.push_str(&format!("\"deployment\": {}, ", json::escape(r.deployment)));
            out.push_str(&format!("\"completed_jobs\": {}, ", r.completed_jobs));
            out.push_str(&format!("\"total_jobs\": {}, ", r.total_jobs));
            out.push_str(&format!("\"avg_jrt_secs\": {}, ", json_f64(r.avg_jrt_secs)));
            out.push_str(&format!("\"makespan_secs\": {}, ", json_f64(r.makespan_secs)));
            out.push_str(&format!("\"events_processed\": {}, ", r.events_processed));
            out.push_str(&format!("\"tasks_stolen\": {}, ", r.tasks_stolen));
            out.push_str(&format!("\"recoveries\": {}, ", r.recoveries));
            out.push_str(&format!("\"elections\": {}, ", r.elections));
            out.push_str(&format!("\"restarts\": {}, ", r.restarts));
            out.push_str(&format!("\"cross_dc_bytes\": {}, ", r.cross_dc_bytes));
            out.push_str(&format!("\"machine_usd\": {}, ", json_f64(r.machine_usd)));
            out.push_str(&format!("\"total_usd\": {}, ", json_f64(r.total_usd)));
            out.push_str(&format!("\"job_usd\": {}, ", json_f64(r.job_usd)));
            out.push_str(&format!("\"digest\": \"{:016x}\", ", r.digest));
            out.push_str(&format!("\"wall_ms\": {}, ", r.wall_ms));
            let viol: Vec<String> = r.violations.iter().map(|v| json::escape(v)).collect();
            out.push_str(&format!("\"violations\": [{}]", viol.join(", ")));
            out.push_str(if i + 1 == self.runs.len() { "}\n" } else { "},\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// The report as CSV (header + one row per run).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&COLUMNS.join(","));
        out.push('\n');
        for r in &self.runs {
            let viol = r.violations.join("; ");
            out.push_str(&format!(
                "{},{},{},{},{},{:.3},{:.3},{},{},{},{},{},{},{:.4},{:.4},{:.4},{:016x},{}\n",
                csv_cell(&r.scenario),
                r.seed,
                csv_cell(r.deployment),
                r.completed_jobs,
                r.total_jobs,
                r.avg_jrt_secs,
                r.makespan_secs,
                r.events_processed,
                r.tasks_stolen,
                r.recoveries,
                r.elections,
                r.restarts,
                r.cross_dc_bytes,
                r.machine_usd,
                r.total_usd,
                r.job_usd,
                r.digest,
                csv_cell(&viol)
            ));
        }
        out
    }
}

/// Quote a CSV cell when it needs it (commas, quotes, newlines).
fn csv_cell(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Which format a path's extension selects.
fn format_of(path: &str) -> Result<&'static str> {
    if path.ends_with(".json") {
        Ok("json")
    } else if path.ends_with(".csv") {
        Ok("csv")
    } else {
        Err(anyhow!("report path {path:?} must end in .json or .csv"))
    }
}

/// Write the report to `path` (format by extension), then read the file
/// back and verify it round-trips — run count, per-run digests and the
/// campaign digest must survive serialization. Returns the format name.
pub fn write_and_verify(report: &CampaignReport, path: &str) -> Result<&'static str> {
    let format = format_of(path)?;
    let text = match format {
        "json" => report.to_json(),
        _ => report.to_csv(),
    };
    std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
    let back = std::fs::read_to_string(path).with_context(|| format!("re-reading {path}"))?;
    match format {
        "json" => verify_json(report, &back),
        _ => verify_csv(report, &back),
    }?;
    Ok(format)
}

fn verify_json(report: &CampaignReport, text: &str) -> Result<()> {
    let doc = json::parse(text).map_err(|e| anyhow!("report is not valid JSON: {e}"))?;
    ensure!(
        doc.get("campaign").and_then(Json::as_str) == Some(report.name.as_str()),
        "campaign name did not round-trip"
    );
    let digest = doc
        .get("campaign_digest")
        .and_then(Json::as_str)
        .context("campaign_digest missing")?;
    ensure!(
        u64::from_str_radix(digest, 16).ok() == Some(report.campaign_digest),
        "campaign digest did not round-trip"
    );
    let runs = doc.get("runs").and_then(Json::as_array).context("runs missing")?;
    ensure!(
        runs.len() == report.runs.len(),
        "run count did not round-trip: {} vs {}",
        runs.len(),
        report.runs.len()
    );
    for (got, want) in runs.iter().zip(&report.runs) {
        check_run(got, want)?;
    }
    Ok(())
}

fn check_run(got: &Json, want: &RunReport) -> Result<()> {
    let ctx = format!("{}/seed{}", want.scenario, want.seed);
    ensure!(
        got.get("scenario").and_then(Json::as_str) == Some(want.scenario.as_str()),
        "{ctx}: scenario did not round-trip"
    );
    // Seeds are emitted as raw JSON numbers; above 2^53 the parser's f64
    // can only carry the nearest representable value, so compare in f64
    // space (the writer's exact decimal parses to `seed as f64`).
    ensure!(
        got.get("seed").and_then(Json::as_f64) == Some(want.seed as f64),
        "{ctx}: seed did not round-trip"
    );
    let digest = got.get("digest").and_then(Json::as_str).context("digest missing")?;
    ensure!(
        u64::from_str_radix(digest, 16).ok() == Some(want.digest),
        "{ctx}: digest did not round-trip"
    );
    // Non-finite costs serialize as null and are a run bug anyway (the
    // cost-sanity invariant flags them); the verifier requires a finite,
    // bit-identical number.
    let usd = got.get("total_usd").and_then(Json::as_f64).context("total_usd missing")?;
    ensure!(
        usd.to_bits() == want.total_usd.to_bits(),
        "{ctx}: total_usd did not round-trip"
    );
    let job_usd = got.get("job_usd").and_then(Json::as_f64).context("job_usd missing")?;
    ensure!(
        job_usd.to_bits() == want.job_usd.to_bits(),
        "{ctx}: job_usd did not round-trip"
    );
    let viol = got.get("violations").and_then(Json::as_array).context("violations missing")?;
    ensure!(
        viol.len() == want.violations.len(),
        "{ctx}: violation count did not round-trip"
    );
    Ok(())
}

fn verify_csv(report: &CampaignReport, text: &str) -> Result<()> {
    let mut lines = text.lines();
    let header = lines.next().context("empty CSV report")?;
    ensure!(header == COLUMNS.join(","), "CSV header mismatch: {header:?}");
    // Quoted cells never contain newlines (violations are ';'-joined on
    // one line), so line count is row count.
    let rows: Vec<&str> = lines.filter(|l| !l.is_empty()).collect();
    ensure!(
        rows.len() == report.runs.len(),
        "CSV row count {} != {} runs",
        rows.len(),
        report.runs.len()
    );
    for (row, want) in rows.iter().zip(&report.runs) {
        let digest = format!("{:016x}", want.digest);
        ensure!(
            row.contains(&digest),
            "{}/seed{}: digest missing from CSV row",
            want.scenario,
            want.seed
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> CampaignReport {
        let run = |scenario: &str, seed, digest, violations: Vec<String>| RunReport {
            scenario: scenario.to_string(),
            seed,
            deployment: "houtu",
            completed_jobs: 1,
            total_jobs: 1,
            avg_jrt_secs: 123.456,
            makespan_secs: 130.0,
            events_processed: 999,
            tasks_stolen: 3,
            recoveries: 1,
            elections: 0,
            restarts: 0,
            cross_dc_bytes: 1 << 30,
            machine_usd: 12.34,
            total_usd: 13.64,
            job_usd: 11.02,
            digest,
            violations,
            wall_ms: 42,
        };
        CampaignReport {
            name: "unit".to_string(),
            workers: 2,
            runs: vec![
                run("clean", 42, 0xdead_beef_0000_0001, vec![]),
                run(
                    "dirty, with \"quotes\"",
                    7,
                    0x0000_0000_0000_00ff,
                    vec!["exactly-once: j0: 3/4 tasks Done".to_string()],
                ),
            ],
            campaign_digest: 0x1234_5678_9abc_def0,
        }
    }

    #[test]
    fn json_round_trips() {
        let rep = report();
        let text = rep.to_json();
        verify_json(&rep, &text).unwrap();
        // Spot-check the parsed shape, not just our own validator.
        let doc = json::parse(&text).unwrap();
        let runs = doc.get("runs").and_then(Json::as_array).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].get("digest").and_then(Json::as_str), Some("deadbeef00000001"));
        assert_eq!(
            runs[1].get("scenario").and_then(Json::as_str),
            Some("dirty, with \"quotes\"")
        );
        assert_eq!(
            runs[1].get("violations").and_then(Json::as_array).map(<[Json]>::len),
            Some(1)
        );
        assert_eq!(doc.get("total_violations").and_then(Json::as_u64), Some(1));
    }

    #[test]
    fn csv_round_trips() {
        let rep = report();
        let text = rep.to_csv();
        verify_csv(&rep, &text).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 rows");
        assert!(lines[0].starts_with("scenario,seed,"));
        assert!(lines[1].contains("deadbeef00000001"));
        assert!(lines[2].starts_with("\"dirty, with \"\"quotes\"\"\","), "{}", lines[2]);
    }

    #[test]
    fn mismatched_report_fails_verification() {
        let rep = report();
        let mut other = report();
        other.runs[0].digest ^= 1;
        assert!(verify_json(&other, &rep.to_json()).is_err());
        other.campaign_digest ^= 1;
        assert!(verify_json(&other, &rep.to_json()).is_err());
    }

    #[test]
    fn format_comes_from_the_extension() {
        assert_eq!(format_of("a/b.json").unwrap(), "json");
        assert_eq!(format_of("out.csv").unwrap(), "csv");
        assert!(format_of("report.txt").is_err());
    }
}
