//! Zookeeper-like coordination substrate (§5 "How the job managers
//! coordinate").
//!
//! One replica per data center forms an ensemble with a single write
//! leader and quorum-acknowledged updates. The znode tree supports
//! persistent, ephemeral and sequential nodes, data/children watches and
//! session expiry — enough to host the paper's intermediate-information
//! replication (taskMap, partitionList, executorList) and the pJM leader
//! election via ephemeral-sequential election nodes.
//!
//! Latency model: a write from DC `d` pays client→leader, a quorum round
//! from the leader (median ack among followers), and the reply — computed
//! against the live WAN fabric, so consensus slows down exactly when the
//! paper says it should. Reads are served by the local replica
//! (Zookeeper's sequential-consistency contract).

use std::collections::{BTreeMap, HashMap};

use crate::ids::DcId;
use crate::net::Wan;
use crate::sim::SimTime;

pub type SessionId = u64;

/// Watch kinds, Zookeeper-style.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WatchKind {
    Data,
    Children,
}

/// A fired watch, to be delivered to `session`'s owner by the caller.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Notification {
    pub session: SessionId,
    pub path: String,
    pub kind: WatchKind,
}

#[derive(Debug, Clone, Default)]
pub struct Znode {
    pub data: Vec<u8>,
    pub version: u64,
    pub ephemeral_owner: Option<SessionId>,
    seq_counter: u64,
}

#[derive(Debug)]
struct Session {
    dc: DcId,
    alive: bool,
    ephemerals: Vec<String>,
}

#[derive(Debug, Default, Clone)]
pub struct ZkStats {
    pub writes: u64,
    pub reads: u64,
    pub bytes_written: u64,
    pub watches_fired: u64,
    pub elections: u64,
}

/// The ensemble (logical state is a single authoritative tree; replication
/// is modeled through the latency/traffic functions and failure hooks).
pub struct ZkEnsemble {
    pub leader: DcId,
    num_dcs: usize,
    tree: BTreeMap<String, Znode>,
    sessions: HashMap<SessionId, Session>,
    next_session: SessionId,
    watches: HashMap<(String, WatchKind), Vec<SessionId>>,
    pub stats: ZkStats,
}

impl ZkEnsemble {
    pub fn new(num_dcs: usize) -> Self {
        ZkEnsemble {
            leader: DcId(0),
            num_dcs,
            tree: BTreeMap::new(),
            sessions: HashMap::new(),
            next_session: 1,
            watches: HashMap::new(),
            stats: ZkStats::default(),
        }
    }

    /// Open a client session homed in `dc`.
    pub fn connect(&mut self, dc: DcId) -> SessionId {
        let sid = self.next_session;
        self.next_session += 1;
        self.sessions.insert(sid, Session { dc, alive: true, ephemerals: Vec::new() });
        sid
    }

    pub fn session_alive(&self, sid: SessionId) -> bool {
        self.sessions.get(&sid).map(|s| s.alive).unwrap_or(false)
    }

    /// Quorum-write latency for a client in `from`, including the fired
    /// control-plane traffic (`bytes` of payload).
    pub fn write_latency(&self, wan: &mut Wan, from: DcId, bytes: u64) -> SimTime {
        let to_leader = wan.message_delay(from, self.leader, bytes + 64);
        // Leader replicates to followers; commit at median ack (quorum).
        let mut acks: Vec<SimTime> = (0..self.num_dcs)
            .map(DcId)
            .filter(|&d| d != self.leader)
            .map(|d| {
                let go = wan.message_delay(self.leader, d, bytes + 64);
                let back = wan.message_delay(d, self.leader, 64);
                go + back
            })
            .collect();
        acks.sort_unstable();
        let quorum = self.num_dcs / 2; // leader + this many followers
        let quorum_delay = if acks.is_empty() {
            0
        } else {
            acks[quorum.saturating_sub(1).min(acks.len() - 1)]
        };
        let reply = wan.message_delay(self.leader, from, 64);
        to_leader + quorum_delay + reply
    }

    /// Local-replica read latency.
    pub fn read_latency(&self, wan: &mut Wan, from: DcId, bytes: u64) -> SimTime {
        wan.message_delay(from, from, bytes)
    }

    fn fire(&mut self, path: &str, kind: WatchKind, out: &mut Vec<Notification>) {
        if let Some(sids) = self.watches.remove(&(path.to_string(), kind)) {
            for session in sids {
                if self.session_alive(session) {
                    self.stats.watches_fired += 1;
                    out.push(Notification { session, path: path.to_string(), kind });
                }
            }
        }
    }

    fn parent_of(path: &str) -> Option<String> {
        path.rfind('/').map(|i| if i == 0 { "/".to_string() } else { path[..i].to_string() })
    }

    /// Create a znode. `sequential` appends a zero-padded monotone counter
    /// scoped to the parent. Returns the actual path and any fired watches.
    pub fn create(
        &mut self,
        session: SessionId,
        path: &str,
        data: Vec<u8>,
        ephemeral: bool,
        sequential: bool,
    ) -> Result<(String, Vec<Notification>), String> {
        if !self.session_alive(session) {
            return Err(format!("session {session} expired"));
        }
        let actual = if sequential {
            let parent = Self::parent_of(path).unwrap_or_else(|| "/".into());
            let counter = {
                let pz = self.tree.entry(parent).or_default();
                let c = pz.seq_counter;
                pz.seq_counter += 1;
                c
            };
            format!("{path}{counter:010}")
        } else {
            path.to_string()
        };
        if self.tree.contains_key(&actual) && !sequential {
            return Err(format!("node exists: {actual}"));
        }
        self.stats.writes += 1;
        self.stats.bytes_written += data.len() as u64;
        let owner = if ephemeral { Some(session) } else { None };
        self.tree.insert(
            actual.clone(),
            Znode { data, version: 0, ephemeral_owner: owner, seq_counter: 0 },
        );
        if ephemeral {
            self.sessions.get_mut(&session).unwrap().ephemerals.push(actual.clone());
        }
        let mut fired = Vec::new();
        if let Some(parent) = Self::parent_of(&actual) {
            self.fire(&parent, WatchKind::Children, &mut fired);
        }
        Ok((actual, fired))
    }

    /// Set a znode's data (version bump). Fires data watches.
    pub fn set_data(&mut self, path: &str, data: Vec<u8>) -> Result<Vec<Notification>, String> {
        self.stats.writes += 1;
        self.stats.bytes_written += data.len() as u64;
        let z = self.tree.get_mut(path).ok_or_else(|| format!("no node {path}"))?;
        z.data = data;
        z.version += 1;
        let mut fired = Vec::new();
        self.fire(path, WatchKind::Data, &mut fired);
        Ok(fired)
    }

    pub fn get(&mut self, path: &str) -> Option<&Znode> {
        self.stats.reads += 1;
        self.tree.get(path)
    }

    pub fn exists(&self, path: &str) -> bool {
        self.tree.contains_key(path)
    }

    /// Delete a znode. Fires data watch on the node and children watch on
    /// the parent.
    pub fn delete(&mut self, path: &str) -> Result<Vec<Notification>, String> {
        let z = self.tree.remove(path).ok_or_else(|| format!("no node {path}"))?;
        if let Some(owner) = z.ephemeral_owner {
            if let Some(s) = self.sessions.get_mut(&owner) {
                s.ephemerals.retain(|p| p != path);
            }
        }
        self.stats.writes += 1;
        let mut fired = Vec::new();
        self.fire(path, WatchKind::Data, &mut fired);
        if let Some(parent) = Self::parent_of(path) {
            self.fire(&parent, WatchKind::Children, &mut fired);
        }
        Ok(fired)
    }

    /// Children of a path (direct descendants), sorted.
    pub fn children(&mut self, path: &str) -> Vec<String> {
        self.stats.reads += 1;
        let prefix = if path == "/" { "/".to_string() } else { format!("{path}/") };
        self.tree
            .range(prefix.clone()..)
            .take_while(|(k, _)| k.starts_with(&prefix))
            .filter(|(k, _)| !k[prefix.len()..].contains('/'))
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Register a one-shot watch.
    pub fn watch(&mut self, session: SessionId, path: &str, kind: WatchKind) {
        self.watches.entry((path.to_string(), kind)).or_default().push(session);
    }

    /// Expire a session: delete its ephemerals, fire their watches. This is
    /// the JM-failure detection primitive — the pJM's election node
    /// disappears and the next candidate's watch fires.
    pub fn expire_session(&mut self, sid: SessionId) -> Vec<Notification> {
        let Some(s) = self.sessions.get_mut(&sid) else {
            return Vec::new();
        };
        if !s.alive {
            return Vec::new();
        }
        s.alive = false;
        let eph = std::mem::take(&mut s.ephemerals);
        let mut fired = Vec::new();
        for path in eph {
            if self.tree.remove(&path).is_some() {
                self.stats.writes += 1;
                self.fire(&path, WatchKind::Data, &mut fired);
                if let Some(parent) = Self::parent_of(&path) {
                    self.fire(&parent, WatchKind::Children, &mut fired);
                }
            }
        }
        fired
    }

    /// Leader-election helper over ephemeral-sequential nodes under
    /// `election_root`: the session owning the smallest sequence number is
    /// the leader. Returns (winner session, its path) if any candidate.
    pub fn election_winner(&mut self, election_root: &str) -> Option<(SessionId, String)> {
        self.stats.elections += 1;
        let kids = self.children(election_root);
        let mut best: Option<(SessionId, String)> = None;
        for k in kids {
            if let Some(z) = self.tree.get(&k) {
                if let Some(owner) = z.ephemeral_owner {
                    if self.session_alive(owner) && (best.is_none() || k < best.as_ref().unwrap().1)
                    {
                        best = Some((owner, k.clone()));
                    }
                }
            }
        }
        best
    }

    /// DC of a session (for latency lookups).
    pub fn session_dc(&self, sid: SessionId) -> Option<DcId> {
        self.sessions.get(&sid).map(|s| s.dc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::util::Pcg;

    fn zk() -> ZkEnsemble {
        ZkEnsemble::new(4)
    }

    #[test]
    fn create_get_set_delete_roundtrip() {
        let mut z = zk();
        let s = z.connect(DcId(0));
        let (p, _) = z.create(s, "/jobs/j1/taskMap", b"v0".to_vec(), false, false).unwrap();
        assert_eq!(p, "/jobs/j1/taskMap");
        assert_eq!(z.get(&p).unwrap().data, b"v0");
        assert_eq!(z.get(&p).unwrap().version, 0);
        z.set_data(&p, b"v1".to_vec()).unwrap();
        assert_eq!(z.get(&p).unwrap().version, 1);
        z.delete(&p).unwrap();
        assert!(!z.exists(&p));
        assert!(z.delete(&p).is_err());
    }

    #[test]
    fn duplicate_create_fails() {
        let mut z = zk();
        let s = z.connect(DcId(0));
        z.create(s, "/a", vec![], false, false).unwrap();
        assert!(z.create(s, "/a", vec![], false, false).is_err());
    }

    #[test]
    fn sequential_nodes_are_monotone() {
        let mut z = zk();
        let s = z.connect(DcId(0));
        let (p1, _) = z.create(s, "/el/n-", vec![], true, true).unwrap();
        let (p2, _) = z.create(s, "/el/n-", vec![], true, true).unwrap();
        let (p3, _) = z.create(s, "/el/n-", vec![], true, true).unwrap();
        assert!(p1 < p2 && p2 < p3, "{p1} {p2} {p3}");
    }

    #[test]
    fn data_watch_fires_once() {
        let mut z = zk();
        let s1 = z.connect(DcId(0));
        let s2 = z.connect(DcId(1));
        z.create(s1, "/x", vec![], false, false).unwrap();
        z.watch(s2, "/x", WatchKind::Data);
        let fired = z.set_data("/x", b"1".to_vec()).unwrap();
        assert_eq!(fired, vec![Notification { session: s2, path: "/x".into(), kind: WatchKind::Data }]);
        // One-shot: second write fires nothing.
        assert!(z.set_data("/x", b"2".to_vec()).unwrap().is_empty());
    }

    #[test]
    fn children_watch_on_create_and_delete() {
        let mut z = zk();
        let s = z.connect(DcId(0));
        z.create(s, "/dir", vec![], false, false).unwrap();
        z.watch(s, "/dir", WatchKind::Children);
        let (_, fired) = z.create(s, "/dir/a", vec![], false, false).unwrap();
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, WatchKind::Children);
        z.watch(s, "/dir", WatchKind::Children);
        let fired = z.delete("/dir/a").unwrap();
        assert!(fired.iter().any(|n| n.kind == WatchKind::Children));
    }

    #[test]
    fn children_lists_direct_descendants_only() {
        let mut z = zk();
        let s = z.connect(DcId(0));
        for p in ["/j/a", "/j/b", "/j/b/nested", "/other"] {
            z.create(s, p, vec![], false, false).unwrap();
        }
        assert_eq!(z.children("/j"), vec!["/j/a".to_string(), "/j/b".to_string()]);
    }

    #[test]
    fn session_expiry_reaps_ephemerals_and_fires_watches() {
        let mut z = zk();
        let s1 = z.connect(DcId(0));
        let s2 = z.connect(DcId(1));
        let (p, _) = z.create(s1, "/el/leader-", vec![], true, true).unwrap();
        z.create(s1, "/persistent", vec![], false, false).unwrap();
        z.watch(s2, &p, WatchKind::Data);
        let fired = z.expire_session(s1);
        assert!(!z.exists(&p), "ephemeral reaped");
        assert!(z.exists("/persistent"), "persistent survives");
        assert!(fired.iter().any(|n| n.session == s2));
        assert!(z.expire_session(s1).is_empty(), "double expiry is no-op");
        assert!(z.create(s1, "/nope", vec![], false, false).is_err(), "dead session can't write");
    }

    #[test]
    fn election_smallest_sequence_wins_and_failover_works() {
        let mut z = zk();
        let s_a = z.connect(DcId(0));
        let s_b = z.connect(DcId(1));
        let s_c = z.connect(DcId(2));
        z.create(s_a, "/job1/el/c-", vec![], true, true).unwrap();
        z.create(s_b, "/job1/el/c-", vec![], true, true).unwrap();
        z.create(s_c, "/job1/el/c-", vec![], true, true).unwrap();
        let (w, _) = z.election_winner("/job1/el").unwrap();
        assert_eq!(w, s_a, "first creator wins");
        z.expire_session(s_a);
        let (w2, _) = z.election_winner("/job1/el").unwrap();
        assert_eq!(w2, s_b, "next in line after failure");
        z.expire_session(s_b);
        z.expire_session(s_c);
        assert!(z.election_winner("/job1/el").is_none());
    }

    #[test]
    fn write_latency_pays_quorum_round() {
        let cfg = Config::default();
        let mut wan = Wan::new(cfg.wan, Pcg::seeded(1));
        let z = ZkEnsemble::new(4);
        // From the leader's own DC: no client hop, but still a quorum round.
        let local = z.write_latency(&mut wan, DcId(0), 1024);
        let remote = z.write_latency(&mut wan, DcId(2), 1024);
        assert!(local >= 30, "quorum round over WAN, got {local}ms");
        assert!(remote > local, "remote client pays extra hop");
        // Reads are local and cheap.
        let read = z.read_latency(&mut wan, DcId(2), 1024);
        assert!(read < 5, "local read {read}ms");
    }

    #[test]
    fn property_election_winner_is_always_live_and_minimal() {
        use crate::testkit::{forall, UsizeIn, VecOf};
        // Random interleavings of joins/expirations.
        let gen = VecOf { elem: UsizeIn(0, 5), min_len: 1, max_len: 20 };
        forall(0xE1EC, &gen, |ops: &Vec<usize>| {
            let mut z = ZkEnsemble::new(4);
            let mut sessions = Vec::new();
            for (i, &op) in ops.iter().enumerate() {
                if op < 4 || sessions.is_empty() {
                    let s = z.connect(DcId(i % 4));
                    z.create(s, "/el/c-", vec![], true, true).unwrap();
                    sessions.push(s);
                } else {
                    let idx = op % sessions.len();
                    let s = sessions.remove(idx);
                    z.expire_session(s);
                }
            }
            match z.election_winner("/el") {
                Some((w, _)) => {
                    crate::prop_assert!(z.session_alive(w), "winner must be alive");
                    crate::prop_assert!(sessions.contains(&w), "winner among live sessions");
                    // Winner is the earliest-connected live session (ephemeral
                    // sequence order == connect order here).
                    let min = sessions.iter().min().unwrap();
                    crate::prop_assert!(w == *min, "winner {w} != earliest live {min}");
                }
                None => {
                    crate::prop_assert!(sessions.is_empty(), "no winner despite live candidates");
                }
            }
            Ok(())
        });
    }
}
