//! Event-queue implementations behind the simulation core.
//!
//! Three interchangeable engines live here, all generic over an opaque
//! event payload `T` — the queues order `(time, seq)` and never look
//! inside the payload (the sim stores a [`crate::sim::Payload`]: a typed
//! event or a boxed closure):
//!
//! * [`SlabQueue`] — the production queue: a generation-stamped slab holds
//!   the event payloads, an index-only 4-ary min-heap orders bare
//!   `(time, seq, slot)` triples. Cancel is O(1) (vacate the slot; the
//!   stale heap entry is skipped lazily at pop), `pending()` is an exact
//!   counter, and there are no side tombstone sets.
//! * [`LegacyQueue`] — the pre-overhaul queue (`BinaryHeap<Entry>` of
//!   payloads plus `live`/`cancelled` `HashSet`s), vendored
//!   verbatim. It is the executable golden record: the differential
//!   suites (`rust/tests/sim_queue.rs`, `rust/tests/golden_digests.rs`)
//!   replay generated schedules and whole campaign cells on both engines
//!   and assert bit-identical pop orders and replay digests, and
//!   `houtu bench` runs the campaign-smoke workload on both so every
//!   report carries the measured old-vs-new ratio.
//! * [`ShardedQueue`] — one [`SlabQueue`] per topology shard (shard = DC),
//!   events routed by scheduling affinity, popped through an exact
//!   `(time, seq)` n-way merge — the single-threaded, bit-identical half
//!   of the sharded DES work ([`crate::sim::shard`] holds the parallel
//!   engine).
//!
//! Both engines implement the same contract (see the invariants block in
//! [`crate::sim`]): pops are ordered by `(time, seq)` with `seq` the
//! caller-supplied strictly-monotone schedule counter, so same-time
//! events are FIFO and the pop order is a pure function of the schedule
//! — the determinism the replay digests pin.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet};

use super::{EventId, SimTime};

/// Which queue engine a [`crate::sim::Sim`] runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// Generation-stamped slab + index-only 4-ary heap (production).
    Slab,
    /// Pre-overhaul `BinaryHeap` + tombstone sets (differential baseline).
    Legacy,
    /// Topology-sharded queue: one [`SlabQueue`] per shard (shard = DC in
    /// the deployment stack), events routed by their
    /// [`crate::sim::Dispatch::affinity`], popped through an exact
    /// `(time, seq)` n-way merge so the executed stream — and every
    /// replay digest — is bit-identical to [`QueueKind::Slab`] for any
    /// shard count. This is the single-threaded half of the sharded-DES
    /// story: it proves the DC partition routing on every standard
    /// campaign cell, while [`crate::sim::shard::ShardedSim`] runs truly
    /// partitioned worlds on one thread per shard.
    Sharded(usize),
}

impl QueueKind {
    pub fn name(self) -> &'static str {
        match self {
            QueueKind::Slab => "slab",
            QueueKind::Legacy => "legacy",
            QueueKind::Sharded(_) => "sharded",
        }
    }
}

/// A popped event: its scheduled time, schedule seq, and payload.
pub struct Popped<T> {
    pub time: SimTime,
    pub seq: u64,
    pub payload: T,
}

// ---------------------------------------------------------------------------
// SlabQueue: generation-stamped slab + index-only 4-ary min-heap.
// ---------------------------------------------------------------------------

/// Sentinel for "no free slot" in the slab free list.
const NO_FREE: u32 = u32::MAX;

struct Slot<T> {
    /// Bumped every time the slot is vacated (fire or cancel), so stale
    /// [`EventId`]s held by callers can never cancel a reused slot.
    gen: u32,
    /// Free-list link, meaningful only while vacant.
    next_free: u32,
    /// Schedule seq of the occupying event; `payload.is_some()` ⇒ valid.
    seq: u64,
    /// The payload; `Some` iff the slot is occupied (event still live).
    payload: Option<T>,
}

/// Bare ordering triple the 4-ary heap stores — no closure, 24 bytes.
#[derive(Clone, Copy)]
struct HeapEntry {
    time: SimTime,
    seq: u64,
    slot: u32,
}

#[inline]
fn key(e: &HeapEntry) -> (SimTime, u64) {
    (e.time, e.seq)
}

/// The production event queue. See the module docs for the design.
pub struct SlabQueue<T> {
    slots: Vec<Slot<T>>,
    free_head: u32,
    heap: Vec<HeapEntry>,
    /// Exact count of live (scheduled, not fired, not cancelled) events.
    live: usize,
}

impl<T> Default for SlabQueue<T> {
    fn default() -> Self {
        SlabQueue::new()
    }
}

impl<T> SlabQueue<T> {
    pub fn new() -> Self {
        SlabQueue { slots: Vec::new(), free_head: NO_FREE, heap: Vec::new(), live: 0 }
    }

    /// Schedule a payload. `seq` must be strictly monotone across calls
    /// (the sim owns the counter); it is both the FIFO tie-break and the
    /// staleness check for lazily-skipped heap entries.
    pub fn schedule(&mut self, time: SimTime, seq: u64, payload: T) -> EventId {
        let slot = if self.free_head != NO_FREE {
            let s = self.free_head as usize;
            self.free_head = self.slots[s].next_free;
            self.slots[s].seq = seq;
            self.slots[s].payload = Some(payload);
            s as u32
        } else {
            let s = self.slots.len();
            assert!(s < NO_FREE as usize, "event slab exhausted");
            self.slots.push(Slot { gen: 0, next_free: NO_FREE, seq, payload: Some(payload) });
            s as u32
        };
        self.heap_push(HeapEntry { time, seq, slot });
        self.live += 1;
        EventId::pack(slot, self.slots[slot as usize].gen)
    }

    /// O(1) cancel: vacate the slot (dropping the payload now, not at
    /// pop) and bump its generation. The heap entry stays behind and is
    /// skipped at pop because its `seq` no longer matches the slot.
    pub fn cancel(&mut self, id: EventId) -> bool {
        let (slot, gen) = id.unpack();
        match self.slots.get_mut(slot as usize) {
            Some(s) if s.gen == gen && s.payload.is_some() => {
                s.payload = None;
                self.vacate(slot);
                true
            }
            _ => false,
        }
    }

    /// Pop the earliest live event, discarding stale heap entries.
    pub fn pop(&mut self) -> Option<Popped<T>> {
        while let Some(e) = self.heap_pop() {
            let s = &mut self.slots[e.slot as usize];
            if s.seq != e.seq || s.payload.is_none() {
                continue; // cancelled (or slot since reused): stale entry
            }
            let payload = s.payload.take().expect("occupied slot");
            self.vacate(e.slot);
            return Some(Popped { time: e.time, seq: e.seq, payload });
        }
        None
    }

    /// Timestamp of the earliest live event, discarding stale heap
    /// entries on the way (which is why this takes `&mut self`).
    pub fn next_time(&mut self) -> Option<SimTime> {
        self.next_key().map(|(t, _)| t)
    }

    /// `(time, seq)` of the earliest live event — the full ordering key,
    /// which [`ShardedQueue`] uses for its exact n-way merge (the
    /// timestamp alone cannot break same-time ties across shards).
    pub fn next_key(&mut self) -> Option<(SimTime, u64)> {
        while let Some(&e) = self.heap.first() {
            let s = &self.slots[e.slot as usize];
            if s.seq == e.seq && s.payload.is_some() {
                return Some((e.time, e.seq));
            }
            self.heap_pop();
        }
        None
    }

    /// Exact number of live events — a counter, not a heap scan.
    pub fn pending(&self) -> usize {
        self.live
    }

    fn vacate(&mut self, slot: u32) {
        let s = &mut self.slots[slot as usize];
        debug_assert!(s.payload.is_none());
        s.gen = s.gen.wrapping_add(1);
        s.next_free = self.free_head;
        self.free_head = slot;
        self.live -= 1;
    }

    // 4-ary min-heap over (time, seq). Wider nodes halve the tree depth
    // versus binary, and the hot compare loop touches one cache line per
    // level (4 × 24-byte entries).

    fn heap_push(&mut self, e: HeapEntry) {
        self.heap.push(e);
        let mut i = self.heap.len() - 1;
        while i > 0 {
            let p = (i - 1) / 4;
            if key(&self.heap[p]) <= key(&self.heap[i]) {
                break;
            }
            self.heap.swap(i, p);
            i = p;
        }
    }

    fn heap_pop(&mut self) -> Option<HeapEntry> {
        let n = self.heap.len();
        if n == 0 {
            return None;
        }
        self.heap.swap(0, n - 1);
        let min = self.heap.pop();
        let n = self.heap.len();
        let mut i = 0;
        loop {
            let c0 = 4 * i + 1;
            if c0 >= n {
                break;
            }
            let mut m = c0;
            for c in (c0 + 1)..(c0 + 4).min(n) {
                if key(&self.heap[c]) < key(&self.heap[m]) {
                    m = c;
                }
            }
            if key(&self.heap[m]) >= key(&self.heap[i]) {
                break;
            }
            self.heap.swap(i, m);
            i = m;
        }
        min
    }
}

// ---------------------------------------------------------------------------
// LegacyQueue: the pre-overhaul engine, vendored as the golden baseline.
// ---------------------------------------------------------------------------

struct Entry<T> {
    time: SimTime,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert so the earliest (time, seq)
        // pops first. seq keeps same-time events FIFO.
        other.time.cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// The pre-overhaul queue: payloads inside the heap, cancellation
/// via `live`/`cancelled` tombstone sets checked at pop time. Kept (not
/// deleted) so the differential suites and `houtu bench` can replay any
/// schedule on the exact pre-swap semantics and compare bit-for-bit.
pub struct LegacyQueue<T> {
    queue: BinaryHeap<Entry<T>>,
    live: HashSet<u64>,
    cancelled: HashSet<u64>,
}

impl<T> Default for LegacyQueue<T> {
    fn default() -> Self {
        LegacyQueue::new()
    }
}

impl<T> LegacyQueue<T> {
    pub fn new() -> Self {
        LegacyQueue { queue: BinaryHeap::new(), live: HashSet::new(), cancelled: HashSet::new() }
    }

    pub fn schedule(&mut self, time: SimTime, seq: u64, payload: T) -> EventId {
        self.live.insert(seq);
        self.queue.push(Entry { time, seq, payload });
        EventId::pack_seq(seq)
    }

    pub fn cancel(&mut self, id: EventId) -> bool {
        let seq = id.raw();
        if self.live.remove(&seq) {
            self.cancelled.insert(seq);
            true
        } else {
            false
        }
    }

    pub fn pop(&mut self) -> Option<Popped<T>> {
        while let Some(e) = self.queue.pop() {
            if self.cancelled.remove(&e.seq) {
                continue;
            }
            self.live.remove(&e.seq);
            return Some(Popped { time: e.time, seq: e.seq, payload: e.payload });
        }
        None
    }

    pub fn next_time(&mut self) -> Option<SimTime> {
        loop {
            match self.queue.peek() {
                Some(e) if self.cancelled.contains(&e.seq) => {
                    let e = self.queue.pop().expect("peeked entry");
                    self.cancelled.remove(&e.seq);
                }
                Some(e) => return Some(e.time),
                None => return None,
            }
        }
    }

    pub fn pending(&self) -> usize {
        self.live.len()
    }
}

// ---------------------------------------------------------------------------
// ShardedQueue: one SlabQueue per topology shard, exact (time, seq) merge.
// ---------------------------------------------------------------------------

/// Shard tag width inside an [`EventId`] slot word: the low 24 bits are
/// the subqueue slot, the next 8 bits the shard index. Bounds both the
/// shard count (≤ 256) and the live events per shard (< 2^24).
const SHARD_SLOT_BITS: u32 = 24;
const SHARD_SLOT_MASK: u32 = (1 << SHARD_SLOT_BITS) - 1;

/// Maximum shard count a [`ShardedQueue`] supports (id-encoding bound).
pub const MAX_QUEUE_SHARDS: usize = 256;

/// The topology-sharded queue behind [`QueueKind::Sharded`]: `n`
/// independent [`SlabQueue`]s, one per shard (shard = DC in the
/// deployment stack), with events routed to a subqueue by the caller's
/// affinity and popped through an **exact** `(time, seq)` n-way merge.
///
/// Because the merge compares the full ordering key — not just the
/// timestamp — the pop stream is bit-identical to a single
/// [`SlabQueue`]'s for *any* shard count and *any* routing function;
/// `rust/tests/golden_digests.rs` pins that over all 30 standard
/// campaign cells for 1/2/4 shards. Cancellation stays O(1): issued
/// [`EventId`]s carry the shard index in the high bits of the slot word,
/// so a cancel goes straight to the owning subqueue.
pub struct ShardedQueue<T> {
    shards: Vec<SlabQueue<T>>,
    /// Exact live count across subqueues (maintained, never summed).
    live: usize,
}

impl<T> ShardedQueue<T> {
    pub fn new(shards: usize) -> Self {
        let n = shards.clamp(1, MAX_QUEUE_SHARDS);
        ShardedQueue { shards: (0..n).map(|_| SlabQueue::new()).collect(), live: 0 }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Schedule onto the subqueue `affinity % num_shards` (closures and
    /// affinity-free events route to shard 0). `seq` is the global
    /// schedule counter — unique across subqueues, so the merge's
    /// `(time, seq)` comparison never ties.
    pub fn schedule(&mut self, time: SimTime, seq: u64, affinity: usize, payload: T) -> EventId {
        let shard = affinity % self.shards.len();
        let (slot, gen) = self.shards[shard].schedule(time, seq, payload).unpack();
        assert!(slot <= SHARD_SLOT_MASK, "sharded subqueue slot space exhausted");
        self.live += 1;
        EventId::pack(((shard as u32) << SHARD_SLOT_BITS) | slot, gen)
    }

    pub fn cancel(&mut self, id: EventId) -> bool {
        let (slot, gen) = id.unpack();
        let shard = (slot >> SHARD_SLOT_BITS) as usize;
        if shard >= self.shards.len() {
            return false;
        }
        let hit = self.shards[shard].cancel(EventId::pack(slot & SHARD_SLOT_MASK, gen));
        if hit {
            self.live -= 1;
        }
        hit
    }

    /// Pop the globally earliest live event: argmin of the subqueue
    /// heads by the full `(time, seq)` key.
    pub fn pop(&mut self) -> Option<Popped<T>> {
        let mut best: Option<(usize, (SimTime, u64))> = None;
        for (i, q) in self.shards.iter_mut().enumerate() {
            if let Some(k) = q.next_key() {
                if best.map_or(true, |(_, bk)| k < bk) {
                    best = Some((i, k));
                }
            }
        }
        let (i, _) = best?;
        let popped = self.shards[i].pop();
        debug_assert!(popped.is_some(), "peeked head must pop");
        if popped.is_some() {
            self.live -= 1;
        }
        popped
    }

    pub fn next_time(&mut self) -> Option<SimTime> {
        self.shards.iter_mut().filter_map(|q| q.next_key()).min().map(|(t, _)| t)
    }

    pub fn pending(&self) -> usize {
        self.live
    }
}

// ---------------------------------------------------------------------------
// Runtime dispatch: one branch per op, so the whole deployment stack can
// run on either engine without threading a type parameter through every
// event producer.
// ---------------------------------------------------------------------------

pub(crate) enum QueueImpl<T> {
    Slab(SlabQueue<T>),
    Legacy(LegacyQueue<T>),
    Sharded(ShardedQueue<T>),
}

impl<T> QueueImpl<T> {
    pub(crate) fn new(kind: QueueKind) -> Self {
        match kind {
            QueueKind::Slab => QueueImpl::Slab(SlabQueue::new()),
            QueueKind::Legacy => QueueImpl::Legacy(LegacyQueue::new()),
            QueueKind::Sharded(n) => QueueImpl::Sharded(ShardedQueue::new(n)),
        }
    }

    pub(crate) fn kind(&self) -> QueueKind {
        match self {
            QueueImpl::Slab(_) => QueueKind::Slab,
            QueueImpl::Legacy(_) => QueueKind::Legacy,
            QueueImpl::Sharded(q) => QueueKind::Sharded(q.num_shards()),
        }
    }

    /// `affinity` is the scheduling event's topology shard (DC index);
    /// only the sharded engine routes on it — the flat engines ignore it.
    #[inline]
    pub(crate) fn schedule(
        &mut self,
        time: SimTime,
        seq: u64,
        affinity: usize,
        payload: T,
    ) -> EventId {
        match self {
            QueueImpl::Slab(q) => q.schedule(time, seq, payload),
            QueueImpl::Legacy(q) => q.schedule(time, seq, payload),
            QueueImpl::Sharded(q) => q.schedule(time, seq, affinity, payload),
        }
    }

    #[inline]
    pub(crate) fn cancel(&mut self, id: EventId) -> bool {
        match self {
            QueueImpl::Slab(q) => q.cancel(id),
            QueueImpl::Legacy(q) => q.cancel(id),
            QueueImpl::Sharded(q) => q.cancel(id),
        }
    }

    #[inline]
    pub(crate) fn pop(&mut self) -> Option<Popped<T>> {
        match self {
            QueueImpl::Slab(q) => q.pop(),
            QueueImpl::Legacy(q) => q.pop(),
            QueueImpl::Sharded(q) => q.pop(),
        }
    }

    #[inline]
    pub(crate) fn next_time(&mut self) -> Option<SimTime> {
        match self {
            QueueImpl::Slab(q) => q.next_time(),
            QueueImpl::Legacy(q) => q.next_time(),
            QueueImpl::Sharded(q) => q.next_time(),
        }
    }

    #[inline]
    pub(crate) fn pending(&self) -> usize {
        match self {
            QueueImpl::Slab(q) => q.pending(),
            QueueImpl::Legacy(q) => q.pending(),
            QueueImpl::Sharded(q) => q.pending(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    // The queues are payload-agnostic; unit payloads keep the tests on
    // pure (time, seq) ordering.
    type Q = SlabQueue<()>;

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = Q::new();
        q.schedule(30, 0, ());
        q.schedule(10, 1, ());
        q.schedule(20, 2, ());
        q.schedule(10, 3, ());
        let order: Vec<(SimTime, u64)> = std::iter::from_fn(|| q.pop())
            .map(|p| (p.time, p.seq))
            .collect();
        assert_eq!(order, vec![(10, 1), (10, 3), (20, 2), (30, 0)]);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn cancel_is_o1_and_exact() {
        let mut q = Q::new();
        let a = q.schedule(5, 0, ());
        let b = q.schedule(5, 1, ());
        assert_eq!(q.pending(), 2);
        assert!(q.cancel(a));
        assert!(!q.cancel(a), "double cancel");
        assert_eq!(q.pending(), 1);
        let p = q.pop().expect("b survives");
        assert_eq!(p.seq, 1);
        assert!(!q.cancel(b), "cancel after fire");
        assert_eq!(q.pending(), 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn slot_reuse_does_not_resurrect_stale_ids() {
        let mut q = Q::new();
        let a = q.schedule(5, 0, ());
        assert!(q.cancel(a));
        // The vacated slot is reused by a new event.
        let b = q.schedule(3, 1, ());
        assert!(!q.cancel(a), "stale id must not hit the reused slot");
        assert_eq!(q.pending(), 1);
        // The stale heap entry for `a` is skipped, `b` pops.
        let p = q.pop().expect("b");
        assert_eq!((p.time, p.seq), (3, 1));
        assert!(q.pop().is_none());
        let _ = b;
    }

    #[test]
    fn next_time_skips_cancelled_heads() {
        let mut q = Q::new();
        let a = q.schedule(1, 0, ());
        q.schedule(9, 1, ());
        assert_eq!(q.next_time(), Some(1));
        assert!(q.cancel(a));
        assert_eq!(q.next_time(), Some(9));
        assert_eq!(q.pop().expect("9").time, 9);
        assert_eq!(q.next_time(), None);
    }

    #[test]
    fn four_ary_heap_orders_large_random_batches() {
        let mut rng = Pcg::seeded(5);
        let mut q = Q::new();
        for seq in 0..5000u64 {
            q.schedule(rng.below(1000), seq, ());
        }
        let mut last = (0u64, 0u64);
        let mut n = 0;
        while let Some(p) = q.pop() {
            assert!((p.time, p.seq) > last || n == 0, "heap order violated");
            last = (p.time, p.seq);
            n += 1;
        }
        assert_eq!(n, 5000);
    }

    /// The sharded merge must reproduce the flat slab's pop stream
    /// exactly, for any shard count and any routing of events to
    /// subqueues — the full (time, seq) key comparison guarantees it.
    #[test]
    fn sharded_merge_matches_flat_slab_for_any_routing() {
        for shards in [1usize, 2, 3, 4, 7] {
            let mut rng = Pcg::seeded(42 + shards as u64);
            let mut flat: SlabQueue<u64> = SlabQueue::new();
            let mut sharded: ShardedQueue<u64> = ShardedQueue::new(shards);
            let mut ids: Vec<(EventId, EventId)> = Vec::new();
            let mut seq = 0u64;
            for _ in 0..3000 {
                match rng.index(4) {
                    0 | 1 => {
                        let t = rng.below(400);
                        let aff = rng.index(8); // deliberately != shard count
                        ids.push((
                            flat.schedule(t, seq, seq),
                            sharded.schedule(t, seq, aff, seq),
                        ));
                        seq += 1;
                    }
                    2 if !ids.is_empty() => {
                        let (a, b) = ids[rng.index(ids.len())];
                        assert_eq!(flat.cancel(a), sharded.cancel(b));
                    }
                    _ => {
                        let (p1, p2) = (flat.pop(), sharded.pop());
                        assert_eq!(
                            p1.as_ref().map(|p| (p.time, p.seq, p.payload)),
                            p2.as_ref().map(|p| (p.time, p.seq, p.payload)),
                            "{shards} shards"
                        );
                    }
                }
                assert_eq!(flat.pending(), sharded.pending(), "{shards} shards");
                assert_eq!(flat.next_time(), sharded.next_time(), "{shards} shards");
            }
            // Drain both to the end: the tails must agree too.
            loop {
                let (p1, p2) = (flat.pop(), sharded.pop());
                assert_eq!(
                    p1.as_ref().map(|p| (p.time, p.seq, p.payload)),
                    p2.as_ref().map(|p| (p.time, p.seq, p.payload))
                );
                if p1.is_none() {
                    break;
                }
            }
        }
    }

    /// Sharded ids carry the shard tag: cancels hit the owning subqueue
    /// and stale ids stay dead after slot reuse, exactly like the flat
    /// engine.
    #[test]
    fn sharded_cancel_is_exact_across_subqueues() {
        let mut q: ShardedQueue<()> = ShardedQueue::new(4);
        let a = q.schedule(5, 0, 0, ());
        let b = q.schedule(5, 1, 3, ());
        let c = q.schedule(1, 2, 2, ());
        assert_eq!(q.pending(), 3);
        assert!(q.cancel(b));
        assert!(!q.cancel(b), "double cancel");
        assert_eq!(q.pending(), 2);
        assert_eq!(q.pop().expect("c first").seq, 2);
        assert_eq!(q.pop().expect("a next").seq, 0);
        assert!(q.pop().is_none());
        assert!(!q.cancel(a), "cancel after fire");
        assert!(!q.cancel(c), "cancel after fire");
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn legacy_and_slab_agree_on_interleaved_ops() {
        // Mini differential smoke (the full generated-schedule suite
        // lives in rust/tests/sim_queue.rs): schedule/cancel/pop
        // interleavings must produce identical (time, seq) streams.
        let mut rng = Pcg::seeded(77);
        let mut slab: SlabQueue<()> = SlabQueue::new();
        let mut legacy: LegacyQueue<()> = LegacyQueue::new();
        let mut ids: Vec<(EventId, EventId)> = Vec::new();
        let mut seq = 0u64;
        for _ in 0..2000 {
            match rng.index(4) {
                0 | 1 => {
                    let t = rng.below(500);
                    ids.push((slab.schedule(t, seq, ()), legacy.schedule(t, seq, ())));
                    seq += 1;
                }
                2 if !ids.is_empty() => {
                    let (a, b) = ids[rng.index(ids.len())];
                    assert_eq!(slab.cancel(a), legacy.cancel(b));
                }
                _ => {
                    let (p1, p2) = (slab.pop(), legacy.pop());
                    assert_eq!(
                        p1.as_ref().map(|p| (p.time, p.seq)),
                        p2.as_ref().map(|p| (p.time, p.seq))
                    );
                }
            }
            assert_eq!(slab.pending(), legacy.pending());
            assert_eq!(slab.next_time(), legacy.next_time());
        }
    }
}
