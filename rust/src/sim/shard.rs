//! Conservative parallel DES: one shard per data center, WAN latency as
//! lookahead.
//!
//! [`ShardedSim`] runs a *partitioned* world — one state value per
//! **part** (part = DC in the deployment stack) — on one OS thread per
//! **shard** (a contiguous block of parts), synchronized with a
//! null-message / lower-bound-on-timestamp (LBTS) protocol in the
//! Chandy–Misra–Bryant tradition. The paper's own topology is the
//! partition argument: intra-DC events never cross a shard boundary, and
//! every cross-DC interaction pays a WAN latency floor ([`Lookahead`],
//! built from the same constants as `net::Wan`), so a shard may safely
//! execute up to `min over other shards t of (next_t + lookahead(t, me))`
//! without ever receiving an event from its past.
//!
//! # Protocol
//!
//! Execution proceeds in barrier-delimited rounds; every round each
//! shard:
//!
//! 1. **Drain** its per-sender mailboxes into its local queue (a
//!    [`SlabQueue`], exactly the production engine's), then **publish**
//!    the timestamp of its earliest pending event (`u64::MAX` when
//!    empty) and its cumulative executed-event count.
//! 2. **Barrier.** Everyone now sees the same published snapshot, so the
//!    termination / budget decision below is taken identically — and
//!    therefore consistently — on every thread.
//! 3. **Execute** every local event with `time < H`, where
//!    `H = min over t≠me of (next_t + la(t → me))` is this shard's LBTS
//!    horizon. Events for parts on other shards are buffered into
//!    per-destination outboxes and flushed to the shared mailboxes at
//!    the end of the phase.
//! 4. **Barrier**, making every flushed message visible before the next
//!    round's drain.
//!
//! **Safety.** A message from shard `t` is stamped
//! `recv = send.now + floor(from, to) + extra ≥ next_t + la(t → me) ≥ H`,
//! and shard `me` only executed events strictly below `H` — so no
//! delivery ever lands in a shard's past (debug-asserted at delivery).
//! **Progress.** Lookahead floors are clamped `≥ 1` ms, so the shard
//! holding the global-minimum timestamp always has `H > next_me` and
//! executes at least one event per round; rounds with an all-`MAX`
//! snapshot terminate the run (mailboxes are drained before publishing,
//! so `MAX` means globally idle, not in-flight).
//!
//! # Determinism contract
//!
//! Every event carries a globally unique canonical key
//! `(born_part << 48) | born_seq`, allocated from the scheduling part's
//! monotone counter, and each shard's queue orders `(time, key)`. Three
//! invariants make the executed streams — and hence [`ShardedSim::digest`]
//! — a pure function of the seeded schedule, **independent of shard
//! count and thread interleaving** (pinned by `rust/tests/shard_sim.rs`):
//!
//! 1. A created event is strictly greater than its creator in
//!    `(time, key)`: local schedules keep `time ≥ now` with a fresh
//!    (maximal) `born_seq`; cross-part sends add a `≥ 1` ms floor.
//! 2. Keys never collide (part-tagged monotone counters), so `(time,
//!    key)` is a total order on all events that every shard's queue
//!    agrees with; restricted to any single part it is the same sequence
//!    no matter which shard executes the part.
//! 3. Handlers only touch their own part's state plus the [`ShardCtx`]
//!    scheduling surface — enforced by construction, since `apply` gets
//!    `&mut S` for exactly one part.
//!
//! The per-part digest folds `(time, key)` per executed event (FNV-1a),
//! and the run digest folds the per-part `(events, digest)` pairs in
//! global part order. The single-threaded twin [`ShardedSim::run_serial`]
//! drives the *identical* round protocol with no atomics or barriers, so
//! `run()` ≡ `run_serial()` bit-for-bit is a CI-pinned property, the same
//! golden-baseline discipline `LegacyQueue` established in PR 4.
//!
//! Two kinds of world run on this engine. Synthetic `Send` workloads
//! (`houtu bench`'s `multi-dc-churn` rows) partition trivially. Real
//! campaign cells run through `deploy::parts`: the monolithic
//! `deploy::World` is split into per-DC `DcPart` state plus a thin
//! global part, and every cross-DC interaction — steals, WAN transfers,
//! JM replication/election, insurance duplicates, `kill_dc`/`wan_pair`
//! chaos — becomes a typed cross-shard message whose arrival pays the
//! `net::wan_lookahead` floor. The exact-merge
//! [`super::queue::ShardedQueue`] remains the bit-identical-to-slab
//! story for the sequential whole-world engine; this module is the
//! thread-per-shard throughput path (`campaign --engine sharded-sim`,
//! `houtu bench`'s `campaign-smoke-threaded` row).
//!
//! **Queue-depth reporting.** [`ShardedSim::peak_pending`] is the high-water
//! mark of the *summed* per-shard queue depths, maximized per round: each
//! shard tracks its own round-local peak, the per-round peaks are summed
//! at the round barrier, and the run keeps the largest round sum. A
//! single-shard run degenerates to the sequential engine's definition,
//! and `run()` ≡ `run_serial()` holds for the metric at every shard
//! count (the round protocol assigns identical events to identical
//! rounds). Earlier revisions reported one shard's lifetime peak, which
//! under-reported the fleet-wide backlog.
//!
//! A panicking event handler poisons the round protocol: the panic is
//! captured, every worker exits at the next barrier, and [`ShardedSim::run`]
//! resumes the unwind on the calling thread.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

use super::queue::SlabQueue;
use super::{SimTime, DEFAULT_EVENT_BUDGET};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fold(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// Bits reserved for the born-part tag in a canonical event key; the
/// low 48 bits are the part's monotone birth counter.
const KEY_PART_SHIFT: u32 = 48;

#[inline]
fn canonical_key(part: u32, born_seq: u64) -> u64 {
    debug_assert!(born_seq < (1u64 << KEY_PART_SHIFT), "per-part birth counter overflow");
    ((part as u64) << KEY_PART_SHIFT) | born_seq
}

/// Thread-safe step clock — the sharded counterpart of
/// [`super::StepClock`], whose `Cell`s are single-thread only. One lives
/// in each shard runner; `advance` is two relaxed atomic stores on the
/// hot path (the barrier protocol provides all cross-thread ordering
/// anyone reads it under).
#[derive(Debug, Default)]
pub struct ShardClock {
    now: AtomicU64,
    steps: AtomicU64,
}

impl ShardClock {
    #[inline]
    pub fn advance(&self, t: SimTime) {
        self.now.store(t, Ordering::Relaxed);
        self.steps.store(self.steps.load(Ordering::Relaxed) + 1, Ordering::Relaxed);
    }

    #[inline]
    pub fn now(&self) -> SimTime {
        self.now.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }
}

/// Per-pair lower bounds on cross-part event latency, in sim ms — the
/// protocol's lookahead. Floors are clamped `≥ 1` so the global-minimum
/// shard always makes progress. Built from the WAN latency constants by
/// `net::wan_lookahead` for deployment topologies, or directly for
/// synthetic workloads.
#[derive(Debug, Clone)]
pub struct Lookahead {
    parts: usize,
    floor_ms: Vec<u64>,
}

impl Lookahead {
    /// The same floor between every pair (including a part to itself).
    pub fn uniform(parts: usize, floor: u64) -> Lookahead {
        Lookahead { parts, floor_ms: vec![floor.max(1); parts * parts] }
    }

    /// Per-pair floors from `f(from, to)`, each clamped `≥ 1` ms.
    pub fn from_fn(parts: usize, mut f: impl FnMut(usize, usize) -> u64) -> Lookahead {
        let mut floor_ms = Vec::with_capacity(parts * parts);
        for a in 0..parts {
            for b in 0..parts {
                floor_ms.push(f(a, b).max(1));
            }
        }
        Lookahead { parts, floor_ms }
    }

    pub fn parts(&self) -> usize {
        self.parts
    }

    /// The minimum latency any event scheduled by `from` for `to` pays.
    #[inline]
    pub fn floor(&self, from: usize, to: usize) -> u64 {
        self.floor_ms[from * self.parts + to]
    }
}

/// A typed event vocabulary for the partitioned engine. Unlike
/// [`super::Dispatch`], `apply` sees only its target part's state plus
/// the [`ShardCtx`] scheduling surface — the isolation that makes
/// per-part execution order (and the digest) independent of the
/// part→shard mapping.
pub trait ShardEvent<S>: Send + Sized {
    fn apply(self, ctx: &mut ShardCtx<'_, S, Self>);

    /// Cheap static tag for diagnostics.
    fn kind(&self) -> &'static str {
        "event"
    }
}

/// A cross-shard message: an event stamped with its arrival time and
/// canonical `(time, key, part)` identity, so merged order is
/// deterministic regardless of thread interleaving.
struct Msg<E> {
    time: SimTime,
    key: u64,
    part: u32,
    ev: E,
}

/// What an executing event sees: exclusive access to its part's state
/// and the scheduling surface. Local schedules go straight into the
/// shard's queue; cross-part sends pay the lookahead floor and are
/// routed through the mailbox protocol when the target part lives on
/// another shard.
pub struct ShardCtx<'a, S, E> {
    /// The target part's state — and nothing else's.
    pub state: &'a mut S,
    now: SimTime,
    part: u32,
    nparts: u32,
    born_seq: &'a mut u64,
    queue: &'a mut SlabQueue<(u32, E)>,
    outbox: &'a mut [Vec<Msg<E>>],
    part_shard: &'a [u32],
    my_shard: u32,
    la: &'a Lookahead,
}

impl<'a, S, E> ShardCtx<'a, S, E> {
    /// The executing event's virtual time (ms).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// The part (DC) this event targets.
    #[inline]
    pub fn part(&self) -> usize {
        self.part as usize
    }

    /// Total parts in the world.
    #[inline]
    pub fn nparts(&self) -> usize {
        self.nparts as usize
    }

    #[inline]
    fn next_key(&mut self) -> u64 {
        let k = canonical_key(self.part, *self.born_seq);
        *self.born_seq += 1;
        k
    }

    /// Schedule `ev` on this same part after `delay` ms (0 = same-time,
    /// FIFO in birth order behind this event).
    pub fn schedule_in(&mut self, delay: SimTime, ev: E) {
        let t = self.now + delay;
        let key = self.next_key();
        let part = self.part;
        self.queue.schedule(t, key, (part, ev));
    }

    /// Send `ev` to `to_part`, arriving at
    /// `now + lookahead_floor(part, to_part) + extra_delay`. The floor is
    /// the WAN latency lower bound that makes conservative parallel
    /// execution safe; `extra_delay` models everything above it
    /// (serialization, queueing, transfer time).
    pub fn send(&mut self, to_part: usize, extra_delay: SimTime, ev: E) {
        assert!(to_part < self.nparts as usize, "send to unknown part {to_part}");
        let t = self.now + self.la.floor(self.part as usize, to_part) + extra_delay;
        let key = self.next_key();
        let to = to_part as u32;
        let dst_shard = self.part_shard[to_part];
        if dst_shard == self.my_shard {
            self.queue.schedule(t, key, (to, ev));
        } else {
            self.outbox[dst_shard as usize].push(Msg { time: t, key, part: to, ev });
        }
    }
}

/// Per-part bookkeeping: the state, the birth counter behind canonical
/// keys, and the executed-stream digest the determinism contract pins.
struct PartCell<S> {
    state: S,
    born_seq: u64,
    events: u64,
    digest: u64,
}

/// One shard: a contiguous block of parts, their own [`SlabQueue`]
/// ordered by `(time, key)`, per-destination outboxes, and a
/// thread-safe clock. Runs on exactly one thread at a time.
struct ShardRunner<S, E> {
    shard: u32,
    part_base: u32,
    parts: Vec<PartCell<S>>,
    queue: SlabQueue<(u32, E)>,
    outbox: Vec<Vec<Msg<E>>>,
    now: SimTime,
    events: u64,
    peak_pending: usize,
    /// This shard's queue-depth peak within the current round (reset at
    /// the start of every `exec_round`); the round barrier sums these
    /// across shards for [`ShardedSim::peak_pending`].
    round_peak: usize,
    clock: ShardClock,
}

/// Read-only world geometry threaded into the execution hot loop.
#[derive(Clone, Copy)]
struct ShardEnv<'x> {
    part_shard: &'x [u32],
    la: &'x Lookahead,
    nparts: u32,
}

impl<S, E: ShardEvent<S>> ShardRunner<S, E> {
    fn next_time(&mut self) -> SimTime {
        self.queue.next_time().unwrap_or(SimTime::MAX)
    }

    fn deliver(&mut self, m: Msg<E>) {
        debug_assert!(
            m.time >= self.now,
            "lookahead violation: message for t={} delivered at shard time {}",
            m.time,
            self.now
        );
        self.queue.schedule(m.time, m.key, (m.part, m.ev));
    }

    /// Execute every local event strictly below `limit` (the LBTS
    /// horizon), stopping early at the `cap` runaway guard. Cross-shard
    /// sends accumulate in `self.outbox`.
    fn exec_round(&mut self, limit: SimTime, cap: u64, env: &ShardEnv<'_>) {
        // The round-entry depth counts too: a shard stalled behind its
        // horizon still holds a backlog this round.
        self.round_peak = self.queue.pending();
        if self.round_peak > self.peak_pending {
            self.peak_pending = self.round_peak;
        }
        loop {
            match self.queue.next_time() {
                Some(t) if t < limit => {}
                _ => break,
            }
            if self.events >= cap {
                break;
            }
            let popped = self.queue.pop().expect("peeked event must pop");
            let (part, ev) = popped.payload;
            let t = popped.time;
            debug_assert!(t >= self.now, "time went backwards within a shard");
            self.now = t;
            self.events += 1;
            self.clock.advance(t);
            let cell = &mut self.parts[(part - self.part_base) as usize];
            cell.events += 1;
            cell.digest = fold(fold(cell.digest, t), popped.seq);
            let mut ctx = ShardCtx {
                state: &mut cell.state,
                now: t,
                part,
                nparts: env.nparts,
                born_seq: &mut cell.born_seq,
                queue: &mut self.queue,
                outbox: &mut self.outbox,
                part_shard: env.part_shard,
                my_shard: self.shard,
                la: env.la,
            };
            ev.apply(&mut ctx);
            let live = self.queue.pending();
            if live > self.round_peak {
                self.round_peak = live;
            }
            if live > self.peak_pending {
                self.peak_pending = live;
            }
        }
    }
}

/// Shared synchronization state for one parallel run. Mailboxes are
/// per-(destination, sender) so two senders never contend on a lock, and
/// a destination drains each slot with its sender's messages already in
/// canonical order (the queue re-sorts anyway — order here is irrelevant
/// by design).
struct Shared<E> {
    next: Vec<AtomicU64>,
    executed: Vec<AtomicU64>,
    /// Per-shard round-local queue-depth peaks, published in phase B and
    /// summed by everyone after the round barrier.
    round_peak: Vec<AtomicU64>,
    /// Largest round sum seen so far — [`ShardedSim::peak_pending`].
    peak: AtomicU64,
    inbox: Vec<Mutex<Vec<Msg<E>>>>,
    poisoned: AtomicBool,
    panics: Mutex<Vec<Box<dyn Any + Send>>>,
    barrier: Barrier,
}

fn worker<S, E: ShardEvent<S>>(
    r: &mut ShardRunner<S, E>,
    shared: &Shared<E>,
    env: ShardEnv<'_>,
    shard_la: &[u64],
    nshards: usize,
    budget: u64,
) {
    let me = r.shard as usize;
    let n = nshards;
    let mut nexts = vec![0u64; n];
    loop {
        // Phase A: drain mailboxes, publish (next event time, executed).
        let res = catch_unwind(AssertUnwindSafe(|| {
            for src in 0..n {
                if src == me {
                    continue;
                }
                let msgs = {
                    let mut slot = shared.inbox[me * n + src].lock().unwrap();
                    std::mem::take(&mut *slot)
                };
                for m in msgs {
                    r.deliver(m);
                }
            }
            shared.next[me].store(r.next_time(), Ordering::SeqCst);
            shared.executed[me].store(r.events, Ordering::SeqCst);
        }));
        if let Err(p) = res {
            shared.poisoned.store(true, Ordering::SeqCst);
            shared.next[me].store(u64::MAX, Ordering::SeqCst);
            shared.panics.lock().unwrap().push(p);
        }
        shared.barrier.wait();
        if shared.poisoned.load(Ordering::SeqCst) {
            return;
        }

        // Decision point: every thread reads the same published snapshot
        // and takes the same branch, so exits are always collective and
        // no thread is left waiting at a barrier.
        for (t, slot) in nexts.iter_mut().enumerate() {
            *slot = shared.next[t].load(Ordering::SeqCst);
        }
        let gmin = nexts.iter().copied().min().unwrap_or(u64::MAX);
        let total: u64 = (0..n).map(|t| shared.executed[t].load(Ordering::SeqCst)).sum();
        if gmin == u64::MAX || total > budget {
            return;
        }

        // Phase B: execute below the LBTS horizon, flush outboxes.
        let res = catch_unwind(AssertUnwindSafe(|| {
            let mut h = u64::MAX;
            for t in 0..n {
                if t != me {
                    h = h.min(nexts[t].saturating_add(shard_la[t * n + me]));
                }
            }
            r.exec_round(h, budget.saturating_add(1), &env);
            shared.round_peak[me].store(r.round_peak as u64, Ordering::SeqCst);
            for dst in 0..n {
                if dst != me && !r.outbox[dst].is_empty() {
                    let mut slot = shared.inbox[dst * n + me].lock().unwrap();
                    slot.append(&mut r.outbox[dst]);
                }
            }
        }));
        if let Err(p) = res {
            shared.poisoned.store(true, Ordering::SeqCst);
            shared.panics.lock().unwrap().push(p);
        }
        shared.barrier.wait();
        if shared.poisoned.load(Ordering::SeqCst) {
            return;
        }
        // Everyone is past the round barrier, so every shard's round
        // peak is visible; sum them and keep the largest round. All
        // threads compute the same sum — fetch_max is idempotent.
        let round_sum: u64 =
            (0..n).map(|t| shared.round_peak[t].load(Ordering::SeqCst)).sum();
        shared.peak.fetch_max(round_sum, Ordering::SeqCst);
    }
}

/// The conservative parallel engine. See the module docs for the
/// protocol, the safety/progress arguments, and the determinism
/// contract.
pub struct ShardedSim<S, E> {
    nparts: u32,
    nshards: usize,
    part_shard: Vec<u32>,
    /// `nshards × nshards` matrix: the minimum part-pair floor between
    /// two shards — what the horizon computation may safely assume about
    /// any message from `t` to `me`.
    shard_la: Vec<u64>,
    la: Lookahead,
    runners: Vec<ShardRunner<S, E>>,
    budget: u64,
    /// Max over rounds of the summed per-shard round peaks.
    peak: usize,
}

impl<S: Send, E: ShardEvent<S>> ShardedSim<S, E> {
    /// Partition `states` (one per part, in global part order) into
    /// `shards` contiguous blocks. `shards` is clamped to `[1, parts]`;
    /// `la` must cover every part pair.
    pub fn new(states: Vec<S>, la: Lookahead, shards: usize) -> Self {
        let nparts = states.len();
        assert!(nparts > 0, "a sharded sim needs at least one part");
        assert!(nparts < (1 << 16), "part index space is 16 bits");
        assert_eq!(la.parts(), nparts, "lookahead table must cover every part");
        let nshards = shards.clamp(1, nparts);
        let part_shard: Vec<u32> =
            (0..nparts).map(|p| (p * nshards / nparts) as u32).collect();

        let mut shard_la = vec![u64::MAX; nshards * nshards];
        for a in 0..nparts {
            for b in 0..nparts {
                let (s, t) = (part_shard[a] as usize, part_shard[b] as usize);
                if s != t {
                    let f = la.floor(a, b);
                    let e = &mut shard_la[s * nshards + t];
                    if f < *e {
                        *e = f;
                    }
                }
            }
        }

        let mut runners: Vec<ShardRunner<S, E>> = (0..nshards)
            .map(|s| ShardRunner {
                shard: s as u32,
                part_base: 0,
                parts: Vec::new(),
                queue: SlabQueue::new(),
                outbox: (0..nshards).map(|_| Vec::new()).collect(),
                now: 0,
                events: 0,
                peak_pending: 0,
                round_peak: 0,
                clock: ShardClock::default(),
            })
            .collect();
        for (p, state) in states.into_iter().enumerate() {
            let r = &mut runners[part_shard[p] as usize];
            if r.parts.is_empty() {
                r.part_base = p as u32;
            }
            r.parts.push(PartCell { state, born_seq: 0, events: 0, digest: FNV_OFFSET });
        }
        debug_assert!(runners.iter().all(|r| !r.parts.is_empty()), "empty shard");

        ShardedSim {
            nparts: nparts as u32,
            nshards,
            part_shard,
            shard_la,
            la,
            runners,
            budget: DEFAULT_EVENT_BUDGET,
            peak: 0,
        }
    }

    pub fn num_parts(&self) -> usize {
        self.nparts as usize
    }

    pub fn num_shards(&self) -> usize {
        self.nshards
    }

    pub fn lookahead(&self) -> &Lookahead {
        &self.la
    }

    /// Configure the runaway guard (default
    /// [`DEFAULT_EVENT_BUDGET`]): a run that exceeds it exits the round
    /// protocol collectively and panics with diagnostics.
    pub fn set_event_budget(&mut self, budget: u64) {
        self.budget = budget;
    }

    /// Seed an event for `part` at absolute time `time` (before running).
    pub fn seed(&mut self, part: usize, time: SimTime, ev: E) {
        assert!(part < self.nparts as usize, "seed for unknown part {part}");
        let r = &mut self.runners[self.part_shard[part] as usize];
        let key = {
            let cell = &mut r.parts[part - r.part_base as usize];
            let k = canonical_key(part as u32, cell.born_seq);
            cell.born_seq += 1;
            k
        };
        r.queue.schedule(time, key, (part as u32, ev));
    }

    /// Drain every queue: one thread per shard when `num_shards() > 1`,
    /// the serial twin otherwise. Panics if the event budget is
    /// exceeded, and resumes any handler panic on this thread.
    pub fn run(&mut self) {
        if self.nshards <= 1 {
            self.run_rounds_serial();
        } else {
            self.run_parallel();
        }
        self.enforce_budget();
    }

    /// The executable golden twin: the *identical* round/horizon math on
    /// one thread, no atomics, no barriers. `run()` must match it
    /// bit-for-bit (digest and per-part event counts) for every shard
    /// count — the differential pin `rust/tests/shard_sim.rs` enforces.
    pub fn run_serial(&mut self) {
        self.run_rounds_serial();
        self.enforce_budget();
    }

    fn run_rounds_serial(&mut self) {
        let n = self.nshards;
        let mut inbox: Vec<Vec<Msg<E>>> = (0..n * n).map(|_| Vec::new()).collect();
        let mut nexts = vec![0u64; n];
        loop {
            for me in 0..n {
                for src in 0..n {
                    if src == me {
                        continue;
                    }
                    let msgs = std::mem::take(&mut inbox[me * n + src]);
                    let r = &mut self.runners[me];
                    for m in msgs {
                        r.deliver(m);
                    }
                }
                nexts[me] = self.runners[me].next_time();
            }
            let gmin = nexts.iter().copied().min().unwrap_or(u64::MAX);
            let total: u64 = self.runners.iter().map(|r| r.events).sum();
            if gmin == u64::MAX || total > self.budget {
                break;
            }
            for me in 0..n {
                let mut h = u64::MAX;
                for t in 0..n {
                    if t != me {
                        h = h.min(nexts[t].saturating_add(self.shard_la[t * n + me]));
                    }
                }
                let env = ShardEnv {
                    part_shard: &self.part_shard,
                    la: &self.la,
                    nparts: self.nparts,
                };
                let r = &mut self.runners[me];
                r.exec_round(h, self.budget.saturating_add(1), &env);
                for dst in 0..n {
                    if dst != me && !r.outbox[dst].is_empty() {
                        let msgs = std::mem::take(&mut r.outbox[dst]);
                        inbox[dst * n + me].extend(msgs);
                    }
                }
            }
            // Same reduction the parallel workers perform after the round
            // barrier: the summed per-shard round peaks, maxed per run.
            let round_sum: usize = self.runners.iter().map(|r| r.round_peak).sum();
            if round_sum > self.peak {
                self.peak = round_sum;
            }
        }
    }

    fn run_parallel(&mut self) {
        let n = self.nshards;
        let shared: Shared<E> = Shared {
            next: (0..n).map(|_| AtomicU64::new(0)).collect(),
            executed: (0..n).map(|_| AtomicU64::new(0)).collect(),
            round_peak: (0..n).map(|_| AtomicU64::new(0)).collect(),
            peak: AtomicU64::new(self.peak as u64),
            inbox: (0..n * n).map(|_| Mutex::new(Vec::new())).collect(),
            poisoned: AtomicBool::new(false),
            panics: Mutex::new(Vec::new()),
            barrier: Barrier::new(n),
        };
        let env = ShardEnv { part_shard: &self.part_shard, la: &self.la, nparts: self.nparts };
        let shard_la: &[u64] = &self.shard_la;
        let budget = self.budget;
        let shared_ref = &shared;
        std::thread::scope(|scope| {
            for r in self.runners.iter_mut() {
                scope.spawn(move || worker(r, shared_ref, env, shard_la, n, budget));
            }
        });
        self.peak = self.peak.max(shared.peak.load(Ordering::SeqCst) as usize);
        if shared.poisoned.load(Ordering::SeqCst) {
            match shared.panics.lock().unwrap().pop() {
                Some(p) => std::panic::resume_unwind(p),
                None => panic!("shard worker poisoned the run without a payload"),
            }
        }
    }

    fn enforce_budget(&mut self) {
        let total = self.events_processed();
        if total <= self.budget {
            return;
        }
        let pending: usize = self.runners.iter().map(|r| r.queue.pending()).sum();
        let next = self
            .runners
            .iter_mut()
            .filter_map(|r| r.queue.next_key().map(|(t, _)| (t, r.shard)))
            .min();
        match next {
            Some((t, shard)) => panic!(
                "shard sim event budget exhausted: {total} events executed and {pending} \
                 still queued; next event at t={t}ms on shard {shard} — runaway \
                 self-rearming event? Raise ShardedSim::set_event_budget if the schedule \
                 is legitimate"
            ),
            None => panic!("shard sim event budget exhausted: {total} events executed"),
        }
    }

    fn cell(&self, part: usize) -> &PartCell<S> {
        let r = &self.runners[self.part_shard[part] as usize];
        &r.parts[part - r.part_base as usize]
    }

    /// Shared read access to a part's state (between/after runs).
    pub fn part_state(&self, part: usize) -> &S {
        &self.cell(part).state
    }

    /// Events executed against `part`.
    pub fn part_events(&self, part: usize) -> u64 {
        self.cell(part).events
    }

    /// Total events executed across all shards.
    pub fn events_processed(&self) -> u64 {
        self.runners.iter().map(|r| r.events).sum()
    }

    /// Fleet-wide pending-queue high-water mark: the largest *summed*
    /// per-shard queue depth any round observed (see the module docs'
    /// "Queue-depth reporting"). Identical between `run()` and
    /// `run_serial()` at every shard count; a 1-shard run degenerates to
    /// the sequential engine's per-pop high-water mark.
    pub fn peak_pending(&self) -> usize {
        self.peak
    }

    /// One shard's own lifetime queue-depth peak (diagnostics).
    pub fn shard_peak_pending(&self, shard: usize) -> usize {
        self.runners[shard].peak_pending
    }

    /// Maximum shard-local virtual time reached.
    pub fn now(&self) -> SimTime {
        self.runners.iter().map(|r| r.now).max().unwrap_or(0)
    }

    /// Steps counted by one shard's thread-safe clock.
    pub fn shard_clock(&self, shard: usize) -> &ShardClock {
        &self.runners[shard].clock
    }

    /// The run's determinism digest: an order-sensitive FNV-1a fold of
    /// every part's `(events, executed-stream digest)` in global part
    /// order. Identical for any shard count and any thread interleaving
    /// of the same seeded schedule.
    pub fn digest(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for p in 0..self.nparts as usize {
            let c = self.cell(p);
            h = fold(h, c.events);
            h = fold(h, c.digest);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix(a: u64, b: u64) -> u64 {
        let mut x = a.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ b.wrapping_add(0x2545_f491_4f6c_dd1d);
        x ^= x >> 30;
        x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
        x ^= x >> 27;
        x
    }

    /// A hop chain: accumulate a hash into the part's counter, then
    /// either stay local or cross to another part, deterministically
    /// derived from (part, left) so order at time ties is irrelevant.
    struct Hop {
        left: u32,
        stride: u32,
    }

    impl ShardEvent<u64> for Hop {
        fn apply(self, ctx: &mut ShardCtx<'_, u64, Self>) {
            let m = mix(ctx.part() as u64, self.left as u64);
            *ctx.state = ctx.state.wrapping_add(m);
            if self.left == 0 {
                return;
            }
            let next = Hop { left: self.left - 1, stride: self.stride };
            if m % 3 == 0 {
                let to = (ctx.part() + self.stride as usize) % ctx.nparts();
                if to != ctx.part() {
                    ctx.send(to, m % 9, next);
                    return;
                }
            }
            ctx.schedule_in(1 + m % 13, next);
        }

        fn kind(&self) -> &'static str {
            "hop"
        }
    }

    fn run_hops(nshards: usize, serial: bool) -> (u64, u64, Vec<u64>) {
        const PARTS: usize = 4;
        let la = Lookahead::from_fn(PARTS, |a, b| if a == b { 1 } else { 15 });
        let mut sim: ShardedSim<u64, Hop> =
            ShardedSim::new(vec![0u64; PARTS], la, nshards);
        for p in 0..PARTS {
            for c in 0..8u32 {
                sim.seed(p, (c as u64) % 5, Hop { left: 40, stride: 1 + c % 3 });
            }
        }
        if serial {
            sim.run_serial();
        } else {
            sim.run();
        }
        let states = (0..PARTS).map(|p| *sim.part_state(p)).collect();
        (sim.digest(), sim.events_processed(), states)
    }

    /// The tentpole pin: digest, event count, and final states are
    /// identical for every shard count — parallel or serial.
    #[test]
    fn digest_invariant_across_shard_counts_and_modes() {
        let golden = run_hops(1, true);
        assert!(golden.1 > 1000, "workload must be non-trivial: {} events", golden.1);
        for nshards in [1usize, 2, 3, 4] {
            assert_eq!(run_hops(nshards, true), golden, "serial, {nshards} shards");
            assert_eq!(run_hops(nshards, false), golden, "parallel, {nshards} shards");
        }
    }

    #[test]
    fn parallel_runs_are_reproducible() {
        assert_eq!(run_hops(4, false), run_hops(4, false));
    }

    fn run_hops_peak(nshards: usize, serial: bool) -> usize {
        const PARTS: usize = 4;
        let la = Lookahead::from_fn(PARTS, |a, b| if a == b { 1 } else { 15 });
        let mut sim: ShardedSim<u64, Hop> = ShardedSim::new(vec![0u64; PARTS], la, nshards);
        for p in 0..PARTS {
            for c in 0..8u32 {
                sim.seed(p, (c as u64) % 5, Hop { left: 40, stride: 1 + c % 3 });
            }
        }
        if serial {
            sim.run_serial();
        } else {
            sim.run();
        }
        sim.peak_pending()
    }

    /// The queue-depth metric is a round-protocol quantity, so the
    /// parallel run must report exactly the serial twin's value at every
    /// shard count (no per-thread timing may leak into it).
    #[test]
    fn peak_pending_sums_shards_and_matches_the_serial_twin() {
        for nshards in [1usize, 2, 3, 4] {
            let s = run_hops_peak(nshards, true);
            let p = run_hops_peak(nshards, false);
            assert!(s > 0, "workload must queue something at {nshards} shards");
            assert_eq!(s, p, "peak_pending run() vs run_serial() at {nshards} shards");
        }
    }

    /// Cross-shard sends arrive at exactly `now + floor + extra`.
    struct Stamp {
        forward: bool,
    }

    impl ShardEvent<Vec<SimTime>> for Stamp {
        fn apply(self, ctx: &mut ShardCtx<'_, Vec<SimTime>, Self>) {
            let now = ctx.now();
            ctx.state.push(now);
            if self.forward {
                ctx.send(1, 3, Stamp { forward: false });
            }
        }
    }

    #[test]
    fn send_pays_the_lookahead_floor() {
        let la = Lookahead::uniform(2, 10);
        let mut sim: ShardedSim<Vec<SimTime>, Stamp> =
            ShardedSim::new(vec![Vec::new(), Vec::new()], la, 2);
        sim.seed(0, 5, Stamp { forward: true });
        sim.run();
        assert_eq!(sim.part_state(0), &vec![5]);
        assert_eq!(sim.part_state(1), &vec![5 + 10 + 3], "arrival = now + floor + extra");
        assert_eq!(sim.events_processed(), 2);
    }

    /// A same-time self-rearming event trips the collective budget exit
    /// and the run panics with diagnostics instead of spinning.
    struct Rearm;

    impl ShardEvent<u64> for Rearm {
        fn apply(self, ctx: &mut ShardCtx<'_, u64, Self>) {
            *ctx.state += 1;
            ctx.schedule_in(0, Rearm);
        }

        fn kind(&self) -> &'static str {
            "rearm"
        }
    }

    #[test]
    fn runaway_schedule_trips_the_budget_collectively() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            let la = Lookahead::uniform(2, 5);
            let mut sim: ShardedSim<u64, Rearm> = ShardedSim::new(vec![0, 0], la, 2);
            sim.set_event_budget(10_000);
            sim.seed(0, 1, Rearm);
            sim.run();
        }));
        let err = result.expect_err("a runaway schedule must panic, not hang");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("budget exhausted"), "{msg}");
    }

    /// A panicking handler must not deadlock the barrier protocol: the
    /// panic is captured, every worker exits, and `run()` resumes it.
    struct Bomb;

    impl ShardEvent<u64> for Bomb {
        fn apply(self, _ctx: &mut ShardCtx<'_, u64, Self>) {
            panic!("boom in a shard handler");
        }
    }

    #[test]
    fn handler_panic_propagates_instead_of_deadlocking() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            let la = Lookahead::uniform(4, 5);
            let mut sim: ShardedSim<u64, Bomb> = ShardedSim::new(vec![0; 4], la, 4);
            sim.seed(2, 7, Bomb);
            sim.run();
        }));
        let err = result.expect_err("the handler panic must reach the caller");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("boom"), "{msg}");
    }
}
