//! Deterministic discrete-event simulation core.
//!
//! The whole geo-distributed testbed (four data centers, WAN, spot market,
//! masters, job managers) runs on this engine: a virtual millisecond clock
//! and a binary-heap event queue with a monotone tie-breaking sequence
//! number, so a run is a pure function of (config, seed). Events are boxed
//! `FnOnce(&mut Sim<S>)` closures over the world state `S`; an event may
//! freely inspect/mutate the state and schedule further events.
//!
//! Events can be cancelled (heartbeat timers, speculative timeouts) via the
//! [`EventId`] returned by `schedule_*`; cancelled entries are lazily
//! skipped at pop time.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet};

/// Virtual time in milliseconds since simulation start.
pub type SimTime = u64;

/// Convert seconds (paper units) to [`SimTime`].
pub const fn secs(s: u64) -> SimTime {
    s * 1000
}

/// Convert fractional seconds to [`SimTime`] (rounded).
pub fn secs_f(s: f64) -> SimTime {
    (s * 1000.0).round().max(0.0) as SimTime
}

/// [`SimTime`] to fractional seconds.
pub fn to_secs(t: SimTime) -> f64 {
    t as f64 / 1000.0
}

/// Handle for cancelling a scheduled event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

type EventFn<S> = Box<dyn FnOnce(&mut Sim<S>)>;
type StepHook<S> = Box<dyn FnMut(&mut S, SimTime)>;

struct Entry<S> {
    time: SimTime,
    seq: u64,
    f: EventFn<S>,
}

impl<S> PartialEq for Entry<S> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<S> Eq for Entry<S> {}
impl<S> PartialOrd for Entry<S> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<S> Ord for Entry<S> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert so the earliest (time, seq) pops
        // first. seq keeps same-time events FIFO => deterministic replay.
        other.time.cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// The simulation engine over world state `S`.
pub struct Sim<S> {
    /// The world; event closures mutate it.
    pub state: S,
    now: SimTime,
    seq: u64,
    queue: BinaryHeap<Entry<S>>,
    /// Seqs scheduled and neither fired nor cancelled yet. Keeping the
    /// live set explicit (instead of `queue.len() - cancelled.len()`)
    /// makes cancel-after-fire a true no-op and [`Sim::pending`] exact.
    live: HashSet<u64>,
    cancelled: HashSet<u64>,
    /// Called after the clock advances to each event's time, before the
    /// event closure runs (the trace bus rides on this).
    hook: Option<StepHook<S>>,
    /// Total events executed (for perf accounting / runaway detection).
    pub events_processed: u64,
}

impl<S> Sim<S> {
    pub fn new(state: S) -> Self {
        Sim {
            state,
            now: 0,
            seq: 0,
            queue: BinaryHeap::new(),
            live: HashSet::new(),
            cancelled: HashSet::new(),
            hook: None,
            events_processed: 0,
        }
    }

    /// Install the per-step hook: it observes `(state, time)` right after
    /// the clock advances to an event's timestamp and right before the
    /// event closure runs, so anything the closure does can rely on the
    /// hook having seen the current time.
    pub fn set_step_hook(&mut self, hook: impl FnMut(&mut S, SimTime) + 'static) {
        self.hook = Some(Box::new(hook));
    }

    /// Current virtual time (ms).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Current virtual time in seconds.
    #[inline]
    pub fn now_secs(&self) -> f64 {
        to_secs(self.now)
    }

    /// Number of pending (non-cancelled, not-yet-fired) events.
    pub fn pending(&self) -> usize {
        self.live.len()
    }

    /// Schedule `f` at absolute virtual time `t` (clamped to now).
    pub fn schedule_at(&mut self, t: SimTime, f: impl FnOnce(&mut Sim<S>) + 'static) -> EventId {
        let t = t.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.live.insert(seq);
        self.queue.push(Entry { time: t, seq, f: Box::new(f) });
        EventId(seq)
    }

    /// Schedule `f` after `delay` ms.
    pub fn schedule_in(
        &mut self,
        delay: SimTime,
        f: impl FnOnce(&mut Sim<S>) + 'static,
    ) -> EventId {
        self.schedule_at(self.now + delay, f)
    }

    /// Schedule `f` to run "immediately" (after currently-queued same-time
    /// events — useful for decoupling call stacks).
    pub fn defer(&mut self, f: impl FnOnce(&mut Sim<S>) + 'static) -> EventId {
        self.schedule_at(self.now, f)
    }

    /// Cancel a scheduled event. A true no-op after the event has fired
    /// (or was already cancelled). Returns whether the id was newly
    /// cancelled — i.e. whether it was still live.
    pub fn cancel(&mut self, id: EventId) -> bool {
        if self.live.remove(&id.0) {
            self.cancelled.insert(id.0);
            true
        } else {
            false
        }
    }

    fn pop_live(&mut self) -> Option<Entry<S>> {
        while let Some(e) = self.queue.pop() {
            if self.cancelled.remove(&e.seq) {
                continue;
            }
            self.live.remove(&e.seq);
            return Some(e);
        }
        None
    }

    /// Execute the next event; returns false when the queue is empty.
    pub fn step(&mut self) -> bool {
        match self.pop_live() {
            Some(e) => {
                debug_assert!(e.time >= self.now, "time went backwards");
                self.now = e.time;
                self.events_processed += 1;
                if let Some(hook) = self.hook.as_mut() {
                    hook(&mut self.state, e.time);
                }
                (e.f)(self);
                true
            }
            None => false,
        }
    }

    /// Run until the queue is empty or `max_events` have been processed.
    /// Returns the number of events executed.
    pub fn run(&mut self, max_events: u64) -> u64 {
        let start = self.events_processed;
        while self.events_processed - start < max_events {
            if !self.step() {
                break;
            }
        }
        self.events_processed - start
    }

    /// Run until virtual time reaches `t` (events at exactly `t` included)
    /// or the queue empties. The clock is advanced to `t` at the end.
    pub fn run_until(&mut self, t: SimTime) {
        loop {
            let next = loop {
                match self.queue.peek() {
                    Some(e) if self.cancelled.contains(&e.seq) => {
                        let e = self.queue.pop().unwrap();
                        self.cancelled.remove(&e.seq);
                    }
                    Some(e) => break Some(e.time),
                    None => break None,
                }
            };
            match next {
                Some(nt) if nt <= t => {
                    self.step();
                }
                _ => break,
            }
        }
        self.now = self.now.max(t);
    }

    /// Drain the queue entirely (with a generous runaway guard).
    pub fn run_to_completion(&mut self) {
        let n = self.run(u64::MAX / 2);
        let _ = n;
    }
}

/// Periodic timer helper: reschedules itself every `period` ms until the
/// predicate returns false. The closure receives the sim.
pub fn every<S: 'static>(
    sim: &mut Sim<S>,
    period: SimTime,
    mut tick: impl FnMut(&mut Sim<S>) -> bool + 'static,
) {
    fn arm<S: 'static>(
        sim: &mut Sim<S>,
        period: SimTime,
        mut tick: impl FnMut(&mut Sim<S>) -> bool + 'static,
    ) {
        sim.schedule_in(period, move |sim| {
            if tick(sim) {
                arm(sim, period, tick);
            }
        });
    }
    if tick(sim) {
        arm(sim, period, tick);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Sim::new(Vec::<u32>::new());
        sim.schedule_at(secs(3), |s| s.state.push(3));
        sim.schedule_at(secs(1), |s| s.state.push(1));
        sim.schedule_at(secs(2), |s| s.state.push(2));
        sim.run_to_completion();
        assert_eq!(sim.state, vec![1, 2, 3]);
        assert_eq!(sim.now(), secs(3));
    }

    #[test]
    fn same_time_events_are_fifo() {
        let mut sim = Sim::new(Vec::<u32>::new());
        for i in 0..100 {
            sim.schedule_at(secs(5), move |s| s.state.push(i));
        }
        sim.run_to_completion();
        assert_eq!(sim.state, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        let mut sim = Sim::new(0u64);
        sim.schedule_at(10, |s| {
            s.state += 1;
            s.schedule_in(5, |s| s.state += 10);
        });
        sim.run_to_completion();
        assert_eq!(sim.state, 11);
        assert_eq!(sim.now(), 15);
    }

    #[test]
    fn cancellation_skips_event() {
        let mut sim = Sim::new(0u64);
        let id = sim.schedule_at(10, |s| s.state += 1);
        sim.schedule_at(5, |s| s.state += 100);
        assert!(sim.cancel(id));
        assert!(!sim.cancel(id), "double-cancel is a no-op");
        sim.run_to_completion();
        assert_eq!(sim.state, 100);
    }

    #[test]
    fn run_until_stops_at_boundary() {
        let mut sim = Sim::new(Vec::<u64>::new());
        for t in [5u64, 10, 15, 20] {
            sim.schedule_at(t, move |s| {
                let now = s.now();
                s.state.push(now);
            });
        }
        sim.run_until(12);
        assert_eq!(sim.state, vec![5, 10]);
        assert_eq!(sim.now(), 12);
        sim.run_until(20);
        assert_eq!(sim.state, vec![5, 10, 15, 20]);
    }

    #[test]
    fn periodic_timer_repeats_until_false() {
        let counter = Rc::new(RefCell::new(0));
        let c2 = counter.clone();
        let mut sim = Sim::new(());
        every(&mut sim, secs(1), move |_| {
            *c2.borrow_mut() += 1;
            *c2.borrow() < 5
        });
        sim.run_to_completion();
        assert_eq!(*counter.borrow(), 5);
        assert_eq!(sim.now(), secs(4));
    }

    #[test]
    fn determinism_across_runs() {
        fn run_once() -> (Vec<u32>, SimTime) {
            let mut sim = Sim::new(Vec::new());
            let mut rng = crate::util::Pcg::seeded(99);
            for i in 0..500u32 {
                let t = rng.below(10_000);
                sim.schedule_at(t, move |s| s.state.push(i));
            }
            sim.run_to_completion();
            let now = sim.now();
            (sim.state, now)
        }
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn cancel_after_fire_is_a_true_noop() {
        // Regression: cancelling an already-fired event used to park its
        // seq in `cancelled` forever, underflowing `pending()`.
        let mut sim = Sim::new(0u64);
        let id = sim.schedule_at(10, |s| s.state += 1);
        assert_eq!(sim.pending(), 1);
        sim.run_to_completion();
        assert_eq!(sim.state, 1);
        assert_eq!(sim.pending(), 0);
        assert!(!sim.cancel(id), "cancelling a fired event must report false");
        assert_eq!(sim.pending(), 0, "stale cancel must not corrupt pending()");
        // The sim keeps working normally afterwards.
        let id2 = sim.schedule_at(20, |s| s.state += 10);
        assert_eq!(sim.pending(), 1);
        sim.run_to_completion();
        assert_eq!(sim.state, 11);
        assert_eq!(sim.pending(), 0);
        assert!(!sim.cancel(id2));
    }

    #[test]
    fn pending_counts_only_live_events() {
        let mut sim = Sim::new(());
        let ids: Vec<EventId> = (0..10u64).map(|t| sim.schedule_at(t, |_| {})).collect();
        assert_eq!(sim.pending(), 10);
        for id in &ids[..5] {
            assert!(sim.cancel(*id));
        }
        assert_eq!(sim.pending(), 5);
        sim.run_to_completion();
        assert_eq!(sim.pending(), 0);
        for id in ids {
            assert!(!sim.cancel(id), "nothing is live after the run");
        }
        assert_eq!(sim.pending(), 0);
    }

    #[test]
    fn step_hook_runs_before_each_event() {
        // The hook must see each event's time before its closure runs, so
        // closures can rely on hook-maintained state (the trace clock).
        let mut sim = Sim::new((0 as SimTime, Vec::<bool>::new()));
        sim.set_step_hook(|s, now| s.0 = now);
        for t in [3u64, 7, 7, 12] {
            sim.schedule_at(t, move |sim| {
                let seen = sim.state.0 == t;
                sim.state.1.push(seen);
            });
        }
        sim.run_to_completion();
        assert_eq!(sim.state.1, vec![true; 4]);
    }

    #[test]
    fn run_respects_event_budget() {
        let mut sim = Sim::new(0u64);
        for t in 0..100 {
            sim.schedule_at(t, |s| s.state += 1);
        }
        let n = sim.run(10);
        assert_eq!(n, 10);
        assert_eq!(sim.state, 10);
    }
}
