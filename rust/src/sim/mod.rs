//! Deterministic discrete-event simulation core.
//!
//! The whole geo-distributed testbed (four data centers, WAN, spot market,
//! masters, job managers) runs on this engine: a virtual millisecond clock
//! and an event queue with a monotone tie-breaking sequence number, so a
//! run is a pure function of (config, seed). An event payload is a
//! [`Payload`]: either a **typed** value of the sim's event vocabulary
//! `E` (a plain enum the engine dispatches through [`Dispatch`] — no heap
//! allocation on the common path) or a **custom** boxed
//! `FnOnce(&mut Sim<S, E>)` closure for the rare bespoke case (tests,
//! [`every`] ticks, probe loops that carry ad-hoc state). An event may
//! freely inspect/mutate the state and schedule further events.
//!
//! # Typed events
//!
//! A sim is `Sim<S, E>` where `E: Dispatch<S>` is its event vocabulary;
//! plain `Sim<S>` defaults to the empty vocabulary [`NoEvent`] so
//! closure-only sims (unit tests, micro-benches) stay as before. The
//! deployment stack's vocabulary is `deploy::events::SimEvent` — the
//! full taxonomy (job lifecycle, scheduling ticks, steal protocol,
//! failure detection/recovery, WAN transfer completions, chaos
//! injections) is documented there. Typed events buy two things over
//! boxed closures:
//!
//! * **No allocator round-trip per event.** The payload is stored inline
//!   in the queue slab; scheduling the common event shapes allocates
//!   nothing (beyond what the event itself owns).
//! * **Serializability.** The executed `(time, seq, event)` stream can
//!   be persisted (`houtu campaign --record`) and lockstep-verified
//!   against a re-execution (`houtu replay`); custom closures are opaque
//!   and appear in the log as `"ev":"custom"` markers. The event-log
//!   schema is documented in `scenario::replay`.
//!
//! # Queue invariants
//!
//! The hot path is the queue, so its contract is spelled out here and
//! enforced by the property/differential suites (`rust/tests/sim_queue.rs`,
//! `rust/tests/golden_digests.rs`); both engines in [`queue`] implement it:
//!
//! 1. **Total order.** Events pop in strictly increasing `(time, seq)`
//!    order, where `seq` is the per-sim monotone schedule counter. Since
//!    `seq` is unique, same-time events are FIFO in schedule order —
//!    the determinism contract every replay digest pins.
//! 2. **Exact `pending()`.** `pending()` counts exactly the events that
//!    were scheduled and have neither fired nor been cancelled — it is a
//!    maintained counter, never `heap_len - tombstones`.
//! 3. **Cancel is O(1) and cancel-after-fire is a true no-op.**
//!    [`Sim::cancel`] returns `true` iff the event was still live; a
//!    stale [`EventId`] (fired, cancelled, or its slot since reused)
//!    returns `false` and perturbs nothing.
//! 4. **No time travel.** `schedule_at` clamps to `now`; the clock never
//!    moves backwards.
//! 5. **Horizon boundary.** [`Sim::run_until`]`(t)` executes every event
//!    with timestamp `≤ t` — including events scheduled *at exactly `t`*
//!    by other events firing at `t` (periodic re-arms landing on the
//!    horizon included) — before stopping, then leaves the clock at `t`.
//!
//! The production engine ([`queue::SlabQueue`]) keeps event payloads in a
//! generation-stamped slab and orders bare `(time, seq, slot)` triples in
//! an index-only 4-ary heap: cancels vacate the slot in O(1) and stale
//! heap entries are skipped lazily at pop, so no tombstone sets exist.
//! The pre-overhaul engine ([`queue::LegacyQueue`]) is vendored as the
//! executable golden baseline; [`Sim::with_queue`] selects at runtime so
//! differential tests and `houtu bench` replay identical schedules on
//! both.
//!
//! # Step clock
//!
//! The trace bus used to ride a boxed per-event step hook (a dynamic
//! dispatch + `RefCell` borrow per event just to advance a clock). The
//! sim now updates an optional shared [`StepClock`] inline — two `Cell`
//! stores — and the tracer reads it lazily when an event is actually
//! published; the boxed [`Sim::set_step_hook`] remains for consumers
//! that need to observe state between events.

use std::cell::Cell;
use std::rc::Rc;

pub mod queue;
pub mod shard;

pub use queue::{LegacyQueue, Popped, QueueKind, ShardedQueue, SlabQueue};
pub use shard::{Lookahead, ShardClock, ShardCtx, ShardEvent, ShardedSim};
use queue::QueueImpl;

/// Virtual time in milliseconds since simulation start.
pub type SimTime = u64;

/// Convert seconds (paper units) to [`SimTime`].
pub const fn secs(s: u64) -> SimTime {
    s * 1000
}

/// Convert fractional seconds to [`SimTime`] (rounded).
pub fn secs_f(s: f64) -> SimTime {
    (s * 1000.0).round().max(0.0) as SimTime
}

/// [`SimTime`] to fractional seconds.
pub fn to_secs(t: SimTime) -> f64 {
    t as f64 / 1000.0
}

/// Handle for cancelling a scheduled event. Opaque: the slab engine packs
/// `(slot, generation)` into it, the legacy engine packs the schedule
/// seq; ids are only meaningful to the sim that issued them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

impl EventId {
    /// Slab encoding: generation in the high 32 bits, slot in the low.
    pub(crate) fn pack(slot: u32, gen: u32) -> EventId {
        EventId(((gen as u64) << 32) | slot as u64)
    }

    pub(crate) fn unpack(self) -> (u32, u32) {
        (self.0 as u32, (self.0 >> 32) as u32)
    }

    /// Legacy encoding: the raw schedule seq.
    pub(crate) fn pack_seq(seq: u64) -> EventId {
        EventId(seq)
    }

    pub(crate) fn raw(self) -> u64 {
        self.0
    }
}

/// Boxed event closure over world state `S` (the `Custom` payload).
pub type EventFn<S, E = NoEvent> = Box<dyn FnOnce(&mut Sim<S, E>)>;

/// A typed event vocabulary the engine can execute. `dispatch` consumes
/// the event and performs its effect against the sim; `kind` is a cheap
/// static tag used by diagnostics (the runaway-guard panic) and the
/// event log.
pub trait Dispatch<S>: Sized {
    fn dispatch(self, sim: &mut Sim<S, Self>);
    fn kind(&self) -> &'static str;

    /// Topology shard this event belongs to — the DC whose state it
    /// mutates — or `None` for global events (ticks, chaos sweeps,
    /// custom closures). [`QueueKind::Sharded`] routes on it; the flat
    /// engines ignore it, so the default costs nothing elsewhere.
    fn affinity(&self) -> Option<usize> {
        None
    }
}

/// The empty event vocabulary — the default for closure-only sims.
/// Uninhabited, so the typed arm of [`Payload`] is statically dead and
/// `Sim<S>` behaves exactly like the pre-typed engine.
pub enum NoEvent {}

impl<S> Dispatch<S> for NoEvent {
    fn dispatch(self, _sim: &mut Sim<S, Self>) {
        match self {}
    }

    fn kind(&self) -> &'static str {
        match *self {}
    }
}

/// What the queue stores per event: a typed value of the sim's event
/// vocabulary (common path — no boxing) or a boxed closure (bespoke
/// path).
pub enum Payload<S, E> {
    Typed(E),
    Custom(EventFn<S, E>),
}

impl<S, E: Dispatch<S>> Payload<S, E> {
    /// Static tag for diagnostics: the typed event's kind, or "custom".
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Typed(e) => e.kind(),
            Payload::Custom(_) => "custom",
        }
    }
}

type StepHook<S> = Box<dyn FnMut(&mut S, SimTime)>;

/// Observer for the executed event stream: called once per step with
/// `(time, seq, Some(&event))` for typed events and `(time, seq, None)`
/// for custom closures (which are opaque). The record/replay layer
/// installs one to persist and to lockstep-verify runs.
type EventRecorder<E> = Box<dyn FnMut(SimTime, u64, Option<&E>)>;

/// Default runaway guard for [`Sim::run_to_completion`]: large enough
/// that no legitimate drain in this repo comes near it (the heaviest
/// campaign cells run low millions of events), small enough that a
/// self-rearming event fails in seconds instead of spinning forever.
/// Override per-sim with [`Sim::set_event_budget`].
pub const DEFAULT_EVENT_BUDGET: u64 = 200_000_000;

/// Shared `(now, steps)` cells the sim advances inline on every step —
/// the zero-dispatch replacement for clock-only step hooks. The trace
/// bus holds one and stamps published events from it lazily, so a step
/// that publishes nothing costs two `Cell` stores and no `RefCell`
/// borrow, no boxed call.
#[derive(Debug, Default)]
pub struct StepClock {
    now: Cell<SimTime>,
    steps: Cell<u64>,
}

impl StepClock {
    /// Advance to an executing event's time and count the step.
    #[inline]
    pub fn advance(&self, t: SimTime) {
        self.now.set(t);
        self.steps.set(self.steps.get() + 1);
    }

    /// Last time advanced to (the stamp clock).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now.get()
    }

    /// Steps counted so far.
    #[inline]
    pub fn steps(&self) -> u64 {
        self.steps.get()
    }
}

/// The simulation engine over world state `S` with typed event
/// vocabulary `E` (default: the empty [`NoEvent`], i.e. closures only).
pub struct Sim<S, E = NoEvent> {
    /// The world; events mutate it.
    pub state: S,
    now: SimTime,
    seq: u64,
    queue: QueueImpl<Payload<S, E>>,
    /// Advanced inline before each event runs (no dynamic dispatch).
    clock: Option<Rc<StepClock>>,
    /// Called after the clock advances to each event's time, before the
    /// event runs.
    hook: Option<StepHook<S>>,
    /// Observes each executed event (record/replay layer).
    recorder: Option<EventRecorder<E>>,
    /// Total events executed (for perf accounting / runaway detection).
    pub events_processed: u64,
    peak_pending: usize,
    event_budget: u64,
}

impl<S> Sim<S> {
    /// A closure-only sim on the production slab queue.
    pub fn new(state: S) -> Self {
        Sim::with_queue(state, QueueKind::Slab)
    }

    /// A closure-only sim on an explicit queue engine (differential
    /// tests and `houtu bench` run the same schedule on both).
    pub fn with_queue(state: S, kind: QueueKind) -> Self {
        Sim::typed_with_queue(state, kind)
    }
}

impl<S, E> Sim<S, E> {
    /// A sim whose queue is partitioned into `shards` topology shards
    /// (shard = DC): events route to the subqueue named by their
    /// [`Dispatch::affinity`] and pop through an exact `(time, seq)`
    /// merge, so the executed stream is bit-identical to the flat
    /// engines for any shard count — pinned over every standard
    /// campaign cell by `rust/tests/golden_digests.rs`.
    pub fn with_topology_shards(state: S, shards: usize) -> Self {
        Sim::typed_with_queue(state, QueueKind::Sharded(shards))
    }

    /// A sim with typed event vocabulary `E` on an explicit queue
    /// engine. (Named distinctly from [`Sim::with_queue`] so closure-only
    /// call sites keep inferring `E = NoEvent`.)
    pub fn typed_with_queue(state: S, kind: QueueKind) -> Self {
        Sim {
            state,
            now: 0,
            seq: 0,
            queue: QueueImpl::new(kind),
            clock: None,
            hook: None,
            recorder: None,
            events_processed: 0,
            peak_pending: 0,
            event_budget: DEFAULT_EVENT_BUDGET,
        }
    }

    /// Which queue engine this sim runs on.
    pub fn queue_kind(&self) -> QueueKind {
        self.queue.kind()
    }

    /// Attach the shared step clock; the sim advances it inline right
    /// before each event closure runs (and before the boxed hook, if
    /// any), so everything the closure publishes sees the event's time.
    pub fn attach_clock(&mut self, clock: Rc<StepClock>) {
        self.clock = Some(clock);
    }

    /// Install the per-step hook: it observes `(state, time)` right after
    /// the clock advances to an event's timestamp and right before the
    /// event closure runs, so anything the closure does can rely on the
    /// hook having seen the current time. Prefer [`Sim::attach_clock`]
    /// when all the hook would do is advance a clock.
    pub fn set_step_hook(&mut self, hook: impl FnMut(&mut S, SimTime) + 'static) {
        self.hook = Some(Box::new(hook));
    }

    /// Install the executed-event observer: called once per step with
    /// `(time, seq, Some(&event))` for typed events, `(time, seq, None)`
    /// for custom closures — *before* the event runs. The record/replay
    /// layer uses this to persist and lockstep-verify runs.
    pub fn set_event_recorder(&mut self, rec: impl FnMut(SimTime, u64, Option<&E>) + 'static) {
        self.recorder = Some(Box::new(rec));
    }

    /// Configure the [`Sim::run_to_completion`] runaway guard (default
    /// [`DEFAULT_EVENT_BUDGET`]): exceeding it panics with the offending
    /// event's time and kind.
    pub fn set_event_budget(&mut self, budget: u64) {
        self.event_budget = budget;
    }

    /// Current virtual time (ms).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Current virtual time in seconds.
    #[inline]
    pub fn now_secs(&self) -> f64 {
        to_secs(self.now)
    }

    /// Number of pending (non-cancelled, not-yet-fired) events.
    pub fn pending(&self) -> usize {
        self.queue.pending()
    }

    /// High-water mark of [`Sim::pending`] over the run so far (the
    /// bench harness reports it as peak queue depth).
    pub fn peak_pending(&self) -> usize {
        self.peak_pending
    }

    /// The one enqueue path: clamp to now, allocate the next seq, track
    /// the pending high-water mark. `affinity` is the event's topology
    /// shard (0 for global/custom events); only [`QueueKind::Sharded`]
    /// routes on it.
    fn enqueue(&mut self, t: SimTime, affinity: usize, payload: Payload<S, E>) -> EventId {
        let t = t.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        let id = self.queue.schedule(t, seq, affinity, payload);
        let live = self.queue.pending();
        if live > self.peak_pending {
            self.peak_pending = live;
        }
        id
    }

    /// Schedule a custom closure at absolute virtual time `t` (clamped
    /// to now).
    pub fn schedule_at(
        &mut self,
        t: SimTime,
        f: impl FnOnce(&mut Sim<S, E>) + 'static,
    ) -> EventId {
        self.enqueue(t, 0, Payload::Custom(Box::new(f)))
    }

    /// Schedule a custom closure after `delay` ms.
    pub fn schedule_in(
        &mut self,
        delay: SimTime,
        f: impl FnOnce(&mut Sim<S, E>) + 'static,
    ) -> EventId {
        self.schedule_at(self.now + delay, f)
    }

    /// Schedule `f` to run "immediately" (after currently-queued same-time
    /// events — useful for decoupling call stacks).
    pub fn defer(&mut self, f: impl FnOnce(&mut Sim<S, E>) + 'static) -> EventId {
        self.schedule_at(self.now, f)
    }

    /// Cancel a scheduled event. A true no-op after the event has fired
    /// (or was already cancelled). Returns whether the id was newly
    /// cancelled — i.e. whether it was still live.
    pub fn cancel(&mut self, id: EventId) -> bool {
        self.queue.cancel(id)
    }

    /// Pop the next event without executing it (runaway diagnostics).
    fn pop_next(&mut self) -> Option<Popped<Payload<S, E>>> {
        self.queue.pop()
    }
}

impl<S, E: Dispatch<S>> Sim<S, E> {
    /// Schedule a typed event at absolute virtual time `t` (clamped to
    /// now) — the allocation-free common path. The event's
    /// [`Dispatch::affinity`] decides its subqueue under
    /// [`QueueKind::Sharded`].
    pub fn schedule_event_at(&mut self, t: SimTime, ev: E) -> EventId {
        let aff = ev.affinity().unwrap_or(0);
        self.enqueue(t, aff, Payload::Typed(ev))
    }

    /// Schedule a typed event after `delay` ms.
    pub fn schedule_event_in(&mut self, delay: SimTime, ev: E) -> EventId {
        self.schedule_event_at(self.now + delay, ev)
    }

    /// Schedule a typed event to run "immediately" (FIFO after
    /// currently-queued same-time events).
    pub fn defer_event(&mut self, ev: E) -> EventId {
        self.schedule_event_at(self.now, ev)
    }

    /// Execute the next event; returns false when the queue is empty.
    pub fn step(&mut self) -> bool {
        match self.queue.pop() {
            Some(e) => {
                debug_assert!(e.time >= self.now, "time went backwards");
                self.now = e.time;
                self.events_processed += 1;
                if let Some(clock) = &self.clock {
                    clock.advance(e.time);
                }
                if let Some(hook) = self.hook.as_mut() {
                    hook(&mut self.state, e.time);
                }
                if let Some(rec) = self.recorder.as_mut() {
                    match &e.payload {
                        Payload::Typed(ev) => rec(e.time, e.seq, Some(ev)),
                        Payload::Custom(_) => rec(e.time, e.seq, None),
                    }
                }
                match e.payload {
                    Payload::Typed(ev) => ev.dispatch(self),
                    Payload::Custom(f) => f(self),
                }
                true
            }
            None => false,
        }
    }

    /// Run until the queue is empty or `max_events` have been processed.
    /// Returns the number of events executed.
    pub fn run(&mut self, max_events: u64) -> u64 {
        let start = self.events_processed;
        while self.events_processed - start < max_events {
            if !self.step() {
                break;
            }
        }
        self.events_processed - start
    }

    /// Run until virtual time reaches `t` or the queue empties, then
    /// advance the clock to `t`. Events at exactly `t` are included —
    /// also ones scheduled *during* the run by other events at `t`, so a
    /// periodic timer whose tick lands exactly on the horizon fires (and
    /// re-arms) before the run stops. Pinned by
    /// `run_until_fires_periodic_event_exactly_at_horizon` below.
    pub fn run_until(&mut self, t: SimTime) {
        while let Some(next) = self.queue.next_time() {
            if next > t {
                break;
            }
            self.step();
        }
        self.now = self.now.max(t);
    }

    /// Drain the queue entirely, guarded by the configurable event
    /// budget ([`Sim::set_event_budget`], default
    /// [`DEFAULT_EVENT_BUDGET`]). A schedule that exceeds the budget
    /// with events still queued — the runaway signature of a
    /// self-rearming event — panics with the next event's time and kind
    /// instead of spinning effectively forever.
    pub fn run_to_completion(&mut self) {
        let budget = self.event_budget;
        let n = self.run(budget);
        if n >= budget {
            if let Some(e) = self.pop_next() {
                panic!(
                    "sim event budget exhausted: {} events executed and {} still queued; \
                     next event is `{}` at t={}ms (seq {}) — runaway self-rearming event? \
                     Raise Sim::set_event_budget if the schedule is legitimate",
                    n,
                    self.queue.pending() + 1,
                    e.payload.kind(),
                    e.time,
                    e.seq,
                );
            }
        }
    }
}

/// Periodic timer helper: reschedules itself every `period` ms until the
/// predicate returns false. The closure receives the sim.
///
/// The first tick is a real queued event at the current time (via
/// [`Sim::defer`]) rather than a synchronous call — so it is counted in
/// `events_processed`, the step clock/hook see it, and it is FIFO-ordered
/// against already-queued same-time events. (It used to run inline at
/// arm time, invisibly to the step hook — the clock stamped its effects
/// with the *previous* event's time.)
pub fn every<S: 'static, E: 'static>(
    sim: &mut Sim<S, E>,
    period: SimTime,
    mut tick: impl FnMut(&mut Sim<S, E>) -> bool + 'static,
) {
    fn arm<S: 'static, E: 'static>(
        sim: &mut Sim<S, E>,
        period: SimTime,
        mut tick: impl FnMut(&mut Sim<S, E>) -> bool + 'static,
    ) {
        sim.schedule_in(period, move |sim| {
            if tick(sim) {
                arm(sim, period, tick);
            }
        });
    }
    sim.defer(move |sim| {
        if tick(sim) {
            arm(sim, period, tick);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Sim::new(Vec::<u32>::new());
        sim.schedule_at(secs(3), |s| s.state.push(3));
        sim.schedule_at(secs(1), |s| s.state.push(1));
        sim.schedule_at(secs(2), |s| s.state.push(2));
        sim.run_to_completion();
        assert_eq!(sim.state, vec![1, 2, 3]);
        assert_eq!(sim.now(), secs(3));
    }

    #[test]
    fn same_time_events_are_fifo() {
        for kind in [QueueKind::Slab, QueueKind::Legacy, QueueKind::Sharded(3)] {
            let mut sim = Sim::with_queue(Vec::<u32>::new(), kind);
            for i in 0..100 {
                sim.schedule_at(secs(5), move |s| s.state.push(i));
            }
            sim.run_to_completion();
            assert_eq!(sim.state, (0..100).collect::<Vec<_>>(), "{:?}", kind);
        }
    }

    #[test]
    fn events_can_schedule_events() {
        let mut sim = Sim::new(0u64);
        sim.schedule_at(10, |s| {
            s.state += 1;
            s.schedule_in(5, |s| s.state += 10);
        });
        sim.run_to_completion();
        assert_eq!(sim.state, 11);
        assert_eq!(sim.now(), 15);
    }

    #[test]
    fn cancellation_skips_event() {
        for kind in [QueueKind::Slab, QueueKind::Legacy, QueueKind::Sharded(2)] {
            let mut sim = Sim::with_queue(0u64, kind);
            let id = sim.schedule_at(10, |s| s.state += 1);
            sim.schedule_at(5, |s| s.state += 100);
            assert!(sim.cancel(id));
            assert!(!sim.cancel(id), "double-cancel is a no-op");
            sim.run_to_completion();
            assert_eq!(sim.state, 100, "{:?}", kind);
        }
    }

    #[test]
    fn run_until_stops_at_boundary() {
        let mut sim = Sim::new(Vec::<u64>::new());
        for t in [5u64, 10, 15, 20] {
            sim.schedule_at(t, move |s| {
                let now = s.now();
                s.state.push(now);
            });
        }
        sim.run_until(12);
        assert_eq!(sim.state, vec![5, 10]);
        assert_eq!(sim.now(), 12);
        sim.run_until(20);
        assert_eq!(sim.state, vec![5, 10, 15, 20]);
    }

    #[test]
    fn periodic_timer_repeats_until_false() {
        let counter = Rc::new(RefCell::new(0));
        let c2 = counter.clone();
        let mut sim = Sim::new(());
        every(&mut sim, secs(1), move |_| {
            *c2.borrow_mut() += 1;
            *c2.borrow() < 5
        });
        sim.run_to_completion();
        assert_eq!(*counter.borrow(), 5);
        assert_eq!(sim.now(), secs(4));
    }

    /// Regression pin for the horizon-boundary semantics (queue
    /// invariant 5): a periodic tick landing exactly on the `run_until`
    /// horizon fires before the run stops — including the re-arm case
    /// where the at-`t` tick schedules the next tick — and ticks beyond
    /// the horizon stay queued for the next run.
    #[test]
    fn run_until_fires_periodic_event_exactly_at_horizon() {
        for kind in [QueueKind::Slab, QueueKind::Legacy] {
            let ticks = Rc::new(RefCell::new(Vec::<SimTime>::new()));
            let t2 = ticks.clone();
            let mut sim = Sim::with_queue((), kind);
            every(&mut sim, secs(10), move |sim| {
                t2.borrow_mut().push(sim.now());
                true
            });
            sim.run_until(secs(30)); // ticks at 0, 10, 20 and exactly 30
            assert_eq!(
                *ticks.borrow(),
                vec![0, secs(10), secs(20), secs(30)],
                "{:?}: the horizon tick must fire before the run stops",
                kind
            );
            assert_eq!(sim.now(), secs(30));
            assert_eq!(sim.pending(), 1, "{:?}: the re-arm at 40s stays queued", kind);
            // A second run picks up exactly where the boundary left off.
            sim.run_until(secs(40));
            assert_eq!(ticks.borrow().last(), Some(&secs(40)), "{:?}", kind);
        }
    }

    /// Same-time chains spawned at the horizon drain before the stop:
    /// an event at `t` defers work to `t`, which defers again — all of
    /// it runs inside `run_until(t)`.
    #[test]
    fn run_until_drains_same_time_chains_at_horizon() {
        for kind in [QueueKind::Slab, QueueKind::Legacy] {
            let mut sim = Sim::with_queue(Vec::<u32>::new(), kind);
            sim.schedule_at(secs(7), |s| {
                s.state.push(1);
                s.defer(|s| {
                    s.state.push(2);
                    s.defer(|s| s.state.push(3));
                });
            });
            sim.run_until(secs(7));
            assert_eq!(sim.state, vec![1, 2, 3], "{:?}", kind);
            assert_eq!(sim.pending(), 0);
        }
    }

    /// `every`'s first tick is a queued event, not a synchronous call:
    /// the step clock and hook observe it, it counts as a step, and it
    /// runs FIFO after same-time events queued before it.
    #[test]
    fn every_first_tick_is_a_real_event() {
        let mut sim = Sim::new(Vec::<&'static str>::new());
        let clock = Rc::new(StepClock::default());
        sim.attach_clock(clock.clone());
        sim.schedule_at(0, |s| s.state.push("queued-first"));
        every(&mut sim, secs(1), |s| {
            s.state.push("tick");
            false
        });
        sim.run_until(0);
        assert_eq!(sim.state, vec!["queued-first", "tick"]);
        assert_eq!(sim.events_processed, 2);
        assert_eq!(clock.steps(), 2, "the first tick must be clock-visible");
    }

    #[test]
    fn determinism_across_runs_and_queue_engines() {
        fn run_once(kind: QueueKind) -> (Vec<u32>, SimTime) {
            let mut sim = Sim::with_queue(Vec::new(), kind);
            let mut rng = crate::util::Pcg::seeded(99);
            for i in 0..500u32 {
                let t = rng.below(10_000);
                sim.schedule_at(t, move |s| s.state.push(i));
            }
            sim.run_to_completion();
            let now = sim.now();
            (sim.state, now)
        }
        assert_eq!(run_once(QueueKind::Slab), run_once(QueueKind::Slab));
        assert_eq!(
            run_once(QueueKind::Slab),
            run_once(QueueKind::Legacy),
            "both engines must replay the same schedule identically"
        );
        for shards in [1usize, 2, 4] {
            assert_eq!(
                run_once(QueueKind::Slab),
                run_once(QueueKind::Sharded(shards)),
                "the {shards}-shard merge must replay the same schedule identically"
            );
        }
    }

    #[test]
    fn cancel_after_fire_is_a_true_noop() {
        // Regression: cancelling an already-fired event used to park its
        // seq in `cancelled` forever, underflowing `pending()`.
        let mut sim = Sim::new(0u64);
        let id = sim.schedule_at(10, |s| s.state += 1);
        assert_eq!(sim.pending(), 1);
        sim.run_to_completion();
        assert_eq!(sim.state, 1);
        assert_eq!(sim.pending(), 0);
        assert!(!sim.cancel(id), "cancelling a fired event must report false");
        assert_eq!(sim.pending(), 0, "stale cancel must not corrupt pending()");
        // The sim keeps working normally afterwards.
        let id2 = sim.schedule_at(20, |s| s.state += 10);
        assert_eq!(sim.pending(), 1);
        sim.run_to_completion();
        assert_eq!(sim.state, 11);
        assert_eq!(sim.pending(), 0);
        assert!(!sim.cancel(id2));
    }

    #[test]
    fn pending_counts_only_live_events() {
        let mut sim = Sim::new(());
        let ids: Vec<EventId> = (0..10u64).map(|t| sim.schedule_at(t, |_| {})).collect();
        assert_eq!(sim.pending(), 10);
        for id in &ids[..5] {
            assert!(sim.cancel(*id));
        }
        assert_eq!(sim.pending(), 5);
        sim.run_to_completion();
        assert_eq!(sim.pending(), 0);
        for id in ids {
            assert!(!sim.cancel(id), "nothing is live after the run");
        }
        assert_eq!(sim.pending(), 0);
    }

    #[test]
    fn peak_pending_tracks_high_water_mark() {
        let mut sim = Sim::new(());
        for t in 0..8u64 {
            sim.schedule_at(t, |_| {});
        }
        assert_eq!(sim.peak_pending(), 8);
        sim.run_to_completion();
        assert_eq!(sim.pending(), 0);
        assert_eq!(sim.peak_pending(), 8, "peak survives the drain");
        sim.schedule_at(100, |_| {});
        assert_eq!(sim.peak_pending(), 8, "lower watermark never lowers the peak");
    }

    #[test]
    fn step_hook_runs_before_each_event() {
        // The hook must see each event's time before its closure runs, so
        // closures can rely on hook-maintained state.
        let mut sim = Sim::new((0 as SimTime, Vec::<bool>::new()));
        sim.set_step_hook(|s, now| s.0 = now);
        for t in [3u64, 7, 7, 12] {
            sim.schedule_at(t, move |sim| {
                let seen = sim.state.0 == t;
                sim.state.1.push(seen);
            });
        }
        sim.run_to_completion();
        assert_eq!(sim.state.1, vec![true; 4]);
    }

    #[test]
    fn attached_clock_advances_before_each_event() {
        let mut sim = Sim::new(Vec::<bool>::new());
        let clock = Rc::new(StepClock::default());
        sim.attach_clock(clock.clone());
        for t in [3u64, 7, 7, 12] {
            let c = clock.clone();
            sim.schedule_at(t, move |sim| {
                sim.state.push(c.now() == t);
            });
        }
        sim.run_to_completion();
        assert_eq!(sim.state, vec![true; 4]);
        assert_eq!(clock.steps(), 4);
    }

    #[test]
    fn run_respects_event_budget() {
        let mut sim = Sim::new(0u64);
        for t in 0..100 {
            sim.schedule_at(t, |s| s.state += 1);
        }
        let n = sim.run(10);
        assert_eq!(n, 10);
        assert_eq!(sim.state, 10);
    }

    /// Minimal typed vocabulary for engine-level tests.
    enum TestEvent {
        Push(u32),
        Chain { next_in: SimTime, value: u32 },
    }

    impl Dispatch<Vec<u32>> for TestEvent {
        fn dispatch(self, sim: &mut Sim<Vec<u32>, TestEvent>) {
            match self {
                TestEvent::Push(v) => sim.state.push(v),
                TestEvent::Chain { next_in, value } => {
                    sim.state.push(value);
                    if value < 3 {
                        sim.schedule_event_in(
                            next_in,
                            TestEvent::Chain { next_in, value: value + 1 },
                        );
                    }
                }
            }
        }

        fn kind(&self) -> &'static str {
            match self {
                TestEvent::Push(_) => "push",
                TestEvent::Chain { .. } => "chain",
            }
        }
    }

    /// Typed and custom events share one queue and one (time, seq)
    /// order: interleavings are FIFO at equal times, and typed events
    /// can re-arm themselves from dispatch.
    #[test]
    fn typed_and_custom_events_interleave_fifo() {
        for kind in [QueueKind::Slab, QueueKind::Legacy, QueueKind::Sharded(2)] {
            let mut sim: Sim<Vec<u32>, TestEvent> = Sim::typed_with_queue(Vec::new(), kind);
            sim.schedule_event_at(5, TestEvent::Push(1));
            sim.schedule_at(5, |s| s.state.push(2));
            sim.schedule_event_at(5, TestEvent::Push(3));
            sim.schedule_event_at(2, TestEvent::Chain { next_in: 10, value: 0 });
            sim.run_to_completion();
            assert_eq!(sim.state, vec![0, 1, 2, 3, 1, 2, 3], "{kind:?}");
            assert_eq!(sim.events_processed, 7, "{kind:?}");
        }
    }

    /// The recorder sees every executed step before it runs: typed
    /// events by reference, custom closures as opaque `None` markers.
    #[test]
    fn recorder_observes_typed_and_custom_steps() {
        let log: Rc<RefCell<Vec<(SimTime, u64, Option<&'static str>)>>> = Rc::default();
        let l2 = log.clone();
        let mut sim: Sim<Vec<u32>, TestEvent> =
            Sim::typed_with_queue(Vec::new(), QueueKind::Slab);
        sim.set_event_recorder(move |t, seq, ev| {
            l2.borrow_mut().push((t, seq, ev.map(|e| e.kind())));
        });
        sim.schedule_event_at(3, TestEvent::Push(7));
        sim.schedule_at(4, |_| {});
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![(3, 0, Some("push")), (4, 1, None)]);
    }

    /// Satellite pin: the runaway guard is a real budget — a
    /// self-rearming event trips it and the panic names the offending
    /// event's time and kind.
    #[test]
    fn run_to_completion_panics_on_runaway_with_diagnostics() {
        let result = std::panic::catch_unwind(|| {
            let mut sim = Sim::new(0u64);
            sim.set_event_budget(100);
            every(&mut sim, 1, |_| true); // re-arms forever
            sim.run_to_completion();
        });
        let err = result.expect_err("a runaway schedule must panic, not spin");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("event budget exhausted"), "{msg}");
        assert!(msg.contains("`custom`"), "diagnostic must name the event kind: {msg}");
        assert!(msg.contains("t="), "diagnostic must carry the event time: {msg}");
    }

    /// The budget only guards `run_to_completion` runaways; a legitimate
    /// drain below the budget is untouched.
    #[test]
    fn budget_does_not_trip_on_legitimate_drains() {
        let mut sim = Sim::new(0u64);
        sim.set_event_budget(1000);
        for t in 0..1000u64 {
            sim.schedule_at(t, |s| s.state += 1);
        }
        sim.run_to_completion();
        assert_eq!(sim.state, 1000);
    }
}
