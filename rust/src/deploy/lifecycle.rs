//! Job lifecycle events (§3.1 steps 0–5): submission, JM generation,
//! stage release with the pJM's initial assignment, Parades-driven task
//! starts (with WAN input fetches), completion reporting with
//! partitionList replication, and job finish.
//!
//! Event handlers follow a strict two-phase pattern: mutate `sim.state`
//! inside a scoped borrow and *collect* follow-up events, then schedule
//! them — keeping the borrow checker and the event queue honest.

use std::collections::{BTreeMap, HashMap};

use crate::cluster::Cluster;
use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::{ContainerId, DcId, JmId, JobId, NodeId, TaskId};
use crate::jm::{Assignment, ContainerView, IntermediateInfo, JobManager, PartitionEntry, Role, WaitingTask};
use crate::sim::{secs_f, SimTime};
use crate::trace::{TraceEvent, TraceSink as _};

use super::events::SimEvent;
use super::world::{master_for, JobRt, WorldSim};

/// Spawn-time for a fresh JM container process (seconds).
pub const JM_SPAWN_SECS: f64 = 1.0;

/// Build a [`ContainerView`] from cluster state.
pub fn view_of(cluster: &Cluster, cid: ContainerId) -> ContainerView {
    let c = cluster.container(cid);
    ContainerView { id: cid, node: c.node, rack: c.rack, free: c.free }
}

/// Submit a job: resolve the description, generate the pJM locally and
/// sJMs remotely (steps 1–2b), then release stage 0.
pub fn submit_job(sim: &mut WorldSim, kind: WorkloadKind, size: SizeClass, home: DcId) -> JobId {
    let now = sim.now_secs();
    let (job, spawns) = {
        let w = &mut sim.state;
        let job = w.alloc_job_id();
        w.gen.ensure_dataset(&mut w.dfs, kind, size);
        let spec = w.gen.make_job(job, kind, size, home, &w.dfs);
        spec.validate(w.cfg.scheduler.theta).expect("generated job invalid");
        w.emit(TraceEvent::JobSubmitted { job, kind, size, tasks: spec.num_tasks() });
        let rt = JobRt {
            progress: crate::dag::JobProgress::new(&spec),
            spec,
            jms: Default::default(),
            primary: home,
            sessions: Default::default(),
            info: IntermediateInfo { job, ..Default::default() },
            outputs: HashMap::new(),
            task_sources: HashMap::new(),
            attempts: HashMap::new(),
            submitted_secs: now,
            done: false,
            steal_inflight: Default::default(),
            steal_rr: 0,
            generation: 0,
            estimator: crate::jm::StageEstimator::standard(),
            started_at: HashMap::new(),
            speculative_relaunches: 0,
            cost: Default::default(),
            insurance: HashMap::new(),
        };
        let jm_dcs = w.jm_dcs(home);
        let spawns: Vec<(DcId, SimTime)> = jm_dcs
            .into_iter()
            .map(|dc| {
                let delay = if dc == home {
                    secs_f(JM_SPAWN_SECS)
                } else {
                    w.wan.message_delay(home, dc, 32 * 1024) + secs_f(JM_SPAWN_SECS)
                };
                (dc, delay)
            })
            .collect();
        w.jobs.insert(job, rt);
        (job, spawns)
    };
    for (dc, delay) in spawns {
        sim.schedule_event_in(delay, SimEvent::SpawnJm { job, dc });
    }
    job
}

/// Create the JM replica for (job, dc): take a container, open a zk
/// session, enter the election, register with the local master.
pub fn spawn_jm(sim: &mut WorldSim, job: JobId, dc: DcId) {
    let now = sim.now_secs();
    enum Next {
        Retry,
        Done(bool), // is_primary
        Abort,
    }
    let next = {
        let w = &mut sim.state;
        match w.jobs.get(&job) {
            None => Next::Abort,
            Some(rt) if rt.done => Next::Abort,
            Some(rt) => {
                let home = rt.primary;
                let role = if dc == home { Role::Primary } else { Role::SemiActive };
                let jm_id = JmId { job, dc };
                let master = master_for(&mut w.global, &mut w.parts, dc);
                match master.spawn_jm_container_at(jm_id, &mut w.cluster, dc) {
                    None => Next::Retry,
                    Some(container) => {
                        master.register(jm_id);
                        let session = w.zk.connect(dc);
                        let _ = w.zk.create(
                            session,
                            &format!("/jobs/j{}/election/c-", job.0),
                            vec![],
                            true,
                            true,
                        );
                        let jm = JobManager::new(jm_id, role, container, now);
                        let rt = w.jobs.get_mut(&job).unwrap();
                        rt.sessions.insert(dc, session);
                        rt.jms.insert(dc, jm);
                        let count = rt.container_count();
                        w.emit(TraceEvent::JmSpawned { job, dc, primary: role == Role::Primary });
                        w.emit(TraceEvent::ContainerCount { job, count });
                        Next::Done(role == Role::Primary)
                    }
                }
            }
        }
    };
    match next {
        Next::Abort => {}
        Next::Retry => {
            sim.schedule_event_in(secs_f(2.0), SimEvent::SpawnJm { job, dc });
        }
        Next::Done(is_primary) => {
            if is_primary {
                sim.defer_event(SimEvent::ReleaseReady { job });
            }
        }
    }
}

/// pJM: release every stage whose parents completed, resolve locality +
/// sources, run the initial assignment (proportional to data per DC) and
/// ship the tasks to the owning JMs (taskMap).
pub fn release_ready(sim: &mut WorldSim, job: JobId) {
    let (shipments, released) = {
        let w = &mut sim.state;
        let Some(rt) = w.jobs.get_mut(&job) else { return };
        if rt.done {
            return;
        }
        let fresh = rt.progress.release_ready_stages(&rt.spec);
        if fresh.is_empty() {
            return;
        }
        let released: Vec<(crate::ids::StageId, usize)> =
            fresh.iter().map(|&sid| (sid, rt.spec.stage(sid).tasks.len())).collect();
        let num_dcs = w.cfg.topology.num_dcs();
        let racks = w.cfg.topology.racks_per_dc.max(1);
        let centralized = w.mode.centralized();
        let home = rt.primary;
        let mut per_dc: BTreeMap<DcId, Vec<WaitingTask>> = BTreeMap::new();

        for sid in fresh {
            rt.info.released_stages.push(sid);
            // Per-DC / per-node weights of the stage's parent outputs.
            let mut dc_weights = vec![0u64; num_dcs];
            let mut node_bytes: BTreeMap<NodeId, u64> = BTreeMap::new();
            for p in &rt.spec.stage(sid).parents {
                for t in &rt.spec.stage(*p).tasks {
                    if let Some((node, bytes)) = rt.outputs.get(&t.id) {
                        dc_weights[node.dc.0] += *bytes;
                        *node_bytes.entry(*node).or_default() += *bytes;
                    }
                }
            }
            let mut best_node: Vec<Option<(NodeId, u64)>> = vec![None; num_dcs];
            for (node, b) in &node_bytes {
                let cur = &mut best_node[node.dc.0];
                if cur.map(|(_, cb)| *b > cb).unwrap_or(true) {
                    *cur = Some((*node, *b));
                }
            }

            let stage_tasks = rt.spec.stage(sid).tasks.clone();
            let all_map = stage_tasks.iter().all(|t| t.pref_node.is_some());
            let targets: Vec<DcId> = if all_map {
                stage_tasks.iter().map(|t| t.pref_dc).collect()
            } else {
                proportional_targets(&dc_weights, stage_tasks.len(), home)
            };

            for (t, &target) in stage_tasks.iter().zip(&targets) {
                let sources: Vec<(DcId, u64)> = if t.pref_node.is_some() {
                    vec![(t.pref_dc, t.input_bytes)]
                } else {
                    let total: u64 = dc_weights.iter().sum();
                    if total == 0 {
                        vec![(target, t.input_bytes)]
                    } else {
                        dc_weights
                            .iter()
                            .enumerate()
                            .filter(|(_, &b)| b > 0)
                            .map(|(d, &b)| {
                                (DcId(d), (t.input_bytes as f64 * b as f64 / total as f64) as u64)
                            })
                            .collect()
                    }
                };
                rt.task_sources.insert(t.id, sources);
                let owner = if centralized { home } else { target };
                rt.info.task_map.push((t.id, owner));
                let pref_node = t.pref_node.or(best_node[target.0].map(|(n, _)| n));
                // Parades thresholds use the §5 estimator, not oracle p.
                let est_p = rt.estimator.estimate_p(sid, t.input_bytes);
                per_dc.entry(owner).or_default().push(WaitingTask {
                    id: t.id,
                    r: t.r,
                    p: est_p,
                    input_bytes: t.input_bytes,
                    pref_node,
                    pref_rack: pref_node.map(|n| (n.dc, n.idx % racks)),
                    wait: 0.0,
                });
            }
        }

        let generation = rt.generation;
        let shipments = per_dc
            .into_iter()
            .map(|(dc, tasks)| {
                let delay = if dc == home { 1 } else { w.wan.message_delay(home, dc, 8 * 1024) };
                (dc, tasks, delay, generation)
            })
            .collect::<Vec<_>>();
        (shipments, released)
    };
    for (stage, tasks) in released {
        sim.state.emit(TraceEvent::StageReleased { job, stage, tasks });
    }
    for (dc, tasks, delay, generation) in shipments {
        sim.schedule_event_in(delay, SimEvent::EnqueueTasks { job, dc, tasks, generation });
    }
    replicate_info(sim, job);
}

/// Largest-remainder proportional split of `n` tasks over DC weights.
/// Falls back to the home DC when all weights are zero.
pub fn proportional_targets(weights: &[u64], n: usize, home: DcId) -> Vec<DcId> {
    let total: u64 = weights.iter().sum();
    if total == 0 || n == 0 {
        return vec![home; n];
    }
    let fracs: Vec<f64> = weights.iter().map(|&w| w as f64 * n as f64 / total as f64).collect();
    let mut counts: Vec<usize> = fracs.iter().map(|f| f.floor() as usize).collect();
    let mut assigned: usize = counts.iter().sum();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = fracs[a] - fracs[a].floor();
        let fb = fracs[b] - fracs[b].floor();
        fb.total_cmp(&fa).then(a.cmp(&b))
    });
    let mut i = 0;
    while assigned < n {
        counts[order[i % order.len()]] += 1;
        assigned += 1;
        i += 1;
    }
    let mut out = Vec::with_capacity(n);
    for (d, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            out.push(DcId(d));
        }
    }
    out.truncate(n);
    out
}

/// Tasks arrive at a JM's queue; poke its idle executors. `generation`
/// guards against shipments that crossed a job restart.
pub fn enqueue_tasks(sim: &mut WorldSim, job: JobId, dc: DcId, tasks: Vec<WaitingTask>, generation: u32) {
    let accepted = {
        let w = &mut sim.state;
        match w.jobs.get_mut(&job) {
            None => return,
            Some(rt) if rt.done || rt.generation != generation => return,
            Some(rt) => match rt.jms.get_mut(&dc) {
                Some(jm) if jm.alive => {
                    jm.enqueue(tasks.clone());
                    true
                }
                _ => false,
            },
        }
    };
    if !accepted {
        // JM not up yet (or dead): retry shortly; tasks are not lost.
        sim.schedule_event_in(secs_f(1.0), SimEvent::EnqueueTasks { job, dc, tasks, generation });
        return;
    }
    poke_executors(sim, job, dc);
}

/// Defer UPDATE events for every executor of (job, dc) with free capacity.
pub fn poke_executors(sim: &mut WorldSim, job: JobId, dc: DcId) {
    let cids: Vec<ContainerId> = {
        let w = &sim.state;
        let Some(rt) = w.jobs.get(&job) else { return };
        let Some(jm) = rt.jms.get(&dc) else { return };
        if !jm.alive {
            return;
        }
        jm.executors
            .iter()
            .copied()
            .filter(|c| {
                w.cluster
                    .containers
                    .get(c)
                    .map(|cc| cc.alive && cc.free > 0.0)
                    .unwrap_or(false)
            })
            .collect()
    };
    for cid in cids {
        sim.defer_event(SimEvent::ContainerUpdate { job, dc, cid });
    }
}

/// The UPDATE event: one container of (job, dc) reports free capacity.
pub fn container_update(sim: &mut WorldSim, job: JobId, dc: DcId, cid: ContainerId) {
    let now = sim.now_secs();
    let picks: Vec<Assignment> = {
        let w = &mut sim.state;
        let Some(rt) = w.jobs.get_mut(&job) else { return };
        if rt.done {
            return;
        }
        let Some(jm) = rt.jms.get_mut(&dc) else { return };
        if !jm.alive || !jm.executors.contains(&cid) {
            return;
        }
        let view = match w.cluster.containers.get(&cid) {
            Some(c) if c.alive && c.free > 0.0 => {
                ContainerView { id: cid, node: c.node, rack: c.rack, free: c.free }
            }
            _ => return,
        };
        jm.handle_update(view, now, w.params)
    };
    for a in picks {
        start_assignment(sim, job, dc, a);
    }
}

/// Commit one assignment: reserve the container, fetch inputs (WAN if
/// cross-DC), run for `p`, then report completion. When insurance
/// replication is on and the container's host looks revocation-risky
/// (spot, market price within `bidding.risk_margin` of its bid, or a
/// price storm active), a duplicate copy starts on another executor of
/// the same JM — first commit wins, the winner frees the loser.
pub fn start_assignment(sim: &mut WorldSim, job: JobId, dc: DcId, a: Assignment) {
    let now_ms = sim.now();
    let now = sim.now_secs();
    let (t, cid, attempt, fetch_ms, links, true_p, insured) = {
        let w = &mut sim.state;
        let Some(rt) = w.jobs.get_mut(&job) else { return };
        let t = a.task.id;
        if rt.progress.task_status(t) != crate::dag::TaskStatus::Waiting {
            // Duplicate queue entry (e.g. a shipment raced a failure
            // re-queue): the other copy is authoritative — drop this one.
            if let Some(jm) = rt.jms.get_mut(&dc) {
                jm.running.remove(&t);
            }
            return;
        }
        rt.progress.mark_running(t);
        let attempt = {
            let e = rt.attempts.entry(t).or_insert(0);
            *e += 1;
            *e
        };
        w.cluster.start_task(a.container, t, a.task.r, now_ms);

        let dst = w.cluster.container(a.container).node.dc;
        let sources = rt.task_sources.get(&t).cloned().unwrap_or_default();
        let mut fetch_ms: SimTime = 0;
        let mut any_remote = false;
        let mut links: Vec<(DcId, DcId)> = Vec::new();
        let per_gb = w.cfg.cloud.transfer_per_gb;
        for (src, bytes) in sources {
            if bytes == 0 {
                continue;
            }
            if src != dst {
                any_remote = true;
                // Per-job cost attribution: cross-DC input bytes at the
                // §6.3 tariff (pure fold — no RNG, no trace events).
                rt.cost.charge_transfer(bytes, per_gb);
            }
            let d = w.wan.begin_transfer(src, dst, bytes);
            links.push((src, dst));
            fetch_ms = fetch_ms.max(d);
        }
        let st = w.tracer.publish(TraceEvent::TaskLaunched {
            job,
            task: t,
            dc: dst,
            locality: a.locality.name(),
            remote_input: any_remote,
        });
        w.metrics.on_event(&st);
        rt.started_at.insert(t, now);
        // True processing time comes from the spec; a.task.p is the
        // scheduler's *estimate* (§5) and only gates delay thresholds.
        let spec_p = rt.spec.stage(t.stage).tasks[t.index as usize].p;
        let mut true_p = spec_p;
        // §2.2 changeable environment at task granularity: some tasks
        // straggle (contention, slow disks); speculation catches them.
        if w.rng.chance(w.cfg.workload.straggler_prob) {
            true_p *= w.cfg.workload.straggler_factor;
        }
        // PingAn-style insurance: duplicate the attempt when the primary
        // sits on a high-revocation-risk spot host and a sibling executor
        // has room. The copy shares the primary's input fetch (the
        // replicated partitionList makes inputs co-readable) and runs the
        // un-straggled spec time, so it also hedges straggler draws.
        let insured: Option<ContainerId> = if w.cfg.bidding.insurance {
            let node = w.cluster.container(a.container).node;
            let risky = match w.cluster.node_class(node) {
                crate::cloud::InstanceClass::Spot { bid } => {
                    let m = &w.parts[node.dc.0].market;
                    m.storm() > 1.0 || m.price() * w.cfg.bidding.risk_margin >= bid
                }
                crate::cloud::InstanceClass::OnDemand => false,
            };
            if risky && !rt.insurance.contains_key(&t) {
                rt.jms.get(&dc).and_then(|jm| {
                    let fits = |c: ContainerId| {
                        c != a.container
                            && w.cluster
                                .containers
                                .get(&c)
                                .map(|cc| cc.alive && cc.free + 1e-9 >= a.task.r)
                                .unwrap_or(false)
                    };
                    // Prefer a different host VM (the whole point is
                    // surviving the primary's node), else any sibling.
                    jm.executors
                        .iter()
                        .copied()
                        .find(|&c| fits(c) && w.cluster.containers[&c].node != node)
                        .or_else(|| jm.executors.iter().copied().find(|&c| fits(c)))
                })
            } else {
                None
            }
        } else {
            None
        };
        if let Some(backup) = insured {
            w.cluster.start_task(backup, t, a.task.r, now_ms);
            rt.insurance.insert(t, backup);
            let st = w.tracer.publish(TraceEvent::InsuranceLaunched { job, task: t, dc });
            w.metrics.on_event(&st);
        }
        (t, a.container, attempt, fetch_ms, links, true_p, insured.map(|b| (b, spec_p)))
    };
    let run_ms = secs_f(true_p);
    for (s, d) in links {
        sim.schedule_event_in(fetch_ms, SimEvent::EndTransfer { from: s, to: d });
    }
    sim.schedule_event_in(
        fetch_ms + run_ms,
        SimEvent::TaskFinished { job, dc, task: t, cid, attempt },
    );
    if let Some((backup, spec_p)) = insured {
        sim.schedule_event_in(
            fetch_ms + secs_f(spec_p),
            SimEvent::TaskFinished { job, dc, task: t, cid: backup, attempt },
        );
    }
}

/// Completion: free the container, record the output partition, replicate
/// the partitionList, release dependent stages, finish the job. With
/// insurance replication the *first* copy to reach this point wins: it
/// frees the losing copy's reservation and invalidates its in-flight
/// completion event, so exactly one `TaskFinished` is published per task.
pub fn task_finished(
    sim: &mut WorldSim,
    job: JobId,
    dc: DcId,
    t: TaskId,
    cid: ContainerId,
    attempt: u32,
) {
    let now_ms = sim.now();
    let now_secs = sim.now_secs();
    enum After {
        JobDone,
        StageDone,
        TaskDone,
    }
    let after = {
        let w = &mut sim.state;
        let Some(rt) = w.jobs.get_mut(&job) else { return };
        if rt.done || rt.attempts.get(&t) != Some(&attempt) {
            return; // stale event (container died / job restarted / lost the race)
        }
        if !w.cluster.containers.get(&cid).map(|c| c.alive).unwrap_or(false) {
            return; // container died mid-flight; failure path re-queues
        }
        w.cluster.finish_task(cid, t, now_ms);
        // First commit wins: free every other live copy of this task (the
        // insured duplicate, or — when the duplicate won — the primary
        // still booked in the JM's running map) and invalidate its event.
        let primary = rt.jms.get(&dc).and_then(|jm| jm.running.get(&t).copied());
        let mut losers: Vec<ContainerId> = Vec::new();
        if let Some(p) = primary {
            if p != cid {
                losers.push(p);
            }
        }
        if let Some(other) = rt.insurance.remove(&t) {
            if other != cid && !losers.contains(&other) {
                losers.push(other);
            }
            // The losing copy's completion event carries this attempt id;
            // bump so it drops as stale instead of double-completing.
            *rt.attempts.entry(t).or_insert(0) += 1;
        }
        for loser in losers {
            if w.cluster.containers.get(&loser).map(|c| c.alive).unwrap_or(false) {
                w.cluster.finish_task(loser, t, now_ms);
            }
        }
        let st = w.tracer.publish(TraceEvent::TaskFinished { job, task: t, dc });
        w.metrics.on_event(&st);
        let node = w.cluster.container(cid).node;
        // Per-job machine-cost attribution: the winning attempt's
        // occupancy (wall seconds × footprint) at its host's class rate.
        {
            let secs_run =
                (now_secs - rt.started_at.get(&t).copied().unwrap_or(now_secs)).max(0.0);
            let class = w.cluster.node_class(node);
            let price = match class {
                crate::cloud::InstanceClass::OnDemand => w.cfg.cloud.on_demand_hourly,
                crate::cloud::InstanceClass::Spot { .. } => w.cfg.cloud.spot_hourly_mean,
            };
            let r = rt.spec.stage(t.stage).tasks[t.index as usize].r;
            rt.cost.charge_machine(class, secs_run / 3600.0 * r, price);
        }
        let finished_spec = &rt.spec.stage(t.stage).tasks[t.index as usize];
        let out_bytes = finished_spec.output_bytes;
        rt.estimator.record(t.stage, finished_spec.p, finished_spec.r);
        rt.outputs.insert(t, (node, out_bytes));
        rt.info.partition_list.push(PartitionEntry { task: t, node, bytes: out_bytes });
        if let Some(jm) = rt.jms.get_mut(&dc) {
            jm.task_done(t);
        }
        let stage_done = rt.progress.mark_done(t);
        let kind = rt.spec.kind;
        if let Some(hook) = w.hook.as_mut() {
            hook.on_task_finished(job, kind, t.stage, t.index, dc);
            if stage_done {
                hook.on_stage_done(job, kind, t.stage);
            }
            if rt.progress.job_done() {
                hook.on_job_done(job, kind);
            }
        }
        if rt.progress.job_done() {
            After::JobDone
        } else if stage_done {
            After::StageDone
        } else {
            After::TaskDone
        }
    };
    match after {
        After::JobDone => {
            finish_job(sim, job);
        }
        After::StageDone => {
            sim.defer_event(SimEvent::ReleaseReady { job });
            replicate_info(sim, job);
            sim.defer_event(SimEvent::ContainerUpdate { job, dc, cid });
        }
        After::TaskDone => {
            replicate_info(sim, job);
            sim.defer_event(SimEvent::ContainerUpdate { job, dc, cid });
        }
    }
}

/// All stages complete: JMs release their resources and themselves
/// (§3.2.1), the job is recorded.
pub fn finish_job(sim: &mut WorldSim, job: JobId) {
    let now_ms = sim.now();
    let w = &mut sim.state;
    let Some(rt) = w.jobs.get_mut(&job) else { return };
    rt.done = true;
    debug_assert!(rt.insurance.is_empty(), "insurance copies must not outlive their job");
    if w.cfg.bidding.active() {
        // The job's accumulated CostMeter total — the per-job cost column
        // campaign/fuzz/bench reports fold from the trace stream.
        let usd = rt.cost.total_usd();
        let st = w.tracer.publish(TraceEvent::CostCharged { job, usd });
        w.metrics.on_event(&st);
    }
    let dcs: Vec<DcId> = rt.jms.keys().copied().collect();
    for dc in dcs {
        let jm_id = JmId { job, dc };
        let master = master_for(&mut w.global, &mut w.parts, dc);
        let held = master.unregister(jm_id);
        for cid in held {
            if w.cluster.containers.get(&cid).map(|c| c.alive).unwrap_or(false) {
                w.cluster.release(cid, now_ms);
            }
        }
        let jm = rt.jms.get_mut(&dc).unwrap();
        if jm.alive && w.cluster.containers.get(&jm.container).map(|c| c.alive).unwrap_or(false) {
            w.cluster.release(jm.container, now_ms);
        }
        jm.alive = false;
        if let Some(s) = rt.sessions.get(&dc) {
            w.zk.expire_session(*s);
        }
    }
    w.emit(TraceEvent::JobCompleted { job });
    w.emit(TraceEvent::ContainerCount { job, count: 0 });
}

/// Re-encode the intermediate info, push it through zk (accounting the
/// quorum traffic + latency) and sample its size for Fig 12a.
pub fn replicate_info(sim: &mut WorldSim, job: JobId) {
    let w = &mut sim.state;
    let Some(rt) = w.jobs.get_mut(&job) else { return };
    rt.info.executor_list =
        rt.jms.values().filter(|j| j.alive).flat_map(JobManager::executor_entries).collect();
    let bytes = rt.info.encode();
    let kind = rt.spec.kind;
    let size = bytes.len();
    let from = rt.primary;
    let session = rt.sessions.get(&from).copied();
    let path = format!("/jobs/j{}/info", job.0);
    let _lat = w.zk.write_latency(&mut w.wan, from, size as u64);
    if w.zk.exists(&path) {
        let _ = w.zk.set_data(&path, bytes);
    } else if let Some(s) = session {
        let _ = w.zk.create(s, &path, bytes, false, false);
    }
    w.emit(TraceEvent::InfoReplicated { job, kind, bytes: size });
}
