//! Deployment assemblies and experiment drivers.
//!
//! Wires the substrates (cluster, WAN, zk, spot market, masters) and the
//! HOUTU coordinator (replicated JMs with Af + Parades) into the four
//! systems evaluated in §6.1 — `houtu`, `cent-dyna` (COBRA), `cent-stat`,
//! `decent-stat` — and drives online job traces through them on the
//! deterministic DES.

pub mod events;
pub mod failure;
pub mod lifecycle;
pub mod parts;
pub mod scheduling;
pub mod world;

pub use events::{SimEvent, TickKind};
pub use failure::{cascade_kill, inject_hogs, kill_dc, kill_jm_host, kill_node};
pub use lifecycle::submit_job;
pub use parts::{run_campaign_parts, run_cell_on_parts, PartCampaignReport, PartCell};
pub use scheduling::{install_timers, should_steal};
pub use world::{DcPart, GlobalPart, JobRt, World, WorldSim};

use crate::config::{Config, Deployment};
use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::DcId;
use crate::sim::{secs, secs_f, QueueKind, Sim, SimTime};
use crate::workloads::TraceEntry;

/// Build a simulation with timers installed up to `horizon`. The sim
/// advances the trace bus's shared [`crate::sim::StepClock`] inline: the
/// tracer sees each event's time (and counts the step) before the event
/// closure runs, so every emission inside the closure carries the right
/// stamp — without a boxed step-hook call per event.
pub fn build_sim(cfg: Config, mode: Deployment, horizon: SimTime) -> WorldSim {
    build_sim_with(cfg, mode, horizon, QueueKind::Slab)
}

/// [`build_sim`] on an explicit queue engine. The differential suites
/// and `houtu bench` run whole campaigns on [`QueueKind::Legacy`] to
/// prove (and measure) the slab queue against the pre-swap baseline.
pub fn build_sim_with(
    cfg: Config,
    mode: Deployment,
    horizon: SimTime,
    queue: QueueKind,
) -> WorldSim {
    let world = World::new(cfg, mode);
    let clock = world.tracer.clock();
    let mut sim = Sim::typed_with_queue(world, queue);
    sim.attach_clock(clock);
    install_timers(&mut sim, horizon);
    sim
}

/// Schedule an online trace of submissions.
pub fn schedule_trace(sim: &mut WorldSim, trace: &[TraceEntry]) {
    for e in trace {
        sim.schedule_event_at(
            secs_f(e.arrival_secs),
            SimEvent::SubmitJob { kind: e.kind, size: e.size, home: e.home_dc },
        );
    }
}

/// Deterministic online trace + run horizon for a config: the identical
/// trace for every deployment/scenario at a given seed (generator stream
/// 777 is independent of the world's RNG), with a generous completion
/// pad. Shared by [`run_trace_experiment`] and the scenario engine so the
/// two can never drift apart.
pub fn online_trace(cfg: &Config) -> (Vec<TraceEntry>, SimTime) {
    let trace = {
        let mut gen = crate::workloads::WorkloadGen::new(cfg, crate::util::Pcg::new(cfg.seed, 777));
        gen.trace(cfg, cfg.workload.num_jobs)
    };
    let last_arrival = trace.last().map(|e| e.arrival_secs).unwrap_or(0.0);
    let horizon = secs((last_arrival + 14_400.0) as u64);
    (trace, horizon)
}

/// Run the standard Fig-8 style experiment: `cfg.workload.num_jobs` jobs
/// arriving online, on the given deployment. Returns the finished world
/// (metrics, cost, WAN stats). Panics if jobs fail to complete within the
/// (generous) horizon — that would be a scheduler bug, not load.
pub fn run_trace_experiment(cfg: &Config, mode: Deployment) -> World {
    let mut cfg = cfg.clone();
    cfg.deployment = mode;
    let (trace, horizon) = online_trace(&cfg);
    let mut sim = build_sim(cfg, mode, horizon);
    schedule_trace(&mut sim, &trace);
    sim.run_until(horizon);
    let makespan = sim.state.metrics.makespan();
    let done = sim.state.metrics.completed_jobs();
    let total = sim.state.metrics.jobs.len();
    assert_eq!(done, total, "{mode:?}: {done}/{total} jobs completed within horizon");
    sim.state.bill_machines(makespan);
    sim.state
}

/// Single-job experiment support (Figs 9 & 11): submit one job, optionally
/// inject hogs or kill a JM, run to completion, return the world.
pub struct SingleJobPlan {
    pub kind: WorkloadKind,
    pub size: SizeClass,
    pub home: DcId,
    /// Inject resource hogs into these DCs at `t` seconds after submission.
    pub inject_at: Option<(f64, Vec<DcId>)>,
    /// Kill the JM replica in this DC at `t` seconds after submission.
    pub kill_jm_at: Option<(f64, DcId)>,
}

pub fn run_single_job(cfg: &Config, mode: Deployment, plan: SingleJobPlan) -> World {
    let mut cfg = cfg.clone();
    cfg.deployment = mode;
    let horizon = secs(14_400);
    let mut sim = build_sim(cfg, mode, horizon);
    let kind = plan.kind;
    let size = plan.size;
    let home = plan.home;
    sim.schedule_at(1, move |sim| {
        let job = submit_job(sim, kind, size, home);
        debug_assert_eq!(job.0, 0);
    });
    if let Some((t, dcs)) = plan.inject_at {
        sim.schedule_at(secs_f(t), move |sim| inject_hogs(sim, &dcs));
    }
    if let Some((t, dc)) = plan.kill_jm_at {
        sim.schedule_at(secs_f(t), move |sim| {
            kill_jm_host(sim, crate::ids::JobId(0), dc)
        });
    }
    sim.run_until(horizon);
    sim.state
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::JobId;

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.workload.num_jobs = 6;
        cfg.workload.mean_interarrival_secs = 30.0;
        cfg.cloud.revocations = false;
        cfg
    }

    #[test]
    fn single_wordcount_completes_on_houtu() {
        let cfg = small_cfg();
        let w = run_single_job(
            &cfg,
            Deployment::Houtu,
            SingleJobPlan {
                kind: WorkloadKind::WordCount,
                size: SizeClass::Small,
                home: DcId(0),
                inject_at: None,
                kill_jm_at: None,
            },
        );
        assert_eq!(w.metrics.completed_jobs(), 1);
        let jrt = w.metrics.jobs[&JobId(0)].jrt().unwrap();
        assert!(jrt > 1.0 && jrt < 600.0, "jrt {jrt}");
        // All containers returned to the pool.
        for d in 0..4 {
            assert_eq!(
                w.cluster.free_pool(DcId(d)).len(),
                w.cluster.dc_capacity(DcId(d)),
                "dc{d} pool leaked"
            );
        }
    }

    #[test]
    fn single_job_completes_on_every_deployment() {
        let cfg = small_cfg();
        for mode in Deployment::ALL {
            for kind in [WorkloadKind::TpcH, WorkloadKind::IterativeMl] {
                let w = run_single_job(
                    &cfg,
                    mode,
                    SingleJobPlan {
                        kind,
                        size: SizeClass::Medium,
                        home: DcId(1),
                        inject_at: None,
                        kill_jm_at: None,
                    },
                );
                assert_eq!(w.metrics.completed_jobs(), 1, "{mode:?}/{kind:?}");
            }
        }
    }

    #[test]
    fn trace_completes_and_is_deterministic() {
        let cfg = small_cfg();
        let w1 = run_trace_experiment(&cfg, Deployment::Houtu);
        let w2 = run_trace_experiment(&cfg, Deployment::Houtu);
        assert_eq!(w1.metrics.completed_jobs(), 6);
        assert_eq!(w1.metrics.avg_jrt(), w2.metrics.avg_jrt());
        assert_eq!(w1.metrics.makespan(), w2.metrics.makespan());
        assert_eq!(
            w1.wan.stats.cross_dc_total_bytes(),
            w2.wan.stats.cross_dc_total_bytes()
        );
    }

    #[test]
    fn houtu_beats_decent_stat_on_the_trace() {
        let cfg = small_cfg();
        let houtu = run_trace_experiment(&cfg, Deployment::Houtu);
        let stat = run_trace_experiment(&cfg, Deployment::DecentStat);
        assert!(
            houtu.metrics.avg_jrt() < stat.metrics.avg_jrt() * 1.10,
            "houtu {:.1}s vs decent-stat {:.1}s",
            houtu.metrics.avg_jrt(),
            stat.metrics.avg_jrt()
        );
    }

    #[test]
    fn stealing_happens_under_injected_load() {
        let cfg = small_cfg();
        let w = run_single_job(
            &cfg,
            Deployment::Houtu,
            SingleJobPlan {
                kind: WorkloadKind::PageRank,
                size: SizeClass::Large,
                home: DcId(1),
                inject_at: Some((10.0, vec![DcId(0), DcId(2), DcId(3)])),
                kill_jm_at: None,
            },
        );
        assert_eq!(w.metrics.completed_jobs(), 1);
        let stolen: u64 = w.jobs[&JobId(0)]
            .jms
            .values()
            .map(|jm| jm.stats.tasks_stolen_in)
            .sum();
        assert!(stolen > 0, "no tasks were stolen despite resource-tense DCs");
    }

    #[test]
    fn sjm_failure_recovers_and_job_finishes() {
        let cfg = small_cfg();
        let w = run_single_job(
            &cfg,
            Deployment::Houtu,
            SingleJobPlan {
                kind: WorkloadKind::WordCount,
                size: SizeClass::Medium,
                home: DcId(0),
                inject_at: None,
                kill_jm_at: Some((15.0, DcId(2))), // an sJM
            },
        );
        assert_eq!(w.metrics.completed_jobs(), 1);
        assert!(!w.metrics.recovery_intervals_secs.is_empty(), "no recovery recorded");
        let iv = w.metrics.recovery_intervals_secs[0];
        assert!(iv < 20.0, "recovery interval {iv}s (paper: < 20 s)");
    }

    #[test]
    fn pjm_failure_elects_new_primary() {
        let cfg = small_cfg();
        let w = run_single_job(
            &cfg,
            Deployment::Houtu,
            SingleJobPlan {
                kind: WorkloadKind::WordCount,
                size: SizeClass::Medium,
                home: DcId(0),
                inject_at: None,
                kill_jm_at: Some((15.0, DcId(0))), // the pJM
            },
        );
        assert_eq!(w.metrics.completed_jobs(), 1);
        assert!(!w.metrics.election_delays_secs.is_empty(), "no election recorded");
        let rt = &w.jobs[&JobId(0)];
        assert_ne!(rt.primary, DcId(0), "primary moved off the killed DC");
    }

    #[test]
    fn centralized_jm_failure_restarts_job() {
        let cfg = small_cfg();
        let w = run_single_job(
            &cfg,
            Deployment::CentDyna,
            SingleJobPlan {
                kind: WorkloadKind::WordCount,
                size: SizeClass::Medium,
                home: DcId(0),
                inject_at: None,
                kill_jm_at: Some((15.0, DcId(0))),
            },
        );
        assert_eq!(w.metrics.completed_jobs(), 1);
        assert_eq!(w.metrics.jobs[&JobId(0)].restarts, 1, "centralized must resubmit");
    }
}
