//! The World-as-parts campaign engine: real campaign cells executed on
//! [`ShardedSim`], with the world split into per-DC part states plus a
//! thin global part.
//!
//! The sequential [`super::World`] keeps non-`Send` machinery (an
//! `Rc`-based tracer, boxed strategy hooks), so it cannot ride the
//! threaded engine directly. This module is the other half of the split
//! that [`super::world::DcPart`] starts: a self-contained `Send` model of
//! the same deployment — spot markets, JM replication and election, work
//! stealing, WAN shuffles, insurance duplicates, and the whole chaos
//! vocabulary — where **every** cross-DC interaction is a typed
//! [`PartEvent`] message routed through `ShardedSim`'s mailboxes under
//! [`crate::net::wan_lookahead`] floors.
//!
//! Part layout: parts `0..num_dcs` are the DC parts (market, container
//! slots, primary/secondary JM bookkeeping, per-part RNG and tracer
//! clock); part `num_dcs` is the global part, which owns only the spot
//! market tick sweep and the campaign probe sweep and holds no DC state.
//!
//! Two-tier fidelity (`topology.exact_dcs`, see `docs/SCALE.md`): on a
//! generated planet-scale topology only the leading `exact_dcs` parts —
//! the *exact tier* — run the full protocol; the remaining *background*
//! parts stay dormant (no market ticks, no probes, no replication
//! fan-out), so events/sec is a function of the exact tier, not the
//! world size. The first event to touch a background part (a DC-targeted
//! chaos injection) *promotes* it: it catches up the price walk it
//! skipped, folds one promotion transition, and runs the full protocol
//! from then on. A `SingleJob` home outside the boundary widens the
//! exact tier statically at cell setup. With `exact_dcs = 0` (the
//! default) every DC is exact and the engine is bit-identical to the
//! pre-tier behavior.
//!
//! Determinism contract (the differential wall pins this): a cell's
//! digest is a pure function of `(base config, scenario, seed)` —
//! independent of the shard/thread count and of wall-clock interleaving,
//! because parts only touch their own state and all cross-part effects
//! travel as messages ordered by `(time, canonical key)`.

use std::collections::BTreeMap;

use crate::config::Config;
use crate::dag::{SizeClass, WorkloadKind};
use crate::scenario::{CampaignSpec, ChaosEvent, ScenarioSpec, ScenarioWorkload};
use crate::sim::shard::{ShardCtx, ShardEvent, ShardedSim};
use crate::sim::{secs, secs_f, SimTime};
use crate::trace::Fnv64;
use crate::util::error::Result;
use crate::util::json;
use crate::util::rng::Pcg;

/// Global-part market sweep period.
const TICK_MS: SimTime = 5_000;
/// Global-part campaign probe period.
const PROBE_MS: SimTime = 30_000;
/// Self-rescheduling drivers stop past this point; job work (and any
/// chaos seeded later) may still finish after it, but the event
/// population is finite once the drivers stop. Kept short enough that
/// CMB rounds do not dwarf the per-task work on the threaded engine.
const HORIZON_MS: SimTime = 180_000;
/// Dead DCs / killed worker VMs re-acquire capacity after this long.
const REVIVE_MS: SimTime = 60_000;
/// Barrier gap between a stage completing and the next stage's release.
const STAGE_GAP_MS: SimTime = 250;
/// Backoff before retrying work that found no capacity anywhere.
const RETRY_MS: SimTime = 500;
/// Spot price (milli-units) above which a stage buys an insurance
/// duplicate in another DC.
const INSURANCE_PRICE_MILLI: u64 = 1_500;
/// Deterministic CPU rounds burned per finished task, so the threaded
/// engine has real per-part work to parallelize (large enough that the
/// barrier cost of a CMB round amortizes away at 4 threads).
const SPIN_ROUNDS: u32 = 20_000;
/// Runaway-model backstop (the engine panics past this).
const EVENT_BUDGET: u64 = 50_000_000;

/// Deterministic task-execution work: a pure integer mix, identical on
/// every engine and thread count.
fn spin(seed: u64, rounds: u32) -> u64 {
    let mut x = seed | 1;
    for i in 0..rounds {
        x = x.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ (x >> 29) ^ i as u64;
        x = x.rotate_left(23).wrapping_add(0x2545_f491_4f6c_dd1d);
    }
    x
}

/// (stages, tasks per stage, task service ms) for a submitted job.
fn job_shape(kind: WorkloadKind, size: SizeClass) -> (u32, u32, u64) {
    let stages = match kind {
        WorkloadKind::WordCount => 3,
        WorkloadKind::TpcH => 5,
        WorkloadKind::IterativeMl => 8,
        WorkloadKind::PageRank => 6,
    };
    let (tasks, task_ms) = match size {
        SizeClass::Small => (8, 400),
        SizeClass::Medium => (24, 900),
        SizeClass::Large => (64, 1_600),
    };
    (stages, tasks, task_ms)
}

/// Primary-JM bookkeeping for one job, owned by exactly one DC part at a
/// time (it moves between parts only inside [`PartEvent::ElectJm`]).
#[derive(Debug, Clone, Copy)]
pub struct JobSlice {
    pub stage: u32,
    pub stages: u32,
    pub tasks: u32,
    pub task_ms: u64,
    pub outstanding: u32,
}

/// One part's entire state. Parts never touch each other's instances;
/// everything a part learns about the rest of the world arrives as a
/// [`PartEvent`].
#[derive(Debug)]
pub struct PartState {
    pub part: usize,
    pub ndc: usize,
    /// Exact-tier size: parts `0..edc` run the full protocol; parts
    /// `edc..ndc` are dormant background until promoted.
    pub edc: usize,
    pub is_global: bool,
    /// Whether this part started in the exact tier (or is the global part).
    pub exact: bool,
    /// Whether a background part has been promoted to exact fidelity.
    pub promoted: bool,
    rng: Pcg,
    pub alive: bool,
    pub slots_free: usize,
    pub slots_total: usize,
    /// Spot price in milli-units (1000 ≈ on-demand parity).
    pub price_milli: u64,
    /// Price-walk volatility multiplier (1000 = calm).
    pub storm_milli: u64,
    /// Outbound WAN factor per destination DC (1000 = nominal; smaller
    /// is slower — degraded links stretch shuffle transfers).
    wan_milli: Vec<u64>,
    jobs: BTreeMap<u64, JobSlice>,
    replicas: BTreeMap<u64, u64>,
    pub tasks_run: u64,
    pub steals: u64,
    pub bytes_in: u64,
    pub duplicates: u64,
    pub elections: u64,
    pub strays: u64,
    pub jobs_done: u64,
    /// Per-part tracer clock: one step per applied event/transition.
    pub steps: u64,
    hash: Fnv64,
}

impl PartState {
    fn new(part: usize, ndc: usize, edc: usize, cfg: &Config) -> PartState {
        let slots = cfg.topology.workers_per_dc * cfg.topology.containers_per_worker;
        let is_global = part == ndc;
        PartState {
            part,
            ndc,
            edc,
            is_global,
            exact: is_global || part < edc,
            promoted: false,
            rng: Pcg::new(cfg.seed, 9_000 + part as u64),
            alive: true,
            slots_free: if is_global { 0 } else { slots },
            slots_total: if is_global { 0 } else { slots },
            price_milli: 1_000,
            storm_milli: 1_000,
            wan_milli: vec![1_000; ndc],
            jobs: BTreeMap::new(),
            replicas: BTreeMap::new(),
            tasks_run: 0,
            steals: 0,
            bytes_in: 0,
            duplicates: 0,
            elections: 0,
            strays: 0,
            jobs_done: 0,
            steps: 0,
            hash: Fnv64::new(),
        }
    }

    /// Advance the part's tracer clock and fold one transition into the
    /// part digest.
    fn fold(&mut self, tag: u64, now: SimTime, a: u64, b: u64) {
        self.steps += 1;
        self.hash.u64(tag);
        self.hash.u64(now);
        self.hash.u64(a);
        self.hash.u64(b);
    }

    /// The running part digest (transition-order sensitive).
    pub fn part_digest(&self) -> u64 {
        self.hash.0
    }

    /// Shuffle-transfer extra delay in ms for `bytes` over the link to
    /// `dst`, stretched by any WAN degradation on that pair.
    fn transfer_ms(&self, bytes: u64, dst: usize) -> SimTime {
        let nominal = (bytes / 2_000).max(1);
        nominal * 1_000 / self.wan_milli[dst].max(1)
    }
}

/// The typed cross-shard vocabulary: every cross-DC path the sequential
/// deploy layer takes through shared memory is one of these messages.
#[derive(Debug, Clone)]
pub enum PartEvent {
    /// A job arrives at its home DC.
    SubmitJob { job: u64, stages: u32, tasks: u32, task_ms: u64 },
    /// Async JM state replication to a secondary DC (`version ==
    /// u64::MAX` retires the replica after the job completes).
    ReplicateJm { job: u64, version: u64 },
    /// The primary JM releases the current stage's tasks.
    ReleaseStage { job: u64 },
    /// A task finishes on whichever part ran it.
    TaskFinish { job: u64, origin: u32, task_ms: u64, seed: u64 },
    /// Shuffle output travels back to the primary (the WAN transfer).
    TaskDone { job: u64, bytes: u64 },
    /// Work sharing: a task with no local slot asks another DC to run it.
    StealRequest { job: u64, origin: u32, task_ms: u64, ttl: u32 },
    /// Belt-and-braces duplicate bought under a hot spot market.
    InsuranceDuplicate { job: u64 },
    /// JM failover: the job's bookkeeping moves to a successor DC.
    ElectJm { job: u64, stage: u32, stages: u32, tasks: u32, task_ms: u64, ttl: u32 },
    /// Global part: periodic market sweep (fans out `MarketTick`).
    MarketSweep,
    /// One DC advances its spot-price random walk.
    MarketTick,
    /// Global part: periodic campaign probe (fans out `Probe`).
    ProbeSweep,
    /// A DC part answers a probe with its tracer clock and digest.
    Probe,
    /// The probe answer, folded into the global part's digest.
    ProbeReply { part: u32, steps: u64, digest: u64 },
    /// `hogs@`: foreign tenants occupy (almost) all spare containers.
    ChaosHogs,
    /// `kill_jm@`: kill one job's JM replica in this DC.
    ChaosKillJm { job: u64 },
    /// `kill_jm_cascade@`: kill the current primary, then hunt and kill
    /// each freshly-elected primary, `remaining` kills in total.
    CascadeKill { job: u64, remaining: u32, gap_ms: SimTime, ttl: u32 },
    /// `kill_node@`: spot-style termination of one worker VM.
    ChaosKillNode { containers: usize },
    /// `kill_dc@`: correlated whole-DC outage.
    ChaosKillDc,
    /// A dead DC re-acquires its capacity.
    DcRevive,
    /// A killed worker VM's containers come back.
    NodeRevive { containers: usize },
    /// `spot_storm@`: raise the price-walk volatility…
    StormStart { milli: u64 },
    /// …and restore calm at the end of the window.
    StormEnd,
    /// `wan@`: set this part's outbound factor to every destination.
    WanSetAll { milli: u64 },
    /// `wan_pair@`: set this part's outbound factor to one destination.
    WanSetPair { dst: u32, milli: u64 },
}

impl ShardEvent<PartState> for PartEvent {
    fn kind(&self) -> &'static str {
        match self {
            PartEvent::SubmitJob { .. } => "submit_job",
            PartEvent::ReplicateJm { .. } => "replicate_jm",
            PartEvent::ReleaseStage { .. } => "release_stage",
            PartEvent::TaskFinish { .. } => "task_finish",
            PartEvent::TaskDone { .. } => "task_done",
            PartEvent::StealRequest { .. } => "steal_request",
            PartEvent::InsuranceDuplicate { .. } => "insurance_duplicate",
            PartEvent::ElectJm { .. } => "elect_jm",
            PartEvent::MarketSweep => "market_sweep",
            PartEvent::MarketTick => "market_tick",
            PartEvent::ProbeSweep => "probe_sweep",
            PartEvent::Probe => "probe",
            PartEvent::ProbeReply { .. } => "probe_reply",
            PartEvent::ChaosHogs => "chaos_hogs",
            PartEvent::ChaosKillJm { .. } => "chaos_kill_jm",
            PartEvent::CascadeKill { .. } => "cascade_kill",
            PartEvent::ChaosKillNode { .. } => "chaos_kill_node",
            PartEvent::ChaosKillDc => "chaos_kill_dc",
            PartEvent::DcRevive => "dc_revive",
            PartEvent::NodeRevive { .. } => "node_revive",
            PartEvent::StormStart { .. } => "storm_start",
            PartEvent::StormEnd => "storm_end",
            PartEvent::WanSetAll { .. } => "wan_set_all",
            PartEvent::WanSetPair { .. } => "wan_set_pair",
        }
    }

    fn apply(self, ctx: &mut ShardCtx<'_, PartState, PartEvent>) {
        let now = ctx.now();
        let me = ctx.part();
        // Two-tier promotion: the first event to touch a background part
        // switches it to exact fidelity. Catch up the price walk it
        // skipped (one draw per elapsed market tick, from the part's own
        // untouched stream — deterministic however the touch arrived),
        // fold one promotion transition, then arm the part's own market
        // tick loop, since the global sweep only covers the exact tier.
        if !ctx.state.is_global && !ctx.state.exact && !ctx.state.promoted {
            ctx.state.promoted = true;
            let ticks = now / TICK_MS;
            for _ in 0..ticks {
                let draw = ctx.state.rng.below(2_001) as i64 - 1_000;
                let delta = draw * ctx.state.storm_milli as i64 / 1_000 / 50;
                let p = (ctx.state.price_milli as i64 + delta).clamp(200, 20_000);
                ctx.state.price_milli = p as u64;
            }
            let price = ctx.state.price_milli;
            ctx.state.fold(25, now, ticks, price);
            if now < HORIZON_MS {
                ctx.schedule_in(TICK_MS, PartEvent::MarketTick);
            }
        }
        match self {
            PartEvent::SubmitJob { job, stages, tasks, task_ms } => {
                ctx.state.fold(1, now, job, (stages as u64) << 32 | tasks as u64);
                if !ctx.state.alive {
                    // The home DC is down: hold the submission until it
                    // (deterministically) revives.
                    ctx.schedule_in(
                        RETRY_MS,
                        PartEvent::SubmitJob { job, stages, tasks, task_ms },
                    );
                    return;
                }
                ctx.state
                    .jobs
                    .insert(job, JobSlice { stage: 0, stages, tasks, task_ms, outstanding: 0 });
                let edc = ctx.state.edc;
                for d in 0..edc {
                    if d != me {
                        ctx.send(d, 0, PartEvent::ReplicateJm { job, version: 0 });
                    }
                }
                ctx.schedule_in(1, PartEvent::ReleaseStage { job });
            }

            PartEvent::ReplicateJm { job, version } => {
                ctx.state.fold(2, now, job, version);
                if version == u64::MAX {
                    ctx.state.replicas.remove(&job);
                } else if ctx.state.alive {
                    ctx.state.replicas.insert(job, version);
                } else {
                    ctx.state.strays += 1;
                }
            }

            PartEvent::ReleaseStage { job } => {
                let Some(sl) = ctx.state.jobs.get(&job).copied() else {
                    ctx.state.strays += 1;
                    ctx.state.fold(3, now, job, u64::MAX);
                    return;
                };
                ctx.state.fold(3, now, job, sl.stage as u64);
                let edc = ctx.state.edc;
                // Insurance: a hot spot market here means this stage's
                // completion is at risk — buy one duplicate elsewhere.
                if ctx.state.price_milli > INSURANCE_PRICE_MILLI && edc > 1 {
                    let tgt = (me + 1 + ctx.state.rng.index(edc - 1)) % edc;
                    ctx.send(tgt, 0, PartEvent::InsuranceDuplicate { job });
                }
                ctx.state.jobs.get_mut(&job).expect("slice present").outstanding = sl.tasks;
                let local = (sl.tasks as usize).min(ctx.state.slots_free) as u32;
                ctx.state.slots_free -= local as usize;
                for _ in 0..local {
                    let jitter = ctx.state.rng.below(200);
                    let seed = ctx.state.rng.next_u64();
                    ctx.schedule_in(
                        sl.task_ms + jitter,
                        PartEvent::TaskFinish { job, origin: me as u32, task_ms: sl.task_ms, seed },
                    );
                }
                // No local slot for the remainder: offer each leftover
                // task to another DC (message-shaped work stealing).
                for _ in local..sl.tasks {
                    let req = PartEvent::StealRequest {
                        job,
                        origin: me as u32,
                        task_ms: sl.task_ms,
                        ttl: edc as u32,
                    };
                    if edc > 1 {
                        let tgt = (me + 1 + ctx.state.rng.index(edc - 1)) % edc;
                        ctx.send(tgt, 0, req);
                    } else {
                        ctx.schedule_in(RETRY_MS, req);
                    }
                }
            }

            PartEvent::StealRequest { job, origin, task_ms, ttl } => {
                ctx.state.fold(4, now, job, (origin as u64) << 32 | ttl as u64);
                let edc = ctx.state.edc;
                if ctx.state.alive && ctx.state.slots_free > 0 {
                    ctx.state.slots_free -= 1;
                    if me != origin as usize {
                        ctx.state.steals += 1;
                    }
                    let jitter = ctx.state.rng.below(200);
                    let seed = ctx.state.rng.next_u64();
                    ctx.schedule_in(
                        task_ms + jitter,
                        PartEvent::TaskFinish { job, origin, task_ms, seed },
                    );
                } else if ttl > 0 && edc > 1 {
                    let tgt = (me + 1 + ctx.state.rng.index(edc - 1)) % edc;
                    ctx.send(tgt, 0, PartEvent::StealRequest { job, origin, task_ms, ttl: ttl - 1 });
                } else {
                    // Nowhere has capacity right now: back off and retry
                    // with a fresh ttl once tasks (or revivals) free slots.
                    ctx.schedule_in(
                        RETRY_MS,
                        PartEvent::StealRequest { job, origin, task_ms, ttl: edc as u32 },
                    );
                }
            }

            PartEvent::TaskFinish { job, origin, task_ms, seed } => {
                if !ctx.state.alive {
                    // The VM died under the task: hand it back to the
                    // primary's part for a retry.
                    ctx.state.fold(5, now, job, 0);
                    let edc = ctx.state.edc;
                    ctx.send(
                        origin as usize,
                        0,
                        PartEvent::StealRequest { job, origin, task_ms, ttl: edc as u32 },
                    );
                    return;
                }
                let work = spin(seed, SPIN_ROUNDS);
                ctx.state.fold(5, now, job, work);
                ctx.state.slots_free = (ctx.state.slots_free + 1).min(ctx.state.slots_total);
                ctx.state.tasks_run += 1;
                let bytes = 10_000 + ctx.state.rng.below(90_000);
                let extra = if origin as usize == me {
                    0
                } else {
                    ctx.state.transfer_ms(bytes, origin as usize)
                };
                ctx.send(origin as usize, extra, PartEvent::TaskDone { job, bytes });
            }

            PartEvent::TaskDone { job, bytes } => {
                ctx.state.bytes_in += bytes;
                if !ctx.state.jobs.contains_key(&job) {
                    // The primary moved (or the job finished) while this
                    // shuffle was in flight — count the stray.
                    ctx.state.strays += 1;
                    ctx.state.fold(6, now, job, u64::MAX);
                    return;
                }
                let done_stage = {
                    let sl = ctx.state.jobs.get_mut(&job).expect("checked above");
                    if sl.outstanding > 0 {
                        sl.outstanding -= 1;
                    }
                    sl.outstanding == 0
                };
                ctx.state.fold(6, now, job, bytes);
                if !done_stage {
                    return;
                }
                let job_over = {
                    let sl = ctx.state.jobs.get_mut(&job).expect("checked above");
                    sl.stage += 1;
                    sl.stage >= sl.stages
                };
                if !job_over {
                    ctx.schedule_in(STAGE_GAP_MS, PartEvent::ReleaseStage { job });
                } else {
                    ctx.state.jobs.remove(&job);
                    ctx.state.jobs_done += 1;
                    let edc = ctx.state.edc;
                    for d in 0..edc {
                        if d != me {
                            ctx.send(d, 0, PartEvent::ReplicateJm { job, version: u64::MAX });
                        }
                    }
                }
            }

            PartEvent::InsuranceDuplicate { job } => {
                let work = {
                    let seed = ctx.state.rng.next_u64();
                    spin(seed, SPIN_ROUNDS / 8)
                };
                ctx.state.duplicates += 1;
                ctx.state.fold(7, now, job, work);
            }

            PartEvent::ElectJm { job, stage, stages, tasks, task_ms, ttl } => {
                ctx.state.fold(8, now, job, (stage as u64) << 32 | ttl as u64);
                let edc = ctx.state.edc;
                if ctx.state.alive {
                    ctx.state.elections += 1;
                    ctx.state
                        .jobs
                        .insert(job, JobSlice { stage, stages, tasks, task_ms, outstanding: 0 });
                    // Re-release the interrupted stage; shuffles already
                    // in flight to the dead primary land as strays there.
                    ctx.schedule_in(1, PartEvent::ReleaseStage { job });
                } else if ttl > 0 {
                    ctx.send(
                        (me + 1) % edc,
                        0,
                        PartEvent::ElectJm { job, stage, stages, tasks, task_ms, ttl: ttl - 1 },
                    );
                } else {
                    // Every DC is down: park the election until revival.
                    ctx.schedule_in(
                        RETRY_MS,
                        PartEvent::ElectJm { job, stage, stages, tasks, task_ms, ttl: edc as u32 },
                    );
                }
            }

            PartEvent::MarketSweep => {
                ctx.state.fold(9, now, 0, 0);
                let edc = ctx.state.edc;
                for d in 0..edc {
                    ctx.send(d, 0, PartEvent::MarketTick);
                }
                if now < HORIZON_MS {
                    ctx.schedule_in(TICK_MS, PartEvent::MarketSweep);
                }
            }

            PartEvent::MarketTick => {
                let draw = ctx.state.rng.below(2_001) as i64 - 1_000;
                let delta = draw * ctx.state.storm_milli as i64 / 1_000 / 50;
                let p = (ctx.state.price_milli as i64 + delta).clamp(200, 20_000);
                ctx.state.price_milli = p as u64;
                let (price, storm) = (ctx.state.price_milli, ctx.state.storm_milli);
                ctx.state.fold(10, now, price, storm);
                // Promoted background parts drive their own tick loop —
                // the global sweep never reaches past the exact tier.
                if ctx.state.promoted && now < HORIZON_MS {
                    ctx.schedule_in(TICK_MS, PartEvent::MarketTick);
                }
            }

            PartEvent::ProbeSweep => {
                ctx.state.fold(11, now, 0, 0);
                let edc = ctx.state.edc;
                for d in 0..edc {
                    ctx.send(d, 0, PartEvent::Probe);
                }
                if now < HORIZON_MS {
                    ctx.schedule_in(PROBE_MS, PartEvent::ProbeSweep);
                }
            }

            PartEvent::Probe => {
                let (steps, digest) = (ctx.state.steps, ctx.state.part_digest());
                ctx.state.fold(12, now, steps, 0);
                let nparts = ctx.nparts();
                ctx.send(nparts - 1, 0, PartEvent::ProbeReply { part: me as u32, steps, digest });
            }

            PartEvent::ProbeReply { part, steps, digest } => {
                ctx.state.fold(13, now, (part as u64) << 32 | steps, digest);
            }

            PartEvent::ChaosHogs => {
                ctx.state.slots_free = ctx.state.slots_free.min(1);
                let free = ctx.state.slots_free as u64;
                ctx.state.fold(14, now, free, 0);
            }

            PartEvent::ChaosKillJm { job } => {
                ctx.state.fold(15, now, job, 0);
                let edc = ctx.state.edc;
                if let Some(sl) = ctx.state.jobs.remove(&job) {
                    ctx.send(
                        (me + 1) % edc,
                        0,
                        PartEvent::ElectJm {
                            job,
                            stage: sl.stage,
                            stages: sl.stages,
                            tasks: sl.tasks,
                            task_ms: sl.task_ms,
                            ttl: edc as u32,
                        },
                    );
                } else {
                    ctx.state.replicas.remove(&job);
                }
            }

            PartEvent::CascadeKill { job, remaining, gap_ms, ttl } => {
                ctx.state.fold(16, now, job, (remaining as u64) << 32 | ttl as u64);
                let edc = ctx.state.edc;
                if let Some(sl) = ctx.state.jobs.remove(&job) {
                    let succ = (me + 1) % edc;
                    ctx.send(
                        succ,
                        0,
                        PartEvent::ElectJm {
                            job,
                            stage: sl.stage,
                            stages: sl.stages,
                            tasks: sl.tasks,
                            task_ms: sl.task_ms,
                            ttl: edc as u32,
                        },
                    );
                    if remaining > 1 {
                        // Hunt the freshly-elected primary after the gap.
                        ctx.send(
                            succ,
                            gap_ms,
                            PartEvent::CascadeKill {
                                job,
                                remaining: remaining - 1,
                                gap_ms,
                                ttl: edc as u32,
                            },
                        );
                    }
                } else if ttl > 0 {
                    ctx.send(
                        (me + 1) % edc,
                        0,
                        PartEvent::CascadeKill { job, remaining, gap_ms, ttl: ttl - 1 },
                    );
                }
                // ttl exhausted with no primary found: the job already
                // finished and the cascade fizzles (recorded by the fold).
            }

            PartEvent::ChaosKillNode { containers } => {
                ctx.state.slots_total = ctx.state.slots_total.saturating_sub(containers);
                ctx.state.slots_free = ctx.state.slots_free.saturating_sub(containers);
                let left = ctx.state.slots_total as u64;
                ctx.state.fold(17, now, containers as u64, left);
                ctx.schedule_in(REVIVE_MS, PartEvent::NodeRevive { containers });
            }

            PartEvent::NodeRevive { containers } => {
                ctx.state.slots_total += containers;
                ctx.state.slots_free += containers;
                let total = ctx.state.slots_total as u64;
                ctx.state.fold(18, now, containers as u64, total);
            }

            PartEvent::ChaosKillDc => {
                ctx.state.alive = false;
                ctx.state.slots_free = 0;
                let orphans = std::mem::take(&mut ctx.state.jobs);
                let norphans = orphans.len() as u64;
                ctx.state.replicas.clear();
                ctx.state.fold(19, now, norphans, 0);
                let edc = ctx.state.edc;
                for (job, sl) in orphans {
                    ctx.send(
                        (me + 1) % edc,
                        0,
                        PartEvent::ElectJm {
                            job,
                            stage: sl.stage,
                            stages: sl.stages,
                            tasks: sl.tasks,
                            task_ms: sl.task_ms,
                            ttl: edc as u32,
                        },
                    );
                }
                ctx.schedule_in(REVIVE_MS, PartEvent::DcRevive);
            }

            PartEvent::DcRevive => {
                ctx.state.alive = true;
                ctx.state.slots_free = ctx.state.slots_total;
                let total = ctx.state.slots_total as u64;
                ctx.state.fold(20, now, total, 0);
            }

            PartEvent::StormStart { milli } => {
                ctx.state.storm_milli = milli.max(1);
                ctx.state.fold(21, now, milli, 0);
            }

            PartEvent::StormEnd => {
                ctx.state.storm_milli = 1_000;
                ctx.state.fold(22, now, 0, 0);
            }

            PartEvent::WanSetAll { milli } => {
                for f in ctx.state.wan_milli.iter_mut() {
                    *f = milli.max(1);
                }
                ctx.state.fold(23, now, milli, 0);
            }

            PartEvent::WanSetPair { dst, milli } => {
                ctx.state.wan_milli[dst as usize] = milli.max(1);
                ctx.state.fold(24, now, dst as u64, milli);
            }
        }
    }
}

/// Place one spec'd chaos injection on the timeline as seeded messages.
/// DC-targeted arms seed their part directly (promoting a background DC
/// on delivery); tier-wide arms — the `wan@` fan and cascade ttls — span
/// the exact tier only, so the aggregate background stays untouched.
fn seed_chaos(
    sim: &mut ShardedSim<PartState, PartEvent>,
    ev: &ChaosEvent,
    edc: usize,
    containers_per_worker: usize,
) {
    match ev {
        ChaosEvent::InjectHogs { at_secs, dcs } => {
            for d in dcs {
                sim.seed(d.0, secs_f(*at_secs), PartEvent::ChaosHogs);
            }
        }
        ChaosEvent::KillJm { at_secs, dc } => {
            sim.seed(dc.0, secs_f(*at_secs), PartEvent::ChaosKillJm { job: 0 });
        }
        ChaosEvent::KillJmCascade { at_secs, dc, count, gap_secs } => {
            sim.seed(
                dc.0,
                secs_f(*at_secs),
                PartEvent::CascadeKill {
                    job: 0,
                    remaining: *count,
                    gap_ms: secs_f(*gap_secs),
                    ttl: edc as u32,
                },
            );
        }
        ChaosEvent::KillNode { at_secs, node } => {
            sim.seed(
                node.dc.0,
                secs_f(*at_secs),
                PartEvent::ChaosKillNode { containers: containers_per_worker },
            );
        }
        ChaosEvent::KillDc { at_secs, dc } => {
            sim.seed(dc.0, secs_f(*at_secs), PartEvent::ChaosKillDc);
        }
        ChaosEvent::SpotStorm { at_secs, dc, dur_secs, sigma_factor } => {
            let milli = (sigma_factor * 1_000.0).round().max(1.0) as u64;
            sim.seed(dc.0, secs_f(*at_secs), PartEvent::StormStart { milli });
            sim.seed(dc.0, secs_f(*at_secs + *dur_secs), PartEvent::StormEnd);
        }
        ChaosEvent::WanDegrade { from_secs, until_secs, factor } => {
            let milli = (factor * 1_000.0).round().max(1.0) as u64;
            for d in 0..edc {
                sim.seed(d, secs_f(*from_secs), PartEvent::WanSetAll { milli });
                sim.seed(d, secs_f(*until_secs), PartEvent::WanSetAll { milli: 1_000 });
            }
        }
        ChaosEvent::WanPairDegrade { at_secs, a, b, factor } => {
            let milli = (factor * 1_000.0).round().max(1.0) as u64;
            sim.seed(a.0, secs_f(*at_secs), PartEvent::WanSetPair { dst: b.0 as u32, milli });
            sim.seed(b.0, secs_f(*at_secs), PartEvent::WanSetPair { dst: a.0 as u32, milli });
        }
    }
}

/// One finished (scenario, seed) cell on the parts engine.
#[derive(Debug, Clone)]
pub struct PartCell {
    pub scenario: String,
    pub seed: u64,
    pub events: u64,
    pub digest: u64,
    pub peak: usize,
    pub tasks_run: u64,
    pub steals: u64,
    pub elections: u64,
    pub jobs_done: u64,
}

/// Run one campaign cell on the parts engine with `threads` ShardedSim
/// shards (`<= 1` uses the serial twin of the same round protocol). The
/// returned digest is thread-count invariant.
pub fn run_cell_on_parts(
    base: &Config,
    spec: &ScenarioSpec,
    seed: u64,
    threads: usize,
) -> Result<PartCell> {
    let cfg = spec.build_config(base, seed)?;
    let ndc = cfg.topology.num_dcs();
    // Two-tier boundary: `exact_dcs = 0` (default) keeps every DC exact.
    // A single-job home beyond the boundary widens the tier statically —
    // the promotion rule applied at setup instead of mid-run.
    let mut edc = if cfg.topology.exact_dcs == 0 { ndc } else { cfg.topology.exact_dcs.min(ndc) };
    if let ScenarioWorkload::SingleJob { home, .. } = spec.workload {
        if home.0 >= edc {
            edc = home.0 + 1;
        }
    }
    let nparts = ndc + 1;
    let states: Vec<PartState> =
        (0..nparts).map(|p| PartState::new(p, ndc, edc, &cfg)).collect();
    let la = crate::net::wan_lookahead(&cfg.wan, nparts);
    let mut sim = ShardedSim::new(states, la, threads.max(1));
    sim.set_event_budget(EVENT_BUDGET);

    match spec.workload {
        ScenarioWorkload::SingleJob { kind, size, home } => {
            let (stages, tasks, task_ms) = job_shape(kind, size);
            sim.seed(home.0, secs(1), PartEvent::SubmitJob { job: 0, stages, tasks, task_ms });
        }
        ScenarioWorkload::Trace { num_jobs } => {
            // Host-side arrival process: a dedicated stream so part RNGs
            // stay untouched by seeding.
            let mut host = Pcg::new(cfg.seed, 8_999);
            let mut t = secs(1);
            for j in 0..num_jobs as u64 {
                let kind = WorkloadKind::ALL[j as usize % WorkloadKind::ALL.len()];
                let (stages, tasks, task_ms) = job_shape(kind, SizeClass::Small);
                sim.seed(
                    j as usize % edc,
                    t,
                    PartEvent::SubmitJob { job: j, stages, tasks, task_ms },
                );
                t += 2_000 + host.below(8_000);
            }
        }
    }

    for ev in &spec.events {
        seed_chaos(&mut sim, ev, edc, cfg.topology.containers_per_worker);
    }

    // The thin global part owns the market tick and probe sweeps.
    sim.seed(ndc, TICK_MS, PartEvent::MarketSweep);
    sim.seed(ndc, PROBE_MS, PartEvent::ProbeSweep);

    if threads <= 1 {
        sim.run_serial();
    } else {
        sim.run();
    }

    // Cell digest: fold the event count plus the per-part digests of the
    // parts that processed at least one event. Dormant background parts
    // (and their indices) stay out of the fold, so a job confined to the
    // exact tier digests identically however many background DCs the
    // generated world carries — the invariance `rust/tests/part_world.rs`
    // pins. The global part always participates (it drives the sweeps).
    let mut h = Fnv64::new();
    h.u64(sim.events_processed());
    h.u64(crate::trace::fold_part_digests(
        (0..nparts).filter(|&p| sim.part_events(p) > 0).map(|p| {
            let s = sim.part_state(p);
            (s.steps, s.part_digest())
        }),
    ));

    let dcs = (0..ndc).map(|p| sim.part_state(p));
    let (mut tasks_run, mut steals, mut elections, mut jobs_done) = (0, 0, 0, 0);
    for s in dcs {
        tasks_run += s.tasks_run;
        steals += s.steals;
        elections += s.elections;
        jobs_done += s.jobs_done;
    }

    Ok(PartCell {
        scenario: spec.name.clone(),
        seed,
        events: sim.events_processed(),
        digest: h.0,
        peak: sim.peak_pending(),
        tasks_run,
        steals,
        elections,
        jobs_done,
    })
}

/// A whole campaign on the parts engine (cells in [`CampaignSpec::expand`]
/// order — the same stable matrix order as the sequential runner).
#[derive(Debug, Clone)]
pub struct PartCampaignReport {
    pub campaign: String,
    pub threads: usize,
    pub cells: Vec<PartCell>,
}

impl PartCampaignReport {
    /// Order-sensitive fold of every cell digest.
    pub fn campaign_digest(&self) -> u64 {
        let mut h = Fnv64::new();
        for c in &self.cells {
            h.u64(c.seed);
            h.u64(c.digest);
        }
        h.0
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "campaign {} on parts engine (ShardedSim, {} thread{})\n",
            self.campaign,
            self.threads,
            if self.threads == 1 { "" } else { "s" }
        ));
        out.push_str(&format!(
            "{:<24} {:>6} {:>9} {:>7} {:>7} {:>6} {:>5}  {:>16}\n",
            "scenario", "seed", "events", "tasks", "steals", "elect", "jobs", "digest"
        ));
        for c in &self.cells {
            out.push_str(&format!(
                "{:<24} {:>6} {:>9} {:>7} {:>7} {:>6} {:>5}  {:016x}\n",
                c.scenario, c.seed, c.events, c.tasks_run, c.steals, c.elections, c.jobs_done,
                c.digest
            ));
        }
        out.push_str(&format!(
            "{} cells, campaign digest {:016x}\n",
            self.cells.len(),
            self.campaign_digest()
        ));
        out
    }

    /// JSON export in the same shape `ci.sh` greps on the sequential
    /// report: per-cell 16-hex `"digest"` strings plus a campaign digest.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"campaign\": {},\n", json::escape(&self.campaign)));
        out.push_str("  \"engine\": \"sharded-sim\",\n");
        out.push_str(&format!("  \"threads\": {},\n", self.threads));
        out.push_str(&format!("  \"campaign_digest\": \"{:016x}\",\n", self.campaign_digest()));
        out.push_str("  \"runs\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"scenario\": {}, ", json::escape(&c.scenario)));
            out.push_str(&format!("\"seed\": {}, ", c.seed));
            out.push_str(&format!("\"events\": {}, ", c.events));
            out.push_str(&format!("\"tasks_run\": {}, ", c.tasks_run));
            out.push_str(&format!("\"steals\": {}, ", c.steals));
            out.push_str(&format!("\"elections\": {}, ", c.elections));
            out.push_str(&format!("\"jobs_done\": {}, ", c.jobs_done));
            out.push_str(&format!("\"peak_pending\": {}, ", c.peak));
            out.push_str(&format!("\"digest\": \"{:016x}\"", c.digest));
            out.push_str(if i + 1 == self.cells.len() { "}\n" } else { "},\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Run every cell of a campaign on the parts engine.
pub fn run_campaign_parts(
    base: &Config,
    spec: &CampaignSpec,
    threads: usize,
) -> Result<PartCampaignReport> {
    let mut cells = Vec::with_capacity(spec.scenarios.len() * spec.seeds.len());
    for (sc, seed) in spec.expand() {
        cells.push(run_cell_on_parts(base, &sc, seed, threads)?);
    }
    Ok(PartCampaignReport { campaign: spec.name.clone(), threads, cells })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario;

    #[test]
    fn smoke_campaign_digest_is_thread_count_invariant() {
        let base = Config::default();
        let spec = scenario::smoke_campaign();
        let serial = run_campaign_parts(&base, &spec, 1).expect("serial parts run");
        assert!(serial.cells.iter().all(|c| c.events > 0), "cells must execute events");
        assert!(serial.cells.iter().all(|c| c.jobs_done > 0), "cells must finish jobs");
        for threads in [2usize, 4] {
            let t = run_campaign_parts(&base, &spec, threads).expect("threaded parts run");
            assert_eq!(
                serial.campaign_digest(),
                t.campaign_digest(),
                "parts campaign digest must not depend on thread count ({threads})"
            );
            for (a, b) in serial.cells.iter().zip(t.cells.iter()) {
                assert_eq!(a.digest, b.digest, "cell {}#{} digest", a.scenario, a.seed);
                assert_eq!(a.events, b.events, "cell {}#{} events", a.scenario, a.seed);
            }
        }
    }

    #[test]
    fn repeated_runs_are_bit_identical() {
        let base = Config::default();
        let spec = scenario::smoke_campaign();
        let a = run_campaign_parts(&base, &spec, 2).expect("first run");
        let b = run_campaign_parts(&base, &spec, 2).expect("second run");
        assert_eq!(a.campaign_digest(), b.campaign_digest());
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn report_json_carries_sixteen_hex_digests() {
        let base = Config::default();
        let spec = scenario::smoke_campaign();
        let report = run_campaign_parts(&base, &spec, 1).expect("parts run");
        let json = report.to_json();
        assert!(json.contains("\"engine\": \"sharded-sim\""));
        let digests = json.matches("\"digest\": \"").count();
        assert_eq!(digests, report.cells.len(), "one digest per cell");
        assert!(json.contains(&format!("\"campaign_digest\": \"{:016x}\"", report.campaign_digest())));
    }
}
