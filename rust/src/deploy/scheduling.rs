//! Periodic machinery: the scheduling-period tick (Af feedback → desires →
//! fair allocation → surplus release), JM heartbeats, WAN re-sampling, and
//! the cross-DC work-stealing protocol.

use crate::ids::{ContainerId, DcId, JmId, JobId};
use crate::jm::{Assignment, ContainerView};
use crate::sim::{secs_f, SimTime};
use crate::trace::{TraceEvent, TraceSink as _};

use super::events::{arm_tick, SimEvent, TickKind};
use super::lifecycle::{container_update, poke_executors, start_assignment};
use super::world::{master_for, WorldSim};

/// Install the recurring world timers: period ticks, heartbeats, WAN
/// resampling, spot-market steps. Call once after building the sim. Each
/// timer is a typed [`SimEvent::Tick`] that re-arms itself on dispatch
/// until the next firing would pass `horizon`.
pub fn install_timers(sim: &mut WorldSim, horizon: SimTime) {
    let period = secs_f(sim.state.cfg.scheduler.period_l_secs);
    let heartbeat = secs_f(sim.state.cfg.scheduler.heartbeat_secs);
    let resample = sim.state.wan.resample_period();
    let market = secs_f(sim.state.cfg.cloud.market_period_secs);
    arm_tick(sim, TickKind::Period, period, horizon);
    arm_tick(sim, TickKind::Heartbeat, heartbeat, horizon);
    arm_tick(sim, TickKind::WanResample, resample, horizon);
    arm_tick(sim, TickKind::Market, market, horizon);
}

/// The scheduling-period boundary for every master (§4.2 / Appendix A):
/// 1. each live JM measures utilization, runs Af (or holds its static
///    desire) and pushes the new desire;
/// 2. JMs whose allocation exceeds the new desire return their idle
///    surplus containers ("aggressively kill the ones first free", §5);
/// 3. each master water-fills free containers to the unsatisfied
///    sub-jobs; fresh grants trigger UPDATE events.
pub fn period_tick(sim: &mut WorldSim) {
    let now_ms = sim.now();
    let now_secs = sim.now_secs();
    let adaptive = sim.state.mode.adaptive();
    let delta = sim.state.cfg.scheduler.delta;
    let rho = sim.state.cfg.scheduler.rho;
    // Bid-strategy inputs for this period's container requests: how far
    // behind schedule the worst job is (deadline strategy) — computed once
    // per tick, pushed per JM below. Inactive bidding skips the push
    // entirely, keeping the legacy allocation order byte-identical.
    let bidding_active = sim.state.cfg.bidding.active();
    let urgency = if bidding_active { sim.state.job_urgency(now_secs) } else { 0.0 };

    // Phase 1+2: desires & surplus release.
    let keys = sim.state.live_jm_keys();
    for (job, dc) in keys.clone() {
        let w = &mut sim.state;
        let jm_id = JmId { job, dc };
        let centralized = w.mode.centralized();
        let capacity: usize = if centralized {
            (0..w.cfg.topology.num_dcs()).map(|d| w.cluster.dc_capacity(DcId(d))).sum()
        } else {
            w.cluster.dc_capacity(dc)
        };
        let static_desire = w.static_desire();
        let Some(rt) = w.jobs.get_mut(&job) else { continue };
        if rt.done {
            continue;
        }
        let Some(jm) = rt.jms.get_mut(&dc) else { continue };
        if !jm.alive {
            continue;
        }
        let executors = jm.executors.clone();
        let allocation = executors.len();
        let util = w.cluster.take_period_utilization(&executors, now_ms);
        let desire = if adaptive {
            let (req, _decision) = jm.period_tick(util, allocation, delta, rho, capacity);
            req
        } else {
            jm.period_tick(util, allocation, delta, rho, capacity); // keep period count moving
            static_desire
        };
        // Surplus: only adaptive JMs proactively shrink.
        let surplus = if adaptive && allocation > desire {
            let cl = &w.cluster;
            jm.surplus_idle_containers(desire, |c| {
                cl.containers.get(&c).map(|cc| if cc.alive { cc.free } else { 0.0 }).unwrap_or(0.0)
            })
        } else {
            Vec::new()
        };
        for cid in &surplus {
            jm.executors.retain(|c| c != cid);
        }
        if !surplus.is_empty() {
            let st = w
                .tracer
                .publish(TraceEvent::ContainersReturned { jm: jm_id, count: surplus.len() });
            w.metrics.on_event(&st);
        }
        let master = master_for(&mut w.global, &mut w.parts, dc);
        master.set_desire(jm_id, desire);
        if bidding_active {
            // The container request carries an instance-class preference
            // next to the desire: the strategy's per-DC decision (storm
            // back-off for adaptive, behind-schedule for deadline).
            master.set_class_pref(jm_id, w.strategy.container_pref(dc, urgency));
        }
        for cid in surplus {
            master.return_container(jm_id, cid, &mut w.cluster, now_ms);
        }
    }

    // Phase 3: allocation per master, in stable slot order (the single
    // central master, or each DC's part master in DC order).
    let n_masters = sim.state.master_count();
    let mut pokes: Vec<(JobId, DcId)> = Vec::new();
    for mi in 0..n_masters {
        let grants = {
            let w = &mut sim.state;
            let (global, parts, cluster) = (&mut w.global, &mut w.parts, &mut w.cluster);
            master_for(global, parts, DcId(mi)).allocate(cluster)
        };
        let w = &mut sim.state;
        for (jm_id, cids) in grants {
            let Some(rt) = w.jobs.get_mut(&jm_id.job) else {
                // Hog pseudo-jobs: containers stay parked (Fig 9 injection).
                continue;
            };
            let Some(jm) = rt.jms.get_mut(&jm_id.dc) else { continue };
            jm.executors.extend(cids.iter().copied());
            let count = rt.container_count();
            w.emit(TraceEvent::ContainersGranted { jm: jm_id, count: cids.len() });
            w.emit(TraceEvent::ContainerCount { job: jm_id.job, count });
            pokes.push((jm_id.job, jm_id.dc));
        }
    }
    for (job, dc) in pokes {
        poke_executors(sim, job, dc);
    }
}

/// Heartbeat: every live JM re-offers its non-full executors (catching
/// tasks whose delay thresholds elapsed) and turns thief when idle.
pub fn heartbeat_tick(sim: &mut WorldSim) {
    let keys = sim.state.live_jm_keys();
    for (job, dc) in keys {
        // Offer free capacity to the queue.
        let cids: Vec<ContainerId> = {
            let w = &sim.state;
            let Some(rt) = w.jobs.get(&job) else { continue };
            let Some(jm) = rt.jms.get(&dc) else { continue };
            if !jm.alive {
                continue;
            }
            jm.executors
                .iter()
                .copied()
                .filter(|c| {
                    w.cluster
                        .containers
                        .get(c)
                        .map(|cc| cc.alive && cc.free > 0.0)
                        .unwrap_or(false)
                })
                .collect()
        };
        for cid in &cids {
            container_update(sim, job, dc, *cid);
        }
        check_stragglers(sim, job, dc);
        maybe_steal(sim, job, dc);
    }
}

/// Task-level straggler mitigation (§7): a running task whose elapsed
/// time exceeds `speculation_factor` × its estimated processing time is
/// aborted and relaunched — the re-queued copy has already "waited" past
/// every delay threshold, so Parades places it at the first opportunity.
pub fn check_stragglers(sim: &mut WorldSim, job: JobId, dc: DcId) {
    if !sim.state.cfg.failures.speculation {
        return;
    }
    let now = sim.now_secs();
    let now_ms = sim.now();
    let w = &mut sim.state;
    let factor = w.cfg.failures.speculation_factor;
    let Some(rt) = w.jobs.get_mut(&job) else { return };
    if rt.done {
        return;
    }
    let relaunch: Vec<(crate::ids::TaskId, ContainerId)> = {
        let Some(jm) = rt.jms.get(&dc) else { return };
        if !jm.alive {
            return;
        }
        jm.running
            .iter()
            .filter(|(t, _)| {
                let Some(&started) = rt.started_at.get(t) else { return false };
                // Only speculate once siblings have been measured (§5
                // estimator warmup) — pre-warmup priors are too coarse.
                if rt.estimator.samples(t.stage) < 2 {
                    return false;
                }
                let spec = &rt.spec.stage(t.stage).tasks[t.index as usize];
                let est = rt.estimator.estimate_p(t.stage, spec.input_bytes).max(1.0);
                // +30 s slack absorbs input-fetch time over the WAN.
                now - started > factor * est + 30.0
            })
            .map(|(&t, &cid)| (t, cid))
            .collect()
    };
    let racks = w.cfg.topology.racks_per_dc.max(1);
    let tau = w.params.tau;
    for (t, cid) in relaunch {
        let spec = rt.spec.stage(t.stage).tasks[t.index as usize].clone();
        // Abort the running attempt: free resources, invalidate its
        // completion event, re-queue with its waiting time preserved so
        // locality thresholds are already satisfied.
        if w.cluster.containers.get(&cid).map(|c| c.alive).unwrap_or(false) {
            w.cluster.finish_task(cid, t, now_ms);
        }
        // An insurance copy of the aborted attempt is aborted with it:
        // the attempt bump below invalidates its completion event, so its
        // reservation must be freed here or it would leak.
        if let Some(backup) = rt.insurance.remove(&t) {
            if w.cluster.containers.get(&backup).map(|c| c.alive).unwrap_or(false) {
                w.cluster.finish_task(backup, t, now_ms);
            }
        }
        *rt.attempts.entry(t).or_insert(0) += 1;
        rt.progress.mark_waiting(t);
        rt.started_at.remove(&t);
        rt.speculative_relaunches += 1;
        let st = w.tracer.publish(TraceEvent::SpeculativeRelaunch { job, task: t, dc });
        w.metrics.on_event(&st);
        let est_p = rt.estimator.estimate_p(t.stage, spec.input_bytes);
        let jm = rt.jms.get_mut(&dc).unwrap();
        jm.running.remove(&t);
        jm.enqueue([crate::jm::WaitingTask {
            id: t,
            r: spec.r,
            p: est_p,
            input_bytes: spec.input_bytes,
            pref_node: spec.pref_node,
            pref_rack: spec.pref_node.map(|n| (n.dc, n.idx % racks)),
            wait: 2.0 * tau * est_p + 1.0,
        }]);
    }
}

/// Algorithm 2's STEAL gate, kept pure for property testing: a JM turns
/// thief only when it has no waiting task of its own, no steal request
/// already in flight, and a nearly-idle container to offer
/// (`free ≥ 1 − δ`, so the victim's *any* clause can fire on it).
pub fn should_steal(has_waiting: bool, steal_inflight: bool, offered_free: f64, delta: f64) -> bool {
    !has_waiting && !steal_inflight && offered_free + 1e-9 >= 1.0 - delta
}

/// Work stealing (Algorithm 2, STEAL): if this JM has no waiting task but
/// a (nearly) idle executor, offer it to a victim JM of the same job.
pub fn maybe_steal(sim: &mut WorldSim, job: JobId, dc: DcId) {
    if !sim.state.mode.stealing() || !sim.state.cfg.scheduler.work_stealing {
        return;
    }
    let now = sim.now_secs();
    let Some((victim, view, delay)) = ({
        let w = &mut sim.state;
        let Some(rt) = w.jobs.get_mut(&job) else { return };
        if rt.done {
            return;
        }
        let Some(jm) = rt.jms.get(&dc) else { return };
        if !jm.alive {
            return;
        }
        // Cheap gates first — the common busy-JM case must not pay the
        // executor scan below.
        let has_waiting = jm.has_waiting();
        let inflight = *rt.steal_inflight.get(&dc).unwrap_or(&false);
        if has_waiting || inflight {
            return;
        }
        // An executor the full gate accepts: should_steal is the single
        // source of the idle threshold (free >= 1 - delta, so the any
        // clause can fire at the victim).
        let idle = jm.executors.iter().copied().find(|c| {
            w.cluster
                .containers
                .get(c)
                .map(|cc| {
                    cc.alive && should_steal(has_waiting, inflight, cc.free, w.params.delta)
                })
                .unwrap_or(false)
        });
        let Some(cid) = idle else { return };
        // Victim: round-robin over other live JMs with waiting tasks.
        let candidates: Vec<DcId> = rt
            .jms
            .iter()
            .filter(|(&d, v)| d != dc && v.alive && v.has_waiting())
            .map(|(&d, _)| d)
            .collect();
        if candidates.is_empty() {
            return;
        }
        let victim = candidates[rt.steal_rr % candidates.len()];
        rt.steal_rr = rt.steal_rr.wrapping_add(1);
        rt.steal_inflight.insert(dc, true);
        let c = &w.cluster.containers[&cid];
        let view = ContainerView { id: cid, node: c.node, rack: c.rack, free: c.free };
        let delay = w.wan.message_delay(dc, victim, 256);
        let rtjm = rt.jms.get_mut(&dc).unwrap();
        rtjm.stats.steal_requests_sent += 1;
        let st = w.tracer.publish(TraceEvent::StealRequested { job, thief: dc, victim });
        w.metrics.on_event(&st);
        Some((victim, view, delay))
    }) else {
        return;
    };
    let sent_at = now;
    sim.schedule_event_in(
        delay,
        SimEvent::StealAtVictim { job, victim, thief: dc, view, sent_at },
    );
}

/// ONRECEIVESTEAL at the victim: treat the thief's container as an UPDATE
/// event; ship any stolen tasks back.
pub(super) fn steal_at_victim(
    sim: &mut WorldSim,
    job: JobId,
    victim: DcId,
    thief: DcId,
    view: ContainerView,
    sent_at: f64,
) {
    let now = sim.now_secs();
    let (stolen, delay): (Vec<Assignment>, SimTime) = {
        let w = &mut sim.state;
        let Some(rt) = w.jobs.get_mut(&job) else { return };
        let params = w.params;
        let picks = match rt.jms.get_mut(&victim) {
            Some(vjm) if vjm.alive => vjm.handle_steal_request(view, now, params),
            _ => Vec::new(),
        };
        let delay = w.wan.message_delay(victim, thief, 256 + 64 * picks.len() as u64);
        let st = w
            .tracer
            .publish(TraceEvent::StealGranted { job, victim, thief, tasks: picks.len() });
        w.metrics.on_event(&st);
        (picks, delay)
    };
    sim.schedule_event_in(
        delay,
        SimEvent::StealResponse { job, thief, victim, stolen, sent_at },
    );
}

/// The thief receives the stolen tasks: start what still fits, queue the
/// rest locally; update the taskMap.
pub(super) fn steal_response(
    sim: &mut WorldSim,
    job: JobId,
    thief: DcId,
    victim: DcId,
    stolen: Vec<Assignment>,
    sent_at: f64,
) {
    let now = sim.now_secs();
    let start_now: Vec<Assignment> = {
        let w = &mut sim.state;
        let Some(rt) = w.jobs.get_mut(&job) else { return };
        rt.steal_inflight.insert(thief, false);
        let st = w.tracer.publish(TraceEvent::StealCompleted {
            job,
            thief,
            victim,
            tasks: stolen.len(),
            delay_ms: (now - sent_at) * 1000.0,
        });
        w.metrics.on_event(&st);
        if rt.done || stolen.is_empty() {
            return;
        }
        let thief_alive = rt.jms.get(&thief).map(|j| j.alive).unwrap_or(false);
        if !thief_alive {
            // Thief died mid-steal: bounce the tasks back to the victim.
            let tasks: Vec<_> = stolen.into_iter().map(|a| a.task).collect();
            if let Some(vjm) = rt.jms.get_mut(&victim) {
                vjm.enqueue(tasks);
            }
            return;
        }
        // Re-own the tasks in the taskMap.
        for a in &stolen {
            if let Some(e) = rt.info.task_map.iter_mut().find(|(t, _)| *t == a.task.id) {
                e.1 = thief;
            }
        }
        let jm = rt.jms.get_mut(&thief).unwrap();
        jm.accept_stolen(&stolen);
        let mut start_now = Vec::new();
        for a in stolen {
            let fits = w
                .cluster
                .containers
                .get(&a.container)
                .map(|c| c.alive && c.free + 1e-9 >= a.task.r)
                .unwrap_or(false);
            if fits {
                start_now.push(a);
            } else {
                // Container got busy meanwhile: keep the task, queue it.
                jm.running.remove(&a.task.id);
                jm.enqueue([a.task]);
            }
        }
        start_now
    };
    for a in start_now {
        start_assignment(sim, job, thief, a);
    }
    replicate_after_steal(sim, job);
}

fn replicate_after_steal(sim: &mut WorldSim, job: JobId) {
    super::lifecycle::replicate_info(sim, job);
}
