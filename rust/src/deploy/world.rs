//! World state: everything the discrete-event simulation mutates, plus
//! construction for each of the four deployments (§6.1 Baselines).

use std::collections::{BTreeMap, HashMap};

use crate::cloud::bidding::{self, BidRequest, BidStrategy};
use crate::cloud::{CostMeter, InstanceClass, SpotMarket};
use crate::cluster::Cluster;
use crate::config::{Config, Deployment};
use crate::consensus::{SessionId, ZkEnsemble};
use crate::dag::{JobProgress, JobSpec, TaskStatus};
use crate::ids::{ContainerId, DcId, JmId, JobId, NodeId, StageId, TaskId};
use crate::jm::{JobManager, ParadesParams, Role, IntermediateInfo};
use crate::master::Master;
use crate::metrics::Metrics;
use crate::net::Wan;
use crate::sim::Sim;

use super::events::SimEvent;
use crate::storage::Dfs;
use crate::trace::{TraceEvent, TraceSink, Tracer};
use crate::util::Pcg;
use crate::workloads::WorkloadGen;

/// Hook for attaching *real* computation to the simulated schedule: the
/// e2e example implements this with the PJRT [`crate::runtime::Runtime`]
/// so every completed gradient/PageRank stage executes genuine numerics
/// in exactly the order and sharding the coordinator chose.
pub trait ComputeHook {
    /// A task of (job, stage) finished on a container in `dc`.
    fn on_task_finished(&mut self, job: JobId, kind: crate::dag::WorkloadKind, stage: crate::ids::StageId, index: u32, dc: DcId);
    /// All tasks of (job, stage) finished.
    fn on_stage_done(&mut self, job: JobId, kind: crate::dag::WorkloadKind, stage: crate::ids::StageId);
    /// The whole job finished.
    fn on_job_done(&mut self, job: JobId, kind: crate::dag::WorkloadKind);
    /// Down-cast support so drivers can read results back out.
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Per-job runtime state.
pub struct JobRt {
    pub spec: JobSpec,
    pub progress: JobProgress,
    /// JM replicas: one per DC (decentralized) or a single entry at the
    /// home DC (centralized).
    pub jms: BTreeMap<DcId, JobManager>,
    /// Which DC hosts the primary JM.
    pub primary: DcId,
    /// Zookeeper session per JM replica.
    pub sessions: BTreeMap<DcId, SessionId>,
    /// Replicated intermediate information (authoritative copy; the zk
    /// layer provides its replication cost/latency/failure semantics).
    pub info: IntermediateInfo,
    /// Completed-task outputs: task -> (location, bytes). Mirrors
    /// info.partition_list in a query-friendly form.
    pub outputs: HashMap<TaskId, (NodeId, u64)>,
    /// Resolved input sources per released task: (src DC, bytes).
    pub task_sources: HashMap<TaskId, Vec<(DcId, u64)>>,
    /// Attempt counter per task — stale completion events are dropped.
    pub attempts: HashMap<TaskId, u32>,
    pub submitted_secs: f64,
    pub done: bool,
    /// Set while a steal request is in flight from the keyed thief DC.
    pub steal_inflight: BTreeMap<DcId, bool>,
    /// Round-robin pointer for victim selection.
    pub steal_rr: usize,
    /// Bumped on every full restart; events born under an older
    /// generation are dropped on arrival.
    pub generation: u32,
    /// §5: per-stage (p, r) estimator fed by finished tasks; Parades'
    /// τ·p thresholds consume *estimates*, not oracle values.
    pub estimator: crate::jm::StageEstimator,
    /// Start time (secs) of each running attempt, for straggler checks.
    pub started_at: HashMap<TaskId, f64>,
    /// Tasks relaunched by speculation (metric).
    pub speculative_relaunches: u32,
    /// Per-job cost attribution (machine occupancy of finished attempts
    /// plus cross-DC input transfer): the `CostCharged` payload and the
    /// deadline strategy's budget input. Always metered; no RNG.
    pub cost: CostMeter,
    /// Live insurance duplicates: task → the container running its copy
    /// (PingAn-style replication; at most one copy per task). The winner
    /// frees the loser; a primary's death promotes a surviving copy.
    pub insurance: HashMap<TaskId, ContainerId>,
}

impl JobRt {
    /// Containers currently belonging to the job (JM hosts + executors)
    /// across all replicas — the Fig 11 quantity.
    pub fn container_count(&self) -> usize {
        self.jms
            .values()
            .filter(|jm| jm.alive)
            .map(|jm| 1 + jm.executors.len())
            .sum()
    }

    /// The primary JM (panics if the primary DC has no replica).
    pub fn pjm(&self) -> &JobManager {
        &self.jms[&self.primary]
    }

    /// Longest remaining path (seconds of oracle processing time) through
    /// the stage DAG, counting only stages with unfinished tasks — the
    /// deadline strategy's critical-path estimate. Finished stages
    /// contribute 0, so the estimate shrinks monotonically as the job
    /// progresses; parents always precede children in the validated spec,
    /// making a single forward pass exact.
    pub fn remaining_critical_path(&self) -> f64 {
        let n = self.spec.stages.len();
        let mut cp = vec![0.0f64; n];
        let mut longest = 0.0f64;
        for (i, s) in self.spec.stages.iter().enumerate() {
            let own = if self.progress.stage_done(StageId(i as u32)) {
                0.0
            } else {
                s.tasks
                    .iter()
                    .filter(|t| self.progress.task_status(t.id) != TaskStatus::Done)
                    .map(|t| t.p)
                    .fold(0.0f64, f64::max)
            };
            let base = s.parents.iter().map(|p| cp[p.0 as usize]).fold(0.0f64, f64::max);
            cp[i] = base + own;
            longest = longest.max(cp[i]);
        }
        longest
    }
}

/// Per-DC slice of the world (the HOUTU "part"): every piece of mutable
/// state whose owner is naturally a single data center — its spot
/// market, its master (decentralized deployments), and any hog sub-jobs
/// injected into it. `DcPart` is `Send`: it holds no `Rc`, no trait
/// objects and no cross-DC references, which is what lets the part-world
/// campaign engine ([`super::parts`]) run one part per [`crate::sim::ShardedSim`]
/// shard while the monolithic `World` keeps the exact same state grouped
/// per DC.
pub struct DcPart {
    pub dc: DcId,
    /// This DC's spot market (prices recalculated by the global tick,
    /// revocations scoped to this DC's nodes).
    pub market: SpotMarket,
    /// The per-DC master. `None` under the centralized baselines, where
    /// the single monolithic master lives in [`GlobalPart`].
    pub master: Option<Master>,
    /// Hog pseudo-sub-jobs injected into this DC (the Fig-9 injection;
    /// kept registered forever).
    pub hogs: Vec<JmId>,
}

/// The thin global part: state that has no single-DC owner — under the
/// centralized baselines that is the monolithic master spanning every
/// region. Spot-market *ticks* and campaign probes are also global
/// concerns, but they carry no state of their own beyond the per-DC
/// markets they fan out to.
pub struct GlobalPart {
    /// The monolithic master (centralized deployments only).
    pub central_master: Option<Master>,
}

/// The master responsible for `dc`, borrowed through the split fields so
/// call sites can hold `&mut w.cluster` / `&mut w.jobs` at the same time
/// (a `World` method would lock the whole struct).
pub(crate) fn master_for<'a>(
    global: &'a mut GlobalPart,
    parts: &'a mut [DcPart],
    dc: DcId,
) -> &'a mut Master {
    match global.central_master.as_mut() {
        Some(m) => m,
        None => parts[dc.0].master.as_mut().expect("per-DC master"),
    }
}

/// The whole simulated testbed.
pub struct World {
    pub cfg: Config,
    pub mode: Deployment,
    pub params: ParadesParams,
    pub cluster: Cluster,
    pub wan: Wan,
    pub zk: ZkEnsemble,
    /// Per-DC part states (market + master + hogs), indexed by DC. The
    /// split mirrors the paper's per-DC autonomy: cross-part interaction
    /// in the deploy layer happens only through `SimEvent` messages.
    pub parts: Vec<DcPart>,
    /// Global (non-per-DC) state: the centralized baselines' monolithic
    /// master. See [`World::master_of`] for the indexing rule.
    pub global: GlobalPart,
    /// The configured bid strategy: prices every worker-VM acquisition,
    /// observes every market recalculation, and hands per-JM container
    /// class preferences to the masters each scheduling period.
    pub strategy: Box<dyn BidStrategy>,
    pub cost: CostMeter,
    pub dfs: Dfs,
    pub gen: WorkloadGen,
    pub jobs: BTreeMap<JobId, JobRt>,
    pub metrics: Metrics,
    /// Flight-recorder bus: every emission site publishes typed events
    /// through this handle (the WAN fabric holds a clone); `metrics` is
    /// fed from the same stream via [`World::emit`].
    pub tracer: Tracer,
    pub rng: Pcg,
    next_job: u64,
    /// Node bids (spot), for revocation checks.
    pub bids: HashMap<NodeId, f64>,
    /// Spot↔on-demand class flips from strategy re-acquisitions, as
    /// (node, change time secs, class *before* the change), appended in
    /// chronological order. [`World::bill_machines`] bills each segment
    /// at its own rate; empty (the naive/default case) degenerates to
    /// the original single-segment billing, bit for bit.
    pub class_changes: Vec<(NodeId, f64, InstanceClass)>,
    /// Wall-clock guard: stop submitting after the trace ends.
    pub trace_done: bool,
    /// Optional real-compute hook (e2e example).
    pub hook: Option<Box<dyn ComputeHook>>,
    /// Invariant violations recorded by the scenario engine's runtime
    /// probe (capped; empty on healthy runs and outside campaigns).
    pub probe_violations: Vec<String>,
}

pub type WorldSim = Sim<World, SimEvent>;

impl World {
    pub fn new(cfg: Config, mode: Deployment) -> World {
        let mut cfg = cfg;
        cfg.deployment = mode;
        cfg.resize_bandwidth();
        cfg.validate().expect("invalid config");
        let mut rng = Pcg::seeded(cfg.seed);
        let tracer = Tracer::new();
        let mut wan = Wan::new(cfg.wan.clone(), rng.split(1));
        wan.attach_tracer(tracer.clone());
        let zk = ZkEnsemble::new(cfg.topology.num_dcs());
        let mut markets: Vec<SpotMarket> = (0..cfg.topology.num_dcs())
            .map(|i| SpotMarket::new(&cfg.cloud, rng.split(100 + i as u64)))
            .collect();
        // Workers: spot for decentralized deployments (§6.3), on-demand for
        // the centralized baselines. The configured bid strategy prices
        // every spot acquisition (the naive default reproduces the seed's
        // blind draw bit-for-bit).
        let spot_workers = !mode.centralized();
        let mut bids = HashMap::new();
        let cloud_cfg = cfg.cloud.clone();
        let mut strategy =
            bidding::build_strategy(cfg.topology.num_dcs(), &cfg.cloud, &cfg.bidding);
        let bidding_active = cfg.bidding.active();
        let cluster = Cluster::build(
            &cfg.topology.regions,
            cfg.topology.workers_per_dc,
            cfg.topology.containers_per_worker,
            cfg.topology.racks_per_dc,
            |dc, idx| {
                // §2.3 extension: worker 0 per region can be pinned
                // On-demand so JM containers (spawned from the lowest
                // container ids = node 0) sit on reliable instances.
                let reliable = cloud_cfg.reliable_jm_hosts && idx == 0;
                if spot_workers && !reliable {
                    let node = NodeId { dc, idx };
                    let class = strategy.quote(
                        &BidRequest::calm(dc),
                        &mut markets[dc.0],
                        &cloud_cfg,
                    );
                    if let InstanceClass::Spot { bid } = class {
                        bids.insert(node, bid);
                    }
                    if bidding_active {
                        tracer.publish(TraceEvent::BidPlaced {
                            node,
                            on_demand: !class.is_spot(),
                            bid: match class {
                                InstanceClass::Spot { bid } => bid,
                                InstanceClass::OnDemand => 0.0,
                            },
                        });
                    }
                    class
                } else {
                    InstanceClass::OnDemand
                }
            },
        );
        let mut masters = if mode.centralized() {
            vec![Master::centralized((0..cfg.topology.num_dcs()).map(DcId).collect())]
        } else {
            (0..cfg.topology.num_dcs()).map(|d| Master::new(DcId(d))).collect::<Vec<_>>()
        };
        if !mode.adaptive() && cfg.scheduler.static_fifo {
            // Stock YARN default queue for the static baselines.
            for m in &mut masters {
                m.policy = crate::master::AllocPolicy::Fifo;
            }
        }
        let gen = WorkloadGen::new(&cfg, rng.split(2));
        // Assemble the per-DC parts: each DC owns its market, its master
        // (decentralized) and its hog list; the centralized baselines park
        // their single monolithic master in the global part instead.
        let mut masters = masters.into_iter();
        let central_master = if mode.centralized() { masters.next() } else { None };
        let parts: Vec<DcPart> = markets
            .into_iter()
            .enumerate()
            .map(|(d, market)| DcPart {
                dc: DcId(d),
                market,
                master: if mode.centralized() { None } else { masters.next() },
                hogs: Vec::new(),
            })
            .collect();
        World {
            params: ParadesParams { delta: cfg.scheduler.delta, tau: cfg.scheduler.tau },
            mode,
            cluster,
            wan,
            zk,
            parts,
            global: GlobalPart { central_master },
            strategy,
            cost: CostMeter::default(),
            dfs: Dfs::default(),
            gen,
            jobs: BTreeMap::new(),
            metrics: Metrics::default(),
            tracer,
            rng,
            next_job: 0,
            bids,
            class_changes: Vec::new(),
            trace_done: false,
            hook: None,
            probe_violations: Vec::new(),
            cfg,
        }
    }

    /// Publish one event on the trace bus and fold it into the figure
    /// metrics. Inside loops that hold a `jobs` borrow, use the
    /// field-disjoint split form instead:
    /// `let st = w.tracer.publish(ev); w.metrics.on_event(&st);`
    pub fn emit(&mut self, event: TraceEvent) {
        let stamped = self.tracer.publish(event);
        self.metrics.on_event(&stamped);
    }

    /// Order-sensitive digest of the run's full event stream (same
    /// (config, seed) ⇒ same value) — the replay-check primitive.
    pub fn trace_digest(&self) -> u64 {
        self.tracer.digest()
    }

    /// The master responsible for `dc`: the monolithic central master if
    /// one exists, else the DC's own part master.
    pub fn master_of(&mut self, dc: DcId) -> &mut Master {
        master_for(&mut self.global, &mut self.parts, dc)
    }

    /// Number of master slots (1 centralized, one per DC otherwise) —
    /// the pre-split `masters.len()`.
    pub fn master_count(&self) -> usize {
        if self.global.central_master.is_some() {
            1
        } else {
            self.parts.len()
        }
    }

    /// All masters in stable slot order (the central master alone, or
    /// each DC's master in DC order) — bit-identical iteration order to
    /// the pre-split `Vec<Master>`.
    pub fn masters(&self) -> impl Iterator<Item = &Master> {
        self.global
            .central_master
            .iter()
            .chain(self.parts.iter().filter_map(|p| p.master.as_ref()))
    }

    /// Mutable twin of [`World::masters`], same slot order.
    pub fn masters_mut(&mut self) -> impl Iterator<Item = &mut Master> {
        self.global
            .central_master
            .iter_mut()
            .chain(self.parts.iter_mut().filter_map(|p| p.master.as_mut()))
    }

    /// This DC's spot market (read side).
    pub fn market(&self, dc: usize) -> &SpotMarket {
        &self.parts[dc].market
    }

    /// This DC's spot market (write side).
    pub fn market_mut(&mut self, dc: usize) -> &mut SpotMarket {
        &mut self.parts[dc].market
    }

    /// True when no DC has hog sub-jobs injected.
    pub fn hogs_empty(&self) -> bool {
        self.parts.iter().all(|p| p.hogs.is_empty())
    }

    pub fn alloc_job_id(&mut self) -> JobId {
        let id = JobId(self.next_job);
        self.next_job += 1;
        id
    }

    /// The DCs where a job keeps JM replicas.
    pub fn jm_dcs(&self, home: DcId) -> Vec<DcId> {
        if self.mode.centralized() {
            vec![home]
        } else {
            (0..self.cfg.topology.num_dcs()).map(DcId).collect()
        }
    }

    /// Desired container count for a sub-job under *static* scheduling.
    pub fn static_desire(&self) -> usize {
        if self.mode.centralized() {
            self.cfg.scheduler.static_executors * self.cfg.topology.num_dcs()
        } else {
            self.cfg.scheduler.static_executors
        }
    }

    /// Count of released-but-waiting + running tasks (diagnostics).
    pub fn active_tasks(&self, job: JobId) -> (usize, usize) {
        let rt = &self.jobs[&job];
        (rt.progress.count(TaskStatus::Waiting), rt.progress.count(TaskStatus::Running))
    }

    /// All live (job, dc) JM keys, for iteration without borrow fights.
    pub fn live_jm_keys(&self) -> Vec<(JobId, DcId)> {
        self.jobs
            .iter()
            .filter(|(_, rt)| !rt.done)
            .flat_map(|(&id, rt)| {
                rt.jms.iter().filter(|(_, jm)| jm.alive).map(move |(&d, _)| (id, d))
            })
            .collect()
    }

    /// Bill machines for `makespan_secs` of cluster time (§6.3 model:
    /// the whole testbed is rented for the duration of the workload).
    /// A node whose class flipped mid-run (a strategy re-acquisition
    /// recorded in [`World::class_changes`]) is billed per segment at
    /// each segment's own rate; without flips this is the original
    /// whole-makespan charge, bit for bit.
    pub fn bill_machines(&mut self, makespan_secs: f64) {
        let hours = makespan_secs / 3600.0;
        let num_dcs = self.cfg.topology.num_dcs();
        // One on-demand master VM per region (all deployments).
        for _ in 0..num_dcs {
            self.cost.charge_machine(InstanceClass::OnDemand, hours, self.cfg.cloud.on_demand_hourly);
        }
        let od_rate = self.cfg.cloud.on_demand_hourly;
        let spot_rate = self.cfg.cloud.spot_hourly_mean;
        let rate = |class: InstanceClass| match class {
            InstanceClass::OnDemand => od_rate,
            InstanceClass::Spot { .. } => spot_rate,
        };
        for d in 0..num_dcs {
            for node in self.cluster.node_ids(DcId(d)) {
                let mut prev = 0.0f64;
                for &(n, t, class_before) in &self.class_changes {
                    if n != node {
                        continue;
                    }
                    let upto = t.clamp(0.0, makespan_secs);
                    let seg = (upto - prev).max(0.0);
                    self.cost.charge_machine(class_before, seg / 3600.0, rate(class_before));
                    prev = prev.max(upto);
                }
                let class = self.cluster.node_class(node);
                let seg = (makespan_secs - prev).max(0.0);
                self.cost.charge_machine(class, seg / 3600.0, rate(class));
            }
        }
        let bytes = self.wan.stats.cross_dc_total_bytes();
        self.cost.charge_transfer(bytes, self.cfg.cloud.transfer_per_gb);
        self.emit(TraceEvent::RunBilled {
            machine_usd: self.cost.machine_usd,
            transfer_usd: self.cost.transfer_usd,
        });
    }

    /// Role of the JM at (job, dc), if alive.
    pub fn jm_role(&self, job: JobId, dc: DcId) -> Option<Role> {
        self.jobs.get(&job)?.jms.get(&dc).filter(|j| j.alive).map(|j| j.role)
    }

    /// How far behind schedule the worst active job is, in [0, 1]: a job
    /// whose elapsed time plus remaining critical-path estimate projects
    /// past `workload.deadline_secs` is behind; 1 means ≥ 100 % overshoot.
    /// 0 when no deadline is configured — the deadline strategy then never
    /// turns aggressive.
    pub fn job_urgency(&self, now_secs: f64) -> f64 {
        let deadline = self.cfg.workload.deadline_secs;
        if deadline <= 0.0 {
            return 0.0;
        }
        let mut urgency = 0.0f64;
        for rt in self.jobs.values().filter(|rt| !rt.done) {
            let projected = (now_secs - rt.submitted_secs) + rt.remaining_critical_path();
            urgency = urgency.max((projected / deadline - 1.0).clamp(0.0, 1.0));
        }
        urgency
    }

    /// Whether any active job has exhausted its `workload.budget_usd`
    /// (0 = unlimited): the deadline strategy's aggression cap.
    pub fn any_over_budget(&self) -> bool {
        let budget = self.cfg.workload.budget_usd;
        budget > 0.0
            && self.jobs.values().any(|rt| !rt.done && rt.cost.total_usd() > budget)
    }
}
