//! The typed DES event vocabulary ([`SimEvent`]) and its dispatcher.
//!
//! Every recurring event shape in the deployment layer — lifecycle steps,
//! scheduling/heartbeat/market/WAN ticks, steal protocol messages,
//! failure detection and recovery, and the scenario engine's chaos
//! injections — is one variant of [`SimEvent`]. The engine dispatches a
//! variant by matching on it ([`Dispatch::dispatch`]) instead of calling
//! a boxed closure, so the common scheduling path allocates nothing
//! beyond what the event's own payload needs (most variants are a few
//! `Copy` ids; only task shipments, steal grants and chaos labels carry
//! owned data).
//!
//! Because variants are plain data, an executed event stream can be
//! *persisted*: [`SimEvent::log_line`] renders the canonical
//! `{"t":..,"seq":..,"ev":..,...}` JSON line that
//! [`crate::scenario::replay`] records with `houtu campaign --record` and
//! verifies with `houtu replay`. Closures scheduled through
//! [`crate::sim::Sim::schedule_at`] still work (tests, `every` ticks, the
//! invariant probe) — they ride the `Custom` payload arm and log as
//! `"ev":"custom"` markers.
//!
//! # Taxonomy
//!
//! | family | variants |
//! |---|---|
//! | lifecycle | `SubmitJob`, `SpawnJm`, `ReleaseReady`, `EnqueueTasks`, `ContainerUpdate`, `TaskFinished` |
//! | network | `EndTransfer` |
//! | periodic | `Tick` (scheduling period, heartbeat, WAN resample, spot market) |
//! | stealing | `StealAtVictim`, `StealResponse` |
//! | failure/recovery | `RestartNode`, `DetectJmFailure`, `RespawnJm`, `ElectPrimary`, `CascadeKill` |
//! | chaos | `ChaosInjectHogs`, `ChaosKillJm`, `ChaosCascade`, `ChaosKillNode`, `ChaosKillDc`, `ChaosWanDegrade`, `ChaosSpotStorm`, `ChaosWanPairDegrade` |

use crate::dag::{SizeClass, WorkloadKind};
use crate::ids::{ContainerId, DcId, JobId, NodeId, TaskId};
use crate::jm::{Assignment, ContainerView, Role, WaitingTask};
use crate::sim::{Dispatch, SimTime};
use crate::trace::TraceEvent;
use crate::util::json;

use super::world::{World, WorldSim};
use super::{failure, lifecycle, scheduling};

/// Which recurring world timer a [`SimEvent::Tick`] drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TickKind {
    /// Scheduling-period boundary (§4.2): Af → desires → allocation.
    Period,
    /// JM heartbeat: re-offer free executors, stragglers, stealing.
    Heartbeat,
    /// WAN bandwidth re-sampling.
    WanResample,
    /// Spot-market price step + revocations.
    Market,
}

impl TickKind {
    pub fn name(self) -> &'static str {
        match self {
            TickKind::Period => "period",
            TickKind::Heartbeat => "heartbeat",
            TickKind::WanResample => "wan_resample",
            TickKind::Market => "market",
        }
    }
}

/// One typed simulation event. See the module docs for the taxonomy.
#[derive(Debug, Clone)]
pub enum SimEvent {
    /// A trace arrival: submit a job (§3.1 step 0).
    SubmitJob { kind: WorkloadKind, size: SizeClass, home: DcId },
    /// Create the (job, dc) JM replica (steps 1–2b); retries itself while
    /// the DC has no free container.
    SpawnJm { job: JobId, dc: DcId },
    /// pJM releases every stage whose parents completed (step 3).
    ReleaseReady { job: JobId },
    /// A taskMap shipment lands at the (job, dc) JM's queue.
    EnqueueTasks { job: JobId, dc: DcId, tasks: Vec<WaitingTask>, generation: u32 },
    /// UPDATE: one container reports free capacity; Parades assigns.
    ContainerUpdate { job: JobId, dc: DcId, cid: ContainerId },
    /// A WAN transfer on the (from, to) link completes.
    EndTransfer { from: DcId, to: DcId },
    /// A task attempt finishes on `cid` (step 5). Stale attempts drop.
    TaskFinished { job: JobId, dc: DcId, task: TaskId, cid: ContainerId, attempt: u32 },
    /// A recurring world timer fires, then re-arms itself while
    /// `now + period ≤ horizon`.
    Tick { kind: TickKind, period: SimTime, horizon: SimTime },
    /// ONRECEIVESTEAL: the thief's offered container arrives at the victim.
    StealAtVictim { job: JobId, victim: DcId, thief: DcId, view: ContainerView, sent_at: f64 },
    /// The stolen tasks arrive back at the thief.
    StealResponse { job: JobId, thief: DcId, victim: DcId, stolen: Vec<Assignment>, sent_at: f64 },
    /// A killed worker VM re-acquires a (re-priced) replacement instance.
    RestartNode { node: NodeId, slots: usize },
    /// The zk session timeout elapses after a JM host died (§3.2.2).
    DetectJmFailure { job: JobId, dc: DcId },
    /// Regenerate a JM replica; inherits containers via master tokens.
    RespawnJm { job: JobId, dc: DcId, role: Role, failed_at: f64 },
    /// Election among live sJMs after the pJM died.
    ElectPrimary { job: JobId, failed_dc: DcId, failed_at: f64 },
    /// Next kill of a `kill_jm_cascade` chain (`target` = None hits the
    /// current primary; polls until one is live).
    CascadeKill { job: JobId, target: Option<DcId>, remaining: u32, gap: SimTime },
    /// Chaos: occupy (almost) all spare containers of the DCs (Fig 9).
    ChaosInjectHogs { label: String, dcs: Vec<DcId> },
    /// Chaos: kill the VM hosting job 0's JM in a DC (Fig 11).
    ChaosKillJm { label: String, job: JobId, dc: DcId },
    /// Chaos: start a cascading JM-kill chain.
    ChaosCascade { label: String, job: JobId, dc: DcId, count: u32, gap: SimTime },
    /// Chaos: spot-style termination of one worker VM.
    ChaosKillNode { label: String, node: NodeId },
    /// Chaos: correlated whole-DC outage.
    ChaosKillDc { label: String, dc: DcId },
    /// Chaos: scale all cross-DC bandwidth (1.0 restores).
    ChaosWanDegrade { factor: f64 },
    /// Chaos: scale one region's spot-price volatility (1.0 restores).
    ChaosSpotStorm { dc: usize, factor: f64 },
    /// Chaos: scale one (a, b) link only (asymmetric partition).
    ChaosWanPairDegrade { label: String, a: DcId, b: DcId, factor: f64 },
}

impl Dispatch<World> for SimEvent {
    fn dispatch(self, sim: &mut WorldSim) {
        match self {
            SimEvent::SubmitJob { kind, size, home } => {
                lifecycle::submit_job(sim, kind, size, home);
            }
            SimEvent::SpawnJm { job, dc } => {
                lifecycle::spawn_jm(sim, job, dc);
            }
            SimEvent::ReleaseReady { job } => {
                lifecycle::release_ready(sim, job);
            }
            SimEvent::EnqueueTasks { job, dc, tasks, generation } => {
                lifecycle::enqueue_tasks(sim, job, dc, tasks, generation);
            }
            SimEvent::ContainerUpdate { job, dc, cid } => {
                lifecycle::container_update(sim, job, dc, cid);
            }
            SimEvent::EndTransfer { from, to } => {
                sim.state.wan.end_transfer(from, to);
            }
            SimEvent::TaskFinished { job, dc, task, cid, attempt } => {
                lifecycle::task_finished(sim, job, dc, task, cid, attempt);
            }
            SimEvent::Tick { kind, period, horizon } => {
                match kind {
                    TickKind::Period => scheduling::period_tick(sim),
                    TickKind::Heartbeat => scheduling::heartbeat_tick(sim),
                    TickKind::WanResample => sim.state.wan.resample(),
                    TickKind::Market => failure::market_tick(sim),
                }
                arm_tick(sim, kind, period, horizon);
            }
            SimEvent::StealAtVictim { job, victim, thief, view, sent_at } => {
                scheduling::steal_at_victim(sim, job, victim, thief, view, sent_at);
            }
            SimEvent::StealResponse { job, thief, victim, stolen, sent_at } => {
                scheduling::steal_response(sim, job, thief, victim, stolen, sent_at);
            }
            SimEvent::RestartNode { node, slots } => {
                failure::restart_node(sim, node, slots);
            }
            SimEvent::DetectJmFailure { job, dc } => {
                failure::detect_jm_failure(sim, job, dc);
            }
            SimEvent::RespawnJm { job, dc, role, failed_at } => {
                failure::respawn_jm(sim, job, dc, role, failed_at);
            }
            SimEvent::ElectPrimary { job, failed_dc, failed_at } => {
                failure::elect_new_primary(sim, job, failed_dc, failed_at);
            }
            SimEvent::CascadeKill { job, target, remaining, gap } => {
                failure::cascade_kill(sim, job, target, remaining, gap);
            }
            SimEvent::ChaosInjectHogs { label, dcs } => {
                sim.state.emit(TraceEvent::ChaosInjected { label });
                failure::inject_hogs(sim, &dcs);
            }
            SimEvent::ChaosKillJm { label, job, dc } => {
                sim.state.emit(TraceEvent::ChaosInjected { label });
                failure::kill_jm_host(sim, job, dc);
            }
            SimEvent::ChaosCascade { label, job, dc, count, gap } => {
                sim.state.emit(TraceEvent::ChaosInjected { label });
                failure::cascade_kill(sim, job, Some(dc), count, gap);
            }
            SimEvent::ChaosKillNode { label, node } => {
                sim.state.emit(TraceEvent::ChaosInjected { label });
                failure::kill_node(sim, node);
            }
            SimEvent::ChaosKillDc { label, dc } => {
                sim.state.emit(TraceEvent::ChaosInjected { label });
                failure::kill_dc(sim, dc);
            }
            SimEvent::ChaosWanDegrade { factor } => {
                sim.state.emit(TraceEvent::ChaosInjected { label: format!("wan-factor={factor}") });
                sim.state.wan.set_degrade(factor);
            }
            SimEvent::ChaosSpotStorm { dc, factor } => {
                sim.state.emit(TraceEvent::ChaosInjected {
                    label: format!("spot_storm:dc{dc}-factor={factor}"),
                });
                sim.state.parts[dc].market.set_storm(factor);
            }
            SimEvent::ChaosWanPairDegrade { label, a, b, factor } => {
                sim.state.emit(TraceEvent::ChaosInjected { label });
                sim.state.wan.set_pair_degrade(a, b, factor);
            }
        }
    }

    /// Which DC's shard should own this event under
    /// [`crate::sim::QueueKind::Sharded`]. Routing is *advisory*: the
    /// sharded queue is an exact `(time, seq)` merge, so any mapping —
    /// including the `None → shard 0` fallback used by global events
    /// like `Tick` and WAN-wide chaos — produces bit-identical runs.
    fn affinity(&self) -> Option<usize> {
        match self {
            SimEvent::SubmitJob { home, .. } => Some(home.0),
            SimEvent::SpawnJm { dc, .. }
            | SimEvent::EnqueueTasks { dc, .. }
            | SimEvent::ContainerUpdate { dc, .. }
            | SimEvent::TaskFinished { dc, .. }
            | SimEvent::DetectJmFailure { dc, .. }
            | SimEvent::RespawnJm { dc, .. }
            | SimEvent::ChaosKillJm { dc, .. }
            | SimEvent::ChaosCascade { dc, .. }
            | SimEvent::ChaosKillDc { dc, .. } => Some(dc.0),
            SimEvent::ReleaseReady { .. } => None,
            SimEvent::EndTransfer { to, .. } => Some(to.0),
            SimEvent::StealAtVictim { victim, .. } => Some(victim.0),
            SimEvent::StealResponse { thief, .. } => Some(thief.0),
            SimEvent::RestartNode { node, .. } | SimEvent::ChaosKillNode { node, .. } => {
                Some(node.dc.0)
            }
            SimEvent::ElectPrimary { failed_dc, .. } => Some(failed_dc.0),
            SimEvent::CascadeKill { target, .. } => target.map(|dc| dc.0),
            SimEvent::ChaosSpotStorm { dc, .. } => Some(*dc),
            SimEvent::Tick { .. }
            | SimEvent::ChaosInjectHogs { .. }
            | SimEvent::ChaosWanDegrade { .. }
            | SimEvent::ChaosWanPairDegrade { .. } => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            SimEvent::SubmitJob { .. } => "submit_job",
            SimEvent::SpawnJm { .. } => "spawn_jm",
            SimEvent::ReleaseReady { .. } => "release_ready",
            SimEvent::EnqueueTasks { .. } => "enqueue_tasks",
            SimEvent::ContainerUpdate { .. } => "container_update",
            SimEvent::EndTransfer { .. } => "end_transfer",
            SimEvent::TaskFinished { .. } => "task_finished",
            SimEvent::Tick { kind: TickKind::Period, .. } => "tick:period",
            SimEvent::Tick { kind: TickKind::Heartbeat, .. } => "tick:heartbeat",
            SimEvent::Tick { kind: TickKind::WanResample, .. } => "tick:wan_resample",
            SimEvent::Tick { kind: TickKind::Market, .. } => "tick:market",
            SimEvent::StealAtVictim { .. } => "steal_at_victim",
            SimEvent::StealResponse { .. } => "steal_response",
            SimEvent::RestartNode { .. } => "restart_node",
            SimEvent::DetectJmFailure { .. } => "detect_jm_failure",
            SimEvent::RespawnJm { .. } => "respawn_jm",
            SimEvent::ElectPrimary { .. } => "elect_primary",
            SimEvent::CascadeKill { .. } => "cascade_kill",
            SimEvent::ChaosInjectHogs { .. } => "chaos:hogs",
            SimEvent::ChaosKillJm { .. } => "chaos:kill_jm",
            SimEvent::ChaosCascade { .. } => "chaos:kill_jm_cascade",
            SimEvent::ChaosKillNode { .. } => "chaos:kill_node",
            SimEvent::ChaosKillDc { .. } => "chaos:kill_dc",
            SimEvent::ChaosWanDegrade { .. } => "chaos:wan",
            SimEvent::ChaosSpotStorm { .. } => "chaos:spot_storm",
            SimEvent::ChaosWanPairDegrade { .. } => "chaos:wan_pair",
        }
    }
}

/// Schedule the next [`SimEvent::Tick`] unless it would land past the
/// horizon — shared by [`scheduling::install_timers`] (the initial arm)
/// and the tick's own dispatch (the re-arm), so the two can never drift.
pub(super) fn arm_tick(sim: &mut WorldSim, kind: TickKind, period: SimTime, horizon: SimTime) {
    if sim.now() + period > horizon {
        return;
    }
    sim.schedule_event_in(period, SimEvent::Tick { kind, period, horizon });
}

impl SimEvent {
    /// Render the canonical event-log line for this event as executed at
    /// `(t, seq)`. The stream of these lines is what `houtu campaign
    /// --record` persists and `houtu replay` verifies (see
    /// [`crate::scenario::replay`] for the file schema). Lines are
    /// compared *as strings*, so any deterministic rendering works; this
    /// one is also valid JSON for offline tooling.
    pub fn log_line(&self, t: SimTime, seq: u64) -> String {
        format!("{{\"t\":{t},\"seq\":{seq},{}}}", self.log_fields())
    }

    fn log_fields(&self) -> String {
        let ev = |name: &str, rest: String| {
            if rest.is_empty() {
                format!("\"ev\":\"{name}\"")
            } else {
                format!("\"ev\":\"{name}\",{rest}")
            }
        };
        match self {
            SimEvent::SubmitJob { kind, size, home } => ev(
                "submit_job",
                format!("\"kind\":\"{}\",\"size\":\"{}\",\"home\":{}", kind.name(), size.name(), home.0),
            ),
            SimEvent::SpawnJm { job, dc } => {
                ev("spawn_jm", format!("\"job\":{},\"dc\":{}", job.0, dc.0))
            }
            SimEvent::ReleaseReady { job } => ev("release_ready", format!("\"job\":{}", job.0)),
            SimEvent::EnqueueTasks { job, dc, tasks, generation } => ev(
                "enqueue_tasks",
                format!("\"job\":{},\"dc\":{},\"n\":{},\"gen\":{}", job.0, dc.0, tasks.len(), generation),
            ),
            SimEvent::ContainerUpdate { job, dc, cid } => ev(
                "container_update",
                format!("\"job\":{},\"dc\":{},\"c\":{}", job.0, dc.0, cid.0),
            ),
            SimEvent::EndTransfer { from, to } => {
                ev("end_transfer", format!("\"from\":{},\"to\":{}", from.0, to.0))
            }
            SimEvent::TaskFinished { job, dc, task, cid, attempt } => ev(
                "task_finished",
                format!(
                    "\"job\":{},\"dc\":{},\"task\":\"{task}\",\"c\":{},\"attempt\":{attempt}",
                    job.0, dc.0, cid.0
                ),
            ),
            SimEvent::Tick { kind, .. } => ev("tick", format!("\"kind\":\"{}\"", kind.name())),
            SimEvent::StealAtVictim { job, victim, thief, view, sent_at } => ev(
                "steal_at_victim",
                format!(
                    "\"job\":{},\"victim\":{},\"thief\":{},\"c\":{},\"sent\":{sent_at}",
                    job.0, victim.0, thief.0, view.id.0
                ),
            ),
            SimEvent::StealResponse { job, thief, victim, stolen, sent_at } => ev(
                "steal_response",
                format!(
                    "\"job\":{},\"thief\":{},\"victim\":{},\"n\":{},\"sent\":{sent_at}",
                    job.0, thief.0, victim.0, stolen.len()
                ),
            ),
            SimEvent::RestartNode { node, slots } => {
                ev("restart_node", format!("\"node\":\"{node}\",\"slots\":{slots}"))
            }
            SimEvent::DetectJmFailure { job, dc } => {
                ev("detect_jm_failure", format!("\"job\":{},\"dc\":{}", job.0, dc.0))
            }
            SimEvent::RespawnJm { job, dc, role, failed_at } => ev(
                "respawn_jm",
                format!(
                    "\"job\":{},\"dc\":{},\"role\":\"{}\",\"failed_at\":{failed_at}",
                    job.0,
                    dc.0,
                    match role {
                        Role::Primary => "primary",
                        Role::SemiActive => "semi",
                    }
                ),
            ),
            SimEvent::ElectPrimary { job, failed_dc, failed_at } => ev(
                "elect_primary",
                format!("\"job\":{},\"failed_dc\":{},\"failed_at\":{failed_at}", job.0, failed_dc.0),
            ),
            SimEvent::CascadeKill { job, target, remaining, .. } => ev(
                "cascade_kill",
                format!(
                    "\"job\":{},\"target\":{},\"remaining\":{remaining}",
                    job.0,
                    match target {
                        Some(dc) => dc.0.to_string(),
                        None => "null".to_string(),
                    }
                ),
            ),
            SimEvent::ChaosInjectHogs { label, .. }
            | SimEvent::ChaosKillJm { label, .. }
            | SimEvent::ChaosCascade { label, .. }
            | SimEvent::ChaosKillNode { label, .. }
            | SimEvent::ChaosKillDc { label, .. }
            | SimEvent::ChaosWanPairDegrade { label, .. } => {
                format!("\"ev\":\"chaos\",\"label\":{}", json::escape(label))
            }
            SimEvent::ChaosWanDegrade { factor } => {
                ev("chaos_wan", format!("\"factor\":{factor}"))
            }
            SimEvent::ChaosSpotStorm { dc, factor } => {
                ev("chaos_spot_storm", format!("\"dc\":{dc},\"factor\":{factor}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Dispatch as _;

    #[test]
    fn log_lines_are_valid_json_with_stamps() {
        let evs = [
            SimEvent::SpawnJm { job: JobId(3), dc: DcId(1) },
            SimEvent::ReleaseReady { job: JobId(0) },
            SimEvent::EnqueueTasks { job: JobId(1), dc: DcId(2), tasks: vec![], generation: 4 },
            SimEvent::ContainerUpdate { job: JobId(0), dc: DcId(0), cid: ContainerId(17) },
            SimEvent::EndTransfer { from: DcId(0), to: DcId(3) },
            SimEvent::Tick { kind: TickKind::Heartbeat, period: 1000, horizon: 9000 },
            SimEvent::RestartNode { node: NodeId { dc: DcId(1), idx: 4 }, slots: 2 },
            SimEvent::CascadeKill { job: JobId(0), target: None, remaining: 2, gap: 1000 },
            SimEvent::ChaosKillDc { label: "kill_dc@60:dc2 \"quoted\"".into(), dc: DcId(2) },
            SimEvent::ChaosWanDegrade { factor: 0.25 },
        ];
        for e in &evs {
            let line = e.log_line(1234, 56);
            let doc = json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
            assert_eq!(doc.get("t").and_then(json::Json::as_u64), Some(1234), "{line}");
            assert_eq!(doc.get("seq").and_then(json::Json::as_u64), Some(56), "{line}");
            assert!(doc.get("ev").and_then(json::Json::as_str).is_some(), "{line}");
        }
    }

    #[test]
    fn affinity_follows_the_owning_dc() {
        let dc_scoped = SimEvent::SpawnJm { job: JobId(0), dc: DcId(2) };
        assert_eq!(dc_scoped.affinity(), Some(2));
        let node_scoped =
            SimEvent::RestartNode { node: NodeId { dc: DcId(1), idx: 9 }, slots: 2 };
        assert_eq!(node_scoped.affinity(), Some(1));
        let global = SimEvent::Tick { kind: TickKind::Period, period: 1, horizon: 2 };
        assert_eq!(global.affinity(), None);
        let transfer = SimEvent::EndTransfer { from: DcId(0), to: DcId(3) };
        assert_eq!(transfer.affinity(), Some(3));
    }

    #[test]
    fn kinds_are_distinct_per_variant_family() {
        let a = SimEvent::SpawnJm { job: JobId(0), dc: DcId(0) };
        let b = SimEvent::Tick { kind: TickKind::Market, period: 1, horizon: 2 };
        assert_eq!(a.kind(), "spawn_jm");
        assert_eq!(b.kind(), "tick:market");
    }
}
